"""Quickstart: QERA in ~40 lines.

Quantize one linear layer with every method and compare output errors —
Theorem 1 (QERA-exact) should win, Theorem 2 (QERA-approx) should be close.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import (
    empirical_output_error, solve_lqer, solve_qera_approx, solve_qera_exact,
    solve_zeroquant_v2, stats_from_samples,
)
from repro.quant import get_quantizer

key = jax.random.PRNGKey(0)
k1, k2, k3 = jax.random.split(key, 3)

# a "pretrained" linear layer y = x W and a realistic (correlated) input dist
m, n, rank = 128, 96, 8
w = jax.random.normal(k1, (m, n)) / jnp.sqrt(m)
mix = jnp.eye(m) + 0.5 * jax.random.normal(k2, (m, m)) / jnp.sqrt(m)
x = (jax.random.normal(k3, (4096, m)) * jnp.exp(jax.random.normal(k1, (m,)))) @ mix

# calibrate, quantize to 2-bit MXINT, reconstruct with rank-8 terms
stats = stats_from_samples(x)          # R_XX, E[x^2], E[|x|]
w_tilde = get_quantizer("mxint2")(w)

for name, (a, b) in {
    "zeroquant_v2 (SVD of weight error)":
        solve_zeroquant_v2(w, w_tilde, rank),
    "lqer        (heuristic S=E|x|)   ":
        solve_lqer(w, w_tilde, rank, stats.mean_abs),
    "qera_approx (Theorem 2)          ":
        solve_qera_approx(w, w_tilde, rank, stats.mean_x2),
    "qera_exact  (Theorem 1)          ":
        solve_qera_exact(w, w_tilde, rank, stats.rxx),
}.items():
    err = empirical_output_error(x, w_tilde + a @ b - w)
    print(f"{name}  E||y - ŷ||² = {float(err):.5f}")
print("(w-only baseline                  "
      f"  E||y - ŷ||² = {float(empirical_output_error(x, w_tilde - w)):.5f})")
