"""Serve a QERA-quantized model with continuous batching: quantize, submit a
mixed batch of requests, stream greedy tokens, verify against fp32 rollouts.

    PYTHONPATH=src python examples/serve_quantized.py
"""
import pathlib
import sys
_root = str(pathlib.Path(__file__).resolve().parent.parent)
sys.path.insert(0, _root) if _root not in sys.path else None

import numpy as np

from benchmarks.common import LM_CFG, calib_batches, calibrate, pretrained_lm, ptq
from repro.serve.batching import ContinuousBatcher, Request

params = pretrained_lm(steps=300)
stats = calibrate(params, LM_CFG, calib_batches(32))
qparams = ptq(params, LM_CFG, "qera_exact", rank=16, quantizer="mxint4",
              stats=stats)

rng = np.random.default_rng(0)
prompts = [rng.integers(0, 256, size=ln).astype(np.int32)
           for ln in [5, 9, 3, 7]]

outputs = {}
for paged in (False, True):
    # chunk_tokens=4 exercises multi-chunk admission (prompts up to 9 tokens
    # prefill over 2-3 ticks, interleaved with running slots' decode ticks)
    batcher = ContinuousBatcher(qparams, LM_CFG, num_slots=2, max_len=96,
                                paged=paged, page_size=16, chunk_tokens=4)
    reqs = [Request(rid=i, prompt=p, max_new_tokens=12)
            for i, p in enumerate(prompts)]
    for r in reqs:
        batcher.submit(r)
    batcher.run()
    outputs[paged] = [r.output for r in reqs]
    mode = "paged" if paged else "dense"
    for r in reqs:
        print(f"[{mode}] req {r.rid}: prompt {r.prompt.tolist()} -> {r.output}")

assert outputs[False] == outputs[True], "paged KV diverged from dense cache"
print("paged == dense: token-identical outputs")
