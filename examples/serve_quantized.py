"""Serve a QERA-quantized model with continuous batching: quantize, submit a
mixed batch of requests, stream greedy tokens, verify against fp32 rollouts.

    PYTHONPATH=src python examples/serve_quantized.py
"""
import pathlib
import sys
_root = str(pathlib.Path(__file__).resolve().parent.parent)
sys.path.insert(0, _root) if _root not in sys.path else None

import numpy as np

from benchmarks.common import LM_CFG, calib_batches, calibrate, pretrained_lm, ptq
from repro.serve.batching import ContinuousBatcher, Request

params = pretrained_lm(steps=300)
stats = calibrate(params, LM_CFG, calib_batches(32))
qparams = ptq(params, LM_CFG, "qera_exact", rank=16, quantizer="mxint4",
              stats=stats)

rng = np.random.default_rng(0)
prompts = [rng.integers(0, 256, size=ln).astype(np.int32)
           for ln in [5, 9, 3, 7]]

outputs = {}
for paged in (False, True):
    # chunk_tokens=4 exercises multi-chunk admission (prompts up to 9 tokens
    # prefill over 2-3 ticks, interleaved with running slots' decode ticks)
    batcher = ContinuousBatcher(qparams, LM_CFG, num_slots=2, max_len=96,
                                paged=paged, page_size=16, chunk_tokens=4)
    reqs = [Request(rid=i, prompt=p, max_new_tokens=12)
            for i, p in enumerate(prompts)]
    for r in reqs:
        batcher.submit(r)
    batcher.run()
    outputs[paged] = [r.output for r in reqs]
    mode = "paged" if paged else "dense"
    for r in reqs:
        print(f"[{mode}] req {r.rid}: prompt {r.prompt.tolist()} -> {r.output}")

assert outputs[False] == outputs[True], "paged KV diverged from dense cache"
print("paged == dense: token-identical outputs")

# -- prefix caching: a shared system prompt across requests ------------------
# Requests 2..N share request 1's 32-token preamble (two full 16-token
# pages). With prefix_cache=True the warm admissions match the cached
# hash-chain, point their page tables at the shared physical pages
# (refcounted, copy-on-write on divergence) and prefill only the suffix —
# outputs must stay token-identical to the cold run above.
preamble = rng.integers(0, 256, size=32).astype(np.int32)
shared_prompts = [np.concatenate([preamble, p]) for p in prompts]
shared_out = {}
for prefix_cache in (False, True):
    batcher = ContinuousBatcher(qparams, LM_CFG, num_slots=2, max_len=96,
                                paged=True, page_size=16, chunk_tokens=8,
                                prefix_cache=prefix_cache)
    reqs = [Request(rid=i, prompt=p, max_new_tokens=12)
            for i, p in enumerate(shared_prompts)]
    for r in reqs:
        batcher.submit(r)
    batcher.run()
    shared_out[prefix_cache] = [r.output for r in reqs]
    if prefix_cache:
        pfx = batcher.prefix
        print(f"[prefix-cache] {pfx.hits} hits, {pfx.hit_tokens} prompt "
              f"tokens served from cache, {batcher.cow_forks} CoW forks")
        assert pfx.hit_tokens >= 32, "warm admissions missed the preamble"

assert shared_out[False] == shared_out[True], \
    "prefix-cached run diverged from cold cache"
print("prefix cache == cold: token-identical outputs")
