"""End-to-end training driver example: train a ~small LM for a few hundred
steps on the synthetic corpus with checkpoints + resume (thin wrapper around
launch/train.py; use --preset 100m for the 100M-param config).

    PYTHONPATH=src python examples/train_lm.py --steps 200
"""
import sys
from repro.launch.train import main

if __name__ == "__main__":
    if len(sys.argv) == 1:
        sys.argv += ["--arch", "minicpm-2b", "--reduced", "--steps", "200",
                     "--batch", "16", "--seq", "64",
                     "--ckpt-dir", "experiments/train_lm_ckpt"]
    main()
