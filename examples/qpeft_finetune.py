"""QPEFT: quantize a pretrained LM to 2-bit, initialize the adapters with
QLoRA / LoftQ / QERA-approx, fine-tune ONLY the adapters, watch convergence
(Figure 2 / Table 2 in miniature).

    PYTHONPATH=src python examples/qpeft_finetune.py
"""
import dataclasses
import sys
sys.path.insert(0, "benchmarks") if "benchmarks" not in sys.path else None

import jax.numpy as jnp

from benchmarks.common import (
    LM_CFG, LM_DATA, calib_batches, calibrate, eval_ce, pretrained_lm, ptq,
)
from repro.core.qpeft import qpeft_finetune
from repro.data.tokenstream import make_batch
from repro.models.transformer import lm_loss
from repro.train import OptimizerConfig

params = pretrained_lm(steps=300)
stats = calibrate(params, LM_CFG, calib_batches(32))
opt = OptimizerConfig(peak_lr=1e-3, schedule="cosine", warmup_steps=8,
                      total_steps=60, weight_decay=0.0)

def batches(n):
    dc = dataclasses.replace(LM_DATA, seed=777)
    for s in range(n):
        yield {k: jnp.asarray(v) for k, v in make_batch(dc, s).items()}

print(f"fp32 CE {eval_ce(params, LM_CFG):.4f}")
for method in ["qlora", "loftq", "qera_approx"]:
    qp = ptq(params, LM_CFG, method, rank=16, quantizer="mxint2", stats=stats)
    ce0 = eval_ce(qp, LM_CFG)
    tuned, losses = qpeft_finetune(
        qp, lambda p, b: lm_loss(p, b, LM_CFG), batches(60), opt)
    print(f"{method:12s} init CE {ce0:.4f} -> tuned CE "
          f"{eval_ce(tuned, LM_CFG):.4f}  (train loss "
          f"{losses[0]:.3f}->{losses[-1]:.3f})")
