"""End-to-end PTQ: pretrain a small LM -> calibrate -> quantize every linear
with QERA -> compare held-out CE across methods (Table 3 in miniature).

    PYTHONPATH=src python examples/ptq_pipeline.py
"""
import pathlib
import sys
_root = str(pathlib.Path(__file__).resolve().parent.parent)
sys.path.insert(0, _root) if _root not in sys.path else None

from benchmarks.common import (
    LM_CFG, calib_batches, calibrate, eval_ce, pretrained_lm, ptq,
)

params = pretrained_lm(steps=300)
stats = calibrate(params, LM_CFG, calib_batches(64))
print(f"fp32 held-out CE: {eval_ce(params, LM_CFG):.4f}")
for method in ["zeroquant_v2", "lqer", "qera_approx", "qera_exact"]:
    qp = ptq(params, LM_CFG, method, rank=16, quantizer="mxint2", stats=stats)
    print(f"mxint2 + {method:13s} rank 16: CE {eval_ce(qp, LM_CFG):.4f}")
