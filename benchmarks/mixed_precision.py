"""Mixed-precision allocation benchmark: equal HBM, spent better.

The allocator (``core/allocate.py``) minimizes the summed QERA expected
output error under the SAME weights-HBM budget the uniform mxint4/r32
operating point spends.  Sections:

* **quality** — for each audited registry arch (reduced shapes, calibrated
  second moments): the uniform reference error, the allocated mixed-plan
  error, and the byte budgets of both.  The run FAILS unless the mixed
  plan is strictly better on at least ``MIN_WINS`` archs at no more HBM —
  the tentpole acceptance bar, asserted where CI can see it.
* **serving** — the calibrated bench LM quantized+packed twice (uniform
  vs allocated plan at equal budget): decode tokens/sec of both trees
  through ``scan_generate``, plus the autotuner warming the mixed tree's
  decode geometries (cache hit/miss counts recorded — the second warm
  must be 100% hits, the determinism contract).

Results land in ``experiments/bench/mixed_precision.json`` and the
consolidated ``bench.json`` (section ``mixed_precision``).
"""

from __future__ import annotations

import json
import time
from pathlib import Path

import jax
import numpy as np

from benchmarks.common import LM_CFG, calib_batches, calibrate, pretrained_lm
from repro.configs import get_arch
from repro.core import PTQConfig, quantize_params
from repro.core.allocate import (
    LayerChoice,
    allocate_plan,
    eligible_shapes,
    plan_bytes,
    plan_expected_error,
    uniform_plan,
)
from repro.core.api import pack_for_serving
from repro.models import init_params
from repro.models.config import reduced
from repro.serve.engine import scan_generate

BENCH_JSON = (Path(__file__).resolve().parent.parent / "experiments"
              / "bench" / "mixed_precision.json")

QUALITY_ARCHS = ("minicpm-2b", "yi-34b", "phi3-mini-3.8b")
MIN_WINS = 2
REFERENCE = LayerChoice("mxint4", 32)
B, PROMPT_LEN, STEPS = 4, 8, 16


def _calibrated_arch(arch: str):
    cfg = reduced(get_arch(arch), scan_layers=False)
    params = init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0,
                              cfg.vocab_size)
    return cfg, params, calibrate(params, cfg, toks)


def _quality_row(arch: str, qcfg: PTQConfig) -> dict:
    cfg, params, stats = _calibrated_arch(arch)
    shapes = eligible_shapes(params, qcfg.skips)
    uni = uniform_plan(REFERENCE.quantizer, REFERENCE.rank)
    budget = plan_bytes(shapes, uni)
    plan = allocate_plan(params, stats, reference=REFERENCE,
                         skips=qcfg.skips)
    err_uni = plan_expected_error(params, stats, uni, skips=qcfg.skips)
    err_mix = plan_expected_error(params, stats, plan, skips=qcfg.skips)
    mix_bytes = plan_bytes(shapes, plan)
    return {
        "arch": cfg.name,
        "budget_bytes": budget,
        "mixed_bytes": mix_bytes,
        "uniform_error": err_uni,
        "mixed_error": err_mix,
        "error_ratio": err_mix / err_uni if err_uni > 0 else None,
        "n_layers": len(plan.assignments),
        "n_formats_used": len({c.quantizer
                               for c in plan.assignments.values()}),
        "win": bool(err_mix < err_uni and mix_bytes <= budget),
    }


def _tokens_per_sec(packed, cfg, prompt) -> float:
    out = scan_generate(packed, cfg, prompt, STEPS)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    out = scan_generate(packed, cfg, prompt, STEPS)
    jax.block_until_ready(out)
    return B * STEPS / (time.perf_counter() - t0)


def _serving_section(qcfg: PTQConfig) -> dict:
    from repro.kernels import autotune as at
    params = pretrained_lm()
    stats = calibrate(params, LM_CFG, calib_batches(8))
    shapes = eligible_shapes(params, qcfg.skips)
    plan = allocate_plan(params, stats, reference=REFERENCE,
                        skips=qcfg.skips)
    uni_cfg = PTQConfig(method="qera_approx", rank=REFERENCE.rank,
                        quantizer=REFERENCE.quantizer,
                        skip_patterns=qcfg.skip_patterns)
    packed_uni = pack_for_serving(
        quantize_params(params, uni_cfg, stats_by_path=stats), uni_cfg)
    packed_mix = pack_for_serving(
        quantize_params(params, qcfg, stats_by_path=stats, plan=plan),
        qcfg, plan=plan)

    # warm the autotuner over the mixed tree's decode geometries, twice:
    # first pass measures (miss), second must be all hits (determinism)
    geoms = at.plan_shapes_for_params(packed_mix, m=B)
    hits = {"first": 0, "second": 0}
    for label in ("first", "second"):
        for m, k, n, bits, bs in geoms:
            _, hit = at.autotune(m, k, n, bits=bits, block_size=bs,
                                 rank=8, reps=1)
            hits[label] += int(hit)

    prompt = jax.random.randint(jax.random.PRNGKey(5), (B, PROMPT_LEN), 0,
                                LM_CFG.vocab_size)
    return {
        "arch": LM_CFG.name,
        "budget_bytes": plan_bytes(shapes, uniform_plan(
            REFERENCE.quantizer, REFERENCE.rank)),
        "mixed_bytes": plan_bytes(shapes, plan),
        "uniform_error": plan_expected_error(
            params, stats, uniform_plan(REFERENCE.quantizer, REFERENCE.rank),
            skips=qcfg.skips),
        "mixed_error": plan_expected_error(params, stats, plan,
                                           skips=qcfg.skips),
        "tokens_per_sec_uniform": _tokens_per_sec(packed_uni, LM_CFG,
                                                  prompt),
        "tokens_per_sec_mixed": _tokens_per_sec(packed_mix, LM_CFG, prompt),
        "autotune_geometries": len(geoms),
        "autotune_hits_first_pass": hits["first"],
        "autotune_hits_second_pass": hits["second"],
        "autotune_deterministic": hits["second"] == len(geoms),
    }


def run(csv_rows: list | None = None) -> dict:
    qcfg = PTQConfig(method="qera_approx", rank=8, quantizer="mxint4")
    quality = [_quality_row(a, qcfg) for a in QUALITY_ARCHS]
    wins = sum(r["win"] for r in quality)
    serving = _serving_section(qcfg)

    results = {
        "reference": {"quantizer": REFERENCE.quantizer,
                      "rank": REFERENCE.rank},
        "quality": quality,
        "quality_summary": {
            "wins": wins,
            "archs": len(quality),
            "mean_error_ratio": float(np.mean(
                [r["error_ratio"] for r in quality
                 if r["error_ratio"] is not None])),
        },
        "serving": serving,
    }

    BENCH_JSON.parent.mkdir(parents=True, exist_ok=True)
    BENCH_JSON.write_text(json.dumps(results, indent=1, sort_keys=True))

    if csv_rows is not None:
        for r in quality:
            csv_rows.append(
                f"mixed_precision/{r['arch']},,"
                f"err_ratio={r['error_ratio']:.4f}"
                f" win={int(r['win'])}")
        csv_rows.append(
            f"mixed_precision/serving,,"
            f"tps_mixed={serving['tokens_per_sec_mixed']:.1f}"
            f" tps_uniform={serving['tokens_per_sec_uniform']:.1f}")

    # ---- acceptance bars ---------------------------------------------------
    assert wins >= MIN_WINS, (
        f"mixed plan beat uniform {REFERENCE.quantizer}/r{REFERENCE.rank} "
        f"on only {wins}/{len(quality)} archs (need {MIN_WINS}): "
        f"{[(r['arch'], r['error_ratio']) for r in quality]}")
    assert all(r["mixed_bytes"] <= r["budget_bytes"] for r in quality), \
        "allocator overdrew its HBM budget"
    assert serving["autotune_deterministic"], (
        "autotune cache: second warm pass was not 100% hits "
        f"({serving['autotune_hits_second_pass']}"
        f"/{serving['autotune_geometries']})")
    print(f"mixed_precision: {wins}/{len(quality)} archs strictly better "
          f"at equal HBM; serving "
          f"{serving['tokens_per_sec_mixed']:.1f} tok/s mixed vs "
          f"{serving['tokens_per_sec_uniform']:.1f} uniform")
    return results


if __name__ == "__main__":
    run()
