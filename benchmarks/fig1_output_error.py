"""Figure 1 — model output error before fine-tuning vs rank and LoftQ iters.

Paper claims reproduced here:
  (1) QERA's output error is the lowest at every (bits, rank);
  (2) QERA's error decreases monotonically with rank;
  (3) LoftQ: more iterations / higher rank do NOT guarantee lower model
      output error (weight error decreases — Appendix A.5 — output may not).
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import (
    LM_CFG,
    calib_batches,
    calibrate,
    model_output_error,
    pretrained_lm,
    ptq,
)


def run(csv_rows: list | None = None) -> dict:
    params = pretrained_lm()
    calib = calib_batches(32)
    eval_toks = calib_batches(16, seed=4321)
    stats = calibrate(params, LM_CFG, calib)

    results: dict = {}
    for bits in ["mxint4", "mxint3"]:
        for rank in [2, 4, 8, 16]:
            for method in ["qlora", "zeroquant_v2", "lqer", "qera_approx",
                           "qera_exact"]:
                qp = ptq(params, LM_CFG, method, rank, bits, stats=stats)
                err = model_output_error(params, qp, LM_CFG, eval_toks)
                results[(bits, rank, method)] = err
        for iters in [1, 2, 3, 5]:
            qp = ptq(params, LM_CFG, "loftq", 8, bits, stats=stats,
                     loftq_iters=iters)
            err = model_output_error(params, qp, LM_CFG, eval_toks)
            results[(bits, f"loftq_iter{iters}", "loftq")] = err

    # -- claim checks ---------------------------------------------------------
    checks = {}
    for bits in ["mxint4", "mxint3"]:
        ranks = [2, 4, 8, 16]
        qera = [results[(bits, r, "qera_exact")] for r in ranks]
        checks[f"{bits}/qera_monotone_in_rank"] = all(
            qera[i + 1] <= qera[i] * 1.001 for i in range(len(qera) - 1))
        for r in ranks:
            best = min(results[(bits, r, m)] for m in
                       ["qlora", "zeroquant_v2", "lqer", "qera_approx"])
            checks[f"{bits}/r{r}/qera_exact_lowest"] = \
                results[(bits, r, "qera_exact")] <= best * 1.001

    if csv_rows is not None:
        for (bits, rank, method), err in sorted(results.items(),
                                                key=lambda kv: str(kv[0])):
            csv_rows.append(
                f"fig1,{bits},{rank},{method},{err:.6f}")
        for name, ok in checks.items():
            csv_rows.append(f"fig1_check,{name},,{'PASS' if ok else 'FAIL'},")
    return {"results": results, "checks": checks}


if __name__ == "__main__":
    rows: list = []
    out = run(rows)
    print("\n".join(rows))
