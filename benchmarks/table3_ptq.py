"""Tables 3 & 4 proxy — PTQ quality (held-out CE = WikiText2-ppl analog).

Methods: w-only, ZeroQuant-V2, LQER, QERA-approx, QERA-exact at 4/3/2-bit
MXINT.  Paper claims: QERA-approx ≥ LQER ≥ ZeroQuant ≥ w-only; QERA-exact
best overall; advantage pronounced at 3 bits and below; 4-bit QERA-exact is
near-lossless.
"""

from __future__ import annotations

from benchmarks.common import (
    LM_CFG,
    calib_batches,
    calibrate,
    eval_ce,
    pretrained_lm,
    ptq,
)

SETUPS = [("mxint4", 8), ("mxint3", 8), ("mxint2", 16)]
METHODS = ["w_only", "zeroquant_v2", "lqer", "qera_approx", "qera_exact"]


def run(csv_rows: list | None = None) -> dict:
    params = pretrained_lm()
    stats = calibrate(params, LM_CFG, calib_batches(64))
    base = eval_ce(params, LM_CFG)
    results = {("fp32", "-"): base}

    for quant, rank in SETUPS:
        for method in METHODS:
            if method == "w_only":
                qp = ptq(params, LM_CFG, "qlora", 1, quant)  # B=0 -> pure W̃
            else:
                qp = ptq(params, LM_CFG, method, rank, quant, stats=stats)
            ce = eval_ce(qp, LM_CFG)
            results[(quant, method)] = ce
            if csv_rows is not None:
                csv_rows.append(f"table3,{quant},{method},ce={ce:.4f},"
                                f"delta={ce - base:+.4f}")

    checks = {}
    for quant, _ in SETUPS:
        qe = results[(quant, "qera_exact")]
        checks[f"{quant}/qera_exact_best"] = qe <= min(
            results[(quant, m)] for m in METHODS[:-1]) * 1.005
        checks[f"{quant}/recon_beats_w_only"] = (
            results[(quant, "qera_approx")] <= results[(quant, "w_only")])
    checks["mxint4/near_lossless"] = (
        results[("mxint4", "qera_exact")] - base < 0.05)
    if csv_rows is not None:
        csv_rows.append(f"table3,fp32,-,ce={base:.4f},delta=+0.0000")
        for name, ok in checks.items():
            csv_rows.append(f"table3_check,{name},,{'PASS' if ok else 'FAIL'},")
    return {"results": results, "checks": checks}


if __name__ == "__main__":
    rows: list = []
    run(rows)
    print("\n".join(rows))
