# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark driver — one module per paper table/figure:

  fig1_output_error   Fig. 1: output error vs rank & LoftQ iterations
  fig3_calib_size     Fig. 3: calibration-size monotonicity (QERA vs LQER)
  table1_qpeft        Tab. 1/2: QPEFT fine-tuning across methods/bits
  table3_ptq          Tab. 3/4: PTQ quality across methods/bits
  table8_runtime      Tab. 7/8: init runtime exact vs approx (+sqrtm kernels)
  kernel_bench        Pallas kernels vs refs + HBM accounting
  decode_throughput   decode fast path: tokens/sec + bytes/token (BENCH json)
  tp_serving          tensor-parallel serving: per-tp tokens/sec +
                      predicted-vs-measured all-reduce bytes (BENCH json)
  speculative         self-speculative decoding: acceptance, launches per
                      token, wall-clock model (BENCH json)
  mixed_precision     per-layer QuantPlan vs uniform mxint4/r32 at equal
                      HBM: expected-error wins + tok/s + autotune
                      determinism (BENCH json)
  roofline            §Roofline from the dry-run artifacts
  consolidate         merge per-section jsons -> bench.json + trend vs
                      the committed benchmarks/baseline artifact

Run all:      PYTHONPATH=src python -m benchmarks.run
Run one:      PYTHONPATH=src python -m benchmarks.run --only table3_ptq
"""

from __future__ import annotations

import argparse
import sys
import time
import traceback

BENCHES = ["fig1_output_error", "fig3_calib_size", "table1_qpeft",
           "table3_ptq", "table8_runtime", "kernel_bench",
           "decode_throughput", "tp_serving", "speculative",
           "mixed_precision", "roofline", "consolidate"]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, choices=BENCHES)
    args = ap.parse_args()
    todo = [args.only] if args.only else BENCHES

    rows: list[str] = ["name,us_per_call,derived"]
    failed = []
    for name in todo:
        t0 = time.time()
        try:
            mod = __import__(f"benchmarks.{name}", fromlist=["run"])
            mod.run(rows)
            print(f"# {name}: done in {time.time() - t0:.1f}s",
                  file=sys.stderr)
        except Exception:  # noqa: BLE001
            failed.append(name)
            print(f"# {name}: FAILED\n{traceback.format_exc()}",
                  file=sys.stderr)
    print("\n".join(rows))
    if failed:
        print(f"# FAILED: {failed}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == '__main__':
    main()
