"""Kernel benchmark: fused MXINT dequant-matmul + low-rank vs unfused ref.

On CPU the Pallas kernels run in interpret mode, so *wall time is not the
signal* — the derived columns are: HBM bytes moved per GEMM (the sub-byte
packed mantissa layout's ~3.6x reduction at 4-bit is the QER serving win)
and achieved-FLOPs accounting for the roofline story.  Interpret-mode
µs/call is still printed for completeness.

Bytes are reported TWICE, labeled: ``*_measured`` is ``.nbytes`` of the
device buffers the kernel actually reads (the honest HBM figure), while
``*_analytic`` is the nominal average-bits arithmetic (``_weight_bytes``).
The two now agree for 4-/2-bit; 3-bit stores a 4-bit container, so its
measured bytes sit above the 3.25-bit analytic claim — by design, labeled.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.ops import flash_attention, quantized_matmul
from repro.kernels.ref import flash_attention_ref, mxint_matmul_lowrank_ref
from repro.quant.mxint import mxint_quantize, pack_mantissa


def _weight_bytes(k, n, bits, bs, rank, lowrank_bytes=2):
    """ANALYTIC bytes at the nominal bit-width (not a measurement)."""
    packed = k * n * 1 + (k // bs) * n * 1          # int8 mant + int8 exp
    if bits < 8:                                     # nominal sub-byte bits
        packed = k * n * bits / 8 + (k // bs) * n
    lowrank = (k + n) * rank * lowrank_bytes
    return packed + lowrank


def _measured_weight_bytes(*buffers) -> int:
    """MEASURED device-buffer bytes: sum of ``.nbytes`` over the HBM buffers
    one fused-GEMM launch streams (packed mantissa, exponents, low-rank)."""
    return int(sum(b.nbytes for b in buffers))


def timed_us(fn, reps: int = 3) -> float:
    """Mean wall-clock µs/call: one explicit blocked warmup (compile/trace),
    then ``reps`` blocked calls under ``time.perf_counter``."""
    jax.block_until_ready(fn())
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn())
    return (time.perf_counter() - t0) / reps * 1e6


def run(csv_rows: list | None = None) -> dict:
    results = {}
    m, k, n, r, bits, bs = 32, 256, 256, 16, 4, 32
    keys = jax.random.split(jax.random.PRNGKey(0), 4)
    x = jax.random.normal(keys[0], (m, k), jnp.float32)
    w = jax.random.normal(keys[1], (k, n), jnp.float32) * 0.1
    a = jax.random.normal(keys[2], (k, r), jnp.float32) * 0.05
    b = jax.random.normal(keys[3], (r, n), jnp.float32) * 0.05
    mant, exp = mxint_quantize(w, bits, bs)
    mant = pack_mantissa(mant.reshape(k, n), bits)   # sub-byte HBM layout

    def fused():
        return quantized_matmul(x, mant, exp, a, b, bits=bits, block_size=bs,
                                block_m=32, block_n=128, block_k=128,
                                interpret=True)

    out, ref = fused(), mxint_matmul_lowrank_ref(x, mant, exp, a, b, bits, bs)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-4,
                               atol=1e-4)
    us = timed_us(fused)
    flops = 2 * m * k * n + 2 * m * r * (k + n)
    bf16_bytes = k * n * 2
    q_bytes_measured = _measured_weight_bytes(mant, exp, a, b)
    q_bytes_analytic = _weight_bytes(k, n, bits, bs, r,
                                     lowrank_bytes=a.dtype.itemsize)
    results["mxint_matmul"] = {
        "us_per_call_interp": us,
        "gemm_flops": flops,
        "weight_bytes_bf16": bf16_bytes,
        "weight_bytes_measured": q_bytes_measured,      # .nbytes of buffers
        "weight_bytes_analytic": q_bytes_analytic,      # nominal avg-bits
        "hbm_reduction_measured": bf16_bytes / q_bytes_measured,
        "hbm_reduction_analytic": bf16_bytes / q_bytes_analytic,
    }
    if csv_rows is not None:
        csv_rows.append(
            f"kernel,mxint_matmul,{us:.0f},flops={flops}"
            f";hbm_reduction_measured={bf16_bytes / q_bytes_measured:.2f}x")

    # flash attention
    bq, h, s, d = 1, 4, 256, 64
    q_ = jax.random.normal(keys[0], (bq, h, s, d), jnp.float32)
    k_ = jax.random.normal(keys[1], (bq, h, s, d), jnp.float32)
    v_ = jax.random.normal(keys[2], (bq, h, s, d), jnp.float32)

    def fa():
        return flash_attention(q_, k_, v_, causal=True, block_q=128,
                               block_kv=128, interpret=True)

    np.testing.assert_allclose(np.asarray(fa()),
                               np.asarray(flash_attention_ref(q_, k_, v_)),
                               rtol=1e-4, atol=1e-4)
    us = timed_us(fa)
    naive_bytes = bq * h * s * s * 4            # materialized scores
    flash_bytes = bq * h * s * d * 4 * 4        # q,k,v,o only
    results["flash_attention"] = {
        "us_per_call_interp": us,
        "score_bytes_avoided": naive_bytes,
        "io_bytes": flash_bytes,
    }
    if csv_rows is not None:
        csv_rows.append(
            f"kernel,flash_attention,{us:.0f},"
            f"score_bytes_avoided={naive_bytes}")
    return results


if __name__ == "__main__":
    rows: list = []
    run(rows)
    print("\n".join(rows))
