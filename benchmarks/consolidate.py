"""Consolidate per-section bench JSONs into ONE ``bench.json`` + trend.

Every bench module writes its own ``experiments/bench/<name>.json``; CI
used to upload them as separate artifacts, which made cross-PR comparison
a manual scavenger hunt.  This module (run LAST by ``benchmarks.run``):

* merges every ``experiments/bench/*.json`` present into
  ``experiments/bench/bench.json`` under a ``sections`` key (so the
  ``speculative`` section sits next to ``decode_throughput``,
  ``tp_serving`` and ``fault_tolerance`` in one artifact);
* computes a ``trend`` block against the PREVIOUS PR's consolidated
  artifact, committed at ``benchmarks/baseline/bench.json``
  (``experiments/`` is gitignored, so the baseline must live in-tree):
  for each curated headline metric, ``{previous, current, ratio}``.
  A missing baseline or section yields ``null`` entries, never a crash —
  new sections simply start their history this PR.

Refreshing the baseline is a deliberate, committed act:

    cp experiments/bench/bench.json benchmarks/baseline/bench.json
"""

from __future__ import annotations

import json
from pathlib import Path

BENCH_DIR = Path(__file__).resolve().parent.parent / "experiments" / "bench"
BENCH_JSON = BENCH_DIR / "bench.json"
BASELINE_JSON = (Path(__file__).resolve().parent / "baseline"
                 / "bench.json")

# headline metrics: (section, path-within-section) -> short name
HEADLINES = {
    "decode_tokens_per_sec":
        ("decode_throughput", ("engine", "tokens_per_sec_scan")),
    "decode_hbm_reduction":
        ("decode_throughput", ("kernel", "hbm_reduction_measured")),
    "prefix_ttft_speedup":
        ("decode_throughput", ("prefix_cache", "ttft_speedup_warm")),
    "fault_goodput_storm":
        ("fault_tolerance", ("goodput_tokens_per_tick_storm",)),
    "spec_launch_reduction":
        ("speculative", ("best", "launch_reduction")),
    "spec_acceptance":
        ("speculative", ("best", "acceptance_rate")),
    "spec_batcher_speedup":
        ("speculative", ("batcher", "wallclock_speedup")),
    "mixed_error_ratio":
        ("mixed_precision", ("quality_summary", "mean_error_ratio")),
    "mixed_plan_wins":
        ("mixed_precision", ("quality_summary", "wins")),
    "mixed_tokens_per_sec":
        ("mixed_precision", ("serving", "tokens_per_sec_mixed")),
}


def _dig(tree, path):
    for p in path:
        if not isinstance(tree, dict) or p not in tree:
            return None
        tree = tree[p]
    return tree if isinstance(tree, (int, float)) else None


def _tp_headlines(sections: dict) -> dict:
    out = {}
    for row in (sections.get("tp_serving") or {}).get("results", []):
        out[f"tp{row['tp']}_tokens_per_sec"] = row.get("tokens_per_sec")
        if row.get("predicted_vs_measured_ratio") is not None:
            out[f"tp{row['tp']}_allreduce_model_ratio"] = \
                row["predicted_vs_measured_ratio"]
    return out


def headline_metrics(consolidated: dict) -> dict:
    sections = consolidated.get("sections", {})
    out = {name: _dig(sections.get(sec, {}), path)
           for name, (sec, path) in HEADLINES.items()}
    out.update(_tp_headlines(sections))
    return {k: v for k, v in out.items() if v is not None}


def run(csv_rows: list | None = None) -> dict:
    sections = {}
    for fn in sorted(BENCH_DIR.glob("*.json")):
        if fn.name == "bench.json":
            continue
        try:
            sections[fn.stem] = json.loads(fn.read_text())
        except (json.JSONDecodeError, OSError) as e:  # partial CI runs
            sections[fn.stem] = {"error": str(e)}

    consolidated: dict = {"sections": sections}
    now = headline_metrics(consolidated)

    baseline = None
    if BASELINE_JSON.exists():
        baseline = headline_metrics(json.loads(BASELINE_JSON.read_text()))
    trend = {}
    for name in sorted(set(now) | set(baseline or {})):
        prev, cur = (baseline or {}).get(name), now.get(name)
        trend[name] = {
            "previous": prev, "current": cur,
            "ratio": (cur / prev) if prev and cur is not None else None,
        }
    consolidated["headlines"] = now
    consolidated["trend"] = trend
    consolidated["baseline_present"] = baseline is not None

    BENCH_DIR.mkdir(parents=True, exist_ok=True)
    BENCH_JSON.write_text(json.dumps(consolidated, indent=2))
    print(f"wrote {BENCH_JSON} ({len(sections)} sections, "
          f"{len(now)} headline metrics, baseline "
          f"{'present' if baseline is not None else 'absent'})")
    if csv_rows is not None:
        for name, t in trend.items():
            if t["ratio"] is not None:
                csv_rows.append(
                    f"trend,{name},0,"
                    f"previous={t['previous']:.3g}"
                    f";current={t['current']:.3g}"
                    f";ratio={t['ratio']:.2f}x")
    return consolidated


if __name__ == "__main__":
    rows: list = []
    run(rows)
    print("\n".join(rows))
