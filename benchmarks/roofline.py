"""§Roofline report: reads the dry-run JSON artifacts and prints the
per-(arch x shape x mesh) table — the three terms, the dominant bottleneck,
MODEL_FLOPS/HLO ratio, and memory fit."""

from __future__ import annotations

import json
from pathlib import Path

DRYRUN_DIR = Path(__file__).resolve().parent.parent / "experiments" / "dryrun"
TP_JSON = (Path(__file__).resolve().parent.parent / "experiments" / "bench"
           / "tp_serving.json")
HBM_PER_CHIP = 16e9   # v5e


def load_cells(mesh: str | None = None) -> list[dict]:
    cells = []
    for f in sorted(DRYRUN_DIR.glob("*.json")):
        d = json.loads(f.read_text())
        if mesh and d["mesh"] != mesh:
            continue
        cells.append(d)
    return cells


def tp_comms_rows(csv_rows: list | None = None) -> dict:
    """Surface the tensor-parallel serving comms term next to the roofline:
    per-tp measured all-reduce bytes of the compiled decode step vs the
    analytic 2-psum/layer prediction (benchmarks/tp_serving.py)."""
    if not TP_JSON.exists():
        return {}
    d = json.loads(TP_JSON.read_text())
    out = {}
    for r in d.get("results", []):
        key = f"tp_serving|{d['arch']}|tp={r['tp']}"
        out[key] = {
            "tokens_per_sec": r["tokens_per_sec"],
            "bytes_per_token": r["bytes_per_token"],
            "measured_allreduce_bytes": r["measured_allreduce_bytes"],
            "predicted_allreduce_bytes": r["predicted_allreduce_bytes"],
            "predicted_vs_measured_ratio": r["predicted_vs_measured_ratio"],
        }
        if csv_rows is not None:
            ratio = r["predicted_vs_measured_ratio"]
            csv_rows.append(
                f"roofline,{key},{r['tokens_per_sec']}tok/s,"
                f"allreduce_pred/meas="
                f"{'n/a' if ratio is None else round(ratio, 3)}")
    return out


def run(csv_rows: list | None = None) -> dict:
    cells = load_cells()
    tp = tp_comms_rows(csv_rows)
    if not cells:
        if csv_rows is not None:
            csv_rows.append("roofline,no-dryrun-artifacts-yet,,")
        return tp
    out = dict(tp)
    for d in cells:
        key = f"{d['arch']}|{d['shape']['name']}|{d['mesh']}"
        mem = d["full"]["memory"]
        hbm = (mem["argument_bytes"] + mem["temp_bytes"]
               + mem["output_bytes"] - mem["alias_bytes"]) / 1e9
        row = {
            "fits_16g": hbm <= 16.0,
            "hbm_gb": round(hbm, 2),
        }
        if "roofline" in d:
            r = d["roofline"]
            dom = r["bottleneck"]
            row.update({
                "compute_s": r["compute_s"], "memory_s": r["memory_s"],
                "collective_s": r["collective_s"], "bottleneck": dom,
                "roofline_fraction": (r["compute_s"] /
                                      max(r[dom], 1e-12)),
                "model_flops_ratio": d.get("model_flops_ratio"),
            })
        out[key] = row
        if csv_rows is not None:
            if "roofline" in d:
                csv_rows.append(
                    f"roofline,{key},{row['compute_s']:.3f}/"
                    f"{row['memory_s']:.3f}/{row['collective_s']:.3f},"
                    f"bottleneck={row['bottleneck']};frac="
                    f"{row['roofline_fraction']:.3f};hbm={row['hbm_gb']}GB")
            else:
                csv_rows.append(f"roofline,{key},memory-only,"
                                f"hbm={row['hbm_gb']}GB")
    return out


def markdown_table() -> str:
    """§Roofline markdown for EXPERIMENTS.md."""
    cells = load_cells()
    lines = [
        "| arch | shape | mesh | compute_s | memory_s | collective_s | "
        "bottleneck | frac | 6ND/HLO | HBM GB | fits |",
        "|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    for d in cells:
        mem = d["full"]["memory"]
        hbm = (mem["argument_bytes"] + mem["temp_bytes"]
               + mem["output_bytes"] - mem["alias_bytes"]) / 1e9
        fits = "✅" if hbm <= 16 else f"❌"
        if "roofline" in d:
            r = d["roofline"]
            dom = r["bottleneck"]
            frac = r["compute_s"] / max(r[dom], 1e-12)
            ratio = d.get("model_flops_ratio") or 0
            lines.append(
                f"| {d['arch']} | {d['shape']['name']} | {d['mesh']} "
                f"| {r['compute_s']:.3f} | {r['memory_s']:.3f} "
                f"| {r['collective_s']:.3f} | {dom[:-2]} | {frac:.3f} "
                f"| {ratio:.3f} | {hbm:.1f} | {fits} |")
        else:
            lines.append(
                f"| {d['arch']} | {d['shape']['name']} | {d['mesh']} "
                f"| — | — | — | (memory-only pass) | — | — "
                f"| {hbm:.1f} | {fits} |")
    return "\n".join(lines)


if __name__ == "__main__":
    import sys
    if "--md" in sys.argv:
        print(markdown_table())
    else:
        rows: list = []
        run(rows)
        print("\n".join(rows))
