"""Figure 3 — recovered model quality vs calibration-set size.

Paper claim: more calibration samples consistently improve QERA (monotone
until convergence) while LQER's heuristic fluctuates; QERA resolves the
discrepancy.  Metric: model output error (lower = better recovery).
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import (
    LM_CFG,
    calib_batches,
    calibrate,
    model_output_error,
    pretrained_lm,
    ptq,
)

SIZES = [2, 8, 32, 128]


def run(csv_rows: list | None = None) -> dict:
    params = pretrained_lm()
    eval_toks = calib_batches(16, seed=4321)

    results: dict = {}
    for n in SIZES:
        stats = calibrate(params, LM_CFG, calib_batches(n))
        for method in ["lqer", "qera_approx", "qera_exact"]:
            qp = ptq(params, LM_CFG, method, 8, "mxint3", stats=stats)
            results[(method, n)] = model_output_error(
                params, qp, LM_CFG, eval_toks)

    # convergence trend: error at max size <= error at min size for QERA
    checks = {}
    for method in ["qera_approx", "qera_exact"]:
        errs = [results[(method, n)] for n in SIZES]
        checks[f"{method}/improves_with_calib"] = errs[-1] <= errs[0] * 1.001
    lq = [results[("lqer", n)] for n in SIZES]
    qa = [results[("qera_approx", n)] for n in SIZES]
    checks["qera_beats_lqer_at_converged"] = qa[-1] <= lq[-1] * 1.001

    if csv_rows is not None:
        for (method, n), err in sorted(results.items()):
            csv_rows.append(f"fig3,{method},{n},{err:.6f}")
        for name, ok in checks.items():
            csv_rows.append(f"fig3_check,{name},,{'PASS' if ok else 'FAIL'}")
    return {"results": results, "checks": checks}


if __name__ == "__main__":
    rows: list = []
    run(rows)
    print("\n".join(rows))
