"""Decode-throughput benchmark: the fused on-device decode fast path.

Three measurements, all CPU-runnable:

* engine level — tokens/sec of ``scan_generate`` (prefill + lax.scan rollout,
  ONE compile, zero per-token host sync) vs ``greedy_generate_loop`` (one jit
  call + host round-trip per token).  On CPU the dispatch overhead is the
  signal; on TPU the same ratio grows with per-launch latency.
* kernel level — the decode-shaped quantized GEMM (M = slot count) through
  the single fused Pallas launch in interpret mode, on the SUB-BYTE PACKED
  mantissa buffer (two 4-bit mantissas per byte, unpacked in VMEM), with
  HBM bytes/token accounting: ``*_measured`` is ``.nbytes`` of the device
  buffers the launch streams, ``*_analytic`` the nominal average-bits
  figure — labeled separately so the json can no longer claim a reduction
  the HBM layout doesn't deliver (they agree at 4-/2-bit; 3-bit stores a
  4-bit container).
* paged attention — K/V bytes read per decode token under the paged cache
  (page-table bucket covering the live prefix) vs the dense (B, max_len)
  cache, cross-checked by actually running the Pallas decode-attention
  kernel at both table widths.  At prefix << max_len the paged read is
  smaller by ~max_len / bucket_tokens.
* chunked admission — TTFT and per-tick latency p50/p95 of the two-queue
  scheduler under a mixed load: a long prompt admitted while another slot
  is mid-decode, chunked (budgeted tokens/tick) vs one-shot (the whole
  prompt in a single chunk).  One-shot admission puts the entire prefill in
  ONE tick — the running slot's inter-token latency spikes to the prompt
  length; chunked bounds every tick by the chunk budget.  Plus the
  chunked-paged vs one-shot-dense prefill attention bytes (the dense path
  used to score every query row against max_len keys).
* prefix caching — N requests over one shared system prompt with the
  copy-on-write prefix cache: prompt-token hit rate, pages allocated warm
  vs cold (a warm admission pays only ``pages_for(suffix)``), and TTFT
  warm vs cold (the skipped prefill work, jit pre-warmed).
* fault tolerance — the same paged+prefix serving load run clean and under
  a seeded fault storm (pool-exhaustion spikes + NaN decode ticks + a
  mid-tick crash recovered from a snapshot): goodput (completed tokens per
  tick), recovery-tick overhead vs clean, and a ``token_identical`` flag
  asserting the storm changed *when* tokens arrived, never *which*.

Results land in the CSV rows AND in the BENCH json
(``experiments/bench/decode_throughput.json``); the fault-tolerance section
is additionally mirrored to ``experiments/bench/fault_tolerance.json`` so CI
can upload it as a standalone per-PR artifact.
"""

from __future__ import annotations

import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.kernel_bench import (_measured_weight_bytes, _weight_bytes,
                                     timed_us)
from repro.kernels.ops import chunk_plan, decode_attention, quantized_matmul
from repro.kernels.ref import decode_attention_ref, mxint_matmul_lowrank_ref
from repro.models import ModelConfig, init_params
from repro.quant.mxint import mxint_quantize, pack_mantissa
from repro.runtime.fault_tolerance import RestartPolicy
from repro.serve.batching import ContinuousBatcher, Request
from repro.serve.engine import greedy_generate_loop, scan_generate
from repro.serve.faults import FaultInjector
from repro.serve.paging import page_bucket
from repro.serve.supervisor import ServingSupervisor

BENCH_JSON = (Path(__file__).resolve().parent.parent / "experiments" / "bench"
              / "decode_throughput.json")
FAULT_JSON = BENCH_JSON.with_name("fault_tolerance.json")

CFG = ModelConfig(family="dense", num_layers=2, d_model=64, num_heads=4,
                  num_kv_heads=2, d_ff=128, vocab_size=128, head_dim=16)


def run(csv_rows: list | None = None) -> dict:
    results: dict = {}

    # ---- engine: scan rollout vs python token loop -------------------------
    b, prompt_len, steps = 4, 8, 32
    params = init_params(CFG, jax.random.PRNGKey(0))
    prompt = jax.random.randint(jax.random.PRNGKey(1), (b, prompt_len), 0,
                                CFG.vocab_size)

    t_scan = timed_us(lambda: scan_generate(params, CFG, prompt, steps)) / 1e6
    t_loop = timed_us(
        lambda: greedy_generate_loop(params, CFG, prompt, steps)) / 1e6
    tok_s_scan = b * steps / t_scan
    tok_s_loop = b * steps / t_loop
    results["engine"] = {
        "tokens_per_sec_scan": tok_s_scan,
        "tokens_per_sec_loop": tok_s_loop,
        "speedup": tok_s_scan / tok_s_loop,
    }
    if csv_rows is not None:
        csv_rows.append(
            f"decode,scan_generate,{t_scan / (b * steps) * 1e6:.0f},"
            f"tokens_per_sec={tok_s_scan:.1f}"
            f";speedup_vs_loop={tok_s_scan / tok_s_loop:.2f}x")

    # ---- kernel: decode-shaped fused GEMM + bytes/token --------------------
    m, k, n, r, bits, bs = 4, 256, 256, 16, 4, 32   # M = decode slot count
    keys = jax.random.split(jax.random.PRNGKey(2), 4)
    x = jax.random.normal(keys[0], (m, k), jnp.float32)
    w = jax.random.normal(keys[1], (k, n), jnp.float32) * 0.1
    a = jax.random.normal(keys[2], (k, r), jnp.float32) * 0.05
    bb = jax.random.normal(keys[3], (r, n), jnp.float32) * 0.05
    mant, exp = mxint_quantize(w, bits, bs)
    mant = pack_mantissa(mant.reshape(k, n), bits)   # sub-byte HBM layout

    def decode_gemm():
        return quantized_matmul(x, mant, exp, a, bb, bits=bits, block_size=bs,
                                interpret=True)

    np.testing.assert_allclose(
        np.asarray(decode_gemm()),
        np.asarray(mxint_matmul_lowrank_ref(x, mant, exp, a, bb, bits, bs)),
        rtol=1e-4, atol=1e-4)
    us = timed_us(decode_gemm)

    # weight bytes moved per token per layer (the decode roofline currency):
    # measured = .nbytes of the device buffers the launch actually streams;
    # analytic = the nominal average-bits arithmetic (labeled, not claimed
    # as HBM traffic)
    q_bytes_measured = _measured_weight_bytes(mant, exp, a, bb)
    q_bytes_analytic = _weight_bytes(k, n, bits, bs, r,
                                     lowrank_bytes=a.dtype.itemsize)
    mant_exp_bytes = _measured_weight_bytes(mant, exp)
    bf16 = k * n * 2
    results["kernel"] = {
        "us_per_call_interp": us,
        "mant_hbm_layout": f"packed int8 {tuple(mant.shape)} "
                           f"({mant.nbytes} bytes for {k}x{n} @ {bits}-bit)",
        "weight_bytes_per_token_measured": q_bytes_measured,
        "weight_bytes_per_token_analytic": q_bytes_analytic,
        "mant_exp_bytes_measured": mant_exp_bytes,
        "weight_bytes_bf16": bf16,
        "hbm_reduction_measured": bf16 / q_bytes_measured,
        "hbm_reduction_analytic": bf16 / q_bytes_analytic,
        "hbm_reduction_weights_only": bf16 / mant_exp_bytes,
        "launches_per_layer_per_token": 1,           # fused prologue
    }
    if csv_rows is not None:
        csv_rows.append(
            f"decode,fused_gemm,{us:.0f},"
            f"bytes_per_token_measured={q_bytes_measured:.0f}"
            f";hbm_reduction_measured={bf16 / q_bytes_measured:.2f}x")

    # ---- paged vs dense attention bytes/token ------------------------------
    # decode-shaped attention reads: dense SDPA streams the whole
    # (B, max_len) K/V row every token; the paged kernel's grid covers only
    # the page-table bucket over the live prefix.
    slots, kvh, hd, page_size, max_len, prefix = 4, 2, 16, 16, 1024, 32
    itemsize = 4                                       # f32 pool on CPU
    live_pages = -(-(prefix + 1) // page_size)
    bucket = page_bucket(live_pages, max_len // page_size)
    kv = 2                                             # K and V
    dense_bytes = kv * kvh * max_len * hd * itemsize
    paged_bytes = (kv * kvh * bucket * page_size * hd * itemsize
                   + bucket * 4)                       # + page-table row
    num_pages = 1 + slots * (max_len // page_size)
    keys = jax.random.split(jax.random.PRNGKey(3), 3)
    q = jax.random.normal(keys[0], (slots, kvh * 2, hd), jnp.float32)
    kp = jax.random.normal(keys[1], (num_pages, kvh, page_size, hd),
                           jnp.float32)
    vp = jax.random.normal(keys[2], (num_pages, kvh, page_size, hd),
                           jnp.float32)
    kv_len = jnp.full((slots,), prefix + 1, jnp.int32)
    pt_full = (1 + jnp.arange(slots * (max_len // page_size), dtype=jnp.int32)
               ).reshape(slots, -1)

    def paged_attn(width):
        return decode_attention(q, kp, vp, pt_full[:, :width], kv_len,
                                interpret=True)

    # correctness cross-check at the bucket width, then interpret-mode
    # timings at bucket vs full-table width (the launch-size signal; wall
    # time off-TPU tracks the page count the grid actually sweeps)
    np.testing.assert_allclose(
        np.asarray(paged_attn(bucket)),
        np.asarray(decode_attention_ref(q, kp, vp, pt_full, kv_len)),
        rtol=2e-5, atol=2e-5)
    us_bucket = timed_us(lambda: paged_attn(bucket))
    us_full = timed_us(lambda: paged_attn(pt_full.shape[1]))
    results["paged_attention"] = {
        "page_size": page_size, "max_len": max_len, "prefix": prefix,
        "bucket_pages": bucket,
        "attn_bytes_per_token_dense": dense_bytes,
        "attn_bytes_per_token_paged": paged_bytes,
        "read_reduction": dense_bytes / paged_bytes,
        "us_per_call_interp_bucket": us_bucket,
        "us_per_call_interp_full_table": us_full,
    }
    if csv_rows is not None:
        csv_rows.append(
            f"decode,paged_attention,{us_bucket:.0f},"
            f"bytes_per_token={paged_bytes:.0f}"
            f";read_reduction={dense_bytes / paged_bytes:.2f}x")

    # ---- chunked admission: TTFT + per-tick latency under mixed load -------
    # one slot decodes throughout while a long prompt is admitted; the tick
    # times during admission ARE the running slot's inter-token latency.
    # chunk_tokens >= prompt reproduces the one-shot admission (whole
    # prefill in one tick); a small budget bounds every tick.
    long_prompt = np.asarray(
        np.random.default_rng(1).integers(0, CFG.vocab_size, 96), np.int32)
    # max_len matches the paged-attention section above: the dense one-shot
    # prefill paid for the whole allocation, not the prompt
    page_size, max_len, kvh, hd = 16, 1024, CFG.num_kv_heads, CFG.hd

    def mixed_load(chunk_tokens: int) -> tuple[float, list[float]]:
        batcher = ContinuousBatcher(params, CFG, num_slots=2, max_len=max_len,
                                    paged=True, page_size=page_size,
                                    chunk_tokens=chunk_tokens)

        def scenario(measure: bool):
            short = Request(rid=0, prompt=np.asarray([3, 1, 4, 1, 5, 9, 2, 6],
                                                     np.int32),
                            max_new_tokens=120)
            batcher.submit(short)
            while not short.output:          # short slot reaches DECODING
                batcher.step()
            long_req = Request(rid=1, prompt=long_prompt, max_new_tokens=4)
            t0 = time.perf_counter()
            batcher.submit(long_req)
            ticks = []
            ttft = None
            while ttft is None:
                ts = time.perf_counter()
                batcher.step()
                ticks.append(time.perf_counter() - ts)
                if long_req.output:
                    ttft = time.perf_counter() - t0
            batcher.run()                    # drain both requests
            return (ttft, ticks) if measure else None

        scenario(measure=False)              # warm every jit cache entry
        return scenario(measure=True)

    admission: dict = {"prompt_len": len(long_prompt),
                       "page_size": page_size}
    for label, budget in (("chunked", 16), ("oneshot", len(long_prompt))):
        ttft, ticks = mixed_load(budget)
        ms = np.asarray(sorted(ticks)) * 1e3
        admission[label] = {
            "chunk_tokens": budget,
            "admission_ticks": len(ticks),
            "ttft_ms": ttft * 1e3,
            "tick_ms_p50": float(np.percentile(ms, 50)),
            "tick_ms_p95": float(np.percentile(ms, 95)),
            "tick_ms_max": float(ms.max()),
        }
        if csv_rows is not None:
            csv_rows.append(
                f"decode,admission_{label},{ttft * 1e6:.0f},"
                f"tick_ms_p95={np.percentile(ms, 95):.2f}"
                f";chunk_tokens={budget}")

    # prefill attention K/V bytes, per layer: the one-shot DENSE admission
    # (pre-chunked scheduler) scored every query row against a max_len-sized
    # cache; chunked-paged reads only the live-prefix page bucket per chunk
    itemsize = 4                                       # f32 pool on CPU
    n = len(long_prompt)
    dense_oneshot = 2 * kvh * max_len * hd * itemsize  # one Skv=max_len pass
    chunked_paged, done = 0, 0
    for w in chunk_plan(n, 16):
        done += w
        bucket = page_bucket(-(-done // page_size), max_len // page_size)
        chunked_paged += 2 * kvh * bucket * page_size * hd * itemsize
    admission["prefill_attn_kv_bytes_oneshot_dense"] = dense_oneshot
    admission["prefill_attn_kv_bytes_chunked_paged"] = chunked_paged
    admission["read_reduction"] = dense_oneshot / chunked_paged
    results["chunked_admission"] = admission

    # ---- prefix caching: N requests over one shared system prompt ----------
    # Production traffic is dominated by shared system prompts / few-shot
    # preambles: with the copy-on-write prefix cache, a warm admission
    # matches the preamble's hash-chain, shares the physical pages
    # (refcounts, zero copies) and prefills only the per-request suffix.
    # Cold vs warm is measured on the SAME batcher: request 0 populates the
    # index, requests 1..N-1 hit it.  TTFT is wall time from submit to the
    # first output token, jit caches pre-warmed so the delta is the prefill
    # work actually skipped, not compile time.
    page_size = 16
    sys_prompt = np.asarray(
        np.random.default_rng(2).integers(0, CFG.vocab_size, 64), np.int32)
    sfx_rng = np.random.default_rng(3)
    suffixes = [sfx_rng.integers(0, CFG.vocab_size, 5).astype(np.int32)
                for _ in range(4)]

    def serve_one(batcher, prompt) -> tuple[float, int]:
        req = Request(rid=0, prompt=prompt, max_new_tokens=4)
        a0 = batcher.pool.acquired_total
        t0 = time.perf_counter()
        batcher.submit(req)
        while not req.output:
            batcher.step()
        ttft = time.perf_counter() - t0
        batcher.run()                        # drain before the next request
        return ttft, batcher.pool.acquired_total - a0

    def shared_prefix_run() -> dict:
        batcher = ContinuousBatcher(params, CFG, num_slots=2, max_len=256,
                                    paged=True, page_size=page_size,
                                    chunk_tokens=16, prefix_cache=True)
        # pre-warm every jit cache entry both cold and warm admissions hit,
        # against a DIFFERENT preamble: same chunk/bucket shapes compile,
        # but the measured cold request below still misses the index
        decoy = np.asarray(np.random.default_rng(4).integers(
            0, CFG.vocab_size, len(sys_prompt)), np.int32)
        for sfx in suffixes[:2]:
            serve_one(batcher, np.concatenate([decoy, sfx]))
        hit0 = batcher.prefix.hit_tokens        # prewarm hits don't count
        cold_ttft, cold_pages = serve_one(
            batcher, np.concatenate([sys_prompt, suffixes[0]]))
        warm = [serve_one(batcher, np.concatenate([sys_prompt, sfx]))
                for sfx in suffixes[1:]]
        warm_prompt_tokens = sum(len(sys_prompt) + len(s)
                                 for s in suffixes[1:])
        return {
            "prefix_len": len(sys_prompt), "page_size": page_size,
            "requests": 1 + len(warm),
            "hit_rate_prompt_tokens":
                (batcher.prefix.hit_tokens - hit0) / warm_prompt_tokens,
            "pages_allocated_cold": cold_pages,
            "pages_allocated_warm_mean":
                float(np.mean([p for _, p in warm])),
            "ttft_ms_cold": cold_ttft * 1e3,
            "ttft_ms_warm_mean": float(np.mean([t for t, _ in warm])) * 1e3,
            "cow_forks": batcher.cow_forks,
        }

    shared = shared_prefix_run()
    shared["ttft_speedup_warm"] = (shared["ttft_ms_cold"]
                                   / shared["ttft_ms_warm_mean"])
    shared["page_alloc_reduction"] = (shared["pages_allocated_cold"]
                                      / shared["pages_allocated_warm_mean"])
    results["prefix_cache"] = shared
    if csv_rows is not None:
        csv_rows.append(
            f"decode,prefix_cache,{shared['ttft_ms_warm_mean'] * 1e3:.0f},"
            f"ttft_speedup_warm={shared['ttft_speedup_warm']:.2f}x"
            f";page_alloc_reduction={shared['page_alloc_reduction']:.2f}x"
            f";hit_rate={shared['hit_rate_prompt_tokens']:.2f}")

    # ---- fault tolerance: goodput + recovery overhead under a storm --------
    # Same serving substrate (paged + prefix cache, shared preamble), run
    # twice: fault-free, then under a seeded storm of pool-exhaustion
    # spikes, NaN decode ticks and one mid-tick crash recovered from an
    # in-memory snapshot.  Faults must cost ticks (retries, stalls, replay),
    # never tokens: the outputs are compared bit-for-bit.
    def serve_load(injector=None):
        batcher = ContinuousBatcher(params, CFG, num_slots=2, max_len=64,
                                    paged=True, page_size=16, num_pages=17,
                                    chunk_tokens=16, prefix_cache=True,
                                    nan_retry_limit=10)
        sup = ServingSupervisor(
            batcher, injector=injector, snapshot_every=2,
            policy=RestartPolicy(max_restarts=4, backoff_base_s=0.0),
            sleep=lambda _: None)
        reqs = [Request(rid=i,
                        prompt=np.concatenate([sys_prompt[:32],
                                               suffixes[i % len(suffixes)]]),
                        max_new_tokens=8)
                for i in range(4)]
        for r in reqs:
            assert sup.submit(r).accepted
        t0 = time.perf_counter()
        rep = sup.run(max_ticks=400)
        wall = time.perf_counter() - t0
        return reqs, rep, wall

    serve_load()                                # warm the jit caches
    clean_reqs, clean_rep, clean_wall = serve_load()
    storm_reqs, storm_rep, storm_wall = serve_load(
        FaultInjector.storm(seed=11, ticks=30, p_spike=0.25, p_nan=0.25,
                            crash_ticks=(5,), spike_duration=2))
    identical = [r.output for r in storm_reqs] == [r.output
                                                   for r in clean_reqs]
    tokens = sum(len(r.output) for r in storm_reqs if r.done)
    fault = {
        "requests": len(storm_reqs),
        "completed_clean": len(clean_rep.completed),
        "completed_storm": len(storm_rep.completed),
        "token_identical": identical,
        "ticks_clean": clean_rep.ticks,
        "ticks_storm": storm_rep.ticks,
        "recovery_tick_overhead": storm_rep.ticks - clean_rep.ticks,
        "goodput_tokens_per_tick_clean":
            sum(len(r.output) for r in clean_reqs if r.done) / clean_rep.ticks,
        "goodput_tokens_per_tick_storm": tokens / storm_rep.ticks,
        "goodput_tokens_per_sec_clean":
            sum(len(r.output) for r in clean_reqs if r.done) / clean_wall,
        "goodput_tokens_per_sec_storm": tokens / storm_wall,
        "recoveries": storm_rep.recoveries,
        "nan_events": storm_rep.nan_events,
        "snapshots": storm_rep.snapshots,
    }
    results["fault_tolerance"] = fault
    if csv_rows is not None:
        csv_rows.append(
            f"decode,fault_tolerance,{storm_wall * 1e6:.0f},"
            f"token_identical={identical}"
            f";recovery_tick_overhead={fault['recovery_tick_overhead']}"
            f";goodput_storm={fault['goodput_tokens_per_tick_storm']:.2f}"
            f"tok/tick;recoveries={storm_rep.recoveries}")

    BENCH_JSON.parent.mkdir(parents=True, exist_ok=True)
    BENCH_JSON.write_text(json.dumps(results, indent=2))
    FAULT_JSON.write_text(json.dumps(fault, indent=2))
    return results


if __name__ == "__main__":
    rows: list = []
    run(rows)
    print("\n".join(rows))
