"""Tensor-parallel serving benchmark: tokens/sec, bytes/token, and
predicted-vs-measured all-reduce cost per tp degree.

For each tp in {1, 2, 4} that fits the visible devices (CI forces 8 CPU
devices via XLA_FLAGS before invoking this):

* **throughput** — tokens/sec of a paged + prefix-cache
  ``ContinuousBatcher`` run on the serving mesh (the full stack: chunked
  admission, CoW prefix sharing, fused decode, all shard_map'd at tp > 1);
* **bytes/token** — XLA cost-analysis bytes of one compiled decode step
  divided by the slot count;
* **comms** — the per-device all-reduce bytes the compiled TP decode step
  actually contains (``collective_bytes`` on its HLO: largest shape per
  instruction, all-reduce doubled for the ring) against the analytic
  ``tp_allreduce_model`` prediction of 2 psums/layer x (B, 1, d_model) in
  the SAME accounting convention (``per_device_bytes``).  Since the model
  fix the bar is tight: predicted/measured must sit within [0.8, 1.25]
  and the all-reduce instruction count must match exactly — the run
  raises otherwise, so CI catches a drifting psum contract or a
  re-broken byte model.  The json records the ratio.

Results land in the CSV rows and ``experiments/bench/tp_serving.json``
(uploaded as a standalone CI artifact).
"""

from __future__ import annotations

import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.launch.dryrun import analyze, tp_allreduce_model
from repro.launch.mesh import make_serving_mesh
from repro.models import init_params
from repro.models.config import reduced
from repro.serve.batching import ContinuousBatcher, Request
from repro.serve.engine import init_cache, make_decode_step
from repro.sharding.serving import plan_for

BENCH_JSON = (Path(__file__).resolve().parent.parent / "experiments"
              / "bench" / "tp_serving.json")

ARCH = "yi-34b"
NUM_SLOTS = 4
MAX_LEN = 64
STEPS = 12


def _requests(cfg, n=6):
    rng = np.random.default_rng(3)
    pre = rng.integers(0, cfg.vocab_size, size=16).astype(np.int32)
    reqs = []
    for i in range(n):
        tail = rng.integers(0, cfg.vocab_size,
                            size=int(rng.integers(3, 10))).astype(np.int32)
        reqs.append(Request(rid=i, max_new_tokens=STEPS,
                            prompt=np.concatenate([pre, tail])
                            if i % 2 else tail))
    return reqs


def _throughput(params, cfg, mesh) -> float:
    def once():
        b = ContinuousBatcher(params, cfg, num_slots=NUM_SLOTS,
                              max_len=MAX_LEN, paged=True, page_size=8,
                              prefix_cache=True, mesh=mesh)
        reqs = _requests(cfg)
        for r in reqs:
            b.submit(r)
        t0 = time.perf_counter()
        b.run()
        toks = sum(len(r.output) for r in reqs)
        return toks, time.perf_counter() - t0

    once()                              # warm the jit caches
    toks, dt = once()
    return toks / dt


def _decode_costs(params, cfg, mesh, tp: int) -> dict:
    """Compile ONE decode step at this tp and read its HLO costs."""
    cache = init_cache(cfg, NUM_SLOTS, MAX_LEN)
    toks = {"tokens": jnp.zeros((NUM_SLOTS, 1), jnp.int32)}
    clen = jnp.zeros((NUM_SLOTS,), jnp.int32)
    if tp > 1:
        from jax.sharding import PartitionSpec as P
        plan = plan_for(cfg, mesh)
        cspecs = plan.cache_specs(cache)
        fn = plan.sjit(make_decode_step(plan.local_cfg),
                       in_specs=(plan.param_specs(params), cspecs,
                                 P(None, None), P(None)),
                       out_specs=(P(None, None, None), cspecs))
    else:
        fn = jax.jit(make_decode_step(cfg))
    compiled = fn.lower(params, cache, toks, clen).compile()
    return analyze(compiled)


def run(csv_rows: list | None = None) -> dict:
    cfg = reduced(get_arch(ARCH), scan_layers=False)
    params = init_params(cfg, jax.random.PRNGKey(0))
    have = jax.device_count()
    tps = [t for t in (1, 2, 4) if t <= have]
    dtype_bytes = jnp.dtype(cfg.compute_dtype).itemsize
    results = []
    for tp in tps:
        mesh = make_serving_mesh(tp) if tp > 1 else None
        toks_s = _throughput(params, cfg, mesh)
        costs = _decode_costs(params, cfg, mesh, tp)
        measured = costs["collectives"]["all-reduce"]
        n_ar = costs["collectives"]["counts"]["all-reduce"]
        pred = tp_allreduce_model(cfg, batch=NUM_SLOTS, seq=1, tp=tp,
                                  dtype_bytes=dtype_bytes)
        ratio = (pred["per_device_bytes"] / measured) if measured else None
        if tp > 1:
            if not (measured and 0.8 <= ratio <= 1.25):
                raise AssertionError(
                    f"tp={tp}: tp_allreduce_model predicts "
                    f"{pred['per_device_bytes']:.0f} B but the compiled "
                    f"decode HLO measures {measured:.0f} B (ratio {ratio}) "
                    f"— outside the [0.8, 1.25] bar")
            if n_ar != pred["allreduce_count"]:
                raise AssertionError(
                    f"tp={tp}: {n_ar} all-reduce instructions in the decode "
                    f"HLO, model expects {pred['allreduce_count']} "
                    f"(2 psums/layer x {cfg.num_layers} layers)")
        results.append({
            "tp": tp,
            "tokens_per_sec": round(toks_s, 2),
            "bytes_per_token": costs["bytes_accessed"] / NUM_SLOTS,
            "allreduce_count": n_ar,
            "measured_allreduce_bytes": measured,
            "predicted_allreduce_bytes": pred["per_device_bytes"],
            "predicted_vs_measured_ratio": ratio,
            "predicted_allreduce_s": pred["predicted_s"],
        })
        if csv_rows is not None:
            csv_rows.append(
                f"tp_serving,tp={tp},{toks_s:.1f}tok/s,"
                f"allreduce={measured:.0f}B/pred="
                f"{pred['per_device_bytes']:.0f}B;n={n_ar}")
    out = {
        "arch": ARCH, "device_count": have, "tps": tps,
        "num_slots": NUM_SLOTS, "steps": STEPS,
        "dtype_bytes": dtype_bytes, "results": results,
    }
    BENCH_JSON.parent.mkdir(parents=True, exist_ok=True)
    BENCH_JSON.write_text(json.dumps(out, indent=2))
    print(f"wrote {BENCH_JSON}")
    return out


if __name__ == "__main__":
    run()
