"""Shared benchmark substrate: small pretrained models + calibration + PTQ.

Benchmarks need models with REALISTIC activation statistics (anisotropic,
correlated — that is what separates QERA-exact from QERA-approx from LQER),
so we briefly pretrain small models on the synthetic corpus and cache the
weights under experiments/bench_cache/.
"""

from __future__ import annotations

import dataclasses
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.ckpt import CheckpointManager
from repro.core import PTQConfig, quantize_params
from repro.core.calibration import LayerStats
from repro.data.tokenstream import DataConfig, make_batch
from repro.models import ModelConfig, Taps, forward, init_params
from repro.train import OptimizerConfig, init_opt_state, make_train_step

CACHE_DIR = Path(__file__).resolve().parent.parent / "experiments" / "bench_cache"

LM_CFG = ModelConfig(
    name="bench-lm", family="dense", num_layers=4, d_model=96, num_heads=6,
    num_kv_heads=3, head_dim=16, d_ff=256, vocab_size=256, max_seq_len=256,
    scan_layers=False)

ENC_CFG = ModelConfig(
    name="bench-enc", family="encoder", num_layers=3, d_model=96, num_heads=6,
    num_kv_heads=6, head_dim=16, d_ff=256, vocab_size=256, max_seq_len=128,
    num_classes=2, scan_layers=False)

LM_DATA = DataConfig(vocab_size=256, seq_len=64, global_batch=16, seed=7)


def pretrained_lm(steps: int = 300, force: bool = False):
    """Small decoder LM trained on the synthetic corpus (cached)."""
    mgr = CheckpointManager(CACHE_DIR / "lm", keep=1)
    if not force and mgr.latest_step() == steps:
        _, tree, _ = mgr.restore()
        return tree["params"]
    params = init_params(LM_CFG, jax.random.PRNGKey(0))
    opt_cfg = OptimizerConfig(peak_lr=3e-3, schedule="cosine",
                              warmup_steps=20, total_steps=steps)
    step_fn = jax.jit(make_train_step(LM_CFG, opt_cfg), donate_argnums=(0, 1))
    state = init_opt_state(params)
    for s in range(steps):
        batch = {k: jnp.asarray(v) for k, v in make_batch(LM_DATA, s).items()}
        params, state, m = step_fn(params, state, batch)
    print(f"# pretrained bench LM: final ce {float(m['ce']):.3f}")
    mgr.save(steps, {"params": params})
    return params


def calib_batches(n_samples: int, seq: int = 64, seed: int = 1234):
    """Calibration token batches disjoint from training (different seed)."""
    dc = dataclasses.replace(LM_DATA, seed=seed,
                             global_batch=max(1, n_samples))
    return make_batch(dc, 0)["tokens"][:n_samples]


def calibrate(params, cfg: ModelConfig, tokens, with_outer: bool = True):
    """Run Taps over calibration tokens -> {weight_path: LayerStats}."""
    taps = Taps(with_outer=with_outer)
    forward(params, {"tokens": jnp.asarray(tokens)}, cfg, taps=taps)
    return remap_stats(taps.layer_stats())


def remap_stats(stats: dict) -> dict[str, LayerStats]:
    """taps keys 'blocks/i/<sub>/<name>' -> param keys 'blocks/<name>:i'
    (+ passthrough for non-block layers)."""
    out = {}
    for k, v in stats.items():
        parts = k.split("/")
        if parts[0] == "blocks":
            out[f"blocks/{parts[-1]}:{parts[1]}"] = v
        else:
            out[k.replace("/", "_")] = v
            out[k] = v
    return out


def ptq(params, cfg_model: ModelConfig, method: str, rank: int,
        quantizer: str, stats=None, **kw):
    qcfg = PTQConfig(method=method, rank=rank, quantizer=quantizer, **kw)
    return quantize_params(params, qcfg, stats_by_path=stats,
                           key=jax.random.PRNGKey(0))


def model_output_error(params_a, params_b, cfg: ModelConfig, tokens) -> float:
    """Mean squared error between output logits of two param sets
    (the paper's Fig. 1 metric)."""
    la, _, _ = forward(params_a, {"tokens": jnp.asarray(tokens)}, cfg)
    lb, _, _ = forward(params_b, {"tokens": jnp.asarray(tokens)}, cfg)
    return float(jnp.mean(jnp.sum((la - lb) ** 2, axis=-1)))


def eval_ce(params, cfg: ModelConfig, *, seed: int = 999, batches: int = 4) -> float:
    """Held-out CE (the WikiText2-perplexity stand-in)."""
    from repro.models.transformer import cross_entropy
    dc = dataclasses.replace(LM_DATA, seed=seed)
    tot = 0.0
    for s in range(batches):
        b = make_batch(dc, s)
        logits, _, _ = forward(params, {"tokens": jnp.asarray(b["tokens"])},
                               cfg)
        tot += float(cross_entropy(logits, jnp.asarray(b["labels"])))
    return tot / batches


def timed(fn, *args, **kw):
    t0 = time.time()
    out = fn(*args, **kw)
    jax.block_until_ready(jax.tree.leaves(out)[0] if jax.tree.leaves(out)
                          else jnp.zeros(()))
    return out, time.time() - t0
