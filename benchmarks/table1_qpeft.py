"""Tables 1 & 2 proxy — QPEFT fine-tuning quality across init methods/bits.

Two settings, mirroring the paper:
  (a) LM continued-pretraining (SlimPajama proxy): quantize the pretrained
      bench LM, init adapters with {QLoRA, LoftQ, QERA-approx}, fine-tune
      adapters on fresh corpus, report held-out CE (Δppl analog).
  (b) encoder classification (GLUE proxy): fp32-pretrain an encoder on task
      A, quantize, adapt to task B.

Paper claims: QERA init ⇒ better final quality than LoftQ > QLoRA, with the
gap growing at lower bits; also lower INITIAL loss (better starting point).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import (
    ENC_CFG,
    LM_CFG,
    LM_DATA,
    calib_batches,
    calibrate,
    eval_ce,
    pretrained_lm,
    ptq,
)
from repro.core.qpeft import qpeft_finetune
from repro.data.tokenstream import make_batch, synth_tokens
from repro.models import forward, init_params
from repro.models.transformer import classification_loss, lm_loss
from repro.train import OptimizerConfig, init_opt_state, make_train_step

BITS = {"mxint4": 8, "mxint3": 8, "mxint2": 16}   # bits -> adapter rank
# qera_exact included: the paper itself uses QERA-exact for the 2-bit GLUE
# row (Table 1) and recommends approx for >=3-bit QPEFT (Appendix A.8).
METHODS = ["qlora", "loftq", "qera_approx", "qera_exact"]
FT_STEPS = 80


def _lm_batches(steps: int, seed: int = 5150):
    dc = dataclasses.replace(LM_DATA, seed=seed)
    for s in range(steps):
        yield {k: jnp.asarray(v) for k, v in make_batch(dc, s).items()}


def run_lm(csv_rows: list | None = None) -> dict:
    params = pretrained_lm()
    stats = calibrate(params, LM_CFG, calib_batches(32))
    base_ce = eval_ce(params, LM_CFG)
    results = {("fp32", "-"): base_ce}
    opt_cfg = OptimizerConfig(peak_lr=1e-3, schedule="cosine", warmup_steps=8,
                              total_steps=FT_STEPS, weight_decay=0.0)

    for quant, rank in BITS.items():
        for method in METHODS:
            qp = ptq(params, LM_CFG, method, rank, quant, stats=stats)
            init_ce = eval_ce(qp, LM_CFG)
            tuned, losses = qpeft_finetune(
                qp, lambda p, b: lm_loss(p, b, LM_CFG),
                _lm_batches(FT_STEPS), opt_cfg)
            final_ce = eval_ce(tuned, LM_CFG)
            results[(quant, method)] = final_ce
            results[(quant, method, "init")] = init_ce
            if csv_rows is not None:
                csv_rows.append(
                    f"table2_lm,{quant},{method},init_ce={init_ce:.4f},"
                    f"final_ce={final_ce:.4f}")

    checks = {}
    for quant in BITS:
        # QERA always beats no-reconstruction (QLoRA); at 2-bit the exact
        # solution must beat everything (the paper's aggressive-quant claim;
        # at CPU bench scale activations are only mildly anisotropic, so
        # LoftQ-5iter can match approx — the paper sees the same at 4-bit).
        checks[f"{quant}/qera_beats_qlora_init"] = (
            results[(quant, "qera_approx", "init")]
            <= results[(quant, "qlora", "init")] * 1.001)
    checks["mxint2/qera_exact_init_best"] = (
        results[("mxint2", "qera_exact", "init")]
        <= min(results[("mxint2", m, "init")]
               for m in ["qlora", "loftq", "qera_approx"]) * 1.005)
    if csv_rows is not None:
        csv_rows.append(f"table2_lm,fp32,-,final_ce={base_ce:.4f},")
        for name, ok in checks.items():
            csv_rows.append(f"table2_check,{name},,{'PASS' if ok else 'FAIL'},")
    return {"results": results, "checks": checks}


# ---------------------------------------------------------------------------
# encoder classification (GLUE proxy)
# ---------------------------------------------------------------------------

def _cls_batch(step: int, *, rule: str, batch: int = 32, seq: int = 32,
               seed: int = 11):
    dc = dataclasses.replace(LM_DATA, seq_len=seq - 1, global_batch=batch,
                             seed=seed + (0 if rule == "a" else 5000))
    toks = synth_tokens(dc, step)[:, :seq]
    if rule == "a":      # majority of tokens in the lower half of the vocab
        labels = (np.mean(toks < dc.vocab_size // 2, axis=1) > 0.5)
    else:                # prevalence of tokens divisible by 3 (> 1/3 base)
        labels = (np.mean(toks % 3 == 0, axis=1) > 1.0 / 3.0)
    return {"tokens": jnp.asarray(toks),
            "labels": jnp.asarray(labels.astype(np.int32))}


def _cls_acc(params, step0: int = 900, rule: str = "b", batches: int = 4):
    accs = []
    for s in range(batches):
        b = _cls_batch(step0 + s, rule=rule)
        logits, _, _ = forward(params, b, ENC_CFG)
        accs.append(float(jnp.mean(
            (jnp.argmax(logits, -1) == b["labels"]).astype(jnp.float32))))
    return float(np.mean(accs))


def run_encoder(csv_rows: list | None = None) -> dict:
    # "pretrain" the encoder fp32 on task A
    params = init_params(ENC_CFG, jax.random.PRNGKey(0))
    opt = OptimizerConfig(peak_lr=2e-3, schedule="cosine", warmup_steps=10,
                          total_steps=150)
    step_fn = jax.jit(make_train_step(
        ENC_CFG, opt, loss_fn=classification_loss), donate_argnums=(0, 1))
    state = init_opt_state(params)
    for s in range(150):
        params, state, _ = step_fn(params, state, _cls_batch(s, rule="a"))

    # calibration on task-A-style inputs (the paper: pretraining-domain calib)
    from benchmarks.common import calibrate as _cal
    calib_toks = _cls_batch(500, rule="a", batch=32)["tokens"]
    stats = _cal(params, ENC_CFG, calib_toks)

    opt_ft = OptimizerConfig(peak_lr=2e-3, schedule="cosine", warmup_steps=8,
                             total_steps=FT_STEPS, weight_decay=0.0)
    results = {}
    for quant, rank in [("mxint3", 8), ("mxint2", 16)]:
        for method in METHODS:
            qp = ptq(params, ENC_CFG, method, rank, quant, stats=stats)
            tuned, _ = qpeft_finetune(
                qp, lambda p, b: classification_loss(p, b, ENC_CFG),
                (_cls_batch(s, rule="b") for s in range(FT_STEPS)), opt_ft)
            acc = _cls_acc(tuned, rule="b")
            results[(quant, method)] = acc
            if csv_rows is not None:
                csv_rows.append(f"table1_enc,{quant},{method},acc={acc:.4f}")
    return {"results": results}


def run(csv_rows: list | None = None) -> dict:
    lm = run_lm(csv_rows)
    enc = run_encoder(csv_rows)
    return {"lm": lm, "encoder": enc}


if __name__ == "__main__":
    rows: list = []
    run(rows)
    print("\n".join(rows))
