"""Tables 7/8 — quantization (init) runtime: QERA-exact vs QERA-approx.

Paper: exact pays for the autocorrelation sqrt + scaled SVD; approx is
2-3x cheaper end-to-end and recommended for QPEFT.  We time the full
model-quantization pass per method/rank on CPU, plus the sqrtm kernel
choice (eigh vs Newton-Schulz — the TPU-native alternative)."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import (
    LM_CFG,
    calib_batches,
    calibrate,
    pretrained_lm,
    ptq,
    timed,
)
from repro.core.sqrtm import psd_sqrt_eigh, psd_sqrt_newton_schulz


def run(csv_rows: list | None = None) -> dict:
    params = pretrained_lm()
    stats = calibrate(params, LM_CFG, calib_batches(32))
    results = {}
    for method, rank in [("qera_approx", 8), ("qera_approx", 16),
                         ("qera_exact", 8), ("qera_exact", 16),
                         ("zeroquant_v2", 8), ("loftq", 8)]:
        ptq(params, LM_CFG, method, rank, "mxint4", stats=stats)  # warm JIT
        _, dt = timed(ptq, params, LM_CFG, method, rank, "mxint4",
                      stats=stats)
        results[(method, rank)] = dt
        if csv_rows is not None:
            csv_rows.append(f"table8,{method},r{rank},{dt * 1e6:.0f}us")

    # sqrtm microbench: eigh vs Newton-Schulz at growing sizes
    for n in [96, 256, 512]:
        x = jax.random.normal(jax.random.PRNGKey(0), (2048, n))
        r = (x.T @ x) / 2048
        for name, fn in [("eigh", lambda: psd_sqrt_eigh(r)),
                         ("newton_schulz",
                          lambda: psd_sqrt_newton_schulz(r, num_iters=30))]:
            fn()  # compile
            t0 = time.time()
            for _ in range(3):
                jax.block_until_ready(fn()[0])
            dt = (time.time() - t0) / 3
            results[(f"sqrtm_{name}", n)] = dt
            if csv_rows is not None:
                csv_rows.append(f"table8_sqrtm,{name},n{n},{dt * 1e6:.0f}us")
    return {"results": results}


if __name__ == "__main__":
    rows: list = []
    run(rows)
    print("\n".join(rows))
