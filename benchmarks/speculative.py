"""Self-speculative decoding benchmark: the MXINT draft plane as a free
draft model (ISSUE 9 / ROADMAP "speculative decoding from the quantization
hierarchy").

All numbers come from the SAME packed weights — the draft path reads the
``draft_bits`` high-order mantissa plane of the HBM-resident buffers
(``serve/speculative.py``), the verifier is the full fused MXINT+low-rank
kernel scoring all k drafts in ONE (B, k+1) chunk launch.  Sections:

* **engine** — ``scan_generate`` at spec_k in {0, 2, 4} x draft_bits in
  {2, 4}: acceptance rate, rounds, and the headline *full-precision
  launches per emitted token* (spec_k=0 pays one fused launch per token;
  speculation pays one verify launch per ROUND).  Outputs are asserted
  bit-identical to spec_k=0 for every cell.  On CPU the per-launch
  dispatch dominates, so launches/token is the hardware-independent
  speedup signal; the run fails if the best cell does not clear 1.5x.
* **cost model** — per-launch wall times of the three step kinds (full
  decode, draft decode, (k+1)-token verify) feed the analytic model
  ``speedup = E[tokens/round] * c_full / (k*c_draft + c_verify)``; the
  json records predicted vs measured wall-clock speedup per cell so a
  regression in either the kernel or the model is visible.  (On CPU
  host emulation the draft launch is NOT cheaper — no HBM bandwidth to
  save — so the cost ratio is recorded, not asserted.)
* **batcher** — wall-clock tokens/sec of a ``ContinuousBatcher`` run at
  spec_k=0 vs spec_k=4 on the serving path (paged + prefix cache),
  outputs compared bit-for-bit.

Results land in the CSV rows and ``experiments/bench/speculative.json``
(consolidated into ``experiments/bench/bench.json`` by
``benchmarks.consolidate``).
"""

from __future__ import annotations

import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import LM_CFG, calib_batches, calibrate, pretrained_lm
from benchmarks.kernel_bench import timed_us
from repro.core import PTQConfig, quantize_params
from repro.core.api import pack_for_serving
from repro.serve.batching import ContinuousBatcher, Request
from repro.serve.engine import init_cache, make_decode_step, scan_generate
from repro.serve.speculative import make_draft_params

BENCH_JSON = (Path(__file__).resolve().parent.parent / "experiments"
              / "bench" / "speculative.json")

B, PROMPT_LEN, STEPS = 4, 8, 32
SPEC_KS = (2, 4)
DRAFT_BITS = (2, 4)
MIN_LAUNCH_REDUCTION = 1.5


def _packed_model():
    params = pretrained_lm()
    stats = calibrate(params, LM_CFG, calib_batches(8))
    qcfg = PTQConfig(method="qera_approx", rank=8, quantizer="mxint4")
    return pack_for_serving(
        quantize_params(params, qcfg, stats_by_path=stats), qcfg)


def _step_costs(packed, cfg, spec_ks, draft_bits) -> dict:
    """Per-launch wall times of the three step kinds on a warm jit."""
    max_len = PROMPT_LEN + STEPS + max(spec_ks) + 1
    cache = init_cache(cfg, B, max_len)
    clen = jnp.full((B,), PROMPT_LEN, jnp.int32)
    step = jax.jit(make_decode_step(cfg))

    def one(params, width):
        toks = {"tokens": jnp.zeros((B, width), jnp.int32)}
        return timed_us(lambda: step(params, cache, toks, clen))

    costs = {"c_full_us": one(packed, 1)}
    for db in draft_bits:
        dp = make_draft_params(packed, draft_bits=db, skip_lowrank=True)
        costs[f"c_draft_us_bits{db}"] = one(dp, 1)
    for k in spec_ks:
        costs[f"c_verify_us_k{k}"] = one(packed, k + 1)
    return costs


def run(csv_rows: list | None = None) -> dict:
    cfg = LM_CFG
    packed = _packed_model()
    prompt = jax.random.randint(jax.random.PRNGKey(5), (B, PROMPT_LEN), 0,
                                cfg.vocab_size)

    results: dict = {"arch": cfg.name, "batch": B, "steps": STEPS}

    # ---- engine: acceptance + launches/token, bit-identity per cell --------
    ref = np.asarray(scan_generate(packed, cfg, prompt, STEPS))
    t_ref = timed_us(lambda: scan_generate(packed, cfg, prompt, STEPS)) / 1e6
    emitted = B * STEPS
    results["baseline"] = {"tokens_per_sec": emitted / t_ref,
                           "launches_per_token": 1.0}

    costs = _step_costs(packed, cfg, SPEC_KS, DRAFT_BITS)
    results["step_costs_us"] = costs
    # <1 on real accelerators (draft skips the low-rank bytes+FLOPs and
    # unpacks a narrower plane); on CPU host emulation there is no HBM
    # bandwidth to save and the plane extraction costs extra integer ops,
    # so the ratio is >1 — recorded, not asserted, and fed into the
    # wall-clock model below so predictions stay honest per backend.
    results["draft_cost_ratio"] = {
        f"bits{db}": costs[f"c_draft_us_bits{db}"] / costs["c_full_us"]
        for db in DRAFT_BITS}

    cells = []
    for k in SPEC_KS:
        for db in DRAFT_BITS:
            def spec():
                return scan_generate(packed, cfg, prompt, STEPS, spec_k=k,
                                     draft_bits=db, return_spec_stats=True)

            toks, stats = spec()
            assert np.array_equal(ref, np.asarray(toks)), (
                f"spec_k={k} draft_bits={db}: output diverged from spec_k=0")
            t_spec = timed_us(lambda: spec()[0]) / 1e6
            rounds = int(stats["rounds"])
            acc = int(stats["accepted"]) / max(int(stats["drafted"]), 1)
            # one full-precision (verify) launch per round vs one per token
            tokens_per_round = STEPS / rounds      # per sequence, greedy
            c_d = costs[f"c_draft_us_bits{db}"]
            c_v = costs[f"c_verify_us_k{k}"]
            predicted = (tokens_per_round * costs["c_full_us"]
                         / (k * c_d + c_v))
            measured = t_ref / t_spec
            cells.append({
                "spec_k": k, "draft_bits": db,
                "acceptance_rate": acc,
                "rounds": rounds,
                "drafted": int(stats["drafted"]),
                "accepted": int(stats["accepted"]),
                "launches_per_token": rounds / STEPS,
                "launch_reduction": tokens_per_round,
                "tokens_per_sec": emitted / t_spec,
                "wallclock_speedup_measured": measured,
                "wallclock_speedup_predicted": predicted,
                "model_error": predicted / measured if measured else None,
            })
            if csv_rows is not None:
                csv_rows.append(
                    f"speculative,k{k}_bits{db},"
                    f"{t_spec / emitted * 1e6:.0f},"
                    f"acceptance={acc:.2f}"
                    f";launch_reduction={tokens_per_round:.2f}x"
                    f";speedup_measured={measured:.2f}x"
                    f";predicted={predicted:.2f}x")
    results["cells"] = cells

    best = max(cells, key=lambda c: c["launch_reduction"])
    results["best"] = {k: best[k] for k in
                       ("spec_k", "draft_bits", "launch_reduction",
                        "acceptance_rate", "wallclock_speedup_measured",
                        "wallclock_speedup_predicted")}
    assert best["launch_reduction"] >= MIN_LAUNCH_REDUCTION, (
        f"best cell (spec_k={best['spec_k']}, draft_bits="
        f"{best['draft_bits']}) reduces full-precision launches only "
        f"{best['launch_reduction']:.2f}x / token — below the "
        f"{MIN_LAUNCH_REDUCTION}x bar")

    # ---- batcher: serving-path tokens/sec, spec_k=0 vs spec_k=4 ------------
    def _requests(n=6):
        rng = np.random.default_rng(9)
        pre = rng.integers(0, cfg.vocab_size, size=16).astype(np.int32)
        return [Request(rid=i, max_new_tokens=12,
                        prompt=np.concatenate(
                            [pre, rng.integers(0, cfg.vocab_size,
                                               size=int(rng.integers(3, 10))
                                               ).astype(np.int32)])
                        if i % 2 else
                        rng.integers(0, cfg.vocab_size, size=6
                                     ).astype(np.int32))
                for i in range(n)]

    def serve(spec_k):
        def once():
            b = ContinuousBatcher(packed, cfg, num_slots=4, max_len=64,
                                  paged=True, page_size=8, prefix_cache=True,
                                  spec_k=spec_k, draft_bits=4)
            reqs = _requests()
            for r in reqs:
                b.submit(r)
            t0 = time.perf_counter()
            b.run()
            toks = sum(len(r.output) for r in reqs)
            return {r.rid: list(r.output) for r in reqs}, toks, \
                time.perf_counter() - t0, b

        once()                               # warm the jit caches
        return once()

    out0, toks0, dt0, _ = serve(0)
    out4, toks4, dt4, b4 = serve(4)
    assert out0 == out4, "batcher spec_k=4 output diverged from spec_k=0"
    results["batcher"] = {
        "tokens_per_sec_spec0": toks0 / dt0,
        "tokens_per_sec_spec4": toks4 / dt4,
        "wallclock_speedup": (toks4 / dt4) / (toks0 / dt0),
        "spec_rounds": b4.spec_rounds,
        "spec_acceptance": b4.spec_accepted / max(b4.spec_drafted, 1),
        "launches_per_committed_token":
            b4.spec_rounds / max(b4.spec_committed, 1),
    }
    if csv_rows is not None:
        csv_rows.append(
            f"speculative,batcher_spec4,{dt4 / max(toks4, 1) * 1e6:.0f},"
            f"tokens_per_sec={toks4 / dt4:.1f}"
            f";speedup={(toks4 / dt4) / (toks0 / dt0):.2f}x"
            f";acceptance={results['batcher']['spec_acceptance']:.2f}")

    BENCH_JSON.parent.mkdir(parents=True, exist_ok=True)
    BENCH_JSON.write_text(json.dumps(results, indent=2))
    print(f"wrote {BENCH_JSON}")
    return results


if __name__ == "__main__":
    rows: list = []
    run(rows)
    print("\n".join(rows))
