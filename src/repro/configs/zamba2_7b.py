"""zamba2-7b — hybrid Mamba2 + shared attention blocks.
[arXiv:2411.15242; unverified]  81L d_model=3584 32H (GQA kv=32) d_ff=14336
vocab=32000, ssm_state=64.  Shared transformer block applied every 6 layers
(single weight copy — the zamba2 signature)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b", family="hybrid_mamba",
    num_layers=81, d_model=3584, num_heads=32, num_kv_heads=32,
    d_ff=14336, vocab_size=32000, head_dim=112,
    ssm_state=64, ssm_expand=2, ssm_head_dim=64, ssm_conv_width=4,
    ssm_chunk=256, attn_every=6,
    max_seq_len=524288, dtype="bfloat16",
)
