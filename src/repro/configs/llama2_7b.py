"""llama-2-7b — the paper's central PTQ/QPEFT subject.
32L d_model=4096 32H MHA d_ff=11008 vocab=32000."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama-2-7b", family="dense",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=32,
    d_ff=11008, vocab_size=32000, head_dim=128,
    max_seq_len=4096, dtype="bfloat16",
)
