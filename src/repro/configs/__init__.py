from repro.configs.registry import (
    ASSIGNED_ARCHS,
    SHAPES,
    ShapeSpec,
    arch_names,
    dryrun_cells,
    get_arch,
    shapes_for,
)
