"""phi3.5-moe-42b-a6.6b — 16 experts top-2.
[hf:microsoft/Phi-3.5-MoE-instruct; hf]  32L d_model=4096 32H (GQA kv=8)
d_ff=6400 vocab=32064."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="phi3.5-moe-42b-a6.6b", family="moe",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8,
    d_ff=6400, vocab_size=32064, head_dim=128,
    num_experts=16, moe_top_k=2, capacity_factor=1.25,
    max_seq_len=32768, dtype="bfloat16",
)
