"""tinyllama-1.1b — the paper's smallest PTQ subject (Table 3).
22L d_model=2048 32H (GQA kv=4) d_ff=5632 vocab=32000."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="tinyllama-1.1b", family="dense",
    num_layers=22, d_model=2048, num_heads=32, num_kv_heads=4,
    d_ff=5632, vocab_size=32000, head_dim=64,
    max_seq_len=2048, dtype="bfloat16",
)
