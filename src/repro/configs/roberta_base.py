"""roberta-base — the paper's own QPEFT encoder (GLUE experiments).
12L d_model=768 12H d_ff=3072 vocab=50265, LayerNorm+GELU, learned positions."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="roberta-base", family="encoder",
    num_layers=12, d_model=768, num_heads=12, num_kv_heads=12,
    d_ff=3072, vocab_size=50265, head_dim=64,
    max_seq_len=512, num_classes=2, dtype="float32",
)
