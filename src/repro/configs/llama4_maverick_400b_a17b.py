"""llama4-maverick-400b-a17b — MoE, 128 experts top-1, early fusion.
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]  48L d_model=5120 40H
(GQA kv=8) d_ff=8192 vocab=202048."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b", family="moe",
    num_layers=48, d_model=5120, num_heads=40, num_kv_heads=8,
    d_ff=8192, vocab_size=202048, head_dim=128,
    num_experts=128, moe_top_k=1, capacity_factor=1.25,
    max_seq_len=32768, dtype="bfloat16",
)
