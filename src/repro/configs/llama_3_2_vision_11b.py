"""llama-3.2-vision-11b — cross-attn image layers every 5th layer (vision
tower is a stub: input_specs provides precomputed patch embeddings).
[hf:meta-llama/Llama-3.2-11B-Vision; unverified]  40L d_model=4096 32H
(GQA kv=8) d_ff=14336 vocab=128256."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-11b", family="vlm",
    num_layers=40, d_model=4096, num_heads=32, num_kv_heads=8,
    d_ff=14336, vocab_size=128256, head_dim=128,
    cross_attn_every=5, vision_seq=1601,
    max_seq_len=32768, dtype="bfloat16",
)
