"""musicgen-medium — decoder-only over EnCodec tokens (backbone only; the
EnCodec frontend is a stub: input_specs provides 4 codebook id streams).
[arXiv:2306.05284; hf]  48L d_model=1536 24H (kv=24) d_ff=6144 vocab=2048."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium", family="audio",
    num_layers=48, d_model=1536, num_heads=24, num_kv_heads=24,
    d_ff=6144, vocab_size=2048, head_dim=64,
    num_codebooks=4,
    max_seq_len=32768, dtype="bfloat16",
)
