"""rwkv6-7b "Finch" — attention-free, data-dependent decay linear RNN.
[arXiv:2404.05892; hf]  32L d_model=4096 d_ff=14336 vocab=65536."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-7b", family="rwkv",
    num_layers=32, d_model=4096, num_heads=64, num_kv_heads=64,
    d_ff=14336, vocab_size=65536, head_dim=64,
    rwkv_head_dim=64, rwkv_decay_lora=64, rwkv_chunk=16,
    max_seq_len=524288, dtype="bfloat16",
)
