"""minicpm-2b — llama-like dense with WSD schedule + mup-style scaling.
[arXiv:2404.06395; hf]  40L d_model=2304 36H (MHA kv=36) d_ff=5760
vocab=122753.  embed_scale=12, residual scaled 1.4/sqrt(L), tied embeddings —
the MiniCPM training recipe knobs (the WSD schedule lives in train/schedules)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="minicpm-2b", family="dense",
    num_layers=40, d_model=2304, num_heads=36, num_kv_heads=36,
    d_ff=5760, vocab_size=122753, head_dim=64,
    tie_embeddings=True, embed_scale=12.0, residual_scale=1.4 / 40 ** 0.5,
    vocab_pad_multiple=256,   # 122753 -> 122880 (sharding divisibility)
    max_seq_len=32768, dtype="bfloat16",
)
