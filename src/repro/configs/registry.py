"""Architecture + input-shape registry.

The 10 assigned architectures each pair with the LM shape set below; shape
applicability rules (assignment spec):

* ``decode_*`` / ``long_*`` lower ``serve_step`` (1 new token against a
  seq_len cache), not ``train_step``;
* ``long_500k`` requires sub-quadratic attention — run only for
  SSM/hybrid/linear-attention archs (zamba2, rwkv6), skipped for pure
  full-attention archs (recorded in DESIGN.md §4);
* all archs are decoder-style, so no encoder-only decode skips apply.
"""

from __future__ import annotations

import dataclasses
import importlib

from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str                      # "train" | "prefill" | "decode"

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}

_ARCH_MODULES = {
    "zamba2-7b": "zamba2_7b",
    "llama4-maverick-400b-a17b": "llama4_maverick_400b_a17b",
    "phi3.5-moe-42b-a6.6b": "phi3_5_moe_42b_a6_6b",
    "yi-34b": "yi_34b",
    "minicpm-2b": "minicpm_2b",
    "phi3-mini-3.8b": "phi3_mini_3_8b",
    "command-r-plus-104b": "command_r_plus_104b",
    "musicgen-medium": "musicgen_medium",
    "llama-3.2-vision-11b": "llama_3_2_vision_11b",
    "rwkv6-7b": "rwkv6_7b",
    # the paper's own models (benchmarks; not part of the 40-cell matrix)
    "roberta-base": "roberta_base",
    "tinyllama-1.1b": "tinyllama_1_1b",
    "llama-2-7b": "llama2_7b",
}

ASSIGNED_ARCHS = tuple(n for n in _ARCH_MODULES
                       if n not in ("roberta-base", "tinyllama-1.1b",
                                    "llama-2-7b"))


def arch_names(include_paper: bool = False) -> list[str]:
    return list(_ARCH_MODULES) if include_paper else list(ASSIGNED_ARCHS)


def get_arch(name: str) -> ModelConfig:
    if name not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {name!r}; choose from {list(_ARCH_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_ARCH_MODULES[name]}")
    return mod.CONFIG


def shapes_for(name: str) -> list[ShapeSpec]:
    """Shape set for an arch, applying the long_500k sub-quadratic rule."""
    cfg = get_arch(name)
    out = [SHAPES["train_4k"], SHAPES["prefill_32k"], SHAPES["decode_32k"]]
    if cfg.is_subquadratic:
        out.append(SHAPES["long_500k"])
    return out


def dryrun_cells(include_skipped: bool = False):
    """All (arch, shape) dry-run cells; skipped cells flagged when asked."""
    cells = []
    for arch in ASSIGNED_ARCHS:
        run_shapes = {s.name for s in shapes_for(arch)}
        for sname, spec in SHAPES.items():
            if sname in run_shapes:
                cells.append((arch, spec, True))
            elif include_skipped:
                cells.append((arch, spec, False))
    return cells
