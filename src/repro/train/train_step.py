"""Train-step factories: standard pjit step, microbatched grad-accumulation,
and the explicit-DP bf16-compressed-gradient variant (shard_map).

The standard step is what the multi-pod dry-run lowers: GSPMD handles all
collectives (grad all-reduce over (pod, data), weight all-gathers for FSDP,
TP reductions) from the in_shardings alone.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.config import ModelConfig
from repro.models.transformer import lm_loss
from repro.train.optimizer import (
    OptimizerConfig,
    adamw_update,
    init_opt_state,
    make_schedule,
)


def make_train_step(cfg: ModelConfig, opt_cfg: OptimizerConfig,
                    loss_fn: Callable | None = None) -> Callable:
    """(params, opt_state, batch) -> (params, opt_state, metrics)."""
    loss_fn = loss_fn or lm_loss
    schedule = make_schedule(opt_cfg)

    def train_step(params, opt_state, batch):
        (total, (ce, aux)), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch, cfg)
        params, opt_state, om = adamw_update(params, grads, opt_state,
                                             opt_cfg, schedule)
        metrics = {"loss": total, "ce": ce, "aux": aux, **om}
        return params, opt_state, metrics

    return train_step


def make_microbatched_train_step(cfg: ModelConfig, opt_cfg: OptimizerConfig,
                                 num_microbatches: int,
                                 loss_fn: Callable | None = None) -> Callable:
    """Gradient accumulation over leading microbatch splits of the batch.

    batch leaves must have global_batch % num_microbatches == 0; grads are
    averaged in f32. The scan keeps compile size O(1) in microbatch count and
    lets GSPMD overlap the per-microbatch collectives with the next
    microbatch's compute (latency hiding).
    """
    loss_fn = loss_fn or lm_loss
    schedule = make_schedule(opt_cfg)

    def split(x):
        b = x.shape[0]
        return x.reshape(num_microbatches, b // num_microbatches, *x.shape[1:])

    def train_step(params, opt_state, batch):
        mb = jax.tree.map(split, dict(batch))
        gz = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

        def body(carry, mbatch):
            acc, ce_acc, aux_acc = carry
            (_, (ce, aux)), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, mbatch, cfg)
            acc = jax.tree.map(lambda a, g: a + g.astype(jnp.float32),
                               acc, grads)
            return (acc, ce_acc + ce, aux_acc + aux), None

        (gsum, ce, aux), _ = jax.lax.scan(
            body, (gz, jnp.zeros(()), jnp.zeros(())), mb)
        grads = jax.tree.map(lambda g: g / num_microbatches, gsum)
        params, opt_state, om = adamw_update(params, grads, opt_state,
                                             opt_cfg, schedule)
        metrics = {"ce": ce / num_microbatches, "aux": aux / num_microbatches,
                   **om}
        return params, opt_state, metrics

    return train_step


def make_compressed_dp_train_step(cfg: ModelConfig, opt_cfg: OptimizerConfig,
                                  mesh, loss_fn: Callable | None = None,
                                  compress_dtype=jnp.bfloat16) -> Callable:
    """Explicit data-parallel step with gradient compression.

    Per-shard grads are cast to ``compress_dtype`` *before* the cross-replica
    psum (halving DP all-reduce bytes vs f32), then averaged in f32 for the
    update — the gradient-compression trick of DESIGN.md §5, written with
    shard_map so the collective is explicit and auditable in tests/HLO.
    Params are replicated across 'data' in this variant (ZeRO handled by the
    GSPMD path; this one demonstrates the comm-compression pattern).
    """
    from jax.experimental.shard_map import shard_map

    loss_fn = loss_fn or lm_loss
    schedule = make_schedule(opt_cfg)
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)

    def sharded_grads(params, batch):
        def per_shard(params, batch):
            (_, (ce, aux)), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch, cfg)
            # --- compressed all-reduce ---
            grads = jax.tree.map(
                lambda g: jax.lax.pmean(g.astype(compress_dtype), dp), grads)
            grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
            ce = jax.lax.pmean(ce, dp)
            aux = jax.lax.pmean(aux, dp)
            return grads, ce, aux

        pspec = jax.tree.map(lambda _: P(), params)
        bspec = jax.tree.map(lambda _: P(dp), dict(batch))
        return shard_map(
            per_shard, mesh=mesh,
            in_specs=(pspec, bspec),
            out_specs=(pspec, P(), P()),
        )(params, batch)

    def train_step(params, opt_state, batch):
        grads, ce, aux = sharded_grads(params, batch)
        params, opt_state, om = adamw_update(params, grads, opt_state,
                                             opt_cfg, schedule)
        return params, opt_state, {"ce": ce, "aux": aux, **om}

    return train_step


__all__ = [
    "OptimizerConfig",
    "init_opt_state",
    "make_train_step",
    "make_microbatched_train_step",
    "make_compressed_dp_train_step",
]
