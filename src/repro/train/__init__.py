from repro.train.optimizer import (
    OptimizerConfig,
    adamw_update,
    clip_by_global_norm,
    global_norm,
    init_opt_state,
    make_schedule,
)
from repro.train.train_step import (
    make_compressed_dp_train_step,
    make_microbatched_train_step,
    make_train_step,
)
