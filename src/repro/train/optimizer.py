"""AdamW from scratch (no optax) with ZeRO-sharded states.

Moments are created ``zeros_like(param)`` so under pjit they inherit the
param's (TP + FSDP) sharding — the optimizer update is therefore fully
sharded with zero extra machinery (ZeRO-1/3 semantics).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    peak_lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    schedule: str = "cosine"          # "cosine" | "wsd" | "linear" | "constant"
    moment_dtype: str = "float32"     # "bfloat16" halves optimizer HBM (the
                                      # production knob for >300B on small pods)
    warmup_steps: int = 100
    total_steps: int = 10_000
    decay_frac: float = 0.1           # WSD: final fraction spent decaying
    min_lr_frac: float = 0.1
    # decoupled WD mask: skip 1-D params (norms/biases) — standard practice
    wd_skip_ndim_below: int = 2


def make_schedule(cfg: OptimizerConfig) -> Callable[[jax.Array], jax.Array]:
    """Step -> lr. WSD (warmup-stable-decay) is the MiniCPM schedule."""
    peak, total, warm = cfg.peak_lr, cfg.total_steps, cfg.warmup_steps
    floor = peak * cfg.min_lr_frac

    def sched(step):
        step = jnp.asarray(step, jnp.float32)
        warmup = peak * jnp.minimum(step / jnp.maximum(warm, 1), 1.0)
        if cfg.schedule == "constant":
            after = peak
        elif cfg.schedule == "linear":
            frac = jnp.clip((step - warm) / jnp.maximum(total - warm, 1), 0, 1)
            after = peak + (floor - peak) * frac
        elif cfg.schedule == "cosine":
            frac = jnp.clip((step - warm) / jnp.maximum(total - warm, 1), 0, 1)
            after = floor + (peak - floor) * 0.5 * (1 + jnp.cos(jnp.pi * frac))
        elif cfg.schedule == "wsd":
            decay_start = total * (1 - cfg.decay_frac)
            frac = jnp.clip((step - decay_start) /
                            jnp.maximum(total - decay_start, 1), 0, 1)
            after = peak * (1 - frac) + floor * frac
        else:
            raise ValueError(cfg.schedule)
        return jnp.where(step < warm, warmup, after)

    return sched


def init_opt_state(params: Any, moment_dtype=None) -> dict[str, Any]:
    def zeros(p):
        return jnp.zeros(p.shape, moment_dtype or p.dtype)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree: Any) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads: Any, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), norm


def adamw_update(params: Any, grads: Any, opt_state: dict, cfg: OptimizerConfig,
                 schedule: Callable | None = None):
    """One AdamW step. Returns (new_params, new_opt_state, metrics)."""
    if schedule is None:
        schedule = make_schedule(cfg)
    step = opt_state["step"] + 1
    lr = schedule(step)

    if cfg.clip_norm > 0:
        grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    else:
        gnorm = global_norm(grads)

    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    mdt = jnp.bfloat16 if cfg.moment_dtype == "bfloat16" else None

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m_new = b1 * m.astype(jnp.float32) + (1 - b1) * g32
        v_new = b2 * v.astype(jnp.float32) + (1 - b2) * g32 * g32
        mh = m_new / bc1
        vh = v_new / bc2
        delta = mh / (jnp.sqrt(vh) + cfg.eps)
        if cfg.weight_decay > 0 and p.ndim >= cfg.wd_skip_ndim_below:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        out_dt = mdt or m.dtype
        return ((p - lr * delta.astype(p.dtype)).astype(p.dtype),
                m_new.astype(out_dt), v_new.astype(out_dt))

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(opt_state["m"])
    flat_v = tdef.flatten_up_to(opt_state["v"])
    new = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = tdef.unflatten([n[0] for n in new])
    new_m = tdef.unflatten([n[1] for n in new])
    new_v = tdef.unflatten([n[2] for n in new])
    new_state = {"m": new_m, "v": new_v, "step": step}
    return new_params, new_state, {"lr": lr, "grad_norm": gnorm}
