"""Mesh construction.  Functions, not module-level constants — importing this
module never touches jax device state."""

from __future__ import annotations

import jax

try:  # jax >= 0.5: explicit-sharding API takes per-axis types
    from jax.sharding import AxisType

    def _mk(shape, axes):
        return jax.make_mesh(shape, axes,
                             axis_types=(AxisType.Auto,) * len(axes))
except ImportError:  # older jax: every mesh axis is implicitly Auto
    def _mk(shape, axes):
        return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips/pod; the multi-pod mesh adds a leading 'pod' axis
    (2 pods = 512 chips).  'pod' composes with 'data' for batch sharding —
    only the gradient all-reduce crosses the pod boundary."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mk(shape, axes)


def make_tiny_mesh(*, multi_pod: bool = False):
    """(2,2)/(2,2,2) mesh for CI-scale sharding tests (8 forced devices)."""
    shape = (2, 2, 2) if multi_pod else (2, 2)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mk(shape, axes)


def make_mesh_by_name(name: str):
    return {
        "prod": lambda: make_production_mesh(multi_pod=False),
        "pod": lambda: make_production_mesh(multi_pod=True),
        "tiny": lambda: make_tiny_mesh(multi_pod=False),
        "tiny_pod": lambda: make_tiny_mesh(multi_pod=True),
    }[name]()
