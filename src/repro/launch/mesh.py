"""Mesh construction.  Functions, not module-level constants — importing this
module never touches jax device state."""

from __future__ import annotations

import math

import jax
import numpy as np
from jax.sharding import Mesh

try:  # jax >= 0.5: explicit-sharding API takes per-axis types
    from jax.sharding import AxisType

    def _make(shape, axes):
        return jax.make_mesh(shape, axes,
                             axis_types=(AxisType.Auto,) * len(axes))
except ImportError:  # older jax: every mesh axis is implicitly Auto
    def _make(shape, axes):
        return jax.make_mesh(shape, axes)


def _mk(shape, axes):
    """Build a mesh, failing with an actionable message (not an XLA assert)
    when the axis product exceeds the visible device count."""
    need = math.prod(shape)
    have = jax.device_count()
    if need > have:
        raise ValueError(
            f"mesh {dict(zip(axes, shape))} needs {need} devices but only "
            f"{have} are visible; on CPU force more with "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={need} "
            f"(must be set before jax initializes — see launch/env.py)")
    return _make(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips/pod; the multi-pod mesh adds a leading 'pod' axis
    (2 pods = 512 chips).  'pod' composes with 'data' for batch sharding —
    only the gradient all-reduce crosses the pod boundary."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mk(shape, axes)


def make_tiny_mesh(*, multi_pod: bool = False):
    """(2,2)/(2,2,2) mesh for CI-scale sharding tests (8 forced devices)."""
    shape = (2, 2, 2) if multi_pod else (2, 2)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mk(shape, axes)


def make_serving_mesh(tp: int | None = None) -> Mesh:
    """1-D ``('model',)`` tensor-parallel serving mesh over the first ``tp``
    devices (default: every visible device).

    Device-count-adaptive — unlike the hard-coded 16x16 production shapes,
    the same call works on a laptop CPU (tp=1), a forced-8-device CI host,
    or a real accelerator slice.  Raises a clear ``ValueError`` (never an
    XLA assert) when ``tp`` does not fit the visible devices.
    """
    have = jax.device_count()
    if tp is None:
        tp = have
    if tp < 1:
        raise ValueError(f"serving mesh needs tp >= 1, got tp={tp}")
    if tp > have:
        raise ValueError(
            f"serving mesh tp={tp} exceeds the {have} visible device(s); "
            f"on CPU force more with "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={tp} "
            f"(must be set before jax initializes — see launch/env.py)")
    return Mesh(np.asarray(jax.devices()[:tp]), ("model",))


def make_mesh_by_name(name: str):
    return {
        "prod": lambda: make_production_mesh(multi_pod=False),
        "pod": lambda: make_production_mesh(multi_pod=True),
        "tiny": lambda: make_tiny_mesh(multi_pod=False),
        "tiny_pod": lambda: make_tiny_mesh(multi_pod=True),
        "serving": lambda: make_serving_mesh(),
    }[name]()
