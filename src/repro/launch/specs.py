"""input_specs: ShapeDtypeStruct stand-ins + shardings for every dry-run cell.

Weak-type-correct, shardable, no device allocation.  The train cells feed
``train_step(params, opt_state, batch)``; prefill feeds
``prefill_step(params, batch)``; decode feeds
``decode_step(params, cache, batch, cache_len)``.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.registry import ShapeSpec
from repro.models.config import ModelConfig
from repro.models.transformer import init_params
from repro.serve.engine import cache_shapes
from repro.sharding import rules
from repro.train.optimizer import init_opt_state


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def batch_specs(cfg: ModelConfig, shape: ShapeSpec, mesh: Mesh):
    """(struct_tree, sharding_tree) for the data batch of one cell."""
    b = shape.global_batch
    s = shape.seq_len if shape.kind != "decode" else 1
    bspec = rules.batch_spec(mesh, b, extra_dims=1)

    if cfg.family == "audio":
        toks = _sds((b, cfg.num_codebooks, s), jnp.int32)
        tspec = rules.batch_spec(mesh, b, extra_dims=2)
    else:
        toks = _sds((b, s), jnp.int32)
        tspec = bspec

    structs: dict[str, Any] = {"tokens": toks}
    shardings: dict[str, Any] = {"tokens": NamedSharding(mesh, tspec)}
    if shape.kind == "train":
        structs["labels"] = toks
        shardings["labels"] = NamedSharding(mesh, tspec)
    if cfg.family == "vlm":
        structs["image_embeds"] = _sds((b, cfg.vision_seq, cfg.d_model),
                                       cfg.compute_dtype)
        shardings["image_embeds"] = NamedSharding(
            mesh, rules.batch_spec(mesh, b, extra_dims=2))
    return structs, shardings


def param_structs(cfg: ModelConfig):
    return jax.eval_shape(partial(init_params, cfg), jax.random.PRNGKey(0))


def param_shardings(cfg: ModelConfig, mesh: Mesh):
    structs = param_structs(cfg)
    return rules.with_mesh(rules.param_specs(structs), mesh), structs


def opt_structs_shardings(cfg: ModelConfig, mesh: Mesh, pstructs, pshard,
                          moment_dtype=None):
    ostructs = jax.eval_shape(partial(init_opt_state,
                                      moment_dtype=moment_dtype), pstructs)
    oshard = {"m": pshard, "v": pshard,
              "step": NamedSharding(mesh, P())}
    return ostructs, oshard


def cache_structs_shardings(cfg: ModelConfig, shape: ShapeSpec, mesh: Mesh):
    structs = cache_shapes(cfg, shape.global_batch, shape.seq_len)
    b = shape.global_batch
    shardings: dict[str, Any] = {}
    if cfg.family in ("dense", "moe", "audio", "vlm"):
        kv = NamedSharding(mesh, rules.kv_cache_spec(
            mesh, b, kv_heads=cfg.num_kv_heads))
        shardings["blocks"] = {"k": kv, "v": kv}
    elif cfg.family == "hybrid_mamba":
        sp = rules.ssm_cache_specs(mesh, b)
        shardings["blocks"] = {k: NamedSharding(mesh, v) for k, v in sp.items()}
        if cfg.attn_every:
            akv = NamedSharding(mesh, rules.kv_cache_spec(
                mesh, b, kv_heads=cfg.num_kv_heads))
            shardings["shared_attn"] = {"k": akv, "v": akv}
    elif cfg.family == "rwkv":
        sp = rules.rwkv_cache_specs(mesh, b)
        shardings["blocks"] = {k: NamedSharding(mesh, v) for k, v in sp.items()}
    return structs, shardings


def tune_for_cell(cfg: ModelConfig, shape: ShapeSpec, mesh: Mesh,
                  *, score_budget_bytes: float = 512e6) -> ModelConfig:
    """Per-cell runtime knobs: bf16 compute, remat for train, and an
    attention q-chunk sized so live scores stay under ``score_budget_bytes``
    per device (B_loc * H * chunk * S_kv * 4B <= budget)."""
    b_loc = shape.global_batch // max(
        1, int(jnp.prod(jnp.asarray(
            [mesh.shape[a] for a in rules.batch_axes(mesh, shape.global_batch)]
        )))) if rules.batch_axes(mesh, shape.global_batch) else shape.global_batch
    overrides: dict[str, Any] = {
        "dtype": "bfloat16", "scan_layers": True,
        "act_sp": True,
        "mesh_axes": tuple((a, mesh.shape[a]) for a in mesh.axis_names),
    }
    if shape.kind == "train":
        overrides["remat"] = True
    if shape.kind in ("train", "prefill") and cfg.family not in ("rwkv",):
        skv = shape.seq_len
        denom = max(1, b_loc * cfg.num_heads * skv * 4)
        chunk = int(score_budget_bytes // denom)
        chunk = max(64, min(shape.seq_len, 1 << (chunk.bit_length() - 1))) \
            if chunk >= 1 else 64
        if shape.seq_len % chunk:
            chunk = 64
        overrides["attn_chunk"] = min(chunk, shape.seq_len)
    return dataclasses.replace(cfg, **overrides)
