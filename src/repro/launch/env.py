"""Computation-environment configuration for the serving entry points.

Backend portability knobs that must be applied BEFORE jax initializes its
backend: the platform override and the forced host (CPU) device count the
TP serving mesh shards over.  ``launch/serve.py`` calls :func:`configure`
at the very top of ``main()`` — jax's backend init is lazy, so setting the
environment there (before the first array op) is sufficient; on a real
TPU/GPU host both knobs default to no-ops and the hardware devices are used
unchanged.
"""

from __future__ import annotations

import os


def set_platform(platform: str) -> None:
    """Pin the jax backend ('cpu' | 'gpu' | 'tpu').

    Only takes effect before jax initializes; an already-initialized
    conflicting backend surfaces as a clear RuntimeError from jax itself.
    """
    os.environ["JAX_PLATFORMS"] = platform


def set_host_device_count(n: int) -> None:
    """Force ``n`` virtual host (CPU) devices for mesh/shard_map testing.

    Appends to any existing ``XLA_FLAGS`` (dropping a previous forced count)
    so flags like dump directives survive.  CI uses this to run the tp=2/4
    serving meshes on a single CPU host.
    """
    n = int(n)
    if n < 1:
        raise ValueError(f"host device count must be >= 1, got {n}")
    flags = [f for f in os.environ.get("XLA_FLAGS", "").split()
             if not f.startswith("--xla_force_host_platform_device_count")]
    flags.append(f"--xla_force_host_platform_device_count={n}")
    os.environ["XLA_FLAGS"] = " ".join(flags)


def configure(platform: str | None = None,
              host_devices: int | None = None) -> None:
    """Apply the environment setup the serving CLI exposes as flags."""
    if platform:
        set_platform(platform)
    if host_devices:
        set_host_device_count(host_devices)
