"""End-to-end training driver.

CPU-scale by default (CI/e2e example); the same driver drives the production
mesh when devices are available (the dry-run proves the sharded lowering).

    PYTHONPATH=src python -m repro.launch.train \
        --arch minicpm-2b --reduced --steps 200 --batch 16 --seq 64

Features: synthetic-corpus stream (resumable), AdamW + WSD/cosine schedule,
grad clipping, checkpoint/restart (atomic, keep-k), straggler monitor,
deterministic resume.
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.checkpoint.ckpt import CheckpointManager
from repro.configs.registry import get_arch
from repro.data.tokenstream import DataConfig, TokenStream, make_batch
from repro.models.config import ModelConfig, reduced
from repro.models.transformer import init_params
from repro.runtime.fault_tolerance import StragglerMonitor
from repro.train.optimizer import OptimizerConfig, init_opt_state
from repro.train.train_step import make_train_step


def train(cfg: ModelConfig, opt_cfg: OptimizerConfig, data_cfg: DataConfig,
          steps: int, *, ckpt_dir: str | None = None, ckpt_every: int = 50,
          resume: bool = False, log_every: int = 10,
          fail_at_step: int | None = None, seed: int = 0,
          verbose: bool = True) -> dict:
    """Returns {"final_step", "losses": [...], "resumed_from"}."""
    mgr = CheckpointManager(ckpt_dir, keep=3) if ckpt_dir else None
    start_step, resumed_from = 0, None

    params = init_params(cfg, jax.random.PRNGKey(seed))
    opt_state = init_opt_state(params)
    if resume and mgr is not None and mgr.latest_step() is not None:
        start_step, tree, extra = mgr.restore()
        params, opt_state = tree["params"], tree["opt_state"]
        resumed_from = start_step

    step_fn = jax.jit(make_train_step(cfg, opt_cfg), donate_argnums=(0, 1))
    stream = TokenStream(data_cfg, start_step=start_step)
    monitor = StragglerMonitor()
    losses = []
    try:
        for step in range(start_step, steps):
            t0 = time.time()
            batch = next(stream)
            batch = {k: jax.numpy.asarray(v) for k, v in batch.items()}
            params, opt_state, metrics = step_fn(params, opt_state, batch)
            dt = time.time() - t0
            monitor.record("host0", dt)
            loss = float(metrics["ce"])
            losses.append(loss)
            if verbose and (step % log_every == 0 or step == steps - 1):
                print(f"step {step:5d}  ce {loss:.4f}  "
                      f"lr {float(metrics['lr']):.2e}  "
                      f"gnorm {float(metrics['grad_norm']):.3f}  {dt:.2f}s")
            if mgr is not None and (step + 1) % ckpt_every == 0:
                mgr.save(step + 1, {"params": params, "opt_state": opt_state},
                         extra={"data_step": stream.step})
            if fail_at_step is not None and step + 1 == fail_at_step:
                from repro.runtime.fault_tolerance import SimulatedFailure
                raise SimulatedFailure(f"injected failure at {step + 1}")
    finally:
        stream.close()
    if mgr is not None:
        mgr.save(steps, {"params": params, "opt_state": opt_state},
                 extra={"data_step": stream.step})
        mgr.wait()
    return {"final_step": steps, "losses": losses,
            "resumed_from": resumed_from, "params": params,
            "stragglers": monitor.stragglers()}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="minicpm-2b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--preset", choices=["tiny", "100m"], default="tiny")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--schedule", default="wsd")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.reduced or args.preset == "tiny":
        cfg = reduced(cfg, vocab_size=256, max_seq_len=max(256, args.seq))
    elif args.preset == "100m":
        cfg = dataclasses.replace(
            reduced(cfg), d_model=768, num_layers=12, num_heads=12,
            num_kv_heads=min(cfg.num_kv_heads, 12) or 12, head_dim=64,
            d_ff=2048, vocab_size=8192, max_seq_len=max(1024, args.seq))

    opt_cfg = OptimizerConfig(peak_lr=args.lr, schedule=args.schedule,
                              warmup_steps=max(10, args.steps // 20),
                              total_steps=args.steps)
    data_cfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                          global_batch=args.batch,
                          num_codebooks=cfg.num_codebooks)
    out = train(cfg, opt_cfg, data_cfg, args.steps, ckpt_dir=args.ckpt_dir,
                resume=args.resume)
    first, last = np.mean(out["losses"][:10]), np.mean(out["losses"][-10:])
    print(f"done: ce {first:.3f} -> {last:.3f} "
          f"({100 * (first - last) / first:.1f}% drop)")


if __name__ == "__main__":
    main()
