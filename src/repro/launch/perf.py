import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""§Perf hillclimbing harness — named experiment variants over dry-run cells.

Each variant = (cell, hypothesis, set of changes); results land in
experiments/perf/<variant>.json and feed EXPERIMENTS.md §Perf.

    PYTHONPATH=src python -m repro.launch.perf --variant yi_train_bf16_params
    PYTHONPATH=src python -m repro.launch.perf --all
"""

import argparse
import dataclasses
import json
import re
from functools import partial
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.configs.registry import SHAPES, get_arch
from repro.core.api import DEFAULT_SKIP
from repro.launch import specs as S
from repro.launch.dryrun import run_cell
from repro.sharding import rules


def _cast_params_bf16(structs):
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, jnp.bfloat16)
        if s.dtype == jnp.float32 else s, structs)


def _packed_struct_tree(structs, *, rank: int = 32, block_size: int = 32):
    """Transform param structs into the packed-quantized serving layout
    (int8 mantissa + int8 exponents + bf16 low-rank terms)."""
    from repro.utils.trees import flatten_dict, unflatten_dict

    def skips(path):
        return any(re.search(p, path) for p in DEFAULT_SKIP)

    flat = flatten_dict(dict(structs))
    out = {}
    for path, leaf in flat.items():
        if (hasattr(leaf, "ndim") and leaf.ndim in (2, 3) and not skips(path)
                and leaf.shape[-2] % block_size == 0):
            lead = leaf.shape[:-2]
            m, n = leaf.shape[-2:]
            out[f"{path}/mant"] = jax.ShapeDtypeStruct(leaf.shape, jnp.int8)
            out[f"{path}/exp"] = jax.ShapeDtypeStruct(
                (*lead, m // block_size, n), jnp.int8)
            out[f"{path}/bits"] = jax.ShapeDtypeStruct((), jnp.int32)
            out[f"{path}/block_size"] = jax.ShapeDtypeStruct((), jnp.int32)
            out[f"{path}/lora_a"] = jax.ShapeDtypeStruct(
                (*lead, m, rank), jnp.bfloat16)
            out[f"{path}/lora_b"] = jax.ShapeDtypeStruct(
                (*lead, rank, n), jnp.bfloat16)
        else:
            out[path] = leaf
    return unflatten_dict(out)


def _patched_param_structs(transform):
    """Context-free monkeypatch of specs.param_structs for one variant."""
    orig = S.param_structs

    def patched(cfg):
        return transform(orig(cfg))

    return orig, patched


from repro.configs.registry import ShapeSpec

# short-context, small-batch decode: the weight-bound serving regime where
# the paper's deployment claim lives (B=16 so batch shards once over 'data')
DECODE_B16 = ShapeSpec("decode_4k_b16", 4096, 16, "decode")

VARIANTS = {
    # ---- cell 1: yi-34b train_4k (most collective-bound) -------------------
    "yi_train_baseline": dict(cell=("yi-34b", "train_4k"), hypo="baseline"),
    "yi_train_bf16_params": dict(
        cell=("yi-34b", "train_4k"), params="bf16",
        hypo="FSDP weight all-gathers move f32 bytes; bf16 params (f32 "
             "moments) halve the dominant constant collective term"),
    "yi_train_bf16_mb4": dict(
        cell=("yi-34b", "train_4k"), params="bf16", tokens_budget=16384,
        hypo="on top of bf16 params, 4 microbatches halve live activations "
             "(memory-fit headroom) without changing collective bytes"),
    # ---- cell 2: llama4-maverick train_4k (EP; does not fit) ---------------
    "llama4_train_baseline": dict(cell=("llama4-maverick-400b-a17b",
                                        "train_4k"), hypo="baseline"),
    "llama4_train_bf16_all": dict(
        cell=("llama4-maverick-400b-a17b", "train_4k"), params="bf16",
        moments="bfloat16",
        hypo="36.9GB args = f32 params+moments; bf16 everything (the "
             "production 8-bit-optimizer stand-in) brings args under HBM"),
    "llama4_train_ep_data": dict(
        cell=("llama4-maverick-400b-a17b", "train_4k"), params="bf16",
        moments="bfloat16", expert_axis="data",
        hypo="EP over 'model' makes MoE dispatch cross the TP axis; "
             "aligning experts with the batch shards (EP=DP, TP inside "
             "the expert FFN) cuts dispatch collective bytes"),
    # ---- cell 3: yi-34b decode_32k (the paper's serving case) --------------
    "yi_decode_baseline": dict(cell=("yi-34b", "decode_32k"), hypo="baseline"),
    "yi_decode_b16_baseline": dict(
        cell=("yi-34b", None), shape_spec=DECODE_B16,
        hypo="baseline for the weight-bound regime: B=16, 4k ctx -> weights "
             "(0.53GB/dev) >= cache (0.5GB/dev), so weight streaming is the "
             "roofline term the paper's method attacks"),
    "yi_decode_b16_quantized": dict(
        cell=("yi-34b", None), shape_spec=DECODE_B16, packed=True,
        hypo="same cell with QERA-packed int4 weights: weight bytes/device "
             "0.53GB -> ~0.15GB; memory term should drop ~2x where weights "
             "dominate"),
    "yi_decode_quantized": dict(
        cell=("yi-34b", "decode_32k"), packed=True,
        hypo="decode streams every weight once per token: QERA-packed "
             "int4-mantissa weights (+rank-32 bf16 low-rank) cut weight "
             "bytes ~3.6x -> memory-roofline win (the paper's deployment "
             "claim, measured from the compiled artifact)"),
    "yi_train_noattnchunk": dict(
        cell=("yi-34b", "train_4k"), cfg_overrides={"attn_chunk": 0},
        hypo="SPMD warns 'involuntary full rematerialization' at the q-chunk "
             "dynamic-slice over the SP-sharded seq axis -> batch-replicated "
             "f32 reshards; at 4k seq chunking is unnecessary (scores "
             "B*H*S/16*S*4B ~ 2GB) so attn_chunk=0 removes the pathology"),
    # ---- memory-fit fixes for the over-16GB train cells ---------------------
    "cmdr_train_bf16_mb8": dict(
        cell=("command-r-plus-104b", "train_4k"), params="bf16",
        tokens_budget=8192,
        hypo="51.7GB cmd-r train: bf16 params + 8 microbatches divide live "
             "activations; target < 16GB"),
    "zamba_train_bf16_mb4": dict(
        cell=("zamba2-7b", "train_4k"), params="bf16", tokens_budget=16384,
        hypo="40.9GB zamba2 train: f32 ssm-chunk intermediates scale with "
             "microbatch tokens; bf16 params + mb4 should fit"),
}


def run_variant(name: str, out_dir: Path) -> dict:
    v = VARIANTS[name]
    arch, shape_name = v["cell"]
    shape = v.get("shape_spec") or SHAPES[shape_name]

    import repro.launch.dryrun as DR

    orig_structs = S.param_structs
    orig_axis = rules.EXPERT_AXIS
    orig_opt = S.opt_structs_shardings
    orig_mb = DR._microbatches
    try:
        if v.get("params") == "bf16":
            S.param_structs = _patched_param_structs(_cast_params_bf16)[1]
        if v.get("packed"):
            S.param_structs = _patched_param_structs(
                partial(_packed_struct_tree, rank=32))[1]
        if v.get("expert_axis"):
            rules.set_expert_axis(v["expert_axis"])
        if v.get("moments"):
            S.opt_structs_shardings = partial(orig_opt,
                                              moment_dtype=jnp.bfloat16)
        if v.get("tokens_budget"):
            DR._microbatches = (lambda cfg, shape_, mesh_:
                                orig_mb(cfg, shape_, mesh_,
                                        tokens_budget=v["tokens_budget"]))
        res = run_cell(arch, shape, "prod", out_dir=None,
                       cfg_overrides=v.get("cfg_overrides"))
    finally:
        S.param_structs = orig_structs
        rules.set_expert_axis(orig_axis)
        S.opt_structs_shardings = orig_opt
        DR._microbatches = orig_mb

    res["variant"] = name
    res["hypothesis"] = v["hypo"]
    out_dir.mkdir(parents=True, exist_ok=True)
    (out_dir / f"{name}.json").write_text(json.dumps(res, indent=2))
    mem = res["full"]["memory"]
    hbm = (mem["argument_bytes"] + mem["temp_bytes"] + mem["output_bytes"]
           - mem["alias_bytes"]) / 1e9
    print(f"{name}: hbm={hbm:.2f}GB roofline={res.get('roofline')}")
    return res


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--variant", choices=list(VARIANTS))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="experiments/perf")
    args = ap.parse_args()
    out = Path(args.out)
    todo = list(VARIANTS) if args.all else [args.variant]
    for name in todo:
        try:
            run_variant(name, out)
        except Exception as e:  # noqa: BLE001
            print(f"VARIANT {name} FAILED: {type(e).__name__}: {e}")


if __name__ == "__main__":
    main()
