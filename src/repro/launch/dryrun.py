import os

from repro.launch.env import set_host_device_count

# Multi-pod dry-run default: 512 forced host devices.  A caller-forced
# count wins (the CI sharded-serving smoke sets 8 in XLA_FLAGS before this
# module is imported for its cost model) — only fill the default in when no
# forced count is present, and preserve unrelated XLA flags either way.
if ("--xla_force_host_platform_device_count"
        not in os.environ.get("XLA_FLAGS", "")):
    set_host_device_count(512)
# Test hook only — must also run before any jax import; overrides both.
if os.environ.get("REPRO_DRYRUN_DEVICES"):
    set_host_device_count(int(os.environ["REPRO_DRYRUN_DEVICES"]))

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell and
extract the roofline terms from the compiled artifact.

Because per-layer params are scanned, XLA's cost model counts the loop body
ONCE regardless of trip count.  We therefore compile each cell three times:

  * full-L         -> memory_analysis (buffer sizes are trip-count-exact)
  * L = p, L = 2p  -> cost deltas: per-layer-group flops/bytes/collectives
                      (p = the layer period: 1, attn_every, or
                      cross_attn_every), extrapolated to the real depth.

Roofline terms (TPU v5e targets): compute = FLOPs/(197 TF/s); memory =
bytes/(819 GB/s); collective = ICI bytes/(50 GB/s per link), all per device.

Usage:
  python -m repro.launch.dryrun --arch yi-34b --shape train_4k --mesh prod
  python -m repro.launch.dryrun --all --mesh prod --out experiments/dryrun
"""

import argparse
import dataclasses
import json
import re
import time
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.configs.registry import SHAPES, ShapeSpec, dryrun_cells, get_arch
from repro.launch import specs as S
from repro.launch.mesh import make_mesh_by_name
from repro.models.config import ModelConfig, reduced
from repro.serve.engine import make_decode_step, make_prefill_step
from repro.sharding import rules
from repro.train.optimizer import OptimizerConfig
from repro.train.train_step import make_microbatched_train_step, make_train_step

HW = {"peak_flops": 197e12, "hbm_bw": 819e9, "ici_bw": 50e9}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}
_COLL_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
             "collective-permute")


def collective_bytes(hlo_text: str) -> dict:
    """Per-device ICI bytes by collective opcode, from post-SPMD HLO text.

    Per instruction we take the LARGEST shape on the line (gathered size for
    all-gather, full size for all-reduce / all-to-all, input for
    reduce-scatter) and double all-reduce (ring: reduce-scatter+all-gather).
    """
    out = {op: 0.0 for op in _COLL_OPS}
    counts = {op: 0 for op in _COLL_OPS}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        if "fusion" in stripped.split("=")[0]:
            continue
        op = next((o for o in _COLL_OPS
                   if f" {o}(" in stripped or f"{o}-start(" in stripped), None)
        if op is None:
            continue
        best = 0
        for dt, dims in _SHAPE_RE.findall(stripped):
            if dt not in _DTYPE_BYTES:
                continue
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            best = max(best, n * _DTYPE_BYTES[dt])
        mult = 2.0 if op == "all-reduce" else 1.0
        out[op] += best * mult
        counts[op] += 1
    out["total"] = sum(out[o] for o in _COLL_OPS)
    out["counts"] = counts
    return out


def tp_allreduce_model(cfg: ModelConfig, *, batch: int, seq: int, tp: int,
                       dtype_bytes: int = 4, ici_bw: float | None = None
                       ) -> dict:
    """Analytic per-layer all-reduce cost of tensor-parallel serving.

    The shard_map serving path (sharding/serving.py) psums exactly TWO
    (batch, seq, d_model) partial outputs per dense layer — one after the
    row-parallel attention out-projection, one after the row-parallel MLP
    down-projection — and nothing else crosses devices.  Each psum operates
    on the FULL (batch, seq, d_model) partial in the layer's compute dtype.

    Two byte counts come out of that, and they are NOT the same number:

    * ``per_device_bytes`` — the :func:`collective_bytes` accounting
      convention (full payload, doubled for the ring reduce-scatter +
      all-gather phases; tp-independent because the HLO text never
      reveals tp).  Compare THIS against the measured HLO bytes; the
      ratio must be ~1.0.  An earlier revision applied the ring fraction
      here too, predicting half the measured bytes at tp=2 (ratio 0.5).
    * ``ring_bytes`` — the physical per-device wire traffic of a ring
      all-reduce, ``2*(tp-1)/tp`` of each payload.  This is what actually
      crosses ICI links, so ``predicted_s`` is built from it.
    """
    payload = batch * seq * cfg.d_model * dtype_bytes
    n_ar = 2 * cfg.num_layers
    hlo = n_ar * 2.0 * payload if tp > 1 else 0.0
    ring = n_ar * 2.0 * (tp - 1) / tp * payload if tp > 1 else 0.0
    return {
        "tp": tp, "allreduces_per_layer": 2, "layers": cfg.num_layers,
        "allreduce_count": n_ar if tp > 1 else 0,
        "payload_bytes": payload,
        "per_device_bytes": hlo,
        "ring_bytes": ring,
        "predicted_s": ring / (ici_bw or HW["ici_bw"]),
    }


def analyze(compiled) -> dict:
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):       # older jax wraps the dict in a list
        ca = ca[0] if ca else {}
    ma = compiled.memory_analysis()
    coll = collective_bytes(compiled.as_text())
    return {
        "flops": float(ca.get("flops", 0.0)),
        "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
        "collectives": coll,
        "memory": None if ma is None else {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
        },
    }


# ---------------------------------------------------------------------------
# cell construction
# ---------------------------------------------------------------------------

def _microbatches(cfg: ModelConfig, shape: ShapeSpec, mesh,
                  tokens_budget: int = 32_768) -> int:
    if shape.kind != "train":
        return 1
    ax = rules.batch_axes(mesh, shape.global_batch)
    dp = 1
    for a in ax:
        dp *= mesh.shape[a]
    b_loc = shape.global_batch // dp
    mb = 1
    while (b_loc % (mb * 2) == 0
           and (b_loc // mb) * shape.seq_len > tokens_budget):
        mb *= 2
    return mb


def build_cell(cfg: ModelConfig, shape: ShapeSpec, mesh, *,
               microbatches: int = 1):
    """Returns (jitted_fn, arg_structs) ready to .lower(*args)."""
    pshard, pstructs = S.param_shardings(cfg, mesh)
    bstructs, bshard = S.batch_specs(cfg, shape, mesh)

    if shape.kind == "train":
        opt_cfg = OptimizerConfig()
        ostructs, oshard = S.opt_structs_shardings(cfg, mesh, pstructs, pshard)
        if microbatches > 1:
            fn = make_microbatched_train_step(cfg, opt_cfg, microbatches)
        else:
            fn = make_train_step(cfg, opt_cfg)
        jitted = jax.jit(fn, in_shardings=(pshard, oshard, bshard),
                         out_shardings=(pshard, oshard, None),
                         donate_argnums=(0, 1))
        return jitted, (pstructs, ostructs, bstructs)

    if shape.kind == "prefill":
        cstructs, cshard = S.cache_structs_shardings(cfg, shape, mesh)
        fn = make_prefill_step(cfg)
        jitted = jax.jit(fn, in_shardings=(pshard, bshard),
                         out_shardings=(None, cshard))
        return jitted, (pstructs, bstructs)

    if shape.kind == "decode":
        cstructs, cshard = S.cache_structs_shardings(cfg, shape, mesh)
        fn = make_decode_step(cfg)
        jitted = jax.jit(
            fn,
            in_shardings=(pshard, cshard, bshard, None),
            out_shardings=(None, cshard),
            donate_argnums=(1,))
        clen = jax.ShapeDtypeStruct((), jnp.int32)
        return jitted, (pstructs, cstructs, bstructs, clen)

    raise ValueError(shape.kind)


def _layer_period(cfg: ModelConfig) -> int:
    if cfg.family == "hybrid_mamba" and cfg.attn_every:
        return cfg.attn_every
    if cfg.family == "vlm" and cfg.cross_attn_every:
        return cfg.cross_attn_every
    return 1


def model_flops(cfg: ModelConfig, shape: ShapeSpec) -> float:
    n = cfg.active_param_count()
    if shape.kind == "train":
        return 6.0 * n * shape.tokens
    if shape.kind == "prefill":
        return 2.0 * n * shape.tokens
    return 2.0 * n * shape.global_batch      # decode: 1 token/seq


def run_cell(arch: str, shape: ShapeSpec, mesh_name: str, *,
             use_reduced: bool = False, out_dir: Path | None = None,
             skip_costs: bool = False,
             cfg_overrides: dict | None = None) -> dict:
    mesh = make_mesh_by_name(mesh_name)
    n_dev = 1
    for a in mesh.axis_names:
        n_dev *= mesh.shape[a]

    cfg = get_arch(arch)
    if use_reduced:
        cfg = reduced(cfg)
        shape = ShapeSpec(shape.name, seq_len=min(shape.seq_len, 64),
                          global_batch=min(shape.global_batch, 8),
                          kind=shape.kind)
    cfg = S.tune_for_cell(cfg, shape, mesh)
    if cfg_overrides:
        cfg = dataclasses.replace(cfg, **cfg_overrides)
    mb = _microbatches(cfg, shape, mesh)

    result = {"arch": arch, "shape": dataclasses.asdict(shape),
              "mesh": mesh_name, "devices": n_dev, "microbatches": mb,
              "reduced": use_reduced,
              "attn_chunk": cfg.attn_chunk, "remat": cfg.remat}

    # ---- full-depth compile: memory analysis --------------------------------
    t0 = time.time()
    jitted, args = build_cell(cfg, shape, mesh, microbatches=mb)
    with mesh:
        lowered = jitted.lower(*args)
        compiled = lowered.compile()
    full = analyze(compiled)
    result["compile_seconds_full"] = round(time.time() - t0, 2)
    result["full"] = full
    print(compiled.memory_analysis())

    # ---- cost extrapolation: (L = p vs 2p) x (S1 vs S2) fit ----------------
    # Cost compiles run fully UNROLLED (scan_layers=False + python chunk
    # loops) at two small sequence/cache lengths so every FLOP is visible to
    # the cost model, then each component is fit as a*S + b*S^2 (train /
    # prefill; attention is the quadratic part) or a + b*C (decode, linear in
    # cache length) and evaluated at the real shape.
    if not skip_costs:
        import numpy as np

        p = _layer_period(cfg)
        is_decode = shape.kind == "decode"
        # decode costs are affine in cache length (2 points); train/prefill
        # need constant + linear + quadratic terms (weight all-gathers are
        # constant in S, matmuls linear, attention quadratic) -> 3 points.
        s_points = (2048, 4096) if is_decode else (512, 1024, 2048)
        costs: dict = {}
        for mult in (1, 2):
            for s_small in s_points:
                # cost-mode chunk policy:
                # * inner chunk count capped at 8 (XLA fusion params charge
                #   the FULL projection arrays once per unrolled chunk — an
                #   O(nc*S) accounting artifact; capping nc makes it linear
                #   and inflates only the negligible intra-chunk term);
                # * attention q-chunk FIXED across S points so the measured
                #   bytes match the real chunked (flash) K/V re-read traffic.
                ccfg = dataclasses.replace(
                    cfg, num_layers=p * mult, scan_layers=False,
                    chunk_python_loop=True,
                    attn_chunk=0 if is_decode else 256,
                    rwkv_chunk=max(cfg.rwkv_chunk, s_small // 8),
                    ssm_chunk=max(cfg.ssm_chunk, s_small // 8))
                cshape = ShapeSpec(shape.name, seq_len=s_small,
                                   global_batch=shape.global_batch,
                                   kind=shape.kind)
                jit_l, args_l = build_cell(ccfg, cshape, mesh, microbatches=1)
                with mesh:
                    comp = jit_l.lower(*args_l).compile()
                costs[(mult, s_small)] = analyze(comp)
        groups = cfg.num_layers / p
        s_real = shape.seq_len

        def fit_eval(vals: list[float]) -> float:
            """Fit polynomial basis through (s_points, vals), eval at s_real."""
            if is_decode:                       # v = a + b*C
                s1, s2 = s_points
                b_ = (vals[1] - vals[0]) / (s2 - s1)
                a_ = vals[0] - b_ * s1
                return max(a_ + b_ * s_real, 0.0)
            vand = np.array([[1.0, s_, s_ * s_] for s_ in s_points])
            coef = np.linalg.solve(vand, np.array(vals, np.float64))
            if coef[2] < 0:
                # sub-quadratic component + accounting noise: refit affine
                # through the two largest points (never extrapolate negative
                # curvature to 16-64x the fit range)
                s2, s3 = s_points[1], s_points[2]
                b_ = (vals[2] - vals[1]) / (s3 - s2)
                a_ = vals[2] - b_ * s3
                return max(a_ + b_ * s_real, 0.0)
            return float(max(coef[0] + coef[1] * s_real
                             + coef[2] * s_real * s_real, 0.0))

        def extrap(key, sub=None) -> float:
            def get(mult, s_):
                v = costs[(mult, s_)][key]
                return v if sub is None else v[sub]
            # layer-group delta and base, each fit over S then combined
            layer = fit_eval([max(get(2, s_) - get(1, s_), 0.0)
                              for s_ in s_points])
            base = fit_eval([max(get(1, s_) - (get(2, s_) - get(1, s_)), 0.0)
                             for s_ in s_points])
            return base + groups * layer

        flops_dev = extrap("flops")
        bytes_dev = extrap("bytes_accessed")
        coll_dev = extrap("collectives", "total")
        result["per_device"] = {
            "flops": flops_dev, "bytes_accessed": bytes_dev,
            "collective_bytes": coll_dev,
            "collective_detail": {
                op: extrap("collectives", op) for op in _COLL_OPS},
        }
        terms = {
            "compute_s": flops_dev / HW["peak_flops"],
            "memory_s": bytes_dev / HW["hbm_bw"],
            "collective_s": coll_dev / HW["ici_bw"],
        }
        terms["bottleneck"] = max(
            ("compute_s", "memory_s", "collective_s"), key=lambda k: terms[k])
        result["roofline"] = terms
        mf = model_flops(cfg, shape)
        result["model_flops"] = mf
        hlo_global = flops_dev * n_dev
        result["hlo_flops_global"] = hlo_global
        result["model_flops_ratio"] = mf / hlo_global if hlo_global else None

    if out_dir is not None:
        out_dir.mkdir(parents=True, exist_ok=True)
        fn = out_dir / f"{arch}__{shape.name}__{mesh_name}.json"
        fn.write_text(json.dumps(result, indent=2))
        print("wrote", fn)
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--mesh", default="prod",
                    choices=["prod", "pod", "tiny", "tiny_pod"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--skip-costs", action="store_true",
                    help="memory-analysis compile only (multi-pod pass)")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()
    out = Path(args.out)

    if args.all:
        ok, failed = 0, []
        for arch, spec, run in dryrun_cells(include_skipped=True):
            if not run:
                print(f"SKIP {arch} x {spec.name} (sub-quadratic rule)")
                continue
            try:
                t0 = time.time()
                run_cell(arch, spec, args.mesh, use_reduced=args.reduced,
                         out_dir=out, skip_costs=args.skip_costs)
                print(f"OK {arch} x {spec.name} x {args.mesh} "
                      f"({time.time()-t0:.1f}s)")
                ok += 1
            except Exception as e:  # noqa: BLE001 — report and continue
                print(f"FAIL {arch} x {spec.name}: {type(e).__name__}: {e}")
                failed.append((arch, spec.name, str(e)[:200]))
        print(f"\n{ok} cells OK, {len(failed)} failed")
        for f in failed:
            print("  FAILED:", f)
        raise SystemExit(1 if failed else 0)

    spec = SHAPES[args.shape or "train_4k"]
    res = run_cell(args.arch or "yi-34b", spec, args.mesh,
                   use_reduced=args.reduced, out_dir=out,
                   skip_costs=args.skip_costs)
    print(json.dumps(res, indent=2))


if __name__ == "__main__":
    main()
