"""Serving driver: load (or init) a model, optionally QERA-quantize it, and
run a continuous-batching session over synthetic requests.

    PYTHONPATH=src python -m repro.launch.serve --arch minicpm-2b --reduced \
        --quantize qera_exact --bits mxint4 --rank 16 --requests 8
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.configs.registry import get_arch
from repro.models import Taps, forward, init_params
from repro.models.config import reduced
from repro.serve.batching import ContinuousBatcher, Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="minicpm-2b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--quantize", default=None,
                    help="qera_exact|qera_approx|lqer|zeroquant_v2|loftq")
    ap.add_argument("--bits", default="mxint4")
    ap.add_argument("--rank", type=int, default=16)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--chunk-tokens", type=int, default=64,
                    help="prefill token budget per tick (bounds per-tick "
                         "latency during admissions)")
    ap.add_argument("--paged", action="store_true",
                    help="paged KV cache: slots share a fixed page pool "
                         "(capacity = pool pages, not slots x max_len)")
    ap.add_argument("--page-size", type=int, default=32,
                    help="tokens per KV page (paged mode)")
    ap.add_argument("--num-pages", type=int, default=None,
                    help="pool pages incl. the garbage page (default: "
                         "lossless, every slot can reach max_len)")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="copy-on-write prefix caching (implies --paged): "
                         "prompts sharing full token pages with cached "
                         "sequences reuse them via refcounted page-table "
                         "indirection and prefill only the uncached suffix; "
                         "a shared page is forked before any write")
    args = ap.parse_args()
    if args.prefix_cache:
        args.paged = True

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = reduced(cfg, scan_layers=False)
    params = init_params(cfg, jax.random.PRNGKey(0))

    if args.quantize:
        from repro.core import PTQConfig, quantize_params
        taps = Taps(with_outer=args.quantize == "qera_exact")
        calib = jax.numpy.asarray(
            np.random.default_rng(0).integers(
                0, cfg.vocab_size, size=(8, 64), dtype=np.int32))
        forward(params, {"tokens": calib}, dataclasses.replace(
            cfg, scan_layers=False), taps=taps)
        from benchmarks.common import remap_stats
        stats = remap_stats(taps.layer_stats())
        qcfg = PTQConfig(method=args.quantize, rank=args.rank,
                         quantizer=args.bits)
        params = quantize_params(params, qcfg, stats_by_path=stats)
        print(f"quantized with {args.quantize}/{args.bits} rank {args.rank}")

    batcher = ContinuousBatcher(params, cfg, num_slots=args.slots,
                                max_len=args.max_len,
                                chunk_tokens=args.chunk_tokens,
                                paged=args.paged, page_size=args.page_size,
                                num_pages=args.num_pages,
                                prefix_cache=args.prefix_cache)
    rng = np.random.default_rng(7)
    # shared few-shot preamble on half the requests so --prefix-cache has
    # real hits to report (production traffic is dominated by shared
    # system prompts)
    preamble = rng.integers(0, cfg.vocab_size,
                            size=min(2 * args.page_size, args.max_len // 2)
                            ).astype(np.int32)
    prompts = []
    for i in range(args.requests):
        tail = rng.integers(0, cfg.vocab_size,
                            size=int(rng.integers(3, 12))).astype(np.int32)
        prompts.append(np.concatenate([preamble, tail]) if i % 2 else tail)
    reqs = [Request(rid=i, prompt=p, max_new_tokens=args.max_new)
            for i, p in enumerate(prompts)]
    t0 = time.time()
    for r in reqs:
        batcher.submit(r)
    batcher.run()
    dt = time.time() - t0
    toks = sum(len(r.output) for r in reqs)
    print(f"served {len(reqs)} requests / {toks} tokens in {dt:.2f}s "
          f"({toks / dt:.1f} tok/s)")
    if batcher.prefix is not None:
        pfx = batcher.prefix
        print(f"prefix cache: {pfx.hits} hits / {pfx.misses} misses, "
              f"{pfx.hit_tokens} prompt tokens served from cache, "
              f"{batcher.cow_forks} CoW forks, "
              f"{len(pfx)} pages registered")
    for r in reqs[:4]:
        print(f"  req {r.rid}: {list(r.prompt)} -> {r.output}")


if __name__ == "__main__":
    main()
