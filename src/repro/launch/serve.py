"""Serving driver: load (or init) a model, optionally QERA-quantize it, and
run a continuous-batching session over synthetic requests.

    PYTHONPATH=src python -m repro.launch.serve --arch minicpm-2b --reduced \
        --quantize qera_exact --bits mxint4 --rank 16 --requests 8

With any fault-tolerance flag (--inject-faults, --ttl-ticks, --max-queue,
--snapshot-dir, --snapshot-every) the batcher runs under the
``ServingSupervisor`` and prints a :class:`ServeReport`.
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.configs.registry import get_arch
from repro.models import Taps, forward, init_params
from repro.models.config import reduced
from repro.serve.batching import ContinuousBatcher, Request

FAILURE_SEMANTICS = """\
failure semantics (supervised mode):
  admission   submit() returns a TYPED verdict, never queues unboundedly:
              Accepted, or Rejected(reason=queue_full|overloaded|unservable).
              Shed requests are counted in the report, never raised
              mid-traffic.
  deadlines   --ttl-ticks attaches a deadline to every request; an expired
              request is aborted wherever it lives (queued, mid-admission,
              decoding) with failed="deadline" and listed in the report —
              expiry is reported, never silent.
  NaN/Inf     non-finite decode logits quarantine ONLY the affected slot:
              the token is discarded, recurrent rows roll back one token and
              the slot re-decodes next tick; after nan-retry-limit
              consecutive strikes the request fails ("nan") and its pages
              are released WITHOUT entering the prefix index.  Co-batched
              slots are unaffected.
  crashes     a tick that raises a device failure is recovered from the
              newest snapshot (--snapshot-dir for crash-safe disk snapshots
              via the checkpoint manager, else in-memory) under a bounded
              exponential-backoff restart policy.  Greedy decode is
              deterministic, so replayed streams re-emit bit-identical
              tokens; injected one-shot faults never re-fire during replay.
  --inject-faults runs a seeded storm (pool-exhaustion spikes + NaN ticks +
              one mid-tick crash) to demonstrate the above; outputs must be
              token-identical to the fault-free run.

static preflight:
  --strict    runs the repro.analysis contract checker on THIS config at
              its MXINT format and tp degree before any device, mesh, or
              weight is touched, and refuses to serve on any error-severity
              violation.  QERA0xx codes are documented in docs/analysis.md.
"""


def main():
    ap = argparse.ArgumentParser(
        epilog=FAILURE_SEMANTICS,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--arch", default="minicpm-2b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--quantize", default=None,
                    help="qera_exact|qera_approx|lqer|zeroquant_v2|loftq")
    ap.add_argument("--bits", default="mxint4")
    ap.add_argument("--rank", type=int, default=16)
    ap.add_argument("--plan", default=None,
                    help="path to a QuantPlan JSON (core/allocate.py): "
                         "per-layer (format, rank) overrides for --quantize "
                         "instead of the uniform --bits/--rank point")
    ap.add_argument("--spec-k", type=int, default=0,
                    help="self-speculative decoding: draft k tokens per tick "
                         "with the reduced-precision weight view, verify in "
                         "one full-precision launch (serve/speculative.py)")
    ap.add_argument("--draft-bits", type=int, default=4,
                    help="mantissa bits of the speculative draft plane; "
                         "draft_bits=2 accepts ~0% (docs/speculative.md) — "
                         "warned here, refused under --strict")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--chunk-tokens", type=int, default=64,
                    help="prefill token budget per tick (bounds per-tick "
                         "latency during admissions)")
    ap.add_argument("--paged", action="store_true",
                    help="paged KV cache: slots share a fixed page pool "
                         "(capacity = pool pages, not slots x max_len)")
    ap.add_argument("--page-size", type=int, default=32,
                    help="tokens per KV page (paged mode)")
    ap.add_argument("--num-pages", type=int, default=None,
                    help="pool pages incl. the garbage page (default: "
                         "lossless, every slot can reach max_len)")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="copy-on-write prefix caching (implies --paged): "
                         "prompts sharing full token pages with cached "
                         "sequences reuse them via refcounted page-table "
                         "indirection and prefill only the uncached suffix; "
                         "a shared page is forked before any write")
    ft = ap.add_argument_group("fault tolerance (any flag enables the "
                               "supervisor; see failure semantics below)")
    ft.add_argument("--inject-faults", action="store_true",
                    help="seeded deterministic fault storm: pool-exhaustion "
                         "spikes, NaN decode ticks, one mid-tick crash")
    ft.add_argument("--fault-seed", type=int, default=11,
                    help="storm seed (same seed => identical fault schedule)")
    ft.add_argument("--ttl-ticks", type=int, default=None,
                    help="per-request deadline in supervisor ticks; expired "
                         "requests abort with failed='deadline'")
    ft.add_argument("--max-queue", type=int, default=None,
                    help="waiting-queue depth above which submit() sheds "
                         "with Rejected(queue_full)")
    ft.add_argument("--snapshot-dir", default=None,
                    help="directory for crash-safe disk snapshots (atomic "
                         "rename, keep-k GC); default: in-memory snapshots")
    ft.add_argument("--snapshot-every", type=int, default=None,
                    help="ticks between batcher snapshots (default 4 in "
                         "supervised mode)")
    ft.add_argument("--nan-retry-limit", type=int, default=3,
                    help="consecutive non-finite decode ticks before a slot "
                         "is quarantined (request fails with 'nan')")
    tp = ap.add_argument_group("tensor parallelism")
    tp.add_argument("--tp", type=int, default=None,
                    help="shard the model over a 1-D ('model',) serving "
                         "mesh of this many devices: column-parallel "
                         "in-projections, row-parallel out-projections, "
                         "KV heads partitioned per device, one all-reduce "
                         "per projection pair (sharding/serving.py)")
    tp.add_argument("--mesh", action="store_true",
                    help="shorthand for --tp <all visible devices>")
    tp.add_argument("--platform", default=None,
                    help="pin the jax backend (cpu|gpu|tpu); applied before "
                         "jax initializes")
    ap.add_argument("--strict", action="store_true",
                    help="static preflight via repro.analysis: audit kernel-"
                         "launch contracts, sharding divisibility, and "
                         "retrace budgets for this (arch, bits, tp) cell; "
                         "exit 2 on any error-severity violation (codes: "
                         "docs/analysis.md)")
    tp.add_argument("--host-devices", type=int, default=None,
                    help="force N virtual CPU devices (XLA host platform "
                         "device count) — lets --tp run on a single CPU "
                         "host, e.g. --platform cpu --host-devices 8 --tp 4")
    args = ap.parse_args()
    # environment knobs must land before jax touches its backend (its init
    # is lazy, so nothing above has triggered it)
    from repro.launch.env import configure
    configure(platform=args.platform, host_devices=args.host_devices)
    if args.prefix_cache:
        args.paged = True
    supervised = (args.inject_faults or args.ttl_ticks is not None
                  or args.max_queue is not None or args.snapshot_dir
                  or args.snapshot_every is not None)

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = reduced(cfg, scan_layers=False)

    if args.strict:
        # pure shape math — refuses a mis-sharded config in milliseconds,
        # before any device, mesh, or parameter exists
        from repro.analysis import strict_audit
        from repro.serve.speculative import check_spec_config
        tp_degree = args.tp if args.tp and args.tp > 1 else 1
        spec_msg = check_spec_config(args.spec_k, args.draft_bits,
                                     where="--strict")
        if spec_msg is not None:
            print(f"--strict: refusing to serve: {spec_msg}")
            raise SystemExit(2)
        rep = strict_audit(cfg, quantizer=args.bits, tp=tp_degree)
        for v in rep.violations:
            print(f"  {v}")
        if rep.errors:
            print(f"--strict: refusing to serve {cfg.name} x {args.bits} x "
                  f"tp{tp_degree}: {len(rep.errors)} error-severity "
                  f"violation(s) above (codes: docs/analysis.md)")
            raise SystemExit(2)
        print(f"--strict: {cfg.name} x {args.bits} x tp{tp_degree} passes "
              f"the static audit ({len(rep.warnings)} warning(s))")

    params = init_params(cfg, jax.random.PRNGKey(0))

    if args.quantize:
        from repro.core import PTQConfig, quantize_params
        taps = Taps(with_outer=args.quantize == "qera_exact")
        calib = jax.numpy.asarray(
            np.random.default_rng(0).integers(
                0, cfg.vocab_size, size=(8, 64), dtype=np.int32))
        forward(params, {"tokens": calib}, dataclasses.replace(
            cfg, scan_layers=False), taps=taps)
        from benchmarks.common import remap_stats
        stats = remap_stats(taps.layer_stats())
        qcfg = PTQConfig(method=args.quantize, rank=args.rank,
                         quantizer=args.bits)
        plan = None
        if args.plan:
            from repro.core import QuantPlan
            plan = QuantPlan.load(args.plan)
            print(f"loaded QuantPlan {args.plan}: "
                  f"{len(plan.assignments)} per-layer assignments, "
                  f"default {plan.default.quantizer}/r{plan.default.rank}")
        params = quantize_params(params, qcfg, stats_by_path=stats, plan=plan)
        print(f"quantized with {args.quantize}/{args.bits} rank {args.rank}"
              + (" (per-layer plan overrides)" if plan else ""))

    mesh = None
    if args.mesh or (args.tp is not None and args.tp > 1):
        from repro.launch.mesh import make_serving_mesh
        mesh = make_serving_mesh(args.tp)
        print(f"tensor parallel: tp={mesh.shape['model']} over "
              f"{jax.device_count()} visible {jax.default_backend()} "
              f"device(s)")

    batcher = ContinuousBatcher(params, cfg, num_slots=args.slots,
                                max_len=args.max_len,
                                chunk_tokens=args.chunk_tokens,
                                paged=args.paged, page_size=args.page_size,
                                num_pages=args.num_pages,
                                prefix_cache=args.prefix_cache,
                                nan_retry_limit=args.nan_retry_limit,
                                mesh=mesh, spec_k=args.spec_k,
                                draft_bits=args.draft_bits)
    rng = np.random.default_rng(7)
    # shared few-shot preamble on half the requests so --prefix-cache has
    # real hits to report (production traffic is dominated by shared
    # system prompts)
    preamble = rng.integers(0, cfg.vocab_size,
                            size=min(2 * args.page_size, args.max_len // 2)
                            ).astype(np.int32)
    prompts = []
    for i in range(args.requests):
        tail = rng.integers(0, cfg.vocab_size,
                            size=int(rng.integers(3, 12))).astype(np.int32)
        prompts.append(np.concatenate([preamble, tail]) if i % 2 else tail)
    reqs = [Request(rid=i, prompt=p, max_new_tokens=args.max_new)
            for i, p in enumerate(prompts)]
    t0 = time.time()
    if supervised:
        from repro.checkpoint.ckpt import CheckpointManager
        from repro.runtime.fault_tolerance import RestartPolicy
        from repro.serve.faults import FaultInjector
        from repro.serve.supervisor import ServingSupervisor
        injector = None
        if args.inject_faults:
            injector = FaultInjector.storm(
                seed=args.fault_seed, ticks=8 * args.requests,
                p_spike=0.15 if args.paged else 0.0, p_nan=0.15,
                crash_ticks=(5,), spike_duration=2)
        sup = ServingSupervisor(
            batcher, injector=injector,
            policy=RestartPolicy(max_restarts=4, jitter=0.25,
                                 seed=args.fault_seed),
            ckpt=(CheckpointManager(args.snapshot_dir, keep=3)
                  if args.snapshot_dir else None),
            snapshot_every=(args.snapshot_every
                            if args.snapshot_every is not None else 4),
            max_queue_depth=(args.max_queue if args.max_queue is not None
                             else 64),
            default_ttl_ticks=args.ttl_ticks)
        for r in reqs:
            verdict = sup.submit(r)
            if not verdict.accepted:
                print(f"  shed req {r.rid}: {verdict.reason} "
                      f"({verdict.detail})")
        report = sup.run()
        dt = time.time() - t0
        toks = sum(len(r.output) for r in reqs if r.done)
        print(f"served {len(report.completed)}/{len(reqs)} requests / "
              f"{toks} tokens in {dt:.2f}s ({toks / dt:.1f} tok/s goodput)")
        print(f"report: ticks={report.ticks} shed={report.shed} "
              f"expired={report.expired} failed={report.failed} "
              f"recoveries={report.recoveries} "
              f"snapshots={report.snapshots} nan_events={report.nan_events}")
        if injector is not None:
            print(f"faults fired: {injector.log}")
    else:
        for r in reqs:
            batcher.submit(r)
        batcher.run()
        dt = time.time() - t0
        toks = sum(len(r.output) for r in reqs)
        print(f"served {len(reqs)} requests / {toks} tokens in {dt:.2f}s "
              f"({toks / dt:.1f} tok/s)")
    if batcher.prefix is not None:
        pfx = batcher.prefix
        print(f"prefix cache: {pfx.hits} hits / {pfx.misses} misses, "
              f"{pfx.hit_tokens} prompt tokens served from cache, "
              f"{batcher.cow_forks} CoW forks, "
              f"{len(pfx)} pages registered")
    for r in reqs[:4]:
        print(f"  req {r.rid}: {list(r.prompt)} -> {r.output}")


if __name__ == "__main__":
    main()
