# NOTE: never import repro.launch.dryrun from here — it sets XLA_FLAGS at
# import time and must only be imported as a standalone entry point.
from repro.launch.mesh import (
    make_mesh_by_name,
    make_production_mesh,
    make_tiny_mesh,
)
