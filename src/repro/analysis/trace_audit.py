"""Layer 2: jaxpr/HLO invariant audit of the traced serving steps.

Everything here works on traced or lowered artifacts — no kernel executes:

* **psum contract** (QERA011): the tensor-parallel decode step must carry
  exactly 2 psums per layer (after attention, after MLP — one all-reduce
  per projection pair, ``sharding/serving.py``), placed INSIDE the layer
  scan body when layers are scanned (so the body traced once carries 2) and
  nowhere at the top level.  This is the single implementation the TP test
  worker calls; ``tests/_tp_worker.py`` no longer string-counts jaxprs.
* **donation** (QERA012): ``place_slot`` / admission scratch / page forks
  are jitted with donated caches so admission is an in-place write; the
  audit lowers them with donation requested and verifies the compiled
  artifact actually aliases buffers (XLA silently drops donation when an
  output cannot alias — e.g. a dtype change — which costs a full cache copy
  per tick).
* **host callbacks** (QERA013): the decode/chunk steps and the fused scan
  body must contain no callback/infeed primitives — one host round-trip per
  token step destroys decode throughput.
* **retrace budget** (QERA014): the serving loop's trace-cache keys come
  from bucketing helpers (``page_bucket``, ``pick_prefill_chunk``/
  ``chunk_plan``); the auditor hashes the key a helper emits over its whole
  input domain and flags any helper whose distinct-key count exceeds the
  O(log) budget — the recompilation-storm detector.
"""

from __future__ import annotations

import math
from typing import Any, Callable, Iterable

from repro.analysis.errors import ERROR, Violation

PSUMS_PER_LAYER = 2

FORBIDDEN_PRIMITIVES = frozenset({
    "pure_callback", "io_callback", "debug_callback", "callback",
    "outside_call", "host_callback_call", "infeed", "outfeed",
})


# -- jaxpr walking ----------------------------------------------------------

def _as_jaxpr(v: Any):
    # duck-typed: ClosedJaxpr carries .jaxpr, a raw Jaxpr carries .eqns
    if hasattr(v, "jaxpr") and hasattr(v.jaxpr, "eqns"):
        return v.jaxpr
    if hasattr(v, "eqns"):
        return v
    return None


def _subjaxprs(params: dict) -> Iterable[Any]:
    for v in params.values():
        vs = v if isinstance(v, (tuple, list)) else (v,)
        for x in vs:
            j = _as_jaxpr(x)
            if j is not None:
                yield j


def count_primitives(jaxpr, names: frozenset[str] | set[str],
                     _in_scan: bool = False) -> dict[str, dict[str, int]]:
    """Count primitive occurrences, split by placement: ``in_scan`` vs
    ``top`` (anywhere outside a scan body, however deeply nested in
    pjit/shard_map)."""
    if hasattr(jaxpr, "jaxpr"):       # ClosedJaxpr
        jaxpr = jaxpr.jaxpr
    counts = {n: {"in_scan": 0, "top": 0} for n in names}
    for eqn in jaxpr.eqns:
        prim = eqn.primitive.name
        if prim in names:
            counts[prim]["in_scan" if _in_scan else "top"] += 1
        inner_scan = _in_scan or prim == "scan"
        for sub in _subjaxprs(eqn.params):
            for n, c in count_primitives(sub, names, inner_scan).items():
                counts[n]["in_scan"] += c["in_scan"]
                counts[n]["top"] += c["top"]
    return counts


def count_psums(jaxpr) -> dict[str, int]:
    """{'in_scan': n, 'top': m} psum placement of a (closed) jaxpr."""
    return count_primitives(jaxpr, frozenset({"psum"}))["psum"]


# -- QERA011: psum count + placement ---------------------------------------

def psum_violations(in_scan: int, top: int, *, tp: int, scan: bool,
                    num_layers: int, where: str = "") -> list[Violation]:
    """The pure checker (unit-testable without devices): expected placement
    given the sharding contract."""
    total = in_scan + top
    out = []
    if tp <= 1:
        if total:
            out.append(Violation(
                "QERA011", ERROR, where,
                f"{total} psum(s) in a tp=1 step: single-device serving "
                f"must not pay any collective",
                "gate lax.psum on cfg.tp_size > 1"))
        return out
    want = PSUMS_PER_LAYER if scan else PSUMS_PER_LAYER * num_layers
    if total != want:
        out.append(Violation(
            "QERA011", ERROR, where,
            f"decode step carries {total} psum(s), contract wants {want} "
            f"({PSUMS_PER_LAYER} per layer pair"
            f"{', scan body traced once' if scan else ''}): an extra psum "
            f"is a per-layer latency tax, a missing one silently computes "
            f"partial sums",
            "one all-reduce after attention + one after MLP "
            "(models/transformer.py _dense_block)"))
    if scan and top:
        out.append(Violation(
            "QERA011", ERROR, where,
            f"{top} psum(s) OUTSIDE the layer scan body: with scanned "
            f"layers both all-reduces must live inside the body so the "
            f"trace stays O(1) in depth", ""))
    return out


def audit_tp_psums(cfg, mesh, *, num_slots: int = 2,
                   max_len: int = 64) -> dict[str, Any]:
    """Trace the sharded decode step for (cfg, mesh) and check the psum
    contract.  Returns found/want counts plus violations; the TP worker
    asserts on this single implementation.  Needs a real multi-device mesh
    — call from a subprocess under the XLA-flags isolation rule."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    from repro.models.transformer import init_params
    from repro.serve.engine import init_cache, make_decode_step
    from repro.sharding.serving import plan_for

    tp = mesh.shape[cfg.tp_axis] if mesh is not None else 1
    params = init_params(cfg, jax.random.PRNGKey(0))
    plan = plan_for(cfg, mesh)
    cache = init_cache(cfg, num_slots, max_len)
    cspecs = plan.cache_specs(cache)
    step = plan.sjit(make_decode_step(plan.local_cfg),
                     in_specs=(plan.param_specs(params), cspecs,
                               P(None, None), P(None)),
                     out_specs=(P(None, None, None), cspecs))
    jaxpr = jax.make_jaxpr(step)(
        params, cache, {"tokens": jnp.zeros((num_slots, 1), jnp.int32)},
        jnp.zeros((num_slots,), jnp.int32))
    counts = count_psums(jaxpr)
    scan = cfg.scan_layers
    want = (PSUMS_PER_LAYER if scan else PSUMS_PER_LAYER * cfg.num_layers)
    where = f"{cfg.name} decode step tp={tp} scan={scan}"
    viol = psum_violations(counts["in_scan"], counts["top"], tp=tp,
                           scan=scan, num_layers=cfg.num_layers, where=where)
    return {"found": counts["in_scan"] + counts["top"],
            "in_scan": counts["in_scan"], "top": counts["top"],
            "want": want if tp > 1 else 0,
            "violations": [str(v) for v in viol]}


# -- QERA012: donation ------------------------------------------------------

def donation_violations(fn: Callable, args: tuple, *,
                        donate_argnums: tuple[int, ...],
                        where: str = "") -> list[Violation]:
    """Lower ``fn`` with donation requested and verify the compiled artifact
    aliases input buffers to outputs (the ``tf.aliasing_output`` attribute
    in the lowered StableHLO — present even on the CPU backend)."""
    import jax
    lowered = jax.jit(fn, donate_argnums=donate_argnums).lower(*args)
    text = lowered.as_text()
    aliased = text.count("tf.aliasing_output")
    ndonated = sum(len(jax.tree.leaves(args[i])) for i in donate_argnums)
    if aliased == 0:
        return [Violation(
            "QERA012", ERROR, where,
            f"donation requested for {ndonated} buffer(s) but the compiled "
            f"artifact aliases none: every call pays a full copy of the "
            f"donated operand (XLA drops donation silently when an output "
            f"cannot alias, e.g. after a dtype/shape change)",
            "return updated buffers with the same shape/dtype as the "
            "donated inputs")]
    return []


def audit_admission_donation(cfg, *, num_slots: int = 2, max_len: int = 32,
                             page_size: int = 16) -> list[Violation]:
    """The buffers the batcher donates every admission tick: ``place_slot``
    (scratch-cache -> slot row) and the CoW ``fork_page`` must stay
    donation-compatible end to end."""
    import jax.numpy as jnp

    from repro.serve.batching import make_place_slot
    from repro.serve.engine import init_cache
    from repro.serve.paging import init_paged_cache, make_fork_page

    out = []
    cache = init_cache(cfg, num_slots, max_len)
    cache1 = init_cache(cfg, 1, max_len)
    out += donation_violations(
        make_place_slot(num_slots), (cache, cache1, jnp.int32(0)),
        donate_argnums=(0,),
        where=f"{cfg.name} place_slot (admission scratch)")
    paged = init_paged_cache(cfg, num_slots, max_len, page_size=page_size,
                             num_pages=5)
    paged.pop("page_table", None)
    out += donation_violations(
        make_fork_page(), (paged, jnp.int32(1), jnp.int32(2)),
        donate_argnums=(0,), where=f"{cfg.name} fork_page (CoW)")
    return out


# -- QERA013: host callbacks in traced steps --------------------------------

def callback_violations(jaxpr, *, where: str = "") -> list[Violation]:
    counts = count_primitives(jaxpr, FORBIDDEN_PRIMITIVES)
    out = []
    for prim, c in counts.items():
        n = c["in_scan"] + c["top"]
        if n:
            out.append(Violation(
                "QERA013", ERROR, where,
                f"{n} `{prim}` primitive(s) in a traced serving step"
                f"{' (inside the scan body)' if c['in_scan'] else ''}: "
                f"each is a blocking host round-trip per decode tick",
                "compute on device; stage host work outside the step"))
    return out


def audit_step_callbacks(cfg, *, num_slots: int = 2,
                         max_len: int = 32) -> list[Violation]:
    """Trace the dense decode + chunk steps and flag any host callback."""
    import jax
    import jax.numpy as jnp

    from repro.models.transformer import init_params
    from repro.serve.engine import init_cache, make_chunk_step, \
        make_decode_step

    params = init_params(cfg, jax.random.PRNGKey(0))
    cache = init_cache(cfg, num_slots, max_len)
    jaxpr = jax.make_jaxpr(make_decode_step(cfg))(
        params, cache, {"tokens": jnp.zeros((num_slots, 1), jnp.int32)},
        jnp.zeros((num_slots,), jnp.int32))
    out = callback_violations(jaxpr, where=f"{cfg.name} decode step")
    cache1 = init_cache(cfg, 1, max_len)
    jaxpr = jax.make_jaxpr(make_chunk_step(cfg))(
        params, cache1, jnp.zeros((1, 8), jnp.int32), jnp.int32(0))
    out += callback_violations(jaxpr, where=f"{cfg.name} chunk step")
    return out


# -- QERA014: retrace budget ------------------------------------------------

def retrace_budget(domain_size: int) -> int:
    """Distinct trace-cache keys a bucketing helper may emit over a domain:
    O(log) plus slack for the fixed non-pow2 edge widths."""
    return 2 * max(math.ceil(math.log2(max(domain_size, 2))), 1) + 4


def bucketing_violations(fn: Callable[[int], Any], domain: Iterable[int], *,
                         name: str, budget: int | None = None,
                         where: str = "") -> list[Violation]:
    """Hash the trace-cache key ``fn`` emits for every input in ``domain``;
    flag a recompilation storm when the distinct-key count exceeds the
    O(log) budget."""
    dom = list(domain)
    keys = {fn(x) for x in dom}
    cap = budget if budget is not None else retrace_budget(len(dom))
    if len(keys) > cap:
        return [Violation(
            "QERA014", ERROR, where,
            f"{name} emits {len(keys)} distinct trace-cache keys over "
            f"{len(dom)} inputs (budget {cap}): every distinct key is a "
            f"full jit retrace of the serving step",
            "bucket to powers of two (serve/paging.py page_bucket, "
            "kernels/ops.py pick_prefill_chunk)")]
    return []


def audit_serving_retraces(*, max_len: int = 4096, page_size: int = 32,
                           chunk_tokens: int = 64,
                           where: str = "serving loop") -> list[Violation]:
    """The shipped bucketing helpers must hold the retrace budget over the
    full domain a serving session can visit."""
    from repro.kernels.ops import chunk_plan, pick_prefill_chunk
    from repro.serve.paging import page_bucket

    max_pages = max(max_len // page_size, 2)
    out = bucketing_violations(
        lambda p: page_bucket(p, max_pages), range(1, max_pages + 1),
        name="page_bucket", where=f"{where} / decode table width")
    out += bucketing_violations(
        lambda n: pick_prefill_chunk(n, page_size=page_size,
                                     max_chunk=chunk_tokens),
        range(1, max_len + 1),
        name="pick_prefill_chunk", where=f"{where} / prefill chunk width")
    # chunk_plan: each WIDTH in a plan is one trace of the chunk step, so
    # the key set is the union of widths across all prompt lengths
    widths: set[int] = set()
    for n in range(1, max_len + 1):
        widths.update(chunk_plan(n, chunk_tokens))
    cap = retrace_budget(chunk_tokens)
    if len(widths) > cap:
        out.append(Violation(
            "QERA014", ERROR, f"{where} / chunk plan",
            f"chunk_plan emits {len(widths)} distinct chunk widths over "
            f"prompts up to {max_len} tokens (budget {cap}): every width "
            f"is a jit retrace of the chunk step",
            "binary tail decomposition must stay pow2"))
    return out
