"""repro.analysis — trace-time contract checker + custom lint pass.

Three layers, all static (no kernel ever executes):

1. kernel-launch contracts (``contracts``): VMEM footprint, sublane/lane
   alignment, packed/exponent-block divisibility, grid sanity for every
   registered Pallas launch (QERA00x);
2. traced-artifact invariants (``trace_audit``): TP psum count/placement,
   donation in the compiled artifact, host callbacks in step functions,
   retrace budgets (QERA01x);
3. AST lint over the serving hot path (``lint``, QERA02x).

CLI: ``python -m repro.analysis --all`` sweeps the registry x MXINT format
x tp matrix and emits the JSON report CI consumes; ``launch/serve.py
--strict`` runs :func:`strict_audit` at startup and refuses a violating
config.  Error codes are documented in docs/analysis.md.

The divisibility primitives (``validate_packed_sharding``,
``packed_shard_granule``) live in ``quant.mxint`` and are re-exported here
— one source of truth for call sites and tests.
"""

from repro.analysis.errors import CODES, ERROR, WARN, Report, Violation
from repro.analysis.contracts import (
    CONTRACTS,
    audit_arch,
    audit_decode_attention,
    audit_flash_attention,
    audit_matmul_launch,
    audit_prefill_attention,
    audit_quantize_weights,
    audit_quantized_matmul,
    check_plan,
)
from repro.analysis.lint import DEFAULT_LINT_PATHS, lint_paths, lint_source
from repro.analysis.trace_audit import (
    audit_admission_donation,
    audit_serving_retraces,
    audit_step_callbacks,
    audit_tp_psums,
    bucketing_violations,
    callback_violations,
    count_psums,
    donation_violations,
    psum_violations,
)
from repro.quant.mxint import packed_shard_granule, validate_packed_sharding

__all__ = [
    "CODES", "CONTRACTS", "ERROR", "WARN", "Report", "Violation",
    "audit_arch", "audit_admission_donation", "audit_decode_attention",
    "audit_flash_attention", "audit_matmul_launch",
    "audit_prefill_attention", "audit_quantize_weights",
    "audit_quantized_matmul", "audit_serving_retraces",
    "audit_step_callbacks", "audit_tp_psums", "bucketing_violations",
    "callback_violations", "check_plan", "count_psums",
    "donation_violations", "lint_paths", "lint_source",
    "packed_shard_granule", "psum_violations", "strict_audit",
    "validate_packed_sharding", "DEFAULT_LINT_PATHS",
]


def strict_audit(cfg, *, quantizer: str = "mxint4", tp: int = 1,
                 backend: str = "tpu") -> Report:
    """The ``launch/serve.py --strict`` startup gate: static launch audit
    of the exact serving config at its format and tp degree, plus the
    retrace-budget check.  Pure shape math — runs before any device, mesh,
    or parameter is touched, so a mis-sharded config is refused in
    milliseconds with the offending QERA code."""
    from repro.quant.mxint import MXINT_CONFIGS
    spec = MXINT_CONFIGS[quantizer]
    report = Report()
    cell = f"{cfg.name} x {quantizer} x tp{tp}"
    report.cells.append(cell)
    if tp > 1:
        from repro.sharding.serving import validate_tp
        try:
            validate_tp(cfg, tp)
        except ValueError as e:
            report.extend([Violation(
                "QERA003", ERROR, cell, str(e),
                "pick a tp degree that divides heads/kv-heads/d_ff")])
            return report
    found = audit_arch(cfg, bits=spec.bits, block_size=spec.block_size,
                       tp=tp, backend=backend)
    if found is not None:
        report.extend(found)
    report.extend(audit_serving_retraces())
    return report
