"""CLI: ``python -m repro.analysis`` — run the analyzer, emit JSON for CI.

    # full matrix (CI): registry x {mxint4,3,2} x tp {1,2,4,8} x k {0,2,4}
    PYTHONPATH=src python -m repro.analysis --all --json report.json

    # one cell, launch layer only
    PYTHONPATH=src python -m repro.analysis --arch yi-34b --tp 2 \
        --layers launch

    # the custom AST lint alone (runs next to ruff in CI)
    PYTHONPATH=src python -m repro.analysis --lint-only

Exit code is 0 iff no error-severity violation was found (warnings never
fail the run).  The trace layer re-traces reduced configs per (arch, tp)
and needs tp virtual devices — the CLI forces the XLA host-platform device
count itself (before jax initializes), so it is safe to invoke from a
single-device shell.  Error codes: docs/analysis.md.
"""

from __future__ import annotations

import argparse
import os
import sys


def _build_report(args):
    from repro.analysis import Report, audit_arch, audit_serving_retraces, \
        lint_paths
    from repro.configs.registry import get_arch
    from repro.quant.mxint import MXINT_CONFIGS

    report = Report()
    layers = set(args.layers.split(","))

    if "lint" in layers:
        root = args.root
        report.extend(lint_paths(list(args.lint_paths), root=root))

    if "launch" in layers:
        for arch in args.arch:
            cfg = get_arch(arch)
            for fmt in args.formats:
                spec = MXINT_CONFIGS[fmt]
                for tp in args.tp:
                    for sk in args.spec_k:
                        cell = (f"{arch} x {fmt} x tp{tp}"
                                + (f" x k{sk}" if sk else ""))
                        found = audit_arch(cfg, bits=spec.bits,
                                           block_size=spec.block_size, tp=tp,
                                           backend=args.backend, spec_k=sk)
                        if found is None:
                            report.skip(cell, "unservable: validate_tp "
                                              "refuses this (family, tp) — "
                                              "clean refusal, not a "
                                              "violation")
                            continue
                        report.cells.append(cell)
                        report.extend(found)
        if args.plan_sweep:
            from repro.core.allocate import mixed_reference_plan
            plan = mixed_reference_plan()
            for arch in args.arch:
                cfg = get_arch(arch)
                for tp in args.tp:
                    cell = f"{arch} x mixed-plan x tp{tp}"
                    found = audit_arch(cfg, bits=4, block_size=32, rank=32,
                                       tp=tp, backend=args.backend,
                                       plan=plan)
                    if found is None:
                        report.skip(cell, "unservable: validate_tp refuses "
                                          "this (family, tp) — clean "
                                          "refusal, not a violation")
                        continue
                    report.cells.append(cell)
                    report.extend(found)
        report.extend(audit_serving_retraces())

    if "trace" in layers:
        from repro.analysis import (audit_admission_donation,
                                    audit_step_callbacks, audit_tp_psums)
        from repro.analysis.errors import Violation
        from repro.launch.mesh import make_serving_mesh
        from repro.models.config import reduced

        for arch in args.arch:
            cfg = get_arch(arch)
            if cfg.family != "dense":
                continue                 # TP (and its psum contract) is
                                         # restricted to the dense family
            rcfg = reduced(cfg)
            report.extend(audit_admission_donation(rcfg))
            report.extend(audit_step_callbacks(rcfg))
            for tp in sorted(set(args.tp) & {1, 2, 4}):
                if tp == 1:
                    continue
                try:
                    from repro.sharding.serving import validate_tp
                    validate_tp(rcfg, tp)
                except ValueError:
                    report.skip(f"{arch} trace tp{tp}", "reduced config "
                                "unservable at this tp")
                    continue
                res = audit_tp_psums(rcfg, make_serving_mesh(tp))
                cell = f"{arch} x trace x tp{tp}"
                report.cells.append(cell)
                for v in res["violations"]:
                    # audit_tp_psums stringifies; re-wrap for the report
                    report.extend([Violation("QERA011", "error", cell, v)])
    return report


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="QERA static analysis: kernel-launch contracts, traced-"
                    "artifact invariants, hot-path AST lint. Error codes "
                    "are documented in docs/analysis.md.")
    ap.add_argument("--all", action="store_true",
                    help="full registry x {mxint4,3,2} x tp {1,2,4,8} x "
                         "spec_k {0,2,4} matrix, all three layers")
    ap.add_argument("--arch", nargs="*", default=None,
                    help="registry arch names (default: all assigned)")
    ap.add_argument("--formats", nargs="*",
                    default=["mxint4", "mxint3", "mxint2"])
    ap.add_argument("--tp", nargs="*", type=int, default=[1, 2, 4, 8])
    ap.add_argument("--spec-k", nargs="*", type=int, default=[0, 2, 4],
                    dest="spec_k",
                    help="speculative draft lengths to audit (0 = plain "
                         "decode; k>0 adds the draft-plane GEMMs and the "
                         "batched (k+1)-token verify launch)")
    ap.add_argument("--plan-sweep", action="store_true", dest="plan_sweep",
                    help="also audit every arch under the heterogeneous "
                         "mixed_reference_plan (per-projection bits/rank) — "
                         "implied by --all")
    ap.add_argument("--layers", default="launch,trace,lint",
                    help="comma-set of launch|trace|lint")
    ap.add_argument("--lint-only", action="store_true",
                    help="shorthand for --layers lint")
    ap.add_argument("--lint-paths", nargs="*", default=None,
                    help="files/dirs for the AST lint (default: serve/, "
                         "kernels/, models/, benchmarks/)")
    ap.add_argument("--backend", default="tpu",
                    help="VMEM budget to audit against (tpu|interpret)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write the JSON report here (CI artifact)")
    ap.add_argument("--root", default=None,
                    help="repo root for lint paths (default: auto)")
    args = ap.parse_args(argv)

    if args.lint_only:
        args.layers = "lint"
    if args.all:
        args.plan_sweep = True
    if args.arch is None or args.all:
        from repro.configs.registry import ASSIGNED_ARCHS
        args.arch = list(ASSIGNED_ARCHS)
    if args.root is None:
        args.root = os.path.dirname(os.path.dirname(os.path.dirname(
            os.path.dirname(os.path.abspath(__file__)))))
    if args.lint_paths is None:
        from repro.analysis.lint import DEFAULT_LINT_PATHS
        args.lint_paths = DEFAULT_LINT_PATHS

    # the trace layer re-traces sharded steps: force enough virtual host
    # devices BEFORE jax initializes its backend (XLA-flags isolation rule
    # — this is a standalone process, never the pytest session)
    if "trace" in args.layers and max(args.tp, default=1) > 1:
        from repro.launch.env import set_host_device_count
        set_host_device_count(max(min(t, 4) for t in args.tp) or 1)
        os.environ.setdefault("JAX_PLATFORMS", "cpu")

    report = _build_report(args)

    if args.json:
        os.makedirs(os.path.dirname(args.json) or ".", exist_ok=True)
        with open(args.json, "w", encoding="utf-8") as f:
            f.write(report.to_json())
    s = report.summary()
    print(f"repro.analysis: {s['cells']} cells audited, {s['skipped']} "
          f"skipped (clean refusals), {s['errors']} error(s), "
          f"{s['warnings']} warning(s)")
    for v in report.violations:
        print(f"  {v}")
    if report.errors:
        print("FAIL: error-severity violations above (docs/analysis.md)")
        return 1
    print("OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
