"""Runtime assertion mode for the paged batcher (``debug_invariants=True``).

Two laws from serve/paging.py, re-checked from scratch after every tick —
an independent reimplementation, not a re-read of the allocator's own
bookkeeping paths:

* **refcount conservation** — for every physical page p > 0, the pool's
  refcount equals the number of slot table references; refcount-0 pages
  partition exactly into the free list and the LRU-parked (registered)
  cache; page 0 (garbage) is never owned; the device-bound page table rows
  mirror ``slot_pages``.
* **shared-page write protection** — a page that is shared (refcount > 1)
  or whose content is registered in the prefix index is NEVER written: the
  checker hashes every protected page's content each tick and compares
  against the previous tick for pages protected in both (a mismatch means a
  write bypassed the CoW fork).

Checks are host-side and O(pool size) per tick — meant for tests
(tests/conftest.py enables them for the serving/prefix-cache/fault suites),
not production serving.
"""

from __future__ import annotations

import hashlib
from collections import Counter
from typing import Any

import numpy as np


def check_page_accounting(pool, slot_pages: list[list[int]],
                          page_table: np.ndarray) -> list[str]:
    """Refcount-conservation violations ('' when the law holds)."""
    acc = pool.accounting()
    refs, free = acc["refs"], acc["free"]
    cached, registered = acc["cached"], acc["registered"]
    errs = []
    owned = Counter(p for pages in slot_pages for p in pages)
    if owned.get(0):
        errs.append("garbage page 0 appears in slot_pages")
    if refs[0] != 0:
        errs.append(f"garbage page 0 has refcount {refs[0]}")
    for p in range(1, pool.num_pages):
        if refs[p] != owned.get(p, 0):
            errs.append(
                f"page {p}: refcount {refs[p]} != {owned.get(p, 0)} slot "
                f"table reference(s) — a release/share was lost")
    free_set, cached_set = set(free), set(cached)
    if len(free_set) != len(free):
        errs.append("duplicate pages on the free list")
    if free_set & cached_set:
        errs.append(f"pages both free and LRU-parked: "
                    f"{sorted(free_set & cached_set)}")
    if not cached_set <= registered:
        errs.append(f"LRU-parked pages without a registration: "
                    f"{sorted(cached_set - registered)}")
    for p in range(1, pool.num_pages):
        idle = refs[p] == 0
        pooled = p in free_set or p in cached_set
        if idle and not pooled:
            errs.append(f"page {p} leaked: refcount 0 but neither free "
                        f"nor LRU-parked")
        if not idle and pooled:
            errs.append(f"page {p} owned (refcount {refs[p]}) but still "
                        f"on the free/cached list")
    for slot, pages in enumerate(slot_pages):
        row = page_table[slot]
        nz = [int(x) for x in row[row != 0]]
        if sorted(nz) != sorted(pages):
            errs.append(
                f"slot {slot}: page_table row {nz} != slot_pages {pages}")
    return errs


def protected_pages(pool) -> set[int]:
    """Pages the CoW law forbids writing: shared or content-registered."""
    acc = pool.accounting()
    refs = acc["refs"]
    shared = {p for p in range(1, pool.num_pages) if refs[p] > 1}
    return shared | acc["registered"]


def snapshot_protected_pages(cache: Any, pool) -> dict[int, tuple[int, str]]:
    """page -> (allocation generation, content digest) for protected pages.

    The generation (bumped by ``PagePool.acquire``) distinguishes the SAME
    physical page across an LRU evict + reallocation: new owner, new
    content, legitimately — only same-generation digests may be compared.
    """
    prot = protected_pages(pool)
    if not prot:
        return {}
    import jax

    from repro.utils.trees import flatten_dict
    gen = pool.accounting()["generation"]
    leaves = {k: np.asarray(jax.device_get(v))
              for k, v in flatten_dict(cache).items()
              if k.rsplit("/", 1)[-1] in ("k_pages", "v_pages")}
    out = {}
    for p in sorted(prot):
        h = hashlib.sha256()
        for k in sorted(leaves):
            h.update(leaves[k][:, p].tobytes())
        out[p] = (int(gen[p]), h.hexdigest())
    return out


def check_protected_writes(prev: dict[int, tuple[int, str]],
                           cur: dict[int, tuple[int, str]]) -> list[str]:
    """A page protected on BOTH ticks, under the SAME allocation
    generation, must have identical content — any change means a write
    bypassed the copy-on-write fork."""
    return [f"protected page {p} was written in place (refcount > 1 or "
            f"registered content changed) — a write bypassed _cow_fork"
            for p in sorted(set(prev) & set(cur))
            if prev[p][0] == cur[p][0] and prev[p][1] != cur[p][1]]
