"""QERA0xx error codes: the vocabulary of the static-analysis pass.

Codes are ruff-style and stable — tests, CI, and docs/analysis.md key on
them.  Three families mirror the analyzer's three layers:

  QERA00x  kernel-launch contracts (VMEM, alignment, divisibility, grid)
  QERA01x  traced-artifact invariants (psum contract, donation, callbacks,
           retrace budget)
  QERA02x  AST lint over the serving hot path

Severity is two-level: ``error`` fails CI / refuses ``--strict`` serving;
``warn`` is surfaced in the report (e.g. a sublane dim the TPU merely pads)
but never fails the run.  See docs/analysis.md for cause/example/fix per
code.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Any

ERROR = "error"
WARN = "warn"

CODES: dict[str, str] = {
    # -- layer 1: kernel-launch contracts ----------------------------------
    "QERA001": "kernel launch exceeds the per-backend VMEM budget",
    "QERA002": "block plan violates sublane/lane tiling alignment",
    "QERA003": "packed-container / exponent-block divisibility violation",
    "QERA004": "degenerate or oversized Pallas grid",
    # -- layer 2: traced-artifact invariants -------------------------------
    "QERA011": "tensor-parallel psum count/placement breaks the sharding "
               "contract",
    "QERA012": "buffer marked for donation is not donated in the compiled "
               "artifact",
    "QERA013": "host callback / blocking transfer inside a traced serving "
               "step",
    "QERA014": "recompilation storm: trace-cache key set exceeds its budget",
    # -- layer 3: hot-path AST lint ----------------------------------------
    "QERA021": "host synchronization on a traced value in a hot-path "
               "function",
    "QERA022": "PagePool internal field mutated outside its methods",
    "QERA023": "pool-page write that bypasses the copy-on-write guard",
    "QERA024": "unseeded randomness in fault-injection or benchmark code",
    "QERA025": "pallas_call site without a registered launch-contract "
               "annotation",
}


@dataclasses.dataclass(frozen=True)
class Violation:
    """One finding: a stable code, a location, and an actionable message.

    ``where`` is a human-locatable site — ``file:line`` for lint findings,
    an ``arch x format x tp / kernel`` cell for contract findings.
    ``suggestion`` is the fix (e.g. the legal block plan ``pick_blocks``
    would have chosen) and may be empty.
    """

    code: str
    severity: str
    where: str
    message: str
    suggestion: str = ""

    def __post_init__(self):
        assert self.code in CODES, f"unknown code {self.code}"
        assert self.severity in (ERROR, WARN), self.severity

    def as_dict(self) -> dict[str, str]:
        return dataclasses.asdict(self)

    def __str__(self) -> str:
        s = f"{self.code} [{self.severity}] {self.where}: {self.message}"
        return s + (f"  (fix: {self.suggestion})" if self.suggestion else "")


@dataclasses.dataclass
class Report:
    """Aggregated analyzer output; ``to_json`` is the CI artifact schema."""

    violations: list[Violation] = dataclasses.field(default_factory=list)
    cells: list[str] = dataclasses.field(default_factory=list)
    skipped: list[dict[str, str]] = dataclasses.field(default_factory=list)

    @property
    def errors(self) -> list[Violation]:
        return [v for v in self.violations if v.severity == ERROR]

    @property
    def warnings(self) -> list[Violation]:
        return [v for v in self.violations if v.severity == WARN]

    def extend(self, violations: list[Violation]) -> None:
        self.violations.extend(violations)

    def skip(self, cell: str, reason: str) -> None:
        self.skipped.append({"cell": cell, "reason": reason})

    def ok(self) -> bool:
        return not self.errors

    def summary(self) -> dict[str, Any]:
        return {"cells": len(self.cells), "skipped": len(self.skipped),
                "errors": len(self.errors), "warnings": len(self.warnings)}

    def to_json(self, indent: int = 2) -> str:
        return json.dumps(
            {"summary": self.summary(),
             "violations": [v.as_dict() for v in self.violations],
             "cells": self.cells, "skipped": self.skipped},
            indent=indent)
