"""Layer 3: AST lint over the serving hot path (QERA02x).

Pure-Python ``ast`` pass over ``serve/``, ``kernels/``, ``models/`` (plus
``benchmarks/`` for the randomness rule) — no jax import, no tracing:

* **QERA021** — host synchronization inside a *traced* function:
  ``.item()`` / ``float()`` / ``np.asarray`` / ``jax.device_get`` /
  ``.block_until_ready()`` on values that are traced there.  "Traced" is
  detected structurally: jit-decorated functions, functions wrapped by a
  module-level ``jax.jit(f)`` / ``partial(jax.jit, ...)(f)``, inner
  functions returned from ``make_*`` factories (the batcher's jitted step
  helpers), functions handed to ``lax.scan``/``while_loop``/``cond``/
  ``pallas_call``, and Pallas kernel bodies (``*_kernel``).
* **QERA022** — ``PagePool`` internals (``_refs``/``_free``/``_cached``/
  ``_registered``) mutated outside ``PagePool`` methods: refcount laws hold
  only if every transition goes through acquire/share/release.
* **QERA023** — pool-page writes outside the CoW guard: ``._fork(...)``
  called anywhere but ``_cow_fork``, or in-place ``.at[...].set`` scatters
  on ``*_pages`` leaves outside ``serve/paging.py`` (the sanctioned jitted
  helpers).
* **QERA024** — unseeded randomness in fault/bench code: a seedless
  ``np.random.default_rng()``, the legacy global ``np.random.*`` API, or
  stdlib ``random.*`` — fault storms and benchmarks must replay bit-
  identically from their seed.
* **QERA025** — a ``pl.pallas_call`` site in ``kernels/`` without a
  ``# contract: <name>`` annotation naming a registered entry in
  ``analysis/contracts.py`` (keeps the launch-contract registry complete).
"""

from __future__ import annotations

import ast
import os
import re

from repro.analysis.errors import ERROR, Violation

HOST_SYNC_NP = {"asarray", "array", "copyto", "from_dlpack"}
HOST_SYNC_METHODS = {"item", "tolist", "block_until_ready"}
LEGACY_NP_RANDOM = {
    "rand", "randn", "randint", "random", "random_sample", "choice",
    "shuffle", "permutation", "normal", "uniform", "seed", "standard_normal",
}
POOL_PRIVATE_FIELDS = {"_refs", "_free", "_cached", "_registered"}
MUTATING_METHODS = {"append", "extend", "pop", "popitem", "clear", "add",
                    "discard", "remove", "update", "insert", "setdefault"}
_CONTRACT_RE = re.compile(r"#\s*contract:\s*([A-Za-z0-9_]+)")


def _name_of(node: ast.AST) -> str:
    """Dotted name of a Name/Attribute chain ('' when not a plain chain)."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""


def _is_jit_expr(node: ast.AST) -> bool:
    """jax.jit / jit / pjit / partial(jax.jit, ...) / functools.partial(...)"""
    if isinstance(node, ast.Call):
        fname = _name_of(node.func)
        if fname.endswith("partial"):
            return any(_is_jit_expr(a) for a in node.args)
        return fname.rsplit(".", 1)[-1] in ("jit", "pjit", "sjit")
    return _name_of(node).rsplit(".", 1)[-1] in ("jit", "pjit")


class _TracedCollector(ast.NodeVisitor):
    """First pass: find the set of function names that run under trace."""

    def __init__(self):
        self.traced: set[str] = set()
        self._factory_depth = 0

    def visit_FunctionDef(self, node: ast.FunctionDef):
        if any(_is_jit_expr(d) for d in node.decorator_list):
            self.traced.add(node.name)
        if self._factory_depth or node.name.endswith("_kernel") \
                or node.name == "kernel":
            # inner defs of make_* factories are the returned jitted
            # helpers; *_kernel bodies run inside pallas_call
            self.traced.add(node.name)
        is_factory = node.name.startswith("make_")
        self._factory_depth += is_factory
        self.generic_visit(node)
        self._factory_depth -= is_factory

    visit_AsyncFunctionDef = visit_FunctionDef

    def visit_Call(self, node: ast.Call):
        fname = _name_of(node.func).rsplit(".", 1)[-1]
        if fname in ("scan", "while_loop", "fori_loop", "cond", "switch",
                     "pallas_call", "checkpoint", "remat", "vmap", "partial"):
            for a in node.args:
                if isinstance(a, ast.Name):
                    self.traced.add(a.id)
        if _is_jit_expr(node):
            for a in node.args:
                if isinstance(a, ast.Name):
                    self.traced.add(a.id)
        self.generic_visit(node)


class _Linter(ast.NodeVisitor):
    def __init__(self, path: str, traced: set[str], scopes: dict[str, bool]):
        self.path = path
        self.traced = traced
        self.scopes = scopes          # rule-key -> applies to this file
        self.violations: list[Violation] = []
        self._fn_stack: list[str] = []
        self._class_stack: list[str] = []

    def _flag(self, code: str, node: ast.AST, msg: str, fix: str = ""):
        where = f"{self.path}:{getattr(node, 'lineno', 0)}"
        self.violations.append(Violation(code, ERROR, where, msg, fix))

    def _in_traced(self) -> bool:
        return any(f in self.traced for f in self._fn_stack)

    def visit_ClassDef(self, node: ast.ClassDef):
        self._class_stack.append(node.name)
        self.generic_visit(node)
        self._class_stack.pop()

    def visit_FunctionDef(self, node: ast.FunctionDef):
        self._fn_stack.append(node.name)
        self.generic_visit(node)
        self._fn_stack.pop()

    visit_AsyncFunctionDef = visit_FunctionDef

    # -- QERA021 + QERA023 + QERA024 hang off calls ------------------------
    def visit_Call(self, node: ast.Call):
        fname = _name_of(node.func)
        tail = fname.rsplit(".", 1)[-1]
        if self.scopes.get("hot") and self._in_traced():
            if tail in HOST_SYNC_METHODS and isinstance(node.func,
                                                        ast.Attribute):
                self._flag(
                    "QERA021", node,
                    f".{tail}() inside traced function "
                    f"'{self._fn_stack[-1]}': forces a device sync per call",
                    "keep the value on device; read it outside the step")
            elif fname.startswith(("np.", "numpy.")) \
                    and tail in HOST_SYNC_NP:
                self._flag(
                    "QERA021", node,
                    f"{fname}() on a traced value inside "
                    f"'{self._fn_stack[-1]}': silently pulls the array to "
                    f"host every tick", "use jnp inside traced code")
            elif fname in ("jax.device_get", "device_get"):
                self._flag(
                    "QERA021", node,
                    f"jax.device_get inside traced function "
                    f"'{self._fn_stack[-1]}'",
                    "move host reads outside the step")
            elif isinstance(node.func, ast.Name) \
                    and node.func.id in ("float", "int") and node.args:
                src = ast.unparse(node.args[0])
                if ".shape" not in src and "len(" not in src \
                        and not isinstance(node.args[0], ast.Constant):
                    self._flag(
                        "QERA021", node,
                        f"{node.func.id}() on a (possibly traced) value "
                        f"inside '{self._fn_stack[-1]}': concretizes the "
                        f"tracer (sync or trace error)",
                        "keep arithmetic in jnp; cast with .astype")
        if self.scopes.get("cow") and tail == "_fork" \
                and isinstance(node.func, ast.Attribute) \
                and "_cow_fork" not in self._fn_stack:
            self._flag(
                "QERA023", node,
                f"page fork called from '{self._fn_stack[-1] or '<module>'}'"
                f", outside the _cow_fork guard: forking without the "
                f"refcount/registration check can clone live pages or skip "
                f"the table re-point",
                "route every fork through ContinuousBatcher._cow_fork")
        if self.scopes.get("pool"):
            # pool._free.append(...) etc. — mutation via method call
            if isinstance(node.func, ast.Attribute) \
                    and tail in MUTATING_METHODS:
                base = node.func.value
                if isinstance(base, ast.Attribute) \
                        and base.attr in POOL_PRIVATE_FIELDS \
                        and "PagePool" not in self._class_stack:
                    self._flag(
                        "QERA022", node,
                        f"PagePool.{base.attr}.{tail}() outside PagePool: "
                        f"refcount conservation only holds through "
                        f"acquire/share/release",
                        "use the PagePool API (or PagePool.accounting() "
                        "for reads)")
        if self.scopes.get("rand"):
            if tail == "default_rng" and not node.args and not node.keywords:
                self._flag(
                    "QERA024", node,
                    "np.random.default_rng() without a seed: fault storms "
                    "and benchmarks must replay bit-identically",
                    "pass an explicit seed")
            elif fname.startswith(("np.random.", "numpy.random.")) \
                    and tail in LEGACY_NP_RANDOM:
                self._flag(
                    "QERA024", node,
                    f"legacy global-state {fname}(): unseedable per-site "
                    f"and order-dependent",
                    "use a seeded np.random.default_rng(seed)")
            elif fname.startswith("random.") \
                    and tail in ("random", "randint", "choice", "shuffle",
                                 "uniform", "gauss", "sample"):
                self._flag(
                    "QERA024", node,
                    f"stdlib {fname}() uses hidden global state",
                    "use a seeded np.random.default_rng(seed)")
        self.generic_visit(node)

    # -- QERA022: assignments to pool internals ----------------------------
    def _check_store(self, target: ast.AST, node: ast.AST):
        if not self.scopes.get("pool") or "PagePool" in self._class_stack:
            return
        t = target
        if isinstance(t, ast.Subscript):
            t = t.value
        if isinstance(t, ast.Attribute) and t.attr in POOL_PRIVATE_FIELDS:
            self._flag(
                "QERA022", node,
                f"assignment to PagePool.{t.attr} outside PagePool: "
                f"bypasses the refcount laws (page 0 reserved, parked LRU "
                f"== registered refcount-0 pages)",
                "use acquire/share/release/set_registered")

    def visit_Assign(self, node: ast.Assign):
        for t in node.targets:
            self._check_store(t, node)
        self.generic_visit(node)

    def visit_AugAssign(self, node: ast.AugAssign):
        self._check_store(node.target, node)
        self.generic_visit(node)

    # -- QERA023: in-place scatters on pool leaves -------------------------
    def visit_Attribute(self, node: ast.Attribute):
        if self.scopes.get("cow") and node.attr in ("at",):
            src = ast.unparse(node.value)
            if src.endswith(("k_pages", "v_pages")) \
                    or "_pages\"]" in src or "_pages']" in src:
                self._flag(
                    "QERA023", node,
                    f"in-place update on pool leaf `{src}` outside "
                    f"serve/paging.py: pool writes must go through the "
                    f"jitted helpers so the CoW guard can intercept them",
                    "use the make_* helpers in serve/paging.py")
        self.generic_visit(node)


def _scopes_for(path: str) -> dict[str, bool]:
    """Which rule families apply to a file, from its repo-relative path."""
    p = path.replace(os.sep, "/")
    in_serve = "/serve/" in p or p.startswith("serve/")
    in_bench = "/benchmarks/" in p or p.startswith("benchmarks/")
    in_kernels = "/kernels/" in p or p.startswith("kernels/")
    in_models = "/models/" in p or p.startswith("models/")
    is_paging = p.endswith("/paging.py") or p == "paging.py"
    return {
        "hot": in_serve or in_kernels or in_models,
        "pool": (in_serve or in_models) and not is_paging,
        "cow": in_serve and not is_paging,
        "rand": in_serve or in_bench,
        "contract": in_kernels,
    }


def _check_contract_annotations(path: str, src: str) -> list[Violation]:
    """QERA025: every pallas_call line needs `# contract: <name>` within the
    10 preceding lines, naming a registered contract."""
    from repro.analysis.contracts import CONTRACTS
    out = []
    lines = src.splitlines()
    for i, line in enumerate(lines):
        if "pallas_call(" not in line or line.lstrip().startswith("#"):
            continue
        window = lines[max(0, i - 10):i + 1]
        m = None
        for w in window:
            m = _CONTRACT_RE.search(w) or m
        if m is None:
            out.append(Violation(
                "QERA025", ERROR, f"{path}:{i + 1}",
                "pallas_call without a `# contract: <name>` annotation: "
                "the launch is invisible to the kernel-launch audit",
                "register the launch in analysis/contracts.py and annotate "
                "the call site"))
        elif m.group(1) not in CONTRACTS:
            out.append(Violation(
                "QERA025", ERROR, f"{path}:{i + 1}",
                f"pallas_call annotated with unregistered contract "
                f"'{m.group(1)}' (known: {sorted(CONTRACTS)})",
                "add the entry to analysis/contracts.py CONTRACTS"))
    return out


def lint_source(src: str, path: str) -> list[Violation]:
    """Lint one file's source; ``path`` selects which rules apply."""
    scopes = _scopes_for(path)
    tree = ast.parse(src)
    collector = _TracedCollector()
    collector.visit(tree)
    linter = _Linter(path, collector.traced, scopes)
    linter.visit(tree)
    out = linter.violations
    if scopes.get("contract"):
        out += _check_contract_annotations(path, src)
    return out


def lint_paths(paths: list[str], root: str = ".") -> list[Violation]:
    """Lint every .py file under the given directories/files."""
    out = []
    for p in paths:
        full = p if os.path.isabs(p) else os.path.join(root, p)
        files = []
        if os.path.isdir(full):
            for dirpath, _, names in os.walk(full):
                files += [os.path.join(dirpath, n) for n in sorted(names)
                          if n.endswith(".py")]
        elif full.endswith(".py"):
            files = [full]
        for f in files:
            with open(f, encoding="utf-8") as fh:
                rel = os.path.relpath(f, root) if not os.path.isabs(p) else f
                out += lint_source(fh.read(), rel)
    return out


DEFAULT_LINT_PATHS = ("src/repro/serve", "src/repro/kernels",
                      "src/repro/models", "benchmarks")
