"""Layer 1: kernel-launch contracts for every Pallas wrapper in ``kernels/``.

Each ``pl.pallas_call`` site carries a ``# contract: <name>`` annotation
naming an entry in :data:`CONTRACTS`; the registry knows, from static shapes
alone, the exact BlockSpec/scratch geometry of the launch.  From that the
auditor computes the VMEM footprint (in/out tiles are double-buffered by the
Pallas pipeline, scratch is resident once), checks sublane/lane tiling
alignment, packed-container and exponent-block divisibility, and grid
sanity — all *before* any ``pallas_call``, so a violating config is refused
at trace/startup time instead of dying in Mosaic three layers down.

The block-plan heuristics are not duplicated here: matmul audits call the
real ``kernels.ops.pick_blocks`` and divisibility audits call the real
``quant.mxint.validate_packed_sharding`` — one source of truth, and error
messages can always print the legal plan ``pick_blocks`` would pick.
"""

from __future__ import annotations

import dataclasses
import math

from repro.analysis.errors import ERROR, WARN, Violation

# -- per-backend VMEM budget (bytes) ---------------------------------------
# TPU cores have ~16 MiB of VMEM; the compiler reserves some for spills, so
# anything above the soft fraction is flagged as a warning before the hard
# budget errors.  ``interpret`` (CPU) has no budget — launches run in plain
# XLA memory.
VMEM_BUDGET_BYTES: dict[str, int | None] = {"tpu": 16 * 2 ** 20,
                                            "interpret": None}
VMEM_SOFT_FRACTION = 0.75

LANE = 128
# minimum sublane tile per element byte-width (f32: 8x128, bf16: 16x128,
# int8: 32x128)
MIN_SUBLANE = {4: 8, 2: 16, 1: 32}
ITEMSIZE = {"float32": 4, "int32": 4, "bfloat16": 2, "float16": 2, "int8": 1}

# decode-attention GQA group rows per block: below the f32 sublane tile the
# TPU pads every (g, d) tile up to (8, d) — correct but wasteful.


@dataclasses.dataclass(frozen=True)
class Block:
    """One VMEM-resident tile of a launch: an in/out BlockSpec block or a
    scratch buffer.  ``strict`` marks dims Mosaic rejects outright when
    misaligned (vs. merely padding them)."""

    name: str
    shape: tuple[int, ...]
    dtype: str
    kind: str = "in"               # in | out | scratch
    strict: bool = False
    # alignment is checked only for blocks whose geometry is config-derived;
    # inherently-tiny design blocks (the shared-exponent tile) opt out.
    check: bool = True

    @property
    def nbytes(self) -> int:
        n = ITEMSIZE[self.dtype]
        for s in self.shape:
            n *= s
        return n


@dataclasses.dataclass(frozen=True)
class LaunchPlan:
    """A fully-resolved launch: contract name, grid, and resident blocks."""

    contract: str
    where: str
    grid: tuple[int, ...]
    blocks: tuple[Block, ...]

    def vmem_bytes(self) -> int:
        return sum(b.nbytes if b.kind == "scratch" else 2 * b.nbytes
                   for b in self.blocks)

    def describe(self) -> str:
        blocks = ", ".join(f"{b.name}{b.shape}:{b.dtype}"
                           for b in self.blocks)
        return f"{self.contract} grid={self.grid} [{blocks}]"


@dataclasses.dataclass(frozen=True)
class Contract:
    name: str
    module: str
    description: str


CONTRACTS: dict[str, Contract] = {c.name: c for c in (
    Contract("mxint_matmul_lowrank", "src/repro/kernels/mxint_matmul.py",
             "fused MXINT dequant-matmul + low-rank path, prefill 3-D grid "
             "(M/bm, N/bn, K/bk), K innermost"),
    Contract("mxint_matmul_lowrank_decode",
             "src/repro/kernels/mxint_matmul.py",
             "fused MXINT dequant-matmul, skinny-M decode variant: whole-M "
             "block, N-major 2-D grid"),
    Contract("mxint_matmul_draft", "src/repro/kernels/mxint_matmul.py",
             "draft-plane MXINT dequant-matmul (top draft_bits of each "
             "mantissa container, no low-rank blocks), prefill 3-D grid "
             "(M/bm, N/bn, K/bk)"),
    Contract("mxint_matmul_draft_decode", "src/repro/kernels/mxint_matmul.py",
             "draft-plane MXINT dequant-matmul, skinny-M decode variant: "
             "whole-M block, N-major 2-D grid"),
    Contract("decode_attention", "src/repro/kernels/decode_attention.py",
             "paged decode attention, grid (B, Hkv, npages), page table via "
             "scalar prefetch"),
    Contract("prefill_attention", "src/repro/kernels/prefill_attention.py",
             "paged chunk-prefill attention, GQA group flattened to G*C "
             "query rows, offset-causal mask"),
    Contract("mxint_quantize", "src/repro/kernels/mxint_quant.py",
             "on-device blockwise MXINT (re)quantization, grid "
             "(K/block_size, N/bn)"),
    Contract("flash_attention", "src/repro/kernels/flash_attention.py",
             "dense flash attention, grid (B, H, Sq/bq, Skv/bkv)"),
)}


# -- generic plan checks ----------------------------------------------------

def check_plan(plan: LaunchPlan, *, backend: str = "tpu",
               suggestion: str = "") -> list[Violation]:
    """QERA001 (VMEM), QERA002 (alignment), QERA004 (grid) for one plan."""
    out = []
    # QERA004: grid sanity
    if any(g < 1 for g in plan.grid):
        out.append(Violation(
            "QERA004", ERROR, plan.where,
            f"degenerate grid {plan.grid} in {plan.describe()}: every grid "
            f"dim must be >= 1 (a zero dim launches nothing and usually "
            f"means an empty page table or a zero-size operand)",
            suggestion))
        return out                  # block shapes are meaningless now
    nprog = math.prod(plan.grid)
    if nprog > 2 ** 31:
        out.append(Violation(
            "QERA004", ERROR, plan.where,
            f"grid {plan.grid} launches {nprog} programs (> 2^31); the "
            f"grid is almost certainly mis-derived", suggestion))
    # QERA001: VMEM budget
    budget = VMEM_BUDGET_BYTES.get(backend)
    if budget is not None:
        used = plan.vmem_bytes()
        if used > budget:
            out.append(Violation(
                "QERA001", ERROR, plan.where,
                f"launch needs ~{used / 2**20:.1f} MiB VMEM "
                f"(> {budget / 2**20:.0f} MiB {backend} budget): "
                f"{plan.describe()}; in/out tiles are double-buffered, "
                f"scratch is resident once",
                suggestion or "shrink block_m/block_n/block_k"))
        elif used > VMEM_SOFT_FRACTION * budget:
            out.append(Violation(
                "QERA001", WARN, plan.where,
                f"launch needs ~{used / 2**20:.1f} MiB VMEM "
                f"(> {VMEM_SOFT_FRACTION:.0%} of the "
                f"{budget / 2**20:.0f} MiB {backend} budget): "
                f"{plan.describe()}", suggestion))
    # QERA002: sublane/lane alignment per block
    for b in plan.blocks:
        if len(b.shape) < 2 or not b.check:
            continue
        sub, lane = b.shape[-2], b.shape[-1]
        min_sub = MIN_SUBLANE[ITEMSIZE[b.dtype]]
        if sub % min_sub:
            sev = ERROR if b.strict else WARN
            verb = ("Mosaic rejects this block" if b.strict else
                    "the TPU pads it to the full tile (correct but wasted "
                    "sublanes)")
            out.append(Violation(
                "QERA002", sev, plan.where,
                f"{plan.contract}: block {b.name}{b.shape} ({b.dtype}) has "
                f"{sub} sublane rows, not a multiple of {min_sub} — {verb}",
                suggestion))
        if lane % LANE and lane >= LANE:
            out.append(Violation(
                "QERA002", WARN, plan.where,
                f"{plan.contract}: block {b.name}{b.shape} ({b.dtype}) has "
                f"{lane} lanes, not a multiple of {LANE} — partially filled "
                f"lane tiles", suggestion))
    return out


# -- fused MXINT matmul (both grid variants) --------------------------------

def matmul_plan(m: int, k: int, n: int, r: int, *, bits: int,
                block_size: int, bm: int, bn: int, bk: int, decode: bool,
                packed: bool = True, x_dtype: str = "float32",
                where: str = "") -> LaunchPlan:
    """Mirror of the BlockSpec/scratch geometry in kernels/mxint_matmul.py
    for an explicit block plan (see the ``# contract:`` annotations there)."""
    from repro.quant.mxint import elems_per_byte
    epb = elems_per_byte(bits) if packed else 1
    contract = ("mxint_matmul_lowrank_decode" if decode
                else "mxint_matmul_lowrank")
    m_pad = -(-m // 8) * 8
    xm = m_pad if decode else bm
    grid = ((n // bn, k // bk) if decode
            else (max(m_pad // bm, 1), n // bn, k // bk))
    blocks = (
        Block("x", (xm, bk), x_dtype, strict=True),
        Block("mant", (bk // epb, bn), "int8"),
        Block("exp", (bk // block_size, bn), "int8", check=False),
        Block("a", (bk, r), "float32"),
        Block("b", (r, bn), "float32"),
        Block("out", (xm, bn), "float32", kind="out", strict=True),
        Block("acc", (xm, bn), "float32", kind="scratch"),
        Block("t", (xm, r), "float32", kind="scratch"),
    )
    return LaunchPlan(contract, where, grid, blocks)


def audit_matmul_launch(m: int, k: int, n: int, r: int, *, bits: int,
                        block_size: int, bm: int, bn: int, bk: int,
                        decode: bool, packed: bool = True,
                        backend: str = "tpu",
                        where: str = "") -> list[Violation]:
    """Audit an EXPLICIT block plan (the asserts in ``_check_shapes`` plus
    the Mosaic/VMEM constraints), suggesting the ``pick_blocks`` plan when
    the given one is illegal."""
    from repro.kernels.ops import pick_blocks
    from repro.quant.mxint import elems_per_byte
    epb = elems_per_byte(bits) if packed else 1
    out = []

    def suggest() -> str:
        try:
            sbm, sbn, sbk, sdec = pick_blocks(
                m, k, n, block_size=block_size, epb=epb)
        except ValueError:
            return ""
        return (f"pick_blocks(m={m}, k={k}, n={n}) -> bm={sbm}, bn={sbn}, "
                f"bk={sbk}, decode={sdec}")

    # QERA003: divisibility (mirrors _check_shapes / pick_blocks)
    for label, dim, blk in (("K", k, bk), ("N", n, bn)):
        if blk < 1 or dim % blk:
            out.append(Violation(
                "QERA003", ERROR, where,
                f"{label}={dim} does not divide block {blk} — the launch "
                f"would fail the kernel's shape assert", suggest()))
    if bk >= 1 and bk % block_size:
        out.append(Violation(
            "QERA003", ERROR, where,
            f"bk={bk} is not a multiple of the MXINT block_size="
            f"{block_size}: every K tile must cover whole exponent blocks",
            suggest()))
    if packed and block_size % epb:
        out.append(Violation(
            "QERA003", ERROR, where,
            f"MXINT block_size={block_size} does not cover whole packed "
            f"bytes (epb={epb})", "use block_size that is a multiple of epb"))
    if not decode and bm >= 1 and (-(-m // 8) * 8) % bm:
        out.append(Violation(
            "QERA003", ERROR, where,
            f"padded M={-(-m // 8) * 8} does not divide block_m={bm}",
            suggest()))
    if out:
        return out
    plan = matmul_plan(m, k, n, r, bits=bits, block_size=block_size, bm=bm,
                       bn=bn, bk=bk, decode=decode, packed=packed,
                       where=where)
    return check_plan(plan, backend=backend, suggestion=suggest())


def draft_matmul_plan(m: int, k: int, n: int, *, bits: int, block_size: int,
                      bm: int, bn: int, bk: int, decode: bool,
                      packed: bool = True, x_dtype: str = "float32",
                      where: str = "") -> LaunchPlan:
    """Mirror of the DRAFT kernels in kernels/mxint_matmul.py: same tiling
    as the fused lowrank launch but no a/b input blocks and no (bm, r)
    prologue scratch — the speculative draft pass drops the low-rank term
    entirely, which is exactly its VMEM/FLOP advantage."""
    from repro.quant.mxint import elems_per_byte
    epb = elems_per_byte(bits) if packed else 1
    contract = ("mxint_matmul_draft_decode" if decode
                else "mxint_matmul_draft")
    m_pad = -(-m // 8) * 8
    xm = m_pad if decode else bm
    grid = ((n // bn, k // bk) if decode
            else (max(m_pad // bm, 1), n // bn, k // bk))
    blocks = (
        Block("x", (xm, bk), x_dtype, strict=True),
        Block("mant", (bk // epb, bn), "int8"),
        Block("exp", (bk // block_size, bn), "int8", check=False),
        Block("out", (xm, bn), "float32", kind="out", strict=True),
        Block("acc", (xm, bn), "float32", kind="scratch"),
    )
    return LaunchPlan(contract, where, grid, blocks)


def audit_quantized_matmul_draft(m: int, k: int, n: int, *, bits: int,
                                 block_size: int, packed: bool = True,
                                 backend: str = "tpu",
                                 where: str = "") -> list[Violation]:
    """Audit the launch ``kernels.ops.quantized_matmul_draft`` would issue —
    blocks come from the same ``pick_blocks`` the wrapper uses, and the
    divisibility rules are identical to the fused launch (the draft reads
    the SAME packed buffers)."""
    from repro.kernels.ops import pick_blocks
    from repro.quant.mxint import elems_per_byte
    epb = elems_per_byte(bits) if packed else 1
    try:
        bm, bn, bk, decode = pick_blocks(m, k, n, block_size=block_size,
                                         epb=epb)
    except ValueError as e:
        return [Violation(
            "QERA003", ERROR, where, str(e),
            f"pad K or pick a tp degree so the local K is a multiple of "
            f"block_size={block_size}")]
    plan = draft_matmul_plan(m, k, n, bits=bits, block_size=block_size,
                             bm=bm, bn=bn, bk=bk, decode=decode,
                             packed=packed, where=where)
    return check_plan(plan, backend=backend)


def audit_quantized_matmul(m: int, k: int, n: int, r: int, *, bits: int,
                           block_size: int, packed: bool = True,
                           block_m: int = 128, block_n: int = 128,
                           block_k: int = 128, backend: str = "tpu",
                           where: str = "") -> list[Violation]:
    """Audit the launch ``kernels.ops.quantized_matmul`` would issue for
    these shapes — the production path: blocks come from ``pick_blocks``."""
    from repro.kernels.ops import pick_blocks
    from repro.quant.mxint import elems_per_byte
    epb = elems_per_byte(bits) if packed else 1
    try:
        bm, bn, bk, decode = pick_blocks(
            m, k, n, block_size=block_size, epb=epb, block_m=block_m,
            block_n=block_n, block_k=block_k)
    except ValueError as e:
        return [Violation(
            "QERA003", ERROR, where, str(e),
            f"pad K or pick a tp degree so the local K is a multiple of "
            f"block_size={block_size}")]
    return audit_matmul_launch(m, k, n, r, bits=bits, block_size=block_size,
                               bm=bm, bn=bn, bk=bk, decode=decode,
                               packed=packed, backend=backend, where=where)


# -- paged attention kernels ------------------------------------------------

def audit_decode_attention(b: int, h: int, hkv: int, d: int, *,
                           page_size: int, npages: int,
                           dtype: str = "float32", backend: str = "tpu",
                           where: str = "") -> list[Violation]:
    """Mirror of kernels/decode_attention.py: grid (B, Hkv/hb, npages).

    ``hb`` comes from the kernel's own ``pick_kv_block`` (single source of
    truth): the per-layer block plan batches ``hb`` kv heads per grid step
    so the q/out/acc tiles hold ``hb·G`` real rows (command-r-plus G=12 →
    24, phi3.5-moe G=4 → 8, llama4-maverick G=5 → 40 — full sublane tiles,
    no waste).  When no divisor of Hkv aligns, the kernel zero-pads the
    rows to the 8-sublane grid EXPLICITLY and crops on the way out, so the
    audited BlockSpec — like the launched one — is always aligned; the
    old G ∉ 8ℤ QERA002 warning class is gone by construction."""
    from repro.kernels.decode_attention import pick_kv_block

    if hkv < 1 or h % hkv:
        return [Violation(
            "QERA003", ERROR, where,
            f"H={h} query heads do not divide Hkv={hkv} kv heads — GQA "
            f"grouping q.reshape(B, Hkv, G, D) is impossible")]
    g = h // hkv
    min_sub = MIN_SUBLANE[ITEMSIZE[dtype]]
    hb = pick_kv_block(hkv, g, min_sub)
    rows = -(-(hb * g) // min_sub) * min_sub       # kernel's explicit pad
    plan = LaunchPlan("decode_attention", where, (b, hkv // hb, npages), (
        Block("q", (1, 1, rows, d), dtype),
        Block("k_page", (1, hb, page_size, d), dtype),
        Block("v_page", (1, hb, page_size, d), dtype),
        Block("out", (1, 1, rows, d), dtype, kind="out"),
        Block("m", (rows, 1), "float32", kind="scratch"),
        Block("l", (rows, 1), "float32", kind="scratch"),
        Block("acc", (rows, d), "float32", kind="scratch"),
    ))
    return check_plan(plan, backend=backend, suggestion="")


def audit_prefill_attention(b: int, h: int, hkv: int, d: int, *, chunk: int,
                            page_size: int, npages: int,
                            dtype: str = "float32", backend: str = "tpu",
                            where: str = "") -> list[Violation]:
    """Mirror of kernels/prefill_attention.py: G*C query rows per block;
    the ops wrapper pads the chunk to an 8-multiple before launch."""
    if hkv < 1 or h % hkv:
        return [Violation(
            "QERA003", ERROR, where,
            f"H={h} query heads do not divide Hkv={hkv} kv heads")]
    g = h // hkv
    c8 = -(-chunk // 8) * 8
    rows = g * c8
    plan = LaunchPlan("prefill_attention", where, (b, hkv, npages), (
        Block("q", (1, 1, rows, d), dtype),
        Block("k_page", (1, 1, page_size, d), dtype),
        Block("v_page", (1, 1, page_size, d), dtype),
        Block("out", (1, 1, rows, d), dtype, kind="out"),
        Block("m", (rows, 1), "float32", kind="scratch"),
        Block("l", (rows, 1), "float32", kind="scratch"),
        Block("acc", (rows, d), "float32", kind="scratch"),
    ))
    return check_plan(plan, backend=backend,
                      suggestion="shrink the prefill chunk (chunk_tokens)")


def audit_flash_attention(b: int, h: int, sq: int, skv: int, d: int, *,
                          block_q: int = 128, block_kv: int = 128,
                          dtype: str = "float32", backend: str = "tpu",
                          where: str = "") -> list[Violation]:
    """Mirror of kernels/flash_attention.py via the ops wrapper's clamping
    (bq = min(block_q, sq), inputs padded to block multiples)."""
    bq = min(block_q, sq)
    bkv = min(block_kv, skv)
    sq_p = -(-sq // bq) * bq
    skv_p = -(-skv // bkv) * bkv
    plan = LaunchPlan("flash_attention", where,
                      (b, h, sq_p // bq, skv_p // bkv), (
                          Block("q", (1, 1, bq, d), dtype),
                          Block("k", (1, 1, bkv, d), dtype),
                          Block("v", (1, 1, bkv, d), dtype),
                          Block("out", (1, 1, bq, d), dtype, kind="out"),
                          Block("m", (bq, 1), "float32", kind="scratch"),
                          Block("l", (bq, 1), "float32", kind="scratch"),
                          Block("acc", (bq, d), "float32", kind="scratch"),
                      ))
    return check_plan(plan, backend=backend,
                      suggestion="pass 8/128-multiple block_q/block_kv")


# -- on-device repack -------------------------------------------------------

def audit_quantize_weights(k: int, n: int, *, bits: int, block_size: int,
                           packed: bool = True, backend: str = "tpu",
                           where: str = "") -> list[Violation]:
    """Mirror of ops.quantize_weights -> kernels/mxint_quant.py, using the
    wrapper's own ``pick_quant_bn`` so the audited plan IS the launched
    plan (one source of truth)."""
    from repro.kernels.ops import pick_quant_bn
    from repro.quant.mxint import elems_per_byte
    epb = elems_per_byte(bits) if packed else 1
    out = []
    if k % block_size:
        return [Violation(
            "QERA003", ERROR, where,
            f"K={k} is not a multiple of MXINT block_size={block_size} — "
            f"quantize_weights cannot form whole shared-exponent blocks",
            "pad K to a block_size multiple before the repack")]
    if packed and block_size % epb:
        return [Violation(
            "QERA003", ERROR, where,
            f"block_size={block_size} does not cover whole packed bytes "
            f"(epb={epb})")]
    bn = pick_quant_bn(n)
    plan = LaunchPlan("mxint_quantize", where, (k // block_size, n // bn), (
        Block("w", (block_size, bn), "float32"),
        # out tiles have <= block_size rows by design: alignment is a
        # property of the kernel, not of the audited config
        Block("mant", (block_size // epb, bn), "int8", kind="out",
              check=False),
        Block("exp", (1, bn), "int8", kind="out", check=False),
    ))
    out += check_plan(
        plan, backend=backend,
        suggestion="" if bn == 128 else
        f"N={n} is not a 128-multiple (pick_quant_bn chose bn={bn}) — pad "
        f"N to a 128-multiple to restore full lane tiling")
    return out


# -- registry sweep ---------------------------------------------------------

def projection_dims(cfg) -> list[tuple[str, int, int, str]]:
    """(name, K, N, role) of every quantized serving GEMM of a config:
    attention + MLP projections (the tensor-parallel contract set from
    ``sharding/serving.py``) plus the replicated lm_head at the padded
    vocab."""
    d, hd = cfg.d_model, cfg.hd
    q, kv, f = cfg.num_heads * hd, cfg.num_kv_heads * hd, cfg.d_ff
    dims = [("wq", d, q, "column"), ("wk", d, kv, "column"),
            ("wv", d, kv, "column"), ("wo", q, d, "row"),
            ("wi", d, f, "column"), ("wg", d, f, "column"),
            ("wu", d, f, "column"), ("wd", f, d, "row")]
    pad = getattr(cfg, "vocab_pad_multiple", 1) or 1
    vocab = -(-cfg.vocab_size // pad) * pad
    dims.append(("lm_head", d, vocab, "replicated"))
    return dims


def audit_arch(cfg, *, bits: int, block_size: int, tp: int = 1,
               rank: int = 16, num_slots: int = 8, prefill_m: int = 256,
               chunk: int = 64, page_size: int = 32, spec_k: int = 0,
               backend: str = "tpu",
               plan=None) -> list[Violation] | None:
    """Static launch audit of one (arch, format, tp[, spec_k]) cell at FULL
    model shapes: every projection GEMM in both decode and prefill regimes,
    the paged attention kernels, the dense flash kernel, and the on-device
    repack.  ``spec_k`` > 0 additionally audits the speculative-decode
    launches: the draft-plane GEMM at decode M (no low-rank blocks) and the
    k+1-token verify — the fused GEMM at M = num_slots*(spec_k+1) rows plus
    the chunk-prefill attention kernel at chunk = spec_k+1.  ``plan`` (a
    ``core.allocate.QuantPlan`` keyed by projection NAME — see
    ``mixed_reference_plan``) makes the audit heterogeneous: each
    projection's launches are checked at its own (bits, block_size, rank)
    and the global ``bits``/``block_size``/``rank`` become the fallback for
    unlisted projections.  Returns None when the cell is unservable by
    design (validate_tp refuses it loudly) — a clean refusal is the
    contract working, not a violation."""
    from repro.quant.mxint import validate_packed_sharding
    fmt = "plan" if plan is not None else f"mxint{bits}"
    cell = f"{cfg.name} x {fmt} x tp{tp}"
    if tp > 1:
        from repro.sharding.serving import validate_tp
        try:
            validate_tp(cfg, tp)
        except ValueError:
            return None

    def point(name: str) -> tuple[int, int, int]:
        if plan is None or name not in plan.assignments:
            return bits, block_size, rank
        c = plan.choice(name)
        spec = c.spec()
        return spec.bits, spec.block_size, c.rank

    out: list[Violation] = []
    for name, k, n, role in projection_dims(cfg):
        p_bits, p_bs, p_rank = point(name)
        k_loc, n_loc = k, n
        if tp > 1 and role == "row":
            try:
                k_loc = validate_packed_sharding(k, tp, p_bits, p_bs,
                                                 name=name)
            except ValueError as e:
                out.append(Violation(
                    "QERA003", ERROR, f"{cell} / {name}", str(e),
                    "choose a tp degree whose K shard is a multiple of "
                    "lcm(block_size, 8*elems_per_byte)"))
                continue
        elif tp > 1 and role == "column":
            n_loc = n // tp
        for regime, m in (("decode", num_slots), ("prefill", prefill_m)):
            out += audit_quantized_matmul(
                m, k_loc, n_loc, p_rank, bits=p_bits, block_size=p_bs,
                backend=backend, where=f"{cell} / {name} ({regime} m={m})")
        if spec_k > 0:
            out += audit_quantized_matmul_draft(
                num_slots, k_loc, n_loc, bits=p_bits, block_size=p_bs,
                backend=backend,
                where=f"{cell} / {name} (draft m={num_slots})")
            m_v = num_slots * (spec_k + 1)
            out += audit_quantized_matmul(
                m_v, k_loc, n_loc, p_rank, bits=p_bits, block_size=p_bs,
                backend=backend,
                where=f"{cell} / {name} (verify k={spec_k} m={m_v})")
        if tp == 1:
            out += audit_quantize_weights(
                k, n, bits=p_bits, block_size=p_bs, backend=backend,
                where=f"{cell} / {name} (repack)")
    h_loc = cfg.num_heads // tp
    kv_loc = max(cfg.num_kv_heads // tp, 1)
    max_len = min(getattr(cfg, "max_seq_len", 4096) or 4096, 32768)
    npages = max(max_len // page_size, 1)
    out += audit_decode_attention(
        num_slots, h_loc, kv_loc, cfg.hd, page_size=page_size,
        npages=npages, backend=backend, where=f"{cell} / decode_attention")
    out += audit_prefill_attention(
        num_slots, h_loc, kv_loc, cfg.hd, chunk=chunk, page_size=page_size,
        npages=npages, backend=backend, where=f"{cell} / prefill_attention")
    if spec_k > 0:
        # the verify step attends spec_k+1 fresh positions per slot through
        # the same chunk-prefill kernel path
        out += audit_prefill_attention(
            num_slots, h_loc, kv_loc, cfg.hd, chunk=spec_k + 1,
            page_size=page_size, npages=npages, backend=backend,
            where=f"{cell} / verify_attention (k={spec_k})")
    out += audit_flash_attention(
        1, h_loc, min(max_len, 2048), min(max_len, 2048), cfg.hd,
        backend=backend, where=f"{cell} / flash_attention")
    return out
