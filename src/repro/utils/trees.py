"""Small pytree helpers shared across the framework."""

from __future__ import annotations

from typing import Any, Callable, Mapping

import jax
import numpy as np


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def tree_map_with_path_str(fn: Callable[[str, Any], Any], tree: Any) -> Any:
    """Like jax.tree.map but fn receives the '/'-joined path string."""
    return jax.tree_util.tree_map_with_path(lambda p, x: fn(_path_str(p), x), tree)


def tree_size_bytes(tree: Any) -> int:
    leaves = jax.tree_util.tree_leaves(tree)
    return sum(x.size * x.dtype.itemsize for x in leaves if hasattr(x, "size"))


def tree_param_count(tree: Any) -> int:
    leaves = jax.tree_util.tree_leaves(tree)
    return int(sum(np.prod(x.shape) for x in leaves if hasattr(x, "shape")))


def flatten_dict(d: Mapping[str, Any], prefix: str = "") -> dict[str, Any]:
    out: dict[str, Any] = {}
    for k, v in d.items():
        key = f"{prefix}/{k}" if prefix else str(k)
        if isinstance(v, Mapping):
            out.update(flatten_dict(v, key))
        else:
            out[key] = v
    return out


def unflatten_dict(flat: Mapping[str, Any]) -> dict[str, Any]:
    out: dict[str, Any] = {}
    for k, v in flat.items():
        parts = k.split("/")
        cur = out
        for p in parts[:-1]:
            cur = cur.setdefault(p, {})
        cur[parts[-1]] = v
    return out
