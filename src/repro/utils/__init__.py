from repro.utils.trees import (
    tree_map_with_path_str,
    tree_size_bytes,
    tree_param_count,
    flatten_dict,
    unflatten_dict,
)
