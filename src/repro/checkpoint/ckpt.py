"""Fault-tolerant checkpointing: per-shard files + manifest, atomic rename,
keep-k GC, mesh-agnostic restore (elastic re-shard on load).

Layout:
  <dir>/step_000123/
      manifest.json                  # tree paths, shapes, dtypes, step, extra
      <flat-path>.npy                # one file per leaf (full array, host)
  <dir>/step_000123.tmp/ ...        # staging; renamed atomically when done

Full-array host files make restore onto ANY mesh trivial: load -> device_put
with the new sharding.  On a real multi-host pod each host writes only its
addressable shards; the single-process layout here is the degenerate case of
the same manifest format (shard_count == 1).
"""

from __future__ import annotations

import dataclasses
import json
import shutil
import threading
from pathlib import Path
from typing import Any, Callable

import jax
import numpy as np

from repro.utils.trees import flatten_dict, unflatten_dict

_SAFE = str.maketrans({"/": "%2F"})


def _encode(path: str) -> str:
    return path.translate(_SAFE)


@dataclasses.dataclass
class CheckpointManager:
    directory: str | Path
    keep: int = 3
    async_save: bool = False

    def __post_init__(self):
        self.directory = Path(self.directory)
        self.directory.mkdir(parents=True, exist_ok=True)
        self._thread: threading.Thread | None = None

    # -- save ---------------------------------------------------------------
    def save(self, step: int, tree: Any, extra: dict | None = None) -> Path:
        if self.async_save:
            self.wait()
            host_tree = jax.tree.map(np.asarray, tree)   # snapshot now
            self._thread = threading.Thread(
                target=self._save_sync, args=(step, host_tree, extra))
            self._thread.start()
            return self._final_dir(step)
        return self._save_sync(step, tree, extra)

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _final_dir(self, step: int) -> Path:
        return self.directory / f"step_{step:09d}"

    def _save_sync(self, step: int, tree: Any, extra: dict | None) -> Path:
        final = self._final_dir(step)
        tmp = final.with_suffix(".tmp")
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        flat = flatten_dict(dict(tree))
        manifest = {"step": step, "extra": extra or {}, "leaves": {}}
        for path, leaf in flat.items():
            arr = np.asarray(leaf)
            fn = _encode(path) + ".npy"
            np.save(tmp / fn, arr)
            manifest["leaves"][path] = {
                "file": fn, "shape": list(arr.shape), "dtype": str(arr.dtype)}
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        if final.exists():
            shutil.rmtree(final)
        tmp.rename(final)                      # atomic commit
        self._gc()
        return final

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep > 0 else []:
            shutil.rmtree(self._final_dir(s), ignore_errors=True)

    # -- load ---------------------------------------------------------------
    def all_steps(self) -> list[int]:
        out = []
        for p in self.directory.iterdir():
            if p.is_dir() and p.name.startswith("step_") and \
                    not p.name.endswith(".tmp"):
                out.append(int(p.name[5:]))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int | None = None,
                shardings: Any = None) -> tuple[int, Any, dict]:
        """Returns (step, tree, extra).  ``shardings`` (same treedef, leaves
        None or Sharding) re-shards onto any mesh — elastic restart."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        d = self._final_dir(step)
        manifest = json.loads((d / "manifest.json").read_text())
        flat = {}
        for path, meta in manifest["leaves"].items():
            arr = np.load(d / meta["file"])
            flat[path] = arr
        tree = unflatten_dict(flat)
        if shardings is not None:
            flat_sh = flatten_dict(dict(shardings)) if isinstance(
                shardings, dict) else None
            def put(path, x):
                sh = flat_sh.get(path) if flat_sh else None
                return jax.device_put(x, sh) if sh is not None else jax.numpy.asarray(x)
            tree = unflatten_dict({p: put(p, x) for p, x in flat.items()})
        else:
            tree = jax.tree.map(jax.numpy.asarray, tree)
        return manifest["step"], tree, manifest.get("extra", {})
