"""QERA core — the paper's primary contribution.

Closed-form quantization error reconstruction (Theorems 1 & 2), streaming
activation calibration, PSD matrix square roots, truncated/randomized SVD,
and the model-level PTQ/QPEFT entry points.
"""

from repro.core.calibration import (
    LayerStats,
    StreamingStats,
    batch_stats,
    stats_from_samples,
)
from repro.core.solvers import (
    METHODS,
    empirical_output_error,
    expected_output_error,
    solve,
    solve_loftq,
    solve_lqer,
    solve_qera_approx,
    solve_qera_exact,
    solve_qlora,
    solve_zeroquant_v2,
)
from repro.core.sqrtm import psd_sqrt_eigh, psd_sqrt_newton_schulz
from repro.core.svd import randomized_svd, svd_lowrank, truncated_svd
from repro.core.api import (
    PTQConfig,
    dequantized_weight,
    is_quantized_linear,
    quantize_linear,
    quantize_params,
)
from repro.core.allocate import (
    LayerChoice,
    QuantPlan,
    allocate_plan,
    describe_packed_plan,
    plan_bytes,
    plan_expected_error,
    uniform_plan,
)
