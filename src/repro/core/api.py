"""Model-level QERA API: calibrate -> quantize every linear -> reconstructed
params tree.

Convention: a quantized linear replaces its 2-D weight leaf ``w`` with a dict
``{"w_tilde": W̃, "lora_a": A, "lora_b": B}``; ``models.quantized`` applies it
as  y = x @ W̃ + (x @ A) @ B.  Embeddings, norms, routers, biases and any 1-D
params are left in high precision (paper setup: weight-only PTQ of linears).
"""

from __future__ import annotations

import dataclasses
import re
from typing import Any, Callable, Mapping

import jax
import jax.numpy as jnp

from repro.core.calibration import LayerStats
from repro.core.solvers import solve
from repro.quant.formats import get_quantizer
from repro.utils.trees import flatten_dict, unflatten_dict


DEFAULT_SKIP = (r"embed", r"lm_head", r"router", r"norm", r"scale", r"bias",
                r"conv", r"a_log", r"dt_bias", r"decay", r"token_shift",
                r"pos_emb")


@dataclasses.dataclass(frozen=True)
class PTQConfig:
    method: str = "qera_approx"       # one of core.solvers.METHODS
    rank: int = 32
    quantizer: str = "mxint4"
    svd_method: str = "exact"         # "exact" | "randomized"
    sqrt_method: str = "eigh"         # "eigh" | "newton_schulz"
    loftq_iters: int = 5
    skip_patterns: tuple[str, ...] = DEFAULT_SKIP
    lowrank_dtype: Any = jnp.float32

    def skips(self, path: str) -> bool:
        return any(re.search(p, path) for p in self.skip_patterns)


def is_quantized_linear(p: Any) -> bool:
    return isinstance(p, Mapping) and "w_tilde" in p


def quantize_linear(w: jax.Array, cfg: PTQConfig,
                    stats: LayerStats | None = None,
                    key: jax.Array | None = None) -> dict[str, jax.Array]:
    """Quantize one (m, n) weight and solve for the rank-k reconstruction."""
    q = get_quantizer(cfg.quantizer)
    w32 = w.astype(jnp.float32)
    w_tilde = q(w32)
    w_tilde, a, b = solve(
        cfg.method, w32, w_tilde, cfg.rank, stats=stats, quant_fn=q.fake_quant,
        key=key, svd_method=cfg.svd_method, sqrt_method=cfg.sqrt_method,
        loftq_iters=cfg.loftq_iters)
    return {
        "w_tilde": w_tilde.astype(w.dtype),
        "lora_a": a.astype(cfg.lowrank_dtype),
        "lora_b": b.astype(cfg.lowrank_dtype),
    }


def quantize_params(params: Mapping[str, Any], cfg: PTQConfig,
                    stats_by_path: Mapping[str, LayerStats] | None = None,
                    key: jax.Array | None = None,
                    stats_key_fn: Callable[[str], str] | None = None,
                    verbose: bool = False, plan=None) -> dict[str, Any]:
    """Quantize every eligible 2-D weight in a params tree.

    ``stats_by_path`` maps a weight's flattened path (or its stats key) to the
    calibration LayerStats of that layer's *input*.  For stacked (scanned)
    layers — leaves with ndim == 3, (num_layers, m, n) — per-layer stats keys
    ``{path}:{i}`` are used when present, else a shared ``{path}`` entry.

    ``plan`` (a ``core.allocate.QuantPlan``) overrides ``cfg.quantizer`` /
    ``cfg.rank`` per path — heterogeneous mixed-precision quantization from
    one call.  Stacked leaves take the plan's choice for the whole stack
    (all slices of one leaf must share mant/lora shapes to stack).
    """
    if key is None:
        key = jax.random.PRNGKey(0)
    stats_by_path = stats_by_path or {}
    stats_key_fn = stats_key_fn or (lambda p: p)

    def cfg_for(path: str) -> PTQConfig:
        if plan is None:
            return cfg
        c = plan.choice(path)
        return dataclasses.replace(cfg, quantizer=c.quantizer, rank=c.rank)

    flat = flatten_dict(dict(params))
    out: dict[str, Any] = {}
    for path, leaf in flat.items():
        if not hasattr(leaf, "ndim") or cfg.skips(path):
            out[path] = leaf
            continue
        if leaf.ndim == 2:
            st = stats_by_path.get(stats_key_fn(path))
            key, sub = jax.random.split(key)
            lcfg = cfg_for(path)
            out[path] = quantize_linear(leaf, lcfg, stats=st, key=sub)
            if verbose:
                print(f"quantized {path} {leaf.shape} "
                      f"[{lcfg.method}/{lcfg.quantizer}/r{lcfg.rank}]")
        elif leaf.ndim == 3 and not cfg.skips(path):
            # stacked layers: quantize each slice with its own stats
            lcfg = cfg_for(path)
            slices = []
            for i in range(leaf.shape[0]):
                st = (stats_by_path.get(f"{stats_key_fn(path)}:{i}")
                      or stats_by_path.get(stats_key_fn(path)))
                key, sub = jax.random.split(key)
                slices.append(quantize_linear(leaf[i], lcfg, stats=st, key=sub))
            out[path] = {
                k: jnp.stack([s[k] for s in slices]) for k in slices[0]
            }
        else:
            out[path] = leaf
    return unflatten_dict(out)


def dequantized_weight(qlin: Mapping[str, jax.Array]) -> jax.Array:
    """W̃ + A B — the effective full weight of a quantized linear."""
    return qlin["w_tilde"] + qlin["lora_a"] @ qlin["lora_b"]


def pack_for_serving(qparams: Mapping[str, Any], cfg: PTQConfig,
                     packed: bool = True, mesh=None, plan=None) -> dict:
    """Convert quantized linears to the PACKED layout the Pallas kernel
    consumes: {"mant" int8, "exp" int8, "bits", "block_size", lora_a/b}.

    W̃ stays packed in HBM (the memory-roofline win), and with the default
    ``packed=True`` the mantissa buffer is truly sub-byte — bits/8 bytes per
    element via ``quant.mxint.pack_mantissa``, unpacked in VMEM inside the
    kernel — so at 4-bit the weight bytes actually moved drop ~3.6x vs bf16;
    models.layers.linear dispatches to the fused kernel when
    ``cfg.use_pallas`` is set.  ``packed=False`` keeps the flat
    one-int8-per-mantissa layout (interpret-mode debugging escape hatch).
    Only MXINT formats pack.

    With ``mesh`` (a 1-D ``('model',)`` serving mesh), every leaf is
    device_put with its tensor-parallel NamedSharding from
    ``sharding/serving.py`` — in-projections column-parallel, out-projections
    row-parallel, everything else replicated — so the packed buffers land
    pre-sharded and shard_map never reshuffles them.

    With ``plan`` (a ``core.allocate.QuantPlan``), every leaf packs at ITS
    OWN format — the packed tree carries per-leaf 0-dim ``bits`` /
    ``block_size`` markers that ``models.layers.linear`` and the sharding
    validators already dispatch on, so one serving tree mixes mxint8/4/3/2
    layers freely."""
    from repro.quant.mxint import MXINT_CONFIGS, mxint_quantize, pack_mantissa

    if cfg.quantizer not in MXINT_CONFIGS:
        raise ValueError(f"packing supports MXINT formats, got {cfg.quantizer}")

    def spec_for(path: str):
        if plan is None:
            return MXINT_CONFIGS[cfg.quantizer]
        fmt = plan.choice(path).quantizer
        if fmt not in MXINT_CONFIGS:
            raise ValueError(f"packing supports MXINT formats, got {fmt} "
                             f"for {path}")
        return MXINT_CONFIGS[fmt]

    def pack(leaf, spec):
        if not (isinstance(leaf, Mapping) and "w_tilde" in leaf):
            return leaf
        w = leaf["w_tilde"]
        if w.ndim not in (2, 3) or w.shape[-2] % spec.block_size:
            return leaf                     # expert/odd leaves stay fake-quant
        mant, exp = mxint_quantize(w, spec.bits, spec.block_size)
        mant = mant.reshape(w.shape)
        if packed:
            mant = pack_mantissa(mant, spec.bits)
        return {
            "mant": mant, "exp": exp,
            "bits": jnp.asarray(spec.bits, jnp.int32),
            "block_size": jnp.asarray(spec.block_size, jnp.int32),
            "lora_a": leaf["lora_a"], "lora_b": leaf["lora_b"],
        }

    flat = flatten_dict(dict(qparams))
    grouped: dict[str, Any] = {}
    done = set()
    for path in list(flat):
        parent = path.rsplit("/", 1)[0]
        if parent in done or not path.endswith(("w_tilde", "lora_a", "lora_b")):
            if not path.endswith(("w_tilde", "lora_a", "lora_b")):
                grouped[path] = flat[path]
            continue
        leaf = {k: flat[f"{parent}/{k}"] for k in ("w_tilde", "lora_a", "lora_b")}
        group = pack(leaf, spec_for(parent))
        for k, v in group.items():
            grouped[f"{parent}/{k}"] = v
        done.add(parent)
    out = unflatten_dict(grouped)
    if mesh is not None:
        from jax.sharding import NamedSharding
        from repro.sharding.serving import serving_param_specs
        specs = serving_param_specs(out, int(mesh.shape["model"]))
        out = jax.tree.map(
            lambda leaf, s: jax.device_put(leaf, NamedSharding(mesh, s)),
            out, specs)
    return out
