"""Calibration-driven mixed rank/bit allocation: the :class:`QuantPlan`.

QERA's closed-form reconstruction makes every layer's output error
*predictable from calibration statistics*: with the scaled-SVD solver the
rank-k correction ``C_k`` is the best rank-k approximation of ``S (W - W̃)``
(S = Rxx^{1/2} for qera_exact, diag(sqrt(E[x²])) for qera_approx), so the
expected output error after reconstruction is exactly the tail energy

    E(fmt, k) = Σ_{i > k} σ_i²      (σ = singular values of S (W - W̃))

— one quantize + one SVD per (layer, format) yields the FULL error-vs-rank
curve.  That turns mixed-precision allocation into a separable budgeted
selection problem: pick one ``(format, rank)`` per layer minimizing the
summed expected error under a global weights-HBM budget (SERQ-style
saliency scoring; Preserve-Then-Quantize-style rank/bit trade, PAPERS.md).

The allocator solves it in two phases, both deterministic:

1. a Lagrangian sweep — for a bisected multiplier λ each layer
   independently picks ``argmin(error + λ · bytes)``, which lands on the
   lower convex hull of each layer's (bytes, error) cloud;
2. a greedy refill — leftover budget is spent on the single best
   ``Δerror/Δbyte`` upgrade until nothing fits, so any slack the hull
   rounding left is converted into strictly lower error.

The result is a :class:`QuantPlan`: an explicit ``path -> (quantizer,
rank)`` assignment plus a default, JSON round-trippable, consumed by
``core.api.quantize_params`` / ``pack_for_serving`` and carried through
serving snapshots (``serve/supervisor.py``).  ``docs/allocation.md`` has
the budget math and the plan file format.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Any, Callable, Iterable, Mapping

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.calibration import LayerStats
from repro.quant.mxint import MXINT_CONFIGS, mxint_fake_quant

# formats the allocator considers by default: same 32-wide exponent blocks
# (so one plan never mixes block sizes inside one packed granule contract)
# spanning 2..8 mantissa bits.
DEFAULT_FORMATS = ("mxint8", "mxint4", "mxint3", "mxint2_bs32")
DEFAULT_RANKS = (8, 16, 32, 64)


@dataclasses.dataclass(frozen=True)
class LayerChoice:
    """One layer's operating point: MXINT format + reconstruction rank."""

    quantizer: str
    rank: int

    def spec(self):
        return MXINT_CONFIGS[self.quantizer]


@dataclasses.dataclass(frozen=True)
class QuantPlan:
    """path -> :class:`LayerChoice` with a default for unlisted paths.

    ``assignments`` keys are the flattened param paths
    ``quantize_params`` walks (stacked 3-D leaves may carry per-slice
    ``{path}:{i}`` keys, falling back to ``{path}``).  ``meta`` records how
    the plan was made (budget, predicted errors) — informational only,
    excluded from equality.
    """

    assignments: Mapping[str, LayerChoice]
    default: LayerChoice = LayerChoice("mxint4", 32)
    method: str = "qera_approx"
    meta: Mapping[str, Any] = dataclasses.field(default_factory=dict,
                                                compare=False)

    def choice(self, path: str) -> LayerChoice:
        c = self.assignments.get(path)
        if c is None and ":" in path:        # stacked-slice key fallback
            c = self.assignments.get(path.rsplit(":", 1)[0])
        return c if c is not None else self.default

    def to_json_dict(self) -> dict:
        return {
            "version": 1,
            "method": self.method,
            "default": dataclasses.asdict(self.default),
            "assignments": {p: dataclasses.asdict(c)
                            for p, c in sorted(self.assignments.items())},
            "meta": dict(self.meta),
        }

    @classmethod
    def from_json_dict(cls, d: Mapping[str, Any]) -> "QuantPlan":
        return cls(
            assignments={p: LayerChoice(**c)
                         for p, c in d.get("assignments", {}).items()},
            default=LayerChoice(**d["default"]),
            method=d.get("method", "qera_approx"),
            meta=dict(d.get("meta", {})))

    def save(self, path) -> None:
        with open(path, "w", encoding="utf-8") as f:
            json.dump(self.to_json_dict(), f, indent=1, sort_keys=True)

    @classmethod
    def load(cls, path) -> "QuantPlan":
        with open(path, encoding="utf-8") as f:
            return cls.from_json_dict(json.load(f))


def uniform_plan(quantizer: str = "mxint4", rank: int = 32,
                 method: str = "qera_approx") -> QuantPlan:
    """The scalar-PTQConfig operating point as a degenerate plan."""
    return QuantPlan(assignments={}, default=LayerChoice(quantizer, rank),
                     method=method)


# ---------------------------------------------------------------------------
# budget math (mirrors benchmarks/kernel_bench._weight_bytes)
# ---------------------------------------------------------------------------

def choice_bytes(k: int, n: int, choice: LayerChoice, *,
                 lowrank_bytes: int = 4) -> int:
    """Weights-HBM bytes of one (k, n) linear at ``choice``: packed
    mantissas (bits/8 per element), one int8 exponent per block, and the
    two low-rank factors (float32 by default — ``PTQConfig.lowrank_dtype``)."""
    spec = choice.spec()
    mant = k * n * spec.bits // 8
    exp = (k // spec.block_size) * n
    lowrank = (k + n) * choice.rank * lowrank_bytes
    return mant + exp + lowrank


def plan_bytes(shapes: Mapping[str, tuple[int, int]], plan: QuantPlan, *,
               lowrank_bytes: int = 4) -> int:
    """Total weights-HBM bytes of ``plan`` over ``path -> (k, n)`` shapes."""
    return sum(choice_bytes(k, n, plan.choice(p), lowrank_bytes=lowrank_bytes)
               for p, (k, n) in shapes.items())


def eligible_shapes(params: Mapping[str, Any], skips: Callable[[str], bool]
                    ) -> dict[str, tuple[int, int]]:
    """path -> (k, n) of every weight ``quantize_params`` would quantize
    (2-D leaves; stacked 3-D leaves contribute one ``{path}:{i}`` entry per
    slice)."""
    from repro.utils.trees import flatten_dict
    out: dict[str, tuple[int, int]] = {}
    for path, leaf in flatten_dict(dict(params)).items():
        if not hasattr(leaf, "ndim") or skips(path):
            continue
        if leaf.ndim == 2:
            out[path] = (int(leaf.shape[0]), int(leaf.shape[1]))
        elif leaf.ndim == 3:
            for i in range(leaf.shape[0]):
                out[f"{path}:{i}"] = (int(leaf.shape[1]), int(leaf.shape[2]))
    return out


# ---------------------------------------------------------------------------
# per-layer error curves
# ---------------------------------------------------------------------------

def error_curve(w: jax.Array, stats: LayerStats | None, quantizer: str, *,
                method: str = "qera_approx") -> np.ndarray:
    """Cumulative-tail expected-error curve of one layer at one format.

    Returns ``tail`` with ``tail[r] = E(format, rank=r)`` for r in
    [0, min(k, n)]: the energy of ``S (W - W̃)`` not captured by the best
    rank-r reconstruction (paper Eq. 15 under the solver's S-weighting).
    ``S`` follows the solver family: Rxx^{1/2} when full second moments are
    available and ``method`` wants them, diag(sqrt(E[x²])) for the
    qera_approx/lqer scaling (identity when no stats at all — plain Fro).
    """
    w32 = w.astype(jnp.float32)
    spec = MXINT_CONFIGS[quantizer]
    err = w32 - mxint_fake_quant(w32, spec.bits, spec.block_size)
    if method == "qera_exact" and stats is not None and stats.rxx is not None:
        from repro.core.sqrtm import psd_sqrt_eigh
        rxx_sqrt, _ = psd_sqrt_eigh(stats.rxx.astype(jnp.float32),
                                    compute_inverse=False)
        s_err = rxx_sqrt @ err
    elif stats is not None and stats.mean_x2 is not None:
        s = jnp.sqrt(jnp.maximum(stats.mean_x2.astype(jnp.float32), 1e-12))
        s_err = s[:, None] * err
    else:
        s_err = err
    sv = jnp.linalg.svd(s_err, compute_uv=False)
    energy = np.asarray(sv, dtype=np.float64) ** 2
    total = float(energy.sum())
    tail = total - np.concatenate([[0.0], np.cumsum(energy)])
    return np.maximum(tail, 0.0)


def plan_expected_error(params: Mapping[str, Any],
                        stats_by_path: Mapping[str, LayerStats],
                        plan: QuantPlan, *,
                        skips: Callable[[str], bool] | None = None,
                        stats_key_fn: Callable[[str], str] | None = None
                        ) -> float:
    """Summed QERA expected output error of ``plan`` over a params tree —
    the allocator objective evaluated at an arbitrary plan (used by the
    mixed_precision bench to score uniform vs mixed at equal HBM)."""
    from repro.core.api import PTQConfig
    skips = skips or PTQConfig().skips
    stats_key_fn = stats_key_fn or (lambda p: p)
    weights = _eligible_weights(params, skips)
    total = 0.0
    for path, w in weights.items():
        c = plan.choice(path)
        curve = _stacked_curve(path, w, stats_by_path, stats_key_fn,
                               c.quantizer, plan.method)
        total += float(curve[min(c.rank, len(curve) - 1)])
    return total


def _eligible_weights(params: Mapping[str, Any],
                      skips: Callable[[str], bool]) -> dict[str, jax.Array]:
    """path -> 2-D or 3-D weight leaf.  Stacked (scanned) 3-D leaves stay
    WHOLE: all slices of one stacked leaf must share a choice (mant/exp/lora
    shapes must stack), so the allocator decides them jointly."""
    from repro.utils.trees import flatten_dict
    out: dict[str, jax.Array] = {}
    for path, leaf in flatten_dict(dict(params)).items():
        if not hasattr(leaf, "ndim") or skips(path):
            continue
        if leaf.ndim in (2, 3):
            out[path] = leaf
    return out


def _stacked_curve(path, w, stats_by_path, stats_key_fn, fmt, method):
    """Summed error curve over a leaf's slices (one slice for 2-D)."""
    if w.ndim == 2:
        st = _stats_for(stats_by_path, stats_key_fn, path)
        return error_curve(w, st, fmt, method=method)
    curves = []
    for i in range(w.shape[0]):
        st = _stats_for(stats_by_path, stats_key_fn, f"{path}:{i}")
        curves.append(error_curve(w[i], st, fmt, method=method))
    return np.sum(curves, axis=0)


def _stats_for(stats_by_path, stats_key_fn, path):
    if ":" in path:
        base, i = path.rsplit(":", 1)
        return (stats_by_path.get(f"{stats_key_fn(base)}:{i}")
                or stats_by_path.get(stats_key_fn(base)))
    return stats_by_path.get(stats_key_fn(path))


# ---------------------------------------------------------------------------
# the allocator
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class _Candidate:
    choice: LayerChoice
    bytes: int
    error: float


def _layer_candidates(path: str, w, stats_by_path, stats_key_fn, *,
                      formats: Iterable[str], ranks: Iterable[int],
                      method: str, lowrank_bytes: int) -> list[_Candidate]:
    k, n = int(w.shape[-2]), int(w.shape[-1])
    mult = int(w.shape[0]) if w.ndim == 3 else 1
    out: list[_Candidate] = []
    for fmt in formats:
        spec = MXINT_CONFIGS[fmt]
        if k % spec.block_size:
            continue                 # unpackable at this format: skip
        curve = _stacked_curve(path, w, stats_by_path, stats_key_fn, fmt,
                               method)
        for r in ranks:
            if r >= min(k, n):
                continue
            out.append(_Candidate(LayerChoice(fmt, r),
                                  mult * choice_bytes(k, n, LayerChoice(fmt, r),
                                                      lowrank_bytes=lowrank_bytes),
                                  float(curve[r])))
    return out


def allocate_plan(params: Mapping[str, Any],
                  stats_by_path: Mapping[str, LayerStats] | None = None, *,
                  budget_bytes: int | None = None,
                  reference: LayerChoice = LayerChoice("mxint4", 32),
                  formats: Iterable[str] = DEFAULT_FORMATS,
                  ranks: Iterable[int] = DEFAULT_RANKS,
                  method: str = "qera_approx",
                  skips: Callable[[str], bool] | None = None,
                  stats_key_fn: Callable[[str], str] | None = None,
                  lowrank_bytes: int = 4) -> QuantPlan:
    """Minimize summed QERA expected output error under a weights-HBM
    budget.

    ``budget_bytes`` defaults to the bytes the uniform ``reference``
    operating point spends — "same HBM as uniform mxint4/r32, spent
    better".  Layers whose K no candidate format divides keep the
    reference choice (they stay fake-quant in ``pack_for_serving`` anyway)
    and are charged outside the optimization.

    Deterministic: candidate order, the λ bisection, and the greedy refill
    are all fixed functions of (params, stats, arguments).
    """
    from repro.core.api import PTQConfig
    skips = skips or PTQConfig().skips
    stats_key_fn = stats_key_fn or (lambda p: p)
    ranks = tuple(sorted(set(int(r) for r in ranks)))
    formats = tuple(formats)

    weights = _eligible_weights(params, skips)
    paths = sorted(weights)
    cands: dict[str, list[_Candidate]] = {}
    fixed: dict[str, LayerChoice] = {}
    fixed_bytes = 0
    def ref_bytes(w) -> int:
        mult = int(w.shape[0]) if w.ndim == 3 else 1
        return mult * choice_bytes(int(w.shape[-2]), int(w.shape[-1]),
                                   reference, lowrank_bytes=lowrank_bytes)

    for p in paths:
        w = weights[p]
        cs = _layer_candidates(p, w, stats_by_path or {}, stats_key_fn,
                               formats=formats, ranks=ranks, method=method,
                               lowrank_bytes=lowrank_bytes)
        if not cs:
            fixed[p] = reference
            fixed_bytes += ref_bytes(w)
            continue
        cands[p] = cs

    if budget_bytes is None:
        budget_bytes = sum(ref_bytes(weights[p]) for p in paths)
    budget = budget_bytes - fixed_bytes

    def pick_at(lam: float) -> dict[str, _Candidate]:
        out = {}
        for p, cs in cands.items():
            out[p] = min(cs, key=lambda c: (c.error + lam * c.bytes,
                                            c.bytes))
        return out

    def total_bytes(sel: dict[str, _Candidate]) -> int:
        return sum(c.bytes for c in sel.values())

    # λ = 0 is "spend freely"; if even that fits, it is optimal.
    sel = pick_at(0.0)
    if total_bytes(sel) > budget:
        lo, hi = 0.0, 1e-12
        while total_bytes(pick_at(hi)) > budget:
            hi *= 4.0
            if hi > 1e12:
                break
        for _ in range(80):                      # bisect λ
            mid = 0.5 * (lo + hi)
            if total_bytes(pick_at(mid)) > budget:
                lo = mid
            else:
                hi = mid
        sel = pick_at(hi)
        if total_bytes(sel) > budget:            # no feasible λ: all-min
            sel = {p: min(cs, key=lambda c: (c.bytes, c.error))
                   for p, cs in cands.items()}

    # greedy refill: spend leftover budget on the best error/byte upgrade
    while True:
        spent = total_bytes(sel)
        best = None                              # (gain_rate, -gain, path, cand)
        for p in sorted(cands):
            cur = sel[p]
            for c in cands[p]:
                extra = c.bytes - cur.bytes
                gain = cur.error - c.error
                if gain <= 0 or spent + extra > budget:
                    continue
                rate = gain / max(extra, 1)
                if best is None or rate > best[0] + 1e-18:
                    best = (rate, gain, p, c)
        if best is None:
            break
        sel[best[2]] = best[3]

    assignments = {p: c.choice for p, c in sel.items()}
    assignments.update(fixed)
    expected = sum(c.error for c in sel.values())
    return QuantPlan(
        assignments=assignments, default=reference, method=method,
        meta={"budget_bytes": int(budget_bytes),
              "plan_bytes": int(total_bytes(sel) + fixed_bytes),
              "expected_error": float(expected),
              "formats": list(formats), "ranks": list(ranks),
              "fixed_paths": sorted(fixed)})


def mixed_reference_plan() -> QuantPlan:
    """A deterministic heterogeneous plan keyed by PROJECTION ROLE
    (``analysis.contracts.projection_dims`` names), not param paths — the
    static analysis sweep's stand-in for a calibrated plan: every registry
    arch gets audited under per-leaf heterogeneous contracts without
    needing weights or stats.  The shape mirrors what calibrated
    allocations actually produce: attention out/down projections (the
    saliency-heavy ones in SERQ's measurements) ride high-bit/low-rank,
    the wide FFN in-projections absorb the budget cut."""
    return QuantPlan(
        assignments={
            "wq": LayerChoice("mxint4", 32),
            "wk": LayerChoice("mxint8", 16),
            "wv": LayerChoice("mxint8", 16),
            "wo": LayerChoice("mxint8", 32),
            "wi": LayerChoice("mxint3", 64),
            "wg": LayerChoice("mxint3", 64),
            "wu": LayerChoice("mxint3", 64),
            "wd": LayerChoice("mxint4", 32),
            "lm_head": LayerChoice("mxint8", 16),
        },
        default=LayerChoice("mxint4", 32))


# ---------------------------------------------------------------------------
# packed-tree introspection (snapshot round-trip validation)
# ---------------------------------------------------------------------------

def describe_packed_plan(params: Any) -> dict[str, dict[str, int]]:
    """Derive the *effective* plan of a packed/quantized params tree:
    ``path -> {"bits", "block_size", "rank"}`` for packed leaves,
    ``{"rank"}`` for fake-quant leaves.  Two serving trees agree on
    precision layout iff their descriptions are equal — what
    ``serve/supervisor.py`` stores in (and checks against) snapshots so a
    mixed-precision server round-trips exactly."""
    from repro.utils.trees import flatten_dict
    out: dict[str, dict[str, int]] = {}
    flat = flatten_dict(dict(params)) if isinstance(params, Mapping) else {}
    for path, leaf in flat.items():
        parent, _, last = path.rpartition("/")
        if last == "mant":
            k = parent or path
            bits = int(np.asarray(jax.device_get(flat[f"{parent}/bits"]))
                       .reshape(-1)[0])
            bs = int(np.asarray(jax.device_get(flat[f"{parent}/block_size"]))
                     .reshape(-1)[0])
            d = out.setdefault(k, {})
            d["bits"], d["block_size"] = bits, bs
        elif last == "w_tilde":
            out.setdefault(parent or path, {})
        elif last == "lora_a":
            out.setdefault(parent or path, {})["rank"] = int(
                leaf.shape[-1])
    return out
