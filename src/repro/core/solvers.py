"""Closed-form and baseline solvers for the quantization-error-reconstruction
problem  min  E_x || x(W̃ + A_k B_k) − xW ||².

Conventions (paper §3.1): W ∈ R^{m×n} with *row-vector* inputs x ∈ R^m,
A_k ∈ R^{m×k}, B_k ∈ R^{k×n}.  Every solver returns (A_k, B_k) except LoftQ,
which also re-quantizes W and returns (W̃, A_k, B_k).

Implemented methods
  qera_exact     Theorem 1   C_k = (R^(1/2))^{-1} SVD_k(R^(1/2) (W−W̃))
  qera_approx    Theorem 2   C_k = S^{-1} SVD_k(S (W−W̃)), S = diag(√E[x²])
  lqer           Zhang'24    same form, S = diag(E[|x|])   (heuristic)
  zeroquant_v2   Yao'23      S = I  (plain weight-error SVD)
  loftq          Li'23       iterative q/SVD  (Algorithm 1)
  qlora          Dettmers'23 A ~ N(0, σ), B = 0 (LoRA init; no reconstruction)
"""

from __future__ import annotations

from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp

from repro.core.svd import svd_lowrank
from repro.core.sqrtm import psd_sqrt_eigh, psd_sqrt_newton_schulz


# ----------------------------------------------------------------------------
# Objective helpers
# ----------------------------------------------------------------------------

def expected_output_error(p: jax.Array, rxx: jax.Array) -> jax.Array:
    """E_x ||xP||² = Tr(R_XX P Pᵀ)  (paper Eq. 15). p = W̃ + C_k − W."""
    return jnp.trace(rxx @ (p @ p.T))


def empirical_output_error(x: jax.Array, p: jax.Array) -> jax.Array:
    """Sample-mean of ||xP||² over rows of x."""
    e = x @ p
    return jnp.mean(jnp.sum(e * e, axis=-1))


# ----------------------------------------------------------------------------
# Scaled-SVD core shared by qera_approx / lqer / zeroquant
# ----------------------------------------------------------------------------

def _scaled_svd_solver(err: jax.Array, s_diag: jax.Array, k: int,
                       svd_method: str = "exact",
                       key: jax.Array | None = None):
    """A = S^{-1} U_k, B = Σ_k V_kᵀ for U Σ Vᵀ = SVD(S · err)."""
    scaled = s_diag[:, None] * err
    u, sv, vt = svd_lowrank(scaled, k, method=svd_method, key=key)
    a = u / s_diag[:, None]
    b = sv[:, None] * vt
    return a, b


def solve_zeroquant_v2(w: jax.Array, w_tilde: jax.Array, k: int, *,
                       svd_method: str = "exact", key=None):
    err = (w - w_tilde).astype(jnp.float32)
    ones = jnp.ones(w.shape[0], jnp.float32)
    return _scaled_svd_solver(err, ones, k, svd_method, key)


def solve_lqer(w: jax.Array, w_tilde: jax.Array, k: int, mean_abs: jax.Array, *,
               eps: float = 1e-6, svd_method: str = "exact", key=None):
    err = (w - w_tilde).astype(jnp.float32)
    s = jnp.maximum(mean_abs.astype(jnp.float32), eps)
    return _scaled_svd_solver(err, s, k, svd_method, key)


def solve_qera_approx(w: jax.Array, w_tilde: jax.Array, k: int,
                      mean_x2: jax.Array, *, eps: float = 1e-12,
                      svd_method: str = "exact", key=None):
    err = (w - w_tilde).astype(jnp.float32)
    s = jnp.sqrt(jnp.maximum(mean_x2.astype(jnp.float32), eps))
    return _scaled_svd_solver(err, s, k, svd_method, key)


def solve_qera_exact(w: jax.Array, w_tilde: jax.Array, k: int, rxx: jax.Array, *,
                     eps: float = 1e-8, sqrt_method: str = "eigh",
                     svd_method: str = "exact", key=None):
    """Theorem 1.  sqrt_method: 'eigh' (exact) or 'newton_schulz' (MXU-native)."""
    err = (w - w_tilde).astype(jnp.float32)
    rxx = rxx.astype(jnp.float32)
    if sqrt_method == "eigh":
        sqrt, inv_sqrt = psd_sqrt_eigh(rxx, eps=eps)
    elif sqrt_method == "newton_schulz":
        sqrt, inv_sqrt = psd_sqrt_newton_schulz(rxx, eps=eps)
    else:
        raise ValueError(f"unknown sqrt method {sqrt_method!r}")
    u, sv, vt = svd_lowrank(sqrt @ err, k, method=svd_method, key=key)
    a = inv_sqrt @ u
    b = sv[:, None] * vt
    return a, b


def solve_qlora(key: jax.Array, w: jax.Array, k: int, dtype=jnp.float32):
    """LoRA/QLoRA init: A ~ N(0, 1/m) Gaussian, B = 0 — no error reconstruction."""
    m, n = w.shape
    a = jax.random.normal(key, (m, k), dtype) / jnp.sqrt(jnp.asarray(m, dtype))
    b = jnp.zeros((k, n), dtype)
    return a, b


def solve_loftq(w: jax.Array, quant_fn: Callable[[jax.Array], jax.Array], k: int,
                iters: int = 5, svd_method: str = "exact", key=None):
    """LoftQ (Algorithm 1): alternate  W̃ = dq(q(W − A B))  and
    (A, B) <- SVD_k(W − W̃).  Returns (w_tilde, A, B)."""
    w = w.astype(jnp.float32)
    m, n = w.shape
    a = jnp.zeros((m, k), jnp.float32)
    b = jnp.zeros((k, n), jnp.float32)
    w_tilde = w
    for _ in range(iters):
        w_tilde = quant_fn(w - a @ b)
        u, sv, vt = svd_lowrank(w - w_tilde, k, method=svd_method, key=key)
        sq = jnp.sqrt(sv)
        a = u * sq[None, :]
        b = sq[:, None] * vt
    return w_tilde, a, b


# ----------------------------------------------------------------------------
# Registry
# ----------------------------------------------------------------------------

METHODS = ("qera_exact", "qera_approx", "lqer", "zeroquant_v2", "loftq", "qlora")


def solve(method: str, w, w_tilde, k, *, stats=None, quant_fn=None,
          key=None, svd_method: str = "exact", sqrt_method: str = "eigh",
          loftq_iters: int = 5):
    """Uniform entry point.  Returns (w_tilde, A, B) for every method
    (LoftQ may replace w_tilde; others pass it through)."""
    if method == "qera_exact":
        if stats is None or stats.rxx is None:
            raise ValueError("qera_exact needs LayerStats with rxx")
        a, b = solve_qera_exact(w, w_tilde, k, stats.rxx, sqrt_method=sqrt_method,
                                svd_method=svd_method, key=key)
    elif method == "qera_approx":
        if stats is None:
            raise ValueError("qera_approx needs LayerStats (mean_x2)")
        a, b = solve_qera_approx(w, w_tilde, k, stats.mean_x2,
                                 svd_method=svd_method, key=key)
    elif method == "lqer":
        if stats is None:
            raise ValueError("lqer needs LayerStats (mean_abs)")
        a, b = solve_lqer(w, w_tilde, k, stats.mean_abs,
                          svd_method=svd_method, key=key)
    elif method == "zeroquant_v2":
        a, b = solve_zeroquant_v2(w, w_tilde, k, svd_method=svd_method, key=key)
    elif method == "loftq":
        if quant_fn is None:
            raise ValueError("loftq needs quant_fn")
        w_tilde, a, b = solve_loftq(w, quant_fn, k, iters=loftq_iters,
                                    svd_method=svd_method, key=key)
    elif method == "qlora":
        if key is None:
            key = jax.random.PRNGKey(0)
        a, b = solve_qlora(key, w, k)
    else:
        raise KeyError(f"unknown method {method!r}; choose from {METHODS}")
    return w_tilde, a, b
