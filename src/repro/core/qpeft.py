"""QPEFT: quantized parameter-efficient fine-tuning (the paper's §4.2 side).

Pipeline: quantize_params() replaces every linear with
{"w_tilde", "lora_a", "lora_b"}; here we freeze everything except the
adapters (+ any extra patterns, e.g. a classifier head) and train only those
— QLoRA/LoftQ/QERA differ ONLY in the (A, B) initialization, which is
exactly the paper's experimental contrast.
"""

from __future__ import annotations

import re
from typing import Any, Callable, Mapping

import jax

from repro.train.optimizer import (
    OptimizerConfig,
    adamw_update,
    init_opt_state,
    make_schedule,
)
from repro.utils.trees import flatten_dict, unflatten_dict

TRAINABLE_DEFAULT = (r"lora_a$", r"lora_b$", r"classifier")


def split_trainable(params: Mapping[str, Any],
                    patterns: tuple[str, ...] = TRAINABLE_DEFAULT):
    flat = flatten_dict(dict(params))
    train = {k: v for k, v in flat.items()
             if any(re.search(p, k) for p in patterns)}
    frozen = {k: v for k, v in flat.items() if k not in train}
    return train, frozen


def merge_params(train: Mapping[str, Any], frozen: Mapping[str, Any]):
    return unflatten_dict({**dict(frozen), **dict(train)})


def make_qpeft_step(loss_fn: Callable, opt_cfg: OptimizerConfig,
                    frozen: Mapping[str, Any]) -> Callable:
    """loss_fn(full_params, batch) -> (loss, aux).  Returns
    step(train_params, opt_state, batch) -> (train_params, opt_state, metrics)
    updating ONLY the trainable subset."""
    schedule = make_schedule(opt_cfg)

    def step(train, opt_state, batch):
        def wrapped(tr):
            return loss_fn(merge_params(tr, frozen), batch)

        (loss, aux), grads = jax.value_and_grad(wrapped, has_aux=True)(train)
        train, opt_state, om = adamw_update(train, grads, opt_state, opt_cfg,
                                            schedule)
        return train, opt_state, {"loss": loss, "aux": aux, **om}

    return step


def qpeft_finetune(params_q: Mapping[str, Any], loss_fn: Callable,
                   batches, opt_cfg: OptimizerConfig,
                   patterns: tuple[str, ...] = TRAINABLE_DEFAULT,
                   eval_fn: Callable | None = None,
                   log_every: int = 0):
    """Run adapter-only fine-tuning over an iterable of batches.

    Returns (final_full_params, losses)."""
    train, frozen = split_trainable(params_q, patterns)
    step = jax.jit(make_qpeft_step(loss_fn, opt_cfg, frozen),
                   donate_argnums=(0, 1))
    opt_state = init_opt_state(train)
    losses = []
    for i, batch in enumerate(batches):
        train, opt_state, m = step(train, opt_state, batch)
        losses.append(float(m["loss"]))
        if log_every and i % log_every == 0:
            print(f"  qpeft step {i}: loss {losses[-1]:.4f}")
    return merge_params(train, frozen), losses
