"""PSD matrix square roots — the numerical core of QERA-exact.

The paper computes ``R_XX^(1/2)`` with SciPy's blocked-Schur algorithm on CPU
(Appendix A.4/A.7) and names accelerator-side sqrtm as the key missing
optimization.  TPU adaptation (DESIGN.md §3): R_XX is symmetric PSD, so

* ``psd_sqrt_eigh``      — exact sqrt/inv-sqrt via eigendecomposition (XLA eigh);
* ``psd_sqrt_newton_schulz`` — Denman–Beavers/Newton–Schulz coupled iteration,
  matmul-only (MXU-friendly, shardable under pjit), with spectral-norm
  pre-scaling for convergence.

Both return (sqrt, inv_sqrt); the inverse is Tikhonov-damped with ``eps``
(paper Remark 1: add a small diagonal perturbation to recover invertibility).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp


def _symmetrize(a: jax.Array) -> jax.Array:
    return 0.5 * (a + a.T)


@partial(jax.jit, static_argnames=("compute_inverse",))
def psd_sqrt_eigh(r: jax.Array, eps: float = 1e-8, compute_inverse: bool = True):
    """Exact PSD sqrt via eigh.  Eigenvalues are clamped at ``eps * max_eig``."""
    r = _symmetrize(r)
    w, v = jnp.linalg.eigh(r)
    floor = jnp.maximum(w[-1], 0.0) * eps + jnp.finfo(r.dtype).tiny
    w = jnp.maximum(w, floor)
    sw = jnp.sqrt(w)
    sqrt = (v * sw) @ v.T
    if not compute_inverse:
        return sqrt, None
    inv_sqrt = (v / sw) @ v.T
    return sqrt, inv_sqrt


@partial(jax.jit, static_argnames=("num_iters",))
def psd_sqrt_newton_schulz(r: jax.Array, num_iters: int = 30, eps: float = 1e-8):
    """Coupled Newton–Schulz iteration for (sqrt, inv-sqrt) of a PSD matrix.

    Y_{k+1} = Y_k (3I - Z_k Y_k) / 2,  Z_{k+1} = (3I - Z_k Y_k) Z_k / 2
    with Y_0 = R / ||R||_F, Z_0 = I; converges when ||I - R/||R||_F|| < 1,
    guaranteed for the Frobenius pre-scaling.  Pure matmuls: lowers to MXU
    dots and shards cleanly (each step is 2 GEMMs).
    """
    r = _symmetrize(r.astype(jnp.float32))
    n = r.shape[0]
    ident = jnp.eye(n, dtype=r.dtype)
    r = r + eps * jnp.trace(r) / n * ident  # Tikhonov damping
    norm = jnp.linalg.norm(r)
    y = r / norm
    z = ident

    def body(_, yz):
        y, z = yz
        t = 0.5 * (3.0 * ident - z @ y)
        return (y @ t, t @ z)

    y, z = jax.lax.fori_loop(0, num_iters, body, (y, z))
    s = jnp.sqrt(norm)
    return y * s, z / s
