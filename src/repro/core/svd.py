"""Truncated and randomized SVD.

QERA needs only the top-k factors of (scaled) weight-error matrices with
k <= 64 << min(m, n).  Dense SVD is O(mn·min(m,n)); the randomized (Halko)
sketch is O(mnk) of *matmul* work — the TPU-native choice (DESIGN.md §3).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp


@partial(jax.jit, static_argnames=("k",))
def truncated_svd(a: jax.Array, k: int):
    """Exact top-k SVD factors: returns (U_k, s_k, Vt_k)."""
    u, s, vt = jnp.linalg.svd(a, full_matrices=False)
    return u[:, :k], s[:k], vt[:k, :]


@partial(jax.jit, static_argnames=("k", "oversample", "power_iters"))
def randomized_svd(a: jax.Array, k: int, *, key: jax.Array,
                   oversample: int = 8, power_iters: int = 2):
    """Halko-style randomized top-k SVD.

    sketch = A @ Omega (m×n · n×(k+p)); optional power iterations
    (A Aᵀ)^q sharpen the spectrum; QR orthonormalizes; small SVD finishes.
    All heavy ops are GEMMs -> MXU.
    """
    m, n = a.shape
    p = min(k + oversample, min(m, n))
    omega = jax.random.normal(key, (n, p), dtype=a.dtype)
    y = a @ omega
    for _ in range(power_iters):
        y = a @ (a.T @ y)
        y, _ = jnp.linalg.qr(y)
    q, _ = jnp.linalg.qr(y)
    b = q.T @ a                      # (p, n) — small
    ub, s, vt = jnp.linalg.svd(b, full_matrices=False)
    u = q @ ub
    return u[:, :k], s[:k], vt[:k, :]


def svd_lowrank(a: jax.Array, k: int, *, method: str = "exact",
                key: jax.Array | None = None):
    """Dispatcher used by the solvers."""
    if method == "exact":
        return truncated_svd(a, k)
    if method == "randomized":
        if key is None:
            key = jax.random.PRNGKey(0)
        return randomized_svd(a, k, key=key)
    raise ValueError(f"unknown svd method {method!r}")
