"""Streaming activation calibration for QERA.

Per linear layer we accumulate, over a calibration stream of row-vector
inputs x ∈ R^m (tokens × features):

* ``sum_xx``  = Σ xᵀx          -> R_XX  = E[xᵀx]        (QERA-exact)
* ``sum_x2``  = Σ x∘x          -> E[x²] -> S = diag(√E[x²]) (QERA-approx)
* ``sum_abs`` = Σ |x|          -> E[|x|]                 (LQER heuristic)

Following the paper's numerics recipe (Appendix A.7): outer products are
computed in FP32 *in-graph*, cross-batch accumulation happens in FP64 on the
host.  Batch-level stats are jittable/pjit-able (layer-parallel calibration —
the paper notes per-layer independence allows full parallelization).
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np


@partial(jax.jit, static_argnames=("with_outer",))
def batch_stats(x: jax.Array, with_outer: bool = True):
    """Stats of one batch. x: (..., m) — leading dims are flattened as tokens."""
    x = x.reshape(-1, x.shape[-1]).astype(jnp.float32)
    count = jnp.asarray(x.shape[0], jnp.float32)
    sum_x2 = jnp.sum(x * x, axis=0)
    sum_abs = jnp.sum(jnp.abs(x), axis=0)
    sum_xx = x.T @ x if with_outer else None
    return dict(count=count, sum_x2=sum_x2, sum_abs=sum_abs, sum_xx=sum_xx)


@dataclasses.dataclass
class StreamingStats:
    """Host-side FP64 accumulator (one per layer input)."""

    dim: int
    with_outer: bool = True
    count: float = 0.0
    sum_x2: np.ndarray | None = None
    sum_abs: np.ndarray | None = None
    sum_xx: np.ndarray | None = None

    def __post_init__(self):
        self.sum_x2 = np.zeros(self.dim, np.float64)
        self.sum_abs = np.zeros(self.dim, np.float64)
        self.sum_xx = np.zeros((self.dim, self.dim), np.float64) if self.with_outer else None

    def update(self, x: jax.Array) -> None:
        s = batch_stats(x, with_outer=self.with_outer)
        self.count += float(s["count"])
        self.sum_x2 += np.asarray(s["sum_x2"], np.float64)
        self.sum_abs += np.asarray(s["sum_abs"], np.float64)
        if self.with_outer:
            self.sum_xx += np.asarray(s["sum_xx"], np.float64)

    def merge(self, other: "StreamingStats") -> "StreamingStats":
        assert self.dim == other.dim and self.with_outer == other.with_outer
        self.count += other.count
        self.sum_x2 += other.sum_x2
        self.sum_abs += other.sum_abs
        if self.with_outer:
            self.sum_xx += other.sum_xx
        return self

    # -- finalized statistics ------------------------------------------------
    @property
    def mean_x2(self) -> np.ndarray:
        return self.sum_x2 / max(self.count, 1.0)

    @property
    def mean_abs(self) -> np.ndarray:
        return self.sum_abs / max(self.count, 1.0)

    @property
    def rxx(self) -> np.ndarray:
        if self.sum_xx is None:
            raise ValueError("outer-product accumulation disabled")
        r = self.sum_xx / max(self.count, 1.0)
        return 0.5 * (r + r.T)

    def as_layer_stats(self) -> "LayerStats":
        return LayerStats(
            mean_x2=jnp.asarray(self.mean_x2, jnp.float32),
            mean_abs=jnp.asarray(self.mean_abs, jnp.float32),
            rxx=None if self.sum_xx is None else jnp.asarray(self.rxx, jnp.float32),
            count=self.count,
        )


@dataclasses.dataclass
class LayerStats:
    """Finalized per-layer calibration statistics (device arrays)."""
    mean_x2: jax.Array            # (m,)  E[x_i^2]
    mean_abs: jax.Array           # (m,)  E[|x_i|]
    rxx: jax.Array | None         # (m, m) E[x^T x] or None
    count: float = 0.0


def stats_from_samples(x: jax.Array, with_outer: bool = True) -> LayerStats:
    """One-shot LayerStats from an in-memory sample matrix (tests/benches)."""
    acc = StreamingStats(dim=x.shape[-1], with_outer=with_outer)
    acc.update(x)
    return acc.as_layer_stats()
