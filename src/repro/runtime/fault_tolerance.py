"""Fault tolerance & straggler mitigation for the training runtime.

On a real 1000-node fleet these hooks bind to the cluster manager; here the
logic is complete and unit-tested against simulated clocks/failures:

* ``StragglerMonitor`` — per-host step-time EMA; flags hosts slower than
  ``threshold`` x the fleet median (the data-loader prefetch + within-step
  collectives hide flagged hosts until the scheduler replaces them).
* ``RestartPolicy`` — bounded restarts with exponential backoff.
* ``ElasticPlan`` — given a surviving device count, picks the largest valid
  (data, model) mesh <= survivors and rescales batch/microbatching; paired
  with the mesh-agnostic checkpoint restore this is elastic scaling.
* ``run_with_restarts`` — drives a train loop through injected failures,
  restoring from the newest checkpoint each time (tested for bitwise-equal
  resume in tests/test_train_runtime.py).
"""

from __future__ import annotations

import dataclasses
import random
import time
from collections import defaultdict
from typing import Callable


@dataclasses.dataclass
class StragglerMonitor:
    threshold: float = 1.5        # x median EMA
    alpha: float = 0.2            # EMA coefficient
    warmup_steps: int = 3

    def __post_init__(self):
        self.ema: dict[str, float] = {}
        self.counts: dict[str, int] = defaultdict(int)

    def record(self, host: str, step_seconds: float) -> None:
        prev = self.ema.get(host)
        self.ema[host] = (step_seconds if prev is None
                          else (1 - self.alpha) * prev + self.alpha * step_seconds)
        self.counts[host] += 1

    def median(self) -> float:
        vals = sorted(self.ema.values())
        if not vals:
            return 0.0
        mid = len(vals) // 2
        # even length: mean of the two middle elements (the upper-middle
        # alone biases the fleet median high, under-flagging stragglers)
        return vals[mid] if len(vals) % 2 else 0.5 * (vals[mid - 1] + vals[mid])

    def stragglers(self) -> list[str]:
        med = self.median()
        if med <= 0:
            return []
        return [h for h, v in self.ema.items()
                if self.counts[h] >= self.warmup_steps
                and v > self.threshold * med]


@dataclasses.dataclass
class RestartPolicy:
    max_restarts: int = 5
    backoff_base_s: float = 0.01
    backoff_cap_s: float = 1.0
    # deterministic jitter: +-jitter fraction around the capped delay, keyed
    # by (seed, attempt) so a fleet of restarters with distinct seeds
    # de-synchronizes (no thundering herd) while each individual schedule
    # stays reproducible.  Default 0.0 = exact exponential backoff.
    jitter: float = 0.0
    seed: int = 0

    def backoff(self, attempt: int) -> float:
        delay = min(self.backoff_base_s * (2 ** attempt), self.backoff_cap_s)
        if self.jitter:
            u = random.Random(self.seed * 1_000_003 + attempt).uniform(-1, 1)
            delay = max(0.0, delay * (1.0 + self.jitter * u))
        return delay


@dataclasses.dataclass(frozen=True)
class ElasticPlan:
    data: int
    model: int
    global_batch: int
    microbatches: int

    @property
    def devices(self) -> int:
        return self.data * self.model


def plan_elastic(survivors: int, *, model_parallel: int,
                 global_batch: int, tokens_budget: int = 1 << 30,
                 seq_len: int = 1) -> ElasticPlan:
    """Largest power-of-two data axis that fits the survivors, keeping TP
    fixed (weights layout unchanged => cheapest re-shard on restore)."""
    assert survivors >= model_parallel, "fewer survivors than TP degree"
    data = 1
    while data * 2 * model_parallel <= survivors and \
            global_batch % (data * 2) == 0:
        data *= 2
    b_loc = global_batch // data
    mb = 1
    while b_loc % (mb * 2) == 0 and (b_loc // mb) * seq_len > tokens_budget:
        mb *= 2
    return ElasticPlan(data=data, model=model_parallel,
                       global_batch=global_batch, microbatches=mb)


class SimulatedFailure(RuntimeError):
    pass


def run_with_restarts(train_loop: Callable[[int], int], *,
                      restore_step: Callable[[], int],
                      policy: RestartPolicy | None = None,
                      sleep: Callable[[float], None] = time.sleep) -> int:
    """Run ``train_loop(start_step) -> final_step``, restarting from the
    latest checkpoint on failure.  Returns the final step reached."""
    policy = policy or RestartPolicy()
    attempt = 0
    while True:
        start = restore_step()
        try:
            return train_loop(start)
        except SimulatedFailure:
            attempt += 1
            if attempt > policy.max_restarts:
                raise
            sleep(policy.backoff(attempt))
