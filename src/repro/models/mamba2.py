"""Mamba2 (SSD) block — chunked-parallel scan, TPU-friendly.

State-space duality formulation (Dao & Gu 2024), minimal but faithful:

  h_t = exp(dt_t·A_head) · h_{t-1} + dt_t · B_t ⊗ x_t      (state (P, N))
  y_t = C_t · h_t + D_head · x_t

Chunked algorithm (chunk length Lc): within a chunk the output is an
attention-like masked matmul with cumulative-decay weights (MXU work); the
inter-chunk state is carried by a lax.scan — O(S·Lc) instead of O(S²),
numerically safe because all exponents are differences of a monotone
cumulative sum (≤ 0).

Shapes: d_inner = expand·d_model, P = ssm_head_dim, H = d_inner/P,
N = ssm_state, G (B/C groups) = 1.
"""

from __future__ import annotations

from typing import Any, Mapping

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import linear, rms_norm


def mamba2_param_shapes(cfg: ModelConfig) -> dict[str, tuple]:
    """Projections are SPLIT per segment (z/x/B/C/dt) instead of one fused
    in_proj: each output axis then has a single logical meaning and shards
    cleanly under TP (fused axes would mix segments across model shards)."""
    d, di, n, h = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    w = cfg.ssm_conv_width
    return {
        "w_z": (d, di),
        "w_x": (d, di),
        "w_b": (d, n),
        "w_c": (d, n),
        "w_dt": (d, h),
        "conv_x": (w, di),
        "conv_b": (w, n),
        "conv_c": (w, n),
        "a_log": (h,),
        "dt_bias": (h,),
        "d_skip": (h,),
        "gate_norm": (di,),
        "out_proj": (di, d),
    }


def _causal_conv(x: jax.Array, w: jax.Array,
                 state: jax.Array | None = None):
    """Depthwise causal conv, width W.  x: (B, S, C); w: (W, C).

    state: (B, W-1, C) previous inputs (decode) — returns (y, new_state).
    """
    width = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], width - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)          # (B, S+W-1, C)
    y = sum(xp[:, i:i + x.shape[1], :] * w[i] for i in range(width))
    new_state = xp[:, -(width - 1):, :]
    return y, new_state


def _projections(p, x, cfg: ModelConfig, cache, taps, prefix, use_pallas,
                 constrain=None):
    """Split z/x/B/C/dt projections + per-segment causal convs."""
    if constrain is not None:
        x = constrain(x, ("dp", None, None))
    z = linear(p["w_z"], x, taps=taps, name=f"{prefix}w_z", use_pallas=use_pallas)
    xr = linear(p["w_x"], x, taps=taps, name=f"{prefix}w_x", use_pallas=use_pallas)
    br = linear(p["w_b"], x, taps=taps, name=f"{prefix}w_b", use_pallas=use_pallas)
    cr = linear(p["w_c"], x, taps=taps, name=f"{prefix}w_c", use_pallas=use_pallas)
    dt = linear(p["w_dt"], x, taps=taps, name=f"{prefix}w_dt", use_pallas=use_pallas)
    if constrain is not None:
        z = constrain(z, ("dp", None, "model"))
        xr = constrain(xr, ("dp", None, "model"))
        br = constrain(br, ("dp", None, None))
        cr = constrain(cr, ("dp", None, None))
    cs = {} if cache is None else cache
    xc, st_x = _causal_conv(xr, p["conv_x"], cs.get("conv_x"))
    bc, st_b = _causal_conv(br, p["conv_b"], cs.get("conv_b"))
    cc, st_c = _causal_conv(cr, p["conv_c"], cs.get("conv_c"))
    conv_state = {"conv_x": st_x, "conv_b": st_b, "conv_c": st_c}
    return (z, jax.nn.silu(xc), jax.nn.silu(bc), jax.nn.silu(cc), dt,
            conv_state)


def mamba2_block(p: Mapping[str, Any], x: jax.Array, cfg: ModelConfig, *,
                 cache: Mapping[str, jax.Array] | None = None,
                 constrain=None,
                 taps=None, prefix: str = "", use_pallas: bool = False):
    """x: (B, S, D) -> (out, new_cache).
    cache = {"conv_x","conv_b","conv_c","ssm"} for decode."""
    b, s, d = x.shape
    di, n, h, pdim = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim

    z, xs_, bmat, cmat, dt, conv_state = _projections(
        p, x, cfg, cache, taps, prefix, use_pallas, constrain=constrain)
    xin = xs_.reshape(b, s, h, pdim)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    a = -jnp.exp(p["a_log"].astype(jnp.float32))     # (H,) negative
    la = dt * a                                      # (B, S, H) log-decay ≤ 0
    dt_x = (dt[..., None] * xin.astype(jnp.float32))  # (B, S, H, P)

    h0 = (jnp.zeros((b, h, pdim, n), jnp.float32) if cache is None
          else cache["ssm"].astype(jnp.float32))

    lc = max(1, min(cfg.ssm_chunk, s))
    if s % lc:
        lc = 1
    nc = s // lc

    def chunk(carry, xs):
        h_in = carry
        la_c, dtx_c, b_c, c_c = xs        # (Lc,B,H) (Lc,B,H,P) (Lc,B,N) (Lc,B,N)
        cum = jnp.cumsum(la_c, axis=0)    # (Lc, B, H) inclusive
        # intra-chunk: att[t, s'] = (C_t·B_s') exp(cum_t − cum_s'), s' ≤ t
        cb = jnp.einsum("tbn,ubn->tub", c_c, b_c)           # (Lc, Lc, B)
        mask = jnp.tril(jnp.ones((cum.shape[0], cum.shape[0]), bool))
        delta = cum[:, None] - cum[None, :]                 # (Lc, Lc, B, H)
        # mask BEFORE exp: above-diagonal deltas are positive and would
        # overflow; exp(-inf) = 0 kills them exactly.
        delta = jnp.where(mask[:, :, None, None], delta, -jnp.inf)
        w_att = cb[..., None] * jnp.exp(delta)
        y_intra = jnp.einsum("tubh,ubhp->tbhp", w_att, dtx_c)
        # inter-chunk: y_state[t] = exp(cum_t) · C_t · h_in
        y_state = jnp.einsum("tbn,bhpn->tbhp", c_c, h_in) * \
            jnp.exp(cum)[..., None]
        # state update: h_out = exp(cum_L) h_in + Σ exp(cum_L − cum_s) dtx⊗B
        wlast = jnp.exp(cum[-1] - cum)                      # (Lc, B, H)
        dstate = jnp.einsum("tbh,tbhp,tbn->bhpn", wlast, dtx_c, b_c)
        h_out = h_in * jnp.exp(cum[-1])[..., None, None] + dstate
        return h_out, y_intra + y_state

    bm32 = bmat.astype(jnp.float32)
    cm32 = cmat.astype(jnp.float32)
    if cfg.chunk_python_loop:
        # unrolled in HLO so the dry-run cost model sees every chunk; chunks
        # are sliced from the NATURAL (B,S,...) layout (chunk-sized slices +
        # small transposes — avoids per-chunk copies of the stacked array)
        def chunk_at(a, i):
            sl = a[:, i * lc:(i + 1) * lc]
            return jnp.moveaxis(sl, 1, 0)
        h_cur, ys_list = h0, []
        for i in range(nc):
            xs_i = (chunk_at(la, i), chunk_at(dt_x, i),
                    chunk_at(bm32, i), chunk_at(cm32, i))
            h_cur, y_i = chunk(h_cur, xs_i)
            ys_list.append(y_i)
        h_last, ys = h_cur, jnp.stack(ys_list)
    else:
        la_s = la.reshape(b, nc, lc, h)
        dtx_s = dt_x.reshape(b, nc, lc, h, pdim)
        b_s = bm32.reshape(b, nc, lc, n)
        c_s = cm32.reshape(b, nc, lc, n)
        xs = (jnp.moveaxis(la_s, 1, 0).transpose(0, 2, 1, 3),
              jnp.moveaxis(dtx_s, 1, 0).transpose(0, 2, 1, 3, 4),
              jnp.moveaxis(b_s, 1, 0).transpose(0, 2, 1, 3),
              jnp.moveaxis(c_s, 1, 0).transpose(0, 2, 1, 3))
        h_last, ys = jax.lax.scan(chunk, h0, xs)     # ys: (nc, Lc, B, H, P)
    y = jnp.moveaxis(ys.reshape(nc * lc, b, h, pdim), 0, 1)  # (B, S, H, P)

    y = y + p["d_skip"].astype(jnp.float32)[None, None, :, None] * \
        xin.astype(jnp.float32)
    y = y.reshape(b, s, di)
    y = rms_norm(y.astype(x.dtype) * jax.nn.silu(z), p["gate_norm"], cfg.norm_eps)
    out = linear(p["out_proj"], y, taps=taps, name=f"{prefix}out_proj",
                 use_pallas=use_pallas)
    new_cache = None
    if cache is not None:
        new_cache = {
            **{k: v.astype(cache[k].dtype) for k, v in conv_state.items()},
            "ssm": h_last.astype(cache["ssm"].dtype),
        }
    return out, new_cache


def mamba2_block_ref(p: Mapping[str, Any], x: jax.Array, cfg: ModelConfig):
    """Per-timestep scan oracle (tests only)."""
    b, s, d = x.shape
    di, n, h, pdim = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    z, xs_, bmat, cmat, dt, _ = _projections(
        p, x, cfg, None, None, "", False)
    xin = xs_.reshape(b, s, h, pdim).astype(jnp.float32)
    bmat = bmat.astype(jnp.float32)
    cmat = cmat.astype(jnp.float32)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    a = -jnp.exp(p["a_log"].astype(jnp.float32))

    def step(hprev, xs):
        xt, bt, ct, dtt = xs              # (B,H,P) (B,N) (B,N) (B,H)
        decay = jnp.exp(dtt * a)          # (B, H)
        upd = jnp.einsum("bhp,bn->bhpn", dtt[..., None] * xt, bt)
        hnew = hprev * decay[..., None, None] + upd
        yt = jnp.einsum("bhpn,bn->bhp", hnew, ct)
        return hnew, yt

    h0 = jnp.zeros((b, h, pdim, n), jnp.float32)
    _, ys = jax.lax.scan(step, h0, (jnp.moveaxis(xin, 1, 0),
                                    jnp.moveaxis(bmat, 1, 0),
                                    jnp.moveaxis(cmat, 1, 0),
                                    jnp.moveaxis(dt, 1, 0)))
    y = jnp.moveaxis(ys, 0, 1)
    y = y + p["d_skip"].astype(jnp.float32)[None, None, :, None] * xin
    y = y.reshape(b, s, di)
    y = rms_norm(y.astype(x.dtype) * jax.nn.silu(z), p["gate_norm"], cfg.norm_eps)
    return linear(p["out_proj"], y)
