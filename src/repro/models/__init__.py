from repro.models.config import ModelConfig, reduced
from repro.models.transformer import (
    classification_loss,
    cross_entropy,
    forward,
    init_params,
    lm_loss,
)
from repro.models.layers import Taps
