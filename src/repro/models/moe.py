"""Top-k routed Mixture-of-Experts with sort-based capacity dispatch.

TPU-native design (DESIGN.md §5): instead of the dense one-hot dispatch
einsum (O(T·E·C) memory — prohibitive at 128 experts), tokens are *sorted*
by expert id and scattered into an (E, C, D) buffer:

  1. router logits -> top-k (gate, expert) per token
  2. stable-sort the T·k assignments by expert id
  3. position-in-expert = rank within the sorted segment; drop > capacity
  4. gather/scatter into (E, C, D); expert GEMMs as one batched einsum
  5. combine: gather back + weighted scatter-add

Under GSPMD the expert axis shards over 'model' (EP); the sort/gather lower
to all-to-all-style collectives.  Capacity C = ceil(T·k/E · capacity_factor).
"""

from __future__ import annotations

from typing import Any, Mapping

import jax
import jax.numpy as jnp

from repro.models.layers import linear


def _capacity(tokens: int, k: int, num_experts: int, factor: float) -> int:
    c = int(tokens * k * factor / num_experts) + 1
    return max(8, ((c + 7) // 8) * 8)   # pad to sublane multiple


def moe_block(p: Mapping[str, Any], x: jax.Array, *, num_experts: int,
              top_k: int, capacity_factor: float = 1.25,
              taps=None, prefix: str = "", use_pallas: bool = False):
    """x: (B, S, D) -> (B, S, D); router in fp32 (precision-critical).

    Returns (out, aux) with aux = load-balancing loss (Switch-style).
    """
    b, s, d = x.shape
    t = b * s
    xt = x.reshape(t, d)

    logits = (xt.astype(jnp.float32) @ p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                  # (T, E)
    gate_vals, expert_ids = jax.lax.top_k(probs, top_k)      # (T, k)
    gate_vals = gate_vals / jnp.sum(gate_vals, -1, keepdims=True)

    # Switch load-balance aux loss: E * mean(frac_tokens * frac_probs)
    counts = jnp.sum(jax.nn.one_hot(expert_ids[:, 0], num_experts), axis=0)
    aux = num_experts * jnp.mean(
        (counts / t) * jnp.mean(probs, axis=0))

    # ---- sort-based dispatch -------------------------------------------
    flat_e = expert_ids.reshape(-1)                          # (T*k,)
    flat_g = gate_vals.reshape(-1)
    flat_tok = jnp.repeat(jnp.arange(t), top_k)
    order = jnp.argsort(flat_e, stable=True)
    e_sorted = flat_e[order]
    tok_sorted = flat_tok[order]
    g_sorted = flat_g[order]

    seg_counts = jnp.bincount(e_sorted, length=num_experts)
    seg_starts = jnp.cumsum(seg_counts) - seg_counts         # exclusive
    pos_in_e = jnp.arange(t * top_k) - seg_starts[e_sorted]

    cap = _capacity(t, top_k, num_experts, capacity_factor)
    keep = pos_in_e < cap
    pos_c = jnp.where(keep, pos_in_e, 0)

    if taps is not None:
        taps.record(f"{prefix}experts", xt)

    buf = jnp.zeros((num_experts, cap, d), x.dtype)
    gathered = jnp.where(keep[:, None], xt[tok_sorted], 0.0)
    buf = buf.at[e_sorted, pos_c].set(gathered.astype(x.dtype), mode="drop")

    # ---- expert compute (batched SwiGLU) --------------------------------
    def eapply(w, h):  # w: (E, din, dout) possibly quantized dict
        if isinstance(w, Mapping):
            y = jnp.einsum("ecd,edf->ecf", h, w["w_tilde"].astype(h.dtype))
            tl = jnp.einsum("ecd,edr->ecr", h, w["lora_a"].astype(h.dtype))
            return y + jnp.einsum("ecr,erf->ecf", tl, w["lora_b"].astype(h.dtype))
        return jnp.einsum("ecd,edf->ecf", h, w.astype(h.dtype))

    hgate = eapply(p["wg"], buf)
    hup = eapply(p["wu"], buf)
    hout = eapply(p["wd"], jax.nn.silu(hgate) * hup)          # (E, C, D)

    # ---- combine ---------------------------------------------------------
    back = hout[e_sorted, pos_c]                              # (T*k, D)
    back = back * (g_sorted * keep).astype(back.dtype)[:, None]
    out = jnp.zeros((t, d), back.dtype).at[tok_sorted].add(back)
    return out.reshape(b, s, d).astype(x.dtype), aux
