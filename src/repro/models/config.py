"""ModelConfig — one dataclass describing every supported architecture family.

Families:
  dense         pre-norm GQA transformer (llama-style: RoPE + SwiGLU)
  moe           dense attention + top-k routed expert MLPs
  hybrid_mamba  Mamba2 (SSD) blocks with a *shared* attention block every
                ``attn_every`` layers (zamba2)
  rwkv          RWKV-6 "Finch": data-dependent-decay linear attention + channel mix
  vlm           dense + cross-attention to precomputed image embeddings every
                ``cross_attn_every``-th layer (frontend stubbed)
  audio         dense decoder over ``num_codebooks`` EnCodec token streams
                (frontend stubbed; per-codebook embeddings and heads)
  encoder       bidirectional encoder (RoBERTa) for the paper's QPEFT benches
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp

DTYPES = {"float32": jnp.float32, "bfloat16": jnp.bfloat16}


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    family: str = "dense"
    num_layers: int = 2
    d_model: int = 128
    num_heads: int = 4
    num_kv_heads: int = 4
    d_ff: int = 256
    vocab_size: int = 256
    head_dim: int = 0                # 0 -> d_model // num_heads
    max_seq_len: int = 8192
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False

    # moe
    num_experts: int = 0
    moe_top_k: int = 1
    capacity_factor: float = 1.25

    # hybrid_mamba (Mamba2 SSD)
    ssm_state: int = 64
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv_width: int = 4
    ssm_chunk: int = 64
    attn_every: int = 0              # shared attn block period (zamba2: 6)

    # rwkv6
    rwkv_head_dim: int = 64
    rwkv_decay_lora: int = 64
    rwkv_chunk: int = 16     # with the -8 logw clamp, 16 keeps exp() in f32 range

    # vlm
    cross_attn_every: int = 0        # cross-attn block period (llama3.2-V: 5)
    vision_seq: int = 1601           # patch tokens from the (stubbed) tower
    # audio
    num_codebooks: int = 0

    # encoder
    num_classes: int = 0

    # vocab padding (shardability: pad to a multiple, mask pad logits)
    vocab_pad_multiple: int = 1

    # numerics / scaling (minicpm-style mup knobs)
    dtype: str = "float32"
    embed_scale: float = 1.0
    residual_scale: float = 1.0
    logit_cap: float = 0.0

    # runtime switches
    scan_layers: bool = True
    remat: bool = False
    attn_chunk: int = 0              # q-chunk for memory-bounded attention
    chunk_python_loop: bool = False  # unroll inner chunk loops in HLO (dry-run
                                     # cost accounting; see launch/dryrun.py)
    act_sp: bool = False             # sequence-parallel activation constraints
    mesh_axes: tuple = ()            # ((name, size), ...) for act constraints
    use_pallas: bool = False         # kernels in the serving path (TPU)

    # serving tensor parallelism (sharding/serving.py): > 1 means this config
    # describes the PER-DEVICE shard of a shard_map'd forward — heads/d_ff are
    # already divided by tp_size and every row-parallel (out-projection)
    # partial output is psum'd over ``tp_axis`` in the block residual.
    tp_size: int = 1
    tp_axis: str = "model"

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def padded_vocab(self) -> int:
        m = max(self.vocab_pad_multiple, 1)
        return ((self.vocab_size + m - 1) // m) * m

    @property
    def compute_dtype(self):
        return DTYPES[self.dtype]

    @property
    def d_inner(self) -> int:        # mamba2 inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def rwkv_heads(self) -> int:
        return self.d_model // self.rwkv_head_dim

    @property
    def is_subquadratic(self) -> bool:
        """Can this arch decode with O(1)/O(s) state at 500k context?"""
        return self.family in ("hybrid_mamba", "rwkv")

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks + head)."""
        d, f, v, l = self.d_model, self.d_ff, self.vocab_size, self.num_layers
        hd, h, kv = self.hd, self.num_heads, self.num_kv_heads
        attn = d * h * hd + 2 * d * kv * hd + h * hd * d
        mlp = 3 * d * f
        if self.family == "moe":
            mlp = self.num_experts * 3 * d * f + d * self.num_experts
        if self.family == "hybrid_mamba":
            di, n, g = self.d_inner, self.ssm_state, self.ssm_heads
            blk = d * (2 * di + 2 * n + g) + di * d + 3 * g
            n_attn = 1 if self.attn_every else 0
            return v * d + l * blk + n_attn * (attn + mlp) + d * v
        if self.family == "rwkv":
            tm = 5 * d * d + 2 * d * self.rwkv_decay_lora + 6 * d
            cm = 2 * d * f + d * d
            return v * d + l * (tm + cm) + d * v
        base = v * d + l * (attn + mlp) + d * v
        if self.family == "vlm" and self.cross_attn_every:
            n_cross = l // self.cross_attn_every
            base += n_cross * attn
        if self.family == "audio" and self.num_codebooks:
            base += (self.num_codebooks - 1) * v * d * 2
        return base

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top_k experts) — the N in 6·N·D."""
        if self.family != "moe":
            return self.param_count()
        d, f, v, l = self.d_model, self.d_ff, self.vocab_size, self.num_layers
        hd, h, kv = self.hd, self.num_heads, self.num_kv_heads
        attn = d * h * hd + 2 * d * kv * hd + h * hd * d
        mlp_active = self.moe_top_k * 3 * d * f + d * self.num_experts
        return v * d + l * (attn + mlp_active) + d * v

    def validate(self) -> "ModelConfig":
        assert self.num_heads % max(self.num_kv_heads, 1) == 0 or \
            self.family in ("rwkv",), "heads must divide kv heads"
        if self.family == "moe":
            assert self.num_experts > 0 and self.moe_top_k >= 1
        if self.family == "hybrid_mamba":
            assert self.d_inner % self.ssm_head_dim == 0
        if self.family == "rwkv":
            assert self.d_model % self.rwkv_head_dim == 0
        if self.family == "audio":
            assert self.num_codebooks > 0
        return self


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """A tiny same-family config for CPU smoke tests."""
    small = dict(
        num_layers=max(2, (cfg.attn_every or cfg.cross_attn_every or 0) or 2),
        d_model=64, num_heads=4, num_kv_heads=min(cfg.num_kv_heads, 4) or 4,
        d_ff=128, vocab_size=128, max_seq_len=256,
        head_dim=16,
        num_experts=min(cfg.num_experts, 4),
        ssm_state=16, ssm_head_dim=16, ssm_chunk=8,
        rwkv_head_dim=16, rwkv_decay_lora=8, rwkv_chunk=8,
        vision_seq=24,
        vocab_pad_multiple=1,
        dtype="float32", scan_layers=cfg.scan_layers, remat=False,
    )
    if cfg.family == "hybrid_mamba":
        small["num_layers"] = 2 * (cfg.attn_every or 2)
    if cfg.family == "vlm":
        small["num_layers"] = 2 * (cfg.cross_attn_every or 2)
    small.update(overrides)
    return dataclasses.replace(cfg, **small).validate()
