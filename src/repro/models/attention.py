"""Attention: q-chunked flash-style jnp implementation + KV-cache decode.

The jnp path is what the pjit/GSPMD dry-run lowers (collectives visible in
HLO); the Pallas flash kernel (kernels/flash_attention.py) is the TPU serving
target and is numerically cross-checked against the same ref oracle.

q-chunking bounds the live score tensor to (B, H, chunk, S_kv) — required for
prefill_32k, harmless elsewhere.  GQA is einsum-grouped (no kv head repeat).
"""

from __future__ import annotations

from typing import Any, Mapping

import jax
import jax.numpy as jnp

from repro.models.layers import apply_rope, linear


def _gqa_scores(q: jax.Array, k: jax.Array) -> jax.Array:
    """q: (B, Hkv, G, Sq, D); k: (B, Hkv, Skv, D) -> (B, Hkv, G, Sq, Skv)."""
    return jnp.einsum("bkgqd,bksd->bkgqs", q, k)


def _gqa_out(p: jax.Array, v: jax.Array) -> jax.Array:
    return jnp.einsum("bkgqs,bksd->bkgqd", p, v)


def sdpa(q: jax.Array, k: jax.Array, v: jax.Array, *, causal: bool,
         q_offset: int | jax.Array = 0, kv_len: int | jax.Array | None = None,
         chunk: int = 0, python_loop: bool = False) -> jax.Array:
    """Grouped-query attention.

    q: (B, H, Sq, D); k, v: (B, Hkv, Skv, D).  ``q_offset`` is the absolute
    position of q[0] (decode: cache length); ``kv_len`` masks cache tails.
    ``chunk`` > 0 iterates q-chunks to bound live score memory; each chunk is
    rematerialized in the backward pass (flash-attention-style memory).
    ``python_loop`` unrolls the chunk loop in HLO (dry-run cost accounting —
    XLA's cost model counts a scan body once regardless of trip count).
    """
    b, h, sq, d = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    g = h // hkv
    qg = q.reshape(b, hkv, g, sq, d)
    scale = 1.0 / (d ** 0.5)

    vec = (kv_len is not None and getattr(kv_len, "ndim", 0) == 1)

    def block(qc: jax.Array, off) -> jax.Array:
        s = _gqa_scores(qc.astype(jnp.float32), k.astype(jnp.float32)) * scale
        kv_ids = jnp.arange(skv)
        sq_c = qc.shape[3]
        if vec:
            # per-row cache lengths/offsets (continuous-batching decode)
            mask = jnp.ones((b, 1, 1, sq_c, skv), bool)
            mask &= (kv_ids[None, :] < kv_len[:, None])[:, None, None, None]
            if causal:
                q_ids = off[:, None] + jnp.arange(sq_c)[None, :]   # (B, Sq)
                mask &= (q_ids[:, :, None] >= kv_ids[None, None, :]
                         )[:, None, None]
        else:
            mask = jnp.ones((sq_c, skv), bool)
            if kv_len is not None:
                mask &= (kv_ids < kv_len)[None, :]
            if causal:
                q_ids = off + jnp.arange(sq_c)
                mask &= q_ids[:, None] >= kv_ids[None, :]
            mask = mask[None, None, None]
        s = jnp.where(mask, s, -jnp.inf)
        p = jax.nn.softmax(s, axis=-1)
        # rows with no visible kv (fully masked) produce nan softmax -> zero
        row_ok = jnp.any(mask, axis=-1, keepdims=True)
        p = jnp.where(row_ok, p, 0.0)
        return _gqa_out(p, v.astype(jnp.float32)).astype(q.dtype)

    if chunk and sq > chunk and sq % chunk == 0:
        # per-chunk remat: backward recomputes this chunk's scores instead of
        # storing them — O(chunk * Skv) live scores instead of O(Sq * Skv).
        block_ckpt = jax.checkpoint(block, static_argnums=())
        nq = sq // chunk
        if python_loop:
            outs = [block(qg[:, :, :, i * chunk:(i + 1) * chunk, :],
                          q_offset + i * chunk) for i in range(nq)]
            out = jnp.concatenate(outs, axis=3)
        else:
            qs = jnp.moveaxis(qg.reshape(b, hkv, g, nq, chunk, d), 3, 0)
            offs = q_offset + jnp.arange(nq) * chunk

            def body(_, xs):
                qc, off = xs
                return None, block_ckpt(qc, off)

            _, outs = jax.lax.scan(body, None, (qs, offs))
            out = jnp.moveaxis(outs, 0, 3).reshape(b, hkv, g, sq, d)
    else:
        out = block(qg, q_offset)
    return out.reshape(b, h, sq, d)


def paged_attention_decode(q: jax.Array, k: jax.Array, v: jax.Array,
                           cache: Mapping[str, jax.Array],
                           page_table: jax.Array, cache_len: jax.Array):
    """Single-token paged decode: append K/V to the slot's current page,
    then attend over the pages the slot owns via the Pallas decode kernel.

    q/k/v: (B, *, 1, hd).  ``cache`` holds the shared pools
    k_pages/v_pages (P, Hkv, page_size, hd); ``page_table`` (B, npages) is
    already sliced to the scheduler's live-prefix bucket, so attention
    reads scale with the context in use, not max_len.  Appends through an
    unallocated (0) table entry land in the reserved garbage page.
    """
    from repro.kernels.ops import decode_attention

    kp, vp = cache["k_pages"], cache["v_pages"]
    page_size = kp.shape[2]
    b = q.shape[0]
    pos = cache_len
    if getattr(pos, "ndim", 0) == 0:               # scan rollout: uniform pos
        pos = jnp.full((b,), pos, jnp.int32)
    phys = jnp.take_along_axis(page_table, pos[:, None] // page_size,
                               axis=1)[:, 0]       # (B,) physical page
    off = pos % page_size
    kp = kp.at[phys, :, off].set(k[:, :, 0, :].astype(kp.dtype))
    vp = vp.at[phys, :, off].set(v[:, :, 0, :].astype(vp.dtype))
    out = decode_attention(q[:, :, 0, :], kp, vp, page_table, pos + 1)
    return out[:, :, None, :], {"k_pages": kp, "v_pages": vp}


def paged_attention_prefill(q: jax.Array, k: jax.Array, v: jax.Array,
                            cache: Mapping[str, jax.Array],
                            page_table: jax.Array, cache_len: jax.Array):
    """Chunked direct-to-page prefill: scatter the chunk's K/V straight into
    the slot's pages, then attend over the pages through the Pallas paged
    prefill kernel — causal within the chunk, fully visible over the
    already-written prefix.

    q/k/v: (B, *, S, hd) with S the chunk width; ``cache_len`` (scalar or
    (B,)) is the absolute position of the chunk's first token.  The chunk
    occupies positions cache_len..cache_len+S-1, whose pages the scheduler
    has already allocated (entries routed through an unallocated 0 entry
    would land in the reserved garbage page).  This is what removes the
    dense batch=1 scratch cache + ``place_pages`` copy from paged admission.
    """
    from repro.kernels.ops import prefill_attention

    kp, vp = cache["k_pages"], cache["v_pages"]
    page_size = kp.shape[2]
    b, _, s, _ = q.shape
    pos0 = cache_len
    if getattr(pos0, "ndim", 0) == 0:
        pos0 = jnp.full((b,), pos0, jnp.int32)
    pos = pos0[:, None] + jnp.arange(s, dtype=jnp.int32)[None, :]   # (B, S)
    phys = jnp.take_along_axis(page_table, pos // page_size, axis=1)
    off = pos % page_size
    kp = kp.at[phys, :, off].set(k.transpose(0, 2, 1, 3).astype(kp.dtype))
    vp = vp.at[phys, :, off].set(v.transpose(0, 2, 1, 3).astype(vp.dtype))
    out = prefill_attention(q, kp, vp, page_table, pos0, pos0 + s)
    return out, {"k_pages": kp, "v_pages": vp}


def attention_block(p: Mapping[str, Any], x: jax.Array, angles: jax.Array, *,
                    num_heads: int, num_kv_heads: int, head_dim: int,
                    causal: bool = True, chunk: int = 0,
                    python_loop: bool = False,
                    cache: Mapping[str, jax.Array] | None = None,
                    cache_len: jax.Array | None = None,
                    page_table: jax.Array | None = None,
                    constrain=None,
                    taps=None, prefix: str = "", use_pallas: bool = False):
    """Self-attention with optional KV cache (decode / prefill-fill).

    x: (B, S, D).  Returns (out, new_cache) where new_cache is None when no
    cache was passed.  ``angles`` must already be sliced to x's positions.
    A paged cache (k_pages/v_pages leaves + ``page_table``) routes through
    the page pool: s == 1 takes the single-token paged decode path, s > 1
    the chunked direct-to-page prefill path (both scatter the new K/V into
    the slot's pages in-graph, then launch ONE Pallas attention kernel).
    """
    b, s, _ = x.shape
    q = linear(p["wq"], x, taps=taps, name=f"{prefix}wq", use_pallas=use_pallas)
    k = linear(p["wk"], x, taps=taps, name=f"{prefix}wk", use_pallas=use_pallas)
    v = linear(p["wv"], x, taps=taps, name=f"{prefix}wv", use_pallas=use_pallas)
    q = q.reshape(b, s, num_heads, head_dim).transpose(0, 2, 1, 3)
    k = k.reshape(b, s, num_kv_heads, head_dim).transpose(0, 2, 1, 3)
    v = v.reshape(b, s, num_kv_heads, head_dim).transpose(0, 2, 1, 3)
    q = apply_rope(q, angles)
    k = apply_rope(k, angles)
    if constrain is not None and cache is None:
        # sequence-parallel attention: q seq over 'model', full k/v local.
        # Without this GSPMD may shard the head_dim CONTRACTION (head counts
        # rarely divide the TP axis), all-reducing every score tile.
        q = constrain(q, ("dp", None, "model", None))
        k = constrain(k, ("dp", None, None, None))
        v = constrain(v, ("dp", None, None, None))

    new_cache = None
    if cache is not None and "k_pages" in cache:
        if s == 1:
            out, new_cache = paged_attention_decode(q, k, v, cache,
                                                    page_table, cache_len)
        else:
            out, new_cache = paged_attention_prefill(q, k, v, cache,
                                                     page_table, cache_len)
    elif cache is not None:
        # insert into cache at cache_len, attend over the whole cache
        ck, cv = cache["k"], cache["v"]
        idx = (jnp.zeros((), jnp.int32) if cache_len is None else cache_len)
        if getattr(idx, "ndim", 0) == 1:
            # per-row insertion positions (continuous-batching decode, s == 1)
            upd = jax.vmap(lambda c, val, i: jax.lax.dynamic_update_slice(
                c, val, (jnp.zeros((), idx.dtype), i, jnp.zeros((), idx.dtype))))
            ck = upd(ck, k.astype(ck.dtype), idx)
            cv = upd(cv, v.astype(cv.dtype), idx)
        else:
            ck = jax.lax.dynamic_update_slice(
                ck, k.astype(ck.dtype), (0, 0, idx, 0))
            cv = jax.lax.dynamic_update_slice(
                cv, v.astype(cv.dtype), (0, 0, idx, 0))
        new_cache = {"k": ck, "v": cv}
        out = sdpa(q, ck.astype(q.dtype), cv.astype(q.dtype), causal=causal,
                   q_offset=idx, kv_len=idx + s, chunk=chunk,
                   python_loop=python_loop)
    else:
        out = sdpa(q, k, v, causal=causal, chunk=chunk,
                   python_loop=python_loop)

    out = out.transpose(0, 2, 1, 3).reshape(b, s, num_heads * head_dim)
    if constrain is not None and cache is None:
        out = constrain(out, ("dp", "model", None))   # stay sequence-parallel
    out = linear(p["wo"], out, taps=taps, name=f"{prefix}wo", use_pallas=use_pallas)
    return out, new_cache


def cross_attention_block(p: Mapping[str, Any], x: jax.Array,
                          kv_embeds: jax.Array, *, num_heads: int,
                          num_kv_heads: int, head_dim: int,
                          taps=None, prefix: str = "", use_pallas: bool = False):
    """Cross-attention onto precomputed (stub-frontend) embeddings.

    x: (B, S, D); kv_embeds: (B, S_img, D). Non-causal, no RoPE (llama3.2-V
    style cross blocks use no positional rotation on image keys).
    """
    b, s, _ = x.shape
    s_kv = kv_embeds.shape[1]
    q = linear(p["wq"], x, taps=taps, name=f"{prefix}wq", use_pallas=use_pallas)
    k = linear(p["wk"], kv_embeds, taps=taps, name=f"{prefix}wk", use_pallas=use_pallas)
    v = linear(p["wv"], kv_embeds, taps=taps, name=f"{prefix}wv", use_pallas=use_pallas)
    q = q.reshape(b, s, num_heads, head_dim).transpose(0, 2, 1, 3)
    k = k.reshape(b, s_kv, num_kv_heads, head_dim).transpose(0, 2, 1, 3)
    v = v.reshape(b, s_kv, num_kv_heads, head_dim).transpose(0, 2, 1, 3)
    out = sdpa(q, k, v, causal=False)
    out = out.transpose(0, 2, 1, 3).reshape(b, s, num_heads * head_dim)
    return linear(p["wo"], out, taps=taps, name=f"{prefix}wo", use_pallas=use_pallas)
