"""RWKV-6 ("Finch") block — data-dependent-decay linear attention.

Time-mix recurrence per head (dk = dv = head_dim), decay on the key dim:

  S_t = diag(w_t) S_{t-1} + k_t ⊗ v_t
  y_t = r_t @ S_{t-1} + (r_t · (u ∘ k_t)) v_t          (u = per-head bonus)

The Finch hallmark is w_t = exp(-exp(w0 + lora(x̄_t))) — *data-dependent*
per-channel decay.  Chunked-parallel evaluation works in log space: all
weights are exp of differences of a monotone cumulative sum (≤ 0 within a
chunk), so no overflow for any chunk length.

Channel-mix is the standard squared-ReLU MLP with token shift.
"""

from __future__ import annotations

from typing import Any, Mapping

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import linear, rms_norm


def rwkv6_param_shapes(cfg: ModelConfig) -> dict[str, tuple]:
    d, f, hd, lo = cfg.d_model, cfg.d_ff, cfg.rwkv_head_dim, cfg.rwkv_decay_lora
    h = cfg.rwkv_heads
    return {
        # time-mix
        "mu_r": (d,), "mu_k": (d,), "mu_v": (d,), "mu_g": (d,), "mu_w": (d,),
        "w_r": (d, d), "w_k": (d, d), "w_v": (d, d), "w_g": (d, d),
        "w_o": (d, d),
        "decay_w0": (d,), "decay_a": (d, lo), "decay_b": (lo, d),
        "bonus_u": (h, hd),
        "ln_x": (d,),
        # channel-mix
        "mu_kc": (d,), "mu_rc": (d,),
        "w_kc": (d, f), "w_vc": (f, d), "w_rc": (d, d),
    }


def _shift(x: jax.Array, last: jax.Array | None = None) -> jax.Array:
    """x_{t-1} per position; position 0 uses ``last`` (decode cache) or 0."""
    if last is None:
        last = jnp.zeros((x.shape[0], 1, x.shape[-1]), x.dtype)
    else:
        last = last[:, None, :].astype(x.dtype)
    return jnp.concatenate([last, x[:, :-1, :]], axis=1)


def _lerp(x, xprev, mu):
    return x + (xprev - x) * mu.astype(x.dtype)


def rwkv6_time_mix(p, x, cfg: ModelConfig, *, state=None, last=None,
                   constrain=None, taps=None, prefix="", use_pallas=False):
    """x: (B,S,D) -> (out, (S_out, x_last)).  state: (B,H,dk,dv)."""
    b, s, d = x.shape
    h, hd = cfg.rwkv_heads, cfg.rwkv_head_dim
    xprev = _shift(x, last)
    if constrain is not None:
        # keep batch on 'data' through the elementwise/lerp ops; TP ('model')
        # lands on the projection outputs (-> head dim in the recurrence)
        x = constrain(x, ("dp", None, None))
        xprev = constrain(xprev, ("dp", None, None))

    r = linear(p["w_r"], _lerp(x, xprev, p["mu_r"]), taps=taps,
               name=f"{prefix}w_r", use_pallas=use_pallas)
    k = linear(p["w_k"], _lerp(x, xprev, p["mu_k"]), taps=taps,
               name=f"{prefix}w_k", use_pallas=use_pallas)
    v = linear(p["w_v"], _lerp(x, xprev, p["mu_v"]), taps=taps,
               name=f"{prefix}w_v", use_pallas=use_pallas)
    g = linear(p["w_g"], _lerp(x, xprev, p["mu_g"]), taps=taps,
               name=f"{prefix}w_g", use_pallas=use_pallas)
    if constrain is not None:
        r, k, v, g = (constrain(t, ("dp", None, "model")) for t in (r, k, v, g))

    # data-dependent decay (the Finch mechanism); kept fp32 + fp params
    xw = _lerp(x, xprev, p["mu_w"]).astype(jnp.float32)
    dyn = jnp.tanh(xw @ p["decay_a"].astype(jnp.float32)) @ \
        p["decay_b"].astype(jnp.float32)
    logw = -jnp.exp(p["decay_w0"].astype(jnp.float32) + dyn)   # (B,S,D) ≤ 0
    # clamp per-step log-decay: exp(-8) ≈ 3e-4 retention — anything below is
    # numerically dead, and the clamp bounds intra-chunk exp() ranges so the
    # factored chunk evaluation can never overflow f32 (see chunk()).
    logw = jnp.maximum(logw, -8.0)

    rh = r.reshape(b, s, h, hd).astype(jnp.float32)
    kh = k.reshape(b, s, h, hd).astype(jnp.float32)
    vh = v.reshape(b, s, h, hd).astype(jnp.float32)
    lw = logw.reshape(b, s, h, hd)
    u = p["bonus_u"].astype(jnp.float32)                        # (H, hd)

    s0 = (jnp.zeros((b, h, hd, hd), jnp.float32) if state is None
          else state.astype(jnp.float32))

    lc = max(1, min(cfg.rwkv_chunk, s))
    if s % lc:
        lc = 1
    nc = s // lc

    def chunk(carry, xs):
        s_in = carry
        r_c, k_c, v_c, lw_c = xs           # each (Lc, B, H, hd)
        cum = jnp.cumsum(lw_c, axis=0)     # inclusive (Lc, B, H, hd)
        # y_t = r_t @ S_{t-1}-decayed-in + intra + bonus-diag
        # S_{t-1} holds k_s v_s decayed by prod_{u=s+1..t-1} w = exp(cum_{t-1}-cum_s)
        cum_prev = jnp.concatenate([jnp.zeros_like(cum[:1]), cum[:-1]], 0)
        # intra (s' < t):  (r_t ∘ exp(cum_{t-1} − cum_s)) · k_s.
        # Factored with a mid-chunk reference offset so each factor's exponent
        # is bounded by (Lc/2)·8 < 88 (f32 exp overflow) given the logw clamp.
        cref = cum[cum.shape[0] // 2]      # (B, H, hd)
        att = jnp.einsum("tbhd,ubhd->tubh",
                         r_c * jnp.exp(cum_prev - cref),
                         k_c * jnp.exp(cref - cum))
        tri = jnp.tril(jnp.ones((cum.shape[0], cum.shape[0]), bool), k=-1)
        # masked (above-diagonal) entries may have overflowed to inf — they
        # are exp() of *positive* log-decay sums; where() (not multiply, which
        # would produce inf*0=NaN) zeroes them exactly.
        att = jnp.where(tri[:, :, None, None], att, 0.0)
        y = jnp.einsum("tubh,ubhd->tbhd", att, v_c)
        # bonus diagonal: (r_t · (u ∘ k_t)) v_t
        diag = jnp.einsum("tbhd,hd,tbhd->tbh", r_c, u, k_c)
        y = y + diag[..., None] * v_c
        # state term: r_t ∘ exp(cum_{t-1}) @ S_in
        y = y + jnp.einsum("tbhk,bhkv->tbhv", r_c * jnp.exp(cum_prev), s_in)
        # state update: S_out = diag(exp(cum_L)) S_in + Σ exp(cum_L − cum_s) k⊗v
        s_out = s_in * jnp.exp(cum[-1])[..., None] + jnp.einsum(
            "tbhk,tbhv->bhkv", k_c * jnp.exp(cum[-1][None] - cum), v_c)
        return s_out, y

    def to_chunks(a):  # (B,S,H,hd) -> (nc, Lc, B, H, hd)
        return jnp.moveaxis(a.reshape(b, nc, lc, h, hd), 1, 0).transpose(0, 2, 1, 3, 4)

    if cfg.chunk_python_loop:
        # unrolled in HLO so the dry-run cost model sees every chunk; chunks
        # are sliced from the NATURAL (B,S,H,hd) layout (chunk-sized slices +
        # small transposes — avoids per-chunk copies of the stacked array)
        def chunk_at(a, i):
            return a[:, i * lc:(i + 1) * lc].transpose(1, 0, 2, 3)
        s_cur, ys_list = s0, []
        for i in range(nc):
            xs_i = tuple(chunk_at(a, i) for a in (rh, kh, vh, lw))
            s_cur, y_i = chunk(s_cur, xs_i)
            ys_list.append(y_i)
        s_last, ys = s_cur, jnp.stack(ys_list)
    else:
        xs = (to_chunks(rh), to_chunks(kh), to_chunks(vh), to_chunks(lw))
        s_last, ys = jax.lax.scan(chunk, s0, xs)
    y = jnp.moveaxis(ys.reshape(nc * lc, b, h, hd), 0, 1)       # (B,S,H,hd)

    # per-head group norm, then output gate
    y = y.reshape(b, s, d)
    y = rms_norm(y.astype(x.dtype), p["ln_x"], cfg.norm_eps)
    y = y * jax.nn.silu(g)
    out = linear(p["w_o"], y, taps=taps, name=f"{prefix}w_o",
                 use_pallas=use_pallas)
    return out, (s_last, x[:, -1, :])


def rwkv6_channel_mix(p, x, cfg: ModelConfig, *, last=None, constrain=None,
                      taps=None, prefix="", use_pallas=False):
    xprev = _shift(x, last)
    if constrain is not None:
        x = constrain(x, ("dp", None, None))
        xprev = constrain(xprev, ("dp", None, None))
    k = linear(p["w_kc"], _lerp(x, xprev, p["mu_kc"]), taps=taps,
               name=f"{prefix}w_kc", use_pallas=use_pallas)
    k = jnp.square(jax.nn.relu(k))
    v = linear(p["w_vc"], k, taps=taps, name=f"{prefix}w_vc",
               use_pallas=use_pallas)
    r = linear(p["w_rc"], _lerp(x, xprev, p["mu_rc"]), taps=taps,
               name=f"{prefix}w_rc", use_pallas=use_pallas)
    return jax.nn.sigmoid(r) * v, x[:, -1, :]


def rwkv6_time_mix_ref(p, x, cfg: ModelConfig):
    """Per-timestep scan oracle (tests only)."""
    b, s, d = x.shape
    h, hd = cfg.rwkv_heads, cfg.rwkv_head_dim
    xprev = _shift(x)
    r = linear(p["w_r"], _lerp(x, xprev, p["mu_r"]))
    k = linear(p["w_k"], _lerp(x, xprev, p["mu_k"]))
    v = linear(p["w_v"], _lerp(x, xprev, p["mu_v"]))
    g = linear(p["w_g"], _lerp(x, xprev, p["mu_g"]))
    xw = _lerp(x, xprev, p["mu_w"]).astype(jnp.float32)
    dyn = jnp.tanh(xw @ p["decay_a"].astype(jnp.float32)) @ \
        p["decay_b"].astype(jnp.float32)
    logw = jnp.maximum(-jnp.exp(p["decay_w0"].astype(jnp.float32) + dyn), -8.0)
    w = jnp.exp(logw)
    rh = r.reshape(b, s, h, hd).astype(jnp.float32)
    kh = k.reshape(b, s, h, hd).astype(jnp.float32)
    vh = v.reshape(b, s, h, hd).astype(jnp.float32)
    wh = w.reshape(b, s, h, hd)
    u = p["bonus_u"].astype(jnp.float32)

    def step(s_prev, xs):
        rt, kt, vt, wt = xs
        yt = jnp.einsum("bhk,bhkv->bhv", rt, s_prev)
        yt = yt + jnp.einsum("bhk,hk,bhk->bh", rt, u, kt)[..., None] * vt
        s_new = s_prev * wt[..., None] + jnp.einsum("bhk,bhv->bhkv", kt, vt)
        return s_new, yt

    s0 = jnp.zeros((b, h, hd, hd), jnp.float32)
    _, ys = jax.lax.scan(step, s0, (jnp.moveaxis(rh, 1, 0),
                                    jnp.moveaxis(kh, 1, 0),
                                    jnp.moveaxis(vh, 1, 0),
                                    jnp.moveaxis(wh, 1, 0)))
    y = jnp.moveaxis(ys, 0, 1).reshape(b, s, d)
    y = rms_norm(y.astype(x.dtype), p["ln_x"], cfg.norm_eps)
    y = y * jax.nn.silu(g)
    return linear(p["w_o"], y)
