"""Model builder: init_params + forward for every architecture family.

Design rules (DESIGN.md §2):
* params are plain nested dicts of arrays — no module framework;
* per-layer params are STACKED on a leading L axis and applied with
  ``lax.scan`` (compile time O(1) in depth — essential for the 512-device
  dry-run) unless ``cfg.scan_layers=False`` (python loop, used for
  calibration Taps and debugging);
* every linear goes through ``layers.linear`` so PTQ'd dicts and Pallas
  packed weights drop in transparently;
* ``forward`` returns (logits, aux, new_cache); aux carries the MoE
  load-balance loss.
"""

from __future__ import annotations

from typing import Any, Mapping

import jax
import jax.numpy as jnp

from repro.models.attention import attention_block, cross_attention_block
from repro.models.config import ModelConfig
from repro.models.layers import (
    embed,
    init_dense,
    key_iter,
    layer_norm,
    linear,
    rms_norm,
    rope_freqs,
    swiglu,
)
from repro.models.mamba2 import mamba2_block, mamba2_param_shapes
from repro.models.moe import moe_block
from repro.models.rwkv6 import (
    rwkv6_channel_mix,
    rwkv6_param_shapes,
    rwkv6_time_mix,
)

Params = dict[str, Any]


# ===========================================================================
# init
# ===========================================================================

def _init_attn(ks, cfg: ModelConfig, layers: int | None = None) -> Params:
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.hd
    lead = () if layers is None else (layers,)
    return {
        "wq": init_dense(next(ks), (*lead, d, h * hd)),
        "wk": init_dense(next(ks), (*lead, d, kv * hd)),
        "wv": init_dense(next(ks), (*lead, d, kv * hd)),
        "wo": init_dense(next(ks), (*lead, h * hd, d)),
    }


def _init_mlp(ks, cfg: ModelConfig, layers: int | None = None) -> Params:
    d, f = cfg.d_model, cfg.d_ff
    lead = () if layers is None else (layers,)
    return {
        "wg": init_dense(next(ks), (*lead, d, f)),
        "wu": init_dense(next(ks), (*lead, d, f)),
        "wd": init_dense(next(ks), (*lead, f, d)),
    }


def init_params(cfg: ModelConfig, key: jax.Array) -> Params:
    cfg.validate()
    ks = key_iter(key)
    d, v, l = cfg.d_model, cfg.padded_vocab, cfg.num_layers
    params: Params = {}

    if cfg.family == "audio":
        params["embed"] = {"tok": 0.02 * jax.random.normal(
            next(ks), (cfg.num_codebooks, v, d))}
        params["lm_head"] = init_dense(next(ks), (cfg.num_codebooks, d, v))
    else:
        params["embed"] = {"tok": 0.02 * jax.random.normal(next(ks), (v, d))}
        if not cfg.tie_embeddings:
            params["lm_head"] = init_dense(next(ks), (d, v))
    params["final_norm"] = jnp.ones((d,))

    if cfg.family in ("dense", "moe", "vlm", "audio"):
        blocks: Params = {
            "norm_attn": jnp.ones((l, d)),
            "norm_mlp": jnp.ones((l, d)),
            **_init_attn(ks, cfg, l),
        }
        if cfg.family == "moe":
            e, f = cfg.num_experts, cfg.d_ff
            blocks["router"] = 0.02 * jax.random.normal(next(ks), (l, d, e))
            blocks["wg"] = init_dense(next(ks), (l, e, d, f))
            blocks["wu"] = init_dense(next(ks), (l, e, d, f))
            blocks["wd"] = init_dense(next(ks), (l, e, f, d))
        else:
            blocks.update(_init_mlp(ks, cfg, l))
        params["blocks"] = blocks
        if cfg.family == "vlm" and cfg.cross_attn_every:
            nx = l // cfg.cross_attn_every
            params["cross_blocks"] = {
                "norm_x": jnp.ones((nx, d)),
                "gate": jnp.zeros((nx,)),          # zero-init gated residual
                **_init_attn(ks, cfg, nx),
            }

    elif cfg.family == "hybrid_mamba":
        shapes = mamba2_param_shapes(cfg)
        blocks = {"norm": jnp.ones((l, d))}
        for name, shp in shapes.items():
            if name == "a_log":
                a0 = jnp.log(jnp.linspace(1.0, 16.0, cfg.ssm_heads))
                blocks[name] = jnp.broadcast_to(a0, (l, *shp)).copy()
            elif name == "dt_bias":
                blocks[name] = jnp.full((l, *shp), -4.6)   # softplus^-1(0.01)
            elif name in ("d_skip", "gate_norm"):
                blocks[name] = jnp.ones((l, *shp))
            elif name == "conv_w":
                blocks[name] = init_dense(next(ks), (l, *shp), scale=0.2)
            else:
                blocks[name] = init_dense(next(ks), (l, *shp))
        params["blocks"] = blocks
        if cfg.attn_every:
            params["shared_attn"] = {
                "norm_attn": jnp.ones((d,)),
                "norm_mlp": jnp.ones((d,)),
                **_init_attn(ks, cfg),
                **_init_mlp(ks, cfg),
            }

    elif cfg.family == "rwkv":
        shapes = rwkv6_param_shapes(cfg)
        blocks = {"norm_tm": jnp.ones((l, d)), "norm_cm": jnp.ones((l, d))}
        for name, shp in shapes.items():
            if name.startswith("mu_"):
                blocks[name] = jax.random.uniform(next(ks), (l, *shp))
            elif name == "decay_w0":
                blocks[name] = jax.random.uniform(next(ks), (l, *shp),
                                                  minval=-2.0, maxval=1.0)
            elif name == "bonus_u":
                blocks[name] = 0.1 * jax.random.normal(next(ks), (l, *shp))
            elif name == "ln_x":
                blocks[name] = jnp.ones((l, *shp))
            else:
                blocks[name] = init_dense(next(ks), (l, *shp))
        params["blocks"] = blocks

    elif cfg.family == "encoder":
        params["embed"]["pos"] = 0.02 * jax.random.normal(
            next(ks), (cfg.max_seq_len, d))
        params["blocks"] = {
            "norm1_scale": jnp.ones((l, d)), "norm1_bias": jnp.zeros((l, d)),
            "norm2_scale": jnp.ones((l, d)), "norm2_bias": jnp.zeros((l, d)),
            **_init_attn(ks, cfg, l),
            "wi": init_dense(next(ks), (l, d, cfg.d_ff)),
            "wo_mlp": init_dense(next(ks), (l, cfg.d_ff, d)),
        }
        if cfg.num_classes:
            params["classifier"] = {
                "dense": init_dense(next(ks), (d, d)),
                "out": init_dense(next(ks), (d, cfg.num_classes)),
            }
    else:
        raise ValueError(f"unknown family {cfg.family!r}")
    return params


# ===========================================================================
# per-layer block applications
# ===========================================================================

def _dense_block(cfg: ModelConfig, p, x, angles, cache=None, cache_len=None,
                 page_table=None, taps=None, prefix="", constrain=None):
    h = rms_norm(x, p["norm_attn"], cfg.norm_eps)
    attn_out, new_cache = attention_block(
        p, h, angles, num_heads=cfg.num_heads, num_kv_heads=cfg.num_kv_heads,
        head_dim=cfg.hd, causal=True, chunk=cfg.attn_chunk,
        python_loop=cfg.chunk_python_loop, cache=cache,
        cache_len=cache_len, page_table=page_table, constrain=constrain,
        taps=taps, prefix=f"{prefix}attn/", use_pallas=cfg.use_pallas)
    if cfg.tp_size > 1:
        # tensor-parallel serving (sharding/serving.py): heads are sharded,
        # so the row-parallel wo output is a partial sum — the ONE attention
        # all-reduce lives here, covering the quantized and low-rank terms
        # of the fused kernel together (lora_b is replicated on out-projs).
        attn_out = jax.lax.psum(attn_out, cfg.tp_axis)
    x = x + cfg.residual_scale * attn_out
    aux = jnp.zeros((), jnp.float32)

    h = rms_norm(x, p["norm_mlp"], cfg.norm_eps)
    if cfg.family == "moe":
        mlp_out, aux = moe_block(
            p, h, num_experts=cfg.num_experts, top_k=cfg.moe_top_k,
            capacity_factor=cfg.capacity_factor, taps=taps,
            prefix=f"{prefix}moe/", use_pallas=cfg.use_pallas)
    else:
        mlp_out = swiglu(p, h, taps=taps, prefix=f"{prefix}mlp/",
                         use_pallas=cfg.use_pallas, constrain=constrain)
    if cfg.tp_size > 1:
        mlp_out = jax.lax.psum(mlp_out, cfg.tp_axis)  # row-parallel wd
    x = x + cfg.residual_scale * mlp_out
    return x, new_cache, aux


def _cross_block(cfg: ModelConfig, cp, x, image_embeds, taps=None, prefix=""):
    hx = rms_norm(x, cp["norm_x"], cfg.norm_eps)
    xo = cross_attention_block(
        cp, hx, image_embeds, num_heads=cfg.num_heads,
        num_kv_heads=cfg.num_kv_heads, head_dim=cfg.hd,
        taps=taps, prefix=f"{prefix}xattn/", use_pallas=cfg.use_pallas)
    return x + jnp.tanh(cp["gate"]).astype(x.dtype) * xo


def _shared_attn_block(cfg: ModelConfig, p, x, angles, cache=None,
                       cache_len=None, page_table=None, taps=None,
                       prefix="", constrain=None):
    """zamba2's shared full transformer block (attention + MLP)."""
    h = rms_norm(x, p["norm_attn"], cfg.norm_eps)
    attn_out, new_cache = attention_block(
        p, h, angles, num_heads=cfg.num_heads, num_kv_heads=cfg.num_kv_heads,
        head_dim=cfg.hd, causal=True, chunk=cfg.attn_chunk,
        python_loop=cfg.chunk_python_loop, cache=cache,
        cache_len=cache_len, page_table=page_table, constrain=constrain,
        taps=taps, prefix=f"{prefix}shared_attn/", use_pallas=cfg.use_pallas)
    x = x + attn_out
    h = rms_norm(x, p["norm_mlp"], cfg.norm_eps)
    x = x + swiglu(p, h, taps=taps, prefix=f"{prefix}shared_mlp/",
                   use_pallas=cfg.use_pallas, constrain=constrain)
    return x, new_cache


def _rwkv_block(cfg: ModelConfig, p, x, cache=None, taps=None, prefix="",
                constrain=None):
    h = rms_norm(x, p["norm_tm"], cfg.norm_eps)
    state = last_tm = last_cm = None
    if cache is not None:
        state, last_tm, last_cm = cache["state"], cache["last_tm"], cache["last_cm"]
    tm_out, (state_new, xlast) = rwkv6_time_mix(
        p, h, cfg, state=state, last=last_tm, constrain=constrain, taps=taps,
        prefix=f"{prefix}tm/", use_pallas=cfg.use_pallas)
    x = x + tm_out
    h = rms_norm(x, p["norm_cm"], cfg.norm_eps)
    cm_out, clast = rwkv6_channel_mix(p, h, cfg, last=last_cm,
                                      constrain=constrain, taps=taps,
                                      prefix=f"{prefix}cm/",
                                      use_pallas=cfg.use_pallas)
    x = x + cm_out
    new_cache = None
    if cache is not None:
        new_cache = {"state": state_new.astype(cache["state"].dtype),
                     "last_tm": xlast.astype(cache["last_tm"].dtype),
                     "last_cm": clast.astype(cache["last_cm"].dtype)}
    return x, new_cache


def _encoder_block(cfg: ModelConfig, p, x, taps=None, prefix=""):
    h = layer_norm(x, p["norm1_scale"], p["norm1_bias"], cfg.norm_eps)
    attn_out, _ = attention_block(
        p, h, jnp.zeros((x.shape[1], cfg.hd // 2)),   # zero angles == no RoPE
        num_heads=cfg.num_heads, num_kv_heads=cfg.num_kv_heads,
        head_dim=cfg.hd, causal=False, chunk=cfg.attn_chunk,
        taps=taps, prefix=f"{prefix}attn/", use_pallas=cfg.use_pallas)
    x = x + attn_out
    h = layer_norm(x, p["norm2_scale"], p["norm2_bias"], cfg.norm_eps)
    h = jax.nn.gelu(linear(p["wi"], h, taps=taps, name=f"{prefix}mlp/wi",
                           use_pallas=cfg.use_pallas))
    x = x + linear(p["wo_mlp"], h, taps=taps, name=f"{prefix}mlp/wo_mlp",
                   use_pallas=cfg.use_pallas)
    return x


# ===========================================================================
# forward
# ===========================================================================

def _split_scan_static(blocks):
    """Separate 0-dim leaves (packed-format bits/block_size metadata) from a
    stacked-blocks tree: lax.scan xs need a leading scan axis."""
    from repro.utils.trees import flatten_dict, unflatten_dict
    flat = flatten_dict(dict(blocks))
    static = {k: v for k, v in flat.items() if getattr(v, "ndim", 1) == 0}
    dyn = unflatten_dict({k: v for k, v in flat.items() if k not in static})
    return dyn, static


def _merge_static(p_i, static):
    if not static:
        return p_i
    from repro.utils.trees import flatten_dict, unflatten_dict
    flat = flatten_dict(dict(p_i))
    flat.update(static)
    return unflatten_dict(flat)


def _layer_slice(tree, i):
    # 0-dim leaves are per-linear metadata (packed-format bits/block_size) —
    # shared across layers, not stacked
    return jax.tree.map(lambda a: a[i] if getattr(a, "ndim", 1) else a, tree)


def _dyn_slice(tree, i):
    return jax.tree.map(lambda a: jax.lax.dynamic_index_in_dim(
        a, i, axis=0, keepdims=False), tree)


def forward(params: Params, batch: Mapping[str, jax.Array], cfg: ModelConfig,
            *, cache: Params | None = None, cache_len: jax.Array | None = None,
            taps=None):
    """batch["tokens"]: (B, S) ids — audio: (B, K, S).
    Returns (logits, aux, new_cache); logits (B, S, V) ((B, K, S, V) audio).
    """
    tokens = batch["tokens"]
    dtype = cfg.compute_dtype
    has_cache = cache is not None

    if cfg.family == "audio":
        embs = jax.vmap(lambda t, i: embed(t, i))(
            params["embed"]["tok"], tokens.swapaxes(0, 1))
        x = jnp.sum(embs, axis=0).astype(dtype)
        b, s = tokens.shape[0], tokens.shape[-1]
    else:
        x = embed(params["embed"]["tok"], tokens, cfg.embed_scale).astype(dtype)
        b, s = tokens.shape

    pos0 = jnp.zeros((), jnp.int32) if cache_len is None else cache_len
    all_angles = rope_freqs(cfg.hd, cfg.max_seq_len, cfg.rope_theta)
    if getattr(pos0, "ndim", 0) == 1:
        # per-row positions (continuous-batching decode s == 1, or per-row
        # chunked prefill s > 1: row b covers positions pos0[b]..pos0[b]+s-1)
        pos = pos0[:, None] + jnp.arange(s, dtype=pos0.dtype)[None, :]
        angles = jnp.take(all_angles, pos, axis=0)[:, None]   # (B,1,S,hd/2)
    else:
        # scalar offset: one-shot prefill (pos0 == 0) or a chunk-prefill
        # step at offset pos0 (chunked admission / scan prologue) — the
        # cache threads per-slot recurrent rows (mamba conv/ssm, rwkv
        # state) across chunks, so hybrid families stay token-exact.
        angles = jax.lax.dynamic_slice_in_dim(all_angles, pos0, s, axis=0)

    aux_total = jnp.zeros((), jnp.float32)
    new_cache: Params | None = {} if has_cache else None
    # paged decode: the page table is shared across layers (each layer's
    # pool slice is indexed by the same slot -> page mapping), so it rides
    # outside the scanned "blocks" leaves and passes through unchanged.
    page_table = cache.get("page_table") if has_cache else None
    if page_table is not None:
        new_cache["page_table"] = page_table
    blocks = params["blocks"]
    image_embeds = None
    if cfg.family == "vlm":
        image_embeds = batch["image_embeds"].astype(dtype)

    use_scan = cfg.scan_layers and taps is None
    dummy_xs = jnp.zeros((cfg.num_layers,))
    constrain = None
    if cfg.act_sp and cfg.mesh_axes:
        from repro.sharding.rules import make_act_constrainer
        constrain = make_act_constrainer(tuple(cfg.mesh_axes))

    # ---------------- layer stack ------------------------------------------
    if cfg.family in ("dense", "moe", "audio", "vlm"):
        every = cfg.cross_attn_every if cfg.family == "vlm" else 0
        cross_blocks = params.get("cross_blocks")

        if use_scan:
            blocks_dyn, blocks_static = _split_scan_static(blocks)

            def body(carry, xs):
                xcur, auxc = carry
                p_i, idx, cache_i = xs
                p_i = _merge_static(p_i, blocks_static)
                xcur, cache_o, aux = _dense_block(
                    cfg, p_i, xcur, angles,
                    cache=cache_i if has_cache else None, cache_len=cache_len,
                    page_table=page_table, constrain=constrain)
                if constrain is not None and not has_cache:
                    # sequence-parallel residual stream: remat residuals and
                    # norm/elementwise work shard S over 'model'
                    xcur = constrain(xcur, ("dp", "model", None))
                if every:
                    cp = _dyn_slice(cross_blocks, idx // every)
                    xcur = jax.lax.cond(
                        (idx + 1) % every == 0,
                        lambda xc: _cross_block(cfg, cp, xc, image_embeds),
                        lambda xc: xc, xcur)
                return (xcur, auxc + aux), cache_o

            body_fn = jax.checkpoint(body) if cfg.remat else body
            idxs = jnp.arange(cfg.num_layers)
            (x, aux_total), caches_o = jax.lax.scan(
                body_fn, (x, aux_total),
                (blocks_dyn, idxs, cache["blocks"] if has_cache else dummy_xs))
            if has_cache:
                new_cache["blocks"] = caches_o
        else:
            # remat in the unrolled path too, so dry-run cost compiles
            # (scan_layers=False) count the recompute FLOPs remat adds
            plain = lambda xc, pp: _dense_block(cfg, pp, xc, angles,
                                                constrain=constrain)  # noqa: E731
            rematted = jax.checkpoint(plain) if cfg.remat else plain
            caches_o = []
            for i in range(cfg.num_layers):
                p_i = _layer_slice(blocks, i)
                cache_i = _layer_slice(cache["blocks"], i) if has_cache else None
                if has_cache or taps is not None:
                    x, cache_o, aux = _dense_block(
                        cfg, p_i, x, angles, cache=cache_i,
                        cache_len=cache_len, page_table=page_table,
                        taps=taps, prefix=f"blocks/{i}/")
                else:
                    x, cache_o, aux = rematted(x, p_i)
                aux_total += aux
                if every and (i + 1) % every == 0:
                    cp = _layer_slice(cross_blocks, i // every)
                    x = _cross_block(cfg, cp, x, image_embeds, taps=taps,
                                     prefix=f"blocks/{i}/")
                caches_o.append(cache_o)
            if has_cache:
                new_cache["blocks"] = jax.tree.map(
                    lambda *xs: jnp.stack(xs), *caches_o)

    elif cfg.family == "hybrid_mamba":
        shared = params.get("shared_attn")
        every = cfg.attn_every

        if use_scan:
            blocks_dyn, blocks_static = _split_scan_static(blocks)

            def body(carry, xs):
                xcur, attn_cache = carry
                p_i, idx, cache_i = xs
                p_i = _merge_static(p_i, blocks_static)
                h = rms_norm(xcur, p_i["norm"], cfg.norm_eps)
                out, mcache_o = mamba2_block(
                    p=p_i, x=h, cfg=cfg,
                    cache=cache_i if has_cache else None, constrain=constrain)
                xcur = xcur + out
                if shared is not None and every:
                    pred = (idx + 1) % every == 0
                    if has_cache:
                        # the shared block is applied at L//every depths; each
                        # application has its OWN cache slice (inputs differ)
                        def w_attn(op):
                            xc, stack = op
                            app = idx // every
                            ci = _dyn_slice(stack, app)
                            y, cnew = _shared_attn_block(
                                cfg, shared, xc, angles, cache=ci,
                                cache_len=cache_len, page_table=page_table,
                                constrain=constrain)
                            stack = jax.tree.map(
                                lambda full, new: jax.lax.
                                dynamic_update_index_in_dim(full, new, app, 0),
                                stack, cnew)
                            return y, stack
                        xcur, attn_cache = jax.lax.cond(
                            pred, w_attn, lambda op: op, (xcur, attn_cache))
                    else:
                        def w_attn_nc(xc):
                            y, _ = _shared_attn_block(cfg, shared, xc, angles,
                                                      constrain=constrain)
                            return y
                        xcur = jax.lax.cond(pred, w_attn_nc, lambda xc: xc, xcur)
                return (xcur, attn_cache), mcache_o

            body_fn = jax.checkpoint(body) if cfg.remat else body
            attn_cache0 = (cache["shared_attn"] if has_cache
                           else jnp.zeros(()))
            idxs = jnp.arange(cfg.num_layers)
            (x, attn_cache), mcaches = jax.lax.scan(
                body_fn, (x, attn_cache0),
                (blocks_dyn, idxs, cache["blocks"] if has_cache else dummy_xs))
            if has_cache:
                new_cache["blocks"] = mcaches
                new_cache["shared_attn"] = attn_cache
        else:
            attn_stack = cache["shared_attn"] if has_cache else None
            attn_caches = []
            mcaches = []
            for i in range(cfg.num_layers):
                p_i = _layer_slice(blocks, i)
                cache_i = _layer_slice(cache["blocks"], i) if has_cache else None
                h = rms_norm(x, p_i["norm"], cfg.norm_eps)
                out, mcache_o = mamba2_block(
                    p=p_i, x=h, cfg=cfg, cache=cache_i, constrain=constrain,
                    taps=taps, prefix=f"blocks/{i}/")
                x = x + out
                if shared is not None and every and (i + 1) % every == 0:
                    app = i // every
                    ci = _layer_slice(attn_stack, app) if has_cache else None
                    x, cnew = _shared_attn_block(
                        cfg, shared, x, angles, cache=ci,
                        cache_len=cache_len, page_table=page_table,
                        taps=taps, prefix=f"blocks/{i}/")
                    attn_caches.append(cnew)
                mcaches.append(mcache_o)
            if has_cache:
                new_cache["blocks"] = jax.tree.map(
                    lambda *xs: jnp.stack(xs), *mcaches)
                if attn_caches:
                    new_cache["shared_attn"] = jax.tree.map(
                        lambda *xs: jnp.stack(xs), *attn_caches)

    elif cfg.family == "rwkv":
        if use_scan:
            blocks_dyn, blocks_static = _split_scan_static(blocks)

            def body(xcur, xs):
                p_i, cache_i = xs
                p_i = _merge_static(p_i, blocks_static)
                return _rwkv_block(cfg, p_i, xcur,
                                   cache=cache_i if has_cache else None,
                                   constrain=constrain)

            body_fn = jax.checkpoint(body) if cfg.remat else body
            x, caches_o = jax.lax.scan(
                body_fn, x,
                (blocks_dyn, cache["blocks"] if has_cache else dummy_xs))
            if has_cache:
                new_cache["blocks"] = caches_o
        else:
            caches_o = []
            for i in range(cfg.num_layers):
                p_i = _layer_slice(blocks, i)
                cache_i = _layer_slice(cache["blocks"], i) if has_cache else None
                x, cache_o = _rwkv_block(cfg, p_i, x, cache=cache_i,
                                         constrain=constrain,
                                         taps=taps, prefix=f"blocks/{i}/")
                caches_o.append(cache_o)
            if has_cache:
                new_cache["blocks"] = jax.tree.map(
                    lambda *xs: jnp.stack(xs), *caches_o)

    elif cfg.family == "encoder":
        pos = jnp.arange(s)
        x = x + embed(params["embed"]["pos"], pos)[None].astype(dtype)
        if use_scan:
            def body(xcur, p_i):
                return _encoder_block(cfg, p_i, xcur), None
            body_fn = jax.checkpoint(body) if cfg.remat else body
            x, _ = jax.lax.scan(body_fn, x, blocks)
        else:
            for i in range(cfg.num_layers):
                x = _encoder_block(cfg, _layer_slice(blocks, i), x,
                                   taps=taps, prefix=f"blocks/{i}/")
    else:
        raise ValueError(cfg.family)

    # ---------------- head --------------------------------------------------
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    if cfg.family == "encoder" and cfg.num_classes:
        cls = x[:, 0, :]
        h = jnp.tanh(linear(params["classifier"]["dense"], cls, taps=taps,
                            name="classifier/dense", use_pallas=cfg.use_pallas))
        logits = linear(params["classifier"]["out"], h, taps=taps,
                        name="classifier/out", use_pallas=cfg.use_pallas)
    elif cfg.family == "audio":
        logits = jnp.einsum("bsd,kdv->bksv", x.astype(jnp.float32),
                            params["lm_head"].astype(jnp.float32))
    else:
        head = (params["embed"]["tok"].T if cfg.tie_embeddings
                else params["lm_head"])
        if isinstance(head, Mapping):
            logits = linear(head, x.astype(jnp.float32))
        else:
            logits = x.astype(jnp.float32) @ head.astype(jnp.float32)
    if constrain is not None:
        logits = constrain(logits, ("dp", None, "model"))
    if cfg.logit_cap > 0:
        logits = cfg.logit_cap * jnp.tanh(logits / cfg.logit_cap)
    if cfg.padded_vocab != cfg.vocab_size and cfg.family != "encoder":
        pad_mask = jnp.arange(cfg.padded_vocab) < cfg.vocab_size
        logits = jnp.where(pad_mask, logits, -1e9)
    return logits, aux_total, new_cache


# ===========================================================================
# losses
# ===========================================================================

def cross_entropy(logits: jax.Array, labels: jax.Array,
                  ignore_id: int = -1) -> jax.Array:
    """Token-mean CE in f32; the vocab axis stays sharded under GSPMD (the
    logsumexp/gather reduce with psum instead of all-gathering logits)."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(
        logits, jnp.maximum(labels, 0)[..., None], axis=-1)[..., 0]
    nll = lse - gold
    mask = (labels != ignore_id).astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def lm_loss(params: Params, batch: Mapping[str, jax.Array], cfg: ModelConfig,
            aux_weight: float = 0.01):
    logits, aux, _ = forward(params, batch, cfg)
    loss = cross_entropy(logits, batch["labels"])
    return loss + aux_weight * aux, (loss, aux)


def classification_loss(params: Params, batch, cfg: ModelConfig):
    logits, aux, _ = forward(params, batch, cfg)
    labels = batch["labels"]
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=-1)[:, 0]
    return jnp.mean(nll), (jnp.mean(nll), aux)
