"""Primitive layers shared by every family (pure functions, params = pytrees).

``linear`` dispatches on the param leaf: a plain array applies x @ W; a
quantized dict {"w_tilde", "lora_a", "lora_b"} applies the QERA serving form
x @ W̃ + (x @ A) @ B (optionally through the fused Pallas kernel when the
packed representation {"mant", "exp", ...} is present and use_pallas is on).

``Taps`` implements calibration capture: when a Taps object is threaded
through a forward pass, every linear records its *input* statistics keyed by
the layer path — exactly what the QERA solvers consume.
"""

from __future__ import annotations

from typing import Any, Mapping

import jax
import jax.numpy as jnp


class Taps:
    """Calibration stats collector (host side, python-loop forwards only)."""

    def __init__(self, with_outer: bool = True):
        self.with_outer = with_outer
        self.stats: dict[str, Any] = {}

    def record(self, name: str, x: jax.Array) -> None:
        from repro.core.calibration import StreamingStats
        acc = self.stats.get(name)
        if acc is None:
            acc = self.stats[name] = StreamingStats(
                dim=x.shape[-1], with_outer=self.with_outer)
        acc.update(x)

    def layer_stats(self) -> dict[str, Any]:
        return {k: v.as_layer_stats() for k, v in self.stats.items()}


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(x.dtype)


def layer_norm(x: jax.Array, scale: jax.Array, bias: jax.Array,
               eps: float = 1e-5) -> jax.Array:
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(x.dtype)


def linear(p: Any, x: jax.Array, *, taps: Taps | None = None,
           name: str = "", use_pallas: bool = False) -> jax.Array:
    """x @ W for plain leaves; QER form for quantized dicts.

    Packed dicts ({"mant","exp",...}) dispatch to the fused Pallas kernel on
    TPU — a SINGLE launch per layer per token: lora_a goes into the kernel
    and the low-rank prologue t = x @ A accumulates in VMEM alongside the
    dequant GEMM (kernels/ops.quantized_matmul) — or to an in-graph dequant
    (GSPMD-shardable; weights stream as int8 — the serving memory-roofline
    win) elsewhere.
    """
    if taps is not None and name:
        taps.record(name, x)
    if isinstance(p, Mapping):
        if "mant" in p:
            # "draft_bits" marks a DRAFT view of the same packed buffers
            # (serve/speculative.make_draft_params): dequantize only the top
            # plane of each mantissa container, scale compensated by
            # 2^draft_shift, and skip the low-rank term unless the view kept
            # it.  Key presence is pytree structure — static under jit.
            draft = "draft_bits" in p
            if use_pallas:
                from repro.kernels.ops import (quantized_matmul,
                                               quantized_matmul_draft)
                if not draft:
                    return quantized_matmul(
                        x, p["mant"], p["exp"], p["lora_a"], p["lora_b"],
                        bits=int(p["bits"]), block_size=int(p["block_size"]))
                y = quantized_matmul_draft(
                    x, p["mant"], p["exp"], bits=int(p["bits"]),
                    block_size=int(p["block_size"]),
                    draft_bits=int(p["draft_bits"]))
                if "lora_a" in p:
                    t = x @ p["lora_a"].astype(x.dtype)
                    y = y + t @ p["lora_b"].astype(x.dtype)
                return y
            mant, exp = p["mant"], p["exp"]
            k = x.shape[-1]
            bs = k // exp.shape[-2]                   # static from shapes
            epb = k // mant.shape[-2]                 # >1 => sub-byte packed
            if epb > 1:
                from repro.quant.mxint import unpack_fields
                mant = unpack_fields(mant, epb, k)
            exp_f = exp.astype(jnp.float32)
            bits_f = p["bits"].astype(jnp.float32)
            if draft:
                # arithmetic shift keeps the plane identical to the packed
                # extract; draft_shift is a concrete 0-dim leaf, so the
                # shift amount is traced but the branch is structural
                shift = p["draft_shift"].astype(jnp.int32)
                mant = jnp.right_shift(mant.astype(jnp.int32), shift)
                scale = jnp.exp2(exp_f - (bits_f - 2)
                                 + shift.astype(jnp.float32))
            else:
                scale = jnp.exp2(exp_f - (bits_f - 2))
            w = (mant.astype(jnp.float32)
                 * jnp.repeat(scale, bs, axis=-2)).astype(x.dtype)
            y = x @ w
            if draft and "lora_a" not in p:
                return y
            t = x @ p["lora_a"].astype(x.dtype)
            return y + t @ p["lora_b"].astype(x.dtype)
        w = p["w_tilde"]
        y = x @ w.astype(x.dtype)
        t = x @ p["lora_a"].astype(x.dtype)
        return y + t @ p["lora_b"].astype(x.dtype)
    return x @ p.astype(x.dtype)


def embed(table: jax.Array, ids: jax.Array, scale: float = 1.0) -> jax.Array:
    out = jnp.take(table, ids, axis=0)
    return out * scale if scale != 1.0 else out


def swiglu(p: Mapping[str, Any], x: jax.Array, *, taps=None, prefix="",
           use_pallas=False, constrain=None) -> jax.Array:
    g = linear(p["wg"], x, taps=taps, name=f"{prefix}wg", use_pallas=use_pallas)
    u = linear(p["wu"], x, taps=taps, name=f"{prefix}wu", use_pallas=use_pallas)
    if constrain is not None:
        # pin hidden activations (and thus their backward cotangents — the
        # transpose of a sharding constraint is the same constraint) to
        # batch-on-data + TP-on-ffn; without this GSPMD reshards cotangents
        # to batch-REPLICATED layouts and all-reduces (B,S,F) tensors.
        g = constrain(g, ("dp", None, "model"))
        u = constrain(u, ("dp", None, "model"))
    h = jax.nn.silu(g) * u
    return linear(p["wd"], h, taps=taps, name=f"{prefix}wd", use_pallas=use_pallas)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, max_seq: int, theta: float) -> jax.Array:
    """(max_seq, head_dim//2) complex rotation angles."""
    inv = 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))
    t = jnp.arange(max_seq, dtype=jnp.float32)
    return jnp.outer(t, inv)          # (S, hd/2)


def apply_rope(x: jax.Array, angles: jax.Array) -> jax.Array:
    """x: (..., S, hd); angles: (S, hd/2) — rotate interleaved pairs."""
    x32 = x.astype(jnp.float32)
    x1, x2 = jnp.split(x32, 2, axis=-1)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    r1 = x1 * cos - x2 * sin
    r2 = x2 * cos + x1 * sin
    return jnp.concatenate([r1, r2], axis=-1).astype(x.dtype)


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------

def init_dense(key: jax.Array, shape, scale: float | None = None,
               dtype=jnp.float32) -> jax.Array:
    if scale is None:
        scale = 1.0 / (shape[-2] ** 0.5) if len(shape) >= 2 else 0.02
    return scale * jax.random.normal(key, shape, dtype)


def key_iter(key: jax.Array):
    while True:
        key, sub = jax.random.split(key)
        yield sub
