"""Deterministic fault injection for the serving stack.

A ``FaultInjector`` carries a schedule of :class:`FaultEvent`\\ s pinned to
*injector tick indices* and exposes the hooks the batcher/supervisor call
each tick.  The injector owns its own monotonically increasing tick counter
(``begin_tick``), which is **never rewound by crash recovery** — so a
one-shot event (a crash, a NaN-corrupted decode) fires exactly once on the
global timeline even when the supervisor restores the batcher to an earlier
state and replays ticks.  Given the same schedule (or the same
``FaultInjector.storm`` seed) a serving session therefore sees a bit-for-bit
identical fault sequence, which is what makes the fault-equivalence tests
(token-identical outputs vs a fault-free run) possible.

Fault kinds:

* ``pool_spike`` — simulated pool-exhaustion pressure: for ``duration``
  ticks, ``pages`` pages of the ``PagePool`` are *reserved* (subtracted from
  ``available()``) without touching refcounts or the free list.  The batcher
  reacts through its existing machinery (admission rollback + requeue,
  pause-don't-corrupt decode).  Reservation — not acquisition — keeps the
  spike out of snapshot state: a snapshot taken mid-spike records the true
  pool ownership, and after a crash-restore the injector simply re-asserts
  the reservation on the fresh pool object via ``pre_tick``.
* ``crash`` — a simulated mid-tick device failure: ``maybe_crash(where)``
  raises :class:`SimulatedDeviceFailure` at the named point inside
  ``ContinuousBatcher.step`` (``"pre"`` = before admission, ``"mid"`` =
  after the prefill chunk, before the decode commit).  One-shot.
* ``nan_logits`` — numeric-blowup simulation: ``corrupt_logits`` overwrites
  the last-position logits of the chosen slot rows with NaN/Inf before the
  batcher's sentinel sees them.  One-shot per event.
* ``slow_tick`` — an artificial straggler tick: ``pre_tick`` sleeps
  ``seconds`` (injectable ``sleep`` for tests).

The injector also keeps a host-side ``log`` of every fired event —
``(tick, kind)`` tuples — so tests and the benchmark can assert the storm
actually happened.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable

import jax.numpy as jnp
import numpy as np

from repro.runtime.fault_tolerance import SimulatedFailure


class SimulatedDeviceFailure(SimulatedFailure):
    """A fault-injected mid-tick device failure (recoverable by restore)."""


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One scheduled fault.  ``tick`` is an injector-tick index (the first
    supervised tick is tick 0).  Unused fields are ignored per kind."""
    tick: int
    kind: str                      # pool_spike | crash | nan_logits | slow_tick
    duration: int = 1              # pool_spike: ticks the reservation holds
    pages: int = 0                 # pool_spike: pages reserved (0 = the pool)
    slots: tuple[int, ...] = ()    # nan_logits: slot rows hit (() = all)
    seconds: float = 0.0           # slow_tick: artificial tick latency
    where: str = "mid"             # crash point: "pre" | "mid"


class FaultInjector:
    def __init__(self, events: list[FaultEvent] | tuple[FaultEvent, ...] = ()):
        for ev in events:
            if ev.kind not in ("pool_spike", "crash", "nan_logits",
                               "slow_tick"):
                raise ValueError(f"unknown fault kind {ev.kind!r}")
        self.events = sorted(events, key=lambda e: e.tick)
        self.tick = -1                       # begin_tick() makes it 0-based
        self._consumed: set[int] = set()     # ids of fired one-shot events
        self.log: list[tuple[int, str]] = []

    @classmethod
    def storm(cls, seed: int, ticks: int, *, p_spike: float = 0.05,
              p_nan: float = 0.05, p_slow: float = 0.0,
              crash_ticks: tuple[int, ...] = (), spike_duration: int = 2,
              slow_seconds: float = 0.0) -> "FaultInjector":
        """A seeded random fault storm over ``ticks`` injector ticks.  The
        schedule is a pure function of the arguments (``default_rng(seed)``),
        so two storms with the same seed are identical.  Crashes are pinned
        explicitly (``crash_ticks``) because every crash costs a restore —
        callers choose how many recoveries the scenario pays for."""
        rng = np.random.default_rng(seed)
        events: list[FaultEvent] = []
        for t in range(ticks):
            draw = rng.random(3)
            if draw[0] < p_spike:
                events.append(FaultEvent(tick=t, kind="pool_spike",
                                         duration=spike_duration))
            if draw[1] < p_nan:
                events.append(FaultEvent(tick=t, kind="nan_logits"))
            if p_slow and draw[2] < p_slow:
                events.append(FaultEvent(tick=t, kind="slow_tick",
                                         seconds=slow_seconds))
        events.extend(FaultEvent(tick=t, kind="crash") for t in crash_ticks)
        return cls(events)

    # -- schedule walking ----------------------------------------------------
    def begin_tick(self) -> int:
        """Advance the global injector clock; call once per supervised tick
        (crash-recovery replays do NOT rewind it)."""
        self.tick += 1
        return self.tick

    def _due(self, kind: str, *, at: int | None = None) -> list[FaultEvent]:
        t = self.tick if at is None else at
        return [ev for ev in self.events if ev.kind == kind and ev.tick == t]

    def _fire_once(self, ev: FaultEvent) -> bool:
        if id(ev) in self._consumed:
            return False
        self._consumed.add(id(ev))
        self.log.append((self.tick, ev.kind))
        return True

    # -- hooks ---------------------------------------------------------------
    def pre_tick(self, pool=None,
                 sleep: Callable[[float], None] = time.sleep) -> None:
        """Start-of-tick hook: asserts the current pool reservation (sum of
        active spikes, re-applied every tick so it survives a pool swapped
        out by crash restore) and sleeps through slow-tick events."""
        if pool is not None:
            reserve = 0
            for ev in self.events:
                if ev.kind != "pool_spike":
                    continue
                if ev.tick <= self.tick < ev.tick + ev.duration:
                    reserve += ev.pages or pool.num_pages
                    if ev.tick == self.tick:
                        self._fire_once(ev)
            pool.reserved = reserve
        for ev in self._due("slow_tick"):
            if self._fire_once(ev):
                sleep(ev.seconds)

    def maybe_crash(self, where: str) -> None:
        """Raise a one-shot :class:`SimulatedDeviceFailure` if a crash is
        scheduled at this tick and point."""
        for ev in self._due("crash"):
            if ev.where == where and self._fire_once(ev):
                raise SimulatedDeviceFailure(
                    f"injected device failure at tick {self.tick} ({where})")

    def corrupt_logits(self, logits: jnp.ndarray,
                       active: list[int]) -> jnp.ndarray:
        """Overwrite the last-position logits of the targeted slot rows with
        NaN (even vocab entries) and +Inf (odd entries) — both classes the
        sentinel must catch.  One-shot per event."""
        for ev in self._due("nan_logits"):
            rows = [s for s in (ev.slots or tuple(active)) if s in active]
            if rows and self._fire_once(ev):
                logits = jnp.asarray(logits)
                rows_ix = jnp.asarray(rows, jnp.int32)
                row = jnp.where(jnp.arange(logits.shape[-1]) % 2 == 0,
                                jnp.nan, jnp.inf).astype(logits.dtype)
                logits = logits.at[rows_ix, -1, :].set(row)
        return logits
