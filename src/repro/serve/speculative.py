"""Self-speculative decoding from the quantization hierarchy.

QERA's serving decomposition ``W ≈ Q(W) + A·B`` means every packed layer
already ships at multiple effective precisions inside ONE HBM-resident
buffer: dropping the low-rank term and the low mantissa bits yields a
strictly cheaper forward pass over the same bytes.  ``make_draft_params``
builds the cheap view — a params pytree sharing the full tree's mant/exp
arrays (no copy, no second HBM buffer) with a ``draft_bits`` marker that
``models.layers.linear`` dispatches on: the dequant keeps only the top
``draft_bits`` of each mantissa container (shift ``s = container -
draft_bits``, scale compensated by ``2^s``) and, with ``skip_lowrank``,
drops the ``x @ A`` prologue entirely.

The speculative loops themselves live next to their serving surfaces —
``serve.engine.scan_generate(spec_k=...)`` (draft k inside the scan, verify
all k+1 positions in one chunk-shaped full-precision launch, accept the
longest matching prefix) and ``ContinuousBatcher(spec_k=...)`` — because the
verifier IS the existing full-precision path, accepted outputs are
bit-identical to non-speculative greedy decoding.  docs/speculative.md has
the bit layout, acceptance rule and rollback contract.
"""

from __future__ import annotations

import warnings
from typing import Any, Mapping

import jax.numpy as jnp

from repro.quant.mxint import container_bits, draft_shift

# Families whose decode cache is pure attention K/V: the verify launch
# recomputes and overwrites K/V at every chunk position with the full model,
# so draft-pass writes need no rollback.  Recurrent families (hybrid_mamba,
# rwkv) additionally integrate per-token state and need the batcher's
# restore-and-replay path; the engine's scan loop supports only these.
KV_ONLY_FAMILIES = ("dense", "moe")


# Below this draft mantissa width the draft's argmax diverges from the
# verifier on essentially every token (docs/speculative.md measures ~0%
# acceptance at draft_bits=2): every draft launch is wasted work.
MIN_USEFUL_DRAFT_BITS = 3


def check_spec_config(spec_k: int, draft_bits: int, *,
                      where: str = "") -> str | None:
    """Warn (loudly) about the known-useless speculative configuration.

    Returns the warning text when ``spec_k > 0`` rides on a draft plane
    too narrow to ever be accepted (None when the config is fine), and
    emits it as a ``UserWarning`` — callers that should hard-refuse
    (``launch/serve.py --strict``) raise on the non-None return instead of
    silently burning a draft+verify launch per token."""
    if spec_k <= 0 or draft_bits >= MIN_USEFUL_DRAFT_BITS:
        return None
    msg = (f"speculative decoding with draft_bits={draft_bits} accepts ~0% "
           f"of drafted tokens (docs/speculative.md): every spec_k={spec_k} "
           f"draft launch is wasted work on top of the verify pass. Use "
           f"draft_bits >= {MIN_USEFUL_DRAFT_BITS} or spec_k=0."
           + (f" [{where}]" if where else ""))
    warnings.warn(msg, UserWarning, stacklevel=3)
    return msg


def make_draft_params(params: Any, *, draft_bits: int = 2,
                      skip_lowrank: bool = True) -> Any:
    """Zero-copy draft view of a packed serving params tree.

    Every packed-quantized dict ``{"mant", "exp", "bits", "block_size",
    "lora_a", "lora_b"}`` becomes ``{"mant", "exp", "bits", "block_size",
    "draft_bits", "draft_shift"}`` — the SAME mant/exp/bits arrays plus two
    concrete 0-dim int32 leaves ``linear`` uses to extract the high-order
    mantissa plane.  ``draft_bits`` is clamped per layer to the container
    width (a 2-bit layer's draft IS the full mantissa).  With
    ``skip_lowrank=False`` the lora factors ride along and the draft keeps
    the low-rank correction at reduced mantissa precision.

    Fake-quant dicts (``{"w_tilde", ...}``) degrade to the bare ``w_tilde``
    leaf (the reconstruction term is the only thing to drop); plain float
    leaves pass through unchanged — their "draft" equals the full path, so
    speculation still verifies bit-identically, just with 100% acceptance.

    Runs eagerly on concrete params (``int(p["bits"])``): call it OUTSIDE
    jit and pass the result in — the draft tree's structure is what the
    traced code dispatches on.  Works on sharded trees too: leaves are
    reused, never transformed, so placement survives.
    """
    if draft_bits < 1:
        raise ValueError(f"draft_bits must be >= 1, got {draft_bits}")
    return _draft_view(params, draft_bits, skip_lowrank)


def _draft_view(p: Any, draft_bits: int, skip_lowrank: bool) -> Any:
    # Eager-only recursion (concrete `int(p["bits"])`, see the
    # make_draft_params docstring) — deliberately NOT nested in the
    # factory, whose inner defs the hot-path lint treats as traced.
    if isinstance(p, Mapping):
        if "mant" in p:
            bits = int(p["bits"])
            db = min(draft_bits, container_bits(bits))
            out = {"mant": p["mant"], "exp": p["exp"], "bits": p["bits"],
                   "block_size": p["block_size"],
                   "draft_bits": jnp.asarray(db, jnp.int32),
                   "draft_shift": jnp.asarray(draft_shift(bits, db),
                                              jnp.int32)}
            if not skip_lowrank:
                out["lora_a"] = p["lora_a"]
                out["lora_b"] = p["lora_b"]
            return out
        if "w_tilde" in p:
            return p["w_tilde"] if skip_lowrank else dict(p)
        return {k: _draft_view(v, draft_bits, skip_lowrank)
                for k, v in p.items()}
    if isinstance(p, (list, tuple)):
        return type(p)(_draft_view(v, draft_bits, skip_lowrank) for v in p)
    return p
