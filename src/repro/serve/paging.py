"""Paged KV cache: refcounted pages in a shared pool + copy-on-write tables.

The dense continuous-batching cache allocates (B, max_len) KV rows, so slot
admission is coupled to max_len and every decode step reads max_len worth of
K/V per slot.  This module decouples both, and lets slots SHARE pages:

* **pool** — K/V live in ``k_pages``/``v_pages`` leaves shaped
  (L, P, Hkv, page_size, hd): P fixed-size pages shared by all slots, with
  the leading layer axis matching the stacked-blocks ``lax.scan`` layout.
  **Physical page 0 is reserved as the garbage page**: page-table entries
  default to 0, so appends routed through an unallocated entry land in
  garbage (harmless — never attended to) instead of corrupting a live slot.
* **refcounts** — ``PagePool`` is a *refcounted* allocator: ``acquire``
  hands out pages at refcount 1, ``share`` bumps the count when a second
  slot points its table row at the same physical page, ``release``
  decrements, and a page is reclaimable only at refcount 0.  A page whose
  content is registered in the prefix index (below) is parked on an LRU
  when its refcount drops to 0 instead of returning to the free list; under
  allocation pressure ``acquire`` reclaims the least-recently-used cached
  page (unregistering it) — so cached prefixes cost nothing until the pool
  actually needs the memory.
* **prefix index** — ``PrefixIndex`` maps the hash-chain of full token
  pages (block hash = H(parent_hash, page_tokens), vLLM-style) to physical
  pages.  Admission matches the longest cached chain of the new prompt,
  points the slot's table row at the shared pages, bumps refcounts, and
  chunk-prefills only the uncached suffix.  Families with per-slot
  recurrent rows (mamba conv/ssm) additionally key a host-side snapshot of
  those rows at each page boundary, since recurrent state is not
  page-addressable; pure-recurrent families (rwkv) have no pageable KV and
  opt out entirely.
* **copy-on-write** — a page with refcount > 1 (or registered content) is
  NEVER written: any write that would touch one first *forks* it —
  ``make_fork_page`` gathers ``pool[src]`` and scatters it to
  ``pool[dst]`` across the layer axis in one jitted call, then the batcher
  repoints the table row on host.  All sharing is page-table indirection,
  so the Pallas decode/prefill kernels and the garbage-page shielding need
  zero changes.
* **page table** — (B, max_pages_per_slot) int32, slot's logical page j ->
  physical page.  Host-owned by the batcher, shipped to device per decode
  tick sliced to the live-prefix bucket, so the decode-attention grid
  covers only pages in actual use.
* **append** — in-kernel: the attention layer scatters the new token's K/V
  into ``pool[pt[b, pos // ps], :, pos % ps]`` (decode) or the whole
  chunk's K/V into the pages its positions cover (chunked prefill); see
  models/attention.py.
* **admit** — ``make_chunk_prefill`` returns ONE jitted call that runs one
  prompt chunk *directly against the pool* through the slot's page-table
  row: the chunk's K/V are scattered straight into the slot's pages and
  attention reads the already-written prefix back through the same table
  (kernels/prefill_attention.py) — including pages shared from the prefix
  index, which are read but never written.  Per-slot O(1) leaves (mamba
  conv/ssm rows) are viewed as a batch=1 slice and written back, so
  recurrent state threads across chunks.

``dense_to_paged`` converts a dense cache to the paged layout with an
identity page table (slot i owns pages 1 + i*npg .. 1 + (i+1)*npg - 1) —
pure reshapes, used by ``engine.scan_generate(page_size=N)`` to run the
fused rollout on the paged decode-attention kernel.
"""

from __future__ import annotations

import hashlib
from collections import OrderedDict
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.serve.engine import init_cache
from repro.utils.trees import flatten_dict, unflatten_dict

PAGED_LEAF_SUFFIXES = ("k_pages", "v_pages")


def _num_pages_axis(key: str) -> bool:
    return key.rsplit("/", 1)[-1] in PAGED_LEAF_SUFFIXES


class PagePool:
    """Host-side refcounted allocator over the shared page pool.

    Page 0 is the reserved garbage page and is never handed out.
    ``acquire`` is all-or-nothing (returns None if n pages are not
    available) so the scheduler can keep a request queued instead of
    half-admitting it.  ``share`` adds an owner to an existing page;
    ``release`` drops one — a page is reclaimable only at refcount 0.
    Registered (prefix-cached) pages at refcount 0 are parked on an LRU and
    reclaimed lazily under allocation pressure via ``on_reclaim`` (the
    prefix index unregisters the hash there), so ``available()`` counts
    them as allocatable.
    """

    def __init__(self, num_pages: int, page_size: int):
        assert num_pages >= 2, "pool needs the garbage page + >= 1 real page"
        self.num_pages = num_pages
        self.page_size = page_size
        self._free = list(range(num_pages - 1, 0, -1))   # pop() -> low first
        self._refs = np.zeros(num_pages, np.int32)
        # bumped each time a page is handed out: the write-protection
        # checker must not compare content across an evict + realloc
        self._gen = np.zeros(num_pages, np.int64)
        self._registered: set[int] = set()
        self._cached: OrderedDict[int, None] = OrderedDict()  # refcount-0 LRU
        self.on_reclaim: Callable[[int], None] | None = None
        self.acquired_total = 0            # stats: pages handed out, ever
        self.reclaimed_cached = 0          # stats: cached pages evicted
        # pages withheld from allocation without owning them (fault
        # injection's pool-exhaustion spikes).  Ephemeral pressure, NOT part
        # of pool ownership: snapshots ignore it and the injector re-asserts
        # it each tick, so a crash-restored pool sees the same spike.
        self.reserved = 0

    def pages_for(self, tokens: int) -> int:
        return -(-tokens // self.page_size)

    def available(self) -> int:
        """Allocatable pages: the free list plus reclaimable cached pages,
        minus any fault-injected reservation."""
        return max(0, len(self._free) + len(self._cached) - self.reserved)

    def refcount(self, page: int) -> int:
        return int(self._refs[page])

    def is_registered(self, page: int) -> bool:
        return page in self._registered

    def acquire(self, n: int) -> list[int] | None:
        """Hand out n pages at refcount 1 (None if not available), evicting
        LRU cached pages under pressure."""
        if n > self.available():
            return None
        pages = []
        for _ in range(n):
            if self._free:
                p = self._free.pop()
            else:
                p, _ = self._cached.popitem(last=False)   # LRU eviction
                assert self._refs[p] == 0
                self.reclaimed_cached += 1
                self._drop_registration(p)
            self._refs[p] = 1
            self._gen[p] += 1
            pages.append(p)
        self.acquired_total += n
        return pages

    def share(self, pages: list[int]) -> None:
        """Add an owner to each page (a slot's table row now points at it).
        Sharing a cached refcount-0 page revives it off the LRU."""
        for p in pages:
            assert 0 < p < self.num_pages, f"bad page {p}"
            if self._refs[p] == 0:
                assert p in self._cached, f"share of unowned page {p}"
                del self._cached[p]
            self._refs[p] += 1

    def release(self, pages: list[int]) -> None:
        """Drop one owner per page.  At refcount 0 a registered page parks
        on the cached LRU (most-recently-used end); an unregistered page
        returns to the free list."""
        for p in pages:
            assert self._refs[p] > 0, f"release of unowned page {p}"
            self._refs[p] -= 1
            if self._refs[p] == 0:
                if p in self._registered:
                    self._cached[p] = None
                else:
                    self._free.append(p)

    def accounting(self) -> dict:
        """Read-only snapshot of the allocator's books for the invariant
        checkers (analysis/runtime.py) — the sanctioned way to observe the
        private fields without mutating them."""
        return {"refs": self._refs.copy(), "free": list(self._free),
                "cached": list(self._cached), "registered":
                set(self._registered), "generation": self._gen.copy()}

    def set_registered(self, page: int, flag: bool) -> None:
        """Prefix-index hook: mark a page's content as cached (survives
        refcount 0 on the LRU) or drop the mark (parks -> free list)."""
        if flag:
            self._registered.add(page)
        else:
            self._registered.discard(page)
            if page in self._cached:
                del self._cached[page]
                self._free.append(page)

    def _drop_registration(self, page: int) -> None:
        self._registered.discard(page)
        if self.on_reclaim is not None:
            self.on_reclaim(page)

    # -- snapshot ------------------------------------------------------------
    def state(self) -> dict:
        """JSON-serializable allocator state (crash-safe snapshot).  The
        ``reserved`` pressure is deliberately excluded — it is injected
        ephemera, re-asserted by the fault injector after restore."""
        return {
            "num_pages": self.num_pages, "page_size": self.page_size,
            "free": list(self._free),
            "refs": [int(r) for r in self._refs],
            "registered": sorted(self._registered),
            "cached": list(self._cached),           # LRU order preserved
            "acquired_total": self.acquired_total,
            "reclaimed_cached": self.reclaimed_cached,
            "generation": [int(g) for g in self._gen],
        }

    def load_state(self, state: dict) -> None:
        assert state["num_pages"] == self.num_pages, "pool geometry mismatch"
        assert state["page_size"] == self.page_size, "pool geometry mismatch"
        self._free = list(state["free"])
        self._refs = np.asarray(state["refs"], np.int32)
        self._registered = set(state["registered"])
        self._cached = OrderedDict((p, None) for p in state["cached"])
        self.acquired_total = state["acquired_total"]
        self.reclaimed_cached = state["reclaimed_cached"]
        self._gen = np.asarray(
            state.get("generation", np.zeros(self.num_pages)), np.int64)
        self.reserved = 0

    # legacy exclusive-ownership names, kept for external callers
    alloc = acquire
    free = release


class PrefixIndex:
    """Host-side hash-chain index over full token pages in the pool.

    Block hash = H(parent_hash, page_tokens) (sha256 digests), so a hit on
    page j implies every earlier page of the prefix matched too — matching
    is a single walk down the prompt's chain.  Entries map a hash to the
    physical page holding that block's K/V; the page's refcount lifecycle
    lives in ``PagePool`` (registered pages park on the LRU at refcount 0
    and this index is notified through ``on_reclaim`` when one is evicted).

    Families with per-slot recurrent rows (hybrid shared-attn) additionally
    store a host snapshot of those rows keyed by the boundary's hash —
    recurrent state is not page-addressable, so a match is only usable up
    to the deepest boundary with a snapshot.
    """

    def __init__(self, pool: PagePool):
        self.pool = pool
        pool.on_reclaim = self._reclaimed
        self._by_hash: dict[bytes, int] = {}
        self._hash_of: dict[int, bytes] = {}
        self._state: dict[bytes, Any] = {}     # boundary hash -> host rows
        self.hits = 0                          # admissions that shared >= 1pg
        self.misses = 0
        self.hit_tokens = 0                    # prompt tokens served by cache

    def __len__(self) -> int:
        return len(self._by_hash)

    @staticmethod
    def chain_hashes(tokens: np.ndarray, page_size: int) -> list[bytes]:
        """Hashes of every FULL page of ``tokens``: h_j = H(h_{j-1}, page)."""
        h = b"\x00" * 32
        out = []
        for j in range(len(tokens) // page_size):
            m = hashlib.sha256(h)
            page = np.ascontiguousarray(
                tokens[j * page_size:(j + 1) * page_size], np.int32)
            m.update(page.tobytes())
            h = m.digest()
            out.append(h)
        return out

    def match(self, prompt: np.ndarray, *, max_pages: int,
              need_state: bool = False) -> tuple[list[int], Any]:
        """Longest cached chain of ``prompt``'s full pages, capped at
        ``max_pages``.  Returns (physical pages, recurrent-rows snapshot at
        the match boundary).  With ``need_state`` the match is truncated to
        the deepest boundary that HAS a snapshot (None matched otherwise);
        the caller bumps refcounts via ``pool.share``."""
        pages: list[int] = []
        best: tuple[list[int], Any] = ([], None)
        for h in self.chain_hashes(prompt, self.pool.page_size)[:max_pages]:
            pg = self._by_hash.get(h)
            if pg is None:
                break
            pages.append(pg)
            if need_state and h in self._state:
                best = (list(pages), self._state[h])
        return best if need_state else (pages, None)

    def register(self, h: bytes, page: int, state: Any = None) -> bool:
        """Record ``page`` as holding the block hashed ``h``.  First writer
        wins: a duplicate hash keeps the existing page (the newcomer's copy
        stays exclusively owned and is simply never shared), but a state
        snapshot still attaches to the boundary if it lacked one."""
        if h in self._by_hash:
            if state is not None and h not in self._state:
                self._state[h] = state
            return False
        if page in self._hash_of:          # already registered under another
            return False                   # hash; cannot alias
        self._by_hash[h] = page
        self._hash_of[page] = h
        if state is not None:
            self._state[h] = state
        self.pool.set_registered(page, True)
        return True

    def state(self) -> tuple[dict, dict]:
        """(json_state, state_snapshots): the hash->page map in insertion
        order (hashes hex-encoded for JSON) plus the recurrent-row snapshot
        pytrees keyed by hex hash (saved as array leaves, not JSON)."""
        return ({
            "entries": [[h.hex(), int(p)] for h, p in self._by_hash.items()],
            "hits": self.hits, "misses": self.misses,
            "hit_tokens": self.hit_tokens,
        }, {h.hex(): s for h, s in self._state.items()})

    def load_state(self, state: dict, snapshots: dict) -> None:
        self._by_hash = {bytes.fromhex(h): p for h, p in state["entries"]}
        self._hash_of = {p: h for h, p in self._by_hash.items()}
        self._state = {bytes.fromhex(h): s for h, s in snapshots.items()}
        self.hits, self.misses = state["hits"], state["misses"]
        self.hit_tokens = state["hit_tokens"]
        for p in self._hash_of:
            self.pool.set_registered(p, True)

    def _reclaimed(self, page: int) -> None:
        """Pool evicted a cached page: drop its hash (and any deeper chain
        entries become unreachable — they age out of the LRU on their own)."""
        h = self._hash_of.pop(page, None)
        if h is not None:
            self._by_hash.pop(h, None)
            self._state.pop(h, None)


def page_bucket(live_pages: int, max_pages: int) -> int:
    """Power-of-two page-table width covering ``live_pages`` (bounds jit
    retraces to log2(max_pages) decode-step variants)."""
    b = 1
    while b < live_pages:
        b *= 2
    return min(b, max_pages)


def init_paged_cache(cfg: ModelConfig, batch: int, max_len: int, *,
                     page_size: int, num_pages: int,
                     dtype=None) -> dict[str, Any]:
    """Paged decode cache: shared page pool + zeroed (all-garbage) page
    table.  Only attention K/V leaves are paged; per-slot O(1) state
    (mamba conv/ssm) keeps its dense slot rows.  ``max_len`` only bounds the
    page-table WIDTH (max pages one slot may own) — it does not size the
    pool, which is the point: capacity is ``num_pages`` regardless of
    max_len."""
    dtype = dtype or cfg.compute_dtype
    assert max_len % page_size == 0, (max_len, page_size)
    max_pages = max_len // page_size
    l, kv, hd = cfg.num_layers, cfg.num_kv_heads, cfg.hd
    table = jnp.zeros((batch, max_pages), jnp.int32)

    if cfg.family in ("dense", "moe", "audio", "vlm"):
        return {"blocks": {
            "k_pages": jnp.zeros((l, num_pages, kv, page_size, hd), dtype),
            "v_pages": jnp.zeros((l, num_pages, kv, page_size, hd), dtype),
        }, "page_table": table}
    if cfg.family == "hybrid_mamba" and cfg.attn_every:
        cache = init_cache(cfg, batch, max_len, dtype)
        napp = cfg.num_layers // cfg.attn_every
        cache["shared_attn"] = {
            "k_pages": jnp.zeros((napp, num_pages, kv, page_size, hd), dtype),
            "v_pages": jnp.zeros((napp, num_pages, kv, page_size, hd), dtype),
        }
        cache["page_table"] = table
        return cache
    raise ValueError(f"family {cfg.family!r} has no pageable attention KV")


def _place_row(big: jax.Array, small: jax.Array, slot: jax.Array,
               num_slots: int) -> jax.Array:
    """Write small's batch row into big at ``slot`` (traced); the batch axis
    is the static axis sized num_slots in big and 1 in small."""
    zero = jnp.zeros((), jnp.int32)
    for ax in range(big.ndim):
        if big.shape[ax] == num_slots and small.shape[ax] == 1:
            idx = [zero] * big.ndim
            idx[ax] = slot
            return jax.lax.dynamic_update_slice(
                big, small.astype(big.dtype), tuple(idx))
    raise ValueError(f"no batch axis in {big.shape} vs {small.shape}")


def make_restore_slot(num_slots: int):
    """(cache, prev, slot) -> cache with ``slot``'s per-slot rows restored
    from ``prev``.

    Used when a paused decode tick must be undone for one slot: pool leaves
    (k_pages/v_pages) keep the NEW value — the paused slot's append landed
    in the garbage page, and other slots' appends are live — but per-slot
    recurrent state (mamba conv/ssm rows) advanced on a token that was
    discarded, and must roll back or the recompute double-feeds it.
    """

    def restore_slot(cache: Any, prev: Any, slot: jax.Array) -> Any:
        flat, flatp = flatten_dict(cache), flatten_dict(prev)
        out: dict[str, jax.Array] = {}
        for key, leaf in flat.items():
            if _num_pages_axis(key):
                out[key] = leaf                      # appends are idempotent
            else:
                row = _slot_row(flatp[key], slot, num_slots)
                out[key] = _place_row(leaf, row, slot, num_slots)
        return unflatten_dict(out)

    return restore_slot


def _slot_row(big: jax.Array, slot: jax.Array, num_slots: int) -> jax.Array:
    """Slice ``slot``'s batch row (kept as size-1 axis) out of a per-slot
    leaf.  Per-slot cache leaves are layer-stacked (L, B, ...): when
    L == num_slots the leading layer axis ties with the batch axis, so a
    size match at axis 0 defers to one at axis 1."""
    axes = [ax for ax in range(big.ndim) if big.shape[ax] == num_slots]
    if not axes:
        raise ValueError(f"no batch axis in {big.shape}")
    ax = 1 if (axes[0] == 0 and 1 in axes) else axes[0]
    return jax.lax.dynamic_slice_in_dim(big, slot, 1, axis=ax)


def has_slot_rows(cache: Any) -> bool:
    """True when the paged cache carries per-slot (non-pool) leaves — the
    recurrent rows chunked prefill must view/restore per slot."""
    return any(not _num_pages_axis(k) for k in flatten_dict(cache))


def make_chunk_prefill(cfg, num_slots: int):
    """(params, cache, chunk, pt_row, slot, pos) -> (tok, cache): one prompt
    chunk prefilled DIRECTLY into the slot's pages.

    ``cache`` is the paged pool cache (WITHOUT the page_table leaf — the
    batcher owns that on host); ``chunk`` the (1, C) token slice at absolute
    offset ``pos``; ``pt_row`` the slot's page-table row sliced to the live
    bucket, with every page the chunk's positions cover already allocated.
    Pool leaves are shared (the in-graph scatter + Pallas prefill kernel
    read/write them through ``pt_row``); per-slot leaves (mamba conv/ssm
    rows) are sliced to a batch=1 view so recurrent state threads across
    chunks, and written back at ``slot``.  ``tok`` is the argmax of the
    chunk's last position, computed in-graph — admission never ships logits
    to host, and only the final chunk's 4-byte token is fetched.  ``slot``
    and ``pos`` are traced; jit with the cache donated for in-place pool
    writes.
    """
    from repro.models.transformer import forward

    def chunk_prefill(params: Any, cache: Any, chunk: jax.Array,
                      pt_row: jax.Array, slot: jax.Array,
                      pos: jax.Array) -> tuple[jax.Array, Any]:
        flat = flatten_dict(cache)
        view = {k: (v if _num_pages_axis(k) else _slot_row(v, slot, num_slots))
                for k, v in flat.items()}
        view = unflatten_dict(view)
        view["page_table"] = pt_row[None, :]
        logits, _, vnew = forward(params, {"tokens": chunk}, cfg,
                                  cache=view, cache_len=pos)
        vnew.pop("page_table")
        flatn = flatten_dict(vnew)
        out = {k: (flatn[k] if _num_pages_axis(k)
                   else _place_row(v, flatn[k], slot, num_slots))
               for k, v in flat.items()}
        tok = jnp.argmax(logits[0, -1]).astype(jnp.int32)
        return tok, unflatten_dict(out)

    return chunk_prefill


def make_slot_chunk(cfg, num_slots: int):
    """(params, cache, chunk, slot, pos) -> cache: replay ``chunk`` through
    ONE slot's rows of a DENSE batch cache at absolute offset ``pos``.

    The speculative-decode rollback primitive for recurrent families in
    dense mode: the batched verify launch integrates all k+1 chunk tokens
    into the slot's conv/ssm/rwkv rows, so a partial accept restores the
    pre-round rows (``make_restore_slot``) and replays only the committed
    tokens here — K/V rewrites are bit-identical to the verify's (same
    model, same positions), and the recurrent rows end exactly where
    token-by-token decoding would leave them.  Logits are discarded: the
    committed tokens were already chosen by the verify launch.  ``slot`` and
    ``pos`` are traced; one compile per replay width.
    """
    from repro.models.transformer import forward

    def slot_chunk(params: Any, cache: Any, chunk: jax.Array,
                   slot: jax.Array, pos: jax.Array) -> Any:
        flat = flatten_dict(cache)
        view = unflatten_dict({k: _slot_row(v, slot, num_slots)
                               for k, v in flat.items()})
        _, _, vnew = forward(params, {"tokens": chunk}, cfg,
                             cache=view, cache_len=pos)
        flatn = flatten_dict(vnew)
        out = {k: _place_row(v, flatn[k], slot, num_slots)
               for k, v in flat.items()}
        return unflatten_dict(out)

    return slot_chunk


def make_fork_page():
    """(cache, src, dst) -> cache with physical page ``dst`` holding a copy
    of ``src`` across every pool leaf (all layers, one call per fork).

    The copy-on-write primitive: before any write that would touch a page
    with refcount > 1 (or whose content is registered in the prefix index),
    the batcher acquires a fresh page, forks the shared one into it, and
    repoints the slot's page-table row — the shared original is never
    mutated.  ``src``/``dst`` are traced scalars, so every fork reuses one
    compiled executable; jit with the cache donated for an in-place
    scatter.  Per-slot (non-pool) leaves pass through untouched.
    """

    def fork_page(cache: Any, src: jax.Array, dst: jax.Array) -> Any:
        flat = flatten_dict(cache)
        out: dict[str, jax.Array] = {}
        for key, leaf in flat.items():
            if _num_pages_axis(key):
                page = jax.lax.dynamic_slice_in_dim(leaf, src, 1, axis=1)
                out[key] = jax.lax.dynamic_update_slice_in_dim(
                    leaf, page, dst, axis=1)
            else:
                out[key] = leaf
        return unflatten_dict(out)

    return fork_page


def make_get_slot_rows(num_slots: int):
    """(cache, slot) -> batch=1 pytree of the slot's per-slot (non-pool)
    rows — the recurrent state (mamba conv/ssm) the prefix index snapshots
    at page boundaries, since it is not page-addressable."""

    def get_slot_rows(cache: Any, slot: jax.Array) -> Any:
        flat = flatten_dict(cache)
        rows = {k: _slot_row(v, slot, num_slots)
                for k, v in flat.items() if not _num_pages_axis(k)}
        return unflatten_dict(rows)

    return get_slot_rows


def make_set_slot_rows(num_slots: int):
    """(cache, rows, slot) -> cache with the slot's per-slot rows replaced
    by ``rows`` (a batch=1 pytree from ``make_get_slot_rows``) — restores a
    prefix-cached recurrent-state snapshot at admission."""

    def set_slot_rows(cache: Any, rows: Any, slot: jax.Array) -> Any:
        flat, flatr = flatten_dict(cache), flatten_dict(rows)
        out = {k: (_place_row(v, flatr[k], slot, num_slots)
                   if k in flatr else v)
               for k, v in flat.items()}
        return unflatten_dict(out)

    return set_slot_rows


def make_zero_slot(num_slots: int):
    """(cache, slot) -> cache with ``slot``'s per-slot rows zeroed.

    Chunked prefill writes straight into the slot's rows, so a freshly
    admitted request must not see the previous occupant's recurrent state
    (mamba conv/ssm rows); pool leaves are untouched — stale page contents
    are dead the moment the table row is re-pointed.
    """

    def zero_slot(cache: Any, slot: jax.Array) -> Any:
        flat = flatten_dict(cache)
        out: dict[str, jax.Array] = {}
        for key, leaf in flat.items():
            if _num_pages_axis(key):
                out[key] = leaf
            else:
                row = _slot_row(leaf, slot, num_slots)
                out[key] = _place_row(leaf, jnp.zeros_like(row), slot,
                                      num_slots)
        return unflatten_dict(out)

    return zero_slot


def dense_to_paged(cache: dict[str, Any], page_size: int) -> dict[str, Any]:
    """Repage a dense cache with an identity page table (pure reshapes, runs
    under jit).  Slot i's logical page j maps to physical 1 + i*npg + j;
    page 0 is the prepended garbage page."""
    flat = flatten_dict(cache)
    out: dict[str, jax.Array] = {}
    table = None
    for key, leaf in flat.items():
        group, name = key.rsplit("/", 1) if "/" in key else ("", key)
        if name in ("k", "v") and leaf.ndim == 5:
            lx, b, kvh, s, hd = leaf.shape
            assert s % page_size == 0, (s, page_size)
            npg = s // page_size
            pages = leaf.reshape(lx, b, kvh, npg, page_size, hd)
            pages = jnp.moveaxis(pages, 3, 2)              # (Lx,B,npg,Hkv,..)
            pool = pages.reshape(lx, b * npg, kvh, page_size, hd)
            pool = jnp.concatenate(
                [jnp.zeros_like(pool[:, :1]), pool], axis=1)
            out[f"{group}/{name}_pages" if group else f"{name}_pages"] = pool
            table = (1 + jnp.arange(b * npg, dtype=jnp.int32)
                     ).reshape(b, npg)
        else:
            out[key] = leaf
    assert table is not None, "no pageable k/v leaves in cache"
    paged = unflatten_dict(out)
    paged["page_table"] = table
    return paged
