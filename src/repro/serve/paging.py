"""Paged KV cache: fixed-size pages in a shared pool + per-slot page tables.

The dense continuous-batching cache allocates (B, max_len) KV rows, so slot
admission is coupled to max_len and every decode step reads max_len worth of
K/V per slot.  This module decouples both:

* **pool** — K/V live in ``k_pages``/``v_pages`` leaves shaped
  (L, P, Hkv, page_size, hd): P fixed-size pages shared by all slots, with
  the leading layer axis matching the stacked-blocks ``lax.scan`` layout.
  **Physical page 0 is reserved as the garbage page**: page-table entries
  default to 0, so appends routed through an unallocated entry land in
  garbage (harmless — never attended to) instead of corrupting a live slot.
* **page table** — (B, max_pages_per_slot) int32, slot's logical page j ->
  physical page.  Host-owned by the batcher (``PagePool`` below hands out
  pages), shipped to device per decode tick sliced to the live-prefix
  bucket, so the decode-attention grid covers only pages in actual use.
* **append** — in-kernel: the attention layer scatters the new token's K/V
  into ``pool[pt[b, pos // ps], :, pos % ps]`` (decode) or the whole
  chunk's K/V into the pages its positions cover (chunked prefill); see
  models/attention.py.
* **admit** — ``make_chunk_prefill`` returns ONE jitted call that runs one
  prompt chunk *directly against the pool* through the slot's page-table
  row: the chunk's K/V are scattered straight into the slot's pages and
  attention reads the already-written prefix back through the same table
  (kernels/prefill_attention.py).  No dense batch=1 scratch cache is ever
  allocated and nothing is copied at admission time.  Per-slot O(1) leaves
  (mamba conv/ssm rows) are viewed as a batch=1 slice and written back, so
  recurrent state threads across chunks.  The slot index, page-table row
  and chunk offset are traced, so compiles are bounded by the O(log) set
  of (chunk width, table bucket) shapes.

``dense_to_paged`` converts a dense cache to the paged layout with an
identity page table (slot i owns pages 1 + i*npg .. 1 + (i+1)*npg - 1) —
pure reshapes, used by ``engine.scan_generate(page_size=N)`` to run the
fused rollout on the paged decode-attention kernel.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.serve.engine import init_cache
from repro.utils.trees import flatten_dict, unflatten_dict

PAGED_LEAF_SUFFIXES = ("k_pages", "v_pages")


def _num_pages_axis(key: str) -> bool:
    return key.rsplit("/", 1)[-1] in PAGED_LEAF_SUFFIXES


class PagePool:
    """Host-side free-list allocator over the shared page pool.

    Page 0 is the reserved garbage page and is never handed out.  ``alloc``
    is all-or-nothing (returns None if n pages are not available) so the
    scheduler can keep a request queued instead of half-admitting it.
    """

    def __init__(self, num_pages: int, page_size: int):
        assert num_pages >= 2, "pool needs the garbage page + >= 1 real page"
        self.num_pages = num_pages
        self.page_size = page_size
        self._free = list(range(num_pages - 1, 0, -1))   # pop() -> low first
        self._live: set[int] = set()

    def pages_for(self, tokens: int) -> int:
        return -(-tokens // self.page_size)

    def available(self) -> int:
        return len(self._free)

    def alloc(self, n: int) -> list[int] | None:
        if n > len(self._free):
            return None
        pages = [self._free.pop() for _ in range(n)]
        self._live.update(pages)
        return pages

    def free(self, pages: list[int]) -> None:
        for p in pages:
            assert p in self._live, f"double free / foreign page {p}"
            self._live.discard(p)
            self._free.append(p)


def page_bucket(live_pages: int, max_pages: int) -> int:
    """Power-of-two page-table width covering ``live_pages`` (bounds jit
    retraces to log2(max_pages) decode-step variants)."""
    b = 1
    while b < live_pages:
        b *= 2
    return min(b, max_pages)


def init_paged_cache(cfg: ModelConfig, batch: int, max_len: int, *,
                     page_size: int, num_pages: int,
                     dtype=None) -> dict[str, Any]:
    """Paged decode cache: shared page pool + zeroed (all-garbage) page
    table.  Only attention K/V leaves are paged; per-slot O(1) state
    (mamba conv/ssm) keeps its dense slot rows.  ``max_len`` only bounds the
    page-table WIDTH (max pages one slot may own) — it does not size the
    pool, which is the point: capacity is ``num_pages`` regardless of
    max_len."""
    dtype = dtype or cfg.compute_dtype
    assert max_len % page_size == 0, (max_len, page_size)
    max_pages = max_len // page_size
    l, kv, hd = cfg.num_layers, cfg.num_kv_heads, cfg.hd
    table = jnp.zeros((batch, max_pages), jnp.int32)

    if cfg.family in ("dense", "moe", "audio", "vlm"):
        return {"blocks": {
            "k_pages": jnp.zeros((l, num_pages, kv, page_size, hd), dtype),
            "v_pages": jnp.zeros((l, num_pages, kv, page_size, hd), dtype),
        }, "page_table": table}
    if cfg.family == "hybrid_mamba" and cfg.attn_every:
        cache = init_cache(cfg, batch, max_len, dtype)
        napp = cfg.num_layers // cfg.attn_every
        cache["shared_attn"] = {
            "k_pages": jnp.zeros((napp, num_pages, kv, page_size, hd), dtype),
            "v_pages": jnp.zeros((napp, num_pages, kv, page_size, hd), dtype),
        }
        cache["page_table"] = table
        return cache
    raise ValueError(f"family {cfg.family!r} has no pageable attention KV")


def _place_row(big: jax.Array, small: jax.Array, slot: jax.Array,
               num_slots: int) -> jax.Array:
    """Write small's batch row into big at ``slot`` (traced); the batch axis
    is the static axis sized num_slots in big and 1 in small."""
    zero = jnp.zeros((), jnp.int32)
    for ax in range(big.ndim):
        if big.shape[ax] == num_slots and small.shape[ax] == 1:
            idx = [zero] * big.ndim
            idx[ax] = slot
            return jax.lax.dynamic_update_slice(
                big, small.astype(big.dtype), tuple(idx))
    raise ValueError(f"no batch axis in {big.shape} vs {small.shape}")


def make_restore_slot(num_slots: int):
    """(cache, prev, slot) -> cache with ``slot``'s per-slot rows restored
    from ``prev``.

    Used when a paused decode tick must be undone for one slot: pool leaves
    (k_pages/v_pages) keep the NEW value — the paused slot's append landed
    in the garbage page, and other slots' appends are live — but per-slot
    recurrent state (mamba conv/ssm rows) advanced on a token that was
    discarded, and must roll back or the recompute double-feeds it.
    """

    def restore_slot(cache: Any, prev: Any, slot: jax.Array) -> Any:
        flat, flatp = flatten_dict(cache), flatten_dict(prev)
        out: dict[str, jax.Array] = {}
        for key, leaf in flat.items():
            if _num_pages_axis(key):
                out[key] = leaf                      # appends are idempotent
            else:
                row = _slot_row(flatp[key], slot, num_slots)
                out[key] = _place_row(leaf, row, slot, num_slots)
        return unflatten_dict(out)

    return restore_slot


def _slot_row(big: jax.Array, slot: jax.Array, num_slots: int) -> jax.Array:
    """Slice ``slot``'s batch row (kept as size-1 axis) out of a per-slot
    leaf.  Per-slot cache leaves are layer-stacked (L, B, ...): when
    L == num_slots the leading layer axis ties with the batch axis, so a
    size match at axis 0 defers to one at axis 1."""
    axes = [ax for ax in range(big.ndim) if big.shape[ax] == num_slots]
    if not axes:
        raise ValueError(f"no batch axis in {big.shape}")
    ax = 1 if (axes[0] == 0 and 1 in axes) else axes[0]
    return jax.lax.dynamic_slice_in_dim(big, slot, 1, axis=ax)


def has_slot_rows(cache: Any) -> bool:
    """True when the paged cache carries per-slot (non-pool) leaves — the
    recurrent rows chunked prefill must view/restore per slot."""
    return any(not _num_pages_axis(k) for k in flatten_dict(cache))


def make_chunk_prefill(cfg, num_slots: int):
    """(params, cache, chunk, pt_row, slot, pos) -> (tok, cache): one prompt
    chunk prefilled DIRECTLY into the slot's pages.

    ``cache`` is the paged pool cache (WITHOUT the page_table leaf — the
    batcher owns that on host); ``chunk`` the (1, C) token slice at absolute
    offset ``pos``; ``pt_row`` the slot's page-table row sliced to the live
    bucket, with every page the chunk's positions cover already allocated.
    Pool leaves are shared (the in-graph scatter + Pallas prefill kernel
    read/write them through ``pt_row``); per-slot leaves (mamba conv/ssm
    rows) are sliced to a batch=1 view so recurrent state threads across
    chunks, and written back at ``slot``.  ``tok`` is the argmax of the
    chunk's last position, computed in-graph — admission never ships logits
    to host, and only the final chunk's 4-byte token is fetched.  ``slot``
    and ``pos`` are traced; jit with the cache donated for in-place pool
    writes.
    """
    from repro.models.transformer import forward

    def chunk_prefill(params: Any, cache: Any, chunk: jax.Array,
                      pt_row: jax.Array, slot: jax.Array,
                      pos: jax.Array) -> tuple[jax.Array, Any]:
        flat = flatten_dict(cache)
        view = {k: (v if _num_pages_axis(k) else _slot_row(v, slot, num_slots))
                for k, v in flat.items()}
        view = unflatten_dict(view)
        view["page_table"] = pt_row[None, :]
        logits, _, vnew = forward(params, {"tokens": chunk}, cfg,
                                  cache=view, cache_len=pos)
        vnew.pop("page_table")
        flatn = flatten_dict(vnew)
        out = {k: (flatn[k] if _num_pages_axis(k)
                   else _place_row(v, flatn[k], slot, num_slots))
               for k, v in flat.items()}
        tok = jnp.argmax(logits[0, -1]).astype(jnp.int32)
        return tok, unflatten_dict(out)

    return chunk_prefill


def make_zero_slot(num_slots: int):
    """(cache, slot) -> cache with ``slot``'s per-slot rows zeroed.

    Chunked prefill writes straight into the slot's rows, so a freshly
    admitted request must not see the previous occupant's recurrent state
    (mamba conv/ssm rows); pool leaves are untouched — stale page contents
    are dead the moment the table row is re-pointed.
    """

    def zero_slot(cache: Any, slot: jax.Array) -> Any:
        flat = flatten_dict(cache)
        out: dict[str, jax.Array] = {}
        for key, leaf in flat.items():
            if _num_pages_axis(key):
                out[key] = leaf
            else:
                row = _slot_row(leaf, slot, num_slots)
                out[key] = _place_row(leaf, jnp.zeros_like(row), slot,
                                      num_slots)
        return unflatten_dict(out)

    return zero_slot


def dense_to_paged(cache: dict[str, Any], page_size: int) -> dict[str, Any]:
    """Repage a dense cache with an identity page table (pure reshapes, runs
    under jit).  Slot i's logical page j maps to physical 1 + i*npg + j;
    page 0 is the prepended garbage page."""
    flat = flatten_dict(cache)
    out: dict[str, jax.Array] = {}
    table = None
    for key, leaf in flat.items():
        group, name = key.rsplit("/", 1) if "/" in key else ("", key)
        if name in ("k", "v") and leaf.ndim == 5:
            lx, b, kvh, s, hd = leaf.shape
            assert s % page_size == 0, (s, page_size)
            npg = s // page_size
            pages = leaf.reshape(lx, b, kvh, npg, page_size, hd)
            pages = jnp.moveaxis(pages, 3, 2)              # (Lx,B,npg,Hkv,..)
            pool = pages.reshape(lx, b * npg, kvh, page_size, hd)
            pool = jnp.concatenate(
                [jnp.zeros_like(pool[:, :1]), pool], axis=1)
            out[f"{group}/{name}_pages" if group else f"{name}_pages"] = pool
            table = (1 + jnp.arange(b * npg, dtype=jnp.int32)
                     ).reshape(b, npg)
        else:
            out[key] = leaf
    assert table is not None, "no pageable k/v leaves in cache"
    paged = unflatten_dict(out)
    paged["page_table"] = table
    return paged
