"""Serving-layer fault tolerance: supervision, deadlines, load shedding,
and crash-safe snapshot/restore of the continuous batcher.

``ServingSupervisor`` wraps a ``ContinuousBatcher`` and turns its happy-path
tick loop into a production failure contract:

* **typed admission** — ``submit()`` returns :class:`Accepted` or a typed
  :class:`Rejected` backpressure verdict instead of queuing unboundedly:
  ``queue_full`` (waiting deque at ``max_queue_depth``), ``overloaded``
  (pool/slot utilization above ``shed_utilization`` with a non-empty
  queue), or ``unservable`` (the batcher's own validation — prompt too
  long for max_len or the page pool).  Shed requests are recorded, never
  raised mid-traffic.
* **deadlines / TTL** — every accepted request may carry a deadline in
  supervisor ticks; an expired request is aborted wherever it lives
  (queued, mid-admission, decoding) with ``failed="deadline"`` and shows up
  in the final :class:`ServeReport` — expiry is reported, never silent.
* **crash recovery** — a tick that raises ``SimulatedDeviceFailure`` (or
  any ``SimulatedFailure``) is retried through the existing
  ``RestartPolicy`` (bounded restarts, exponential backoff with optional
  deterministic jitter): the batcher is restored from the newest snapshot
  and the lost ticks replay.  Greedy decode is deterministic, so replayed
  requests re-emit bit-identical tokens.
* **snapshot/restore** — ``capture_state``/``apply_state`` serialize the
  FULL batcher state: host queues and slot metadata, page tables + pool
  refcounts/free-list/LRU, the prefix index (hash chain + recurrent-row
  snapshots), the in-flight admission, and every device cache leaf.
  ``save_snapshot``/``load_snapshot`` persist that through
  ``checkpoint/ckpt.py`` (atomic rename, keep-k GC), so a killed server
  process resumes mid-stream token-identically — see
  ``checkpoint/serving_snapshot.md`` for the on-disk format.

The supervisor owns the *global* tick clock (``self.tick``) and the fault
injector's clock: neither rewinds on recovery, so deadlines keep their
meaning across restores and one-shot injected faults never re-fire during
replay.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.ckpt import CheckpointManager
from repro.core.allocate import describe_packed_plan
from repro.runtime.fault_tolerance import RestartPolicy, SimulatedFailure
from repro.serve.batching import ContinuousBatcher, Request, _Admission

# ---------------------------------------------------------------------------
# typed submit results
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Accepted:
    rid: int
    deadline_tick: int | None = None
    accepted: bool = True


@dataclasses.dataclass(frozen=True)
class Rejected:
    """Typed backpressure/shed verdict — the caller decides whether to
    retry elsewhere, back off, or fail upstream."""
    rid: int
    reason: str                # "queue_full" | "overloaded" | "unservable"
    detail: str = ""
    queue_depth: int = 0
    utilization: float = 0.0
    accepted: bool = False


@dataclasses.dataclass
class ServeReport:
    """End-of-run accounting: every submitted request is in exactly one of
    completed / failed / pending; shed requests never entered the queue."""
    ticks: int
    completed: list[int]
    failed: dict[int, str]             # rid -> reason (deadline, nan, ...)
    expired: list[int]                 # the failed subset with reason=deadline
    pending: list[int]                 # only non-empty when max_ticks ran out
    shed: int
    recoveries: int
    snapshots: int
    nan_events: int


# ---------------------------------------------------------------------------
# snapshot / restore
# ---------------------------------------------------------------------------


def _request_state(req: Request) -> dict:
    return {"rid": req.rid, "prompt": [int(t) for t in req.prompt],
            "max_new_tokens": req.max_new_tokens, "eos_id": req.eos_id,
            "output": list(req.output),
            "prefix_counted": bool(req.prefix_counted)}


def capture_state(batcher: ContinuousBatcher) -> tuple[dict, dict]:
    """(host, device): the complete batcher state.  ``host`` is
    JSON-serializable (queues, slot metadata, page table, pool allocator,
    prefix index, in-flight admission, counters); ``device`` is a pytree of
    array leaves (the cache pool, the dense admission scratch, the prefix
    index's recurrent-row snapshots)."""
    b = batcher
    live = list(b.queue) + [r for r in b.slot_req if r is not None]
    host: dict[str, Any] = {
        "geometry": {
            "num_slots": b.b, "max_len": b.max_len, "paged": b.paged,
            "page_size": b.page_size if b.paged else 0,
            "num_pages": b.pool.num_pages if b.paged else 0,
            "chunk_tokens": b.chunk_tokens,
            "prefix_cache": b.prefix is not None,
            "nan_guard": b.nan_guard, "nan_retry_limit": b.nan_retry_limit,
            "family": b.cfg.family,
            "tp": b.plan.tp if b.plan is not None else 1,
            "spec_k": b.spec_k,
            "draft_bits": getattr(b, "draft_bits", 2),
        },
        # the effective per-layer precision layout (path -> bits/block_size/
        # rank, derived from the packed tree) — a mixed-precision QuantPlan
        # server restores ONLY into a batcher whose params agree leaf-for-
        # leaf, so heterogeneous serving round-trips exactly
        "quant_plan": describe_packed_plan(b.params),
        # tensor-parallel batchers record the serving-mesh spec and store
        # every SHARDED cache leaf as a stacked (tp, ...) array of its
        # per-device shards (see ServingPlan.to_host_shards) — restore
        # validates shard compatibility instead of silently reassembling
        # onto a mismatched mesh
        "mesh": b.plan.mesh_spec() if b.plan is not None else None,
        "tick": b.tick_count,
        "lengths": b.lengths.tolist(),
        "last_tok": b.last_tok.tolist(),
        "slot_rids": [r.rid if r is not None else None for r in b.slot_req],
        "queue": [r.rid for r in b.queue],
        "requests": [_request_state(r) for r in live],
        "counters": {
            "admission_rollbacks": b.admission_rollbacks,
            "cow_forks": b.cow_forks, "nan_events": b.nan_events,
            "nan_strikes": b._nan_strikes.tolist(),
            "nan_quarantined": list(b.nan_quarantined),
            "completed_rids": list(b.completed_rids),
            "failed_rids": {str(k): v for k, v in b.failed_rids.items()},
        },
    }
    dev: dict[str, Any] = {"cache": b.cache}
    if b.plan is not None:
        dev["cache"] = b.plan.to_host_shards(b.cache,
                                             b.plan.cache_specs(b.cache))
    if b.paged:
        host["page_table"] = b.page_table.tolist()
        host["slot_pages"] = [list(p) for p in b.slot_pages]
        host["starved"] = list(b._starved)
        host["pool"] = b.pool.state()
        if b.prefix is not None:
            pjson, psnaps = b.prefix.state()
            host["prefix"] = pjson
            if psnaps:
                dev["prefix_state"] = psnaps
    adm = b._adm
    if adm is not None:
        host["adm"] = {
            "rid": adm.req.rid, "slot": adm.slot, "plan": list(adm.plan),
            "done": adm.done, "registered": adm.registered,
            "hashes": ([h.hex() for h in adm.hashes]
                       if adm.hashes is not None else None),
            "has_cache1": adm.cache1 is not None,
        }
        if adm.cache1 is not None:
            dev["adm_cache1"] = adm.cache1
            if b.plan is not None:
                dev["adm_cache1"] = b.plan.to_host_shards(
                    adm.cache1, b.plan.cache_specs(adm.cache1))
    else:
        host["adm"] = None
    return host, dev


def apply_state(batcher: ContinuousBatcher, host: dict, dev: dict,
                requests: dict[int, Request] | None = None
                ) -> dict[int, Request]:
    """Overwrite ``batcher``'s state with a snapshot.  ``requests`` maps
    rid -> existing Request objects to restore IN PLACE (in-process crash
    recovery: callers holding references see outputs rolled back to the
    snapshot); missing rids get fresh Request objects (new-process
    restore).  Returns the rid -> Request map actually used."""
    b = batcher
    g = host["geometry"]
    assert g["num_slots"] == b.b and g["max_len"] == b.max_len \
        and g["paged"] == b.paged, "snapshot/batcher geometry mismatch"
    # shard compatibility: a snapshot taken at tp=N stores per-shard cache
    # leaves — restoring into a batcher on a different mesh would misread
    # the stacked shard axis, so fail loudly with the fix spelled out.
    # ``.get`` keeps pre-TP snapshots (no "tp" key) restorable at tp=1.
    snap_tp = g.get("tp", 1)
    have_tp = b.plan.tp if b.plan is not None else 1
    if snap_tp != have_tp:
        raise ValueError(
            f"snapshot was taken on a tp={snap_tp} serving mesh but this "
            f"batcher runs tp={have_tp}; rebuild the batcher with "
            f"mesh=make_serving_mesh(tp={snap_tp}) to restore it "
            f"(mesh spec in snapshot: {host.get('mesh')})")
    # precision-layout compatibility: a mixed-precision snapshot must land
    # on params with the SAME per-layer (bits, block_size, rank) layout —
    # a silently different plan would replay greedy streams on different
    # weights.  ``.get`` keeps pre-plan snapshots restorable unchecked.
    snap_plan = host.get("quant_plan")
    if snap_plan is not None:
        have_plan = describe_packed_plan(b.params)
        if snap_plan != have_plan:
            diff = sorted(
                p for p in set(snap_plan) | set(have_plan)
                if snap_plan.get(p) != have_plan.get(p))[:8]
            raise ValueError(
                f"snapshot quant plan does not match this batcher's params "
                f"(first differing layers: {diff}); re-quantize/pack with "
                f"the snapshot's QuantPlan before restoring")
    requests = dict(requests or {})
    by_rid: dict[int, Request] = {}
    for rs in host["requests"]:
        req = requests.get(rs["rid"])
        if req is None:
            req = Request(rid=rs["rid"],
                          prompt=np.asarray(rs["prompt"], np.int32),
                          max_new_tokens=rs["max_new_tokens"],
                          eos_id=rs["eos_id"])
        # live-at-snapshot: whatever happened since (completion, failure,
        # extra tokens) rolls back; greedy replay re-derives it identically
        req.output[:] = rs["output"]
        req.done, req.failed = False, None
        req.prefix_counted = rs["prefix_counted"]
        by_rid[req.rid] = req
    b.queue = deque(by_rid[rid] for rid in host["queue"])
    b.slot_req = [by_rid[rid] if rid is not None else None
                  for rid in host["slot_rids"]]
    b.lengths = np.asarray(host["lengths"], np.int32)
    b.last_tok = np.asarray(host["last_tok"], np.int32)
    b.tick_count = host["tick"]
    c = host["counters"]
    b.admission_rollbacks = c["admission_rollbacks"]
    b.cow_forks = c["cow_forks"]
    b.nan_events = c["nan_events"]
    b._nan_strikes = np.asarray(c["nan_strikes"], np.int32)
    b.nan_quarantined = list(c["nan_quarantined"])
    b.completed_rids = list(c["completed_rids"])
    b.failed_rids = {int(k): v for k, v in c["failed_rids"].items()}
    if b.paged:
        b.pool.load_state(host["pool"])
        b.page_table = np.asarray(host["page_table"], np.int32)
        b.slot_pages = [list(p) for p in host["slot_pages"]]
        b._starved = list(host["starved"])
        if b.prefix is not None:
            b.prefix.load_state(host.get("prefix", {"entries": [], "hits": 0,
                                                    "misses": 0,
                                                    "hit_tokens": 0}),
                                dev.get("prefix_state", {}))
    if b.plan is not None:
        # specs come from the live batcher cache — the snapshot tree has the
        # extra leading (tp,) shard axis, so it can't describe itself
        cspecs = b.plan.cache_specs(b.cache)
        b.cache = b.plan.from_host_shards(dev["cache"], cspecs)
    else:
        b.cache = jax.tree.map(jnp.asarray, dev["cache"])
    a = host["adm"]
    if a is None:
        b._adm = None
    else:
        if a["has_cache1"]:
            if b.plan is not None:
                # dense scratch shares the dense cache's structural specs
                cache1 = b.plan.from_host_shards(dev["adm_cache1"], cspecs)
            else:
                cache1 = jax.tree.map(jnp.asarray, dev["adm_cache1"])
        else:
            cache1 = None
        b._adm = _Admission(
            req=by_rid[a["rid"]], slot=a["slot"], plan=list(a["plan"]),
            done=a["done"], registered=a["registered"],
            hashes=([bytes.fromhex(h) for h in a["hashes"]]
                    if a["hashes"] is not None else None),
            cache1=cache1)
    return by_rid


def save_snapshot(manager: CheckpointManager,
                  batcher: ContinuousBatcher) -> Any:
    """Persist a crash-safe snapshot through the checkpoint manager (atomic
    rename, keep-k GC).  The snapshot step is the batcher tick, so replays
    that re-reach a tick simply overwrite its snapshot."""
    host, dev = capture_state(batcher)
    return manager.save(batcher.tick_count, dev, extra=host)


def load_snapshot(manager: CheckpointManager, params: Any, cfg: Any, *,
                  step: int | None = None,
                  requests: dict[int, Request] | None = None,
                  fault_injector: Any = None, mesh: Any = None
                  ) -> tuple[ContinuousBatcher, dict[int, Request]]:
    """Rebuild a batcher (fresh process) from the newest (or given)
    snapshot.  Returns (batcher, rid -> Request) — resuming ``run()`` on the
    result continues every in-flight stream token-identically.

    A snapshot taken on a tp>1 serving mesh must be given a compatible
    ``mesh`` (same tp extent) — ``apply_state`` validates and raises
    otherwise; a pre-TP snapshot restores with ``mesh=None`` unchanged."""
    _, dev, host = manager.restore(step)
    g = host["geometry"]
    batcher = ContinuousBatcher(
        params, cfg, num_slots=g["num_slots"], max_len=g["max_len"],
        paged=g["paged"], page_size=g["page_size"] or 32,
        num_pages=g["num_pages"] or None, chunk_tokens=g["chunk_tokens"],
        prefix_cache=g["prefix_cache"], fault_injector=fault_injector,
        nan_guard=g["nan_guard"], nan_retry_limit=g["nan_retry_limit"],
        mesh=mesh, spec_k=g.get("spec_k", 0),
        draft_bits=g.get("draft_bits", 2))
    by_rid = apply_state(batcher, host, dev, requests)
    return batcher, by_rid


# ---------------------------------------------------------------------------
# the supervisor
# ---------------------------------------------------------------------------


class ServingSupervisor:
    def __init__(self, batcher: ContinuousBatcher, *,
                 injector: Any = None, policy: RestartPolicy | None = None,
                 ckpt: CheckpointManager | None = None,
                 snapshot_every: int = 0, max_queue_depth: int = 64,
                 shed_utilization: float = 1.0,
                 default_ttl_ticks: int | None = None,
                 sleep: Callable[[float], None] = time.sleep):
        self.batcher = batcher
        if injector is not None:
            batcher.injector = injector
        self.injector = batcher.injector
        self.policy = policy or RestartPolicy()
        self.ckpt = ckpt
        self.snapshot_every = snapshot_every
        self.max_queue_depth = max_queue_depth
        self.shed_utilization = shed_utilization
        self.default_ttl_ticks = default_ttl_ticks
        self.sleep = sleep
        self.tick = 0                       # global; never rewound
        self.requests: dict[int, Request] = {}
        self.deadlines: dict[int, int] = {}
        self.shed: list[Rejected] = []
        self.expired: list[int] = []
        self.recoveries = 0
        self.snapshots_taken = 0
        self._restarts = 0                  # consecutive, reset on progress
        self._mem_snap: tuple[dict, dict] | None = None

    # -- admission ------------------------------------------------------------
    def utilization(self) -> float:
        b = self.batcher
        if b.paged:
            alloc = b.pool.num_pages - 1
            return 1.0 - b.pool.available() / alloc if alloc else 1.0
        return sum(r is not None for r in b.slot_req) / b.b

    def submit(self, req: Request,
               ttl_ticks: int | None = None) -> Accepted | Rejected:
        depth = len(self.batcher.queue)
        util = self.utilization()
        if depth >= self.max_queue_depth:
            rej = Rejected(req.rid, "queue_full", queue_depth=depth,
                           utilization=util,
                           detail=f"waiting depth {depth} >= "
                                  f"{self.max_queue_depth}")
        elif util >= self.shed_utilization and depth > 0:
            rej = Rejected(req.rid, "overloaded", queue_depth=depth,
                           utilization=util,
                           detail=f"utilization {util:.2f} >= "
                                  f"{self.shed_utilization:.2f}")
        else:
            try:
                self.batcher.submit(req)
            except ValueError as e:
                rej = Rejected(req.rid, "unservable", queue_depth=depth,
                               utilization=util, detail=str(e))
            else:
                self.requests[req.rid] = req
                ttl = (ttl_ticks if ttl_ticks is not None
                       else self.default_ttl_ticks)
                deadline = None
                if ttl is not None:
                    deadline = self.tick + ttl
                    self.deadlines[req.rid] = deadline
                return Accepted(req.rid, deadline)
        self.shed.append(rej)
        return rej

    # -- snapshots ------------------------------------------------------------
    def snapshot(self) -> None:
        """Capture restore state now: to disk when a checkpoint manager is
        attached (crash-safe across processes), else in memory (enough for
        in-process recovery and a lot cheaper)."""
        if self.ckpt is not None:
            save_snapshot(self.ckpt, self.batcher)
        else:
            host, dev = capture_state(self.batcher)
            self._mem_snap = (host, jax.tree.map(np.asarray, dev))
        self.snapshots_taken += 1

    def _recover(self, err: SimulatedFailure) -> None:
        self._restarts += 1
        if self._restarts > self.policy.max_restarts:
            raise err
        self.sleep(self.policy.backoff(self._restarts))
        if self.ckpt is not None and self.ckpt.latest_step() is not None:
            _, dev, host = self.ckpt.restore()
            apply_state(self.batcher, host, dev, self.requests)
        elif self._mem_snap is not None:
            host, dev = self._mem_snap
            apply_state(self.batcher, host, dev, self.requests)
        else:
            raise err                     # nothing to restore from
        self.recoveries += 1

    # -- the supervised tick --------------------------------------------------
    def _expire(self) -> None:
        for rid, deadline in list(self.deadlines.items()):
            req = self.requests[rid]
            if req.finished:
                del self.deadlines[rid]
                continue
            if self.tick > deadline:
                if self.batcher.abort(req, "deadline"):
                    self.expired.append(rid)
                del self.deadlines[rid]

    def step(self) -> None:
        self.tick += 1
        if self.injector is not None:
            self.injector.begin_tick()
            self.injector.pre_tick(
                self.batcher.pool if self.batcher.paged else None,
                sleep=self.sleep)
        self._expire()
        try:
            self.batcher.step()
        except SimulatedFailure as e:
            self._recover(e)
            return
        self._restarts = 0                # a clean tick resets the budget
        if self.snapshot_every and self.tick % self.snapshot_every == 0:
            self.snapshot()

    def run(self, max_ticks: int = 10_000) -> ServeReport:
        b = self.batcher
        if (self.snapshot_every or self.ckpt is not None) \
                and self.snapshots_taken == 0:
            self.snapshot()               # recovery base before tick 1
        t0 = self.tick
        while self.tick - t0 < max_ticks:
            if not b.queue and b._adm is None and not b._active():
                break
            self.step()
        completed = [r.rid for r in self.requests.values() if r.done]
        failed = {r.rid: r.failed for r in self.requests.values()
                  if r.failed is not None}
        return ServeReport(
            ticks=self.tick - t0, completed=completed, failed=failed,
            expired=[rid for rid, why in failed.items() if why == "deadline"],
            pending=b.pending_rids(), shed=len(self.shed),
            recoveries=self.recoveries, snapshots=self.snapshots_taken,
            nan_events=b.nan_events)
