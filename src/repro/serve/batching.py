"""Continuous batching: slot-based request scheduler over prefill/decode.

The production pattern (vLLM-style, simplified to the parts that matter for
QER serving): a fixed pool of B slots shares one decode step; new requests
are prefilled into a free slot's cache region while other slots keep
decoding; finished slots are freed immediately.

Implementation notes for the JAX runtime:
* one (B, max_len) KV cache, slot = batch row; per-slot lengths vector;
* prefill computes the prompt with batch=1 and writes its cache rows into
  the slot via ONE jitted ``place_slot`` call with the big cache donated
  (zero-copy admission: XLA updates the cache in place instead of copying
  every leaf, and the slot index is a traced scalar so one compile serves
  every slot);
* decode advances ALL active slots each step with a single decode_step call
  (inactive slots are masked out of sampling).

Paged mode (``paged=True``, see serve/paging.py):
* K/V rows are replaced by a shared **page pool** + host-owned page tables;
  admission becomes page **allocation** (``PagePool.alloc``) + ONE jitted
  ``place_pages`` scatter into exactly the pages the request owns, so
  capacity is bounded by pool pages actually in use — not B x max_len;
* each tick ships the page table sliced to the live-prefix **bucket**
  (power-of-two page count covering the longest active context), so the
  Pallas decode-attention kernel reads only live pages: attention bytes
  scale with the context in use, never with max_len;
* a slot whose next token crosses a page boundary allocates lazily before
  the tick; if the pool is empty the slot **pauses** — its append lands in
  the reserved garbage page, its sampled token is discarded, and the same
  token is recomputed once a page frees (greedy decode is deterministic);
* freeing a slot returns its pages to the pool and zeroes its table row.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.serve.engine import init_cache, make_decode_step, make_prefill_step
from repro.serve.paging import (
    PagePool,
    _place_row,
    init_paged_cache,
    make_place_pages,
    make_restore_slot,
    page_bucket,
)


def make_place_slot(num_slots: int) -> Callable:
    """(cache, cache1, slot) -> cache with cache1's batch row written at slot.

    The batch axis differs per leaf family; it is the (static) axis whose
    size == num_slots in the big leaf and 1 in the small one.  ``slot`` is a
    traced scalar, so the jitted function compiles once for all slots; jit
    with ``donate_argnums=(0,)`` to update the cache buffers in place.
    """

    def place_slot(cache: Any, cache1: Any, slot: jax.Array) -> Any:
        return jax.tree.map(
            lambda big, small: _place_row(big, small, slot, num_slots),
            cache, cache1)

    return place_slot


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                 # (len,) int32
    max_new_tokens: int = 16
    eos_id: int | None = None
    # filled by the engine
    output: list = dataclasses.field(default_factory=list)
    done: bool = False


class ContinuousBatcher:
    def __init__(self, params: Any, cfg: ModelConfig, *, num_slots: int = 4,
                 max_len: int = 256, paged: bool = False, page_size: int = 32,
                 num_pages: int | None = None):
        self.params, self.cfg = params, cfg
        self.paged = paged
        # page geometry needs a page-multiple length; the request done-check
        # keeps the CALLER's max_len so paged stays token-identical to dense
        # even when max_len % page_size != 0.
        alloc_len = -(-max_len // page_size) * page_size if paged else max_len
        self.b, self.max_len = num_slots, max_len
        self.lengths = np.zeros(num_slots, np.int32)
        self.slot_req: list[Request | None] = [None] * num_slots
        self.last_tok = np.zeros(num_slots, np.int32)
        self._prefill = jax.jit(make_prefill_step(cfg, max_len=alloc_len))
        self._decode = jax.jit(make_decode_step(cfg))
        # donate the big cache so admission is a true in-place slot write
        # (no full-cache copy); CPU ignores donation, so only request it on
        # backends that implement it to avoid per-call warnings.
        donate = (0,) if jax.default_backend() in ("tpu", "gpu") else ()
        if paged:
            self.page_size = page_size
            self.max_pages_per_slot = alloc_len // page_size
            # default pool is lossless (every slot can grow to max_len);
            # pass a smaller num_pages to actually oversubscribe.
            num_pages = num_pages or 1 + num_slots * self.max_pages_per_slot
            self.pool = PagePool(num_pages, page_size)
            self.cache = init_paged_cache(
                cfg, num_slots, alloc_len, page_size=page_size,
                num_pages=num_pages)
            # host-owned page table; shipped per tick sliced to the bucket
            self.cache.pop("page_table")
            self.page_table = np.zeros(
                (num_slots, self.max_pages_per_slot), np.int32)
            self.slot_pages: list[list[int]] = [[] for _ in range(num_slots)]
            self._starved: list[int] = []    # slots paused on the last tick
            self._place = jax.jit(make_place_pages(num_slots, page_size),
                                  donate_argnums=donate)
            self._restore = jax.jit(make_restore_slot(num_slots),
                                    donate_argnums=donate)
        else:
            self.cache = init_cache(cfg, num_slots, max_len)
            self._place = jax.jit(make_place_slot(num_slots),
                                  donate_argnums=donate)
        self.queue: list[Request] = []

    # -- admission -----------------------------------------------------------
    def submit(self, req: Request) -> None:
        if self.paged:
            need = self.pool.pages_for(len(req.prompt))
            if need > self.pool.num_pages - 1:
                # reject up front: queued it would stall admission forever
                raise ValueError(
                    f"request {req.rid}: prompt needs {need} pages but the "
                    f"pool has {self.pool.num_pages - 1} allocatable")
        self.queue.append(req)

    def _free_slots(self) -> list[int]:
        return [i for i, r in enumerate(self.slot_req) if r is None]

    def _admit(self) -> None:
        if self.paged and self._starved and self._active():
            # running slots are stalled on page allocation: freed pages must
            # grow them first, or admission (notably of a just-evicted
            # request) steals the page back and the pool thrashes
            return
        for slot in self._free_slots():
            if not self.queue:
                return
            req = self.queue[0]
            pages: list[int] | None = None
            if self.paged:
                need = self.pool.pages_for(len(req.prompt))
                pages = self.pool.alloc(need)
                if pages is None:          # pool exhausted: wait for frees
                    return
            self.queue.pop(0)
            prompt = jnp.asarray(req.prompt[None, :])            # (1, len)
            logits, cache1 = self._prefill(self.params, {"tokens": prompt})
            if self.paged:
                # scatter the prefix into exactly the pages this request
                # owns: one jitted call, page-table row + slot traced
                self.page_table[slot, :] = 0
                self.page_table[slot, :len(pages)] = pages
                self.slot_pages[slot] = pages
                self.cache = self._place(
                    self.cache, cache1,
                    jnp.asarray(self.page_table[slot]),
                    jnp.asarray(slot, jnp.int32))
            else:
                # write the single-row cache into this slot's row: one jitted
                # call, slot as a traced scalar (prompt cache rows were
                # already padded to max_len inside prefill)
                self.cache = self._place(self.cache, cache1,
                                         jnp.asarray(slot, jnp.int32))
            tok = int(jnp.argmax(logits[0, -1]))
            req.output.append(tok)
            self.slot_req[slot] = req
            self.lengths[slot] = len(req.prompt)
            self.last_tok[slot] = tok

    # -- decode tick ----------------------------------------------------------
    def _active(self) -> list[int]:
        return [i for i, r in enumerate(self.slot_req) if r is not None]

    def _grow_pages(self, active: list[int]) -> list[int]:
        """Lazily allocate the page each active slot's next token lands in.
        Returns the slots that must pause this tick (pool empty): their
        append hits the garbage page and their token is discarded — greedy
        decode recomputes the identical token once a page frees."""
        paused = []
        for i in active:
            lp = self.lengths[i] // self.page_size
            if self.page_table[i, lp] == 0:
                pg = self.pool.alloc(1)
                if pg is None:
                    paused.append(i)
                    continue
                self.page_table[i, lp] = pg[0]
                self.slot_pages[i].append(pg[0])
        return paused

    def _evict(self, slot: int) -> None:
        """Preempt-and-requeue: release the slot's pages and put its request
        back at the head of the queue with output cleared — greedy decode is
        deterministic, so re-admission recomputes the same tokens."""
        req = self.slot_req[slot]
        req.output.clear()
        self.queue.insert(0, req)
        self.slot_req[slot] = None
        self.pool.free(self.slot_pages[slot])
        self.slot_pages[slot] = []
        self.page_table[slot, :] = 0
        self.lengths[slot] = 0

    def step(self) -> None:
        self._admit()
        active = self._active()
        if not active:
            return
        # single fused decode for all slots (inactive rows are don't-care);
        # per-slot cache lengths keep each request's positions independent
        paused: list[int] = []
        toks = jnp.asarray(self.last_tok[:, None])
        clen = jnp.asarray(self.lengths, jnp.int32)          # (B,)
        if self.paged:
            paused = self._grow_pages(active)
            self._starved = list(paused)
            if paused and len(paused) == len(active):
                # every active slot stalled on allocation: no tick can ever
                # free a page, so preempt one request to restore progress
                if len(active) == 1:
                    raise RuntimeError(
                        f"page pool ({self.pool.num_pages} pages, page_size="
                        f"{self.page_size}) too small for request "
                        f"{self.slot_req[active[0]].rid} alone")
                self._evict(paused.pop())
                return
            # paused slots' appends land in the garbage page and their
            # tokens are discarded, but per-slot recurrent state (mamba
            # conv/ssm rows) would still advance on the discarded token —
            # keep the pre-tick cache to roll those rows back below.
            prev = self.cache if paused else None
            live = max(-(-int(self.lengths[i] + 1) // self.page_size)
                       for i in active)
            bucket = page_bucket(live, self.max_pages_per_slot)
            cache = {**self.cache,
                     "page_table": jnp.asarray(self.page_table[:, :bucket])}
            logits, cache = self._decode(self.params, cache,
                                         {"tokens": toks}, clen)
            cache.pop("page_table")
            self.cache = cache
            for i in paused:
                self.cache = self._restore(self.cache, prev,
                                           jnp.asarray(i, jnp.int32))
        else:
            logits, self.cache = self._decode(self.params, self.cache,
                                              {"tokens": toks}, clen)
        nxt = np.asarray(jnp.argmax(logits[:, -1], axis=-1), np.int32)
        for i in active:
            if i in paused:
                continue
            req = self.slot_req[i]
            tok = int(nxt[i])
            req.output.append(tok)
            self.lengths[i] += 1
            self.last_tok[i] = tok
            hit_eos = req.eos_id is not None and tok == req.eos_id
            if (len(req.output) >= req.max_new_tokens or hit_eos
                    or self.lengths[i] + 1 >= self.max_len):
                req.done = True
                self.slot_req[i] = None      # slot freed; admitted next tick
                if self.paged:
                    self.pool.free(self.slot_pages[i])
                    self.slot_pages[i] = []
                    self.page_table[i, :] = 0
                    self.lengths[i] = 0   # freed row attends 1 garbage token

    def run(self, max_ticks: int = 1000) -> None:
        for _ in range(max_ticks):
            if not self.queue and not self._active():
                return
            self.step()
