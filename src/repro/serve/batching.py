"""Continuous batching: slot-based request scheduler over prefill/decode.

The production pattern (vLLM-style, simplified to the parts that matter for
QER serving): a fixed pool of B slots shares one decode step; new requests
are prefilled into a free slot's cache region while other slots keep
decoding; finished slots are freed immediately.

Implementation notes for the JAX runtime:
* one (B, max_len) KV cache, slot = batch row; per-slot lengths vector;
* prefill computes the prompt with batch=1 and writes its cache rows into
  the slot via ONE jitted ``place_slot`` call with the big cache donated
  (zero-copy admission: XLA updates the cache in place instead of copying
  every leaf, and the slot index is a traced scalar so one compile serves
  every slot);
* decode advances ALL active slots each step with a single decode_step call
  (inactive slots are masked out of sampling).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.serve.engine import init_cache, make_decode_step, make_prefill_step


def make_place_slot(num_slots: int) -> Callable:
    """(cache, cache1, slot) -> cache with cache1's batch row written at slot.

    The batch axis differs per leaf family; it is the (static) axis whose
    size == num_slots in the big leaf and 1 in the small one.  ``slot`` is a
    traced scalar, so the jitted function compiles once for all slots; jit
    with ``donate_argnums=(0,)`` to update the cache buffers in place.
    """

    def place_slot(cache: Any, cache1: Any, slot: jax.Array) -> Any:
        zero = jnp.zeros((), jnp.int32)

        def place(big, small):
            for ax in range(big.ndim):
                if big.shape[ax] == num_slots and small.shape[ax] == 1:
                    idx = [zero] * big.ndim
                    idx[ax] = slot
                    return jax.lax.dynamic_update_slice(
                        big, small.astype(big.dtype), tuple(idx))
            raise ValueError("no batch axis found")

        return jax.tree.map(place, cache, cache1)

    return place_slot


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                 # (len,) int32
    max_new_tokens: int = 16
    eos_id: int | None = None
    # filled by the engine
    output: list = dataclasses.field(default_factory=list)
    done: bool = False


class ContinuousBatcher:
    def __init__(self, params: Any, cfg: ModelConfig, *, num_slots: int = 4,
                 max_len: int = 256):
        self.params, self.cfg = params, cfg
        self.b, self.max_len = num_slots, max_len
        self.cache = init_cache(cfg, num_slots, max_len)
        self.lengths = np.zeros(num_slots, np.int32)
        self.slot_req: list[Request | None] = [None] * num_slots
        self.last_tok = np.zeros(num_slots, np.int32)
        self._prefill = jax.jit(make_prefill_step(cfg, max_len=max_len))
        self._decode = jax.jit(make_decode_step(cfg))
        # donate the big cache so admission is a true in-place slot write
        # (no full-cache copy); CPU ignores donation, so only request it on
        # backends that implement it to avoid per-call warnings.
        donate = (0,) if jax.default_backend() in ("tpu", "gpu") else ()
        self._place = jax.jit(make_place_slot(num_slots), donate_argnums=donate)
        self.queue: list[Request] = []

    # -- admission -----------------------------------------------------------
    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _free_slots(self) -> list[int]:
        return [i for i, r in enumerate(self.slot_req) if r is None]

    def _admit(self) -> None:
        for slot in self._free_slots():
            if not self.queue:
                return
            req = self.queue.pop(0)
            prompt = jnp.asarray(req.prompt[None, :])            # (1, len)
            logits, cache1 = self._prefill(self.params, {"tokens": prompt})
            # write the single-row cache into this slot's row: one jitted
            # call, slot as a traced scalar (prompt cache rows were already
            # padded to max_len inside prefill)
            self.cache = self._place(self.cache, cache1,
                                     jnp.asarray(slot, jnp.int32))
            tok = int(jnp.argmax(logits[0, -1]))
            req.output.append(tok)
            self.slot_req[slot] = req
            self.lengths[slot] = len(req.prompt)
            self.last_tok[slot] = tok

    # -- decode tick ----------------------------------------------------------
    def _active(self) -> list[int]:
        return [i for i, r in enumerate(self.slot_req) if r is not None]

    def step(self) -> None:
        self._admit()
        active = self._active()
        if not active:
            return
        # single fused decode for all slots (inactive rows are don't-care);
        # per-slot cache lengths keep each request's positions independent
        toks = jnp.asarray(self.last_tok[:, None])
        clen = jnp.asarray(self.lengths, jnp.int32)          # (B,)
        logits, self.cache = self._decode(self.params, self.cache,
                                          {"tokens": toks}, clen)
        nxt = np.asarray(jnp.argmax(logits[:, -1], axis=-1), np.int32)
        for i in active:
            req = self.slot_req[i]
            tok = int(nxt[i])
            req.output.append(tok)
            self.lengths[i] += 1
            self.last_tok[i] = tok
            hit_eos = req.eos_id is not None and tok == req.eos_id
            if (len(req.output) >= req.max_new_tokens or hit_eos
                    or self.lengths[i] + 1 >= self.max_len):
                req.done = True
                self.slot_req[i] = None      # slot freed; admitted next tick

    def run(self, max_ticks: int = 1000) -> None:
        for _ in range(max_ticks):
            if not self.queue and not self._active():
                return
            self.step()
