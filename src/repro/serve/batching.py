"""Continuous batching: a two-queue scheduler over chunked prefill + decode.

The production pattern (vLLM-style, simplified to the parts that matter for
QER serving): a fixed pool of B slots shares one fused decode step; new
requests are *chunk-prefilled* into a slot while the other slots keep
decoding; finished slots are freed immediately.

State machine (one ``step()`` == one tick; two queues = the waiting deque
plus the decoding slot set, with at most ONE request in the PREFILLING
state between them):

    submit() ──> waiting (collections.deque)
    waiting ──_start_admission()──> PREFILLING   (one free slot claimed)
    PREFILLING ──one chunk per tick (≤ chunk_tokens)──> … ──last chunk──>
        DECODING   (first token = the chunk step's in-graph argmax)
    DECODING ──fused decode tick, all slots──> … ──eos/max──> slot freed

Every tick runs AT MOST one prefill chunk for the admitting request *and*
the decode step for all running slots, so admitting a long prompt never
stalls running requests for more than one chunk's worth of compute:
per-tick latency (and therefore inter-token latency of running slots) is
bounded by the chunk budget, never by the prompt length.  Chunk widths come
from ``kernels.ops.pick_prefill_chunk`` / ``chunk_plan`` — power-of-two
pieces plus a binary tail, so every chunk is exactly sized (recurrent-state
families never integrate padding) and jit retraces stay O(log chunk).

Dense mode: chunks run through a batch=1 scratch cache sized to the
(power-of-two bucketed) prompt — never max_len, so prefill attention stops
reading max_len worth of masked keys — threading mamba conv/ssm and rwkv
state across chunks; the finished scratch is placed into the slot's rows
with ONE jitted donated call (``make_place_slot``).

Paged mode (``paged=True``, see serve/paging.py):
* chunks write STRAIGHT into the slot's pages: ``make_chunk_prefill`` views
  the slot's per-slot rows batch=1, scatters the chunk's K/V through the
  page-table row, and the Pallas paged prefill kernel
  (kernels/prefill_attention.py) attends over the already-written prefix
  through the same table — no dense scratch cache, no ``place_pages`` copy;
* pages are allocated **chunk-by-chunk**, not all-up-front; if the pool
  runs dry mid-prefill the partial pages are rolled back and the request is
  requeued at the head (greedy recompute is deterministic) —
  ``admission_rollbacks`` counts these;
* while a slot is PREFILLING, decode ticks ship its page-table row zeroed
  (its appends land in the reserved garbage page) and roll its recurrent
  rows back afterwards (``make_restore_slot``), so the interleaved decode
  stream can never corrupt the half-built prefix;
* decode-tick behavior is unchanged: per-tick lazy page growth with
  pause-don't-corrupt on pool exhaustion, live-prefix bucketed page tables
  (attention bytes scale with context in use, not max_len), and
  preempt-and-requeue eviction to break all-slots-paused livelock — an
  in-flight admission is rolled back first, since freeing its pages is
  cheaper than evicting a decoded prefix.

Prefix caching (``prefix_cache=True``, paged mode only): admission matches
the longest cached hash-chain of the prompt's full pages in the
``PrefixIndex``, points the slot's table row at the shared physical pages
(``PagePool.share`` bumps refcounts) and chunk-prefills ONLY the uncached
suffix — a warm request costs ``pages_for(suffix)`` fresh pages and the
suffix's compute.  At least one token is always recomputed (the final
chunk's in-graph argmax is the first output token), so a page-aligned full
match shares every page and recomputes just the last position — the one
write that lands in a shared page, forked first by the copy-on-write rule:
NO write (chunk scatter or decode append) ever touches a page with
refcount > 1 or registered content; ``_cow_fork`` copies it to a fresh
page (one jitted gather/scatter across the layer axis) and repoints the
table row on host.  Full pages register into the index as prefill covers
them and when a finished/evicted slot releases (generated tokens become
matchable for conversation-continuation prompts); released registered
pages park on the pool's refcount-0 LRU and are reclaimed lazily under
allocation pressure.  Families with per-slot recurrent rows (hybrid
shared-attn) snapshot those rows at page boundaries into the index — the
state is not page-addressable, so their matches stop at the deepest
boundary with a snapshot; rwkv has no pageable KV and cannot run paged at
all.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.ops import chunk_plan, pick_prefill_chunk
from repro.models.config import ModelConfig
from repro.serve.engine import init_cache, make_chunk_step, make_decode_step
from repro.serve.paging import (
    PagePool,
    PrefixIndex,
    _place_row,
    has_slot_rows,
    init_paged_cache,
    make_chunk_prefill,
    make_fork_page,
    make_get_slot_rows,
    make_restore_slot,
    make_set_slot_rows,
    make_zero_slot,
    page_bucket,
)


def make_place_slot(num_slots: int) -> Callable:
    """(cache, cache1, slot) -> cache with cache1's batch row written at slot.

    The batch axis differs per leaf family; it is the (static) axis whose
    size == num_slots in the big leaf and 1 in the small one.  Axes where
    the small leaf is shorter (a prompt-bucket-sized scratch cache vs the
    slot's max_len row) are written as a prefix — the tail beyond the
    prompt is masked by the slot's kv length and never attended.  ``slot``
    is a traced scalar, so the jitted function compiles once per scratch
    bucket; jit with ``donate_argnums=(0,)`` to update the cache buffers in
    place.
    """

    def place_slot(cache: Any, cache1: Any, slot: jax.Array) -> Any:
        return jax.tree.map(
            lambda big, small: _place_row(big, small, slot, num_slots),
            cache, cache1)

    return place_slot


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                 # (len,) int32
    max_new_tokens: int = 16
    eos_id: int | None = None
    # filled by the engine
    output: list = dataclasses.field(default_factory=list)
    done: bool = False
    # terminal failure reason ("nan", "deadline", ...) — a failed request is
    # REPORTED, never silently dropped; ``done`` stays False
    failed: str | None = None
    # prefix-cache stats are per REQUEST, not per admission attempt: a
    # rollback/evict re-admission re-matches the same pages but must not
    # re-count the hit (hit rates could exceed 1.0 under churn)
    prefix_counted: bool = dataclasses.field(default=False, repr=False)

    @property
    def finished(self) -> bool:
        return self.done or self.failed is not None


@dataclasses.dataclass
class RunReport:
    """What a ``run()`` drain actually did — completions AND failures are
    accounted; nothing falls on the floor."""
    ticks: int
    completed: list[int]               # rids that emitted their full output
    failed: dict[int, str]             # rid -> terminal failure reason
    # speculative-decode counters (all 0 when spec_k == 0): acceptance rate
    # is spec_accepted / spec_drafted; full-precision launches per emitted
    # token is spec_rounds / spec_committed (the perf headline — 1.0 means
    # speculation bought nothing, 1/(k+1) is the upper bound)
    spec_rounds: int = 0               # verify launches run
    spec_drafted: int = 0              # draft tokens proposed
    spec_accepted: int = 0             # draft tokens committed
    spec_committed: int = 0            # tokens committed by verify launches


class IncompleteRunError(RuntimeError):
    """``run(max_ticks)`` exhausted its tick budget with requests still
    queued/decoding.  Carries the pending rids and the partial report so the
    caller can retry, extend the budget, or fail the requests explicitly."""

    def __init__(self, pending: list[int], report: RunReport):
        self.pending = pending
        self.report = report
        super().__init__(
            f"run() stopped after {report.ticks} ticks with "
            f"{len(pending)} unfinished request(s): {pending} "
            f"(completed {len(report.completed)}, "
            f"failed {len(report.failed)})")


@dataclasses.dataclass
class _Admission:
    """The PREFILLING state: one request mid-chunked-prefill in one slot."""
    req: Request
    slot: int
    plan: list[int]                    # remaining chunk widths
    done: int = 0                      # prompt tokens prefilled so far
    cache1: Any = None                 # dense mode: batch=1 scratch cache
    hashes: list = None                # prefix cache: prompt's page chain
    registered: int = 0                # pages already in the prefix index


class ContinuousBatcher:
    def __init__(self, params: Any, cfg: ModelConfig, *, num_slots: int = 4,
                 max_len: int = 256, paged: bool = False, page_size: int = 32,
                 num_pages: int | None = None, chunk_tokens: int = 64,
                 prefix_cache: bool = False, fault_injector: Any = None,
                 nan_guard: bool = True, nan_retry_limit: int = 3,
                 mesh: Any = None, debug_invariants: bool = False,
                 spec_k: int = 0, draft_bits: int = 2,
                 skip_lowrank: bool = True):
        self.params, self.cfg = params, cfg
        # self-speculative decoding (serve/speculative.py): each decode tick
        # drafts spec_k greedy tokens with the reduced-precision view of the
        # SAME packed weights, then scores all k+1 positions in one
        # full-precision chunk-shaped launch and commits the longest
        # matching prefix — the verifier IS the normal decode path, so
        # committed token streams are bit-identical to spec_k=0.
        if spec_k < 0:
            raise ValueError(f"spec_k must be >= 0, got {spec_k}")
        from repro.serve.speculative import check_spec_config
        check_spec_config(spec_k, draft_bits, where="ContinuousBatcher")
        self.spec_k = spec_k
        self.draft_bits = draft_bits
        # recurrent families integrate per-token state for every chunk
        # position; partial accepts restore-and-replay (``_replay_slot``)
        self._recurrent = cfg.family in ("hybrid_mamba", "rwkv")
        # tensor parallelism: a 1-D ('model',) serving mesh shard_maps every
        # forward-calling step — decode and chunked prefill — so each device
        # runs its own Pallas launches on its KV-head/d_ff shard
        # (sharding/serving.py).  ALL host logic (admission, page tables,
        # PagePool, PrefixIndex, NaN sentinel) is shard-agnostic and runs
        # unchanged; the data-movement helpers (place/restore/zero/fork/
        # get/set rows) never index the sharded heads axis, so they stay
        # plain jit and GSPMD partitions them communication-free.
        self.plan = None
        if mesh is not None:
            from repro.sharding.serving import plan_for
            plan = plan_for(cfg, mesh)
            if plan.tp > 1:
                self.plan = plan
                params = plan.shard_params(params)
                self.params = params
        step_cfg = self.plan.local_cfg if self.plan is not None else cfg
        self.paged = paged
        self.chunk_tokens = chunk_tokens
        self.prefix: PrefixIndex | None = None
        # fault tolerance: an optional FaultInjector (serve/faults.py) whose
        # hooks fire inside step(), and the NaN/Inf sentinel on decode
        # logits — a non-finite logits row pauses that slot (token
        # discarded, recurrent rows rolled back, re-decoded next tick) and
        # after ``nan_retry_limit`` consecutive strikes quarantines the
        # request (failed="nan", slot freed WITHOUT registering its pages in
        # the prefix index) so one poisoned stream never stalls co-batched
        # slots.
        self.injector = fault_injector
        # debug_invariants: re-check the paged-pool laws (refcount
        # conservation, shared-page write protection) from scratch after
        # every tick (analysis/runtime.py).  O(pool) host work per tick —
        # for tests, not production.
        self.debug_invariants = debug_invariants
        self._protected_digests: dict[int, str] = {}
        self.nan_guard = nan_guard
        self.nan_retry_limit = nan_retry_limit
        self._nan_strikes = np.zeros(num_slots, np.int32)
        self.nan_events = 0                # non-finite decode rows seen
        self.nan_quarantined: list[int] = []   # rids failed by the sentinel
        self.tick_count = 0
        self.completed_rids: list[int] = []
        self.failed_rids: dict[int, str] = {}
        if prefix_cache and not paged:
            raise ValueError("prefix_cache requires paged=True (sharing is "
                             "page-table indirection over the page pool)")
        # page geometry needs a page-multiple length; the request done-check
        # keeps the CALLER's max_len so paged stays token-identical to dense
        # even when max_len % page_size != 0.  Speculation appends k extra
        # slack positions: the verify chunk writes (but never commits) up to
        # lengths+k, so the cache rows / page-table width cover max_len+k.
        alloc_len = max_len + spec_k
        if paged:
            alloc_len = -(-alloc_len // page_size) * page_size
        self.b, self.max_len = num_slots, max_len
        self.lengths = np.zeros(num_slots, np.int32)
        self.slot_req: list[Request | None] = [None] * num_slots
        self.last_tok = np.zeros(num_slots, np.int32)
        # donate the big cache so admission/restore are true in-place writes
        # (no full-cache copy); CPU ignores donation, so only request it on
        # backends that implement it to avoid per-call warnings.
        donate = jax.default_backend() in ("tpu", "gpu")
        if paged:
            self.page_size = page_size
            self.max_pages_per_slot = alloc_len // page_size
            # default pool is lossless (every slot can grow to max_len);
            # pass a smaller num_pages to actually oversubscribe.
            num_pages = num_pages or 1 + num_slots * self.max_pages_per_slot
            self.pool = PagePool(num_pages, page_size)
            self.cache = init_paged_cache(
                cfg, num_slots, alloc_len, page_size=page_size,
                num_pages=num_pages)
            # host-owned page table; shipped per tick sliced to the bucket
            self.cache.pop("page_table")
            self.page_table = np.zeros(
                (num_slots, self.max_pages_per_slot), np.int32)
            self.slot_pages: list[list[int]] = [[] for _ in range(num_slots)]
            self._starved: list[int] = []    # slots paused on the last tick
            self._has_slot_rows = has_slot_rows(self.cache)
            if self.plan is not None:
                from jax.sharding import PartitionSpec as P
                cspecs = self.plan.cache_specs(self.cache)
                self.cache = self.plan.shard_cache(self.cache)
                self._chunk = self.plan.sjit(
                    make_chunk_prefill(step_cfg, num_slots),
                    in_specs=(self.plan.param_specs(params), cspecs,
                              P(None, None), P(None), P(), P()),
                    out_specs=(P(), cspecs),
                    donate_argnums=(1,) if donate else ())
            else:
                self._chunk = jax.jit(make_chunk_prefill(cfg, num_slots),
                                      donate_argnums=(1,) if donate else ())
            self._zero = jax.jit(make_zero_slot(num_slots),
                                 donate_argnums=(0,) if donate else ())
            self._restore = jax.jit(make_restore_slot(num_slots),
                                    donate_argnums=(0,) if donate else ())
            if prefix_cache:
                self.prefix = PrefixIndex(self.pool)
                self._fork = jax.jit(make_fork_page(),
                                     donate_argnums=(0,) if donate else ())
                if self._has_slot_rows:
                    self._get_rows = jax.jit(make_get_slot_rows(num_slots))
                    self._set_rows = jax.jit(
                        make_set_slot_rows(num_slots),
                        donate_argnums=(0,) if donate else ())
        else:
            self.cache = init_cache(cfg, num_slots, max_len)
            if self.plan is not None:
                from jax.sharding import PartitionSpec as P
                # dense cache and the batch=1 admission scratch share one
                # structural spec tree (sharding is on the KV-heads axis,
                # batch extent is irrelevant)
                cspecs = self.plan.cache_specs(self.cache)
                self.cache = self.plan.shard_cache(self.cache)
                self._chunk = self.plan.sjit(
                    make_chunk_step(step_cfg),
                    in_specs=(self.plan.param_specs(params), cspecs,
                              P(None, None), P()),
                    out_specs=(P(), cspecs),
                    donate_argnums=(1,) if donate else ())
            else:
                self._chunk = jax.jit(make_chunk_step(cfg),
                                      donate_argnums=(1,) if donate else ())
            self._place = jax.jit(make_place_slot(num_slots),
                                  donate_argnums=(0,) if donate else ())
            # the NaN sentinel rolls a poisoned slot back one token; in
            # dense mode that restores ALL its per-slot rows (K/V append is
            # re-written identically on the re-decode)
            self._restore = jax.jit(make_restore_slot(num_slots),
                                    donate_argnums=(0,) if donate else ())
        if self.plan is not None:
            from jax.sharding import PartitionSpec as P
            dspecs = self.plan.cache_specs(self.cache)
            if paged:
                dspecs = {**dspecs, "page_table": P(None, None)}
            self._decode = self.plan.sjit(
                make_decode_step(step_cfg),
                in_specs=(self.plan.param_specs(params), dspecs,
                          P(None, None), P(None)),
                out_specs=(P(None, None, None), dspecs))
        else:
            self._decode = jax.jit(make_decode_step(cfg))
        if spec_k:
            from repro.serve.speculative import make_draft_params
            # zero-copy: the draft tree SHARES self.params' mant/exp buffers
            # (and their shards under tp) — only the 0-dim draft markers are
            # new, so speculation adds no weight memory and no collectives
            self.draft_params = make_draft_params(
                self.params, draft_bits=draft_bits, skip_lowrank=skip_lowrank)
            if self.plan is not None:
                from jax.sharding import PartitionSpec as P
                self._draft_decode = self.plan.sjit(
                    make_decode_step(step_cfg),
                    in_specs=(self.plan.param_specs(self.draft_params),
                              dspecs, P(None, None), P(None)),
                    out_specs=(P(None, None, None), dspecs))
            else:
                # same jitted wrapper: jit re-traces per params structure
                self._draft_decode = self._decode
            if self._recurrent and not paged:
                from repro.serve.paging import make_slot_chunk
                self._slot_chunk = jax.jit(
                    make_slot_chunk(cfg, num_slots),
                    donate_argnums=(1,) if donate else ())
        self.spec_rounds = 0               # verify launches run
        self.spec_drafted = 0              # draft tokens proposed
        self.spec_accepted = 0             # draft tokens committed
        self.spec_committed = 0            # total tokens committed by verify
        self.queue: deque[Request] = deque()
        self._adm: _Admission | None = None
        self.admission_rollbacks = 0       # pool ran dry mid-prefill
        self.cow_forks = 0                 # shared pages copied before a write

    # -- admission -----------------------------------------------------------
    def submit(self, req: Request) -> None:
        n = len(req.prompt)
        if n == 0:
            raise ValueError(f"request {req.rid}: empty prompt")
        if n + 1 > self.max_len:
            # dense mode would silently clamp the decode append into the last
            # cache row; paged mode would index past the page-table width
            # mid-admission — reject up front in both modes
            raise ValueError(
                f"request {req.rid}: prompt of {n} tokens + 1 generated "
                f"token exceeds max_len {self.max_len}")
        if self.paged:
            # +1: the first decode append needs a page slot too — a
            # page-aligned prompt that exactly fills the pool can prefill
            # but never take its first decode step (+spec_k: a speculative
            # tick needs the whole k+1 verify span allocated)
            need = self.pool.pages_for(n + 1 + self.spec_k)
            if need > self.pool.num_pages - 1:
                # reject up front: queued it would stall admission forever
                raise ValueError(
                    f"request {req.rid}: prompt + first decode append need "
                    f"{need} pages but the pool has "
                    f"{self.pool.num_pages - 1} allocatable")
        self.queue.append(req)

    def _free_slots(self) -> list[int]:
        adm_slot = self._adm.slot if self._adm is not None else -1
        return [i for i, r in enumerate(self.slot_req)
                if r is None and i != adm_slot]

    def _start_admission(self) -> None:
        if self._adm is not None or not self.queue:
            return
        if self.paged and self._starved and self._active():
            # running slots are stalled on page allocation: freed pages must
            # grow them first, or admission (notably of a just-evicted
            # request) steals the page back and the pool thrashes
            return
        free = self._free_slots()
        if not free:
            return
        req = self.queue[0]
        n = len(req.prompt)
        matched, mpages, mstate = 0, [], None
        if self.prefix is not None:
            if self._has_slot_rows:
                # recurrent rows must be restorable at the match boundary:
                # match only boundaries with a state snapshot, and never the
                # whole prompt (>= 1 token is always recomputed)
                mpages, mstate = self.prefix.match(
                    req.prompt, max_pages=(n - 1) // self.page_size,
                    need_state=True)
                matched = len(mpages) * self.page_size
            else:
                mpages, _ = self.prefix.match(
                    req.prompt, max_pages=n // self.page_size)
                # a page-aligned full match still recomputes the final token
                # (its argmax is the first output) — the lone write into a
                # shared page, handled by the copy-on-write fork
                matched = min(len(mpages) * self.page_size, n - 1)
        chunk = pick_prefill_chunk(
            n - matched, page_size=self.page_size if self.paged else 0,
            max_chunk=self.chunk_tokens)
        slot = free[0]
        adm = _Admission(req=req, slot=slot,
                         plan=chunk_plan(n - matched, chunk), done=matched)
        if self.paged:
            if self.pool.available() < self.pool.pages_for(adm.plan[0]):
                return                 # first chunk can't land; stay queued
            self.page_table[slot, :] = 0
            self.slot_pages[slot] = []
            if self._has_slot_rows:
                # the previous occupant's recurrent rows are live state for
                # direct-to-slot prefill — zero them before chunk 1
                self.cache = self._zero(self.cache,
                                        jnp.asarray(slot, jnp.int32))
            if self.prefix is not None:
                if mpages:
                    # point the slot's row at the cached prefix: refcounts
                    # up, zero new pages, only the suffix gets prefilled
                    self.pool.share(mpages)
                    self.slot_pages[slot] = list(mpages)
                    self.page_table[slot, :len(mpages)] = mpages
                    if mstate is not None:
                        self.cache = self._set_rows(
                            self.cache, mstate, jnp.asarray(slot, jnp.int32))
                    if not req.prefix_counted:
                        self.prefix.hits += 1
                        self.prefix.hit_tokens += matched
                elif not req.prefix_counted:
                    self.prefix.misses += 1
                req.prefix_counted = True
                adm.hashes = PrefixIndex.chain_hashes(req.prompt,
                                                      self.page_size)
                adm.registered = len(mpages)
        else:
            # pow2-bucketed scratch length: O(log) chunk-step compiles
            adm.cache1 = init_cache(self.cfg, 1, page_bucket(n, self.max_len))
        self.queue.popleft()
        self.slot_req[slot] = req
        self.lengths[slot] = 0         # stays 0 until the last chunk lands
        self._nan_strikes[slot] = 0
        self._adm = adm

    def _rollback_admission(self) -> None:
        """Pool ran dry mid-prefill: release the partial pages, requeue the
        request at the head (greedy recompute is deterministic) and release
        the slot — decoders get the pages back immediately.  Pages already
        registered in the prefix index stay cached (refcount 0 on the LRU),
        so the requeued request's re-admission skips the work it finished."""
        adm = self._adm
        self.pool.release(self.slot_pages[adm.slot])
        self.slot_pages[adm.slot] = []
        self.page_table[adm.slot, :] = 0
        self.slot_req[adm.slot] = None
        self.lengths[adm.slot] = 0
        adm.req.output.clear()
        self.queue.appendleft(adm.req)
        self._adm = None
        self.admission_rollbacks += 1

    # -- prefix cache ---------------------------------------------------------
    def _cow_fork(self, slot: int, lp: int) -> bool:
        """Copy-on-write: if writing the slot's logical page ``lp`` would
        mutate a shared (refcount > 1) or prefix-cached physical page, fork
        it — acquire a fresh page, copy src -> dst across the layer axis in
        one jitted call, repoint the table row, drop the shared ref.  True
        when the page is now safely writable; False when the pool could not
        supply the fork page."""
        src = int(self.page_table[slot, lp])
        if src == 0 or not (self.pool.refcount(src) > 1
                            or self.pool.is_registered(src)):
            return True
        dst = self.pool.acquire(1)
        if dst is None:
            return False
        self.cache = self._fork(self.cache, jnp.asarray(src, jnp.int32),
                                jnp.asarray(dst[0], jnp.int32))
        self.page_table[slot, lp] = dst[0]
        owned = self.slot_pages[slot]
        owned[owned.index(src)] = dst[0]
        self.pool.release([src])
        self.cow_forks += 1
        return True

    def _register_prefilled(self, adm: _Admission, done: int) -> None:
        """Register every prompt page fully covered by the first ``done``
        prefilled tokens.  Recurrent-row families attach a host snapshot of
        the slot's rows when ``done`` lands exactly on a page boundary (the
        state a future match at that boundary must restore)."""
        full = done // self.page_size
        if full <= adm.registered:
            return
        state = None
        if self._has_slot_rows and done % self.page_size == 0:
            state = jax.device_get(self._get_rows(
                self.cache, jnp.asarray(adm.slot, jnp.int32)))
        for j in range(adm.registered, full):
            st = state if (j + 1) * self.page_size == done else None
            self.prefix.register(adm.hashes[j],
                                 int(self.page_table[adm.slot, j]), st)
        adm.registered = full

    def _register_finished(self, slot: int, req: Request) -> None:
        """A slot is releasing its pages (finished or evicted): register the
        full pages of everything in its cache — prompt AND generated tokens,
        so a conversation-continuation prompt that extends this response can
        share them.  Content is immutable from here (registered pages are
        never written; release parks them on the pool's refcount-0 LRU)."""
        if self.prefix is None:
            return
        n_cache = int(self.lengths[slot])
        fed = n_cache - len(req.prompt)    # output tokens already appended
        if fed < 0:
            return                         # mid-admission eviction
        seq = np.concatenate([req.prompt,
                              np.asarray(req.output[:fed], np.int32)])
        for j, h in enumerate(PrefixIndex.chain_hashes(seq, self.page_size)):
            pg = int(self.page_table[slot, j])
            if pg:
                self.prefix.register(h, pg)

    def _prefill_tick(self) -> None:
        """Run at most ONE chunk of the in-flight admission."""
        adm = self._adm
        if adm is None:
            return
        if self.paged and self._starved and self._active():
            return                     # freed pages belong to starved slots
        w = adm.plan[0]
        prompt = adm.req.prompt
        chunk = jnp.asarray(prompt[None, adm.done:adm.done + w])
        pos = jnp.asarray(adm.done, jnp.int32)
        if self.paged:
            # allocate exactly the pages this chunk's positions cover
            lp0 = adm.done // self.page_size
            lp1 = (adm.done + w - 1) // self.page_size
            need = [lp for lp in range(lp0, lp1 + 1)
                    if self.page_table[adm.slot, lp] == 0]
            if need:
                pages = self.pool.acquire(len(need))
                if pages is None:
                    self._rollback_admission()
                    return
                for lp, pg in zip(need, pages):
                    self.page_table[adm.slot, lp] = pg
                self.slot_pages[adm.slot].extend(pages)
            if self.prefix is not None:
                # copy-on-write: the chunk's scatter may cover a page shared
                # from the prefix index (the recompute-last-token case) —
                # fork it so a refcount>1 / cached page is never written
                for lp in range(lp0, lp1 + 1):
                    if not self._cow_fork(adm.slot, lp):
                        self._rollback_admission()
                        return
            width = page_bucket(-(-(adm.done + w) // self.page_size),
                                self.max_pages_per_slot)
            tok, self.cache = self._chunk(
                self.params, self.cache, chunk,
                jnp.asarray(self.page_table[adm.slot, :width]),
                jnp.asarray(adm.slot, jnp.int32), pos)
            if self.prefix is not None:
                self._register_prefilled(adm, adm.done + w)
        else:
            tok, adm.cache1 = self._chunk(self.params, adm.cache1, chunk, pos)
        adm.plan.pop(0)
        adm.done += w
        if adm.plan:
            return
        # last chunk: the slot joins THIS tick's decode with its first token
        if not self.paged:
            self.cache = self._place(self.cache, adm.cache1,
                                     jnp.asarray(adm.slot, jnp.int32))
        t = int(tok)                   # 4-byte scalar; argmax ran in-graph
        adm.req.output.append(t)
        self.lengths[adm.slot] = len(prompt)
        self.last_tok[adm.slot] = t
        self._adm = None

    # -- decode tick ----------------------------------------------------------
    def _active(self) -> list[int]:
        adm_slot = self._adm.slot if self._adm is not None else -1
        return [i for i, r in enumerate(self.slot_req)
                if r is not None and i != adm_slot]

    def _grow_pages(self, active: list[int], span: int = 1
                    ) -> tuple[list[int], list[tuple[int, int]]]:
        """Lazily allocate the page(s) each active slot's next ``span``
        positions land in (span = 1 + spec_k: a speculative tick appends the
        whole verify chunk).  Returns the slots that must pause this tick
        (pool empty): their appends hit the garbage page and their tokens
        are discarded — greedy decode recomputes the identical tokens once a
        page frees.  A slot whose span covers a shared page must fork it
        first (copy-on-write); if the fork page cannot be acquired the slot
        pauses too, and its table entry is shielded (shipped zeroed) so the
        appends cannot touch the shared page.  Pages acquired before a
        mid-span stall stay owned by the slot (refcounts conserved; they are
        exactly the pages the retry needs)."""
        paused: list[int] = []
        shield: list[tuple[int, int]] = []
        for i in active:
            lp0 = self.lengths[i] // self.page_size
            lp1 = (self.lengths[i] + span - 1) // self.page_size
            for lp in range(lp0, lp1 + 1):
                if self.page_table[i, lp] == 0:
                    pg = self.pool.acquire(1)
                    if pg is None:
                        paused.append(i)
                        break
                    self.page_table[i, lp] = pg[0]
                    self.slot_pages[i].append(pg[0])
                elif self.prefix is not None and not self._cow_fork(i, lp):
                    paused.append(i)
                    shield.append((i, lp))
                    break
        return paused, shield

    def _evict(self, slot: int) -> None:
        """Preempt-and-requeue: release the slot's pages and put its request
        back at the head of the queue with output cleared — greedy decode is
        deterministic, so re-admission recomputes the same tokens (and, with
        the prefix cache on, mostly re-matches them: the evicted slot's full
        pages register before release and park on the reclaimable LRU)."""
        req = self.slot_req[slot]
        self._register_finished(slot, req)
        req.output.clear()
        self.queue.appendleft(req)
        self.slot_req[slot] = None
        self.pool.release(self.slot_pages[slot])
        self.slot_pages[slot] = []
        self.page_table[slot, :] = 0
        self.lengths[slot] = 0

    def _release_slot(self, slot: int, *, register: bool) -> None:
        """Free a slot's resources (terminal: finished, quarantined, or
        aborted).  ``register`` controls whether its full pages enter the
        prefix index — quarantined slots must NOT register (their K/V may
        carry the NaN that poisoned the logits)."""
        req = self.slot_req[slot]
        self.slot_req[slot] = None
        self._nan_strikes[slot] = 0
        if self.paged:
            if register:
                self._register_finished(slot, req)
            self.pool.release(self.slot_pages[slot])
            self.slot_pages[slot] = []
            self.page_table[slot, :] = 0
        self.lengths[slot] = 0

    def step(self) -> None:
        self._step()
        if self.debug_invariants and self.paged:
            self._assert_invariants()

    def _assert_invariants(self) -> None:
        """Runtime assertion mode: refcount conservation + shared-page
        write protection, re-derived from scratch after the tick."""
        from repro.analysis.runtime import (check_page_accounting,
                                            check_protected_writes,
                                            snapshot_protected_pages)
        errs = check_page_accounting(self.pool, self.slot_pages,
                                     self.page_table)
        cur = snapshot_protected_pages(self.cache, self.pool)
        errs += check_protected_writes(self._protected_digests, cur)
        self._protected_digests = cur
        if errs:
            raise AssertionError(
                f"debug_invariants after tick {self.tick_count}: "
                + "; ".join(errs))

    def _step(self) -> None:
        self.tick_count += 1
        if self.injector is not None:
            self.injector.maybe_crash("pre")
        self._start_admission()
        self._prefill_tick()
        if self.injector is not None:
            # "mid-tick": admission/prefill work done, decode not committed
            self.injector.maybe_crash("mid")
        active = self._active()
        if not active:
            return
        if self.spec_k:
            self._spec_decode_tick(active)
        else:
            self._decode_tick(active)

    def _paged_decode_setup(self, active: list[int], span: int):
        """Page growth + all-paused recovery + the shielded/bucketed table,
        shared by the plain and speculative decode ticks.  Returns ``None``
        when the tick must end here (recovery took an action instead of
        decoding); otherwise ``(cache, paused, prev, roll_adm)`` where
        ``cache`` carries the shipped page_table leaf."""
        adm = self._adm
        paused, shield = self._grow_pages(active, span=span)
        self._starved = list(paused)
        if paused and len(paused) == len(active):
            if self.pool.reserved:
                # fault-injected exhaustion spike: the pressure is
                # transient by construction, so pause-and-wait IS the
                # recovery — evicting or raising here would turn a
                # simulated blip into real lost work
                return None
            # every decoding slot stalled on allocation: no tick can
            # ever free a page, so reclaim some to restore progress —
            # rolling back an in-flight admission is cheaper than
            # evicting a decoded prefix
            if adm is not None:
                self._rollback_admission()
                return None
            if len(active) == 1:
                raise RuntimeError(
                    f"page pool ({self.pool.num_pages} pages, page_size="
                    f"{self.page_size}) too small for request "
                    f"{self.slot_req[active[0]].rid} alone")
            self._evict(paused.pop())
            return None
        # paused slots' appends land in the garbage page and their
        # tokens are discarded, but per-slot recurrent state (mamba
        # conv/ssm rows) would still advance on the discarded token —
        # keep the pre-tick cache to roll those rows back afterwards.  The
        # PREFILLING slot is treated the same way: its table row ships
        # zeroed (append -> garbage page) and its rows roll back, so
        # the decode stream cannot touch the half-built prefix.
        roll_adm = adm is not None and self._has_slot_rows
        prev = (self.cache
                if (paused or roll_adm or self.nan_guard or span > 1)
                else None)
        live = max(-(-int(self.lengths[i] + span) // self.page_size)
                   for i in active)
        bucket = page_bucket(live, self.max_pages_per_slot)
        tbl = self.page_table[:, :bucket]
        if adm is not None or shield or (span > 1 and paused):
            tbl = tbl.copy()
            if adm is not None:
                tbl[adm.slot] = 0
            if span > 1:
                # a speculative span may cross into pages the stalled slot
                # never allocated or forked — ship the whole row zeroed
                # (every append -> garbage page; the tokens are discarded
                # and the recurrent rows restored, so nothing is lost)
                for i in paused:
                    tbl[i] = 0
            else:
                for i, lp in shield:
                    # fork-starved slot: its append must not reach the
                    # shared page — route it to the garbage page instead
                    # (the entry is at a fresh page boundary, so no live
                    # position is hidden from attention)
                    if lp < bucket:
                        tbl[i, lp] = 0
        cache = {**self.cache, "page_table": jnp.asarray(tbl)}
        return cache, paused, prev, roll_adm

    def _decode_tick(self, active: list[int]) -> None:
        # single fused decode for all slots (inactive rows are don't-care);
        # per-slot cache lengths keep each request's positions independent
        paused: list[int] = []
        adm = self._adm
        toks = jnp.asarray(self.last_tok[:, None])
        clen = jnp.asarray(self.lengths, jnp.int32)          # (B,)
        if self.paged:
            setup = self._paged_decode_setup(active, 1)
            if setup is None:
                return
            cache, paused, prev, roll_adm = setup
            logits, cache = self._decode(self.params, cache,
                                         {"tokens": toks}, clen)
            cache.pop("page_table")
            self.cache = cache
            for i in paused:
                self.cache = self._restore(self.cache, prev,
                                           jnp.asarray(i, jnp.int32))
            if roll_adm:
                self.cache = self._restore(self.cache, prev,
                                           jnp.asarray(adm.slot, jnp.int32))
        else:
            # dense mode needs no admission shielding: chunks run in the
            # scratch cache, and the slot's garbage decode rows are fully
            # overwritten by the final place.  prev backs the NaN sentinel's
            # one-token rollback (the decode step is not donated, so this is
            # a reference, not a copy).
            prev = self.cache if self.nan_guard else None
            logits, self.cache = self._decode(self.params, self.cache,
                                              {"tokens": toks}, clen)
        live = [i for i in active if i not in paused]
        if self.injector is not None:
            logits = self.injector.corrupt_logits(logits, live)
        bad: list[int] = []
        if self.nan_guard:
            finite = np.asarray(jnp.all(jnp.isfinite(logits[:, -1]), -1))
            bad = [i for i in live if not finite[i]]
        nxt = np.asarray(jnp.argmax(logits[:, -1], axis=-1), np.int32)
        for i in bad:
            # NaN/Inf sentinel: the slot's token this tick is garbage.
            # Quarantine = pause-don't-corrupt, one slot at a time: discard
            # the token, roll the recurrent rows back (the K/V append is
            # re-written identically on the re-decode), and retry next tick.
            # Rows are independent through the batched forward, so
            # co-batched slots commit their tokens normally below.
            self._nan_strike(i, prev)
        for i in live:
            if i in bad:
                continue
            self._nan_strikes[i] = 0
            req = self.slot_req[i]
            tok = int(nxt[i])
            req.output.append(tok)
            self.lengths[i] += 1
            self.last_tok[i] = tok
            hit_eos = req.eos_id is not None and tok == req.eos_id
            if (len(req.output) >= req.max_new_tokens or hit_eos
                    or self.lengths[i] + 1 >= self.max_len):
                req.done = True
                self.completed_rids.append(req.rid)
                # full pages register (generated tokens become matchable
                # for continuation prompts) before the refs drop; the freed
                # paged row attends 1 garbage token until re-admitted
                self._release_slot(i, register=True)

    def _nan_strike(self, i: int, prev: Any) -> None:
        """One non-finite-logits strike against slot ``i``: discard the
        tick's token(s), restore the slot's rows from ``prev`` and retry
        next tick, or quarantine the request (failed="nan", pages never
        registered — its K/V may be poisoned) after ``nan_retry_limit``
        consecutive strikes."""
        self.nan_events += 1
        self._nan_strikes[i] += 1
        req = self.slot_req[i]
        if self._nan_strikes[i] >= self.nan_retry_limit:
            # persistent blowup: fail THIS request, not the batch
            req.failed = "nan"
            self.failed_rids[req.rid] = "nan"
            self.nan_quarantined.append(req.rid)
            self._release_slot(i, register=False)
        else:
            self.cache = self._restore(self.cache, prev,
                                       jnp.asarray(i, jnp.int32))

    def _spec_decode_tick(self, active: list[int]) -> None:
        """Draft spec_k greedy tokens with the reduced-precision param view,
        then score all k+1 positions in ONE full-precision chunk-shaped
        launch and commit the longest matching prefix (always >= 1 token:
        position 0 is the normal decode of last_tok).

        Bit-identity with ``_decode_tick``: the verify launch recomputes
        every chunk position with the SAME params, cache and positions the
        plain tick would use, commits apply the exact same done conditions
        token by token, and rejected suffixes leave no trace — draft K/V
        appends are overwritten by the verify, stale verify K/V beyond the
        committed length sits above kv_len (masked; rewritten before read
        next round), and recurrent rows restore-and-replay through
        ``_replay_slot``.  The drafts run on a throwaway functional fork of
        the cache, so "rollback" of the draft pass is simply not keeping
        it."""
        k = self.spec_k
        paused: list[int] = []
        roll_adm = False
        adm = self._adm
        clen = jnp.asarray(self.lengths, jnp.int32)          # (B,)
        if self.paged:
            setup = self._paged_decode_setup(active, k + 1)
            if setup is None:
                return
            cache, paused, prev, roll_adm = setup
        else:
            cache = self.cache
            # always held in spec mode: NaN strikes and recurrent partial
            # accepts both roll whole slot rows back to the pre-round state
            prev = self.cache
        cur = jnp.asarray(self.last_tok[:, None])
        drafts = []
        dcache = cache
        for j in range(k):
            dlogits, dcache = self._draft_decode(
                self.draft_params, dcache, {"tokens": cur}, clen + j)
            cur = jnp.argmax(dlogits[:, -1], axis=-1).astype(jnp.int32)[:, None]
            drafts.append(cur)
        dv = jnp.concatenate(drafts, axis=1)                  # (B, k)
        chunk = jnp.concatenate(
            [jnp.asarray(self.last_tok[:, None]), dv], axis=1)
        # ONE batched launch scores all k+1 positions through the Sq=k+1
        # chunk kernel path and overwrites the draft's K/V appends
        logits, cache = self._decode(self.params, cache,
                                     {"tokens": chunk}, clen)
        if self.paged:
            cache.pop("page_table")
        self.cache = cache
        for i in paused:
            self.cache = self._restore(self.cache, prev,
                                       jnp.asarray(i, jnp.int32))
        if roll_adm:
            self.cache = self._restore(self.cache, prev,
                                       jnp.asarray(adm.slot, jnp.int32))
        live = [i for i in active if i not in paused]
        if self.injector is not None:
            logits = self.injector.corrupt_logits(logits, live)
        bad: list[int] = []
        if self.nan_guard:
            # any poisoned position invalidates the whole chunk for that
            # row: acceptance depends on every verify argmax
            finite = np.asarray(jnp.all(jnp.isfinite(logits), axis=(1, 2)))
            bad = [i for i in live if not finite[i]]
        yv = np.asarray(jnp.argmax(logits, axis=-1), np.int32)   # (B, k+1)
        dv_h = np.asarray(dv, np.int32)                          # (B, k)
        chunk_h = np.asarray(chunk, np.int32)                    # (B, k+1)
        for i in bad:
            self._nan_strike(i, prev)
        self.spec_rounds += 1
        for i in live:
            if i in bad:
                continue
            self._nan_strikes[i] = 0
            req = self.slot_req[i]
            committed = 0
            for j in range(k + 1):
                if j > 0 and dv_h[i, j - 1] != yv[i, j - 1]:
                    break                  # first rejected draft ends the run
                tok = int(yv[i, j])
                req.output.append(tok)
                self.lengths[i] += 1
                self.last_tok[i] = tok
                committed += 1
                hit_eos = req.eos_id is not None and tok == req.eos_id
                if (len(req.output) >= req.max_new_tokens or hit_eos
                        or self.lengths[i] + 1 >= self.max_len):
                    req.done = True
                    break
            self.spec_drafted += k
            self.spec_accepted += committed - 1
            self.spec_committed += committed
            if req.done:
                self.completed_rids.append(req.rid)
                self._release_slot(i, register=True)
            elif self._recurrent and committed < k + 1:
                self._replay_slot(i, chunk_h[i, :committed], prev)

    def _replay_slot(self, i: int, toks: np.ndarray, prev: Any) -> None:
        """Recurrent rollback for a partial accept: the verify launch
        integrated all k+1 chunk tokens into slot ``i``'s conv/ssm/rwkv
        rows.  Restore the pre-round rows and replay only the committed
        tokens with the full model — state (and, in dense mode, the
        restored K/V rows) ends bit-identical to token-by-token decoding."""
        committed = len(toks)
        pos = int(self.lengths[i]) - committed
        self.cache = self._restore(self.cache, prev,
                                   jnp.asarray(i, jnp.int32))
        chunk = jnp.asarray(toks[None, :])
        if self.paged:
            width = page_bucket(-(-(pos + committed) // self.page_size),
                                self.max_pages_per_slot)
            _, self.cache = self._chunk(
                self.params, self.cache, chunk,
                jnp.asarray(self.page_table[i, :width]),
                jnp.asarray(i, jnp.int32), jnp.asarray(pos, jnp.int32))
        else:
            self.cache = self._slot_chunk(
                self.params, self.cache, chunk,
                jnp.asarray(i, jnp.int32), jnp.asarray(pos, jnp.int32))

    # -- abort / drain --------------------------------------------------------
    def abort(self, req: Request, reason: str) -> bool:
        """Terminally fail ``req`` wherever it currently lives — queued,
        mid-admission, or decoding — releasing its resources.  Used by the
        supervisor for deadline/TTL expiry; the request is marked
        ``failed=reason`` and reported, never silently dropped.  Returns
        False if the request is not in the batcher (already finished)."""
        if req.finished:
            return False
        if self._adm is not None and self._adm.req is req:
            adm = self._adm
            if self.paged:
                self.pool.release(self.slot_pages[adm.slot])
                self.slot_pages[adm.slot] = []
                self.page_table[adm.slot, :] = 0
            self.slot_req[adm.slot] = None
            self.lengths[adm.slot] = 0
            req.output.clear()
            self._adm = None
        elif req in self.queue:
            self.queue.remove(req)
        elif req in self.slot_req:
            # a decoded prefix is valid content: register before release
            self._release_slot(self.slot_req.index(req), register=True)
        else:
            return False
        req.failed = reason
        self.failed_rids[req.rid] = reason
        return True

    def pending_rids(self) -> list[int]:
        """Requests still owed work: queued, mid-admission, or decoding."""
        rids = [r.rid for r in self.queue]
        rids += [r.rid for r in self.slot_req if r is not None]
        return rids

    def run(self, max_ticks: int = 1000) -> RunReport:
        """Drive ticks until every submitted request is terminal (done or
        failed).  Returns a :class:`RunReport`; raises
        :class:`IncompleteRunError` if the tick budget runs out with
        requests still pending — unfinished work is never silently
        dropped."""
        t0 = self.tick_count
        for _ in range(max_ticks):
            if not self.queue and self._adm is None and not self._active():
                break
            self.step()
        report = RunReport(ticks=self.tick_count - t0,
                           completed=list(self.completed_rids),
                           failed=dict(self.failed_rids),
                           spec_rounds=self.spec_rounds,
                           spec_drafted=self.spec_drafted,
                           spec_accepted=self.spec_accepted,
                           spec_committed=self.spec_committed)
        pending = self.pending_rids()
        if pending:
            raise IncompleteRunError(pending, report)
        return report
