"""Serving engine: per-family cache structs + prefill/decode step factories.

``decode_step`` is what the ``decode_32k`` / ``long_500k`` dry-run cells
lower: one new token against a seq_len cache.  ``prefill_step`` fills the
cache from a prompt (``prefill_32k``).  ``scan_generate`` is the decode fast
path: prefill + an N-token ``lax.scan`` rollout compiled ONCE, with argmax
and eos masking on device (``greedy_generate_loop`` keeps the python-loop
reference).  Caches:

  dense/moe/audio/vlm : {"blocks": {"k","v": (L, B, KVH, S_max, hd)}}
  hybrid_mamba        : {"blocks": {"conv_*", "ssm"}, "shared_attn": {"k","v"}}
  rwkv                : {"blocks": {"state", "last_tm", "last_cm"}}

Paged caches (serve/paging.py) replace the dense K/V rows with a shared page
pool ("k_pages"/"v_pages": (L, P, KVH, page_size, hd)) plus a "page_table"
leaf; ``forward`` detects the layout from the leaf names and routes cached
decode through the Pallas decode-attention kernel (s == 1) or chunked
prefill through the paged prefill kernel (s > 1), reading/writing only the
pages each slot owns.  ``scan_generate(page_size=N)`` prefills straight
into the pool (chunked prologue) and runs the fused rollout on that path;
the dense layout stays as the reference oracle.  ``make_chunk_step`` is
the dense-mode chunked-admission step (batch=1 scratch sized to the
prompt, argmax in-graph).
"""

from __future__ import annotations

from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.transformer import forward


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=None) -> dict[str, Any]:
    dtype = dtype or cfg.compute_dtype
    l, kv, hd, d = cfg.num_layers, cfg.num_kv_heads, cfg.hd, cfg.d_model
    if cfg.family in ("dense", "moe", "audio", "vlm"):
        return {"blocks": {
            "k": jnp.zeros((l, batch, kv, max_len, hd), dtype),
            "v": jnp.zeros((l, batch, kv, max_len, hd), dtype),
        }}
    if cfg.family == "hybrid_mamba":
        w, di, n = cfg.ssm_conv_width, cfg.d_inner, cfg.ssm_state
        h, p = cfg.ssm_heads, cfg.ssm_head_dim
        cache = {"blocks": {
            "conv_x": jnp.zeros((l, batch, w - 1, di), dtype),
            "conv_b": jnp.zeros((l, batch, w - 1, n), dtype),
            "conv_c": jnp.zeros((l, batch, w - 1, n), dtype),
            "ssm": jnp.zeros((l, batch, h, p, n), jnp.float32),
        }}
        if cfg.attn_every:
            napp = cfg.num_layers // cfg.attn_every
            cache["shared_attn"] = {
                "k": jnp.zeros((napp, batch, kv, max_len, hd), dtype),
                "v": jnp.zeros((napp, batch, kv, max_len, hd), dtype),
            }
        return cache
    if cfg.family == "rwkv":
        h, hd_r = cfg.rwkv_heads, cfg.rwkv_head_dim
        return {"blocks": {
            "state": jnp.zeros((l, batch, h, hd_r, hd_r), jnp.float32),
            "last_tm": jnp.zeros((l, batch, d), dtype),
            "last_cm": jnp.zeros((l, batch, d), dtype),
        }}
    raise ValueError(f"no cache for family {cfg.family!r}")


def cache_shapes(cfg: ModelConfig, batch: int, max_len: int, dtype=None):
    """ShapeDtypeStruct tree of the cache (dry-run: no allocation)."""
    return jax.eval_shape(partial(init_cache, cfg, batch, max_len, dtype))


def make_prefill_step(cfg: ModelConfig, max_len: int | None = None) -> Callable:
    """(params, batch) -> (logits, cache).  Cache is allocated inside (sized
    max_len or the prompt length)."""

    def prefill_step(params, batch):
        tokens = batch["tokens"]
        b = tokens.shape[0]
        s = tokens.shape[-1]
        cache = init_cache(cfg, b, max_len or s)
        logits, _, cache = forward(params, batch, cfg, cache=cache,
                                   cache_len=jnp.zeros((), jnp.int32))
        return logits, cache

    return prefill_step


def make_decode_step(cfg: ModelConfig) -> Callable:
    """(params, cache, batch, cache_len) -> (logits_1tok, new_cache)."""

    def decode_step(params, cache, batch, cache_len):
        logits, _, cache = forward(params, batch, cfg, cache=cache,
                                   cache_len=cache_len)
        return logits, cache

    return decode_step


def make_chunk_step(cfg: ModelConfig) -> Callable:
    """(params, cache1, chunk, pos) -> (tok, cache1): one prompt chunk
    through a batch=1 scratch cache at absolute offset ``pos``.

    The dense-mode chunked admission step: the scratch cache is sized to
    the (bucketed) prompt — never max_len — so prefill attention stops
    reading max_len worth of mostly-masked keys, and recurrent rows (mamba
    conv/ssm, rwkv state) thread across chunks through the cache.  ``tok``
    is the argmax of the chunk's last position computed in-graph, so
    admission fetches a 4-byte scalar instead of syncing full logits to
    host.
    """

    def chunk_step(params, cache, chunk, pos):
        logits, _, cache = forward(params, {"tokens": chunk}, cfg,
                                   cache=cache, cache_len=pos)
        return jnp.argmax(logits[0, -1]).astype(jnp.int32), cache

    return chunk_step


def _scan_generate_impl(params, prompt: jax.Array, eos_tok: jax.Array, *,
                        cfg: ModelConfig, steps: int, max_len: int,
                        has_eos: bool, page_size: int = 0,
                        prefill_chunk: int = 0):
    """One-compile greedy rollout: prefill + a ``lax.scan`` over decode steps.

    Everything stays on device — argmax, eos masking, cache updates — so an
    N-token rollout is a single XLA executable with zero per-token host
    round-trips (vs. N jit calls + N host syncs for the python loop).  The
    eos *value* is a traced scalar (only its presence is static), so
    per-request eos ids never retrace the rollout.

    ``page_size`` > 0 allocates the page pool up front (identity page
    table) and runs the *chunked direct-to-page prefill* as the rollout
    prologue: each chunk's K/V are scattered straight into the pages and
    attended through the Pallas paged prefill kernel, then every decode
    step in the scan runs the fused Pallas decode-attention kernel over the
    same pool — no dense max_len cache is ever materialized on the paged
    path.  ``prefill_chunk`` bounds the prologue chunk width (0 = whole
    prompt in one chunk).
    """
    b, s = prompt.shape
    if page_size:
        from repro.kernels.ops import chunk_plan
        from repro.serve.paging import init_paged_cache
        npg = max_len // page_size
        cache = init_paged_cache(cfg, b, max_len, page_size=page_size,
                                 num_pages=1 + b * npg)
        cache["page_table"] = (1 + jnp.arange(b * npg, dtype=jnp.int32)
                               ).reshape(b, npg)
        off = 0
        for w in chunk_plan(s, prefill_chunk or s):
            logits, _, cache = forward(params, {"tokens": prompt[:, off:off + w]},
                                       cfg, cache=cache,
                                       cache_len=jnp.asarray(off, jnp.int32))
            off += w
    else:
        cache = init_cache(cfg, b, max_len)
        logits, _, cache = forward(params, {"tokens": prompt}, cfg,
                                   cache=cache,
                                   cache_len=jnp.zeros((), jnp.int32))
    tok0 = jnp.argmax(logits[:, -1], axis=-1).astype(prompt.dtype)
    done0 = (tok0 == eos_tok.astype(tok0.dtype) if has_eos
             else jnp.zeros((b,), bool))

    def body(carry, t):
        cache, tok, done = carry
        logits, _, cache = forward(params, {"tokens": tok[:, None]}, cfg,
                                   cache=cache, cache_len=t)
        nxt = jnp.argmax(logits[:, -1], axis=-1).astype(tok.dtype)
        if has_eos:
            # rows that already emitted eos keep emitting eos (masked greedy)
            eos = eos_tok.astype(nxt.dtype)
            nxt = jnp.where(done, eos, nxt)
            done = done | (nxt == eos)
        return (cache, nxt, done), nxt

    positions = jnp.arange(s, s + steps - 1, dtype=jnp.int32)
    _, toks = jax.lax.scan(body, (cache, tok0, done0), positions)
    return jnp.concatenate([tok0[:, None], toks.T], axis=1)


_scan_generate = partial(jax.jit, static_argnames=(
    "cfg", "steps", "max_len", "has_eos", "page_size", "prefill_chunk",
))(_scan_generate_impl)


def _spec_generate_impl(params, draft_params, prompt: jax.Array,
                        eos_tok: jax.Array, *, cfg: ModelConfig, steps: int,
                        max_len: int, has_eos: bool, spec_k: int,
                        page_size: int = 0, prefill_chunk: int = 0):
    """Self-speculative greedy rollout: draft ``spec_k`` tokens with the
    cheap quantization-plane model, verify all of them (plus the bonus
    position) in ONE full-precision chunk launch, accept the longest
    matching prefix — a ``lax.while_loop`` over rounds instead of a scan
    over tokens.

    Bit-identity argument (the verifier IS the baseline): candidate j of a
    round is the full model's argmax after the prompt, the committed tokens,
    and drafts d_1..d_j; when every d_i (i ≤ j) matched candidate i-1, those
    drafts ARE the committed greedy tokens, so candidate j equals what
    ``_scan_generate_impl`` would emit — and rejected positions are never
    emitted.  Cache consistency needs NO rollback for attention-KV families:
    the verify chunk rewrites K/V at every chunk position with full-precision
    activations (erasing nothing the draft pass computed — drafts run on a
    throwaway fork of the carried cache), and K/V beyond the committed
    length is masked by ``kv_len`` until the next round overwrites it.
    That argument only covers KV-only families; ``scan_generate`` restricts
    ``spec_k > 0`` to them (the batcher handles recurrent families with
    restore + replay).

    The cache/pool is allocated with ``spec_k`` rows of slack past
    ``max_len``: a round at the buffer tail still writes k+1 speculative
    positions, and JAX's clamped dynamic-slice writes would otherwise
    silently corrupt the last committed rows.  Rows that already produced
    ``steps`` tokens keep riding along (their writes land in the slack, the
    emit buffer scatter parks their tokens in the slack columns) until every
    row is finished.
    """
    b, s = prompt.shape
    k = spec_k
    alloc_len = max_len + k
    if page_size:
        from repro.kernels.ops import chunk_plan
        from repro.serve.paging import init_paged_cache
        alloc_len = -(-alloc_len // page_size) * page_size
        npg = alloc_len // page_size
        cache = init_paged_cache(cfg, b, alloc_len, page_size=page_size,
                                 num_pages=1 + b * npg)
        cache["page_table"] = (1 + jnp.arange(b * npg, dtype=jnp.int32)
                               ).reshape(b, npg)
        off = 0
        for w in chunk_plan(s, prefill_chunk or s):
            logits, _, cache = forward(params,
                                       {"tokens": prompt[:, off:off + w]},
                                       cfg, cache=cache,
                                       cache_len=jnp.asarray(off, jnp.int32))
            off += w
    else:
        cache = init_cache(cfg, b, alloc_len)
        logits, _, cache = forward(params, {"tokens": prompt}, cfg,
                                   cache=cache,
                                   cache_len=jnp.zeros((), jnp.int32))
    tok0 = jnp.argmax(logits[:, -1], axis=-1).astype(prompt.dtype)
    done0 = (tok0 == eos_tok.astype(tok0.dtype) if has_eos
             else jnp.zeros((b,), bool))
    # emit buffer col j holds token j+2 of the stream (tok0 is separate);
    # spec_k slack columns absorb finished rows' rides-along writes
    buf0 = jnp.zeros((b, steps + k), prompt.dtype)
    count0 = jnp.ones((b,), jnp.int32)           # tokens emitted incl. tok0
    stats0 = jnp.zeros((3,), jnp.int32)          # rounds, drafted, accepted

    def cond(carry):
        _, _, _, count, _, _ = carry
        return jnp.any(count < steps)

    def body(carry):
        cache, tok, done, count, buf, stats = carry
        done_in = done
        clen = s + count - 1                              # (B,) per-row
        # -- draft: k cheap forwards on a throwaway fork of the cache ------
        dcache = cache
        cur = tok[:, None]
        drafts = []
        for i in range(k):
            dlogits, _, dcache = forward(draft_params, {"tokens": cur}, cfg,
                                         cache=dcache, cache_len=clen + i)
            cur = jnp.argmax(dlogits[:, -1], axis=-1
                             ).astype(tok.dtype)[:, None]
            drafts.append(cur[:, 0])
        dv = jnp.stack(drafts, axis=1)                    # (B, k)
        # -- verify: all k+1 positions in ONE full-precision launch --------
        chunk = jnp.concatenate([tok[:, None], dv], axis=1)
        vlogits, _, cache = forward(params, {"tokens": chunk}, cfg,
                                    cache=cache, cache_len=clen)
        yv = jnp.argmax(vlogits, axis=-1).astype(tok.dtype)   # (B, k+1)
        # longest matching prefix; the committed tokens are the CANDIDATES
        # (the full model's own argmaxes), never the drafts
        match = (dv == yv[:, :k]).astype(jnp.int32)
        acc = jnp.cumprod(match, axis=1).sum(axis=1)          # (B,) in 0..k
        inc = acc + 1                                 # accepted + correction
        if has_eos:
            eos = eos_tok.astype(yv.dtype)
            inc = jnp.where(done, k + 1, inc)
            is_eos = (yv == eos).astype(jnp.int32)
            prev_eos = (jnp.cumsum(is_eos, axis=1) - is_eos) > 0
            emit = jnp.where(done[:, None] | prev_eos, eos, yv)
            within = jnp.arange(k + 1, dtype=jnp.int32)[None, :] < inc[:, None]
            done = done | jnp.any((emit == eos) & within, axis=1)
        else:
            emit = yv
        tok = jnp.take_along_axis(emit, (inc - 1)[:, None], axis=1)[:, 0]
        mask = (count < steps) & ~done_in        # rows whose drafts counted
        stats = stats + jnp.stack([
            jnp.asarray(1, jnp.int32),
            k * mask.sum().astype(jnp.int32),
            jnp.where(mask, acc, 0).sum().astype(jnp.int32)])
        step_inc = jnp.minimum(inc, steps - count)        # frozen rows: 0
        buf = jax.vmap(lambda row, upd, st: jax.lax.dynamic_update_slice(
            row, upd, (st,)))(buf, emit, count - 1)
        return cache, tok, done, count + step_inc, buf, stats

    carry = (cache, tok0, done0, count0, buf0, stats0)
    _, _, _, _, buf, stats = jax.lax.while_loop(cond, body, carry)
    return jnp.concatenate([tok0[:, None], buf[:, :steps - 1]], axis=1), stats


_spec_generate = partial(jax.jit, static_argnames=(
    "cfg", "steps", "max_len", "has_eos", "spec_k", "page_size",
    "prefill_chunk",
))(_spec_generate_impl)


def scan_generate(params, cfg: ModelConfig, prompt: jax.Array, steps: int,
                  max_len: int | None = None, eos_id: int | None = None,
                  page_size: int = 0, prefill_chunk: int = 0, mesh=None,
                  spec_k: int = 0, draft_bits: int = 2,
                  skip_lowrank: bool = True, return_spec_stats: bool = False):
    """Fused greedy decoding: compiles once per (shape, steps), returns the
    (B, steps) token matrix with no per-token host sync.  ``page_size`` > 0
    prefills straight into the paged KV pool (chunked by ``prefill_chunk``;
    0 = one chunk) and routes every decode step through the Pallas
    decode-attention kernel (see serve/paging.py).

    ``spec_k`` > 0 turns on self-speculative decoding: each rollout round
    drafts ``spec_k`` tokens with the ``draft_bits`` high-order mantissa
    plane of the SAME packed weights (serve/speculative.py; ``skip_lowrank``
    drops the x@A prologue too) and verifies them in one chunk-shaped
    full-precision launch — outputs stay bit-identical to ``spec_k=0``, the
    full launch count drops by the acceptance factor.  Restricted to
    KV-only families (dense/moe): the verify overwrite argument does not
    cover recurrent state (the batcher handles those via restore+replay).
    ``return_spec_stats`` also returns {"rounds", "drafted", "accepted"}.

    ``mesh`` (a 1-D ``('model',)`` serving mesh, see launch/mesh.py) runs
    the whole rollout tensor-parallel under shard_map: each device prefills
    and decodes its own KV-head shard with its own Pallas launches and the
    per-layer psums are the only collectives (sharding/serving.py)."""
    _, s = prompt.shape
    eos_tok = jnp.asarray(0 if eos_id is None else eos_id, jnp.int32)
    max_len = max_len or (s + steps)
    if max_len < s + steps:
        # the rollout appends past the cache/pool end otherwise: JAX clamps
        # the dynamic-slice start, so late tokens silently overwrite the
        # last row/page and greedy outputs diverge from the loop oracle
        raise ValueError(
            f"max_len={max_len} cannot hold prompt ({s}) + steps ({steps}) "
            f"tokens; raise max_len or lower steps")
    if page_size:
        max_len = -(-max_len // page_size) * page_size
    if spec_k:
        from repro.serve.speculative import (KV_ONLY_FAMILIES,
                                             check_spec_config,
                                             make_draft_params)
        check_spec_config(spec_k, draft_bits, where="scan_generate")
        if cfg.family not in KV_ONLY_FAMILIES:
            raise ValueError(
                f"scan_generate(spec_k>0) supports KV-only families "
                f"{KV_ONLY_FAMILIES}, not {cfg.family!r}: recurrent state "
                f"integrates every chunk token, so rejected drafts need the "
                f"batcher's restore+replay path (ContinuousBatcher supports "
                f"speculation for those families)")
        draft_params = make_draft_params(params, draft_bits=draft_bits,
                                         skip_lowrank=skip_lowrank)
        if mesh is not None:
            from repro.sharding.serving import plan_for, tp_spec_generate
            toks, stats = tp_spec_generate(
                plan_for(cfg, mesh), params, draft_params, prompt, eos_tok,
                steps=steps, max_len=max_len, has_eos=eos_id is not None,
                spec_k=spec_k, page_size=page_size,
                prefill_chunk=prefill_chunk)
        else:
            toks, stats = _spec_generate(
                params, draft_params, prompt, eos_tok, cfg=cfg, steps=steps,
                max_len=max_len, has_eos=eos_id is not None, spec_k=spec_k,
                page_size=page_size, prefill_chunk=prefill_chunk)
        if return_spec_stats:
            r = [int(v) for v in stats]
            return toks, {"rounds": r[0], "drafted": r[1], "accepted": r[2]}
        return toks
    if mesh is not None:
        from repro.sharding.serving import plan_for, tp_scan_generate
        return tp_scan_generate(
            plan_for(cfg, mesh), params, prompt, eos_tok, steps=steps,
            max_len=max_len, has_eos=eos_id is not None,
            page_size=page_size, prefill_chunk=prefill_chunk)
    return _scan_generate(params, prompt, eos_tok, cfg=cfg, steps=steps,
                          max_len=max_len, has_eos=eos_id is not None,
                          page_size=page_size, prefill_chunk=prefill_chunk)


def greedy_generate(params, cfg: ModelConfig, prompt: jax.Array,
                    steps: int, max_len: int | None = None,
                    eos_id: int | None = None):
    """Greedy decoding (prefill + N-token rollout) — the scan fast path."""
    return scan_generate(params, cfg, prompt, steps, max_len=max_len,
                         eos_id=eos_id)


_DECODE_STEP_CACHE: dict[ModelConfig, Callable] = {}


def _decode_step_jit(cfg: ModelConfig) -> Callable:
    """Per-config cached jit of the decode step (a fresh jax.jit wrapper per
    call would re-trace and re-compile every time)."""
    fn = _DECODE_STEP_CACHE.get(cfg)
    if fn is None:
        fn = _DECODE_STEP_CACHE[cfg] = jax.jit(make_decode_step(cfg))
    return fn


def greedy_generate_loop(params, cfg: ModelConfig, prompt: jax.Array,
                         steps: int, max_len: int | None = None):
    """Reference python token loop (one jit call + host sync per token).

    Kept as the correctness oracle for ``scan_generate`` and as the slow
    baseline in the decode-throughput benchmark.
    """
    b, s = prompt.shape
    max_len = max_len or (s + steps)
    cache = init_cache(cfg, b, max_len)
    logits, _, cache = forward(params, {"tokens": prompt}, cfg, cache=cache,
                               cache_len=jnp.zeros((), jnp.int32))
    tok = jnp.argmax(logits[:, -1], axis=-1).astype(prompt.dtype)
    out = [tok]
    decode = _decode_step_jit(cfg)
    for t in range(steps - 1):
        logits, cache = decode(params, cache, {"tokens": tok[:, None]},
                               jnp.asarray(s + t, jnp.int32))
        tok = jnp.argmax(logits[:, -1], axis=-1).astype(prompt.dtype)
        out.append(tok)
    return jnp.stack(out, axis=1)
