"""Serving engine: per-family cache structs + prefill/decode step factories.

``decode_step`` is what the ``decode_32k`` / ``long_500k`` dry-run cells
lower: one new token against a seq_len cache.  ``prefill_step`` fills the
cache from a prompt (``prefill_32k``).  ``scan_generate`` is the decode fast
path: prefill + an N-token ``lax.scan`` rollout compiled ONCE, with argmax
and eos masking on device (``greedy_generate_loop`` keeps the python-loop
reference).  Caches:

  dense/moe/audio/vlm : {"blocks": {"k","v": (L, B, KVH, S_max, hd)}}
  hybrid_mamba        : {"blocks": {"conv_*", "ssm"}, "shared_attn": {"k","v"}}
  rwkv                : {"blocks": {"state", "last_tm", "last_cm"}}

Paged caches (serve/paging.py) replace the dense K/V rows with a shared page
pool ("k_pages"/"v_pages": (L, P, KVH, page_size, hd)) plus a "page_table"
leaf; ``forward`` detects the layout from the leaf names and routes cached
decode through the Pallas decode-attention kernel (s == 1) or chunked
prefill through the paged prefill kernel (s > 1), reading/writing only the
pages each slot owns.  ``scan_generate(page_size=N)`` prefills straight
into the pool (chunked prologue) and runs the fused rollout on that path;
the dense layout stays as the reference oracle.  ``make_chunk_step`` is
the dense-mode chunked-admission step (batch=1 scratch sized to the
prompt, argmax in-graph).
"""

from __future__ import annotations

from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.transformer import forward


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=None) -> dict[str, Any]:
    dtype = dtype or cfg.compute_dtype
    l, kv, hd, d = cfg.num_layers, cfg.num_kv_heads, cfg.hd, cfg.d_model
    if cfg.family in ("dense", "moe", "audio", "vlm"):
        return {"blocks": {
            "k": jnp.zeros((l, batch, kv, max_len, hd), dtype),
            "v": jnp.zeros((l, batch, kv, max_len, hd), dtype),
        }}
    if cfg.family == "hybrid_mamba":
        w, di, n = cfg.ssm_conv_width, cfg.d_inner, cfg.ssm_state
        h, p = cfg.ssm_heads, cfg.ssm_head_dim
        cache = {"blocks": {
            "conv_x": jnp.zeros((l, batch, w - 1, di), dtype),
            "conv_b": jnp.zeros((l, batch, w - 1, n), dtype),
            "conv_c": jnp.zeros((l, batch, w - 1, n), dtype),
            "ssm": jnp.zeros((l, batch, h, p, n), jnp.float32),
        }}
        if cfg.attn_every:
            napp = cfg.num_layers // cfg.attn_every
            cache["shared_attn"] = {
                "k": jnp.zeros((napp, batch, kv, max_len, hd), dtype),
                "v": jnp.zeros((napp, batch, kv, max_len, hd), dtype),
            }
        return cache
    if cfg.family == "rwkv":
        h, hd_r = cfg.rwkv_heads, cfg.rwkv_head_dim
        return {"blocks": {
            "state": jnp.zeros((l, batch, h, hd_r, hd_r), jnp.float32),
            "last_tm": jnp.zeros((l, batch, d), dtype),
            "last_cm": jnp.zeros((l, batch, d), dtype),
        }}
    raise ValueError(f"no cache for family {cfg.family!r}")


def cache_shapes(cfg: ModelConfig, batch: int, max_len: int, dtype=None):
    """ShapeDtypeStruct tree of the cache (dry-run: no allocation)."""
    return jax.eval_shape(partial(init_cache, cfg, batch, max_len, dtype))


def make_prefill_step(cfg: ModelConfig, max_len: int | None = None) -> Callable:
    """(params, batch) -> (logits, cache).  Cache is allocated inside (sized
    max_len or the prompt length)."""

    def prefill_step(params, batch):
        tokens = batch["tokens"]
        b = tokens.shape[0]
        s = tokens.shape[-1]
        cache = init_cache(cfg, b, max_len or s)
        logits, _, cache = forward(params, batch, cfg, cache=cache,
                                   cache_len=jnp.zeros((), jnp.int32))
        return logits, cache

    return prefill_step


def make_decode_step(cfg: ModelConfig) -> Callable:
    """(params, cache, batch, cache_len) -> (logits_1tok, new_cache)."""

    def decode_step(params, cache, batch, cache_len):
        logits, _, cache = forward(params, batch, cfg, cache=cache,
                                   cache_len=cache_len)
        return logits, cache

    return decode_step


def make_chunk_step(cfg: ModelConfig) -> Callable:
    """(params, cache1, chunk, pos) -> (tok, cache1): one prompt chunk
    through a batch=1 scratch cache at absolute offset ``pos``.

    The dense-mode chunked admission step: the scratch cache is sized to
    the (bucketed) prompt — never max_len — so prefill attention stops
    reading max_len worth of mostly-masked keys, and recurrent rows (mamba
    conv/ssm, rwkv state) thread across chunks through the cache.  ``tok``
    is the argmax of the chunk's last position computed in-graph, so
    admission fetches a 4-byte scalar instead of syncing full logits to
    host.
    """

    def chunk_step(params, cache, chunk, pos):
        logits, _, cache = forward(params, {"tokens": chunk}, cfg,
                                   cache=cache, cache_len=pos)
        return jnp.argmax(logits[0, -1]).astype(jnp.int32), cache

    return chunk_step


def _scan_generate_impl(params, prompt: jax.Array, eos_tok: jax.Array, *,
                        cfg: ModelConfig, steps: int, max_len: int,
                        has_eos: bool, page_size: int = 0,
                        prefill_chunk: int = 0):
    """One-compile greedy rollout: prefill + a ``lax.scan`` over decode steps.

    Everything stays on device — argmax, eos masking, cache updates — so an
    N-token rollout is a single XLA executable with zero per-token host
    round-trips (vs. N jit calls + N host syncs for the python loop).  The
    eos *value* is a traced scalar (only its presence is static), so
    per-request eos ids never retrace the rollout.

    ``page_size`` > 0 allocates the page pool up front (identity page
    table) and runs the *chunked direct-to-page prefill* as the rollout
    prologue: each chunk's K/V are scattered straight into the pages and
    attended through the Pallas paged prefill kernel, then every decode
    step in the scan runs the fused Pallas decode-attention kernel over the
    same pool — no dense max_len cache is ever materialized on the paged
    path.  ``prefill_chunk`` bounds the prologue chunk width (0 = whole
    prompt in one chunk).
    """
    b, s = prompt.shape
    if page_size:
        from repro.kernels.ops import chunk_plan
        from repro.serve.paging import init_paged_cache
        npg = max_len // page_size
        cache = init_paged_cache(cfg, b, max_len, page_size=page_size,
                                 num_pages=1 + b * npg)
        cache["page_table"] = (1 + jnp.arange(b * npg, dtype=jnp.int32)
                               ).reshape(b, npg)
        off = 0
        for w in chunk_plan(s, prefill_chunk or s):
            logits, _, cache = forward(params, {"tokens": prompt[:, off:off + w]},
                                       cfg, cache=cache,
                                       cache_len=jnp.asarray(off, jnp.int32))
            off += w
    else:
        cache = init_cache(cfg, b, max_len)
        logits, _, cache = forward(params, {"tokens": prompt}, cfg,
                                   cache=cache,
                                   cache_len=jnp.zeros((), jnp.int32))
    tok0 = jnp.argmax(logits[:, -1], axis=-1).astype(prompt.dtype)
    done0 = (tok0 == eos_tok.astype(tok0.dtype) if has_eos
             else jnp.zeros((b,), bool))

    def body(carry, t):
        cache, tok, done = carry
        logits, _, cache = forward(params, {"tokens": tok[:, None]}, cfg,
                                   cache=cache, cache_len=t)
        nxt = jnp.argmax(logits[:, -1], axis=-1).astype(tok.dtype)
        if has_eos:
            # rows that already emitted eos keep emitting eos (masked greedy)
            eos = eos_tok.astype(nxt.dtype)
            nxt = jnp.where(done, eos, nxt)
            done = done | (nxt == eos)
        return (cache, nxt, done), nxt

    positions = jnp.arange(s, s + steps - 1, dtype=jnp.int32)
    _, toks = jax.lax.scan(body, (cache, tok0, done0), positions)
    return jnp.concatenate([tok0[:, None], toks.T], axis=1)


_scan_generate = partial(jax.jit, static_argnames=(
    "cfg", "steps", "max_len", "has_eos", "page_size", "prefill_chunk",
))(_scan_generate_impl)


def scan_generate(params, cfg: ModelConfig, prompt: jax.Array, steps: int,
                  max_len: int | None = None, eos_id: int | None = None,
                  page_size: int = 0, prefill_chunk: int = 0, mesh=None):
    """Fused greedy decoding: compiles once per (shape, steps), returns the
    (B, steps) token matrix with no per-token host sync.  ``page_size`` > 0
    prefills straight into the paged KV pool (chunked by ``prefill_chunk``;
    0 = one chunk) and routes every decode step through the Pallas
    decode-attention kernel (see serve/paging.py).

    ``mesh`` (a 1-D ``('model',)`` serving mesh, see launch/mesh.py) runs
    the whole rollout tensor-parallel under shard_map: each device prefills
    and decodes its own KV-head shard with its own Pallas launches and the
    per-layer psums are the only collectives (sharding/serving.py)."""
    _, s = prompt.shape
    eos_tok = jnp.asarray(0 if eos_id is None else eos_id, jnp.int32)
    max_len = max_len or (s + steps)
    if max_len < s + steps:
        # the rollout appends past the cache/pool end otherwise: JAX clamps
        # the dynamic-slice start, so late tokens silently overwrite the
        # last row/page and greedy outputs diverge from the loop oracle
        raise ValueError(
            f"max_len={max_len} cannot hold prompt ({s}) + steps ({steps}) "
            f"tokens; raise max_len or lower steps")
    if page_size:
        max_len = -(-max_len // page_size) * page_size
    if mesh is not None:
        from repro.sharding.serving import plan_for, tp_scan_generate
        return tp_scan_generate(
            plan_for(cfg, mesh), params, prompt, eos_tok, steps=steps,
            max_len=max_len, has_eos=eos_id is not None,
            page_size=page_size, prefill_chunk=prefill_chunk)
    return _scan_generate(params, prompt, eos_tok, cfg=cfg, steps=steps,
                          max_len=max_len, has_eos=eos_id is not None,
                          page_size=page_size, prefill_chunk=prefill_chunk)


def greedy_generate(params, cfg: ModelConfig, prompt: jax.Array,
                    steps: int, max_len: int | None = None,
                    eos_id: int | None = None):
    """Greedy decoding (prefill + N-token rollout) — the scan fast path."""
    return scan_generate(params, cfg, prompt, steps, max_len=max_len,
                         eos_id=eos_id)


_DECODE_STEP_CACHE: dict[ModelConfig, Callable] = {}


def _decode_step_jit(cfg: ModelConfig) -> Callable:
    """Per-config cached jit of the decode step (a fresh jax.jit wrapper per
    call would re-trace and re-compile every time)."""
    fn = _DECODE_STEP_CACHE.get(cfg)
    if fn is None:
        fn = _DECODE_STEP_CACHE[cfg] = jax.jit(make_decode_step(cfg))
    return fn


def greedy_generate_loop(params, cfg: ModelConfig, prompt: jax.Array,
                         steps: int, max_len: int | None = None):
    """Reference python token loop (one jit call + host sync per token).

    Kept as the correctness oracle for ``scan_generate`` and as the slow
    baseline in the decode-throughput benchmark.
    """
    b, s = prompt.shape
    max_len = max_len or (s + steps)
    cache = init_cache(cfg, b, max_len)
    logits, _, cache = forward(params, {"tokens": prompt}, cfg, cache=cache,
                               cache_len=jnp.zeros((), jnp.int32))
    tok = jnp.argmax(logits[:, -1], axis=-1).astype(prompt.dtype)
    out = [tok]
    decode = _decode_step_jit(cfg)
    for t in range(steps - 1):
        logits, cache = decode(params, cache, {"tokens": tok[:, None]},
                               jnp.asarray(s + t, jnp.int32))
        tok = jnp.argmax(logits[:, -1], axis=-1).astype(prompt.dtype)
        out.append(tok)
    return jnp.stack(out, axis=1)
