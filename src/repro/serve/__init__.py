from repro.serve.engine import (
    cache_shapes,
    greedy_generate,
    init_cache,
    make_decode_step,
    make_prefill_step,
)
