from repro.serve.engine import (
    cache_shapes,
    greedy_generate,
    greedy_generate_loop,
    init_cache,
    make_decode_step,
    make_prefill_step,
    scan_generate,
)
from repro.serve.paging import (
    PagePool,
    dense_to_paged,
    init_paged_cache,
    make_place_pages,
    page_bucket,
)

__all__ = [
    "PagePool",
    "cache_shapes",
    "dense_to_paged",
    "greedy_generate",
    "greedy_generate_loop",
    "init_cache",
    "init_paged_cache",
    "make_decode_step",
    "make_place_pages",
    "make_prefill_step",
    "page_bucket",
    "scan_generate",
]
