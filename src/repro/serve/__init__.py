from repro.serve.engine import (
    cache_shapes,
    greedy_generate,
    greedy_generate_loop,
    init_cache,
    make_chunk_step,
    make_decode_step,
    make_prefill_step,
    scan_generate,
)
from repro.serve.paging import (
    PagePool,
    PrefixIndex,
    dense_to_paged,
    init_paged_cache,
    make_chunk_prefill,
    make_fork_page,
    make_zero_slot,
    page_bucket,
)

__all__ = [
    "PagePool",
    "PrefixIndex",
    "cache_shapes",
    "dense_to_paged",
    "greedy_generate",
    "greedy_generate_loop",
    "init_cache",
    "init_paged_cache",
    "make_chunk_prefill",
    "make_chunk_step",
    "make_decode_step",
    "make_fork_page",
    "make_prefill_step",
    "make_zero_slot",
    "page_bucket",
    "scan_generate",
]
