from repro.serve.engine import (
    cache_shapes,
    greedy_generate,
    greedy_generate_loop,
    init_cache,
    make_decode_step,
    make_prefill_step,
    scan_generate,
)
