"""MXINT block-floating-point emulation (OCP-MX style).

The paper's quantization format: ``emulated MXINT with block size 32``
(4-/3-bit) and ``block size 16`` (2-bit).  A block of ``block_size``
consecutive weights along the *input* dimension shares one 8-bit exponent;
each element stores a signed ``bits``-bit integer mantissa.

Average bits/weight = bits + 8 / block_size:
    MXINT4 bs=32 -> 4.25    MXINT3 bs=32 -> 3.25    MXINT2 bs=16 -> 2.50

All q/dq functions are pure-jnp and jittable.  ``mxint_fake_quant`` is the
quantize->dequantize roundtrip used everywhere the framework needs W-tilde.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class MXINTSpec(NamedTuple):
    bits: int          # mantissa bits incl. sign
    block_size: int    # elements sharing one exponent

    @property
    def average_bits(self) -> float:
        return self.bits + 8.0 / self.block_size


MXINT_CONFIGS = {
    "mxint8": MXINTSpec(8, 32),
    "mxint4": MXINTSpec(4, 32),
    "mxint3": MXINTSpec(3, 32),
    "mxint2": MXINTSpec(2, 16),
    "mxint2_bs32": MXINTSpec(2, 32),
}


def _blocked(w: jax.Array, block_size: int) -> tuple[jax.Array, tuple[int, ...]]:
    """Reshape (..., m, n) -> (..., m//bs, bs, n) along the input (row) dim.

    Blocking runs along the *input-feature* (contraction) axis, matching how
    a dequant-matmul kernel walks memory.  Rows must divide block_size; all
    real layer dims here are multiples of 16.
    """
    *lead, m, n = w.shape
    if m % block_size != 0:
        raise ValueError(f"input dim {m} not divisible by block_size {block_size}")
    return w.reshape(*lead, m // block_size, block_size, n), (*lead, m, n)


def mxint_quantize(w: jax.Array, bits: int, block_size: int):
    """Quantize to (mantissa int8, shared exponent int8).

    mantissa in [-(2^(bits-1)-1), 2^(bits-1)-1]  (symmetric, no -2^(b-1) to
    keep dequant scale symmetric), exponent e such that
    scale = 2^(e - (bits - 2)) covers max|block|.
    """
    wb, _ = _blocked(w.astype(jnp.float32), block_size)
    maxabs = jnp.max(jnp.abs(wb), axis=-2, keepdims=True)  # (..., nb, 1, n)
    # exponent of max |x|: floor(log2(maxabs)); guard zeros.
    safe = jnp.where(maxabs > 0, maxabs, 1.0)
    e = jnp.floor(jnp.log2(safe)).astype(jnp.int32)
    e = jnp.clip(e, -126, 127)
    qmax = 2 ** (bits - 1) - 1
    scale = jnp.exp2(e.astype(jnp.float32) - (bits - 2))
    # After the floor, maxabs/scale can be up to 2^(bits-1) (=qmax+1); bump the
    # exponent where the rounded mantissa would overflow.
    over = jnp.round(maxabs / scale) > qmax
    e = jnp.where(over, e + 1, e)
    scale = jnp.exp2(e.astype(jnp.float32) - (bits - 2))
    mant = jnp.clip(jnp.round(wb / scale), -qmax, qmax).astype(jnp.int8)
    return mant, e.squeeze(-2).astype(jnp.int8)  # (..., nb, bs, n), (..., nb, n)


def mxint_dequantize(mant: jax.Array, exp: jax.Array, bits: int,
                     out_shape: tuple[int, ...] | None = None,
                     dtype=jnp.float32) -> jax.Array:
    scale = jnp.exp2(exp.astype(jnp.float32) - (bits - 2))[..., :, None, :]
    w = mant.astype(jnp.float32) * scale
    *lead, nb, bs, n = w.shape
    w = w.reshape(*lead, nb * bs, n)
    if out_shape is not None:
        w = w.reshape(out_shape)
    return w.astype(dtype)


def mxint_fake_quant(w: jax.Array, bits: int, block_size: int) -> jax.Array:
    """dq(q(w)) with the original shape/dtype (the emulation the paper uses).

    Input dims that do not divide ``block_size`` are zero-padded for the
    block reduction and cropped back (padding never changes a block's maxabs
    direction since pad values are 0).
    """
    m = w.shape[-2]
    pad = (-m) % block_size
    if pad:
        widths = [(0, 0)] * (w.ndim - 2) + [(0, pad), (0, 0)]
        wp = jnp.pad(w, widths)
        mant, exp = mxint_quantize(wp, bits, block_size)
        out = mxint_dequantize(mant, exp, bits, out_shape=wp.shape, dtype=w.dtype)
        return out[..., :m, :]
    mant, exp = mxint_quantize(w, bits, block_size)
    return mxint_dequantize(mant, exp, bits, out_shape=w.shape, dtype=w.dtype)


class PackedMXINT(NamedTuple):
    """Storage layout the Pallas kernel consumes: int8 mantissa laid out as the
    original (m, n) matrix plus per-(block,col) int8 exponents."""
    mant: jax.Array      # (m, n) int8
    exp: jax.Array       # (m // block_size, n) int8
    bits: int
    block_size: int
    shape: tuple[int, int]


def pack_mxint(w: jax.Array, bits: int, block_size: int) -> PackedMXINT:
    mant, exp = mxint_quantize(w, bits, block_size)
    m, n = w.shape[-2], w.shape[-1]
    mant2d = mant.reshape(*w.shape[:-2], m, n)
    return PackedMXINT(mant2d, exp, bits, block_size, (m, n))


def unpack_mxint(p: PackedMXINT, dtype=jnp.float32) -> jax.Array:
    m, n = p.shape
    mant = p.mant.reshape(*p.mant.shape[:-2], m // p.block_size, p.block_size, n)
    return mxint_dequantize(mant, p.exp, p.bits, dtype=dtype)
