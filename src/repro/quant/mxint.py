"""MXINT block-floating-point emulation (OCP-MX style).

The paper's quantization format: ``emulated MXINT with block size 32``
(4-/3-bit) and ``block size 16`` (2-bit).  A block of ``block_size``
consecutive weights along the *input* dimension shares one 8-bit exponent;
each element stores a signed ``bits``-bit integer mantissa.

Average bits/weight = bits + 8 / block_size:
    MXINT4 bs=32 -> 4.25    MXINT3 bs=32 -> 3.25    MXINT2 bs=16 -> 2.50

All q/dq functions are pure-jnp and jittable.  ``mxint_fake_quant`` is the
quantize->dequantize roundtrip used everywhere the framework needs W-tilde.

Sub-byte HBM storage
--------------------

``pack_mantissa``/``unpack_mantissa`` store mantissas truly sub-byte so the
HBM bytes moved match the nominal bit-width instead of one int8 per element:

* container width = smallest power-of-two >= bits (``container_bits``):
  4-bit -> 4, 3-bit -> 4 (two per byte, savings are 4 bits/elt — documented,
  not the ideal 3), 2-bit -> 2 (four per byte), 8-bit -> 8 (no packing).
* ``elems_per_byte`` (epb) = 8 // container.  Packing runs along the
  *input* (row / contraction) axis: byte row ``u`` of the packed (K/epb, N)
  int8 buffer holds element rows ``u*epb + j`` for ``j`` in ``range(epb)``,
  field ``j`` occupying bits ``[j*w, (j+1)*w)`` — little-endian within the
  byte, so the LOW nibble is the EVEN row.  Fields are two's-complement at
  container width (sign-extension recovers the int8 mantissa exactly).

The fused Pallas kernels (``kernels/mxint_matmul``) consume this layout
directly: the mantissa BlockSpec shrinks to (bk // epb, bn) and the kernel
widens to int32 and sign-extends in VMEM right before the dequant-dot, so
only packed bytes ever cross HBM.  ``packed=False`` on ``pack_mxint`` /
``core.api.pack_for_serving`` keeps the flat int8 layout as an
interpret-mode debugging escape hatch.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class MXINTSpec(NamedTuple):
    bits: int          # mantissa bits incl. sign
    block_size: int    # elements sharing one exponent

    @property
    def average_bits(self) -> float:
        return self.bits + 8.0 / self.block_size


MXINT_CONFIGS = {
    "mxint8": MXINTSpec(8, 32),
    "mxint4": MXINTSpec(4, 32),
    "mxint3": MXINTSpec(3, 32),
    "mxint2": MXINTSpec(2, 16),
    "mxint2_bs32": MXINTSpec(2, 32),
}


def _blocked(w: jax.Array, block_size: int) -> tuple[jax.Array, tuple[int, ...]]:
    """Reshape (..., m, n) -> (..., m//bs, bs, n) along the input (row) dim.

    Blocking runs along the *input-feature* (contraction) axis, matching how
    a dequant-matmul kernel walks memory.  Rows must divide block_size; all
    real layer dims here are multiples of 16.
    """
    *lead, m, n = w.shape
    if m % block_size != 0:
        raise ValueError(f"input dim {m} not divisible by block_size {block_size}")
    return w.reshape(*lead, m // block_size, block_size, n), (*lead, m, n)


def mxint_quantize(w: jax.Array, bits: int, block_size: int):
    """Quantize to (mantissa int8, shared exponent int8).

    mantissa in [-(2^(bits-1)-1), 2^(bits-1)-1]  (symmetric, no -2^(b-1) to
    keep dequant scale symmetric), exponent e such that
    scale = 2^(e - (bits - 2)) covers max|block|.
    """
    wb, _ = _blocked(w.astype(jnp.float32), block_size)
    maxabs = jnp.max(jnp.abs(wb), axis=-2, keepdims=True)  # (..., nb, 1, n)
    # exponent of max |x|: floor(log2(maxabs)); guard zeros.
    safe = jnp.where(maxabs > 0, maxabs, 1.0)
    e = jnp.floor(jnp.log2(safe)).astype(jnp.int32)
    e = jnp.clip(e, -126, 127)
    qmax = 2 ** (bits - 1) - 1
    scale = jnp.exp2(e.astype(jnp.float32) - (bits - 2))
    # After the floor, maxabs/scale can be up to 2^(bits-1) (=qmax+1); bump the
    # exponent where the rounded mantissa would overflow.
    over = jnp.round(maxabs / scale) > qmax
    # re-clip AFTER the bump: a block whose maxabs needs the bump at e = 127
    # would otherwise emit e = 128, which wraps to -128 on the int8 cast and
    # dequantizes to garbage — clamping saturates the mantissa at qmax instead.
    e = jnp.clip(jnp.where(over, e + 1, e), -126, 127)
    scale = jnp.exp2(e.astype(jnp.float32) - (bits - 2))
    mant = jnp.clip(jnp.round(wb / scale), -qmax, qmax).astype(jnp.int8)
    return mant, e.squeeze(-2).astype(jnp.int8)  # (..., nb, bs, n), (..., nb, n)


def mxint_dequantize(mant: jax.Array, exp: jax.Array, bits: int,
                     out_shape: tuple[int, ...] | None = None,
                     dtype=jnp.float32) -> jax.Array:
    scale = jnp.exp2(exp.astype(jnp.float32) - (bits - 2))[..., :, None, :]
    w = mant.astype(jnp.float32) * scale
    *lead, nb, bs, n = w.shape
    w = w.reshape(*lead, nb * bs, n)
    if out_shape is not None:
        w = w.reshape(out_shape)
    return w.astype(dtype)


def mxint_fake_quant(w: jax.Array, bits: int, block_size: int) -> jax.Array:
    """dq(q(w)) with the original shape/dtype (the emulation the paper uses).

    Input dims that do not divide ``block_size`` are zero-padded for the
    block reduction and cropped back (padding never changes a block's maxabs
    direction since pad values are 0).
    """
    m = w.shape[-2]
    pad = (-m) % block_size
    if pad:
        widths = [(0, 0)] * (w.ndim - 2) + [(0, pad), (0, 0)]
        wp = jnp.pad(w, widths)
        mant, exp = mxint_quantize(wp, bits, block_size)
        out = mxint_dequantize(mant, exp, bits, out_shape=wp.shape, dtype=w.dtype)
        return out[..., :m, :]
    mant, exp = mxint_quantize(w, bits, block_size)
    return mxint_dequantize(mant, exp, bits, out_shape=w.shape, dtype=w.dtype)


# ---------------------------------------------------------------------------
# sub-byte mantissa packing (HBM layout; see module docstring for the format)
# ---------------------------------------------------------------------------

def container_bits(bits: int) -> int:
    """Storage width per element: smallest power-of-two >= bits (max 8)."""
    w = 8
    while w // 2 >= bits:
        w //= 2
    return w


def elems_per_byte(bits: int) -> int:
    """How many mantissas share one stored byte (1 for >4-bit formats)."""
    return 8 // container_bits(bits)


def pack_fields(mant: jax.Array, epb: int) -> jax.Array:
    """(..., K, N) int8 mantissas -> (..., ceil(K/epb), N) int8 bytes.

    Byte row u, field j (bits [j*w, (j+1)*w), w = 8/epb) <- element row
    u*epb + j.  K not divisible by epb is zero-padded (unpack crops).
    """
    if epb == 1:
        return mant
    w = 8 // epb
    k = mant.shape[-2]
    pad = (-k) % epb
    if pad:
        widths = [(0, 0)] * (mant.ndim - 2) + [(0, pad), (0, 0)]
        mant = jnp.pad(mant, widths)
    g = mant.astype(jnp.int32) & ((1 << w) - 1)
    *lead, kp, n = g.shape
    g = g.reshape(*lead, kp // epb, epb, n)
    out = g[..., 0, :]
    for j in range(1, epb):
        out = out | (g[..., j, :] << (j * w))
    return out.astype(jnp.int8)


def unpack_fields(packed: jax.Array, epb: int,
                  k: int | None = None) -> jax.Array:
    """Inverse of ``pack_fields``: sign-extend each field back to int8.

    ``k`` crops the row axis (needed when pack zero-padded a non-aligned K).
    """
    if epb == 1:
        return packed
    w = 8 // epb
    p32 = packed.astype(jnp.int32)
    # field j: left-align (drop higher fields), arithmetic-shift back down
    # so the container-width two's-complement sign lands in bit 31 first.
    parts = [(p32 << (32 - w * (j + 1))) >> (32 - w) for j in range(epb)]
    st = jnp.stack(parts, axis=-2)                # (..., Kp, epb, N)
    *lead, kp, _, n = st.shape
    out = st.reshape(*lead, kp * epb, n).astype(jnp.int8)
    return out if k is None else out[..., :k, :]


def pack_mantissa(mant: jax.Array, bits: int) -> jax.Array:
    """Pack flat int8 mantissas along the input axis for ``bits``-bit MXINT."""
    return pack_fields(mant, elems_per_byte(bits))


def unpack_mantissa(packed: jax.Array, bits: int,
                    k: int | None = None) -> jax.Array:
    return unpack_fields(packed, elems_per_byte(bits), k)


# ---------------------------------------------------------------------------
# draft mantissa plane (self-speculative decoding's cheap forward pass)
# ---------------------------------------------------------------------------
#
# The packed layout stores every mantissa in a ``container_bits(bits)``-wide
# two's-complement field, so the HIGH-order ``d`` bits of each field are
# themselves a valid signed d-bit mantissa for the SAME block exponent — a
# coarser quantization of the same weight, readable from the same HBM bytes.
# With shift s = container_bits(bits) - d:
#
#     mant_draft = mant >> s            (arithmetic shift = floor(mant / 2^s))
#     scale_draft = 2^(e - (bits - 2) + s) = scale * 2^s
#
# so mant_draft * scale_draft approximates mant * scale with the low s bits
# of the container dropped.  The shift is defined against the CONTAINER
# width, not ``bits``: the 3-bit format stores 4-bit containers, and plane
# extraction straight from packed bytes naturally yields the container-top
# bits, keeping packed, flat, and kernel paths bit-identical.

def draft_shift(bits: int, draft_bits: int) -> int:
    """Arithmetic right-shift extracting the ``draft_bits`` high-order plane
    from a ``bits``-bit mantissa container."""
    c = container_bits(bits)
    if not 1 <= draft_bits <= c:
        raise ValueError(
            f"draft_bits={draft_bits} outside [1, container={c}] for "
            f"{bits}-bit mantissas")
    return c - draft_bits


def unpack_fields_plane(packed: jax.Array, epb: int, draft_bits: int,
                        k: int | None = None) -> jax.Array:
    """Top-``draft_bits`` plane of each packed field, sign-extended to int8.

    Bit-identical to ``unpack_fields(packed, epb, k) >> (w - draft_bits)``
    (w = 8 // epb, arithmetic shift) but extracted in one shift per field:
    left-align the field so its sign bit lands at bit 31, then
    arithmetic-shift down keeping only ``draft_bits`` of it.  ``epb == 1``
    means an 8-bit container (mxint8); the flat int8 escape hatch for
    narrower formats should shift by ``draft_shift(bits, draft_bits)``
    directly instead.
    """
    w = 8 // epb
    if not 1 <= draft_bits <= w:
        raise ValueError(f"draft_bits={draft_bits} outside [1, {w}]")
    p32 = packed.astype(jnp.int32)
    if epb == 1:
        return (p32 >> (8 - draft_bits)).astype(jnp.int8)
    parts = [(p32 << (32 - w * (j + 1))) >> (32 - draft_bits)
             for j in range(epb)]
    st = jnp.stack(parts, axis=-2)                # (..., Kp, epb, N)
    *lead, kp, _, n = st.shape
    out = st.reshape(*lead, kp * epb, n).astype(jnp.int8)
    return out if k is None else out[..., :k, :]


def mxint_draft_dequantize(mant: jax.Array, exp: jax.Array, bits: int,
                           draft_bits: int, dtype=jnp.float32) -> jax.Array:
    """Host reference: dequantize the draft plane from FLAT (K, N) int8
    mantissas + (K/bs, N) exponents.  The oracle the packed/kernel draft
    paths must match bit-for-bit."""
    s = draft_shift(bits, draft_bits)
    k = mant.shape[-2]
    bs = k // exp.shape[-2]
    md = jnp.right_shift(mant.astype(jnp.int32), s)
    scale = jnp.exp2(exp.astype(jnp.float32) - (bits - 2) + s)
    w = md.astype(jnp.float32) * jnp.repeat(scale, bs, axis=-2)
    return w.astype(dtype)


class PackedMXINT(NamedTuple):
    """Storage layout the Pallas kernel consumes: int8 mantissa bytes —
    sub-byte packed along the input axis when ``packed`` (the HBM layout the
    kernels unpack in VMEM) or one int8 per element otherwise — plus
    per-(block, col) int8 exponents."""
    mant: jax.Array      # (m // elems_per_byte(bits), n) int8 if packed
    exp: jax.Array       # (m // block_size, n) int8
    bits: int
    block_size: int
    shape: tuple[int, int]
    packed: bool = True


def pack_mxint(w: jax.Array, bits: int, block_size: int,
               packed: bool = True) -> PackedMXINT:
    mant, exp = mxint_quantize(w, bits, block_size)
    m, n = w.shape[-2], w.shape[-1]
    mant2d = mant.reshape(*w.shape[:-2], m, n)
    if packed:
        mant2d = pack_mantissa(mant2d, bits)
    return PackedMXINT(mant2d, exp, bits, block_size, (m, n), packed)


def unpack_mxint(p: PackedMXINT, dtype=jnp.float32) -> jax.Array:
    m, n = p.shape
    mant = unpack_mantissa(p.mant, p.bits, m) if p.packed else p.mant
    mant = mant.reshape(*mant.shape[:-2], m // p.block_size, p.block_size, n)
    return mxint_dequantize(mant, p.exp, p.bits, dtype=dtype)


# ---------------------------------------------------------------------------
# tensor-parallel shard validity (sharding/serving.py uses these to place the
# packed buffers on a mesh without ever splitting a byte or exponent block)
# ---------------------------------------------------------------------------

def packed_shard_granule(bits: int, block_size: int) -> int:
    """Smallest input-dim (K) granule a row-parallel shard must be a multiple
    of: lcm(block_size, 8 * epb).

    block_size keeps every shard's exponent blocks whole (an exponent is
    shared by a block of K rows — splitting one across devices would need a
    cross-device dequant); 8 * epb keeps whole packed bytes per shard AND
    leaves the per-shard packed tile (K_local / epb rows) 8-sublane-aligned,
    so the single-device Pallas layout stays valid verbatim on each shard.
    Column (N) sharding has no granule beyond lane alignment: packing runs
    along K, so splitting columns never divides a byte or a block.
    """
    import math
    return math.lcm(block_size, 8 * elems_per_byte(bits))


def validate_packed_sharding(k: int, tp: int, bits: int, block_size: int, *,
                             name: str = "") -> int:
    """Check a K=``k`` packed buffer can shard row-parallel ``tp`` ways;
    returns the local K.  Raises a clear ValueError (layer name included)
    instead of letting an off-granule shard reach the kernel."""
    what = f" for {name}" if name else ""
    if k % tp:
        raise ValueError(
            f"K={k}{what} does not divide across tp={tp} devices")
    g = packed_shard_granule(bits, block_size)
    if (k // tp) % g:
        raise ValueError(
            f"row-parallel shard K/tp={k // tp}{what} is not a multiple of "
            f"the packed granule {g} (= lcm(block_size={block_size}, "
            f"8*epb={8 * elems_per_byte(bits)})): a shard would split an "
            f"exponent block or a packed byte, or break 8-sublane alignment")
    return k // tp


def shard_packed(p: PackedMXINT, tp: int, axis: str) -> list[PackedMXINT]:
    """Split a packed buffer into ``tp`` per-device shards ("row" splits K,
    "column" splits N), each a valid standalone PackedMXINT the fused kernel
    consumes unchanged.  Reference implementation for tests and snapshot
    tooling; the serving path shards lazily via NamedSharding device_put."""
    k, n = p.shape
    if axis == "column":
        if n % tp:
            raise ValueError(f"N={n} does not divide across tp={tp} devices")
        step = n // tp
        return [PackedMXINT(p.mant[..., :, d * step:(d + 1) * step],
                            p.exp[..., :, d * step:(d + 1) * step],
                            p.bits, p.block_size, (k, step), p.packed)
                for d in range(tp)]
    if axis != "row":
        raise ValueError(f"axis must be 'row' or 'column', got {axis!r}")
    k_loc = validate_packed_sharding(k, tp, p.bits, p.block_size)
    mstep = k_loc // (elems_per_byte(p.bits) if p.packed else 1)
    estep = k_loc // p.block_size
    return [PackedMXINT(p.mant[..., d * mstep:(d + 1) * mstep, :],
                        p.exp[..., d * estep:(d + 1) * estep, :],
                        p.bits, p.block_size, (k_loc, n), p.packed)
            for d in range(tp)]
