"""Quantizer registry: name -> fake-quant callable + bits accounting.

A ``QuantConfig`` fully describes q()/dq() for the framework; QERA itself is
format-agnostic (the paper: "QERA adds no constraints to the quantization
function").
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable

import jax

from repro.quant.mxint import MXINT_CONFIGS, mxint_fake_quant
from repro.quant.intq import int_fake_quant
from repro.quant.nf4 import nf4_fake_quant


@dataclasses.dataclass(frozen=True)
class QuantConfig:
    name: str                 # registry key, e.g. "mxint4"
    fake_quant: Callable[[jax.Array], jax.Array]
    average_bits: float

    def __call__(self, w: jax.Array) -> jax.Array:
        return self.fake_quant(w)


def get_quantizer(name: str) -> QuantConfig:
    if name in MXINT_CONFIGS:
        spec = MXINT_CONFIGS[name]
        return QuantConfig(
            name=name,
            fake_quant=partial(mxint_fake_quant, bits=spec.bits, block_size=spec.block_size),
            average_bits=spec.average_bits,
        )
    if name.startswith("int") and "_g" in name:  # e.g. "int4_g64"
        bits_s, group_s = name[3:].split("_g")
        bits, group = int(bits_s), int(group_s)
        return QuantConfig(
            name=name,
            fake_quant=partial(int_fake_quant, bits=bits, group_size=group),
            # bits + fp16 scale + uint8 zero per group
            average_bits=bits + (16 + 8) / group,
        )
    if name == "nf4":
        return QuantConfig(
            name=name,
            fake_quant=partial(nf4_fake_quant, block_size=64),
            average_bits=4 + 16 / 64,
        )
    if name in ("none", "bf16"):
        return QuantConfig(name="none", fake_quant=lambda w: w, average_bits=16.0)
    raise KeyError(f"unknown quantizer {name!r}")


def average_bits(name: str) -> float:
    return get_quantizer(name).average_bits
