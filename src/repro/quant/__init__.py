from repro.quant.formats import (
    QuantConfig,
    get_quantizer,
    average_bits,
)
from repro.quant.mxint import (
    mxint_quantize,
    mxint_dequantize,
    mxint_fake_quant,
    pack_mxint,
    unpack_mxint,
    pack_mantissa,
    unpack_mantissa,
    container_bits,
    elems_per_byte,
    MXINT_CONFIGS,
)
from repro.quant.intq import int_fake_quant
from repro.quant.nf4 import nf4_fake_quant, NF4_LEVELS
