"""Group-wise affine INT quantization (HQQ-style storage format, minmax solver).

Used as the non-MX baseline format: ``bits``-bit asymmetric integers with a
float16 scale/zero-point per group of ``group_size`` weights along the input
dimension (HQQ in the paper uses INT4 g=64 -> 4.25 avg bits).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def int_quantize(w: jax.Array, bits: int, group_size: int):
    *lead, m, n = w.shape
    if m % group_size != 0:
        raise ValueError(f"input dim {m} not divisible by group_size {group_size}")
    wg = w.astype(jnp.float32).reshape(*lead, m // group_size, group_size, n)
    wmin = jnp.min(wg, axis=-2, keepdims=True)
    wmax = jnp.max(wg, axis=-2, keepdims=True)
    qmax = 2**bits - 1
    scale = (wmax - wmin) / qmax
    scale = jnp.where(scale > 0, scale, 1.0)
    zero = jnp.round(-wmin / scale)
    q = jnp.clip(jnp.round(wg / scale + zero), 0, qmax).astype(jnp.uint8)
    return q, scale.squeeze(-2), zero.squeeze(-2)


def int_dequantize(q, scale, zero, out_shape, dtype=jnp.float32):
    w = (q.astype(jnp.float32) - zero[..., :, None, :]) * scale[..., :, None, :]
    return w.reshape(out_shape).astype(dtype)


def int_fake_quant(w: jax.Array, bits: int, group_size: int) -> jax.Array:
    q, scale, zero = int_quantize(w, bits, group_size)
    return int_dequantize(q, scale, zero, w.shape, dtype=w.dtype)
