"""NF4 (NormalFloat-4) emulation — the QLoRA format.

16 levels placed at the quantiles of N(0,1), absmax-scaled per block.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

# The 16 NF4 levels from the QLoRA paper (bitsandbytes reference values).
NF4_LEVELS = np.array(
    [
        -1.0, -0.6961928009986877, -0.5250730514526367, -0.39491748809814453,
        -0.28444138169288635, -0.18477343022823334, -0.09105003625154495, 0.0,
        0.07958029955625534, 0.16093020141124725, 0.24611230194568634,
        0.33791524171829224, 0.44070982933044434, 0.5626170039176941,
        0.7229568362236023, 1.0,
    ],
    dtype=np.float32,
)


def nf4_fake_quant(w: jax.Array, block_size: int = 64) -> jax.Array:
    *lead, m, n = w.shape
    if m % block_size != 0:
        raise ValueError(f"input dim {m} not divisible by block_size {block_size}")
    wb = w.astype(jnp.float32).reshape(*lead, m // block_size, block_size, n)
    absmax = jnp.max(jnp.abs(wb), axis=-2, keepdims=True)
    absmax = jnp.where(absmax > 0, absmax, 1.0)
    x = wb / absmax  # in [-1, 1]
    levels = jnp.asarray(NF4_LEVELS)
    idx = jnp.argmin(jnp.abs(x[..., None] - levels), axis=-1)
    deq = levels[idx] * absmax
    return deq.reshape(*lead, m, n).astype(w.dtype)
