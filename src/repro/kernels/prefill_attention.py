"""Pallas TPU kernel: paged prefill attention (Sq = chunk, per-slot offset).

The chunked-admission counterpart of ``decode_attention.py``: Q is a chunk of
C prompt tokens per slot at absolute offset ``q_off`` (the tokens already
prefilled), K/V are read through the slot's **page table** — the chunk's own
keys included, because the caller scatters them into the slot's pages before
the launch (models/attention.py ``paged_attention_prefill``).  That is what
lets admission write straight into the page pool: no dense batch=1 scratch
cache exists for the prefix to be copied out of afterwards.

Masking is causal *with offset*: query row i (absolute position
``q_off + i``) sees every already-written prefix token and the chunk tokens
at positions ≤ its own — ``kv_id <= q_off + i`` — plus the usual
``kv_id < kv_len`` length mask for page tails (and padded query rows, which
the ops wrapper crops).

Layout (see serve/paging.py for the pool):

  q           (B, H, C, D)         C-token chunk per slot, GQA grouped
  k/v pages   (P, Hkv, ps, D)      shared pool, page 0 reserved as garbage
  page_table  (B, npages) int32    slot's logical page j -> physical page
  q_off       (B,) int32           absolute position of q[:, :, 0]
  kv_len      (B,) int32           live tokens incl. this chunk (masks tails)

grid = (B, Hkv, npages), page axis innermost; page table / q_off / kv_len
ride in as **scalar prefetch** (``PrefetchScalarGridSpec``) so the K/V
BlockSpec index_map gathers ``pt[b, p]`` — the kernel never touches pages
the slot does not own, and attention reads scale with the table width the
scheduler ships (the live-prefix bucket), never with max_len.  All
G = H/Hkv query heads are flattened into the chunk's row axis, so each page
costs one (G*C, ps) MXU dot.

Online-softmax state (m, l, acc) lives in VMEM scratch across the page
sweep.  Logical page 0 always holds live tokens for every real query row
(kv ids from 0 are visible under the offset-causal mask), so the running
max is real before any fully-masked page contributes exp(s - m) ~= 0.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(pt_ref, off_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
            m_ref, l_ref, acc_ref, *, sm_scale: float, page_size: int,
            chunk: int):
    b = pl.program_id(0)
    p = pl.program_id(2)

    @pl.when(p == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)            # (G*C, d)
    k = k_ref[0, 0].astype(jnp.float32)            # (ps, d)
    v = v_ref[0, 0].astype(jnp.float32)

    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * sm_scale
    kv_ids = p * page_size + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    # rows are g-major: row = g*C + i, so the in-chunk position is row % C
    rows = jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
    q_pos = off_ref[b] + rows % chunk
    mask = (kv_ids <= q_pos) & (kv_ids < len_ref[b])
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                            # (G*C, 1)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    pexp = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(pexp, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
        pexp, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(p == pl.num_programs(2) - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / denom).astype(o_ref.dtype)


def prefill_attention_pallas(
    q: jax.Array,           # (B, H, C, D) — C-token chunk per slot
    k_pages: jax.Array,     # (P, Hkv, page_size, D)
    v_pages: jax.Array,     # (P, Hkv, page_size, D)
    page_table: jax.Array,  # (B, npages) int32
    q_off: jax.Array,       # (B,) int32 — absolute position of q[:, :, 0]
    kv_len: jax.Array,      # (B,) int32 — live tokens incl. this chunk
    *,
    sm_scale: float | None = None,
    interpret: bool = False,
) -> jax.Array:
    bsz, h, c, d = q.shape
    _, hkv, page_size, _ = k_pages.shape
    g = h // hkv
    npages = page_table.shape[1]
    if sm_scale is None:
        sm_scale = 1.0 / (d ** 0.5)
    # flatten the GQA group into the chunk's row axis: (B, Hkv, G*C, d)
    qg = q.reshape(bsz, hkv, g, c, d).reshape(bsz, hkv, g * c, d)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,            # page_table, q_off, kv_len
        grid=(bsz, hkv, npages),
        in_specs=[
            pl.BlockSpec((1, 1, g * c, d),
                         lambda b, h_, p, pt, off, ln: (b, h_, 0, 0)),
            pl.BlockSpec((1, 1, page_size, d),
                         lambda b, h_, p, pt, off, ln: (pt[b, p], h_, 0, 0)),
            pl.BlockSpec((1, 1, page_size, d),
                         lambda b, h_, p, pt, off, ln: (pt[b, p], h_, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g * c, d),
                               lambda b, h_, p, pt, off, ln: (b, h_, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g * c, 1), jnp.float32),
            pltpu.VMEM((g * c, 1), jnp.float32),
            pltpu.VMEM((g * c, d), jnp.float32),
        ],
    )
    kernel = functools.partial(_kernel, sm_scale=sm_scale,
                               page_size=page_size, chunk=c)
    # contract: prefill_attention
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(qg.shape, q.dtype),
        interpret=interpret,
    )(page_table, q_off.astype(jnp.int32), kv_len.astype(jnp.int32),
      qg, k_pages, v_pages)
    return out.reshape(bsz, hkv, g, c, d).reshape(bsz, h, c, d)
