"""Pallas TPU kernel: fused MXINT dequant-matmul with in-kernel low-rank path.

Computes  y = x @ dq(Wq) + (x @ A) @ B  in ONE kernel launch: Wq is stored
packed in HBM as int8 mantissas (K, N) plus int8 shared exponents (K/bs, N);
A is the (K, r) low-rank factor (r ≤ 64), B the (r, N) one.

This is the serving hot loop of QERA-style PTQ: weight bytes moved from HBM
drop ~4x at 4-bit vs bf16 (memory-roofline win), dequantization happens in
VMEM right before the MXU dot, and — unlike the two-launch design where
t = x @ A was a standalone f32 GEMM with its own HBM round-trip — the
low-rank *prologue* is folded into the K-loop: during the FIRST N-block's
K-sweep each K-step accumulates t_acc += x_tile @ A_tile into a tiny (bm, r)
VMEM scratch; the scratch persists across grid steps, so every later N-block
of the same M-block reuses the finished t (no recompute — the prologue costs
one M*K*r pass per launch, exactly the old standalone GEMM's FLOPs), and the
final K-step applies t_acc @ B in the epilogue so y is written exactly once.

Two grid layouts share one kernel body:

* prefill (``mxint_matmul_lowrank_pallas``): grid = (M/bm, N/bn, K/bk),
  K innermost; MXU-aligned defaults bm = bn = bk = 128.
* decode  (``mxint_matmul_lowrank_decode_pallas``): M is tiny (the slot
  count), so the whole (padded) M lives in a single block and the grid is
  N-major 2D (N/bn, K/bk) — decode stops padding to prefill-sized M tiles
  and weight tiles stream exactly once.

bk must be a multiple of the MXINT block size so each exponent tile covers
whole blocks.  Accumulation is in f32 VMEM scratch ((bm, bn) main + (bm, r)
low-rank).

Sub-byte packed storage (``packed=True``): the mantissa HBM buffer is the
``quant.mxint.pack_mantissa`` layout — (K // epb, N) int8 with epb = 2 at
4-/3-bit (4-bit container, low nibble = even K row) and epb = 4 at 2-bit —
so the mantissa BlockSpec shrinks to (bk // epb, bn) and only packed bytes
cross HBM.  The kernel body widens each byte to int32, replicates it epb-fold
along sublanes, and recovers field ``k0 % epb`` for element row ``k0`` with a
per-row variable shift + container-width sign-extension, all in VMEM right
before the dequant-dot.  Mantissa *values* are identical to the flat int8
path, so outputs are bit-identical — only the storage changes.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.quant.mxint import container_bits, elems_per_byte


def _unpack_tile(packed: jax.Array, epb: int) -> jax.Array:
    """(bk // epb, bn) int8 packed bytes -> (bk, bn) int32 mantissas.

    Row-replicate + variable shift (no gather): element row k0 reads byte row
    k0 // epb, field k0 % epb; sign-extend from the container width w = 8/epb
    via the ``(v ^ h) - h`` two's-complement trick.
    """
    w = 8 // epb
    p32 = jnp.repeat(packed.astype(jnp.int32), epb, axis=0)   # (bk, bn)
    bk, bn = p32.shape
    field = jax.lax.broadcasted_iota(jnp.int32, (bk, bn), 0) % epb
    v = (p32 >> (field * w)) & ((1 << w) - 1)
    half = 1 << (w - 1)
    return (v ^ half) - half


def _unpack_tile_plane(packed: jax.Array, epb: int,
                       draft_bits: int) -> jax.Array:
    """(bk // epb, bn) packed bytes -> (bk, bn) int32 DRAFT mantissas: the
    top ``draft_bits`` of each container field, sign-extended.

    Same replicate + variable-shift scheme as ``_unpack_tile`` but the field
    mask keeps only the high plane: equals the full unpack followed by an
    arithmetic shift right by (w - draft_bits), without ever materializing
    the low bits.
    """
    w = 8 // epb
    s = w - draft_bits
    p32 = jnp.repeat(packed.astype(jnp.int32), epb, axis=0)   # (bk, bn)
    bk, bn = p32.shape
    field = jax.lax.broadcasted_iota(jnp.int32, (bk, bn), 0) % epb
    v = (p32 >> (field * w + s)) & ((1 << draft_bits) - 1)
    half = 1 << (draft_bits - 1)
    return (v ^ half) - half


def _draft_kernel(x_ref, mant_ref, exp_ref, o_ref, acc_ref, *, bits: int,
                  draft_bits: int, block_size: int, epb: int, out_dtype,
                  k_axis: int):
    """Draft-plane matmul body: y = x @ dq_draft(Wq) — no low-rank refs, no
    t scratch.  The dequant reads the top ``draft_bits`` of each mantissa
    container (shift s = container - draft_bits) and compensates the scale
    by 2^s, so the draft weight is a coarser rounding of the SAME packed
    bytes."""
    k_step = pl.program_id(k_axis)
    shift = container_bits(bits) - draft_bits

    @pl.when(k_step == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    mant = mant_ref[...]                          # (bk // epb, bn) int8
    if epb > 1:
        mant = _unpack_tile_plane(mant, epb, draft_bits)   # (bk, bn) int32
    else:
        mant = mant.astype(jnp.int32) >> shift
    exp = exp_ref[...]                            # (bk//bs, bn) int8
    scale = jnp.exp2(exp.astype(jnp.float32) - (bits - 2 - shift))
    bk, bn = mant.shape
    nblk = bk // block_size
    scale_full = jnp.broadcast_to(
        scale[:, None, :], (nblk, block_size, bn)).reshape(bk, bn)
    w = mant.astype(jnp.float32) * scale_full
    x = x_ref[...].astype(jnp.float32)
    acc_ref[...] += jnp.dot(x, w, preferred_element_type=jnp.float32)

    @pl.when(k_step == pl.num_programs(k_axis) - 1)
    def _epilogue():
        o_ref[...] = acc_ref[...].astype(out_dtype)


def _kernel(x_ref, mant_ref, exp_ref, a_ref, b_ref, o_ref, acc_ref, t_ref, *,
            bits: int, block_size: int, epb: int, out_dtype, n_axis: int,
            k_axis: int):
    k_step = pl.program_id(k_axis)
    n_step = pl.program_id(n_axis)

    @pl.when(k_step == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when((k_step == 0) & (n_step == 0))
    def _init_t():
        t_ref[...] = jnp.zeros_like(t_ref)

    # In-VMEM dequant: scale[u, n] applies to mantissa rows u*bs:(u+1)*bs.
    mant = mant_ref[...]                          # (bk // epb, bn) int8
    if epb > 1:
        mant = _unpack_tile(mant, epb)            # (bk, bn) int32
    exp = exp_ref[...]                            # (bk//bs, bn) int8
    scale = jnp.exp2(exp.astype(jnp.float32) - (bits - 2))
    bk, bn = mant.shape
    nblk = bk // block_size
    scale_full = jnp.broadcast_to(
        scale[:, None, :], (nblk, block_size, bn)).reshape(bk, bn)
    w = mant.astype(jnp.float32) * scale_full
    x = x_ref[...].astype(jnp.float32)
    acc_ref[...] += jnp.dot(x, w, preferred_element_type=jnp.float32)

    # fused low-rank prologue: t = x @ A depends only on the M block, and the
    # grid sweeps K innermost with N before M, so accumulate t ONLY during
    # the first N-block's K-sweep; the scratch persists across grid steps and
    # every later N-block reuses the finished t from VMEM.  Total extra MXU
    # work is one M*K*r pass per launch — the cost of the old standalone
    # GEMM, minus its kernel launch and HBM round-trip for t.
    @pl.when(n_step == 0)
    def _acc_t():
        t_ref[...] += jnp.dot(x, a_ref[...].astype(jnp.float32),
                              preferred_element_type=jnp.float32)

    @pl.when(k_step == pl.num_programs(k_axis) - 1)
    def _epilogue():
        lowrank = jnp.dot(t_ref[...], b_ref[...].astype(jnp.float32),
                          preferred_element_type=jnp.float32)
        o_ref[...] = (acc_ref[...] + lowrank).astype(out_dtype)


def _check_shapes(x, mant, exp, a, b, block_size, block_n, block_k, epb):
    m, k = x.shape
    kn, n = mant.shape
    r = a.shape[1]
    assert kn * epb == k and exp.shape == (k // block_size, n), (
        f"quantized shapes {mant.shape}/{exp.shape} mismatch x {x.shape} "
        f"(elems_per_byte={epb})")
    assert a.shape == (k, r) and b.shape == (r, n), (
        f"low-rank factors {a.shape}/{b.shape} mismatch ({k=}, {n=})")
    assert n % block_n == 0 and k % block_k == 0, (
        f"shapes ({m},{k},{n}) must divide blocks ({block_k},{block_n}) "
        "— use kernels.ops wrapper for padding/heuristics")
    assert block_k % block_size == 0, "block_k must cover whole MXINT blocks"
    assert block_size % epb == 0, (
        f"MXINT block {block_size} must cover whole packed bytes (epb={epb})")
    return m, k, n, r


def mxint_matmul_lowrank_pallas(
    x: jax.Array,        # (M, K)
    mant: jax.Array,     # (K, N) int8, or (K // epb, N) when packed
    exp: jax.Array,      # (K // block_size, N) int8
    a: jax.Array,        # (K, r) low-rank down-projection (fused in-kernel)
    b: jax.Array,        # (r, N)
    *,
    bits: int,
    block_size: int,
    packed: bool = False,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 128,
    out_dtype=jnp.float32,
    interpret: bool = False,
) -> jax.Array:
    """Prefill-shaped launch: 3D grid, K innermost for accumulation."""
    epb = elems_per_byte(bits) if packed else 1
    m, k, n, r = _check_shapes(x, mant, exp, a, b, block_size, block_n,
                               block_k, epb)
    assert m % block_m == 0, (
        f"M={m} must divide block_m={block_m} — use kernels.ops wrapper")

    grid = (m // block_m, n // block_n, k // block_k)
    kernel = functools.partial(_kernel, bits=bits, block_size=block_size,
                               epb=epb, out_dtype=out_dtype, n_axis=1, k_axis=2)
    # contract: mxint_matmul_lowrank
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, s: (i, s)),
            pl.BlockSpec((block_k // epb, block_n), lambda i, j, s: (s, j)),
            pl.BlockSpec((block_k // block_size, block_n), lambda i, j, s: (s, j)),
            pl.BlockSpec((block_k, r), lambda i, j, s: (s, 0)),
            pl.BlockSpec((r, block_n), lambda i, j, s: (0, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, s: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32),
                        pltpu.VMEM((block_m, r), jnp.float32)],
        interpret=interpret,
    )(x, mant, exp, a, b)


def mxint_matmul_lowrank_decode_pallas(
    x: jax.Array,        # (M, K) — M tiny (decode slot count), whole-M block
    mant: jax.Array,     # (K, N) int8, or (K // epb, N) when packed
    exp: jax.Array,      # (K // block_size, N) int8
    a: jax.Array,        # (K, r)
    b: jax.Array,        # (r, N)
    *,
    bits: int,
    block_size: int,
    packed: bool = False,
    block_n: int = 128,
    block_k: int = 128,
    out_dtype=jnp.float32,
    interpret: bool = False,
) -> jax.Array:
    """Skinny-M decode launch: the whole (padded) M is one block, grid is
    N-major 2D (N/bn, K/bk) — no M tiling, weight tiles stream exactly once
    per token step."""
    epb = elems_per_byte(bits) if packed else 1
    m, k, n, r = _check_shapes(x, mant, exp, a, b, block_size, block_n,
                               block_k, epb)

    grid = (n // block_n, k // block_k)
    kernel = functools.partial(_kernel, bits=bits, block_size=block_size,
                               epb=epb, out_dtype=out_dtype, n_axis=0, k_axis=1)
    # contract: mxint_matmul_lowrank_decode
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((m, block_k), lambda j, s: (0, s)),
            pl.BlockSpec((block_k // epb, block_n), lambda j, s: (s, j)),
            pl.BlockSpec((block_k // block_size, block_n), lambda j, s: (s, j)),
            pl.BlockSpec((block_k, r), lambda j, s: (s, 0)),
            pl.BlockSpec((r, block_n), lambda j, s: (0, j)),
        ],
        out_specs=pl.BlockSpec((m, block_n), lambda j, s: (0, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((m, block_n), jnp.float32),
                        pltpu.VMEM((m, r), jnp.float32)],
        interpret=interpret,
    )(x, mant, exp, a, b)


def _check_shapes_draft(x, mant, exp, bits, draft_bits, block_size, block_n,
                        block_k, epb):
    m, k = x.shape
    kn, n = mant.shape
    assert kn * epb == k and exp.shape == (k // block_size, n), (
        f"quantized shapes {mant.shape}/{exp.shape} mismatch x {x.shape} "
        f"(elems_per_byte={epb})")
    assert 1 <= draft_bits <= container_bits(bits), (
        f"draft_bits={draft_bits} outside the {container_bits(bits)}-bit "
        f"container of the {bits}-bit format")
    assert n % block_n == 0 and k % block_k == 0, (
        f"shapes ({m},{k},{n}) must divide blocks ({block_k},{block_n}) "
        "— use kernels.ops wrapper for padding/heuristics")
    assert block_k % block_size == 0, "block_k must cover whole MXINT blocks"
    assert block_size % epb == 0, (
        f"MXINT block {block_size} must cover whole packed bytes (epb={epb})")
    return m, k, n


def mxint_matmul_draft_pallas(
    x: jax.Array,        # (M, K)
    mant: jax.Array,     # (K, N) int8, or (K // epb, N) when packed
    exp: jax.Array,      # (K // block_size, N) int8
    *,
    bits: int,
    draft_bits: int,
    block_size: int,
    packed: bool = False,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 128,
    out_dtype=jnp.float32,
    interpret: bool = False,
) -> jax.Array:
    """Prefill-shaped draft launch (the k-token verify chunk also uses this
    shape at M = batch * (k+1)): 3D grid, K innermost, no low-rank blocks."""
    epb = elems_per_byte(bits) if packed else 1
    m, k, n = _check_shapes_draft(x, mant, exp, bits, draft_bits, block_size,
                                  block_n, block_k, epb)
    assert m % block_m == 0, (
        f"M={m} must divide block_m={block_m} — use kernels.ops wrapper")

    grid = (m // block_m, n // block_n, k // block_k)
    kernel = functools.partial(_draft_kernel, bits=bits,
                               draft_bits=draft_bits, block_size=block_size,
                               epb=epb, out_dtype=out_dtype, k_axis=2)
    # contract: mxint_matmul_draft
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, s: (i, s)),
            pl.BlockSpec((block_k // epb, block_n), lambda i, j, s: (s, j)),
            pl.BlockSpec((block_k // block_size, block_n),
                         lambda i, j, s: (s, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, s: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        interpret=interpret,
    )(x, mant, exp)


def mxint_matmul_draft_decode_pallas(
    x: jax.Array,        # (M, K) — M tiny (decode slot count), whole-M block
    mant: jax.Array,     # (K, N) int8, or (K // epb, N) when packed
    exp: jax.Array,      # (K // block_size, N) int8
    *,
    bits: int,
    draft_bits: int,
    block_size: int,
    packed: bool = False,
    block_n: int = 128,
    block_k: int = 128,
    out_dtype=jnp.float32,
    interpret: bool = False,
) -> jax.Array:
    """Skinny-M draft decode launch: whole-M block, N-major 2D grid — the
    cheap forward of self-speculative decoding, streaming the SAME packed
    buffers as the full path but skipping the low-rank prologue/epilogue."""
    epb = elems_per_byte(bits) if packed else 1
    m, k, n = _check_shapes_draft(x, mant, exp, bits, draft_bits, block_size,
                                  block_n, block_k, epb)

    grid = (n // block_n, k // block_k)
    kernel = functools.partial(_draft_kernel, bits=bits,
                               draft_bits=draft_bits, block_size=block_size,
                               epb=epb, out_dtype=out_dtype, k_axis=1)
    # contract: mxint_matmul_draft_decode
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((m, block_k), lambda j, s: (0, s)),
            pl.BlockSpec((block_k // epb, block_n), lambda j, s: (s, j)),
            pl.BlockSpec((block_k // block_size, block_n),
                         lambda j, s: (s, j)),
        ],
        out_specs=pl.BlockSpec((m, block_n), lambda j, s: (0, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((m, block_n), jnp.float32)],
        interpret=interpret,
    )(x, mant, exp)
