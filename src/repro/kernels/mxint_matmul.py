"""Pallas TPU kernel: fused MXINT dequant-matmul with low-rank epilogue.

Computes  y = x @ dq(Wq) + t @ B   where t = x @ A is the small (M, r)
low-rank activation (r ≤ 64), Wq is stored packed in HBM as int8 mantissas
(K, N) plus int8 shared exponents (K/bs, N).

This is the serving hot loop of QERA-style PTQ: weight bytes moved from HBM
drop ~4x at 4-bit vs bf16 (memory-roofline win), dequantization happens in
VMEM right before the MXU dot, and the low-rank correction is fused into the
final K-step epilogue so y is written exactly once.

Tiling: grid = (M/bm, N/bn, K/bk), K innermost for accumulation in an
f32 VMEM scratch tile (bm, bn).  bk must be a multiple of the MXINT block
size so each exponent tile covers whole blocks.  MXU-aligned defaults:
bm = bn = bk = 128 (>= 8x128 VREG lanes, f32 accumulate).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, mant_ref, exp_ref, t_ref, b_ref, o_ref, acc_ref, *,
            bits: int, block_size: int, out_dtype):
    k_step = pl.program_id(2)

    @pl.when(k_step == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # In-VMEM dequant: scale[u, n] applies to mantissa rows u*bs:(u+1)*bs.
    mant = mant_ref[...]                          # (bk, bn) int8
    exp = exp_ref[...]                            # (bk//bs, bn) int8
    scale = jnp.exp2(exp.astype(jnp.float32) - (bits - 2))
    bk, bn = mant.shape
    nblk = bk // block_size
    scale_full = jnp.broadcast_to(
        scale[:, None, :], (nblk, block_size, bn)).reshape(bk, bn)
    w = mant.astype(jnp.float32) * scale_full
    acc_ref[...] += jnp.dot(x_ref[...].astype(jnp.float32), w,
                            preferred_element_type=jnp.float32)

    @pl.when(k_step == pl.num_programs(2) - 1)
    def _epilogue():
        lowrank = jnp.dot(t_ref[...].astype(jnp.float32),
                          b_ref[...].astype(jnp.float32),
                          preferred_element_type=jnp.float32)
        o_ref[...] = (acc_ref[...] + lowrank).astype(out_dtype)


def mxint_matmul_lowrank_pallas(
    x: jax.Array,        # (M, K)
    mant: jax.Array,     # (K, N) int8
    exp: jax.Array,      # (K // block_size, N) int8
    t: jax.Array,        # (M, r)  = x @ A, precomputed (r is tiny)
    b: jax.Array,        # (r, N)
    *,
    bits: int,
    block_size: int,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 128,
    out_dtype=jnp.float32,
    interpret: bool = False,
) -> jax.Array:
    m, k = x.shape
    kn, n = mant.shape
    r = t.shape[1]
    assert kn == k and exp.shape == (k // block_size, n) and b.shape == (r, n)
    assert m % block_m == 0 and n % block_n == 0 and k % block_k == 0, (
        f"shapes ({m},{k},{n}) must divide blocks ({block_m},{block_k},{block_n}) "
        "— use kernels.ops wrapper for padding")
    assert block_k % block_size == 0, "block_k must cover whole MXINT blocks"

    grid = (m // block_m, n // block_n, k // block_k)
    kernel = functools.partial(_kernel, bits=bits, block_size=block_size,
                               out_dtype=out_dtype)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, s: (i, s)),
            pl.BlockSpec((block_k, block_n), lambda i, j, s: (s, j)),
            pl.BlockSpec((block_k // block_size, block_n), lambda i, j, s: (s, j)),
            pl.BlockSpec((block_m, r), lambda i, j, s: (i, 0)),
            pl.BlockSpec((r, block_n), lambda i, j, s: (0, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, s: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        interpret=interpret,
    )(x, mant, exp, t, b)
