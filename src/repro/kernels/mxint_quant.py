"""Pallas TPU kernel: blockwise MXINT quantization.

One program quantizes a (block_size, block_n) tile: shared-exponent
reduction over the block dimension, overflow-aware exponent bump, mantissa
round/clip — all in VMEM.  Used to (re)pack weights on device, e.g. after an
optimizer step in QAT-style flows, without a round-trip through HBM floats.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(w_ref, mant_ref, exp_ref, *, bits: int):
    w = w_ref[...].astype(jnp.float32)            # (bs, bn)
    maxabs = jnp.max(jnp.abs(w), axis=0, keepdims=True)
    safe = jnp.where(maxabs > 0, maxabs, 1.0)
    e = jnp.floor(jnp.log2(safe)).astype(jnp.int32)
    e = jnp.clip(e, -126, 127)
    qmax = 2 ** (bits - 1) - 1
    scale = jnp.exp2(e.astype(jnp.float32) - (bits - 2))
    over = jnp.round(maxabs / scale) > qmax
    e = jnp.where(over, e + 1, e)
    scale = jnp.exp2(e.astype(jnp.float32) - (bits - 2))
    mant_ref[...] = jnp.clip(jnp.round(w / scale), -qmax, qmax).astype(jnp.int8)
    exp_ref[...] = e.astype(jnp.int8)


def mxint_quantize_pallas(w: jax.Array, *, bits: int, block_size: int,
                          block_n: int = 128, interpret: bool = False):
    """w: (K, N) -> (mant int8 (K, N), exp int8 (K//bs, N))."""
    k, n = w.shape
    assert k % block_size == 0 and n % block_n == 0, (
        f"shape ({k},{n}) must divide (block_size={block_size}, block_n={block_n})")
    grid = (k // block_size, n // block_n)
    kernel = functools.partial(_kernel, bits=bits)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block_size, block_n), lambda i, j: (i, j))],
        out_specs=[
            pl.BlockSpec((block_size, block_n), lambda i, j: (i, j)),
            pl.BlockSpec((1, block_n), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((k, n), jnp.int8),
            jax.ShapeDtypeStruct((k // block_size, n), jnp.int8),
        ],
        interpret=interpret,
    )(w)
