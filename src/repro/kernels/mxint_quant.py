"""Pallas TPU kernel: blockwise MXINT quantization.

One program quantizes a (block_size, block_n) tile: shared-exponent
reduction over the block dimension, overflow-aware exponent bump (re-clipped
to int8 range so a bump at e = 127 saturates instead of wrapping), mantissa
round/clip — all in VMEM.  Used to (re)pack weights on device, e.g. after an
optimizer step in QAT-style flows, without a round-trip through HBM floats.

``packed=True`` emits the sub-byte ``quant.mxint.pack_mantissa`` HBM layout
(two 4-bit fields per byte at 4-/3-bit, four 2-bit fields at 2-bit; low
field = even row) — the SAME layout the fused matmul kernels consume, so an
on-device repack feeds the serving GEMM without a host round-trip and
without a layout mismatch.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.quant.mxint import elems_per_byte, pack_fields


def _kernel(w_ref, mant_ref, exp_ref, *, bits: int, epb: int):
    w = w_ref[...].astype(jnp.float32)            # (bs, bn)
    maxabs = jnp.max(jnp.abs(w), axis=0, keepdims=True)
    safe = jnp.where(maxabs > 0, maxabs, 1.0)
    e = jnp.floor(jnp.log2(safe)).astype(jnp.int32)
    e = jnp.clip(e, -126, 127)
    qmax = 2 ** (bits - 1) - 1
    scale = jnp.exp2(e.astype(jnp.float32) - (bits - 2))
    over = jnp.round(maxabs / scale) > qmax
    # re-clip after the bump: e = 128 would wrap to -128 on the int8 cast
    e = jnp.clip(jnp.where(over, e + 1, e), -126, 127)
    scale = jnp.exp2(e.astype(jnp.float32) - (bits - 2))
    q = jnp.clip(jnp.round(w / scale), -qmax, qmax).astype(jnp.int8)
    # the ONE encoder of the packed byte layout lives in quant.mxint
    mant_ref[...] = pack_fields(q, epb)
    exp_ref[...] = e.astype(jnp.int8)


def mxint_quantize_pallas(w: jax.Array, *, bits: int, block_size: int,
                          block_n: int = 128, packed: bool = False,
                          interpret: bool = False):
    """w: (K, N) -> (mant int8 (K, N) — (K // epb, N) when packed —
    exp int8 (K//bs, N))."""
    k, n = w.shape
    assert k % block_size == 0 and n % block_n == 0, (
        f"shape ({k},{n}) must divide (block_size={block_size}, block_n={block_n})")
    epb = elems_per_byte(bits) if packed else 1
    assert block_size % epb == 0, (
        f"MXINT block {block_size} must cover whole packed bytes (epb={epb})")
    grid = (k // block_size, n // block_n)
    kernel = functools.partial(_kernel, bits=bits, epb=epb)
    # contract: mxint_quantize
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block_size, block_n), lambda i, j: (i, j))],
        out_specs=[
            pl.BlockSpec((block_size // epb, block_n), lambda i, j: (i, j)),
            pl.BlockSpec((1, block_n), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((k // epb, n), jnp.int8),
            jax.ShapeDtypeStruct((k // block_size, n), jnp.int8),
        ],
        interpret=interpret,
    )(w)
