"""Public jit'd wrappers for the Pallas kernels.

Handle non-aligned shapes by padding to block multiples (cropped on the way
out), pick interpret mode automatically off-TPU, and expose a uniform API the
model layer can call:

    quantized_matmul(x, packed, a, b)    # the QER serving GEMM
    quantize_weights(w, bits, block_size)
    flash_attention(q, k, v, causal=..., kv_len=...)
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.mxint_matmul import mxint_matmul_lowrank_pallas
from repro.kernels.mxint_quant import mxint_quantize_pallas
from repro.kernels.flash_attention import flash_attention_pallas
from repro.quant.mxint import PackedMXINT


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_to(x: jax.Array, axis: int, mult: int) -> jax.Array:
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@partial(jax.jit, static_argnames=("bits", "block_size", "block_m", "block_n",
                                   "block_k", "interpret"))
def quantized_matmul(x: jax.Array, mant: jax.Array, exp: jax.Array,
                     a: jax.Array, b: jax.Array, *, bits: int, block_size: int,
                     block_m: int = 128, block_n: int = 128, block_k: int = 128,
                     interpret: bool | None = None) -> jax.Array:
    """y = x @ dq(mant, exp) + (x @ a) @ b; x may have leading batch dims."""
    if interpret is None:
        interpret = not _on_tpu()
    lead = x.shape[:-1]
    k = x.shape[-1]
    n = mant.shape[1]
    x2 = x.reshape(-1, k)
    m = x2.shape[0]

    bm = min(block_m, max(8, m))
    bk = block_k
    if k % bk:                       # shrink to a divisor covering MX blocks
        bk = block_size
    bn = block_n if n % block_n == 0 else n

    t = x2.astype(jnp.float32) @ a.astype(jnp.float32)
    x2p = _pad_to(x2, 0, bm)
    tp = _pad_to(t, 0, bm)
    y = mxint_matmul_lowrank_pallas(
        x2p, mant, exp, tp, b, bits=bits, block_size=block_size,
        block_m=bm, block_n=bn, block_k=bk, interpret=interpret)
    return y[:m].reshape(*lead, n)


def quantized_matmul_packed(x: jax.Array, packed: PackedMXINT, a: jax.Array,
                            b: jax.Array, **kw) -> jax.Array:
    return quantized_matmul(x, packed.mant, packed.exp, a, b,
                            bits=packed.bits, block_size=packed.block_size, **kw)


@partial(jax.jit, static_argnames=("bits", "block_size", "interpret"))
def quantize_weights(w: jax.Array, *, bits: int, block_size: int,
                     interpret: bool | None = None):
    if interpret is None:
        interpret = not _on_tpu()
    k, n = w.shape
    bn = 128 if n % 128 == 0 else n
    return mxint_quantize_pallas(w, bits=bits, block_size=block_size,
                                 block_n=bn, interpret=interpret)


@partial(jax.jit, static_argnames=("causal", "sm_scale", "kv_len", "block_q",
                                   "block_kv", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, sm_scale: float | None = None,
                    kv_len: int | None = None, block_q: int = 128,
                    block_kv: int = 128, interpret: bool | None = None):
    if interpret is None:
        interpret = not _on_tpu()
    sq, skv = q.shape[2], k.shape[2]
    if kv_len is None:
        kv_len = skv
    bq = min(block_q, sq)
    bkv = min(block_kv, skv)
    qp = _pad_to(q, 2, bq)
    kp = _pad_to(k, 2, bkv)
    vp = _pad_to(v, 2, bkv)
    out = flash_attention_pallas(
        qp, kp, vp, causal=causal, sm_scale=sm_scale, kv_len=kv_len,
        block_q=bq, block_kv=bkv, interpret=interpret)
    return out[:, :, :sq, :]
