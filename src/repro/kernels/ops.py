"""Public jit'd wrappers for the Pallas kernels.

Handle non-aligned shapes by padding to block multiples (cropped on the way
out), pick interpret mode automatically off-TPU, choose block sizes from a
(M, K, N)-keyed heuristic, and expose a uniform API the model layer can call:

    quantized_matmul(x, packed, a, b)    # the QER serving GEMM (one launch)
    quantize_weights(w, bits, block_size)
    flash_attention(q, k, v, causal=..., kv_len=...)
    decode_attention(q, k_pages, v_pages, page_table, kv_len)
    prefill_attention(q, k_pages, v_pages, page_table, q_off, kv_len)

``pick_prefill_chunk`` / ``chunk_plan`` are the chunked-prefill sizing
heuristic: pow2 chunk widths + binary tail decomposition keep per-tick
admission work bounded while bounding jit retraces to O(log chunk).

``quantized_matmul`` issues exactly one Pallas launch: the low-rank
``t = x @ A`` prologue is fused into the kernel's K-loop (no standalone f32
GEMM, no HBM round-trip for t).  Decode-shaped calls (M = slot count) take
the skinny-M N-major-grid variant instead of padding M up to prefill tiles.
"""

from __future__ import annotations

import math
from functools import lru_cache, partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.kernels.decode_attention import decode_attention_pallas
from repro.kernels.prefill_attention import prefill_attention_pallas
from repro.kernels.mxint_matmul import (
    mxint_matmul_draft_decode_pallas,
    mxint_matmul_draft_pallas,
    mxint_matmul_lowrank_decode_pallas,
    mxint_matmul_lowrank_pallas,
)
from repro.kernels.mxint_quant import mxint_quantize_pallas
from repro.kernels.flash_attention import flash_attention_pallas
from repro.quant.mxint import PackedMXINT, elems_per_byte

# Decode = the whole (8-padded) M fits one skinny block.  Above this M the
# 3D prefill grid amortizes weight streaming across M tiles instead.
_DECODE_M_MAX = 32


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_to(x: jax.Array, axis: int, mult: int) -> jax.Array:
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def _largest_divisor(dim: int, cap: int, mult: int = 1) -> int:
    """Largest d ≤ cap with dim % d == 0 and d % mult == 0 (0 if none)."""
    for d in range(min(cap, dim), mult - 1, -1):
        if dim % d == 0 and d % mult == 0:
            return d
    return 0


def pick_blocks(m: int, k: int, n: int, *, block_size: int, epb: int = 1,
                block_m: int = 128, block_n: int = 128,
                block_k: int = 128) -> tuple[int, int, int, bool]:
    """Block-size heuristic keyed on (M, K, N) -> (bm, bn, bk, decode).

    Regimes (caps are the caller-supplied block_* values):

      M regime            bm                  grid
      ------------------  ------------------  ---------------------------
      decode (M ≤ 32*)    M padded up to 8    2D N-major, whole-M block
      prefill (M large)   min(block_m, M8)    3D (M, N, K), K innermost
                          (M8 = 8-padded M)

      (* and the padded M still fits under block_m)

    bk: largest divisor of K that is a multiple of the MXINT block size and
    ≤ block_k — NOT a collapse to block_size, which tanked tile efficiency
    whenever K wasn't a block_k multiple (e.g. K=192, bk=128 now picks 96,
    not 32).  With sub-byte packed mantissas (``epb`` > 1; epb = mantissas
    per stored byte, ``quant.mxint.elems_per_byte``) bk must also respect
    the packing granularity: the packed tile has bk / epb
    sublane rows, so bk prefers multiples of lcm(block_size, 8 * epb) to keep
    the packed mantissa tile 8-sublane-aligned (falling back to plain
    block_size multiples — always correct, whole bytes per tile — when K has
    no such divisor).  A K that cannot hold whole exponent blocks at all
    (K < block_size or K % block_size != 0 — e.g. an invalid TP row shard)
    raises a clear ValueError here instead of an XLA shape assert three
    layers down.  bn: block_n when it divides N, else the largest divisor of
    N ≤ block_n that keeps 8-lane alignment, else the largest divisor at
    all; a degenerate narrow result (< 8 lanes — shard-local N = N/tp with
    no usable divisor) is clamped to one whole-N block rather than a 1-wide
    tile grid.
    """
    bk = 0
    if epb > 1:
        gran = math.lcm(block_size, 8 * epb)
        bk = _largest_divisor(k, block_k, gran)
    if not bk:
        bk = _largest_divisor(k, block_k, block_size)
    if not bk:
        if k < block_size or k % block_size:
            raise ValueError(
                f"K={k} cannot be tiled by MXINT block_size={block_size}: "
                f"every K tile must hold whole exponent blocks, so K (and "
                f"any tensor-parallel shard K/tp) must be a multiple of "
                f"block_size")
        bk = block_size                # caller's block_k cap < block_size
    if n % block_n == 0:
        bn = block_n
    else:
        bn = (_largest_divisor(n, block_n, 8)
              or _largest_divisor(n, block_n))
        if bn < 8:
            bn = n                     # degenerate narrow tiles: one block
    m_pad = -(-m // 8) * 8
    decode = m_pad <= min(block_m, _DECODE_M_MAX)
    # prefill bm stays 8-sublane-aligned too (Mosaic rejects e.g. bm=33),
    # so round a non-aligned block_m cap DOWN to the 8-sublane grid
    cap8 = max(8, block_m - block_m % 8)
    bm = m_pad if decode else min(cap8, m_pad)
    return bm, bn, bk, decode


def _block_plan(m: int, k: int, n: int, *, bits: int, block_size: int,
                epb: int, block_m: int, block_n: int,
                block_k: int) -> tuple[int, int, int, bool]:
    """Tuned-or-heuristic block plan for one fused-matmul launch.

    When the caller left every cap at the default, consult the measured
    autotune cache (``kernels.autotune.lookup`` — a dict probe at TRACE
    time; shapes are static under jit) and take the tuned ``(bm, bn, bk,
    decode)`` on a hit.  Explicit caps and cache misses fall through to the
    ``pick_blocks`` heuristic, so behavior without a cache is unchanged.
    """
    if block_m == 128 and block_n == 128 and block_k == 128:
        from repro.kernels.autotune import lookup
        tuned = lookup(m, k, n, bits=bits, block_size=block_size, epb=epb)
        if tuned is not None:
            return tuned
    return pick_blocks(m, k, n, block_size=block_size, epb=epb,
                       block_m=block_m, block_n=block_n, block_k=block_k)


def pick_quant_bn(n: int, cap: int = 2048) -> int:
    """Lane-block width for the on-device repack (``quantize_weights``).

    128 when N is lane-aligned; otherwise the largest divisor of N up to
    ``cap``.  A vocab-sized N that is not a 128-multiple (llama4-maverick:
    202048) must never collapse into a single whole-row block — that is a
    tens-of-MiB VMEM launch (QERA001).
    """
    if n % 128 == 0:
        return 128
    if n <= cap:
        return n
    return _largest_divisor(n, cap, 8) or _largest_divisor(n, cap) or n


@partial(jax.jit, static_argnames=("bits", "block_size", "block_m", "block_n",
                                   "block_k", "interpret"))
def quantized_matmul(x: jax.Array, mant: jax.Array, exp: jax.Array,
                     a: jax.Array, b: jax.Array, *, bits: int, block_size: int,
                     block_m: int = 128, block_n: int = 128, block_k: int = 128,
                     interpret: bool | None = None) -> jax.Array:
    """y = x @ dq(mant, exp) + (x @ a) @ b; x may have leading batch dims.

    One fused Pallas launch: ``a`` goes into the kernel and t = x @ a is
    accumulated in VMEM scratch across K-steps (no separate GEMM).

    ``mant`` may be flat int8 (K, N) or the sub-byte packed (K // epb, N)
    layout from ``quant.mxint.pack_mantissa`` — detected from the shapes
    (static under jit); the packed form streams bits/8 bytes per element
    from HBM and unpacks in VMEM inside the kernel.
    """
    if interpret is None:
        interpret = not _on_tpu()
    lead = x.shape[:-1]
    k = x.shape[-1]
    n = mant.shape[1]
    x2 = x.reshape(-1, k)
    m = x2.shape[0]

    epb = elems_per_byte(bits)
    if mant.shape[0] == k:
        packed = False
    elif epb > 1 and mant.shape[0] * epb == k:
        packed = True
    else:
        raise ValueError(
            f"mantissa rows {mant.shape[0]} match neither flat K={k} nor "
            f"packed K/epb={k // epb} (bits={bits})")

    bm, bn, bk, decode = _block_plan(m, k, n, bits=bits,
                                     block_size=block_size,
                                     epb=epb if packed else 1,
                                     block_m=block_m, block_n=block_n,
                                     block_k=block_k)
    x2p = _pad_to(x2, 0, bm)
    common = dict(bits=bits, block_size=block_size, packed=packed,
                  block_n=bn, block_k=bk, interpret=interpret)
    if decode:
        y = mxint_matmul_lowrank_decode_pallas(x2p, mant, exp, a, b, **common)
    else:
        y = mxint_matmul_lowrank_pallas(x2p, mant, exp, a, b, block_m=bm,
                                        **common)
    return y[:m].reshape(*lead, n)


@partial(jax.jit, static_argnames=("bits", "block_size", "draft_bits",
                                   "block_m", "block_n", "block_k",
                                   "interpret"))
def quantized_matmul_draft(x: jax.Array, mant: jax.Array, exp: jax.Array, *,
                           bits: int, block_size: int, draft_bits: int = 2,
                           block_m: int = 128, block_n: int = 128,
                           block_k: int = 128,
                           interpret: bool | None = None) -> jax.Array:
    """y = x @ dq_draft(mant, exp): the self-speculative DRAFT forward.

    Reads the SAME packed (or flat) mantissa/exponent buffers as
    ``quantized_matmul`` but dequantizes only the top ``draft_bits`` of each
    mantissa container (scale compensated by 2^shift) and skips the low-rank
    prologue/epilogue entirely — a strictly cheaper launch over the same HBM
    bytes.  Block heuristics and decode/prefill routing match the full path.
    """
    if interpret is None:
        interpret = not _on_tpu()
    lead = x.shape[:-1]
    k = x.shape[-1]
    n = mant.shape[1]
    x2 = x.reshape(-1, k)
    m = x2.shape[0]

    epb = elems_per_byte(bits)
    if mant.shape[0] == k:
        packed = False
    elif epb > 1 and mant.shape[0] * epb == k:
        packed = True
    else:
        raise ValueError(
            f"mantissa rows {mant.shape[0]} match neither flat K={k} nor "
            f"packed K/epb={k // epb} (bits={bits})")

    bm, bn, bk, decode = _block_plan(m, k, n, bits=bits,
                                     block_size=block_size,
                                     epb=epb if packed else 1,
                                     block_m=block_m, block_n=block_n,
                                     block_k=block_k)
    x2p = _pad_to(x2, 0, bm)
    common = dict(bits=bits, draft_bits=draft_bits, block_size=block_size,
                  packed=packed, block_n=bn, block_k=bk, interpret=interpret)
    if decode:
        y = mxint_matmul_draft_decode_pallas(x2p, mant, exp, **common)
    else:
        y = mxint_matmul_draft_pallas(x2p, mant, exp, block_m=bm, **common)
    return y[:m].reshape(*lead, n)


def quantized_matmul_packed(x: jax.Array, packed: PackedMXINT, a: jax.Array,
                            b: jax.Array, **kw) -> jax.Array:
    return quantized_matmul(x, packed.mant, packed.exp, a, b,
                            bits=packed.bits, block_size=packed.block_size, **kw)


@lru_cache(maxsize=None)
def _sharded_qmm(mesh, axis: str, role: str, bits: int, block_size: int,
                 x_ndim: int):
    """Cached jit(shard_map(...)) for one (mesh, role, format, rank) combo.

    Each device runs its OWN Pallas launch on its local shard —
    ``pick_blocks`` sees the local (M, K/tp) or (M, N/tp) shapes because
    shard_map hands the kernel local array views, so no kernel-body change
    is needed.  Column-parallel shards N (y stays partitioned, no
    collective); row-parallel shards K, the per-device launch fuses the
    local x@A prologue and t@B epilogue (lora_b is replicated on
    row-parallel layers, so sum_d((x_d @ A_d) @ B) == (sum_d x_d @ A_d) @ B
    and the partial outputs ``psum`` ONCE after the launch — one all-reduce
    per layer, none inside the kernel).
    """
    from repro.sharding.serving import shard_map_compat

    lead = (None,) * (x_ndim - 1)

    def qmm(x, mant, exp, a, b):
        return quantized_matmul(x, mant, exp, a, b, bits=bits,
                                block_size=block_size)

    if role == "column":               # shard N: mant/exp/lora_b columns
        fn = qmm
        in_specs = (P(*lead, None), P(None, axis), P(None, axis), P(),
                    P(None, axis))
        out_specs = P(*lead, axis)
    elif role == "row":                # shard K: mant/exp rows, lora_a rows
        def fn(x, mant, exp, a, b):
            return jax.lax.psum(qmm(x, mant, exp, a, b), axis)

        in_specs = (P(*lead, axis), P(axis, None), P(axis, None),
                    P(axis, None), P())
        out_specs = P(*lead, None)
    else:
        raise ValueError(f"role must be 'column' or 'row', got {role!r}")
    return jax.jit(shard_map_compat(fn, mesh, in_specs, out_specs))


def quantized_matmul_sharded(x: jax.Array, mant: jax.Array, exp: jax.Array,
                             a: jax.Array, b: jax.Array, *, bits: int,
                             block_size: int, mesh, role: str,
                             axis: str = "model") -> jax.Array:
    """Tensor-parallel ``quantized_matmul``: one Pallas launch PER DEVICE.

    ``role`` follows the ``sharding/rules.py`` naming contract: "column" for
    in-projections (wide axis last — shard N; packed mantissa columns split
    cleanly, no byte or exponent block is ever divided), "row" for
    out-projections (wide axis first — shard K; each shard keeps whole
    packed bytes and exponent blocks, validated by
    ``quant.mxint.validate_packed_sharding``).  Row-parallel partial outputs
    are reduced with exactly one ``psum``; column-parallel needs none.
    Inputs may be unsharded — jit reshards them to the in_specs.
    """
    return _sharded_qmm(mesh, axis, role, bits, block_size, x.ndim)(
        x, mant, exp, a, b)


@partial(jax.jit, static_argnames=("bits", "block_size", "packed", "interpret"))
def quantize_weights(w: jax.Array, *, bits: int, block_size: int,
                     packed: bool = False, interpret: bool | None = None):
    """On-device (re)quantize; ``packed=True`` emits the sub-byte mantissa
    layout the fused matmul kernels consume (no host round-trip, no layout
    mismatch)."""
    if interpret is None:
        interpret = not _on_tpu()
    k, n = w.shape
    bn = pick_quant_bn(n)
    return mxint_quantize_pallas(w, bits=bits, block_size=block_size,
                                 block_n=bn, packed=packed,
                                 interpret=interpret)


@partial(jax.jit, static_argnames=("sm_scale", "interpret"))
def decode_attention(q: jax.Array, k_pages: jax.Array, v_pages: jax.Array,
                     page_table: jax.Array, kv_len: jax.Array, *,
                     sm_scale: float | None = None,
                     interpret: bool | None = None) -> jax.Array:
    """Paged decode attention (Sq = 1 per slot) — ONE Pallas launch.

    q: (B, H, D); k/v_pages: (P, Hkv, page_size, D); page_table: (B, npages)
    int32; kv_len: (B,) int32.  The page-axis grid width is the (static)
    page_table width, so the scheduler bounds attention reads by slicing the
    table to the live-prefix bucket — reads scale with the context actually
    in use, never with max_len.  Retraces once per bucket width.
    """
    if interpret is None:
        interpret = not _on_tpu()
    return decode_attention_pallas(q, k_pages, v_pages, page_table, kv_len,
                                   sm_scale=sm_scale, interpret=interpret)


@partial(jax.jit, static_argnames=("sm_scale", "interpret"))
def prefill_attention(q: jax.Array, k_pages: jax.Array, v_pages: jax.Array,
                      page_table: jax.Array, q_off: jax.Array,
                      kv_len: jax.Array, *, sm_scale: float | None = None,
                      interpret: bool | None = None) -> jax.Array:
    """Paged chunk-prefill attention (Sq = C per slot) — ONE Pallas launch.

    q: (B, H, C, D) a C-token prompt chunk per slot at absolute offset
    ``q_off`` (B,); k/v_pages: (P, Hkv, page_size, D) with the chunk's own
    K/V already scattered into the slot's pages; page_table: (B, npages)
    int32; kv_len: (B,) int32 live tokens including the chunk.  Masking is
    causal-with-offset: row i sees kv ids ≤ q_off + i, plus the kv_len tail
    mask.  The page-axis grid width is the (static) table width, so the
    scheduler bounds reads by slicing the table to the live-prefix bucket.
    Non-8-multiple chunk widths are padded: the padded rows DO attend (their
    q_pos runs past the real chunk under the offset-causal mask) and produce
    don't-care values that only the crop on the way out discards — callers
    must never rely on them being masked.  Retraces once per (chunk width,
    bucket width) pair.
    """
    if interpret is None:
        interpret = not _on_tpu()
    c = q.shape[2]
    c8 = -(-c // 8) * 8
    qp = _pad_to(q, 2, c8) if c8 != c else q
    out = prefill_attention_pallas(qp, k_pages, v_pages, page_table,
                                   q_off, kv_len, sm_scale=sm_scale,
                                   interpret=interpret)
    return out[:, :, :c]


def pick_prefill_chunk(prompt_len: int, *, page_size: int = 0,
                       max_chunk: int = 64) -> int:
    """Per-tick prefill chunk width for ``prompt_len`` prompt tokens.

    The smallest power of two covering the prompt, capped at ``max_chunk``
    (the scheduler's token budget per tick — what bounds inter-token latency
    for running slots during an admission).  Power-of-two widths plus the
    binary tail decomposition in ``chunk_plan`` bound jit retraces to
    O(log max_chunk) distinct chunk shapes.  With a paged cache the width is
    trimmed to a ``page_size`` multiple (when it is at least one page) so
    chunk boundaries land on page boundaries and each tick allocates whole
    pages.
    """
    c = 1
    while c < prompt_len and c < max_chunk:
        c *= 2
    c = min(c, max_chunk)
    if page_size and c > page_size and c % page_size:
        c -= c % page_size
    return max(c, 1)


def chunk_plan(n: int, chunk: int) -> list[int]:
    """Split ``n`` prompt tokens into per-tick chunk widths: full ``chunk``-
    sized pieces, then a binary decomposition of the remainder (largest
    piece first).  Every piece is exactly sized — no padded tail tokens, so
    recurrent-state families (mamba conv/ssm, rwkv state) never integrate
    garbage positions and chunked prefill stays token-exact — while the set
    of distinct widths stays O(log chunk)."""
    plan = [chunk] * (n // chunk)
    rem = n % chunk
    w = 1 << max(rem.bit_length() - 1, 0)
    while rem:
        if rem >= w:
            plan.append(w)
            rem -= w
        w //= 2
    return plan


@partial(jax.jit, static_argnames=("causal", "sm_scale", "kv_len", "block_q",
                                   "block_kv", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, sm_scale: float | None = None,
                    kv_len: int | None = None, block_q: int = 128,
                    block_kv: int = 128, interpret: bool | None = None):
    if interpret is None:
        interpret = not _on_tpu()
    sq, skv = q.shape[2], k.shape[2]
    if kv_len is None:
        kv_len = skv
    bq = min(block_q, sq)
    bkv = min(block_kv, skv)
    qp = _pad_to(q, 2, bq)
    kp = _pad_to(k, 2, bkv)
    vp = _pad_to(v, 2, bkv)
    out = flash_attention_pallas(
        qp, kp, vp, causal=causal, sm_scale=sm_scale, kv_len=kv_len,
        block_q=bq, block_kv=bkv, interpret=interpret)
    return out[:, :, :sq, :]
