"""Pallas TPU kernel: online-softmax (flash) attention with GQA.

Serving the QERA-quantized models still needs a fast attention prefill; this
kernel keeps the (Sq x Skv) score matrix out of HBM entirely.  Standard
running-max/denominator formulation:

  grid = (batch, heads, Sq/bq, Skv/bkv), kv innermost;
  scratch: m (bq,1), l (bq,1), acc (bq, d) in VMEM;
  K/V BlockSpecs index heads via h // group so GQA needs no host-side repeat.

Causal masking uses absolute tile offsets; fully-masked kv tiles above the
diagonal contribute exp(-inf)=0 (correct, if not skipped — the dry-run/roofline
path uses the jnp chunked implementation; this kernel is the TPU target).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            sm_scale: float, causal: bool, block_q: int, block_kv: int,
            kv_len: int):
    iq = pl.program_id(2)
    ikv = pl.program_id(3)

    @pl.when(ikv == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)            # (bq, d)
    k = k_ref[0, 0].astype(jnp.float32)            # (bkv, d)
    v = v_ref[0, 0].astype(jnp.float32)            # (bkv, d)

    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * sm_scale
    kv_ids = ikv * block_kv + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = kv_ids < kv_len
    if causal:
        q_ids = iq * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        mask = jnp.logical_and(mask, q_ids >= kv_ids)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                            # (bq, 1)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ikv == pl.num_programs(3) - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / denom).astype(o_ref.dtype)


def flash_attention_pallas(
    q: jax.Array,        # (B, H, Sq, D)
    k: jax.Array,        # (B, Hkv, Skv, D)
    v: jax.Array,        # (B, Hkv, Skv, D)
    *,
    causal: bool = True,
    sm_scale: float | None = None,
    kv_len: int | None = None,   # valid kv prefix (defaults to Skv)
    block_q: int = 128,
    block_kv: int = 128,
    interpret: bool = False,
) -> jax.Array:
    bsz, h, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    g = h // hkv
    if sm_scale is None:
        sm_scale = 1.0 / (d ** 0.5)
    if kv_len is None:
        kv_len = skv
    block_q = min(block_q, sq)
    block_kv = min(block_kv, skv)
    assert sq % block_q == 0 and skv % block_kv == 0, (
        f"seq ({sq},{skv}) must divide blocks ({block_q},{block_kv}) "
        "— use kernels.ops wrapper for padding")

    grid = (bsz, h, sq // block_q, skv // block_kv)
    kernel = functools.partial(
        _kernel, sm_scale=sm_scale, causal=causal, block_q=block_q,
        block_kv=block_kv, kv_len=kv_len)
    # contract: flash_attention
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d), lambda b, h_, i, j: (b, h_, i, 0)),
            pl.BlockSpec((1, 1, block_kv, d), lambda b, h_, i, j: (b, h_ // g, j, 0)),
            pl.BlockSpec((1, 1, block_kv, d), lambda b, h_, i, j: (b, h_ // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d), lambda b, h_, i, j: (b, h_, i, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
