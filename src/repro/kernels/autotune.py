"""Measured block-plan autotuner for the fused MXINT matmul kernels.

``pick_blocks`` is a heuristic: one divisor-and-alignment rule for every
``(M, K, N, format)``.  With heterogeneous :class:`~repro.core.allocate.
QuantPlan` serving trees a single rule is untenable — each layer now has
its own ``(bits, block_size, epb)`` packing geometry, and the best
``(bm, bn, bk)`` differs per layer.  This module measures instead of
guessing:

- ``autotune(...)`` times every legal candidate plan on the live backend
  via the same blocked-wall-clock harness ``benchmarks/kernel_bench`` uses
  and persists the winner under ``experiments/autotune/{backend}.json``;
- ``lookup(...)`` is the zero-cost hot-path read: the serving wrappers
  (``kernels.ops.quantized_matmul*``) consult it at TRACE time (shapes are
  static under jit) and fall back to ``pick_blocks`` on a miss, so
  behavior without a cache is bit-for-bit the heuristic's.

Measurement NEVER happens implicitly: serving only ever reads the cache.
Populate it offline (``python -m repro.kernels.autotune`` or the
kernel_bench/mixed_precision benches).  Because jit traces capture the
plan, load caches (``warm``) before the first forward pass of a process.

Determinism contract (checked by CI's autotune smoke): candidate
enumeration is a pure function of the key; a cache hit returns the stored
plan without re-measuring; and the JSON file is written with sorted keys,
so hit/miss behavior and file bytes are reproducible run-to-run (only the
measured ``us`` field depends on the machine).
"""

from __future__ import annotations

import json
import os
import time
from typing import Any, Iterable

import jax
import jax.numpy as jnp

from repro.kernels.ops import pick_blocks
from repro.quant.mxint import elems_per_byte, mxint_quantize, pack_mantissa

DEFAULT_CACHE_DIR = os.path.join("experiments", "autotune")
ENV_CACHE_DIR = "QERA_AUTOTUNE_DIR"

# candidate cap grids the enumerator sweeps (each triple is filtered
# through pick_blocks, so only legal, deduped plans are ever measured)
_CAP_M = (32, 64, 128, 256)
_CAP_N = (64, 128, 256)
_CAP_K = (64, 128, 256)

# in-memory cache: backend -> {key: entry}; _LOADED marks backends whose
# file has been read (including "file absent"), so the hot-path lookup is
# one dict probe after the first call.
_CACHE: dict[str, dict[str, dict[str, Any]]] = {}
_LOADED: set[tuple[str, str]] = set()


def cache_dir(root: str | None = None) -> str:
    return root or os.environ.get(ENV_CACHE_DIR, DEFAULT_CACHE_DIR)


def cache_path(backend: str, root: str | None = None) -> str:
    return os.path.join(cache_dir(root), f"{backend}.json")


def plan_key(m: int, k: int, n: int, *, bits: int, block_size: int,
             epb: int) -> str:
    return f"m{m}_k{k}_n{n}_b{bits}_bs{block_size}_e{epb}"


def current_backend() -> str:
    return "tpu" if jax.default_backend() == "tpu" else "interpret"


def candidate_plans(m: int, k: int, n: int, *, block_size: int,
                    epb: int = 1) -> list[tuple[int, int, int, bool]]:
    """Deterministic, deduplicated legal ``(bm, bn, bk, decode)`` plans:
    the cap-grid product filtered through ``pick_blocks`` (which owns
    legality — divisibility, packing granularity, sublane alignment)."""
    seen = []
    for cm in _CAP_M:
        for cn in _CAP_N:
            for ck in _CAP_K:
                try:
                    plan = pick_blocks(m, k, n, block_size=block_size,
                                       epb=epb, block_m=cm, block_n=cn,
                                       block_k=ck)
                except ValueError:
                    continue
                if plan not in seen:
                    seen.append(plan)
    return seen


def _load(backend: str, root: str | None = None) -> dict[str, dict[str, Any]]:
    key = (backend, cache_dir(root))
    store = _CACHE.setdefault(backend, {})
    if key in _LOADED:
        return store
    path = cache_path(backend, root)
    if os.path.exists(path):
        with open(path, encoding="utf-8") as f:
            store.update(json.load(f))
    _LOADED.add(key)
    return store


def _save(backend: str, root: str | None = None) -> str:
    path = cache_path(backend, root)
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w", encoding="utf-8") as f:
        json.dump(_CACHE.get(backend, {}), f, indent=1, sort_keys=True)
    return path


def reset(backend: str | None = None) -> None:
    """Drop the in-memory cache (tests / cache-dir switches). Does not
    touch files, but DOES clear the jit traces that captured old plans."""
    if backend is None:
        _CACHE.clear()
        _LOADED.clear()
    else:
        _CACHE.pop(backend, None)
        for k in [k for k in _LOADED if k[0] == backend]:
            _LOADED.discard(k)
    jax.clear_caches()


def lookup(m: int, k: int, n: int, *, bits: int, block_size: int,
           epb: int = 1, backend: str | None = None,
           root: str | None = None) -> tuple[int, int, int, bool] | None:
    """Hot-path cache probe: the tuned ``(bm, bn, bk, decode)`` for this
    launch geometry, or None (caller falls back to ``pick_blocks``)."""
    backend = backend or current_backend()
    e = _load(backend, root).get(
        plan_key(m, k, n, bits=bits, block_size=block_size, epb=epb))
    if e is None:
        return None
    return int(e["bm"]), int(e["bn"]), int(e["bk"]), bool(e["decode"])


def _timed_us(fn, reps: int = 3) -> float:
    jax.block_until_ready(fn())
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn())
    return (time.perf_counter() - t0) / reps * 1e6


def autotune(m: int, k: int, n: int, *, bits: int, block_size: int,
             rank: int = 32, packed: bool = True, reps: int = 3,
             backend: str | None = None, root: str | None = None,
             force: bool = False) -> tuple[dict[str, Any], bool]:
    """Measure-and-cache the best block plan for one launch geometry.

    Returns ``(entry, hit)``: ``entry`` is the cached record ``{"bm",
    "bn", "bk", "decode", "us", "candidates"}``; ``hit`` is True when the
    plan came from the cache without re-measuring (the determinism the CI
    smoke asserts).  ``force=True`` re-measures and overwrites.
    """
    from repro.kernels.ops import quantized_matmul

    backend = backend or current_backend()
    epb = elems_per_byte(bits) if packed else 1
    key = plan_key(m, k, n, bits=bits, block_size=block_size, epb=epb)
    store = _load(backend, root)
    if key in store and not force:
        return store[key], True

    cands = candidate_plans(m, k, n, block_size=block_size, epb=epb)
    if not cands:
        raise ValueError(f"no legal block plan for {key}")

    keys = jax.random.split(jax.random.PRNGKey(0), 4)
    x = jax.random.normal(keys[0], (m, k), jnp.float32)
    w = jax.random.normal(keys[1], (k, n), jnp.float32) * 0.1
    a = jax.random.normal(keys[2], (k, rank), jnp.float32) * 0.05
    b = jax.random.normal(keys[3], (rank, n), jnp.float32) * 0.05
    mant, exp = mxint_quantize(w, bits, block_size)
    mant = mant.reshape(k, n)
    if packed:
        mant = pack_mantissa(mant, bits)

    interpret = backend != "tpu"
    best = None
    for bm, bn, bk, decode in cands:
        # feed the caps straight through so pick_blocks reproduces exactly
        # this candidate inside the wrapper
        us = _timed_us(
            lambda bm=bm, bn=bn, bk=bk: quantized_matmul(
                x, mant, exp, a, b, bits=bits, block_size=block_size,
                block_m=bm, block_n=bn, block_k=bk, interpret=interpret),
            reps=reps)
        if best is None or us < best["us"]:
            best = {"bm": bm, "bn": bn, "bk": bk, "decode": decode,
                    "us": round(us, 2)}
    best["candidates"] = len(cands)
    store[key] = best
    _save(backend, root)
    return best, False


def autotune_shapes(shapes: Iterable[tuple[int, int, int, int, int]], *,
                    rank: int = 32, backend: str | None = None,
                    root: str | None = None, reps: int = 3,
                    verbose: bool = False) -> dict[str, Any]:
    """Tune a batch of ``(m, k, n, bits, block_size)`` geometries; returns
    ``key -> entry`` for the batch (hits included)."""
    out = {}
    for m, k, n, bits, bs in shapes:
        entry, hit = autotune(m, k, n, bits=bits, block_size=bs, rank=rank,
                              backend=backend, root=root, reps=reps)
        out[plan_key(m, k, n, bits=bits, block_size=bs,
                     epb=elems_per_byte(bits))] = entry
        if verbose:
            tag = "hit " if hit else "tuned"
            print(f"[{tag}] m={m} k={k} n={n} bits={bits} bs={bs} -> "
                  f"bm={entry['bm']} bn={entry['bn']} bk={entry['bk']} "
                  f"({entry['us']}us)")
    return out


def plan_shapes_for_params(packed_params, m: int = 8
                           ) -> list[tuple[int, int, int, int, int]]:
    """The decode-shaped launch geometries of a packed serving tree — what
    a server would tune before going live.  ``m`` is the slot count."""
    from repro.utils.trees import flatten_dict

    flat = flatten_dict(dict(packed_params))
    shapes = []
    for path, leaf in flat.items():
        if not path.endswith("/mant"):
            continue
        parent = path.rsplit("/", 1)[0]
        bits = int(jax.device_get(flat[f"{parent}/bits"]).reshape(-1)[0])
        bs = int(jax.device_get(flat[f"{parent}/block_size"]).reshape(-1)[0])
        epb = elems_per_byte(bits)
        rows, n = int(leaf.shape[-2]), int(leaf.shape[-1])
        k = rows * epb
        entry = (m, k, n, bits, bs)
        if entry not in shapes:
            shapes.append(entry)
    return shapes


def main(argv=None) -> int:
    import argparse

    ap = argparse.ArgumentParser(
        description="measure-and-cache MXINT matmul block plans")
    ap.add_argument("--m", type=int, default=8)
    ap.add_argument("--k", type=int, default=256)
    ap.add_argument("--n", type=int, default=256)
    ap.add_argument("--bits", type=int, default=4)
    ap.add_argument("--block-size", type=int, default=32)
    ap.add_argument("--rank", type=int, default=32)
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--cache-dir", default=None)
    args = ap.parse_args(argv)
    entry, hit = autotune(args.m, args.k, args.n, bits=args.bits,
                          block_size=args.block_size, rank=args.rank,
                          reps=args.reps, root=args.cache_dir)
    print(json.dumps({"hit": hit, **entry}, indent=1))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
