"""Pallas TPU kernel: paged decode attention (Sq = 1, per-slot kv_len).

The last unfused launch in the decode step: cached decode previously routed
attention through the jnp SDPA path, which reads the full dense (B, max_len)
cache every token.  This kernel reads K/V through a **page table** instead —
the grid's page axis covers only the pages the scheduler passes in (the live
prefix, bucketed), so attention bytes scale with the actual context length,
not max_len.

Layout (see serve/paging.py for the pool):

  q           (B, H, D)            one query token per slot, GQA grouped
  k/v pages   (P, Hkv, ps, D)      shared pool, page 0 reserved as garbage
  page_table  (B, npages) int32    slot's logical page j -> physical page
  kv_len      (B,) int32           live tokens per slot (masks page tails)

grid = (B, Hkv / hb, npages) with the page axis innermost; the page table
and kv_len ride in as **scalar prefetch** (``PrefetchScalarGridSpec``) so
the K/V BlockSpec index_map can gather ``pt[b, p]`` before the body runs —
the kernel never touches pages the slot does not own.

**KV-head blocking** (``pick_kv_block``): when the GQA group G = H/Hkv is
not sublane-aligned (G ∉ 8ℤ — command-r-plus G=12, phi3.5-moe G=4,
llama4-maverick G=5), a single-group q tile wastes most of its 8-sublane
rows.  The per-layer block plan instead batches ``hb`` consecutive kv heads
per grid step — the smallest divisor of Hkv with ``hb·G % 8 == 0`` — so the
q/out/acc tiles hold ``hb·G`` real rows and fill whole sublane tiles
(G=12 → hb=2 → 24 rows; G=4 → hb=2 → 8; G=5 → hb=8 → 40).  Scores for the
``hb``-head block come from ONE MXU dot against the page's ``hb`` heads
flattened to (hb·ps, d); a head-match mask (row's kv head == column's kv
head) kills the cross-head terms.  Numerics are unchanged: masked columns
underflow to exact 0.0 in the exp, and each head's live columns stay a
ps-aligned contiguous run, so the per-row reductions see the same values
in the same tree order as the single-head launch.  When no divisor aligns
(or G already does), ``hb = 1`` and any remaining pad rows are explicit
zero-q rows cropped on the way out.

Online-softmax state (m, l, acc) lives in VMEM scratch across the page
sweep, exactly like the prefill flash kernel.  Tokens at ``ids >= kv_len``
(page tails, unallocated logical pages mapped to garbage page 0) are masked
to NEG_INF; page 0 of the sweep always holds live tokens (kv_len >= 1), so
the running max is real before any fully-masked page contributes exp(s - m)
~= 0.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def pick_kv_block(hkv: int, g: int, min_sub: int = 8) -> int:
    """KV heads per decode-attention grid step: the smallest divisor ``hb``
    of ``hkv`` that makes the q-tile row count ``hb * g`` sublane-aligned
    (1 when ``g`` already is, or when no divisor aligns — the launch then
    pads rows explicitly).  Mirrored by ``analysis.contracts.
    audit_decode_attention``; keep this the single source of truth."""
    if g % min_sub == 0:
        return 1
    for hb in range(1, hkv + 1):
        if hkv % hb == 0 and (hb * g) % min_sub == 0:
            return hb
    return 1


def _kernel(pt_ref, len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref,
            acc_ref, *, sm_scale: float, page_size: int, g: int, hb: int):
    b = pl.program_id(0)
    p = pl.program_id(2)

    @pl.when(p == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)            # (rows_pad, d)
    d = q.shape[-1]
    k = k_ref[0].astype(jnp.float32).reshape(hb * page_size, d)
    v = v_ref[0].astype(jnp.float32).reshape(hb * page_size, d)

    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * sm_scale
    col = jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    row = jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
    ids = p * page_size + col % page_size          # token id of the column
    # row's kv head (pad rows clamp to the last real head — they are
    # cropped, any value is fine) must match the column's kv head
    same_head = jnp.minimum(row // g, hb - 1) == col // page_size
    live = (ids < len_ref[b]) & same_head          # causal == length mask
    s = jnp.where(live, s, NEG_INF)

    m_prev = m_ref[...]                            # (rows_pad, 1)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    pexp = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(pexp, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
        pexp, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(p == pl.num_programs(2) - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / denom).astype(o_ref.dtype)


def decode_attention_pallas(
    q: jax.Array,           # (B, H, D) — one token per slot
    k_pages: jax.Array,     # (P, Hkv, page_size, D)
    v_pages: jax.Array,     # (P, Hkv, page_size, D)
    page_table: jax.Array,  # (B, npages) int32
    kv_len: jax.Array,      # (B,) int32
    *,
    sm_scale: float | None = None,
    interpret: bool = False,
) -> jax.Array:
    bsz, h, d = q.shape
    _, hkv, page_size, _ = k_pages.shape
    g = h // hkv
    npages = page_table.shape[1]
    if sm_scale is None:
        sm_scale = 1.0 / (d ** 0.5)

    hb = pick_kv_block(hkv, g)
    nhb = hkv // hb
    rows = hb * g
    rows_pad = -(-rows // 8) * 8
    qg = q.reshape(bsz, nhb, rows, d)
    if rows_pad != rows:
        qg = jnp.pad(qg, ((0, 0), (0, 0), (0, rows_pad - rows), (0, 0)))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,            # page_table, kv_len
        grid=(bsz, nhb, npages),
        in_specs=[
            pl.BlockSpec((1, 1, rows_pad, d),
                         lambda b, h_, p, pt, ln: (b, h_, 0, 0)),
            pl.BlockSpec((1, hb, page_size, d),
                         lambda b, h_, p, pt, ln: (pt[b, p], h_, 0, 0)),
            pl.BlockSpec((1, hb, page_size, d),
                         lambda b, h_, p, pt, ln: (pt[b, p], h_, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, rows_pad, d),
                               lambda b, h_, p, pt, ln: (b, h_, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((rows_pad, 1), jnp.float32),
            pltpu.VMEM((rows_pad, 1), jnp.float32),
            pltpu.VMEM((rows_pad, d), jnp.float32),
        ],
    )
    kernel = functools.partial(_kernel, sm_scale=sm_scale,
                               page_size=page_size, g=g, hb=hb)
    # contract: decode_attention
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((bsz, nhb, rows_pad, d), q.dtype),
        interpret=interpret,
    )(page_table, kv_len.astype(jnp.int32), qg, k_pages, v_pages)
    return out[:, :, :rows, :].reshape(bsz, h, d)
