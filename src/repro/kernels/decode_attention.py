"""Pallas TPU kernel: paged decode attention (Sq = 1, per-slot kv_len).

The last unfused launch in the decode step: cached decode previously routed
attention through the jnp SDPA path, which reads the full dense (B, max_len)
cache every token.  This kernel reads K/V through a **page table** instead —
the grid's page axis covers only the pages the scheduler passes in (the live
prefix, bucketed), so attention bytes scale with the actual context length,
not max_len.

Layout (see serve/paging.py for the pool):

  q           (B, H, D)            one query token per slot, GQA grouped
  k/v pages   (P, Hkv, ps, D)      shared pool, page 0 reserved as garbage
  page_table  (B, npages) int32    slot's logical page j -> physical page
  kv_len      (B,) int32           live tokens per slot (masks page tails)

grid = (B, Hkv, npages) with the page axis innermost; the page table and
kv_len ride in as **scalar prefetch** (``PrefetchScalarGridSpec``) so the
K/V BlockSpec index_map can gather ``pt[b, p]`` before the body runs — the
kernel never touches pages the slot does not own.  All G = H/Hkv query heads
of one kv head are processed in a single block (one MXU dot per page).

Online-softmax state (m, l, acc) lives in VMEM scratch across the page
sweep, exactly like the prefill flash kernel.  Tokens at ``ids >= kv_len``
(page tails, unallocated logical pages mapped to garbage page 0) are masked
to NEG_INF; page 0 of the sweep always holds live tokens (kv_len >= 1), so
the running max is real before any fully-masked page contributes exp(s - m)
~= 0.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(pt_ref, len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref,
            acc_ref, *, sm_scale: float, page_size: int):
    b = pl.program_id(0)
    p = pl.program_id(2)

    @pl.when(p == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)            # (G, d)
    k = k_ref[0, 0].astype(jnp.float32)            # (ps, d)
    v = v_ref[0, 0].astype(jnp.float32)

    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * sm_scale
    ids = p * page_size + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(ids < len_ref[b], s, NEG_INF)    # causal == length mask

    m_prev = m_ref[...]                            # (G, 1)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    pexp = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(pexp, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
        pexp, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(p == pl.num_programs(2) - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / denom).astype(o_ref.dtype)


def decode_attention_pallas(
    q: jax.Array,           # (B, H, D) — one token per slot
    k_pages: jax.Array,     # (P, Hkv, page_size, D)
    v_pages: jax.Array,     # (P, Hkv, page_size, D)
    page_table: jax.Array,  # (B, npages) int32
    kv_len: jax.Array,      # (B,) int32
    *,
    sm_scale: float | None = None,
    interpret: bool = False,
) -> jax.Array:
    bsz, h, d = q.shape
    _, hkv, page_size, _ = k_pages.shape
    g = h // hkv
    npages = page_table.shape[1]
    if sm_scale is None:
        sm_scale = 1.0 / (d ** 0.5)
    qg = q.reshape(bsz, hkv, g, d)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,            # page_table, kv_len
        grid=(bsz, hkv, npages),
        in_specs=[
            pl.BlockSpec((1, 1, g, d),
                         lambda b, h_, p, pt, ln: (b, h_, 0, 0)),
            pl.BlockSpec((1, 1, page_size, d),
                         lambda b, h_, p, pt, ln: (pt[b, p], h_, 0, 0)),
            pl.BlockSpec((1, 1, page_size, d),
                         lambda b, h_, p, pt, ln: (pt[b, p], h_, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, d),
                               lambda b, h_, p, pt, ln: (b, h_, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, d), jnp.float32),
        ],
    )
    kernel = functools.partial(_kernel, sm_scale=sm_scale,
                               page_size=page_size)
    # contract: decode_attention
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(qg.shape, q.dtype),
        interpret=interpret,
    )(page_table, kv_len.astype(jnp.int32), qg, k_pages, v_pages)
    return out.reshape(bsz, h, d)
