"""Pallas TPU kernels for QERA's compute hot-spots.

mxint_matmul    — fused MXINT dequant GEMM + low-rank epilogue (serving path)
mxint_quant     — on-device blockwise MXINT packing
flash_attention — online-softmax attention (prefill path)

ops.py holds the jit'd public wrappers (padding + interpret fallback);
ref.py the pure-jnp oracles every kernel is tested against.
EXAMPLE.md documents the layout conventions.
"""

from repro.kernels.ops import (
    flash_attention,
    pick_blocks,
    quantize_weights,
    quantized_matmul,
    quantized_matmul_packed,
)
