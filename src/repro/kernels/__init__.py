"""Pallas TPU kernels for QERA's compute hot-spots.

mxint_matmul      — fused MXINT dequant GEMM + low-rank epilogue (serving)
mxint_quant       — on-device blockwise MXINT packing
flash_attention   — online-softmax attention (dense prefill path)
decode_attention  — paged Sq=1 attention through the page table (decode)
prefill_attention — paged Sq=chunk attention (chunked admission prefill)

ops.py holds the jit'd public wrappers (padding + interpret fallback) plus
the chunk-size heuristic for chunked prefill; ref.py the pure-jnp oracles
every kernel is tested against.  EXAMPLE.md documents the layout
conventions.
"""

from repro.kernels.ops import (
    chunk_plan,
    decode_attention,
    flash_attention,
    pick_blocks,
    pick_prefill_chunk,
    prefill_attention,
    quantize_weights,
    quantized_matmul,
    quantized_matmul_packed,
)
