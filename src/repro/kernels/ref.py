"""Pure-jnp oracles for every Pallas kernel (the correctness contract).

Each function is the semantic reference the kernels/tests assert against —
no tiling, no VMEM reasoning, just the math.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.quant.mxint import (
    mxint_quantize,
    mxint_dequantize,
    pack_mantissa,
    unpack_mantissa,
)


def mxint_matmul_lowrank_ref(x: jax.Array, mant: jax.Array, exp: jax.Array,
                             a: jax.Array, b: jax.Array, bits: int,
                             block_size: int) -> jax.Array:
    """y = x @ dq(Wq) + (x @ A) @ B  with f32 accumulation.

    x: (M, K); mant: (K, N) int8 — or the sub-byte packed (K // epb, N)
    layout, detected from the shapes and unpacked here; exp: (K//bs, N) int8;
    a: (K, r); b: (r, N).  Oracle for BOTH kernel variants (prefill 3D grid
    and skinny-M decode N-major grid) — the fused in-kernel prologue must
    match this unfused two-GEMM form exactly up to f32 accumulation order.
    """
    k = x.shape[-1]
    n = mant.shape[-1]
    if mant.shape[-2] != k:
        mant = unpack_mantissa(mant, bits, k)
    mant_b = mant.reshape(k // block_size, block_size, n)
    w = mxint_dequantize(mant_b, exp, bits, out_shape=(k, n), dtype=jnp.float32)
    x32 = x.astype(jnp.float32)
    y = x32 @ w + (x32 @ a.astype(jnp.float32)) @ b.astype(jnp.float32)
    return y


def mxint_quantize_ref(w: jax.Array, bits: int, block_size: int,
                       packed: bool = False):
    """(mant int8 (K, N) — (K // epb, N) when packed — exp int8 (K//bs, N))."""
    mant, exp = mxint_quantize(w, bits, block_size)
    k, n = w.shape[-2], w.shape[-1]
    mant = mant.reshape(*w.shape[:-2], k, n)
    if packed:
        mant = pack_mantissa(mant, bits)
    return mant, exp


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True, sm_scale: float | None = None,
                        kv_len: int | None = None) -> jax.Array:
    """Naive softmax attention with GQA head-group broadcast.

    q: (B, H, Sq, D); k, v: (B, Hkv, Skv, D); returns (B, H, Sq, D).
    """
    bq, h, sq, d = q.shape
    hkv = k.shape[1]
    g = h // hkv
    if sm_scale is None:
        sm_scale = 1.0 / (d ** 0.5)
    kk = jnp.repeat(k, g, axis=1)
    vv = jnp.repeat(v, g, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   kk.astype(jnp.float32)) * sm_scale
    skv = k.shape[2]
    if kv_len is not None:
        mask = jnp.arange(skv)[None, :] < kv_len
        s = jnp.where(mask, s, -jnp.inf)
    if causal:
        cm = jnp.arange(sq)[:, None] >= jnp.arange(skv)[None, :]
        s = jnp.where(cm, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, vv.astype(jnp.float32)).astype(q.dtype)


def gather_paged_kv(pages: jax.Array, page_table: jax.Array) -> jax.Array:
    """(P, Hkv, ps, D) pool + (B, npages) table -> contiguous (B, Hkv, S, D)
    with S = npages * ps, tokens in logical order."""
    b, npages = page_table.shape
    _, hkv, ps, d = pages.shape
    g = pages[page_table]                         # (B, npages, Hkv, ps, D)
    return jnp.moveaxis(g, 2, 1).reshape(b, hkv, npages * ps, d)


def prefill_attention_ref(q: jax.Array, k_pages: jax.Array,
                          v_pages: jax.Array, page_table: jax.Array,
                          q_off: jax.Array, kv_len: jax.Array, *,
                          sm_scale: float | None = None) -> jax.Array:
    """Chunked paged prefill attention oracle: gather the slot's pages to a
    contiguous prefix, then offset-causal masked softmax attention.

    q: (B, H, C, D) — a C-token prompt chunk per slot whose first token sits
    at absolute position ``q_off[b]``; k/v_pages: (P, Hkv, ps, D) with the
    chunk's own K/V already scattered in; page_table: (B, npages) int32;
    kv_len: (B,) int32 live tokens including this chunk.  Query row i sees
    kv ids ≤ q_off + i (the written prefix plus the chunk's causal part) and
    < kv_len (page tails).  Returns (B, H, C, D).
    """
    b, h, c, d = q.shape
    if sm_scale is None:
        sm_scale = 1.0 / (d ** 0.5)
    kk = gather_paged_kv(k_pages, page_table)          # (B, Hkv, S, D)
    vv = gather_paged_kv(v_pages, page_table)
    g = h // kk.shape[1]
    kk = jnp.repeat(kk, g, axis=1)
    vv = jnp.repeat(vv, g, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   kk.astype(jnp.float32)) * sm_scale
    kv_ids = jnp.arange(kk.shape[2])
    q_pos = q_off[:, None] + jnp.arange(c)             # (B, C)
    mask = (kv_ids[None, None, :] <= q_pos[:, :, None]) & \
           (kv_ids[None, None, :] < kv_len[:, None, None])
    s = jnp.where(mask[:, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p,
                      vv.astype(jnp.float32)).astype(q.dtype)


def decode_attention_ref(q: jax.Array, k_pages: jax.Array, v_pages: jax.Array,
                         page_table: jax.Array, kv_len: jax.Array, *,
                         sm_scale: float | None = None) -> jax.Array:
    """Paged decode attention oracle: gather the slot's pages to a contiguous
    prefix, then masked softmax attention.

    q: (B, H, D) one token per slot; k/v_pages: (P, Hkv, ps, D);
    page_table: (B, npages) int32; kv_len: (B,) int32.  Returns (B, H, D).
    Causality is subsumed by the length mask (the query is the newest token).
    """
    b, h, d = q.shape
    kk = gather_paged_kv(k_pages, page_table)
    vv = gather_paged_kv(v_pages, page_table)
    out = flash_attention_ref(q[:, :, None, :], kk, vv, causal=False,
                              sm_scale=sm_scale,
                              kv_len=kv_len[:, None, None, None])
    return out[:, :, 0, :]
