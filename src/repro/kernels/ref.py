"""Pure-jnp oracles for every Pallas kernel (the correctness contract).

Each function is the semantic reference the kernels/tests assert against —
no tiling, no VMEM reasoning, just the math.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.quant.mxint import mxint_quantize, mxint_dequantize


def mxint_matmul_lowrank_ref(x: jax.Array, mant: jax.Array, exp: jax.Array,
                             a: jax.Array, b: jax.Array, bits: int,
                             block_size: int) -> jax.Array:
    """y = x @ dq(Wq) + (x @ A) @ B  with f32 accumulation.

    x: (M, K); mant: (K, N) int8; exp: (K//bs, N) int8; a: (K, r); b: (r, N).
    Oracle for BOTH kernel variants (prefill 3D grid and skinny-M decode
    N-major grid) — the fused in-kernel prologue must match this unfused
    two-GEMM form exactly up to f32 accumulation order.
    """
    k, n = mant.shape
    mant_b = mant.reshape(k // block_size, block_size, n)
    w = mxint_dequantize(mant_b, exp, bits, out_shape=(k, n), dtype=jnp.float32)
    x32 = x.astype(jnp.float32)
    y = x32 @ w + (x32 @ a.astype(jnp.float32)) @ b.astype(jnp.float32)
    return y


def mxint_quantize_ref(w: jax.Array, bits: int, block_size: int):
    """(mant int8 (K, N), exp int8 (K//bs, N)) — flat-mantissa layout."""
    mant, exp = mxint_quantize(w, bits, block_size)
    k, n = w.shape[-2], w.shape[-1]
    return mant.reshape(*w.shape[:-2], k, n), exp


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True, sm_scale: float | None = None,
                        kv_len: int | None = None) -> jax.Array:
    """Naive softmax attention with GQA head-group broadcast.

    q: (B, H, Sq, D); k, v: (B, Hkv, Skv, D); returns (B, H, Sq, D).
    """
    bq, h, sq, d = q.shape
    hkv = k.shape[1]
    g = h // hkv
    if sm_scale is None:
        sm_scale = 1.0 / (d ** 0.5)
    kk = jnp.repeat(k, g, axis=1)
    vv = jnp.repeat(v, g, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   kk.astype(jnp.float32)) * sm_scale
    skv = k.shape[2]
    if kv_len is not None:
        mask = jnp.arange(skv)[None, :] < kv_len
        s = jnp.where(mask, s, -jnp.inf)
    if causal:
        cm = jnp.arange(sq)[:, None] >= jnp.arange(skv)[None, :]
        s = jnp.where(cm, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, vv.astype(jnp.float32)).astype(q.dtype)
