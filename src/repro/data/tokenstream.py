"""Deterministic synthetic token corpus + sharded, checkpointable loader.

The stream has LEARNABLE structure (a noisy affine bigram process over a
Zipf-ish unigram base): a small LM's loss drops well below the uniform
baseline within a few hundred steps, which is what the e2e training example
and convergence tests assert.

Properties needed by the 1000-node posture:
* deterministic function of (seed, host_id, step) — any host can regenerate
  any batch: data state is a single int in the checkpoint;
* host-sharded: host h of H draws disjoint batch slices;
* background prefetch thread with a bounded queue (straggler hiding).
"""

from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Iterator

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int = 256
    seq_len: int = 128
    global_batch: int = 8
    seed: int = 0
    noise: float = 0.15          # fraction of uniform-random successors
    num_codebooks: int = 0       # audio-family batches
    num_hosts: int = 1
    host_id: int = 0

    @property
    def host_batch(self) -> int:
        assert self.global_batch % self.num_hosts == 0
        return self.global_batch // self.num_hosts


def _batch_rng(cfg: DataConfig, step: int) -> np.random.Generator:
    return np.random.default_rng(
        np.random.SeedSequence([cfg.seed, cfg.host_id, step]))


def synth_tokens(cfg: DataConfig, step: int,
                 batch: int | None = None) -> np.ndarray:
    """(batch, seq_len + 1) int32 — slice [:-1]/[1:] for inputs/labels."""
    rng = _batch_rng(cfg, step)
    b = batch or cfg.host_batch
    v = cfg.vocab_size
    s = cfg.seq_len + 1
    # Zipf-ish start tokens
    ranks = np.arange(1, v + 1)
    probs = (1.0 / ranks) / np.sum(1.0 / ranks)
    toks = np.empty((b, s), np.int32)
    toks[:, 0] = rng.choice(v, size=b, p=probs)
    # affine successor with uniform noise
    noise = rng.random((b, s - 1)) < cfg.noise
    rand = rng.integers(0, v, size=(b, s - 1))
    for t in range(1, s):
        succ = (toks[:, t - 1] * 7 + 13) % v
        toks[:, t] = np.where(noise[:, t - 1], rand[:, t - 1], succ)
    return toks


def make_batch(cfg: DataConfig, step: int) -> dict[str, np.ndarray]:
    if cfg.num_codebooks:
        streams = [synth_tokens(
            dataclasses.replace(cfg, seed=cfg.seed + 1000 * (k + 1)), step)
            for k in range(cfg.num_codebooks)]
        toks = np.stack(streams, axis=1)           # (B, K, S+1)
        return {"tokens": toks[..., :-1], "labels": toks[..., 1:]}
    toks = synth_tokens(cfg, step)
    return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


class TokenStream:
    """Stateful iterator with prefetch; state == next step index."""

    def __init__(self, cfg: DataConfig, start_step: int = 0,
                 prefetch: int = 2):
        self.cfg = cfg
        self.step = start_step
        self._q: queue.Queue = queue.Queue(maxsize=max(prefetch, 1))
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        step = self.step
        while not self._stop.is_set():
            batch = make_batch(self.cfg, step)
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def __next__(self) -> dict[str, np.ndarray]:
        step, batch = self._q.get()
        self.step = step + 1            # checkpointable state
        return batch

    def __iter__(self) -> Iterator[dict[str, np.ndarray]]:
        return self

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2.0)
