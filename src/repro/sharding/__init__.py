from repro.sharding.rules import (
    batch_axes,
    batch_spec,
    dp_axes,
    kv_cache_spec,
    param_spec,
    param_specs,
    rwkv_cache_specs,
    ssm_cache_specs,
    with_mesh,
)
