"""Sharding: training-time logical-axis rules and serving-time tensor
parallelism.

- ``rules.py`` — the training scheme (TP x FSDP x DP over 'model' / 'data'
  / 'pod'), param path -> PartitionSpec via the IN_PROJS/OUT_PROJS naming
  contract.
- ``serving.py`` — tensor-parallel *serving* over a 1-D ('model',) mesh:
  shards the packed MXINT + low-rank serving params, the paged KV pool, and
  the decode/prefill step functions under ``shard_map`` so every device
  runs its own fused Pallas launch with exactly one all-reduce per
  in/out-projection pair.  Entry points: ``plan_for(cfg, mesh)`` ->
  ``ServingPlan``.
"""

from repro.sharding.rules import (
    batch_axes,
    batch_spec,
    dp_axes,
    kv_cache_spec,
    param_spec,
    param_specs,
    rwkv_cache_specs,
    ssm_cache_specs,
    with_mesh,
)
from repro.sharding.serving import (
    ServingPlan,
    plan_for,
    serving_cache_specs,
    serving_param_specs,
    shard_map_compat,
    tp_local_cfg,
    tp_role,
    validate_tp,
)
