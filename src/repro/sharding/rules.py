"""Logical-axis sharding rules: param path -> PartitionSpec.

Scheme (DESIGN.md §5): TP on 'model' for every projection's wide axis, FSDP
(ZeRO-3) on 'data' for the other weight axis, batch on ('pod','data').
Stacked-layer leaves carry a leading L axis (never sharded).  Optimizer
moments inherit the param spec -> fully-sharded optimizer states for free.

Naming contract with models/*: in-projections end in one of IN_PROJS (wide
axis LAST), out-projections in OUT_PROJS (wide axis FIRST); everything small
(norms, biases, routers, decay vectors) replicates.

Serving-time tensor parallelism (``sharding/serving.py``) reuses the same
IN_PROJS/OUT_PROJS contract over a 1-D ('model',) mesh, but shards the
*packed* serving leaves (mant/exp/lora_a/lora_b) Megatron-style instead:
column-parallel in-projections, row-parallel out-projections, one psum per
projection pair.  These rules stay the training/eval scheme.
"""

from __future__ import annotations

import re
from typing import Any, Mapping

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.utils.trees import flatten_dict, unflatten_dict

# suffix name -> role
IN_PROJS = {"wq", "wk", "wv", "wi", "wg", "wu", "w_z", "w_x", "w_r", "w_k",
            "w_v", "w_g", "w_kc", "w_rc", "dense"}
OUT_PROJS = {"wo", "wd", "wo_mlp", "w_o", "w_vc", "out_proj"}
NARROW_IN = {"w_b", "w_c", "w_dt"}            # small output dim: FSDP only
REPLICATED = {"norm", "bias", "scale", "gate", "a_log", "dt_bias", "d_skip",
              "bonus_u", "decay_w0", "ln_x", "router", "conv_b", "conv_c",
              r"^pos$", r"^out$"}


def _base_spec(name: str, ndim: int, path: str) -> tuple:
    """Spec for the trailing (non-stacked) dims of a leaf."""
    if name == "tok":                         # embedding (V, D)
        if ndim == 3:                         # audio codebooks (K, V, D)
            return (None, "model", "data")
        return ("model", "data")
    if name == "lm_head" or path.endswith("lm_head"):
        if ndim == 3:                         # audio heads (K, D, V)
            return (None, "data", "model")
        return ("data", "model")              # (D, V)
    if name.startswith("mu_") or any(re.search(p, name) for p in REPLICATED):
        return (None,) * ndim
    if name == "conv_x":                      # (W, d_inner)
        return (None, "model")
    if name == "decay_a":                     # (D, lora)
        return ("data", None)
    if name == "decay_b":                     # (lora, D)
        return (None, "model")
    if name in NARROW_IN:
        return ("data", None)
    if name in IN_PROJS:
        if ndim == 3:                         # MoE experts (E, D, F)
            if EXPERT_AXIS == "data":         # DeepSpeed-style EP=DP + TP FFN
                return ("data", None, "model")
            return ("model", "data", None)
        return ("data", "model")
    if name in OUT_PROJS:
        if ndim == 3:                         # MoE experts (E, F, D)
            if EXPERT_AXIS == "data":
                return ("data", "model", None)
            return ("model", None, "data")
        return ("model", "data")
    return (None,) * ndim                     # unknown -> replicate


# Expert-parallel axis variant (perf experiments): "model" shards experts on
# the TP axis (all-to-all over ICI-heavy axis); "data" aligns expert shards
# with the batch shards (dispatch all-to-all stays within the data axis).
EXPERT_AXIS = "model"


def set_expert_axis(axis: str) -> None:
    global EXPERT_AXIS
    assert axis in ("model", "data")
    EXPERT_AXIS = axis


def param_spec(path: str, leaf: Any, *, stacked_depth: int | None = None) -> P:
    """PartitionSpec for one param leaf.

    ``stacked_depth``: how many leading stacked axes to skip (inferred from
    path when None: anything under blocks/ or cross_blocks/ has one).
    """
    parts = path.split("/")
    name = parts[-1]
    quant_suffix = None
    if name in ("w_tilde", "lora_a", "lora_b", "mant", "exp"):
        quant_suffix, name = name, parts[-2]

    ndim = leaf.ndim if hasattr(leaf, "ndim") else len(leaf.shape)
    if ndim == 0:                              # packed-format metadata scalars
        return P()
    if stacked_depth is None:
        stacked_depth = 1 if parts[0] in ("blocks", "cross_blocks") else 0
    base_nd = ndim - stacked_depth
    if quant_suffix in ("lora_a", "lora_b"):
        base_nd = 2  # always (m, k) / (k, n) under the stack

    spec = _base_spec(name, base_nd, path)
    if quant_suffix == "lora_a":               # (in_dim, k)
        spec = (spec[0], None)
    elif quant_suffix == "lora_b":             # (k, out_dim)
        spec = (None, spec[-1])
    elif quant_suffix == "exp":                # (in/bs, out) same as weight
        spec = spec
    if len(spec) < base_nd:                    # e.g. replicate fallbacks
        spec = spec + (None,) * (base_nd - len(spec))
    return P(*((None,) * stacked_depth + tuple(spec[:base_nd])))


def param_specs(params_or_shapes: Mapping[str, Any]) -> dict[str, Any]:
    """Whole-tree PartitionSpecs (pure specs; wrap with mesh via shardings)."""
    flat = flatten_dict(dict(params_or_shapes))
    out = {p: param_spec(p, leaf) for p, leaf in flat.items()}
    return unflatten_dict(out)


def with_mesh(spec_tree: Any, mesh: Mesh) -> Any:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        spec_tree, is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# batch / activation / cache specs
# ---------------------------------------------------------------------------

def dp_axes(mesh: Mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def batch_axes(mesh: Mesh, global_batch: int) -> tuple[str, ...]:
    """Largest prefix of (pod, data) whose product divides global_batch."""
    axes, prod = [], 1
    for a in dp_axes(mesh):
        size = mesh.shape[a]
        if global_batch % (prod * size) == 0:
            axes.append(a)
            prod *= size
    return tuple(axes)


def batch_spec(mesh: Mesh, global_batch: int, extra_dims: int = 1) -> P:
    """(B, ...) arrays: batch over usable dp axes, rest replicated."""
    ax = batch_axes(mesh, global_batch)
    lead = ax if len(ax) > 1 else (ax[0] if ax else None)
    return P(lead, *((None,) * extra_dims))


def kv_cache_spec(mesh: Mesh, global_batch: int, *, stacked: bool = True,
                  kv_heads: int | None = None) -> P:
    """(L, B, KVH, S, hd): batch over dp, cache SEQ over 'model'
    (sequence-parallel decode attention — softmax reduces with psum).
    When the batch cannot shard (e.g. long-context B=1), the 'data' axis
    moves to KV heads instead so the cache still spreads across the pod."""
    ax = batch_axes(mesh, global_batch)
    lead = ax if len(ax) > 1 else (ax[0] if ax else None)
    head_ax = None
    if not ax and kv_heads is not None and kv_heads % mesh.shape["data"] == 0:
        head_ax = "data"
    spec = (lead, head_ax, "model", None)
    return P(*(((None,) if stacked else ()) + spec))


def ssm_cache_specs(mesh: Mesh, global_batch: int) -> dict[str, P]:
    ax = batch_axes(mesh, global_batch)
    lead = ax if len(ax) > 1 else (ax[0] if ax else None)
    return {
        "ssm": P(None, lead, "model", None, None),     # (L,B,H,P,N): H over TP
        "conv_x": P(None, lead, None, "model"),
        "conv_b": P(None, lead, None, None),
        "conv_c": P(None, lead, None, None),
    }


def make_act_constrainer(mesh_axes: tuple[tuple[str, int], ...]):
    """Divisibility-aware with_sharding_constraint helper for activations.

    ``mesh_axes`` carries (name, size) pairs (ModelConfig.mesh_axes — set by
    the dry-run / launcher, empty in plain CPU tests -> returns None).
    Dim names: 'dp' expands to ('pod','data'); any other mesh axis name maps
    directly; None leaves a dim unconstrained.  Axes that do not divide the
    dim are silently dropped (e.g. batch=1 decode, 56 heads on a 16-way TP).
    """
    if not mesh_axes:
        return None
    sizes = dict(mesh_axes)

    def constrain(x: jax.Array, names: tuple) -> jax.Array:
        spec = []
        for dim, name in zip(x.shape, names):
            if name is None:
                spec.append(None)
                continue
            cand = ("pod", "data") if name == "dp" else (name,)
            chosen, prod = [], 1
            for a in cand:
                if a in sizes and dim % (prod * sizes[a]) == 0:
                    chosen.append(a)
                    prod *= sizes[a]
            spec.append(tuple(chosen) if len(chosen) > 1
                        else (chosen[0] if chosen else None))
        return jax.lax.with_sharding_constraint(x, P(*spec))

    return constrain


def rwkv_cache_specs(mesh: Mesh, global_batch: int) -> dict[str, P]:
    ax = batch_axes(mesh, global_batch)
    lead = ax if len(ax) > 1 else (ax[0] if ax else None)
    return {
        "state": P(None, lead, "model", None, None),   # (L,B,H,dk,dv)
        "last_tm": P(None, lead, None),
        "last_cm": P(None, lead, None),
    }
