"""Serving-time tensor parallelism: one 1-D ``('model',)`` mesh, shard_map,
and exactly one all-reduce per projection pair.

Training sharding (``sharding/rules.py``) lets GSPMD place every op from
logical-axis PartitionSpecs.  Serving cannot: the fused MXINT matmul and the
paged-attention kernels are single Pallas launches GSPMD will not split, so
TP serving uses ``shard_map`` instead — every device runs its OWN Pallas
launch on its local shard, and the only cross-device traffic is an explicit
``psum``.  This module is the single source of truth for that layout:

Parameters (the ``rules.py`` naming contract, folded onto one axis):
  * in-projections (``IN_PROJS``: wq/wk/wv/wg/wu/... — wide axis LAST) are
    **column-parallel**: weight / ``w_tilde`` / packed ``mant`` / ``exp`` /
    ``lora_b`` shard their LAST axis; ``lora_a`` replicates.  Mantissa
    packing runs along K, so a column split never divides a packed byte or
    an exponent block.
  * out-projections (``OUT_PROJS``: wo/wd/... — wide axis FIRST) are
    **row-parallel**: weight / ``w_tilde`` / ``mant`` / ``exp`` / ``lora_a``
    shard their K axis; ``lora_b`` replicates.  Row shards must keep whole
    exponent blocks, whole packed bytes, and 8-sublane alignment —
    ``quant.mxint.validate_packed_sharding`` enforces K/tp % lcm(block_size,
    8*epb) == 0 with a clear error.
  * everything else (embeddings, lm_head, norms, scalar packed metadata)
    replicates.

Activations: the residual stream stays replicated.  A column-parallel
in-projection emits head-sharded q/k/v; attention and the row-parallel
out-projection then produce a PARTIAL (B, S, D) output whose ``psum`` lives
in ``models/transformer._dense_block`` — one all-reduce after attention
(wo) and one after the MLP (wd), two per layer, none inside any kernel.
Since ``lora_b`` is replicated on row-parallel layers,
``sum_d((x_d @ A_d) @ B) == (sum_d(x_d @ A_d)) @ B`` — the fused in-kernel
low-rank epilogue stays valid per shard and the block-level psum covers the
quantized and low-rank terms together.

KV cache: dense ``k``/``v`` (L, B, KVH, S, hd) and paged ``k_pages``/
``v_pages`` (L, P, KVH, page_size, hd) shard the KV-HEADS axis (index 2) on
'model' — each device owns the pages for its heads.  The page table,
``PagePool`` refcounts, and the ``PrefixIndex`` hash-chain are host-local
integers describing page IDENTITY, not content, so every CoW/prefix/
scheduler decision is shard-agnostic and carries over untouched; the slot
data-movement helpers (place/restore/zero/fork) never index the heads axis
and partition communication-free under plain jit.

Inside shard_map the model runs with a LOCAL config
(:func:`tp_local_cfg`): heads, kv-heads and d_ff divided by tp, head_dim
pinned (it would otherwise re-derive from the unsharded d_model), and
``tp_size``/``tp_axis`` set so the block residual knows to psum.
"""

from __future__ import annotations

import dataclasses
from functools import lru_cache, partial
from typing import Any, Mapping

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.config import ModelConfig
from repro.quant.mxint import elems_per_byte, validate_packed_sharding
from repro.sharding.rules import IN_PROJS, OUT_PROJS
from repro.utils.trees import flatten_dict, unflatten_dict

TP_AXIS = "model"

# leaf-name suffixes of a quantized / packed linear group
_QUANT_SUFFIXES = ("w_tilde", "lora_a", "lora_b", "mant", "exp", "bits",
                   "block_size")
_KV_LEAVES = ("k", "v", "k_pages", "v_pages")


def shard_map_compat(fn, mesh: Mesh, in_specs, out_specs):
    """``shard_map`` across jax versions, replication checking off (Pallas
    calls and explicit psums confuse the rep checker)."""
    try:
        from jax import shard_map as _sm  # jax >= 0.6
        try:
            return _sm(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                       check_vma=False)
        except TypeError:
            return _sm(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs)
    except ImportError:
        from jax.experimental.shard_map import shard_map as _sm
        return _sm(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                   check_rep=False)


# ---------------------------------------------------------------------------
# roles and specs
# ---------------------------------------------------------------------------


def tp_role(path: str) -> str:
    """'column' | 'row' | 'replicated' for a flattened param path, per the
    rules.py suffix naming contract (quant suffixes see their parent)."""
    parts = path.split("/")
    name = parts[-1]
    if name in _QUANT_SUFFIXES and len(parts) > 1:
        name = parts[-2]
    if name in IN_PROJS:
        return "column"
    if name in OUT_PROJS:
        return "row"
    return "replicated"


def validate_tp(cfg: ModelConfig, tp: int) -> None:
    """Config-level shardability; raises a clear ValueError, never an XLA
    assert.  Serving TP covers the dense family (the paper's PTQ targets);
    other families keep their single-device serving path."""
    if tp <= 1:
        return
    if cfg.family != "dense":
        raise ValueError(
            f"tensor-parallel serving supports the dense family only "
            f"(got family={cfg.family!r}); run {cfg.family!r} configs at "
            f"tp=1")
    for what, dim in (("num_heads", cfg.num_heads),
                      ("num_kv_heads", cfg.num_kv_heads),
                      ("d_ff", cfg.d_ff)):
        if dim % tp:
            raise ValueError(
                f"{what}={dim} does not divide across tp={tp} devices "
                f"(config {cfg.name!r})")


def validate_plan_tp(shapes: Mapping[str, tuple[int, int]], plan: Any,
                     tp: int) -> None:
    """Per-leaf packed-granule preflight of a heterogeneous QuantPlan.

    ``shapes`` maps flattened param paths to their (K, N)
    (``core.allocate.eligible_shapes``); each leaf is validated at ITS OWN
    plan format — row-parallel shards must hold whole exponent blocks and
    whole packed bytes of that leaf's (bits, block_size), column-parallel
    shards must divide N — so a mixed-precision plan is refused before any
    weight is quantized, with the offending layer named."""
    if tp <= 1:
        return
    from repro.quant.mxint import MXINT_CONFIGS

    for path in sorted(shapes):
        k, n = shapes[path]
        role = tp_role(path)
        c = plan.choice(path)
        spec = MXINT_CONFIGS[c.quantizer]
        if role == "row":
            validate_packed_sharding(k, tp, spec.bits, spec.block_size,
                                     name=f"{path} ({c.quantizer})")
        elif role == "column" and n % tp:
            raise ValueError(
                f"plan leaf {path!r} N={n} does not divide across tp={tp} "
                f"devices")


def tp_local_cfg(cfg: ModelConfig, tp: int) -> ModelConfig:
    """The PER-DEVICE config the model runs with inside shard_map.

    head_dim must be pinned to the global ``cfg.hd``: the local head count
    changes, so the ``d_model // num_heads`` fallback would silently give
    each shard fatter heads.
    """
    if tp <= 1:
        return cfg
    return dataclasses.replace(
        cfg, num_heads=cfg.num_heads // tp,
        num_kv_heads=cfg.num_kv_heads // tp,
        d_ff=cfg.d_ff // tp, head_dim=cfg.hd,
        tp_size=tp, tp_axis=TP_AXIS)


def serving_param_spec(path: str, leaf: Any) -> P:
    """PartitionSpec of one param leaf on the 1-D serving mesh."""
    ndim = leaf.ndim if hasattr(leaf, "ndim") else len(leaf.shape)
    if ndim == 0:                       # packed metadata scalars (bits, bs)
        return P()
    parts = path.split("/")
    name = parts[-1]
    stacked = 1 if parts[0] == "blocks" and ndim > 2 else 0
    role = tp_role(path)
    if role == "replicated" or name == "lora_a" and role == "column" \
            or name == "lora_b" and role == "row":
        return P(*(None,) * ndim)
    lead = (None,) * stacked
    if role == "column":                # wide axis LAST: shard N
        return P(*lead, *(None,) * (ndim - stacked - 1), TP_AXIS)
    # row-parallel: shard the K axis (second-to-last for 2-D leaves)
    return P(*lead, *(None,) * (ndim - stacked - 2), TP_AXIS, None)


def serving_param_specs(params: Mapping[str, Any], tp: int) -> dict:
    """Whole-tree specs + per-leaf divisibility validation.

    Checks every sharded axis divides ``tp``; quantized row-parallel groups
    additionally go through ``validate_packed_sharding`` (whole exponent
    blocks / packed bytes / 8-sublane alignment per shard).
    """
    flat = flatten_dict(dict(params))
    out: dict[str, P] = {}
    for path, leaf in flat.items():
        spec = serving_param_spec(path, leaf)
        out[path] = spec
        if tp <= 1:
            continue
        for ax, s in enumerate(spec):
            if s == TP_AXIS and leaf.shape[ax] % tp:
                raise ValueError(
                    f"param {path!r} axis {ax} (size {leaf.shape[ax]}) does "
                    f"not divide across tp={tp} devices")
        if path.endswith("/mant") and tp_role(path) == "row":
            parent = path.rsplit("/", 1)[0]
            bits = int(np.asarray(flat[f"{parent}/bits"]))
            bs = int(np.asarray(flat[f"{parent}/block_size"]))
            lora_a = flat.get(f"{parent}/lora_a")
            if lora_a is not None:
                k = lora_a.shape[-2]
            else:                       # draft views drop the lora factors
                k = leaf.shape[-2] * elems_per_byte(bits)
            validate_packed_sharding(k, tp, bits, bs, name=parent)
    return unflatten_dict(out)


def serving_cache_spec(path: str, leaf: Any) -> P:
    """Cache-leaf spec: K/V (dense rows or page pool) shard the KV-heads
    axis; the page table and scalar leaves replicate."""
    name = path.rsplit("/", 1)[-1]
    ndim = leaf.ndim if hasattr(leaf, "ndim") else len(leaf.shape)
    if name in _KV_LEAVES:
        if ndim != 5:
            raise ValueError(
                f"cache leaf {path!r} has ndim={ndim}, expected 5 "
                f"(L, B|P, KVH, S|page_size, hd)")
        return P(None, None, TP_AXIS, None, None)
    if name == "page_table":
        return P(*(None,) * ndim)
    raise ValueError(
        f"cache leaf {path!r} has no TP sharding rule — tensor-parallel "
        f"serving covers dense K/V caches only")


def serving_cache_specs(cache: Mapping[str, Any]) -> dict:
    flat = flatten_dict(dict(cache))
    return unflatten_dict(
        {p: serving_cache_spec(p, leaf) for p, leaf in flat.items()})


def replicated_specs(tree: Any) -> Any:
    return jax.tree.map(
        lambda x: P(*(None,) * (x.ndim if hasattr(x, "ndim") else 0)), tree)


def _shard_axis(spec: P) -> int | None:
    for i, s in enumerate(spec):
        if s == TP_AXIS:
            return i
    return None


# ---------------------------------------------------------------------------
# the plan
# ---------------------------------------------------------------------------


class ServingPlan:
    """Everything the batcher/engine/supervisor need to run one config on
    one serving mesh: local config, spec builders, shard placement, jitted
    shard_map wrappers, and the snapshot shard codec."""

    def __init__(self, cfg: ModelConfig, mesh: Mesh):
        if TP_AXIS not in mesh.axis_names:
            raise ValueError(
                f"serving mesh must carry a {TP_AXIS!r} axis, got "
                f"{mesh.axis_names}")
        self.tp = int(mesh.shape[TP_AXIS])
        validate_tp(cfg, self.tp)
        self.cfg = cfg
        self.mesh = mesh
        self.local_cfg = tp_local_cfg(cfg, self.tp)

    # -- placement ----------------------------------------------------------
    def named(self, spec_tree: Any) -> Any:
        return jax.tree.map(lambda s: NamedSharding(self.mesh, s), spec_tree,
                            is_leaf=lambda x: isinstance(x, P))

    def shard(self, tree: Any, spec_tree: Any) -> Any:
        return jax.tree.map(jax.device_put, tree, self.named(spec_tree))

    def param_specs(self, params: Any) -> Any:
        return serving_param_specs(params, self.tp)

    def cache_specs(self, cache: Any) -> Any:
        return serving_cache_specs(cache)

    def shard_params(self, params: Any) -> Any:
        return self.shard(params, self.param_specs(params))

    def shard_cache(self, cache: Any) -> Any:
        return self.shard(cache, self.cache_specs(cache))

    # -- compiled steps -----------------------------------------------------
    def sjit(self, fn, in_specs, out_specs, donate_argnums=()):
        """jit(shard_map(fn)): each device traces its own Pallas launches on
        local shapes; unsharded args are resharded to in_specs on entry."""
        return jax.jit(shard_map_compat(fn, self.mesh, in_specs, out_specs),
                       donate_argnums=donate_argnums)

    # -- snapshots ----------------------------------------------------------
    def mesh_spec(self) -> dict:
        """JSON mesh descriptor recorded in snapshot host state."""
        return {"axis": TP_AXIS, "tp": self.tp}

    def to_host_shards(self, tree: Any, spec_tree: Any) -> Any:
        """Device tree -> host numpy tree with each SHARDED leaf stored as a
        stacked (tp, ...) array of its per-device shards (deterministic
        split order along the shard axis — no dependence on device
        enumeration), replicated leaves stored whole."""
        flat, fspec = flatten_dict(tree), flatten_dict(spec_tree)
        out: dict[str, Any] = {}
        for key, leaf in flat.items():
            arr = np.asarray(leaf)
            ax = _shard_axis(fspec[key])
            out[key] = (np.stack(np.split(arr, self.tp, axis=ax))
                        if ax is not None else arr)
        return unflatten_dict(out)

    def from_host_shards(self, tree: Any, spec_tree: Any) -> Any:
        """Inverse of :meth:`to_host_shards`, device_put back onto the mesh
        with the leaf's NamedSharding."""
        flat, fspec = flatten_dict(tree), flatten_dict(spec_tree)
        out: dict[str, Any] = {}
        for key, leaf in flat.items():
            arr = np.asarray(leaf)
            ax = _shard_axis(fspec[key])
            if ax is not None:
                arr = np.concatenate(list(arr), axis=ax)
            out[key] = jax.device_put(arr,
                                      NamedSharding(self.mesh, fspec[key]))
        return unflatten_dict(out)


@lru_cache(maxsize=None)
def plan_for(cfg: ModelConfig, mesh: Mesh) -> ServingPlan:
    """Cached plan per (config, mesh) — plans hold jit caches upstream, so
    identity matters."""
    return ServingPlan(cfg, mesh)


# ---------------------------------------------------------------------------
# mesh-aware scan_generate (the whole rollout runs inside ONE shard_map)
# ---------------------------------------------------------------------------

_TP_SCAN_CACHE: dict = {}


def tp_scan_generate(plan: ServingPlan, params, prompt, eos_tok, *,
                     steps: int, max_len: int, has_eos: bool,
                     page_size: int = 0, prefill_chunk: int = 0):
    """Tensor-parallel fused rollout: prefill + lax.scan decode entirely
    inside shard_map with the plan's local config — the paged pool (when
    ``page_size`` > 0) is allocated per device with local KV heads, and the
    2-per-layer psums are the only collectives in the whole executable."""
    from repro.serve.engine import _scan_generate_impl

    key = (plan.cfg, plan.mesh, steps, max_len, has_eos, page_size,
           prefill_chunk, jax.tree.structure(params))
    fn = _TP_SCAN_CACHE.get(key)
    if fn is None:
        impl = partial(_scan_generate_impl, cfg=plan.local_cfg, steps=steps,
                       max_len=max_len, has_eos=has_eos, page_size=page_size,
                       prefill_chunk=prefill_chunk)
        fn = plan.sjit(impl,
                       in_specs=(plan.param_specs(params), P(None, None),
                                 P()),
                       out_specs=P(None, None))
        _TP_SCAN_CACHE[key] = fn
    return fn(params, prompt, eos_tok)


def tp_spec_generate(plan: ServingPlan, params, draft_params, prompt,
                     eos_tok, *, steps: int, max_len: int, has_eos: bool,
                     spec_k: int, page_size: int = 0,
                     prefill_chunk: int = 0):
    """Tensor-parallel speculative rollout: the draft/verify while_loop runs
    entirely inside ONE shard_map.  The draft view shares the full tree's
    mant/exp buffers, so ``param_specs`` places its leaves on exactly the
    same shards (the 0-dim ``draft_bits``/``draft_shift`` markers replicate)
    and the draft pass needs the same 2-per-layer psums and nothing more —
    speculation adds no collectives."""
    from repro.serve.engine import _spec_generate_impl

    key = (plan.cfg, plan.mesh, steps, max_len, has_eos, spec_k, page_size,
           prefill_chunk, jax.tree.structure(params),
           jax.tree.structure(draft_params))
    fn = _TP_SCAN_CACHE.get(key)
    if fn is None:
        impl = partial(_spec_generate_impl, cfg=plan.local_cfg, steps=steps,
                       max_len=max_len, has_eos=has_eos, spec_k=spec_k,
                       page_size=page_size, prefill_chunk=prefill_chunk)
        fn = plan.sjit(impl,
                       in_specs=(plan.param_specs(params),
                                 plan.param_specs(draft_params),
                                 P(None, None), P()),
                       out_specs=(P(None, None), P(None)))
        _TP_SCAN_CACHE[key] = fn
    return fn(params, draft_params, prompt, eos_tok)
