"""Property tests for self-speculative decoding (hypothesis).

One law, stormed over the whole configuration space: for ANY combination
of speculation depth k, draft precision, page geometry, prompt mix
(including prompts that land exactly on page boundaries), fault storm and
recurrent state, a ``ContinuousBatcher`` run with ``spec_k > 0``

* emits token streams BIT-IDENTICAL to the same run at ``spec_k=0``, and
* leaves the page pool's refcounts conserved after every tick
  (``debug_invariants=True`` re-derives the accounting laws from scratch
  per tick and raises on the first violation).

Dense and paged+prefix modes are stormed here in-process; the tp=2 copy
of the same law runs in ``tests/test_speculative.py`` through the
subprocess worker (XLA-flags isolation rule).  The deterministic
equivalents of these properties also live there, so this file skipping
(hypothesis is an optional dependency) never removes the only coverage.
"""

import jax
import numpy as np
import pytest

from repro.core import PTQConfig, quantize_params
from repro.core.api import pack_for_serving
from repro.models import ModelConfig, Taps, forward, init_params
from repro.serve.batching import ContinuousBatcher, Request

pytest.importorskip("hypothesis")  # property tests skip without hypothesis
from hypothesis import given, settings, strategies as st  # noqa: E402

DENSE_CFG = ModelConfig(family="dense", num_layers=2, d_model=64,
                        num_heads=4, num_kv_heads=2, d_ff=128,
                        vocab_size=64, head_dim=16, scan_layers=False)
HYBRID_CFG = ModelConfig(family="hybrid_mamba", num_layers=4, d_model=32,
                         num_heads=4, num_kv_heads=2, head_dim=8, d_ff=64,
                         vocab_size=64, ssm_state=8, ssm_head_dim=8,
                         ssm_chunk=4, attn_every=2, scan_layers=False)
_RECURRENT_SKIPS = PTQConfig().skip_patterns + (r"d_skip", r"mu_",
                                                r"bonus", r"ln_")


def _packed_dense():
    params = init_params(DENSE_CFG, jax.random.PRNGKey(0))
    taps = Taps()
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                              DENSE_CFG.vocab_size)
    forward(params, {"tokens": toks}, DENSE_CFG, taps=taps)
    from benchmarks.common import remap_stats
    qcfg = PTQConfig(method="qera_approx", rank=8, quantizer="mxint4")
    return pack_for_serving(
        quantize_params(params, qcfg,
                        stats_by_path=remap_stats(taps.layer_stats())), qcfg)


def _packed_hybrid():
    params = init_params(HYBRID_CFG, jax.random.PRNGKey(2))
    qcfg = PTQConfig(method="zeroquant_v2", rank=4, quantizer="mxint4",
                     skip_patterns=_RECURRENT_SKIPS)
    return pack_for_serving(quantize_params(params, qcfg), qcfg)


@pytest.fixture(scope="module")
def packed_dense():
    return _packed_dense()


@pytest.fixture(scope="module")
def packed_hybrid():
    return _packed_hybrid()


def _run(params, cfg, prompts, max_new, *, storm_seed=None, **kw):
    b = ContinuousBatcher(params, cfg, num_slots=3, max_len=48,
                          debug_invariants=kw.get("paged", False),
                          nan_retry_limit=10, **kw)
    reqs = [Request(rid=i, prompt=p, max_new_tokens=max_new)
            for i, p in enumerate(prompts)]
    if storm_seed is not None:
        from repro.runtime.fault_tolerance import RestartPolicy
        from repro.serve.faults import FaultInjector
        from repro.serve.supervisor import ServingSupervisor
        sup = ServingSupervisor(
            b, injector=FaultInjector.storm(seed=storm_seed, ticks=30,
                                            p_spike=0.2, p_nan=0.2,
                                            crash_ticks=(5,),
                                            spike_duration=2),
            snapshot_every=2,
            policy=RestartPolicy(max_restarts=4, backoff_base_s=0.0),
            sleep=lambda _: None)
        for r in reqs:
            assert sup.submit(r).accepted
        sup.run(max_ticks=500)
    else:
        for r in reqs:
            b.submit(r)
        b.run()
    if kw.get("paged"):
        from repro.analysis.runtime import check_page_accounting
        errs = check_page_accounting(b.pool, b.slot_pages, b.page_table)
        assert not errs, errs
    return {r.rid: list(r.output) for r in reqs}


def _prompts(cfg, lens, seed, page_size):
    rng = np.random.default_rng(seed)
    pre = rng.integers(0, cfg.vocab_size, size=page_size).astype(np.int32)
    out = []
    for i, n in enumerate(lens):
        tail = rng.integers(0, cfg.vocab_size, size=n).astype(np.int32)
        # odd requests share a page-aligned preamble: speculative spans must
        # CoW-fork shared pages, never write them
        out.append(np.concatenate([pre, tail]) if i % 2 else tail)
    return out


@settings(max_examples=10, deadline=None)
@given(spec_k=st.integers(1, 4),
       draft_bits=st.sampled_from([2, 4]),
       page_size=st.sampled_from([4, 8]),
       # lengths straddle multiples of both page sizes (boundary storms)
       lens=st.lists(st.integers(1, 17), min_size=2, max_size=4),
       seed=st.integers(0, 2**16))
def test_spec_batcher_identity_and_refcounts(packed_dense, spec_k,
                                             draft_bits, page_size, lens,
                                             seed):
    prompts = _prompts(DENSE_CFG, lens, seed, page_size)
    for kw in ({}, {"paged": True, "page_size": page_size},
               {"paged": True, "page_size": page_size,
                "prefix_cache": True}):
        ref = _run(packed_dense, DENSE_CFG, prompts, 6, **kw)
        got = _run(packed_dense, DENSE_CFG, prompts, 6, spec_k=spec_k,
                   draft_bits=draft_bits, **kw)
        assert got == ref, f"diverged under {kw or 'dense'}"


@settings(max_examples=6, deadline=None)
@given(spec_k=st.integers(1, 4),
       storm_seed=st.integers(0, 2**16),
       seed=st.integers(0, 2**16))
def test_spec_survives_fault_storm(packed_dense, spec_k, storm_seed, seed):
    prompts = _prompts(DENSE_CFG, [5, 9, 13], seed, 8)
    kw = dict(paged=True, page_size=8, num_pages=23, prefix_cache=True)
    ref = _run(packed_dense, DENSE_CFG, prompts, 6, **kw)
    got = _run(packed_dense, DENSE_CFG, prompts, 6, spec_k=spec_k,
               draft_bits=4, storm_seed=storm_seed, **kw)
    assert got == ref


@settings(max_examples=6, deadline=None)
@given(spec_k=st.integers(1, 3),
       draft_bits=st.sampled_from([2, 4]),
       lens=st.lists(st.integers(1, 13), min_size=2, max_size=3),
       seed=st.integers(0, 2**16))
def test_spec_hybrid_recurrent_state(packed_hybrid, spec_k, draft_bits,
                                     lens, seed):
    """Partial accepts on a recurrent family exercise the restore+replay
    path: the SSM rows must be rebuilt exactly, for any acceptance
    pattern the draft plane produces."""
    prompts = _prompts(HYBRID_CFG, lens, seed, 8)
    for kw in ({}, {"paged": True, "page_size": 8}):
        ref = _run(packed_hybrid, HYBRID_CFG, prompts, 5, **kw)
        got = _run(packed_hybrid, HYBRID_CFG, prompts, 5, spec_k=spec_k,
                   draft_bits=draft_bits, **kw)
        assert got == ref, f"diverged under {kw or 'dense'}"
