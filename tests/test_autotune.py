"""Measured block-plan autotuner: determinism, cache hit/miss, hot-path.

The CI smoke asserts the determinism contract: candidate enumeration is a
pure function of the launch key, a second ``autotune`` call is a cache HIT
that returns the stored plan without re-measuring, and the persisted JSON
is keyed/sorted reproducibly.  The hot-path test checks the serving
wrappers actually consume the tuned plan (and stay numerically correct).
"""

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import autotune as at
from repro.kernels.ops import quantized_matmul
from repro.kernels.ref import mxint_matmul_lowrank_ref
from repro.quant.mxint import mxint_quantize, pack_mantissa


@pytest.fixture()
def cache(tmp_path, monkeypatch):
    monkeypatch.setenv(at.ENV_CACHE_DIR, str(tmp_path))
    at.reset()
    yield tmp_path
    at.reset()


def test_candidate_enumeration_deterministic():
    a = at.candidate_plans(8, 128, 128, block_size=32, epb=2)
    b = at.candidate_plans(8, 128, 128, block_size=32, epb=2)
    assert a == b and a
    assert len(set(a)) == len(a)          # deduped
    # every candidate is a legal pick_blocks outcome at its own caps
    from repro.kernels.ops import pick_blocks
    for bm, bn, bk, decode in a:
        got = pick_blocks(8, 128, 128, block_size=32, epb=2,
                          block_m=bm, block_n=bn, block_k=bk)
        assert got == (bm, bn, bk, decode)


def test_autotune_miss_then_hit(cache):
    kw = dict(bits=4, block_size=32, rank=8, reps=1, backend="interpret")
    e1, hit1 = at.autotune(8, 64, 64, **kw)
    e2, hit2 = at.autotune(8, 64, 64, **kw)
    assert (hit1, hit2) == (False, True)
    assert (e1["bm"], e1["bn"], e1["bk"], e1["decode"]) == \
        (e2["bm"], e2["bn"], e2["bk"], e2["decode"])
    # persisted under the env-pointed dir with a stable key
    path = cache / "interpret.json"
    assert path.exists()
    store = json.loads(path.read_text())
    key = at.plan_key(8, 64, 64, bits=4, block_size=32, epb=2)
    assert key in store
    assert store[key]["candidates"] == e1["candidates"]
    # lookup is the zero-cost read of the same entry
    got = at.lookup(8, 64, 64, bits=4, block_size=32, epb=2,
                    backend="interpret")
    assert got == (e1["bm"], e1["bn"], e1["bk"], e1["decode"])
    # unknown geometry -> None (callers fall back to pick_blocks)
    assert at.lookup(8, 96, 64, bits=4, block_size=32, epb=2,
                     backend="interpret") is None


def test_tuned_hot_path_matches_reference(cache):
    """quantized_matmul consults the cache at default caps; the tuned plan
    must produce the same math as the reference."""
    m, k, n, r = 8, 64, 64, 8
    at.autotune(m, k, n, bits=4, block_size=32, rank=r, reps=1,
                backend="interpret")
    keys = jax.random.split(jax.random.PRNGKey(0), 4)
    x = jax.random.normal(keys[0], (m, k), jnp.float32)
    w = jax.random.normal(keys[1], (k, n), jnp.float32) * 0.1
    a = jax.random.normal(keys[2], (k, r), jnp.float32) * 0.05
    b = jax.random.normal(keys[3], (r, n), jnp.float32) * 0.05
    mant, exp = mxint_quantize(w, 4, 32)
    mant = pack_mantissa(mant.reshape(k, n), 4)
    out = quantized_matmul(x, mant, exp, a, b, bits=4, block_size=32,
                           interpret=True)
    ref = mxint_matmul_lowrank_ref(x, mant, exp, a, b, 4, 32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


def test_explicit_caps_bypass_cache(cache):
    """Explicit block caps are the caller's choice — the cache must not
    override them (this is also what keeps autotune's own measurement
    loop from consulting the cache it is building)."""
    m, k, n, r = 8, 64, 64, 8
    at.autotune(m, k, n, bits=4, block_size=32, rank=r, reps=1,
                backend="interpret")
    from repro.kernels.ops import _block_plan
    tuned = _block_plan(m, k, n, bits=4, block_size=32, epb=2,
                        block_m=128, block_n=128, block_k=128)
    assert tuned[:3] == at.lookup(m, k, n, bits=4, block_size=32, epb=2,
                                  backend="interpret")[:3]
    pinned = _block_plan(m, k, n, bits=4, block_size=32, epb=2,
                         block_m=32, block_n=64, block_k=64)
    from repro.kernels.ops import pick_blocks
    assert pinned == pick_blocks(m, k, n, block_size=32, epb=2,
                                 block_m=32, block_n=64, block_k=64)


def test_plan_shapes_for_params(cache):
    """A packed serving tree yields its decode launch geometries."""
    from repro.core import PTQConfig, quantize_params
    from repro.core.api import pack_for_serving
    from repro.models import ModelConfig, init_params
    cfg = ModelConfig(family="dense", num_layers=2, d_model=64, num_heads=4,
                      num_kv_heads=2, d_ff=128, vocab_size=64, head_dim=16)
    qcfg = PTQConfig(method="loftq", rank=8, quantizer="mxint4",
                     skip_patterns=PTQConfig().skip_patterns)
    packed = pack_for_serving(quantize_params(init_params(
        cfg, jax.random.PRNGKey(0)), qcfg), qcfg)
    shapes = at.plan_shapes_for_params(packed, m=8)
    assert shapes
    assert all(s[0] == 8 and s[3] == 4 and s[4] == 32 for s in shapes)
    ks = {(s[1], s[2]) for s in shapes}
    assert (64, 128) in ks or (128, 64) in ks
