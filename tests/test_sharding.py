"""Sharding tests.

Rule-level tests run in-process (pure PartitionSpec logic); the dry-run
integration tests spawn SUBPROCESSES with a forced 8-device host platform so
the main test session keeps seeing 1 device (per the project's XLA_FLAGS
isolation rule)."""

import json
import os
import subprocess
import sys

import pytest
from jax.sharding import PartitionSpec as P

from repro.sharding.rules import (
    _base_spec,
    batch_axes,
    param_spec,
)


# ---------------------------------------------------------------------------
# pure rule logic
# ---------------------------------------------------------------------------

class _FakeLeaf:
    def __init__(self, *shape):
        self.shape = shape
        self.ndim = len(shape)


def test_param_spec_dense_blocks():
    assert param_spec("blocks/wq", _FakeLeaf(4, 64, 64)) == \
        P(None, "data", "model")
    assert param_spec("blocks/wo", _FakeLeaf(4, 64, 64)) == \
        P(None, "model", "data")
    assert param_spec("blocks/norm_attn", _FakeLeaf(4, 64)) == P(None, None)
    assert param_spec("embed/tok", _FakeLeaf(128, 64)) == P("model", "data")
    assert param_spec("lm_head", _FakeLeaf(64, 128)) == P("data", "model")


def test_param_spec_moe_experts():
    assert param_spec("blocks/wg", _FakeLeaf(2, 8, 64, 128)) == \
        P(None, "model", "data", None)
    assert param_spec("blocks/wd", _FakeLeaf(2, 8, 128, 64)) == \
        P(None, "model", None, "data")
    assert param_spec("blocks/router", _FakeLeaf(2, 64, 8)) == \
        P(None, None, None)


def test_param_spec_quantized_leaves():
    assert param_spec("blocks/wq/w_tilde", _FakeLeaf(4, 64, 64)) == \
        P(None, "data", "model")
    assert param_spec("blocks/wq/lora_a", _FakeLeaf(4, 64, 8)) == \
        P(None, "data", None)
    assert param_spec("blocks/wq/lora_b", _FakeLeaf(4, 8, 64)) == \
        P(None, None, "model")


def test_param_spec_mamba_rwkv():
    assert param_spec("blocks/w_z", _FakeLeaf(2, 64, 128)) == \
        P(None, "data", "model")
    assert param_spec("blocks/w_b", _FakeLeaf(2, 64, 16)) == \
        P(None, "data", None)
    assert param_spec("blocks/decay_a", _FakeLeaf(2, 64, 8)) == \
        P(None, "data", None)
    assert param_spec("blocks/mu_r", _FakeLeaf(2, 64)) == P(None, None)
    assert param_spec("blocks/out_proj", _FakeLeaf(2, 128, 64)) == \
        P(None, "model", "data")


# ---------------------------------------------------------------------------
# dry-run integration (subprocess, 8 forced devices)
# ---------------------------------------------------------------------------

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_dryrun_cell(arch: str, shape: str, mesh: str):
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"),
               REPRO_DRYRUN_DEVICES="8")
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
         "--shape", shape, "--mesh", mesh, "--reduced", "--skip-costs",
         "--out", "/tmp/test_dryrun"],
        capture_output=True, text=True, env=env, cwd=REPO, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    fn = f"/tmp/test_dryrun/{arch}__{shape}__{mesh}.json"
    return json.loads(open(fn).read())


_ALLREDUCE_MODEL_SCRIPT = r"""
import json
from repro.launch.dryrun import collective_bytes, tp_allreduce_model
from repro.models.config import ModelConfig

cfg = ModelConfig(family="dense", num_layers=2, d_model=64, num_heads=4,
                  num_kv_heads=2, d_ff=128, vocab_size=64, head_dim=16)
# Synthetic post-SPMD decode HLO: exactly 2 psums/layer x 2 layers on the
# full (B=4, 1, d_model=64) f32 partial — what sharding/serving.py emits.
hlo = "\n".join(
    f"  %ar.{i} = f32[4,1,64]{{2,1,0}} all-reduce(f32[4,1,64]{{2,1,0}} %p.{i}),"
    " replica_groups={{0,1}}, to_apply=%add" for i in range(4))
meas = collective_bytes(hlo)
out = {"measured": meas["all-reduce"], "count": meas["counts"]["all-reduce"],
       "pred": {tp: tp_allreduce_model(cfg, batch=4, seq=1, tp=tp)
                for tp in (1, 2, 4)}}
print(json.dumps(out))
"""


def test_tp_allreduce_model_matches_hlo_convention():
    """Regression: the analytic model must count bytes in the SAME
    convention as ``collective_bytes`` (full payload doubled, tp-agnostic).
    PR 7 shipped it with the physical ring fraction instead, predicting
    half the measured bytes at tp=2 (ratio 0.5).  Runs in a subprocess
    because importing ``repro.launch.dryrun`` forces the host device
    count (XLA-flags isolation rule)."""
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"),
               REPRO_DRYRUN_DEVICES="1", JAX_PLATFORMS="cpu")
    out = subprocess.run([sys.executable, "-c", _ALLREDUCE_MODEL_SCRIPT],
                         capture_output=True, text=True, env=env, cwd=REPO,
                         timeout=300)
    assert out.returncode == 0, out.stderr[-2000:]
    d = json.loads(out.stdout.strip().splitlines()[-1])
    payload = 4 * 1 * 64 * 4                      # (B, 1, d_model) f32
    assert d["count"] == 4
    assert d["measured"] == 4 * 2.0 * payload     # ring-doubled full shape
    for tp in ("2", "4"):
        pred = d["pred"][tp]
        assert pred["per_device_bytes"] == d["measured"]      # ratio 1.0
        assert pred["allreduce_count"] == d["count"]
        # the physical wire estimate keeps the ring fraction and feeds
        # predicted_s — it is NOT the HLO-comparable number
        frac = 2.0 * (int(tp) - 1) / int(tp)
        assert pred["ring_bytes"] == pytest.approx(
            4 * frac * payload)
    assert d["pred"]["1"]["per_device_bytes"] == 0.0


@pytest.mark.slow
@pytest.mark.parametrize("arch,shape", [
    ("yi-34b", "train_4k"),              # dense train
    ("phi3.5-moe-42b-a6.6b", "decode_32k"),   # MoE decode (EP + cache)
    ("zamba2-7b", "prefill_32k"),        # hybrid prefill (ssm + shared attn)
    ("rwkv6-7b", "long_500k"),           # linear-attn long decode
])
def test_dryrun_reduced_cells_compile(arch, shape):
    d = _run_dryrun_cell(arch, shape, "tiny")
    assert d["full"]["memory"]["temp_bytes"] >= 0
    assert d["devices"] == 4


@pytest.mark.slow
def test_dryrun_multipod_mesh():
    d = _run_dryrun_cell("minicpm-2b", "train_4k", "tiny_pod")
    assert d["devices"] == 8        # 2 x 2 x 2 — the 'pod' axis shards


def test_batch_axes_divisibility():
    class FakeMesh:
        axis_names = ("pod", "data", "model")
        shape = {"pod": 2, "data": 16, "model": 16}
    assert batch_axes(FakeMesh(), 256) == ("pod", "data")
    assert batch_axes(FakeMesh(), 2) == ("pod",)
    assert batch_axes(FakeMesh(), 1) == ()
    assert batch_axes(FakeMesh(), 33) == ()
