"""Sublane-aligned KV-head tiles for GQA decode attention.

``pick_kv_block`` groups KV heads per grid step so the q-tile row count
(``hb * g``) is 8-sublane aligned whenever a divisor of ``hkv`` allows it;
the kernel zero-pads the rows explicitly otherwise.  Covered here: the
chooser's arithmetic, kernel-vs-oracle numerics across every alignment
regime (grouped, already-aligned, padded), and the acceptance bar — the
static auditor reports ZERO decode-attention sublane warnings for the
documented GQA offenders (command-r-plus, phi3.5-moe, llama4-maverick)
across the full tp sweep.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.kernels.decode_attention import pick_kv_block
from repro.kernels.ops import decode_attention
from repro.kernels.ref import decode_attention_ref

GQA_OFFENDERS = ("command-r-plus-104b", "phi3.5-moe-42b-a6.6b",
                 "llama4-maverick-400b-a17b")


def test_pick_kv_block_arithmetic():
    assert pick_kv_block(8, 8) == 1       # aligned g: one KV head per step
    assert pick_kv_block(2, 16) == 1
    assert pick_kv_block(8, 12) == 2      # command-r-plus: 2*12 = 24 rows
    assert pick_kv_block(4, 4) == 2       # phi3.5-moe: 2*4 = 8 rows
    assert pick_kv_block(8, 5) == 8       # llama4-maverick: 8*5 = 40 rows
    assert pick_kv_block(3, 2) == 1       # no divisor aligns -> pad path
    assert pick_kv_block(1, 12) == 1      # tp-sharded to one KV head
    # the chosen tile always divides hkv
    for hkv in (1, 2, 3, 4, 5, 8, 12):
        for g in (1, 2, 4, 5, 7, 8, 12):
            hb = pick_kv_block(hkv, g)
            assert hkv % hb == 0
            # alignment achieved whenever ANY divisor could achieve it
            aligned = any(hkv % d == 0 and (d * g) % 8 == 0
                          for d in range(1, hkv + 1))
            assert ((hb * g) % 8 == 0) == aligned or hb == 1


@pytest.mark.parametrize("hkv,g", [
    (8, 12),   # grouped: hb=2, 24 rows, no pad
    (4, 4),    # grouped: hb=2, exactly 8 rows
    (8, 5),    # grouped: hb=8, 40 rows
    (3, 2),    # unalignable: hb=1, 2 rows padded to 8
    (2, 8),    # already aligned: hb=1, no pad
])
def test_gqa_kernel_vs_oracle(hkv, g):
    b, d, ps, npg, ptot = 3, 16, 8, 4, 16
    h = hkv * g
    ks = jax.random.split(jax.random.PRNGKey(hkv * 31 + g), 3)
    q = jax.random.normal(ks[0], (b, h, d), jnp.float32)
    kp = jax.random.normal(ks[1], (ptot, hkv, ps, d), jnp.float32)
    vp = jax.random.normal(ks[2], (ptot, hkv, ps, d), jnp.float32)
    pt = jnp.asarray(np.random.RandomState(0).choice(
        np.arange(1, ptot), (b, npg), replace=False).astype(np.int32))
    kv_len = jnp.asarray([5, 17, 32], jnp.int32)
    got = decode_attention(q, kp, vp, pt, kv_len, interpret=True)
    want = decode_attention_ref(q, kp, vp, pt, kv_len)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("arch", GQA_OFFENDERS)
def test_no_gqa_sublane_warnings(arch):
    """The documented GQA sublane-waste warnings are gone by construction:
    the auditor mirrors pick_kv_block and checks the launched (grouped,
    padded) geometry."""
    from repro.analysis.contracts import audit_arch
    cfg = get_arch(arch)
    for tp in (1, 2, 4, 8):
        found = audit_arch(cfg, bits=4, block_size=32, tp=tp, backend="tpu")
        if found is None:
            continue                      # clean validate_tp refusal
        bad = [v for v in found if "decode_attention" in v.message
               or "decode_attention" in v.where]
        assert not bad, [str(v) for v in bad]
