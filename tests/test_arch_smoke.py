"""Per-architecture smoke tests: REDUCED same-family config, one forward +
one train step on CPU, asserting output shapes and no NaNs.  The FULL configs
are exercised only via the dry-run (ShapeDtypeStruct, no allocation)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS, get_arch, shapes_for
from repro.models import forward, init_params, lm_loss
from repro.models.config import reduced


def _smoke_batch(cfg, key, batch=2, seq=16):
    if cfg.family == "audio":
        toks = jax.random.randint(key, (batch, cfg.num_codebooks, seq + 1),
                                  0, cfg.vocab_size)
        b = {"tokens": toks[..., :-1], "labels": toks[..., 1:]}
    else:
        toks = jax.random.randint(key, (batch, seq + 1), 0, cfg.vocab_size)
        b = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
    if cfg.family == "vlm":
        b["image_embeds"] = 0.1 * jax.random.normal(
            jax.random.PRNGKey(9), (batch, cfg.vision_seq, cfg.d_model))
    return b


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_arch_full_config_fields(arch):
    cfg = get_arch(arch).validate()
    assert cfg.name == arch
    assert cfg.param_count() > 1e8          # all assigned archs are >= ~1B
    shapes = shapes_for(arch)
    names = {s.name for s in shapes}
    assert {"train_4k", "prefill_32k", "decode_32k"} <= names
    assert ("long_500k" in names) == cfg.is_subquadratic


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_arch_reduced_smoke_forward_and_train(arch):
    cfg = reduced(get_arch(arch))
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = _smoke_batch(cfg, jax.random.PRNGKey(1))

    logits, aux, _ = forward(params, batch, cfg)
    if cfg.family == "audio":
        assert logits.shape == (2, cfg.num_codebooks, 16, cfg.vocab_size)
    else:
        assert logits.shape == (2, 16, cfg.vocab_size)
    assert np.all(np.isfinite(np.asarray(logits))), arch

    (loss, _), grads = jax.value_and_grad(lm_loss, has_aux=True)(
        params, batch, cfg)
    assert np.isfinite(float(loss)), arch
    for leaf in jax.tree.leaves(grads):
        assert np.all(np.isfinite(np.asarray(leaf))), arch


def test_registry_counts():
    from repro.configs import dryrun_cells
    assert len(ASSIGNED_ARCHS) == 10
    cells = dryrun_cells()
    # 8 full-attention archs x 3 shapes + 2 subquadratic x 4 shapes = 32
    assert len(cells) == 32
    all_cells = dryrun_cells(include_skipped=True)
    assert len(all_cells) == 40
    assert sum(1 for *_, run in all_cells if not run) == 8


def test_paper_model_configs_load():
    for name in ["roberta-base", "tinyllama-1.1b", "llama-2-7b"]:
        cfg = get_arch(name)
        assert cfg.validate() is cfg


@pytest.mark.parametrize("arch,expected_b", [
    ("yi-34b", 34e9), ("command-r-plus-104b", 104e9),
    ("phi3-mini-3.8b", 3.8e9), ("minicpm-2b", 2.4e9),
])
def test_param_counts_match_names(arch, expected_b):
    got = get_arch(arch).param_count()
    assert 0.55 * expected_b < got < 1.6 * expected_b, (arch, got, expected_b)


def test_moe_active_param_counts():
    # a17b / a6.6b names refer to ACTIVE params (top-k experts per token).
    mav = get_arch("llama4-maverick-400b-a17b")
    assert 10e9 < mav.active_param_count() < 25e9
    phi = get_arch("phi3.5-moe-42b-a6.6b")
    assert 4e9 < phi.active_param_count() < 10e9
    assert 30e9 < phi.param_count() < 55e9
