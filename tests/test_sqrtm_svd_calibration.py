"""Tests for the numerical substrate: PSD sqrt, randomized SVD, calibration."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    StreamingStats,
    batch_stats,
    psd_sqrt_eigh,
    psd_sqrt_newton_schulz,
    randomized_svd,
    stats_from_samples,
    truncated_svd,
)

pytest.importorskip("hypothesis")  # property tests skip without hypothesis
from hypothesis import given, settings, strategies as st  # noqa: E402


def _random_psd(seed, n=16, cond=1e3):
    key = jax.random.PRNGKey(seed)
    q, _ = jnp.linalg.qr(jax.random.normal(key, (n, n)))
    eigs = jnp.logspace(0, np.log10(cond), n)
    return (q * eigs) @ q.T


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 1000))
def test_psd_sqrt_eigh_property(seed):
    r = _random_psd(seed)
    s, si = psd_sqrt_eigh(r)
    np.testing.assert_allclose(np.asarray(s @ s), np.asarray(r), rtol=2e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(s @ si), np.eye(r.shape[0]),
                               rtol=1e-2, atol=2e-3)


def test_newton_schulz_matches_eigh():
    r = _random_psd(0, n=24, cond=100.0)
    s_e, si_e = psd_sqrt_eigh(r)
    s_n, si_n = psd_sqrt_newton_schulz(r, num_iters=40)
    np.testing.assert_allclose(np.asarray(s_n), np.asarray(s_e), rtol=5e-3, atol=5e-3)
    np.testing.assert_allclose(np.asarray(si_n), np.asarray(si_e), rtol=5e-2, atol=5e-3)


def test_newton_schulz_high_condition_converges():
    r = _random_psd(1, n=16, cond=1e4)
    s_n, _ = psd_sqrt_newton_schulz(r, num_iters=60)
    np.testing.assert_allclose(np.asarray(s_n @ s_n), np.asarray(r),
                               rtol=5e-2, atol=5e-1)


def test_truncated_svd_matches_numpy():
    a = jax.random.normal(jax.random.PRNGKey(2), (32, 20))
    u, s, vt = truncated_svd(a, 5)
    un, sn, vtn = np.linalg.svd(np.asarray(a), full_matrices=False)
    np.testing.assert_allclose(np.asarray(s), sn[:5], rtol=1e-4)
    np.testing.assert_allclose(np.asarray(u * s) @ np.asarray(vt),
                               (un[:, :5] * sn[:5]) @ vtn[:5], rtol=1e-3, atol=1e-4)


def test_randomized_svd_close_to_exact():
    # low effective rank matrix => rSVD nearly exact
    key = jax.random.PRNGKey(3)
    u = jax.random.normal(key, (64, 8))
    v = jax.random.normal(jax.random.PRNGKey(4), (8, 48))
    a = u @ v + 0.01 * jax.random.normal(jax.random.PRNGKey(5), (64, 48))
    ue, se, vte = truncated_svd(a, 8)
    ur, sr, vtr = randomized_svd(a, 8, key=jax.random.PRNGKey(6))
    np.testing.assert_allclose(np.asarray(sr), np.asarray(se), rtol=1e-2)
    err_e = np.linalg.norm(np.asarray(a) - np.asarray((ue * se) @ vte))
    err_r = np.linalg.norm(np.asarray(a) - np.asarray((ur * sr) @ vtr))
    assert err_r <= err_e * 1.1 + 1e-5


def test_streaming_equals_batch_stats():
    x = jax.random.normal(jax.random.PRNGKey(7), (1000, 12)) * 3.0
    full = stats_from_samples(x)
    acc = StreamingStats(dim=12)
    for chunk in jnp.split(x, 10):
        acc.update(chunk)
    np.testing.assert_allclose(np.asarray(acc.rxx), np.asarray(full.rxx),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(acc.mean_x2, np.asarray(full.mean_x2), rtol=1e-5)
    np.testing.assert_allclose(acc.mean_abs, np.asarray(full.mean_abs), rtol=1e-5)
    assert acc.count == 1000


def test_streaming_merge():
    x = jax.random.normal(jax.random.PRNGKey(8), (256, 8))
    a, b = StreamingStats(dim=8), StreamingStats(dim=8)
    a.update(x[:100])
    b.update(x[100:])
    a.merge(b)
    ref = stats_from_samples(x)
    np.testing.assert_allclose(np.asarray(a.rxx), np.asarray(ref.rxx),
                               rtol=1e-5, atol=1e-6)


def test_batch_stats_flattens_leading_dims():
    x = jax.random.normal(jax.random.PRNGKey(9), (4, 16, 8))
    s3 = batch_stats(x)
    s2 = batch_stats(x.reshape(-1, 8))
    np.testing.assert_allclose(np.asarray(s3["sum_xx"]), np.asarray(s2["sum_xx"]),
                               rtol=1e-6)
    assert float(s3["count"]) == 64


def test_rxx_psd_and_symmetric():
    x = jax.random.normal(jax.random.PRNGKey(10), (512, 10))
    st_ = stats_from_samples(x)
    r = np.asarray(st_.rxx)
    np.testing.assert_allclose(r, r.T, atol=1e-7)
    eigs = np.linalg.eigvalsh(r)
    assert eigs.min() >= -1e-5
