"""Serving tests: prefill+decode == full forward for every cache family,
greedy generation, continuous batching."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import ModelConfig, forward, init_params
from repro.serve.batching import ContinuousBatcher, Request, make_place_slot
from repro.serve.engine import (
    greedy_generate,
    greedy_generate_loop,
    init_cache,
    scan_generate,
)

CFGS = {
    "dense": ModelConfig(family="dense", num_layers=2, d_model=32, num_heads=4,
                         num_kv_heads=2, d_ff=64, vocab_size=64, head_dim=8),
    # capacity_factor high enough that no tokens drop: capacity-MoE output
    # is otherwise (by construction) a function of the total token count.
    "moe": ModelConfig(family="moe", num_layers=2, d_model=32, num_heads=4,
                       num_kv_heads=4, d_ff=48, vocab_size=64, head_dim=8,
                       num_experts=4, moe_top_k=2, capacity_factor=16.0),
    "hybrid_mamba": ModelConfig(family="hybrid_mamba", num_layers=4,
                                d_model=32, num_heads=4, num_kv_heads=4,
                                head_dim=8, d_ff=64, vocab_size=64,
                                ssm_state=8, ssm_head_dim=8, ssm_chunk=4,
                                attn_every=2),
    "rwkv": ModelConfig(family="rwkv", num_layers=2, d_model=32, num_heads=4,
                        num_kv_heads=4, d_ff=64, vocab_size=64,
                        rwkv_head_dim=8, rwkv_decay_lora=4, rwkv_chunk=4),
}


@pytest.mark.parametrize("family", list(CFGS))
def test_prefill_then_decode_matches_full_forward(family):
    cfg = CFGS[family]
    params = init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0, cfg.vocab_size)
    logits_full, _, _ = forward(params, {"tokens": toks}, cfg)

    cache = init_cache(cfg, 2, 16)
    lp, _, cache = forward(params, {"tokens": toks[:, :8]}, cfg, cache=cache,
                           cache_len=jnp.asarray(0, jnp.int32))
    np.testing.assert_allclose(np.asarray(lp), np.asarray(logits_full[:, :8]),
                               rtol=3e-3, atol=3e-3)
    for t in range(8, 12):
        lt, _, cache = forward(params, {"tokens": toks[:, t:t + 1]}, cfg,
                               cache=cache, cache_len=jnp.asarray(t, jnp.int32))
        np.testing.assert_allclose(
            np.asarray(lt[:, 0]), np.asarray(logits_full[:, t]),
            rtol=3e-3, atol=3e-3, err_msg=f"{family} step {t}")


def test_greedy_generate_matches_argmax_rollout():
    cfg = CFGS["dense"]
    params = init_params(cfg, jax.random.PRNGKey(0))
    prompt = jax.random.randint(jax.random.PRNGKey(2), (1, 6), 0, 64)
    gen = greedy_generate(params, cfg, prompt, steps=5)
    assert gen.shape == (1, 5)
    # reference: full re-forward argmax rollout
    cur = prompt
    for t in range(5):
        logits, _, _ = forward(params, {"tokens": cur}, cfg)
        nxt = jnp.argmax(logits[:, -1], -1)[:, None]
        assert int(nxt[0, 0]) == int(gen[0, t]), t
        cur = jnp.concatenate([cur, nxt.astype(cur.dtype)], axis=1)


@pytest.mark.parametrize("family", ["dense", "rwkv"])
def test_scan_generate_matches_loop(family):
    """The one-compile lax.scan rollout must be token-for-token identical to
    the python-loop reference (same cached forward, different orchestration)."""
    cfg = CFGS[family]
    params = init_params(cfg, jax.random.PRNGKey(0))
    prompt = jax.random.randint(jax.random.PRNGKey(3), (2, 5), 0,
                                cfg.vocab_size)
    fast = scan_generate(params, cfg, prompt, steps=6)
    ref = greedy_generate_loop(params, cfg, prompt, steps=6)
    np.testing.assert_array_equal(np.asarray(fast), np.asarray(ref))


def test_scan_generate_eos_masking():
    """Once a row emits eos every later token is masked to eos on device."""
    cfg = CFGS["dense"]
    params = init_params(cfg, jax.random.PRNGKey(0))
    prompt = jax.random.randint(jax.random.PRNGKey(4), (1, 4), 0, 64)
    free = np.asarray(scan_generate(params, cfg, prompt, steps=6))
    eos = int(free[0, 2])                 # force an eos hit mid-rollout
    gen = np.asarray(scan_generate(params, cfg, prompt, steps=6, eos_id=eos))
    hit = int(np.argmax(gen[0] == eos))
    assert gen[0, hit] == eos
    np.testing.assert_array_equal(gen[0, hit:], np.full(6 - hit, eos))
    np.testing.assert_array_equal(gen[0, :hit], free[0, :hit])


@pytest.mark.parametrize("family", ["dense", "hybrid_mamba"])
def test_place_slot_matches_reference(family):
    """The jitted slot write must equal a host-side per-leaf placement for
    every cache leaf family (batch axis position differs per leaf)."""
    cfg = CFGS[family]
    num_slots = 3
    big = init_cache(cfg, num_slots, 16)
    small = init_cache(cfg, 1, 16)
    leaves, treedef = jax.tree.flatten(small)
    keys = jax.random.split(jax.random.PRNGKey(5), len(leaves))
    small = jax.tree.unflatten(treedef, [
        jax.random.normal(k, l.shape).astype(l.dtype)
        for k, l in zip(keys, leaves)])

    slot = 1
    got = jax.jit(make_place_slot(num_slots))(big, small,
                                              jnp.asarray(slot, jnp.int32))

    def ref_place(bg, sm):
        for ax in range(bg.ndim):
            if bg.shape[ax] == num_slots and sm.shape[ax] == 1:
                out = np.array(bg)
                idx = [slice(None)] * bg.ndim
                idx[ax] = slice(slot, slot + 1)
                out[tuple(idx)] = np.asarray(sm).astype(out.dtype)
                return out
        raise ValueError("no batch axis")

    want = jax.tree.map(ref_place, big, small)
    for g, w in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
        np.testing.assert_array_equal(np.asarray(g), w)


def test_continuous_batching_matches_single_stream():
    cfg = CFGS["dense"]
    params = init_params(cfg, jax.random.PRNGKey(0))
    prompts = [np.asarray([1, 2, 3, 4], np.int32),
               np.asarray([9, 8, 7], np.int32),
               np.asarray([5, 5], np.int32)]
    # reference: independent greedy rollouts
    refs = []
    for p in prompts:
        g = greedy_generate(params, cfg, jnp.asarray(p)[None], steps=4,
                            max_len=32)
        refs.append(np.asarray(g[0]))

    batcher = ContinuousBatcher(params, cfg, num_slots=2, max_len=32)
    reqs = [Request(rid=i, prompt=p, max_new_tokens=4)
            for i, p in enumerate(prompts)]
    for r in reqs:
        batcher.submit(r)
    batcher.run(max_ticks=50)
    for r, ref in zip(reqs, refs):
        assert r.done
        np.testing.assert_array_equal(np.asarray(r.output), ref,
                                      err_msg=f"req {r.rid}")
