"""Tensor-parallel serving tests.

Fast tests run in-process on the single default device (pure spec/role
logic, pick_blocks shard-shape regression, mesh construction errors,
pack_for_serving).  Everything that needs a real multi-device mesh runs in
SUBPROCESSES via ``tests/_tp_worker.py`` with a forced 8-device host
platform, keeping the main pytest session at 1 device (the repo's XLA-flags
isolation rule).  The worker modes cover the acceptance bars:

* tp in {2, 4} token-identical to the single-device batcher across dense,
  paged, paged+prefix-cache, and the fused ``scan_generate`` rollout;
* the PR 6 fault storm (spikes + NaN ticks + crash recovery) identical at
  tp=2;
* shard-aware snapshot round-trip + loud tp-mismatch rejection;
* exactly one all-reduce per projection pair (2 psums per layer) in the
  decode jaxpr, and the sharded fused kernel matching the single-device
  kernel in both parallel roles.
"""

import json
import math
import os
import subprocess
import sys

import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ASSIGNED_ARCHS, get_arch
from repro.kernels.ops import pick_blocks
# the divisibility checkers are re-exported by the analyzer — import them
# from there so the test exercises the same entry point CI audits with
from repro.analysis import packed_shard_granule, validate_packed_sharding
from repro.quant.mxint import MXINT_CONFIGS, elems_per_byte
from repro.sharding.serving import (serving_param_spec, tp_local_cfg, tp_role,
                                    validate_tp)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ---------------------------------------------------------------------------
# roles and specs (pure logic)
# ---------------------------------------------------------------------------

class _FakeLeaf:
    def __init__(self, *shape):
        self.shape = shape
        self.ndim = len(shape)


def test_tp_role_contract():
    assert tp_role("blocks/wq") == "column"
    assert tp_role("blocks/wi") == "column"
    assert tp_role("blocks/wo") == "row"
    assert tp_role("blocks/wd") == "row"
    assert tp_role("blocks/norm_attn") == "replicated"
    assert tp_role("embed/tok") == "replicated"
    # quant suffixes see their parent projection
    assert tp_role("blocks/wq/mant") == "column"
    assert tp_role("blocks/wo/lora_a") == "row"
    assert tp_role("blocks/wd/w_tilde") == "row"


def test_serving_param_spec_roles():
    # column: wide axis LAST sharded; lora_a replicated, lora_b sharded
    assert serving_param_spec("blocks/wq", _FakeLeaf(4, 64, 64)) == \
        P(None, None, "model")
    assert serving_param_spec("blocks/wq/mant", _FakeLeaf(4, 32, 64)) == \
        P(None, None, "model")
    assert serving_param_spec("blocks/wq/lora_a", _FakeLeaf(4, 64, 8)) == \
        P(None, None, None)
    assert serving_param_spec("blocks/wq/lora_b", _FakeLeaf(4, 8, 64)) == \
        P(None, None, "model")
    # row: K axis sharded; lora_a sharded, lora_b replicated
    assert serving_param_spec("blocks/wo", _FakeLeaf(4, 64, 64)) == \
        P(None, "model", None)
    assert serving_param_spec("blocks/wo/mant", _FakeLeaf(4, 32, 64)) == \
        P(None, "model", None)
    assert serving_param_spec("blocks/wo/lora_a", _FakeLeaf(4, 64, 8)) == \
        P(None, "model", None)
    assert serving_param_spec("blocks/wo/lora_b", _FakeLeaf(4, 8, 64)) == \
        P(None, None, None)
    # replicated / scalar metadata
    assert serving_param_spec("blocks/wq/bits", _FakeLeaf()) == P()
    assert serving_param_spec("embed/tok", _FakeLeaf(128, 64)) == \
        P(None, None)


def test_validate_tp_errors():
    cfg = get_arch("yi-34b")
    validate_tp(cfg, 1)
    validate_tp(cfg, 2)
    with pytest.raises(ValueError, match="num_heads.*does not divide"):
        validate_tp(cfg, 3)
    with pytest.raises(ValueError, match="num_kv_heads"):
        validate_tp(cfg, 7)               # 56 heads divide, 8 kv heads don't
    rwkv = get_arch("rwkv6-7b")
    with pytest.raises(ValueError, match="dense family"):
        validate_tp(rwkv, 2)


def test_tp_local_cfg_pins_head_dim():
    cfg = get_arch("yi-34b")
    loc = tp_local_cfg(cfg, 4)
    assert loc.num_heads == cfg.num_heads // 4
    assert loc.num_kv_heads == cfg.num_kv_heads // 4
    assert loc.d_ff == cfg.d_ff // 4
    assert loc.hd == cfg.hd               # NOT re-derived from d_model
    assert loc.tp_size == 4 and loc.tp_axis == "model"
    assert tp_local_cfg(cfg, 1) is cfg


def test_validate_packed_sharding():
    # mxint4: epb=2, granule lcm(32, 16) = 32
    assert packed_shard_granule(4, 32) == 32
    assert validate_packed_sharding(128, 2, 4, 32) == 64
    with pytest.raises(ValueError, match="divide"):
        validate_packed_sharding(100, 3, 4, 32)
    with pytest.raises(ValueError, match="granule|multiple"):
        validate_packed_sharding(48, 2, 4, 32)   # 24 per shard < granule


# ---------------------------------------------------------------------------
# pick_blocks shard-shape regression: every registry config, tp in {2,4,8}
# ---------------------------------------------------------------------------

def _dense_proj_dims(cfg):
    """(K, N, sharded_axis) of every TP-sharded projection of a config."""
    d, hd = cfg.d_model, cfg.hd
    q, kv, f = cfg.num_heads * hd, cfg.num_kv_heads * hd, cfg.d_ff
    return [("wq", d, q, "n"), ("wk", d, kv, "n"), ("wv", d, kv, "n"),
            ("wo", q, d, "k"), ("wi", d, f, "n"), ("wg", d, f, "n"),
            ("wu", d, f, "n"), ("wd", f, d, "k")]


@pytest.mark.parametrize("arch", list(ASSIGNED_ARCHS))
@pytest.mark.parametrize("tp", [2, 4, 8])
def test_pick_blocks_on_shard_shapes(arch, tp):
    """Per-shard (M, K/tp or N/tp) shapes of every registry config must get
    VALID tiles from pick_blocks for every MXINT format — dividing tiles, no
    degenerate fallbacks, clean ValueError (never an XLA assert) when a
    shard cannot hold whole exponent blocks."""
    cfg = get_arch(arch)
    for spec in MXINT_CONFIGS.values():
        epb = elems_per_byte(spec.bits)
        for name, k, n, ax in _dense_proj_dims(cfg):
            k_loc = k // tp if ax == "k" and k % tp == 0 else k
            n_loc = n // tp if ax == "n" and n % tp == 0 else n
            try:
                bm, bn, bk, decode = pick_blocks(
                    8, k_loc, n_loc, block_size=spec.block_size, epb=epb)
            except ValueError as e:
                # only legitimate for K shards that cannot hold whole blocks
                assert k_loc % spec.block_size != 0, (arch, name, str(e))
                continue
            assert k_loc % bk == 0 and bk % spec.block_size == 0, \
                (arch, name, spec.bits, k_loc, bk)
            assert n_loc % bn == 0 and bn >= min(8, n_loc), \
                (arch, name, spec.bits, n_loc, bn)
            if epb > 1 and bk % math.lcm(spec.block_size, 8 * epb) == 0:
                assert (bk // epb) % 8 == 0   # packed tile stays 8-aligned


def test_pick_blocks_degenerate_k_raises():
    with pytest.raises(ValueError, match="block_size"):
        pick_blocks(8, 40, 64, block_size=32, epb=2)   # 40 % 32 != 0


def test_pick_blocks_narrow_n_no_one_wide_tiles():
    bm, bn, bk, _ = pick_blocks(8, 64, 7, block_size=32)   # prime narrow N
    assert bn == 7                         # whole-N single block, not bn=1


# ---------------------------------------------------------------------------
# mesh construction
# ---------------------------------------------------------------------------

def test_make_serving_mesh_errors():
    from repro.launch.mesh import make_serving_mesh
    with pytest.raises(ValueError, match="tp >= 1"):
        make_serving_mesh(0)
    with pytest.raises(ValueError, match="xla_force_host_platform"):
        make_serving_mesh(64)              # actionable, not an XLA assert
    mesh = make_serving_mesh(1)
    assert mesh.axis_names == ("model",) and mesh.shape["model"] == 1


def test_env_configure_flags():
    from repro.launch.env import set_host_device_count
    old = os.environ.get("XLA_FLAGS")
    try:
        os.environ["XLA_FLAGS"] = "--xla_dump_to=/tmp/d " \
            "--xla_force_host_platform_device_count=2"
        set_host_device_count(8)
        flags = os.environ["XLA_FLAGS"].split()
        assert "--xla_force_host_platform_device_count=8" in flags
        assert "--xla_dump_to=/tmp/d" in flags
        assert "--xla_force_host_platform_device_count=2" not in flags
        with pytest.raises(ValueError):
            set_host_device_count(0)
    finally:
        if old is None:
            os.environ.pop("XLA_FLAGS", None)
        else:
            os.environ["XLA_FLAGS"] = old


# ---------------------------------------------------------------------------
# pack_for_serving
# ---------------------------------------------------------------------------

def _tiny_qtree():
    import jax.numpy as jnp
    rng = np.random.default_rng(0)

    def qlin(k, n, r=4):
        return {"w_tilde": jnp.asarray(rng.normal(size=(k, n)), jnp.float32),
                "lora_a": jnp.asarray(rng.normal(size=(k, r)), jnp.float32),
                "lora_b": jnp.asarray(rng.normal(size=(r, n)), jnp.float32)}
    return {"blocks": {"wq": qlin(64, 64), "wo": qlin(64, 64)},
            "norm": jnp.ones((64,))}


def test_pack_for_serving_packed_false_all_leaves():
    """Regression: ``packed=False`` must stay in effect for EVERY quantized
    leaf (a loop variable used to shadow the flag after the first one)."""
    from repro.core.api import PTQConfig, pack_for_serving
    cfg = PTQConfig(quantizer="mxint4")
    out = pack_for_serving(_tiny_qtree(), cfg, packed=False)
    for name in ("wq", "wo"):
        g = out["blocks"][name]
        assert g["mant"].shape == (64, 64), name   # flat, not 32 packed rows
    packed = pack_for_serving(_tiny_qtree(), cfg, packed=True)
    for name in ("wq", "wo"):
        assert packed["blocks"][name]["mant"].shape == (32, 64), name


# ---------------------------------------------------------------------------
# multi-device integration (subprocess, 8 forced devices)
# ---------------------------------------------------------------------------

def _worker(mode: str) -> dict:
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"),
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               JAX_PLATFORMS="cpu")
    out = subprocess.run(
        [sys.executable, os.path.join(REPO, "tests", "_tp_worker.py"), mode],
        capture_output=True, text=True, env=env, cwd=REPO, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


@pytest.mark.slow
def test_tp_token_identity():
    res = _worker("identity")
    assert res == {k: True for k in res}, res


@pytest.mark.slow
def test_tp_fault_storm_identity():
    res = _worker("storm")
    assert res["storm_tp2"] and res["nonempty"], res


@pytest.mark.slow
def test_tp_snapshot_round_trip():
    res = _worker("snapshot")
    assert res["geometry_tp"] == 2
    assert res["mesh_spec"] == {"axis": "model", "tp": 2}
    assert res["stacked_leading_tp"], res
    assert res["replay_identical"], res
    assert res["mismatch_raises"] is True, res


@pytest.mark.slow
def test_tp_one_allreduce_per_projection_pair():
    res = _worker("psum")
    for scan in ("True", "False"):
        found, want, violations = res[f"psums_scan_{scan}"]
        assert found == want and not violations, res
    assert res["kernel_column_close"] and res["kernel_row_close"], res


@pytest.mark.slow
def test_tp_mixed_plan_identity():
    """A heterogeneous QuantPlan (per-leaf bits/rank) shards at tp=2 and
    stays token-identical to the single-device batcher on the same mixed
    packed tree (dense + paged); validate_plan_tp accepts the granules."""
    res = _worker("plan")
    assert res == {k: True for k in res}, res
