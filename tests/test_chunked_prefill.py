"""Chunked direct-to-page prefill tests.

Four layers of coverage: the Pallas paged prefill-attention kernel against
the gather-then-softmax oracle (non-aligned chunk widths and offsets,
poisoned dead pages), the chunk planning heuristic, chunk-vs-one-shot token
identity through the ContinuousBatcher across dense + hybrid_mamba + rwkv
families (non-aligned chunk/page/prompt lengths included), and the
mid-prefill pool-exhaustion path (partial pages rolled back, request
requeued, nothing leaked)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ops import chunk_plan, pick_prefill_chunk, prefill_attention
from repro.kernels.ref import prefill_attention_ref
from repro.models import ModelConfig, init_params
from repro.serve.batching import ContinuousBatcher, Request
from repro.serve.engine import greedy_generate_loop, scan_generate

CFGS = {
    "dense": ModelConfig(family="dense", num_layers=2, d_model=32, num_heads=4,
                         num_kv_heads=2, d_ff=64, vocab_size=64, head_dim=8),
    "hybrid_mamba": ModelConfig(family="hybrid_mamba", num_layers=4,
                                d_model=32, num_heads=4, num_kv_heads=4,
                                head_dim=8, d_ff=64, vocab_size=64,
                                ssm_state=8, ssm_head_dim=8, ssm_chunk=4,
                                attn_every=2),
    "rwkv": ModelConfig(family="rwkv", num_layers=2, d_model=32, num_heads=4,
                        num_kv_heads=4, d_ff=64, vocab_size=64,
                        rwkv_head_dim=8, rwkv_decay_lora=4, rwkv_chunk=4),
}

PROMPTS = [np.asarray([1, 2, 3, 4, 11, 9, 2, 5, 30, 7, 7, 2, 4], np.int32),
           np.asarray([9, 8, 7], np.int32),
           np.asarray([5, 5, 12, 1, 6, 19, 44, 3], np.int32),
           np.asarray([11, 3, 7, 7, 2], np.int32)]


# ---------------------------------------------------------------------------
# kernel vs oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("c,offs", [
    (8, (0, 16, 5)),      # aligned chunk; zero / page-aligned / mid-page off
    (6, (3, 0, 11)),      # non-8-multiple chunk (wrapper pads + crops)
    (1, (7, 2, 0)),       # single-token chunk (binary-plan tail)
    (13, (0, 9, 17)),     # chunk > page_size, crosses page boundaries
])
def test_prefill_attention_kernel_vs_ref(c, offs):
    b, h, hkv, d, ps, npg, ptot = 3, 4, 2, 16, 8, 4, 16
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, h, c, d), jnp.float32)
    kp = jax.random.normal(ks[1], (ptot, hkv, ps, d), jnp.float32)
    vp = jax.random.normal(ks[2], (ptot, hkv, ps, d), jnp.float32)
    # scrambled (non-identity) page table over distinct real pages
    pt = jnp.asarray(np.random.RandomState(0).choice(
        np.arange(1, ptot), (b, npg), replace=False).astype(np.int32))
    q_off = jnp.asarray(offs, jnp.int32)
    kv_len = q_off + c
    got = prefill_attention(q, kp, vp, pt, q_off, kv_len, interpret=True)
    want = prefill_attention_ref(q, kp, vp, pt, q_off, kv_len)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_prefill_attention_ignores_dead_pages():
    """Tokens past kv_len (page tails, pages above the chunk's extent, and
    garbage-page entries) must not contribute: poisoning them with huge
    values cannot change the output."""
    b, h, hkv, d, ps, npg, ptot = 2, 2, 2, 8, 4, 4, 12
    c = 3
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (b, h, c, d), jnp.float32)
    kp = jax.random.normal(ks[1], (ptot, hkv, ps, d), jnp.float32)
    vp = jax.random.normal(ks[2], (ptot, hkv, ps, d), jnp.float32)
    q_off = jnp.asarray([3, 0], jnp.int32)       # live: 6 resp. 3 tokens
    kv_len = q_off + c
    pt = jnp.asarray([[1, 2, 0, 0], [3, 0, 0, 0]], jnp.int32)
    base = prefill_attention(q, kp, vp, pt, q_off, kv_len, interpret=True)
    dead = [0] + list(range(4, ptot))            # garbage + unowned pages
    kp2 = kp.at[jnp.asarray(dead)].set(1e4)
    vp2 = vp.at[jnp.asarray(dead)].set(1e4)
    # poison the live pages' tails past kv_len too
    kp2 = kp2.at[2, :, 2:].set(-1e4).at[3, :, 3:].set(-1e4)
    vp2 = vp2.at[2, :, 2:].set(-1e4).at[3, :, 3:].set(-1e4)
    poisoned = prefill_attention(q, kp2, vp2, pt, q_off, kv_len,
                                 interpret=True)
    np.testing.assert_allclose(np.asarray(poisoned), np.asarray(base),
                               rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# chunk planning
# ---------------------------------------------------------------------------

def test_pick_prefill_chunk():
    assert pick_prefill_chunk(3) == 4                 # pow2 cover, 1 chunk
    assert pick_prefill_chunk(64) == 64
    assert pick_prefill_chunk(1000, max_chunk=64) == 64
    # trimmed to a page multiple once past one page
    assert pick_prefill_chunk(100, page_size=16, max_chunk=24) == 16
    # but never below one page's worth when the prompt is tiny
    assert pick_prefill_chunk(3, page_size=16, max_chunk=64) == 4
    assert pick_prefill_chunk(1) == 1


def test_chunk_plan_exact_and_logarithmic():
    for n in (1, 3, 8, 13, 100, 257):
        for c in (1, 4, 5, 64):
            plan = chunk_plan(n, c)
            assert sum(plan) == n                     # exact, no padding
            assert all(w <= c for w in plan)
            # distinct widths stay O(log c): full chunks + binary tail
            assert len(set(plan)) <= 1 + max(c.bit_length(), 1)
    assert chunk_plan(0, 4) == []
    assert chunk_plan(13, 4) == [4, 4, 4, 1]


# ---------------------------------------------------------------------------
# end-to-end: chunked admission == one-shot admission, token for token
# ---------------------------------------------------------------------------

def _run_batcher(params, cfg, *, steps=6, max_len=32, prompts=PROMPTS,
                 max_ticks=400, **kw):
    batcher = ContinuousBatcher(params, cfg, num_slots=2, max_len=max_len,
                                **kw)
    reqs = [Request(rid=i, prompt=p, max_new_tokens=steps)
            for i, p in enumerate(prompts)]
    for r in reqs:
        batcher.submit(r)
    batcher.run(max_ticks=max_ticks)
    assert all(r.done for r in reqs)
    return [r.output for r in reqs], batcher


@pytest.mark.parametrize("family", list(CFGS))
def test_chunked_matches_oneshot_dense_mode(family):
    """chunk_tokens large enough covers every prompt in ONE chunk (the
    one-shot reference); tiny budgets must stay token-identical — recurrent
    rows (mamba conv/ssm, rwkv state) thread across chunks through the
    scratch cache."""
    cfg = CFGS[family]
    params = init_params(cfg, jax.random.PRNGKey(0))
    oneshot, _ = _run_batcher(params, cfg, chunk_tokens=64)
    for budget in (3, 5):
        chunked, _ = _run_batcher(params, cfg, chunk_tokens=budget)
        assert chunked == oneshot, f"budget={budget}"


@pytest.mark.parametrize("family", ["dense", "hybrid_mamba"])
def test_chunked_matches_oneshot_paged_mode(family):
    """Direct-to-page chunked admission vs single-chunk admission vs the
    dense-mode batcher: all token-identical, pool fully drained after."""
    cfg = CFGS[family]
    params = init_params(cfg, jax.random.PRNGKey(0))
    dense, _ = _run_batcher(params, cfg, chunk_tokens=3)
    oneshot, _ = _run_batcher(params, cfg, paged=True, page_size=4,
                              chunk_tokens=64)
    chunked, batcher = _run_batcher(params, cfg, paged=True, page_size=4,
                                    chunk_tokens=3)
    assert chunked == oneshot == dense
    assert batcher.pool.available() == batcher.pool.num_pages - 1


def test_chunked_nonaligned_chunk_page_prompt():
    """Nothing divides anything: prompt 13, page 4, chunk budget 5 (trimmed
    to 4 by the page heuristic -> plan [4,4,4,1]), max_len not a page
    multiple — paged chunked must still match the dense one-shot run."""
    cfg = CFGS["dense"]
    params = init_params(cfg, jax.random.PRNGKey(0))
    dense, _ = _run_batcher(params, cfg, steps=10, max_len=30,
                            chunk_tokens=64)
    paged, batcher = _run_batcher(params, cfg, steps=10, max_len=30,
                                  paged=True, page_size=4, chunk_tokens=5)
    assert dense == paged
    assert batcher.pool.available() == batcher.pool.num_pages - 1


@pytest.mark.parametrize("family", ["dense", "hybrid_mamba"])
def test_decode_interleaves_with_admission(family):
    """The two-queue property: while a long prompt is being chunk-prefilled,
    the already-running slot must keep emitting tokens every tick (the old
    scheduler stalled every running slot for the whole prefill)."""
    cfg = CFGS[family]
    params = init_params(cfg, jax.random.PRNGKey(0))
    batcher = ContinuousBatcher(params, cfg, num_slots=2, max_len=64,
                                paged=True, page_size=4, chunk_tokens=4)
    a = Request(rid=0, prompt=PROMPTS[1], max_new_tokens=40)
    batcher.submit(a)
    while not a.output:                      # admit A, first decode ticks
        batcher.step()
    b = Request(rid=1, prompt=PROMPTS[0], max_new_tokens=4)   # 13 tokens
    batcher.submit(b)
    grew = 0
    admission_ticks = 0
    while not b.output and admission_ticks < 50:
        before = len(a.output)
        batcher.step()
        if batcher._adm is not None or b.output:
            admission_ticks += 1
            grew += len(a.output) > before
    assert admission_ticks >= 3              # 13 tokens / 4-token budget
    assert grew >= admission_ticks - 1       # A decoded during admission


def test_pool_exhaustion_mid_prefill_rolls_back_and_requeues():
    """A chunk whose pages cannot be allocated must roll the partial
    admission back (pages freed, request requeued at the head) and retry
    once decoders release pages — outputs stay identical to a lossless
    pool and nothing leaks."""
    cfg = CFGS["dense"]
    params = init_params(cfg, jax.random.PRNGKey(0))
    prompts = [np.asarray([1, 2, 3, 4], np.int32), PROMPTS[0]]  # 4 + 13 toks
    roomy, _ = _run_batcher(params, cfg, steps=11, max_len=16,
                            prompts=prompts, paged=True, page_size=4,
                            chunk_tokens=4)
    tight, batcher = _run_batcher(params, cfg, steps=11, max_len=16,
                                  prompts=prompts, paged=True, page_size=4,
                                  num_pages=5, chunk_tokens=4, max_ticks=600)
    assert tight == roomy
    assert batcher.admission_rollbacks >= 1
    assert batcher.pool.available() == batcher.pool.num_pages - 1


def test_scan_generate_chunked_prologue_matches_loop():
    """The fused rollout's chunked direct-to-page prologue (prefill straight
    into the pool, no dense max_len cache, no repage copy) must stay
    token-identical to the dense python-loop oracle."""
    cfg = CFGS["dense"]
    params = init_params(cfg, jax.random.PRNGKey(0))
    prompt = jax.random.randint(jax.random.PRNGKey(3), (2, 7), 0,
                                cfg.vocab_size)
    ref = greedy_generate_loop(params, cfg, prompt, steps=6)
    for chunk in (0, 3):                     # one-shot and chunked prologue
        paged = scan_generate(params, cfg, prompt, steps=6, page_size=4,
                              prefill_chunk=chunk)
        np.testing.assert_array_equal(np.asarray(paged), np.asarray(ref))
