"""Subprocess worker for tensor-parallel serving tests.

Run as ``python tests/_tp_worker.py <mode>`` in its own process so the forced
8-device host platform never leaks into the main pytest session (the repo's
XLA-flags isolation rule).  Each mode prints one JSON verdict on stdout.
"""

import json
import os
import sys

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax                                                      # noqa: E402
import jax.numpy as jnp                                         # noqa: E402
import numpy as np                                              # noqa: E402

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from repro.configs import get_arch                              # noqa: E402
from repro.launch.mesh import make_serving_mesh                 # noqa: E402
from repro.models.config import reduced                         # noqa: E402
from repro.models.transformer import init_params                # noqa: E402
from repro.serve.batching import ContinuousBatcher, Request     # noqa: E402

ARCH = "yi-34b"


def _cfg(**kw):
    return reduced(get_arch(ARCH), **kw)


def _params(cfg):
    return init_params(cfg, jax.random.PRNGKey(0))


def _prompts(cfg, n=5, seed=0):
    rng = np.random.default_rng(seed)
    pre = rng.integers(0, cfg.vocab_size, size=16).astype(np.int32)
    out = []
    for i in range(n):
        tail = rng.integers(0, cfg.vocab_size,
                            size=int(rng.integers(3, 12))).astype(np.int32)
        out.append(np.concatenate([pre, tail]) if i % 2 else tail)
    return out


def _serve(params, cfg, mesh=None, injector=None, supervised=False, **kw):
    b = ContinuousBatcher(params, cfg, num_slots=3, max_len=64, mesh=mesh,
                          **kw)
    reqs = [Request(rid=i, prompt=p, max_new_tokens=6)
            for i, p in enumerate(_prompts(cfg))]
    if supervised:
        from repro.serve.supervisor import ServingSupervisor
        sup = ServingSupervisor(b, injector=injector, snapshot_every=2)
        for r in reqs:
            assert sup.submit(r).accepted
        sup.run(max_ticks=400)
    else:
        for r in reqs:
            b.submit(r)
        b.run()
    return {r.rid: list(r.output) for r in reqs}


def mode_identity():
    """tp in {2, 4} token-identical to the single-device batcher, in dense,
    paged, and paged+prefix-cache modes; plus the fused scan_generate."""
    from repro.serve.engine import scan_generate
    cfg = _cfg()
    params = _params(cfg)
    out = {}
    modes = {"dense": {},
             "paged": {"paged": True, "page_size": 8},
             "prefix": {"paged": True, "page_size": 8, "prefix_cache": True}}
    for name, kw in modes.items():
        ref = _serve(params, cfg, **kw)
        for tp in (2, 4):
            got = _serve(params, cfg, mesh=make_serving_mesh(tp), **kw)
            out[f"{name}_tp{tp}"] = got == ref
    prompt = jnp.asarray(np.stack([p[:8] for p in _prompts(cfg, 2, seed=3)]))
    ref = np.asarray(scan_generate(params, cfg, prompt, steps=8))
    for tp in (2, 4):
        got = np.asarray(scan_generate(params, cfg, prompt, steps=8,
                                       mesh=make_serving_mesh(tp)))
        out[f"scan_tp{tp}"] = bool(np.array_equal(ref, got))
    gotp = np.asarray(scan_generate(params, cfg, prompt, steps=8,
                                    page_size=8, prefill_chunk=8,
                                    mesh=make_serving_mesh(2)))
    out["scan_paged_tp2"] = bool(np.array_equal(ref, gotp))
    return out


def mode_storm():
    """The PR 6 fault storm (pool spikes + NaN ticks + a mid-tick crash
    recovered from snapshots) stays token-identical at tp=2."""
    from repro.serve.faults import FaultInjector
    cfg = _cfg()
    params = _params(cfg)
    kw = dict(paged=True, page_size=8, num_pages=17, prefix_cache=True,
              nan_retry_limit=10)

    def injector():
        return FaultInjector.storm(seed=11, ticks=30, p_spike=0.25,
                                   p_nan=0.25, crash_ticks=(5,),
                                   spike_duration=2)

    ref = _serve(params, cfg, injector=injector(), supervised=True, **kw)
    got = _serve(params, cfg, mesh=make_serving_mesh(2),
                 injector=injector(), supervised=True, **kw)
    return {"storm_tp2": got == ref,
            "nonempty": all(len(v) for v in ref.values())}


def mode_snapshot():
    """Shard-aware snapshot: capture mid-stream at tp=2, restore into a
    fresh tp=2 batcher (replay must be token-identical), and a tp-mismatched
    restore must raise a clear ValueError."""
    from repro.serve.supervisor import apply_state, capture_state
    cfg = _cfg()
    params = _params(cfg)
    mesh = make_serving_mesh(2)
    kw = dict(num_slots=2, max_len=64, paged=True, page_size=8)

    b = ContinuousBatcher(params, cfg, mesh=mesh, **kw)
    reqs = [Request(rid=i, prompt=p, max_new_tokens=8)
            for i, p in enumerate(_prompts(cfg, 2))]
    for r in reqs:
        b.submit(r)
    for _ in range(4):
        b.step()
    host, dev = capture_state(b)
    dev = jax.tree.map(np.asarray, dev)
    for _ in range(40):
        if all(r.finished for r in reqs):
            break
        b.step()
    full = {r.rid: list(r.output) for r in reqs}

    kv = dev["cache"]["blocks"]["k_pages"]
    out = {"geometry_tp": host["geometry"]["tp"],
           "mesh_spec": host["mesh"],
           "stacked_leading_tp": kv.ndim == 6 and kv.shape[0] == 2}
    b2 = ContinuousBatcher(params, cfg, mesh=mesh, **kw)
    by_rid = apply_state(b2, host, dev)
    for _ in range(40):
        if all(r.finished for r in by_rid.values()):
            break
        b2.step()
    out["replay_identical"] = {k: list(r.output)
                               for k, r in by_rid.items()} == full
    b3 = ContinuousBatcher(params, cfg, **kw)
    try:
        apply_state(b3, host, dev)
        out["mismatch_raises"] = False
    except ValueError as e:
        out["mismatch_raises"] = "tp=2" in str(e) and "tp=1" in str(e)
    return out


def mode_psum():
    """Exactly one all-reduce per projection pair: the psum count AND
    placement contract now lives in ``repro.analysis.audit_tp_psums`` (one
    implementation — unit-tested at 1 device via ``psum_violations``,
    integration-tested here on a real 2-device mesh); the standalone
    sharded kernel must also match the single-device fused kernel in both
    roles."""
    from repro.analysis import audit_tp_psums
    out = {}
    for scan in (True, False):
        cfg = _cfg(scan_layers=scan)
        res = audit_tp_psums(cfg, make_serving_mesh(2))
        out[f"psums_scan_{scan}"] = [res["found"], res["want"],
                                     res["violations"]]

    # sharded fused kernel vs the single-device kernel
    from repro.kernels.ops import quantized_matmul, quantized_matmul_sharded
    from repro.quant.mxint import mxint_quantize, pack_mantissa
    key = jax.random.PRNGKey(1)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    m, k, n, r = 8, 128, 96, 8
    x = jax.random.normal(k1, (m, k), jnp.float32)
    w = jax.random.normal(k2, (k, n), jnp.float32)
    a = 0.01 * jax.random.normal(k3, (k, r), jnp.float32)
    bmat = 0.01 * jax.random.normal(k4, (r, n), jnp.float32)
    mant, exp = mxint_quantize(w, 4, 32)
    mant = pack_mantissa(mant.reshape(w.shape), 4)
    ref = quantized_matmul(x, mant, exp, a, bmat, bits=4, block_size=32,
                           interpret=True)
    mesh = make_serving_mesh(2)
    for role in ("column", "row"):
        got = quantized_matmul_sharded(x, mant, exp, a, bmat, bits=4,
                                       block_size=32, mesh=mesh, role=role)
        out[f"kernel_{role}_close"] = bool(
            jnp.allclose(ref, got, atol=2e-4, rtol=2e-4))
    return out


def mode_spec():
    """Self-speculative decoding composes with tp=2: engine and batcher
    token streams stay bit-identical to spec_k=0 while the drafts run the
    REAL reduced-precision mantissa plane on packed per-device shards (the
    draft view shares the full tree's shards; no extra collectives)."""
    from repro.core import PTQConfig, quantize_params
    from repro.core.api import pack_for_serving
    from repro.models import Taps
    from repro.models.config import ModelConfig
    from repro.models.transformer import forward
    from repro.serve.engine import scan_generate

    cfg = ModelConfig(family="dense", num_layers=2, d_model=64, num_heads=4,
                      num_kv_heads=2, d_ff=128, vocab_size=64, head_dim=16,
                      scan_layers=False)
    params = init_params(cfg, jax.random.PRNGKey(0))
    taps = Taps()
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                              cfg.vocab_size)
    forward(params, {"tokens": toks}, cfg, taps=taps)
    from benchmarks.common import remap_stats
    qcfg = PTQConfig(method="qera_approx", rank=8, quantizer="mxint4",
                     skip_patterns=PTQConfig().skip_patterns)
    packed = pack_for_serving(
        quantize_params(params, qcfg,
                        stats_by_path=remap_stats(taps.layer_stats())), qcfg)

    out = {}
    mesh = make_serving_mesh(2)
    prompt = jnp.asarray(
        np.stack([p[:8] for p in _prompts(cfg, 2, seed=3)])) % cfg.vocab_size
    ref = np.asarray(scan_generate(packed, cfg, prompt, steps=10))
    drafted = 0
    for name, pk in (("dense", {}),
                     ("paged", {"page_size": 8, "prefill_chunk": 4})):
        for k in (2, 4):
            got, stats = scan_generate(
                packed, cfg, prompt, steps=10, spec_k=k, draft_bits=4,
                mesh=mesh, return_spec_stats=True, **pk)
            out[f"scan_{name}_k{k}_tp2"] = bool(
                np.array_equal(ref, np.asarray(got)))
            drafted += stats["drafted"]
    out["drafted_some"] = drafted > 0
    for name, kw in (("dense", {}),
                     ("paged", {"paged": True, "page_size": 8}),
                     ("prefix", {"paged": True, "page_size": 8,
                                 "prefix_cache": True})):
        refb = _serve(packed, cfg, **kw)
        gotb = _serve(packed, cfg, mesh=mesh, spec_k=4, draft_bits=4, **kw)
        out[f"batch_{name}_tp2"] = gotb == refb
    return out


def mode_plan():
    """A heterogeneous QuantPlan serves at tp=2: validate_plan_tp accepts
    the per-leaf granules, and the sharded batcher is token-identical to
    the single-device batcher on the SAME mixed packed tree (dense and
    paged), with the per-leaf (bits, block_size, rank) markers intact."""
    from repro.core import PTQConfig, quantize_params
    from repro.core.allocate import (LayerChoice, QuantPlan,
                                     describe_packed_plan, eligible_shapes)
    from repro.core.api import pack_for_serving
    from repro.models import Taps
    from repro.models.config import ModelConfig
    from repro.models.transformer import forward
    from repro.sharding.serving import validate_plan_tp

    cfg = ModelConfig(family="dense", num_layers=2, d_model=64, num_heads=4,
                      num_kv_heads=2, d_ff=128, vocab_size=64, head_dim=16,
                      scan_layers=False)
    params = init_params(cfg, jax.random.PRNGKey(0))
    taps = Taps()
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                              cfg.vocab_size)
    forward(params, {"tokens": toks}, cfg, taps=taps)
    from benchmarks.common import remap_stats
    qcfg = PTQConfig(method="qera_approx", rank=8, quantizer="mxint4",
                     skip_patterns=PTQConfig().skip_patterns)
    fmts = ("mxint8", "mxint4", "mxint3", "mxint2_bs32")
    shapes = eligible_shapes(params, qcfg.skips)
    bases = sorted({p.split(":")[0] for p in shapes})
    plan = QuantPlan(
        assignments={p: LayerChoice(fmts[i % len(fmts)], (4, 8)[i % 2])
                     for i, p in enumerate(bases)},
        default=LayerChoice("mxint4", 8), method="qera_approx")
    out = {}
    try:
        validate_plan_tp(shapes, plan, 2)
        out["plan_tp_ok"] = True
    except ValueError as e:
        return {"plan_tp_ok": False, "error": str(e)}
    packed = pack_for_serving(
        quantize_params(params, qcfg, stats_by_path=remap_stats(
            taps.layer_stats()), plan=plan), qcfg, plan=plan)
    desc = describe_packed_plan(packed)
    out["mixed_markers"] = len({(e["bits"], e.get("rank"))
                                for e in desc.values() if "bits" in e}) > 2
    mesh = make_serving_mesh(2)
    for name, kw in (("dense", {}), ("paged", {"paged": True,
                                               "page_size": 8})):
        ref = _serve(packed, cfg, **kw)
        got = _serve(packed, cfg, mesh=mesh, **kw)
        out[f"{name}_tp2"] = got == ref
        out[f"{name}_nonempty"] = all(len(v) for v in ref.values())
    return out


MODES = {"identity": mode_identity, "storm": mode_storm,
         "snapshot": mode_snapshot, "psum": mode_psum, "spec": mode_spec,
         "plan": mode_plan}

if __name__ == "__main__":
    print(json.dumps(MODES[sys.argv[1]]()))
