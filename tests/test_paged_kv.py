"""Paged KV cache + decode-attention tests.

Three layers of coverage: the Pallas decode-attention kernel against the
gather-then-softmax oracle (incl. non-aligned kv_len), PagePool allocator
invariants under random churn, and end-to-end paged-vs-dense
ContinuousBatcher token equivalence (dense + hybrid, full and oversubscribed
pools)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ops import decode_attention
from repro.kernels.ref import decode_attention_ref, gather_paged_kv
from repro.models import ModelConfig, init_params
from repro.serve.batching import ContinuousBatcher, Request
from repro.serve.engine import greedy_generate_loop, init_cache, scan_generate
from repro.serve.paging import PagePool, dense_to_paged, page_bucket

CFGS = {
    "dense": ModelConfig(family="dense", num_layers=2, d_model=32, num_heads=4,
                         num_kv_heads=2, d_ff=64, vocab_size=64, head_dim=8),
    "hybrid_mamba": ModelConfig(family="hybrid_mamba", num_layers=4,
                                d_model=32, num_heads=4, num_kv_heads=4,
                                head_dim=8, d_ff=64, vocab_size=64,
                                ssm_state=8, ssm_head_dim=8, ssm_chunk=4,
                                attn_every=2),
}

PROMPTS = [np.asarray([1, 2, 3, 4], np.int32),
           np.asarray([9, 8, 7], np.int32),
           np.asarray([5, 5], np.int32),
           np.asarray([11, 3, 7, 7, 2], np.int32)]


# ---------------------------------------------------------------------------
# kernel vs oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kv_lens", [
    (5, 17, 32),          # non-aligned, page-aligned, full
    (1, 9, 24),           # single live token; mid-page tails
])
def test_decode_attention_kernel_vs_ref(kv_lens):
    b, h, hkv, d, ps, npg, ptot = 3, 4, 2, 16, 8, 4, 16
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, h, d), jnp.float32)
    kp = jax.random.normal(ks[1], (ptot, hkv, ps, d), jnp.float32)
    vp = jax.random.normal(ks[2], (ptot, hkv, ps, d), jnp.float32)
    # scrambled (non-identity) page table over distinct real pages
    pt = jnp.asarray(np.random.RandomState(0).choice(
        np.arange(1, ptot), (b, npg), replace=False).astype(np.int32))
    kv_len = jnp.asarray(kv_lens, jnp.int32)
    got = decode_attention(q, kp, vp, pt, kv_len, interpret=True)
    want = decode_attention_ref(q, kp, vp, pt, kv_len)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_decode_attention_ignores_dead_pages():
    """Pages past kv_len (and garbage-page entries) must not contribute:
    poisoning them with huge values cannot change the output."""
    b, h, hkv, d, ps, npg, ptot = 2, 2, 2, 8, 4, 4, 12
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (b, h, d), jnp.float32)
    kp = jax.random.normal(ks[1], (ptot, hkv, ps, d), jnp.float32)
    vp = jax.random.normal(ks[2], (ptot, hkv, ps, d), jnp.float32)
    kv_len = jnp.asarray([6, 3], jnp.int32)      # live: 2 pages, 1 page
    pt = jnp.asarray([[1, 2, 0, 0], [3, 0, 0, 0]], jnp.int32)
    base = decode_attention(q, kp, vp, pt, kv_len, interpret=True)
    dead = [0] + list(range(4, ptot))            # garbage + unowned pages
    kp2 = kp.at[jnp.asarray(dead)].set(1e4)
    vp2 = vp.at[jnp.asarray(dead)].set(1e4)
    poisoned = decode_attention(q, kp2, vp2, pt, kv_len, interpret=True)
    np.testing.assert_allclose(np.asarray(poisoned), np.asarray(base),
                               rtol=1e-6, atol=1e-6)


def test_dense_to_paged_roundtrip():
    cfg = CFGS["dense"]
    cache = init_cache(cfg, 3, 16)
    leaves, treedef = jax.tree.flatten(cache)
    keys = jax.random.split(jax.random.PRNGKey(2), len(leaves))
    cache = jax.tree.unflatten(treedef, [
        jax.random.normal(k, x.shape).astype(x.dtype)
        for k, x in zip(keys, leaves)])
    paged = dense_to_paged(cache, page_size=4)
    pt = paged["page_table"]
    assert pt.shape == (3, 4)
    for name in ("k", "v"):
        pool = paged["blocks"][f"{name}_pages"]      # (L, P, Hkv, ps, hd)
        for layer in range(cfg.num_layers):
            got = gather_paged_kv(pool[layer], pt)   # (B, Hkv, S, hd)
            np.testing.assert_array_equal(
                np.asarray(got), np.asarray(cache["blocks"][name][layer]))


# ---------------------------------------------------------------------------
# page pool
# ---------------------------------------------------------------------------

def test_page_pool_invariants_under_churn():
    """Refcounted-pool churn: random acquire / share (an extra owner per
    page) / release / register storms never double-free, never hand out a
    page that still has owners, and keep the available-page accounting
    exact — registered refcount-0 pages stay reclaimable (LRU), so they
    count as available."""
    rng = np.random.RandomState(0)
    pool = PagePool(num_pages=17, page_size=8)
    held: list[list[int]] = []                    # one entry per ownership
    seen_live: set[int] = set()
    for _ in range(1000):
        r = rng.rand()
        if held and r < 0.35:
            pool.release(held.pop(rng.randint(len(held))))
        elif held and r < 0.5:
            pages = held[rng.randint(len(held))]  # second owner of a ref
            pool.share(pages)
            held.append(list(pages))
        elif held and r < 0.6:
            # prefix index registers a random held page's content
            pool.set_registered(held[rng.randint(len(held))][0], True)
        else:
            got = pool.acquire(rng.randint(1, 5))
            if got is None:
                assert pool.available() < 5       # only all-or-nothing fails
                continue
            flat = [p for ps_ in held for p in ps_]
            assert not set(got) & set(flat), "page with owners handed out"
            assert 0 not in got, "garbage page handed out"
            assert all(pool.refcount(p) == 1 for p in got)
            held.append(got)
            seen_live.update(got)
        flat = [p for ps_ in held for p in ps_]
        for p in set(flat):
            assert pool.refcount(p) == flat.count(p), "refcount drifted"
        # every page without owners is allocatable (free or cached LRU)
        assert pool.available() == pool.num_pages - 1 - len(set(flat))
    for h in held:
        pool.release(h)
    assert pool.available() == pool.num_pages - 1
    assert seen_live <= set(range(1, 17))
    with pytest.raises(AssertionError):           # over-release is an error
        pool.free([1])


def test_page_bucket():
    assert page_bucket(1, 8) == 1
    assert page_bucket(3, 8) == 4
    assert page_bucket(5, 8) == 8
    assert page_bucket(9, 8) == 8                 # capped at max_pages


# ---------------------------------------------------------------------------
# end-to-end: paged batcher == dense batcher, token for token
# ---------------------------------------------------------------------------

def _run_batcher(params, cfg, *, steps=6, max_len=32,
                 **kw) -> tuple[list[list[int]], ContinuousBatcher]:
    batcher = ContinuousBatcher(params, cfg, num_slots=2, max_len=max_len,
                                **kw)
    reqs = [Request(rid=i, prompt=p, max_new_tokens=steps)
            for i, p in enumerate(PROMPTS)]
    for r in reqs:
        batcher.submit(r)
    batcher.run(max_ticks=300)
    assert all(r.done for r in reqs)
    return [r.output for r in reqs], batcher


@pytest.mark.parametrize("family", list(CFGS))
def test_paged_batcher_matches_dense(family):
    cfg = CFGS[family]
    params = init_params(cfg, jax.random.PRNGKey(0))
    dense, _ = _run_batcher(params, cfg)
    paged, batcher = _run_batcher(params, cfg, paged=True, page_size=4)
    assert dense == paged
    # every slot freed -> every page back in the pool
    assert batcher.pool.available() == batcher.pool.num_pages - 1


@pytest.mark.parametrize("family", list(CFGS))
def test_paged_batcher_oversubscribed_pool_pauses_not_corrupts(family):
    """A pool too small for all slots to reach max_len forces mid-decode
    pauses; outputs must still be token-identical to the lossless run
    (pauses roll back per-slot recurrent state — the hybrid case — and
    appends land in the garbage page) and no page may leak."""
    cfg = CFGS[family]
    params = init_params(cfg, jax.random.PRNGKey(0))
    full, _ = _run_batcher(params, cfg, steps=8, paged=True, page_size=4)
    tight, batcher = _run_batcher(params, cfg, steps=8, paged=True,
                                  page_size=4, num_pages=6)
    assert full == tight
    assert batcher.pool.available() == batcher.pool.num_pages - 1


def test_paged_batcher_nonaligned_max_len_matches_dense():
    """max_len not a page multiple: page geometry rounds up internally but
    the request done-check must keep the caller's max_len, so paged and
    dense still terminate on the same token."""
    cfg = CFGS["dense"]
    params = init_params(cfg, jax.random.PRNGKey(0))
    dense, _ = _run_batcher(params, cfg, steps=30, max_len=10)
    paged, _ = _run_batcher(params, cfg, steps=30, max_len=10, paged=True,
                            page_size=4)
    assert dense == paged
    assert all(len(o) <= 30 for o in paged)


def test_paged_batcher_all_slots_paused_evicts_and_recovers():
    """Both slots crossing a page boundary with an empty pool would livelock
    (no slot can ever finish and free pages); the batcher must preempt one
    request — requeued and recomputed from prefill — and still produce the
    lossless outputs."""
    cfg = CFGS["dense"]
    params = init_params(cfg, jax.random.PRNGKey(0))
    prompts = [np.asarray([1, 2, 3, 4], np.int32),
               np.asarray([9, 8, 7, 6], np.int32)]
    outs = {}
    for num_pages in (None, 5):     # 5 => 4 usable pages for 2 slots
        batcher = ContinuousBatcher(params, cfg, num_slots=2, max_len=32,
                                    paged=True, page_size=4,
                                    num_pages=num_pages)
        reqs = [Request(rid=i, prompt=p, max_new_tokens=12)
                for i, p in enumerate(prompts)]
        for r in reqs:
            batcher.submit(r)
        batcher.run(max_ticks=500)
        assert all(r.done for r in reqs)
        outs[num_pages] = [r.output for r in reqs]
    assert outs[None] == outs[5]
    assert batcher.pool.available() == batcher.pool.num_pages - 1


def test_paged_batcher_pool_too_small_for_one_request_raises():
    """All-slots-paused with a single active slot cannot make progress by
    eviction (the slot already holds every page) — must raise, not spin."""
    cfg = CFGS["dense"]
    params = init_params(cfg, jax.random.PRNGKey(0))
    batcher = ContinuousBatcher(params, cfg, num_slots=1, max_len=32,
                                paged=True, page_size=4, num_pages=3)
    batcher.submit(Request(rid=0, prompt=PROMPTS[0], max_new_tokens=20))
    with pytest.raises(RuntimeError, match="too small"):
        batcher.run(max_ticks=100)


def test_scan_generate_paged_matches_loop():
    """The fused rollout on the paged decode-attention kernel must stay
    token-identical to the dense python-loop oracle."""
    cfg = CFGS["dense"]
    params = init_params(cfg, jax.random.PRNGKey(0))
    prompt = jax.random.randint(jax.random.PRNGKey(3), (2, 5), 0,
                                cfg.vocab_size)
    ref = greedy_generate_loop(params, cfg, prompt, steps=6)
    paged = scan_generate(params, cfg, prompt, steps=6, page_size=4)
    np.testing.assert_array_equal(np.asarray(paged), np.asarray(ref))
