"""The packed Pallas serving path: pack_for_serving + use_pallas forward
must match the fake-quant (w_tilde) forward; plus randomized-SVD and
Newton-Schulz solver variants produce near-identical reconstructions."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import PTQConfig, quantize_params, stats_from_samples
from repro.core.api import pack_for_serving
from repro.core.solvers import solve_qera_exact
from repro.models import ModelConfig, Taps, forward, init_params
from repro.quant import get_quantizer

CFG = ModelConfig(family="dense", num_layers=2, d_model=64, num_heads=4,
                  num_kv_heads=2, d_ff=128, vocab_size=64, head_dim=16,
                  scan_layers=False)


def _quantized():
    params = init_params(CFG, jax.random.PRNGKey(0))
    taps = Taps()
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, 64)
    forward(params, {"tokens": toks}, CFG, taps=taps)
    from benchmarks.common import remap_stats
    stats = remap_stats(taps.layer_stats())
    qcfg = PTQConfig(method="qera_approx", rank=8, quantizer="mxint4",
                     skip_patterns=PTQConfig().skip_patterns)
    return quantize_params(params, qcfg, stats_by_path=stats), qcfg, toks


def test_pack_for_serving_matches_fake_quant_forward():
    qparams, qcfg, toks = _quantized()
    logits_ref, _, _ = forward(qparams, {"tokens": toks}, CFG)

    packed = pack_for_serving(qparams, qcfg)
    from repro.utils.trees import flatten_dict
    flat = flatten_dict(packed)
    assert any(k.endswith("/mant") for k in flat), "nothing packed"
    cfg_pallas = dataclasses.replace(CFG, use_pallas=True)
    logits_pk, _, _ = forward(packed, {"tokens": toks}, cfg_pallas)
    np.testing.assert_allclose(np.asarray(logits_pk), np.asarray(logits_ref),
                               rtol=2e-3, atol=2e-3)


def test_randomized_svd_solver_close_to_exact():
    key = jax.random.PRNGKey(0)
    w = jax.random.normal(key, (96, 64)) / 10
    x = jax.random.normal(jax.random.PRNGKey(1), (2048, 96)) * \
        jnp.exp(jax.random.normal(jax.random.PRNGKey(2), (96,)))
    stats = stats_from_samples(x)
    w_t = get_quantizer("mxint3")(w)
    a_e, b_e = solve_qera_exact(w, w_t, 8, stats.rxx, svd_method="exact")
    a_r, b_r = solve_qera_exact(w, w_t, 8, stats.rxx, svd_method="randomized",
                                key=jax.random.PRNGKey(3))
    from repro.core import empirical_output_error
    err_e = float(empirical_output_error(x, w_t + a_e @ b_e - w))
    err_r = float(empirical_output_error(x, w_t + a_r @ b_r - w))
    assert err_r <= err_e * 1.05     # rSVD sketch within 5% of optimal


def test_newton_schulz_solver_close_to_eigh():
    key = jax.random.PRNGKey(4)
    w = jax.random.normal(key, (64, 48)) / 8
    x = jax.random.normal(jax.random.PRNGKey(5), (4096, 64)) * \
        jnp.exp(0.5 * jax.random.normal(jax.random.PRNGKey(6), (64,)))
    stats = stats_from_samples(x)
    w_t = get_quantizer("mxint3")(w)
    a_e, b_e = solve_qera_exact(w, w_t, 8, stats.rxx, sqrt_method="eigh")
    a_n, b_n = solve_qera_exact(w, w_t, 8, stats.rxx,
                                sqrt_method="newton_schulz")
    from repro.core import empirical_output_error
    err_e = float(empirical_output_error(x, w_t + a_e @ b_e - w))
    err_n = float(empirical_output_error(x, w_t + a_n @ b_n - w))
    assert err_n <= err_e * 1.05     # MXU-native sqrt within 5% of exact
