"""Unit + property tests for the quantization formats."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.quant import (
    get_quantizer,
    mxint_fake_quant,
    pack_mxint,
    int_fake_quant,
    nf4_fake_quant,
)
from repro.quant.mxint import unpack_mxint, MXINT_CONFIGS

pytest.importorskip("hypothesis")  # property tests skip without hypothesis
from hypothesis import given, settings, strategies as st  # noqa: E402


def test_average_bits_match_paper():
    # Paper Table 1/3 W-bits column.
    assert get_quantizer("mxint4").average_bits == pytest.approx(4.25)
    assert get_quantizer("mxint3").average_bits == pytest.approx(3.25)
    assert get_quantizer("mxint2").average_bits == pytest.approx(2.50)
    assert get_quantizer("mxint2_bs32").average_bits == pytest.approx(2.25)


@pytest.mark.parametrize("name", ["mxint8", "mxint4", "mxint3", "mxint2"])
def test_mxint_roundtrip_error_bound(name):
    spec = MXINT_CONFIGS[name]
    key = jax.random.PRNGKey(0)
    w = jax.random.normal(key, (64, 48), dtype=jnp.float32)
    wq = mxint_fake_quant(w, spec.bits, spec.block_size)
    # per-block max error <= scale/2, scale <= 2 * maxabs / (2^(b-1)-1)
    wb = w.reshape(-1, spec.block_size, 48)
    eb = (w - wq).reshape(-1, spec.block_size, 48)
    maxabs = np.max(np.abs(np.asarray(wb)), axis=1)
    qmax = 2 ** (spec.bits - 1) - 1
    bound = (2.0 * maxabs / qmax) / 2 + 1e-7
    assert np.all(np.max(np.abs(np.asarray(eb)), axis=1) <= bound)


@pytest.mark.parametrize("name", ["mxint8", "mxint4", "mxint3", "mxint2"])
def test_mxint_idempotent(name):
    spec = MXINT_CONFIGS[name]
    w = jax.random.normal(jax.random.PRNGKey(1), (32, 16))
    wq = mxint_fake_quant(w, spec.bits, spec.block_size)
    wqq = mxint_fake_quant(wq, spec.bits, spec.block_size)
    np.testing.assert_allclose(np.asarray(wq), np.asarray(wqq), rtol=0, atol=0)


def test_mxint_zero_block():
    w = jnp.zeros((32, 8))
    wq = mxint_fake_quant(w, 4, 32)
    assert np.all(np.asarray(wq) == 0)


def test_mxint_pack_unpack_consistent():
    w = jax.random.normal(jax.random.PRNGKey(2), (128, 64))
    packed = pack_mxint(w, 4, 32)
    # sub-byte HBM layout: two 4-bit mantissas per byte along the input axis
    assert packed.mant.shape == (64, 64) and packed.mant.dtype == jnp.int8
    assert packed.mant.nbytes == 128 * 64 // 2
    assert packed.exp.shape == (4, 64) and packed.exp.dtype == jnp.int8
    deq = unpack_mxint(packed)
    ref = mxint_fake_quant(w, 4, 32)
    np.testing.assert_allclose(np.asarray(deq), np.asarray(ref), atol=0)
    # flat escape hatch round-trips identically
    flat = pack_mxint(w, 4, 32, packed=False)
    assert flat.mant.shape == (128, 64)
    np.testing.assert_allclose(np.asarray(unpack_mxint(flat)),
                               np.asarray(ref), atol=0)


@settings(max_examples=30, deadline=None)
@given(
    bits=st.sampled_from([2, 3, 4, 8]),
    k=st.integers(1, 80),
    n=st.integers(1, 16),
    seed=st.integers(0, 2**31 - 1),
)
def test_mantissa_pack_roundtrip_property(bits, k, n, seed):
    """pack -> unpack is the identity for any K (incl. non-byte-aligned)."""
    from repro.quant.mxint import pack_mantissa, unpack_mantissa
    qmax = 2 ** (bits - 1) - 1
    mant = jax.random.randint(jax.random.PRNGKey(seed), (k, n), -qmax,
                              qmax + 1, dtype=jnp.int32).astype(jnp.int8)
    out = unpack_mantissa(pack_mantissa(mant, bits), bits, k)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(mant))


@settings(max_examples=20, deadline=None)
@given(
    bits=st.sampled_from([2, 3, 4, 7]),  # bits+1 must still fit int8 mantissa
    scale=st.floats(1e-3, 1e3),
    seed=st.integers(0, 2**31 - 1),
)
def test_mxint_error_decreases_with_bits_property(bits, scale, seed):
    """More mantissa bits never increase block quantization error (same bs)."""
    w = np.asarray(jax.random.normal(jax.random.PRNGKey(seed), (32, 4))) * scale
    w = jnp.asarray(w)
    e_lo = float(jnp.linalg.norm(w - mxint_fake_quant(w, bits, 32)))
    e_hi = float(jnp.linalg.norm(w - mxint_fake_quant(w, bits + 1, 32)))
    assert e_hi <= e_lo + 1e-5 * max(1.0, e_lo)


def test_int_fake_quant_bound():
    w = jax.random.normal(jax.random.PRNGKey(3), (128, 32))
    wq = int_fake_quant(w, 4, 64)
    wb = np.asarray(w).reshape(2, 64, 32)
    err = np.abs(np.asarray(w - wq)).reshape(2, 64, 32)
    rng = wb.max(axis=1) - wb.min(axis=1)
    bound = rng / (2**4 - 1) / 2 + 1e-6
    assert np.all(err.max(axis=1) <= bound)


def test_nf4_levels_and_extremes():
    w = jax.random.normal(jax.random.PRNGKey(4), (64, 16))
    wq = nf4_fake_quant(w, block_size=64)
    # max-|.| element per block is reproduced exactly (level +-1 * absmax)
    col_absmax_in = np.abs(np.asarray(w)).max(axis=0)
    col_absmax_out = np.abs(np.asarray(wq)).max(axis=0)
    np.testing.assert_allclose(col_absmax_in, col_absmax_out, rtol=1e-6)


def test_quantizer_registry():
    for name in ["mxint4", "mxint3", "mxint2", "int4_g64", "nf4", "none"]:
        q = get_quantizer(name)
        w = jax.random.normal(jax.random.PRNGKey(5), (64, 64))
        wq = q(w)
        assert wq.shape == w.shape and wq.dtype == w.dtype
    with pytest.raises(KeyError):
        get_quantizer("fp5")


def test_quantizers_jittable():
    w = jax.random.normal(jax.random.PRNGKey(6), (64, 64))
    for name in ["mxint4", "int4_g64", "nf4"]:
        q = get_quantizer(name)
        out = jax.jit(q.fake_quant)(w)
        np.testing.assert_allclose(np.asarray(out), np.asarray(q(w)), atol=1e-6)
