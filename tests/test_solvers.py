"""Property + unit tests for the QERA solvers (Theorems 1 & 2 and baselines)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    empirical_output_error,
    expected_output_error,
    solve_loftq,
    solve_lqer,
    solve_qera_approx,
    solve_qera_exact,
    solve_qlora,
    solve_zeroquant_v2,
    stats_from_samples,
)
from repro.quant import get_quantizer

pytest.importorskip("hypothesis")  # property tests skip without hypothesis
from hypothesis import given, settings, strategies as st  # noqa: E402


def _problem(seed, m=24, n=20, tokens=512, correlated=True):
    """Random QER problem: anisotropic, (optionally) correlated inputs."""
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    w = jax.random.normal(k1, (m, n), jnp.float32)
    scales = jnp.exp(jax.random.normal(k2, (m,)))  # anisotropic dims
    x = jax.random.normal(k3, (tokens, m)) * scales
    if correlated:
        mix = jnp.eye(m) + 0.3 * jax.random.normal(k2, (m, m)) / np.sqrt(m)
        x = x @ mix
    return w, x


def _errors(w, w_tilde, x, a, b):
    p = (w_tilde + a @ b - w).astype(jnp.float32)
    return float(empirical_output_error(x.astype(jnp.float32), p))


@pytest.mark.parametrize("quant", ["mxint4", "mxint2"])
def test_qera_exact_beats_all_baselines(quant):
    """Theorem 1: QERA-exact minimizes E||xP||² over rank-k C_k — must beat
    (or tie) every other method on the *training* distribution."""
    w, x = _problem(0)
    stats = stats_from_samples(x)
    q = get_quantizer(quant)
    w_tilde = q(w)
    k = 4
    a_e, b_e = solve_qera_exact(w, w_tilde, k, stats.rxx)
    err_exact = _errors(w, w_tilde, x, a_e, b_e)
    for name, (a, b) in {
        "approx": solve_qera_approx(w, w_tilde, k, stats.mean_x2),
        "lqer": solve_lqer(w, w_tilde, k, stats.mean_abs),
        "zq": solve_zeroquant_v2(w, w_tilde, k),
        "qlora": solve_qlora(jax.random.PRNGKey(1), w, k),
    }.items():
        err = _errors(w, w_tilde, x, a, b)
        assert err_exact <= err * (1 + 1e-4) + 1e-7, (name, err_exact, err)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10_000), k=st.sampled_from([1, 2, 4, 8]))
def test_qera_exact_optimality_property(seed, k):
    """Hypothesis sweep of Theorem 1 optimality vs random rank-k competitors."""
    w, x = _problem(seed, m=16, n=12, tokens=256)
    stats = stats_from_samples(x)
    w_tilde = get_quantizer("mxint3")(w)
    a_e, b_e = solve_qera_exact(w, w_tilde, k, stats.rxx)
    # exact expected error via R_XX (not sample error — this is the objective)
    rxx = stats.rxx
    p_opt = w_tilde + a_e @ b_e - w
    err_opt = float(expected_output_error(p_opt, rxx))
    # competitors: perturbations of the optimum and other solvers
    key = jax.random.PRNGKey(seed)
    for i in range(3):
        key, k1, k2 = jax.random.split(key, 3)
        a_c = a_e + 0.1 * jax.random.normal(k1, a_e.shape)
        b_c = b_e + 0.1 * jax.random.normal(k2, b_e.shape)
        p_c = w_tilde + a_c @ b_c - w
        err_c = float(expected_output_error(p_c, rxx))
        assert err_opt <= err_c * (1 + 1e-4) + 1e-7


def test_qera_approx_equals_exact_when_uncorrelated():
    """Theorem 2 == Theorem 1 when R_XX is (exactly) diagonal."""
    w, _ = _problem(3, m=16, n=12)
    var = jnp.exp(jax.random.normal(jax.random.PRNGKey(4), (16,)))
    rxx = jnp.diag(var)
    w_tilde = get_quantizer("mxint3")(w)
    a_e, b_e = solve_qera_exact(w, w_tilde, 4, rxx)
    # hand LayerStats mean_x2 = diag(R)
    a_a, b_a = solve_qera_approx(w, w_tilde, 4, var)
    np.testing.assert_allclose(np.asarray(a_e @ b_e), np.asarray(a_a @ b_a),
                               rtol=1e-3, atol=1e-4)


def test_zeroquant_equals_lqer_with_identity_scale():
    """Paper §2: ZeroQuant-V2 is LQER with S = I."""
    w, _ = _problem(5)
    w_tilde = get_quantizer("mxint4")(w)
    a_z, b_z = solve_zeroquant_v2(w, w_tilde, 4)
    a_l, b_l = solve_lqer(w, w_tilde, 4, jnp.ones(w.shape[0]))
    np.testing.assert_allclose(np.asarray(a_z @ b_z), np.asarray(a_l @ b_l),
                               rtol=1e-4, atol=1e-5)


def test_loftq_one_iter_equals_zeroquant():
    """Paper §2: ZeroQuant-V2 == LoftQ with one iteration."""
    w, _ = _problem(6)
    q = get_quantizer("mxint4")
    w_tilde, a, b = solve_loftq(w, q.fake_quant, 4, iters=1)
    a_z, b_z = solve_zeroquant_v2(w, q(w), 4)
    np.testing.assert_allclose(np.asarray(w_tilde), np.asarray(q(w)), atol=0)
    np.testing.assert_allclose(np.asarray(a @ b), np.asarray(a_z @ b_z),
                               rtol=1e-4, atol=1e-5)


def test_loftq_weight_error_decreases():
    """Appendix A.5: LoftQ weight error decreases monotonically in iterations."""
    w, _ = _problem(7, m=32, n=24)
    q = get_quantizer("mxint3")
    errs = []
    for t in range(1, 6):
        w_tilde, a, b = solve_loftq(w, q.fake_quant, 8, iters=t)
        errs.append(float(jnp.linalg.norm(w - w_tilde - a @ b)))
    # allow tiny numerical wiggle
    assert all(errs[i + 1] <= errs[i] * 1.02 for i in range(len(errs) - 1)), errs


def test_qera_error_monotone_in_rank():
    """Fig. 1 claim: QERA output error decreases monotonically with rank."""
    w, x = _problem(8)
    stats = stats_from_samples(x)
    w_tilde = get_quantizer("mxint3")(w)
    errs = []
    for k in [1, 2, 4, 8, 12]:
        a, b = solve_qera_exact(w, w_tilde, k, stats.rxx)
        errs.append(_errors(w, w_tilde, x, a, b))
    assert all(errs[i + 1] <= errs[i] + 1e-6 for i in range(len(errs) - 1)), errs


def test_full_rank_reconstruction_is_lossless():
    """At k = min(m, n) every SVD-based method reconstructs W exactly."""
    w, x = _problem(9, m=12, n=10)
    stats = stats_from_samples(x)
    w_tilde = get_quantizer("mxint2")(w)
    for a, b in [
        solve_qera_exact(w, w_tilde, 10, stats.rxx),
        solve_qera_approx(w, w_tilde, 10, stats.mean_x2),
        solve_zeroquant_v2(w, w_tilde, 10),
    ]:
        np.testing.assert_allclose(np.asarray(w_tilde + a @ b), np.asarray(w),
                                   rtol=1e-4, atol=1e-4)


def test_expected_matches_empirical_error():
    """Tr(R P Pᵀ) == sample mean ||xP||² when R comes from the same samples."""
    w, x = _problem(10)
    stats = stats_from_samples(x)
    w_tilde = get_quantizer("mxint4")(w)
    a, b = solve_qera_approx(w, w_tilde, 4, stats.mean_x2)
    p = w_tilde + a @ b - w
    analytic = float(expected_output_error(p, stats.rxx))
    empirical = float(empirical_output_error(x, p))
    assert analytic == pytest.approx(empirical, rel=1e-3)


def test_solve_registry_roundtrip():
    from repro.core import solve, stats_from_samples
    w, x = _problem(11)
    stats = stats_from_samples(x)
    q = get_quantizer("mxint4")
    for method in ["qera_exact", "qera_approx", "lqer", "zeroquant_v2",
                   "loftq", "qlora"]:
        w_t, a, b = solve(method, w, q(w), 4, stats=stats, quant_fn=q.fake_quant,
                          key=jax.random.PRNGKey(0))
        assert a.shape == (w.shape[0], 4) and b.shape == (4, w.shape[1])
        assert np.all(np.isfinite(np.asarray(a))) and np.all(np.isfinite(np.asarray(b)))
