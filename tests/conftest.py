"""Shared test config: runtime invariant checking for the serving suites.

Every ``ContinuousBatcher`` constructed from the serving, paging,
prefix-cache, chunked-prefill, fault-tolerance, and TP suites runs with
``debug_invariants=True``: after every tick the batcher re-derives page
refcount conservation from the slot tables and hashes every protected
(shared or prefix-registered) page to prove no write bypassed the
copy-on-write fork (repro.analysis.runtime).  Tests that pass the flag
explicitly keep their value — the fixture only fills the default.
"""

import pytest

_INVARIANT_SUITES = (
    "test_serving",
    "test_serving_kernel_path",
    "test_paged_kv",
    "test_prefix_cache",
    "test_chunked_prefill",
    "test_fault_tolerance_serving",
    "test_tp_serving",
)


@pytest.fixture(autouse=True)
def _debug_invariants(request, monkeypatch):
    if request.module.__name__ not in _INVARIANT_SUITES:
        yield
        return
    from repro.serve.batching import ContinuousBatcher
    orig = ContinuousBatcher.__init__

    def init(self, *args, **kwargs):
        kwargs.setdefault("debug_invariants", True)
        orig(self, *args, **kwargs)

    monkeypatch.setattr(ContinuousBatcher, "__init__", init)
    yield
