"""Fault-tolerant serving tests.

Coverage map:

* ``FaultInjector`` — seeded storm determinism, one-shot events that never
  re-fire across crash-recovery replays.
* ``ContinuousBatcher.run`` — typed :class:`RunReport`, and
  :class:`IncompleteRunError` when the tick budget runs out (satellite c:
  unfinished work is never silently dropped).
* ``ServingSupervisor`` — typed load shedding (queue_full / overloaded /
  unservable), deadline/TTL expiry reported via ``abort``, bounded crash
  recovery from in-memory and on-disk snapshots.
* NaN sentinel — a corrupted decode tick costs the victim one retry tick
  and nothing else; persistent corruption quarantines ONLY the victim.
* Snapshot/restore — pool reservations (injected pressure) stay out of
  snapshots; a cold process rebuilt via ``load_snapshot`` finishes every
  in-flight stream token-identically.
* Fault equivalence (satellite d) — a seeded storm (pool-exhaustion spikes
  + NaN ticks + a mid-tick crash, interleaved with prefix-cache hits)
  completes every non-expired request bit-identical to the fault-free run,
  in dense and paged+hybrid modes.
"""

import jax
import numpy as np
import pytest

from repro.checkpoint.ckpt import CheckpointManager
from repro.models import ModelConfig, init_params
from repro.runtime.fault_tolerance import RestartPolicy
from repro.serve import (
    ContinuousBatcher,
    FaultEvent,
    FaultInjector,
    IncompleteRunError,
    PagePool,
    Request,
    ServingSupervisor,
    SimulatedDeviceFailure,
    load_snapshot,
)

CFGS = {
    "dense": ModelConfig(family="dense", num_layers=2, d_model=32, num_heads=4,
                         num_kv_heads=2, d_ff=64, vocab_size=64, head_dim=8),
    "hybrid_mamba": ModelConfig(family="hybrid_mamba", num_layers=4,
                                d_model=32, num_heads=4, num_kv_heads=4,
                                head_dim=8, d_ff=64, vocab_size=64,
                                ssm_state=8, ssm_head_dim=8, ssm_chunk=4,
                                attn_every=2),
}
PARAMS = {k: init_params(v, jax.random.PRNGKey(0)) for k, v in CFGS.items()}
PREAMBLE = list(range(1, 9))          # 8 shared tokens = 2 full pages


def _req(rid, *, extra=None, new=4, prompt=None):
    if prompt is None:
        prompt = PREAMBLE + (extra if extra is not None else [10 + rid])
    return Request(rid=rid, prompt=np.asarray(prompt, np.int32),
                   max_new_tokens=new)


def _batcher(family="dense", **kw):
    kw.setdefault("num_slots", 2)
    kw.setdefault("max_len", 32)
    kw.setdefault("chunk_tokens", 4)
    return ContinuousBatcher(PARAMS[family], CFGS[family], **kw)


def _supervise(batcher, **kw):
    kw.setdefault("policy", RestartPolicy(max_restarts=4, backoff_base_s=0.0))
    kw.setdefault("sleep", lambda s: None)
    return ServingSupervisor(batcher, **kw)


# ---------------------------------------------------------------------------
# the injector itself
# ---------------------------------------------------------------------------

def test_storm_schedule_is_seed_deterministic():
    a = FaultInjector.storm(seed=5, ticks=50, p_spike=0.2, p_nan=0.2,
                            crash_ticks=(7, 19))
    b = FaultInjector.storm(seed=5, ticks=50, p_spike=0.2, p_nan=0.2,
                            crash_ticks=(7, 19))
    assert a.events == b.events and len(a.events) > 2
    c = FaultInjector.storm(seed=6, ticks=50, p_spike=0.2, p_nan=0.2,
                            crash_ticks=(7, 19))
    assert a.events != c.events


def test_injector_events_fire_exactly_once():
    """One-shot semantics are what keep crash-recovery replay from
    re-raising the crash that triggered it."""
    inj = FaultInjector([FaultEvent(tick=0, kind="crash", where="pre"),
                         FaultEvent(tick=0, kind="nan_logits")])
    inj.begin_tick()
    with pytest.raises(SimulatedDeviceFailure):
        inj.maybe_crash("pre")
    inj.maybe_crash("pre")                       # replayed tick: no re-fire
    logits = np.zeros((2, 1, 8), np.float32)
    out = np.asarray(inj.corrupt_logits(logits, [0, 1]))
    assert not np.isfinite(out[:, -1]).all()
    again = np.asarray(inj.corrupt_logits(np.zeros_like(logits), [0, 1]))
    assert np.isfinite(again).all()              # consumed
    assert inj.log == [(0, "crash"), (0, "nan_logits")]


def test_injector_spike_reserves_and_releases_pool():
    inj = FaultInjector([FaultEvent(tick=1, kind="pool_spike", duration=2,
                                    pages=3)])
    pool = PagePool(num_pages=8, page_size=4)
    free0 = pool.available()
    for expect in [free0, free0 - 3, free0 - 3, free0]:
        inj.begin_tick()
        inj.pre_tick(pool)
        assert pool.available() == expect
    # reservations are ephemeral pressure: snapshots never record them
    state = pool.state()
    assert "reserved" not in state
    fresh = PagePool(num_pages=8, page_size=4)
    fresh.reserved = 5
    fresh.load_state(state)
    assert fresh.reserved == 0


def test_injector_slow_tick_uses_injected_sleep():
    inj = FaultInjector([FaultEvent(tick=0, kind="slow_tick", seconds=2.5)])
    slept = []
    inj.begin_tick()
    inj.pre_tick(None, sleep=slept.append)
    assert slept == [2.5]


# ---------------------------------------------------------------------------
# run() contract (satellite c)
# ---------------------------------------------------------------------------

def test_run_returns_report_or_raises_incomplete():
    b = _batcher(num_slots=1)
    b.submit(_req(0, new=6))
    with pytest.raises(IncompleteRunError) as ei:
        b.run(max_ticks=2)
    assert ei.value.pending == [0] and ei.value.report.ticks == 2
    report = b.run()                              # finish the drain
    assert report.completed == [0] and not report.failed
    assert b.pending_rids() == []


# ---------------------------------------------------------------------------
# load shedding + deadlines
# ---------------------------------------------------------------------------

def test_submit_sheds_with_typed_rejections():
    sup = _supervise(_batcher(num_slots=1), max_queue_depth=8,
                     shed_utilization=0.9)
    # unservable: the batcher's own validation, surfaced as a verdict
    too_long = _req(9, prompt=list(range(40)))
    v = sup.submit(too_long)
    assert not v.accepted and v.reason == "unservable"
    assert sup.submit(_req(0, new=6)).accepted
    for _ in range(3):                            # r0 into the only slot
        sup.step()
    assert sup.utilization() == 1.0
    assert sup.submit(_req(1)).accepted           # queue empty: no shed
    v = sup.submit(_req(2))                       # depth 1 + util 1.0
    assert not v.accepted and v.reason == "overloaded"
    sup.max_queue_depth = 1
    v = sup.submit(_req(3))
    assert v.reason == "queue_full" and v.queue_depth == 1
    assert len(sup.shed) == 3
    rep = sup.run()
    assert sorted(rep.completed) == [0, 1] and rep.shed == 3


def test_deadline_expiry_is_reported_not_dropped():
    sup = _supervise(_batcher(num_slots=1))
    sup.submit(_req(0, new=6))
    doomed = _req(1)
    sup.submit(doomed, ttl_ticks=1)               # expires while queued
    rep = sup.run()
    assert rep.expired == [1] and rep.failed == {1: "deadline"}
    assert doomed.failed == "deadline" and not doomed.done
    assert rep.completed == [0] and rep.pending == []


# ---------------------------------------------------------------------------
# NaN sentinel + quarantine
# ---------------------------------------------------------------------------

def _drain(batcher, reqs, injector=None, **sup_kw):
    sup = _supervise(batcher, injector=injector, **sup_kw)
    for r in reqs:
        assert sup.submit(r).accepted
    rep = sup.run(max_ticks=400)
    return rep, sup


def test_nan_tick_costs_one_retry_and_nothing_else():
    clean = [_req(i, new=5) for i in range(3)]
    crep, _ = _drain(_batcher(), clean)
    inj = FaultInjector([FaultEvent(tick=3, kind="nan_logits")])
    noisy = [_req(i, new=5) for i in range(3)]
    nrep, _ = _drain(_batcher(), noisy, injector=inj)
    assert [r.output for r in noisy] == [r.output for r in clean]
    assert nrep.nan_events > 0 and not nrep.failed
    assert nrep.ticks > crep.ticks                # the retry tick is visible


def test_persistent_nan_quarantines_only_the_victim():
    clean = [_req(i, new=6) for i in range(2)]
    _drain(_batcher(), clean)
    # r0 lands in slot 0 first; hit that slot on enough consecutive decode
    # ticks to exhaust nan_retry_limit=3
    inj = FaultInjector([FaultEvent(tick=t, kind="nan_logits", slots=(0,))
                         for t in range(3, 10)])
    noisy = [_req(i, new=6) for i in range(2)]
    rep, sup = _drain(_batcher(nan_retry_limit=3), noisy, injector=inj)
    assert noisy[0].failed == "nan" and not noisy[0].done
    assert rep.failed == {0: "nan"}
    assert sup.batcher.nan_quarantined == [0]
    # the co-batched request never saw the corruption
    assert noisy[1].done and noisy[1].output == clean[1].output


# ---------------------------------------------------------------------------
# crash recovery
# ---------------------------------------------------------------------------

def test_crash_without_snapshot_propagates():
    inj = FaultInjector([FaultEvent(tick=2, kind="crash")])
    sup = _supervise(_batcher(), injector=inj)    # no ckpt, no snapshot_every
    sup.submit(_req(0))
    with pytest.raises(SimulatedDeviceFailure):
        sup.run()


def test_restart_budget_bounds_recovery():
    inj = FaultInjector([FaultEvent(tick=t, kind="crash")
                         for t in range(2, 6)])
    sup = _supervise(_batcher(), injector=inj, snapshot_every=1,
                     policy=RestartPolicy(max_restarts=2, backoff_base_s=0.0))
    sup.submit(_req(0, new=8))
    with pytest.raises(SimulatedDeviceFailure):
        sup.run()                                 # 3rd consecutive crash
    assert sup.recoveries == 2


def test_crash_recovery_in_memory_token_identical():
    clean = [_req(i, new=5) for i in range(3)]
    crep, _ = _drain(_batcher(), clean)
    inj = FaultInjector([FaultEvent(tick=4, kind="crash", where="mid")])
    noisy = [_req(i, new=5) for i in range(3)]
    nrep, _ = _drain(_batcher(), noisy, injector=inj, snapshot_every=2)
    assert [r.output for r in noisy] == [r.output for r in clean]
    assert nrep.recoveries == 1 and nrep.ticks > crep.ticks


def test_disk_snapshot_cold_restore(tmp_path):
    """Kill-and-restart: a fresh process rebuilds the batcher from disk and
    every stream that was live at the snapshot finishes token-identically."""
    clean = [_req(i, new=4) for i in range(4)]
    b = _batcher(paged=True, page_size=4, num_pages=12, prefix_cache=True)
    _drain(b, clean)
    mgr = CheckpointManager(tmp_path, keep=2)
    b2 = _batcher(paged=True, page_size=4, num_pages=12, prefix_cache=True)
    sup = _supervise(b2, ckpt=mgr, snapshot_every=3)
    noisy = [_req(i, new=4) for i in range(4)]
    for r in noisy:
        assert sup.submit(r).accepted
    for _ in range(4):                            # past one periodic snapshot
        sup.step()
    assert mgr.latest_step() is not None
    # "new process": fresh batcher + Request objects from the snapshot alone
    b3, by_rid = load_snapshot(mgr, PARAMS["dense"], CFGS["dense"])
    assert by_rid                                 # something was in flight
    sup3 = _supervise(b3)
    sup3.requests.update(by_rid)
    sup3.run(max_ticks=200)
    for rid, req in by_rid.items():
        assert req.done and req.output == clean[rid].output, rid


# ---------------------------------------------------------------------------
# fault equivalence under a seeded storm (satellite d)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", ["dense", "paged_hybrid"])
def test_storm_fault_equivalence(mode):
    family = "dense" if mode == "dense" else "hybrid_mamba"
    kw = {} if mode == "dense" else dict(paged=True, page_size=4,
                                         num_pages=17, prefix_cache=True)

    def build():
        # generous retry limit: quarantine has its own test — here every
        # non-expired request must survive the storm
        return _batcher(family, nan_retry_limit=10, **kw)

    def submit_all(sup):
        reqs = [_req(i, new=4) for i in range(4)]  # shared preamble
        for r in reqs:
            assert sup.submit(r).accepted
        doomed = _req(99)
        assert sup.submit(doomed, ttl_ticks=0).accepted
        return reqs, doomed

    sup = _supervise(build())
    clean, cdoomed = submit_all(sup)
    crep = sup.run(max_ticks=400)
    assert cdoomed.failed == "deadline"
    if mode != "dense":
        assert sup.batcher.prefix.hits > 0        # the storm must interleave
        # with real prefix-cache traffic, not an idle pool
    inj = FaultInjector.storm(seed=11, ticks=30, p_spike=0.25, p_nan=0.25,
                              crash_ticks=(5,), spike_duration=2)
    sup2 = _supervise(build(), injector=inj, snapshot_every=2)
    noisy, ndoomed = submit_all(sup2)
    nrep = sup2.run(max_ticks=400)
    fired = {k for _, k in inj.log}
    assert "crash" in fired and "nan_logits" in fired
    if mode != "dense":
        assert "pool_spike" in fired          # spikes only bite a real pool
    # every non-expired request: bit-identical to the fault-free run
    assert [r.output for r in noisy] == [r.output for r in clean]
    assert all(r.done for r in noisy)
    # expiry is reported in BOTH runs, never silently dropped
    assert ndoomed.failed == "deadline" and nrep.expired == [99]
    assert crep.expired == [99]
    assert nrep.recoveries >= 1
    assert nrep.pending == [] and crep.pending == []
