"""Per-layer QuantPlan: the allocator, heterogeneous packed trees, mixed-
precision serving identity, snapshot plan guarding, and the tp preflight.

Acceptance bars covered here:

* the allocator stays inside its HBM budget and never does worse than the
  uniform reference at the same budget (the objective is a relaxation of
  the uniform point, which is always a feasible candidate);
* a heterogeneous plan threads through ``quantize_params`` ->
  ``pack_for_serving`` with per-leaf (bits, block_size, rank) markers, and
  every leaf's packed mantissas unpack bit-identically (hypothesis storm);
* serving a mixed-plan packed tree is token-identical to the per-layer
  fake-quant (w_tilde) oracle in dense, paged, and prefix-cache modes;
* snapshots carry the plan and refuse restoration onto a tree packed
  under a different plan;
* ``validate_plan_tp`` refuses a plan whose per-leaf packing granules do
  not survive the shard split, before any weight is quantized.
"""

import jax
import numpy as np
import pytest

from repro.core import PTQConfig, quantize_params
from repro.core.allocate import (
    LayerChoice,
    QuantPlan,
    allocate_plan,
    choice_bytes,
    describe_packed_plan,
    eligible_shapes,
    error_curve,
    mixed_reference_plan,
    plan_bytes,
    plan_expected_error,
    uniform_plan,
)
from repro.core.api import pack_for_serving
from repro.models import ModelConfig, Taps, forward, init_params
from repro.serve.batching import ContinuousBatcher, Request

CFG = ModelConfig(family="dense", num_layers=2, d_model=64, num_heads=4,
                  num_kv_heads=2, d_ff=128, vocab_size=64, head_dim=16,
                  scan_layers=False)

# formats a 64/128-dim toy model can serve packed (all block_size=32)
FORMATS = ("mxint8", "mxint4", "mxint3", "mxint2_bs32")

PROMPTS = [np.asarray([1, 2, 3, 4, 9, 8], np.int32),
           np.asarray([1, 2, 3, 4, 7], np.int32),
           np.asarray([5, 5, 2], np.int32)]


def _calibrated():
    params = init_params(CFG, jax.random.PRNGKey(0))
    taps = Taps()
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                              CFG.vocab_size)
    forward(params, {"tokens": toks}, CFG, taps=taps)
    from benchmarks.common import remap_stats
    return params, remap_stats(taps.layer_stats())


def _qcfg():
    return PTQConfig(method="qera_approx", rank=8, quantizer="mxint4",
                     skip_patterns=PTQConfig().skip_patterns)


def _mixed_plan(params):
    """A deterministic genuinely-mixed plan over every eligible layer.
    Stacked leaves are assigned by their BASE path (one choice per leaf —
    slices of one stacked tensor must share mant/exp/lora shapes)."""
    qcfg = _qcfg()
    paths = sorted({p.split(":")[0]
                    for p in eligible_shapes(params, qcfg.skips)})
    assert len(paths) >= 6, paths
    ranks = (4, 8)
    return QuantPlan(
        assignments={p: LayerChoice(FORMATS[i % len(FORMATS)],
                                    ranks[i % len(ranks)])
                     for i, p in enumerate(paths)},
        default=LayerChoice("mxint4", 8), method="qera_approx")


# ---------------------------------------------------------------------------
# plan algebra: bytes, JSON, fallbacks
# ---------------------------------------------------------------------------

def test_choice_bytes_math():
    c = LayerChoice("mxint4", 8)
    # packed mantissas + one int8 exponent per 32-block + fp32 lora factors
    assert choice_bytes(64, 128, c) == \
        64 * 128 * 4 // 8 + (64 // 32) * 128 + (64 + 128) * 8 * 4
    # nominal bits, mirroring kernel_bench._weight_bytes (mxint3's 4-bit
    # HBM container costs more on disk; the budget charges the format's
    # nominal rate so uniform mxint3 and mxint4 stay distinguishable)
    c3 = LayerChoice("mxint3", 0)
    assert choice_bytes(64, 128, c3) == \
        64 * 128 * 3 // 8 + (64 // 32) * 128


def test_plan_json_roundtrip(tmp_path):
    plan = QuantPlan(assignments={"blocks/0/wq": LayerChoice("mxint8", 16),
                                  "blocks/1/wd": LayerChoice("mxint2_bs32",
                                                             64)},
                     default=LayerChoice("mxint4", 32), method="qera_exact",
                     meta={"budget_bytes": 123})
    p = tmp_path / "plan.json"
    plan.save(p)
    back = QuantPlan.load(p)
    assert back.assignments == plan.assignments
    assert back.default == plan.default
    assert back.method == plan.method
    assert back.meta["budget_bytes"] == 123


def test_plan_choice_fallback():
    c = LayerChoice("mxint8", 16)
    plan = QuantPlan(assignments={"blocks/wq": c},
                     default=LayerChoice("mxint4", 32))
    assert plan.choice("blocks/wq") == c
    # per-slice keys of a stacked leaf resolve to the base path
    assert plan.choice("blocks/wq:3") == c
    assert plan.choice("blocks/unknown") == plan.default


# ---------------------------------------------------------------------------
# error curves and the allocator
# ---------------------------------------------------------------------------

def test_error_curve_monotone_and_format_ordered():
    w = jax.random.normal(jax.random.PRNGKey(2), (64, 48)) * 0.1
    c4 = error_curve(w, None, "mxint4")
    assert len(c4) == 49                      # ranks 0..min(k,n)
    assert np.all(np.diff(c4) <= 1e-9)        # more rank never hurts
    assert c4[-1] <= 1e-9                     # full rank reconstructs exactly
    c8 = error_curve(w, None, "mxint8")
    c2 = error_curve(w, None, "mxint2_bs32")
    assert c8[0] < c4[0] < c2[0]              # more bits, less residual


def test_allocator_beats_uniform_at_equal_budget():
    params, stats = _calibrated()
    qcfg = _qcfg()
    ref = LayerChoice("mxint4", 32)
    plan = allocate_plan(params, stats, reference=ref, skips=qcfg.skips)
    shapes = eligible_shapes(params, qcfg.skips)
    budget = plan.meta["budget_bytes"]
    assert budget == plan_bytes(shapes, uniform_plan("mxint4", 32))
    assert plan.meta["plan_bytes"] <= budget
    assert plan_bytes(shapes, plan) <= budget
    mixed = plan_expected_error(params, stats, plan, skips=qcfg.skips)
    uni = plan_expected_error(params, stats, uniform_plan("mxint4", 32),
                              skips=qcfg.skips)
    assert mixed <= uni + 1e-9
    # the reported objective matches an independent re-evaluation
    assert mixed == pytest.approx(plan.meta["expected_error"], rel=1e-6)


def test_allocator_ties_stacked_slices():
    """Scanned (3-D stacked) leaves get ONE choice — per-slice choices
    cannot stack into a single mant/exp/lora leaf."""
    import dataclasses
    cfg = dataclasses.replace(CFG, scan_layers=True)
    params = init_params(cfg, jax.random.PRNGKey(0))
    plan = allocate_plan(params, {}, skips=_qcfg().skips)
    assert plan.assignments
    assert not any(":" in p for p in plan.assignments)


def test_allocator_tight_budget_downgrades():
    """Starving the budget forces cheaper formats, never an overdraft."""
    params, stats = _calibrated()
    qcfg = _qcfg()
    shapes = eligible_shapes(params, qcfg.skips)
    tight = plan_bytes(shapes, uniform_plan("mxint4", 32)) // 2
    plan = allocate_plan(params, stats, budget_bytes=tight,
                         skips=qcfg.skips)
    assert plan.meta["plan_bytes"] <= tight
    assert plan_bytes(shapes, plan) <= tight


# ---------------------------------------------------------------------------
# plan -> quantize -> pack: per-leaf markers and serving token identity
# ---------------------------------------------------------------------------

def test_mixed_plan_packs_per_leaf_markers():
    params, stats = _calibrated()
    plan = _mixed_plan(params)
    qcfg = _qcfg()
    qparams = quantize_params(params, qcfg, stats_by_path=stats, plan=plan)
    packed = pack_for_serving(qparams, qcfg, plan=plan)
    desc = describe_packed_plan(packed)
    hit = 0
    for path, entry in desc.items():
        if path not in plan.assignments or "bits" not in entry:
            continue
        want = plan.assignments[path]
        spec = want.spec()
        assert entry["bits"] == spec.bits, path
        assert entry["block_size"] == spec.block_size, path
        assert entry["rank"] == want.rank, path
        hit += 1
    assert hit >= 6          # genuinely heterogeneous, not one format
    assert len({(e["bits"], e.get("rank")) for e in desc.values()
                if "bits" in e}) > 2


def _tokens(params, cfg, **kw):
    b = ContinuousBatcher(params, cfg, num_slots=2, max_len=48, **kw)
    reqs = [Request(rid=i, prompt=p, max_new_tokens=6)
            for i, p in enumerate(PROMPTS)]
    for r in reqs:
        b.submit(r)
    b.run(max_ticks=300)
    return {r.rid: list(r.output) for r in reqs}


@pytest.mark.parametrize("mode,kw", [
    ("dense", {}),
    ("paged", {"paged": True, "page_size": 4}),
    ("prefix", {"paged": True, "page_size": 4, "prefix_cache": True}),
])
def test_mixed_plan_serving_token_identity(mode, kw):
    """Packed mixed-precision serving == the per-layer fake-quant oracle,
    token for token, in every cache mode."""
    params, stats = _calibrated()
    plan = _mixed_plan(params)
    qcfg = _qcfg()
    qparams = quantize_params(params, qcfg, stats_by_path=stats, plan=plan)
    packed = pack_for_serving(qparams, qcfg, plan=plan)
    ref = _tokens(qparams, CFG, **kw)       # w_tilde oracle
    got = _tokens(packed, CFG, **kw)
    assert got == ref
    assert all(len(v) for v in ref.values())


# ---------------------------------------------------------------------------
# snapshots carry the plan
# ---------------------------------------------------------------------------

def test_snapshot_carries_plan_and_refuses_mismatch():
    from repro.serve.supervisor import apply_state, capture_state
    params, stats = _calibrated()
    qcfg = _qcfg()
    plan = _mixed_plan(params)
    qparams = quantize_params(params, qcfg, stats_by_path=stats, plan=plan)
    packed = pack_for_serving(qparams, qcfg, plan=plan)
    uniform = pack_for_serving(
        quantize_params(params, qcfg, stats_by_path=stats), qcfg)

    kw = dict(num_slots=2, max_len=32)
    b = ContinuousBatcher(packed, CFG, **kw)
    reqs = [Request(rid=i, prompt=p, max_new_tokens=8)
            for i, p in enumerate(PROMPTS[:2])]
    for r in reqs:
        b.submit(r)
    for _ in range(4):
        b.step()
    host, dev = capture_state(b)
    assert host["quant_plan"] == describe_packed_plan(packed)
    assert host["geometry"]["spec_k"] == 0
    for _ in range(60):
        if all(r.finished for r in reqs):
            break
        b.step()
    full = {r.rid: list(r.output) for r in reqs}

    # same plan -> restore and replay identically
    b2 = ContinuousBatcher(packed, CFG, **kw)
    by_rid = apply_state(b2, host, dev)
    for _ in range(60):
        if all(r.finished for r in by_rid.values()):
            break
        b2.step()
    assert {k: list(r.output) for k, r in by_rid.items()} == full

    # different plan -> loud refusal naming the mismatch
    b3 = ContinuousBatcher(uniform, CFG, **kw)
    with pytest.raises(ValueError, match="QuantPlan"):
        apply_state(b3, host, dev)


# ---------------------------------------------------------------------------
# tp preflight: per-leaf granules
# ---------------------------------------------------------------------------

def test_validate_plan_tp_mixed():
    from repro.sharding.serving import validate_plan_tp
    ok = QuantPlan(assignments={"blocks/wo": LayerChoice("mxint2_bs32", 8)},
                   default=LayerChoice("mxint4", 32))
    # row leaf at its OWN format: k=64, tp=2 -> 32-row shards hold whole
    # 32-blocks and whole packed bytes of the 2-bit container
    validate_plan_tp({"blocks/wo": (64, 64), "blocks/wq": (64, 64)}, ok, 2)
    # k=96 shards to 48 rows — off the 32-row packed granule; the refusal
    # names the LEAF's own format, not the plan default
    with pytest.raises(ValueError, match="mxint2"):
        validate_plan_tp({"blocks/wo": (96, 64)}, ok, 2)
    with pytest.raises(ValueError, match="divide"):
        validate_plan_tp({"blocks/wq": (64, 30)},
                         uniform_plan("mxint4", 32), 4)
    # tp=1 is always a no-op
    validate_plan_tp({"blocks/wo": (96, 64)}, ok, 1)


# ---------------------------------------------------------------------------
# the static auditor accepts a plan
# ---------------------------------------------------------------------------

def test_audit_arch_heterogeneous_plan_cell():
    from repro.analysis.contracts import audit_arch
    from repro.configs import get_arch
    cfg = get_arch("minicpm-2b")
    found = audit_arch(cfg, bits=4, block_size=32, rank=32, tp=1,
                       backend="tpu", plan=mixed_reference_plan())
    assert found is not None
    assert not [v for v in found if v.severity == "error"]
    # cells are labelled as plan cells, per projection
    assert all("x plan x" in v.where for v in found)
