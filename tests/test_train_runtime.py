"""Training-substrate tests: optimizer, schedules, checkpoint/restart,
fault tolerance, data pipeline, end-to-end loss decrease."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.ckpt import CheckpointManager
from repro.data.tokenstream import DataConfig, TokenStream, make_batch, synth_tokens
from repro.models import ModelConfig, init_params
from repro.runtime.fault_tolerance import (
    RestartPolicy,
    SimulatedFailure,
    StragglerMonitor,
    plan_elastic,
    run_with_restarts,
)
from repro.train import (
    OptimizerConfig,
    adamw_update,
    init_opt_state,
    make_microbatched_train_step,
    make_schedule,
    make_train_step,
)

CFG = ModelConfig(family="dense", num_layers=2, d_model=32, num_heads=4,
                  num_kv_heads=2, d_ff=64, vocab_size=64, head_dim=8)


def _batch(key, batch=4, seq=16, vocab=64):
    toks = jax.random.randint(key, (batch, seq + 1), 0, vocab)
    return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


# ---------------------------------------------------------------------------
# optimizer + schedules
# ---------------------------------------------------------------------------

def test_adamw_reduces_quadratic():
    params = {"w": jnp.asarray([5.0, -3.0])}
    state = init_opt_state(params)
    cfg = OptimizerConfig(peak_lr=0.1, schedule="constant", warmup_steps=0,
                          weight_decay=0.0, clip_norm=0)
    for _ in range(300):
        grads = {"w": 2 * params["w"]}
        params, state, _ = adamw_update(params, grads, state, cfg)
    assert float(jnp.abs(params["w"]).max()) < 0.05


def test_schedules_shapes():
    base = OptimizerConfig(peak_lr=1.0, warmup_steps=10, total_steps=100)
    for name in ["cosine", "linear", "wsd", "constant"]:
        sched = make_schedule(dataclasses.replace(base, schedule=name))
        lrs = [float(sched(s)) for s in range(101)]
        assert lrs[0] < lrs[9] <= 1.0 + 1e-6          # warmup
        assert max(lrs) <= 1.0 + 1e-6
        if name != "constant":
            assert lrs[-1] < 0.5                      # decayed
    # WSD: flat in the middle, sharp decay at the end
    wsd = make_schedule(dataclasses.replace(base, schedule="wsd"))
    assert float(wsd(50)) == pytest.approx(1.0)
    assert float(wsd(89)) == pytest.approx(1.0)
    assert float(wsd(99)) < 0.3


def test_grad_clip_applied():
    params = {"w": jnp.ones(4)}
    state = init_opt_state(params)
    cfg = OptimizerConfig(peak_lr=0.0, clip_norm=1.0, schedule="constant",
                          warmup_steps=0)
    _, _, m = adamw_update(params, {"w": jnp.full(4, 100.0)}, state, cfg)
    assert float(m["grad_norm"]) == pytest.approx(200.0, rel=1e-4)


def test_train_step_loss_decreases():
    params = init_params(CFG, jax.random.PRNGKey(0))
    opt_cfg = OptimizerConfig(peak_lr=5e-3, schedule="constant",
                              warmup_steps=0, total_steps=100)
    step = jax.jit(make_train_step(CFG, opt_cfg))
    state = init_opt_state(params)
    batch = _batch(jax.random.PRNGKey(1))
    losses = []
    for _ in range(30):
        params, state, metrics = step(params, state, batch)
        losses.append(float(metrics["ce"]))
    assert losses[-1] < losses[0] * 0.8


def test_microbatched_matches_plain_grads():
    """Microbatched accumulation == full-batch step (same update)."""
    params = init_params(CFG, jax.random.PRNGKey(0))
    opt_cfg = OptimizerConfig(peak_lr=1e-3, schedule="constant",
                              warmup_steps=0, clip_norm=0.0)
    batch = _batch(jax.random.PRNGKey(2), batch=8)
    p1, _, m1 = jax.jit(make_train_step(CFG, opt_cfg))(
        params, init_opt_state(params), batch)
    p2, _, m2 = jax.jit(make_microbatched_train_step(CFG, opt_cfg, 4))(
        params, init_opt_state(params), batch)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-5)


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    tree = {"params": {"w": jnp.arange(6.0).reshape(2, 3)},
            "opt": {"m": jnp.zeros(3), "step": jnp.asarray(7)}}
    mgr.save(5, tree, extra={"data_step": 5})
    step, loaded, extra = mgr.restore()
    assert step == 5 and extra["data_step"] == 5
    np.testing.assert_array_equal(np.asarray(loaded["params"]["w"]),
                                  np.asarray(tree["params"]["w"]))


def test_checkpoint_keep_k_gc(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    for s in [1, 2, 3, 4]:
        mgr.save(s, {"x": jnp.asarray([s])})
    assert mgr.all_steps() == [3, 4]


def test_checkpoint_async(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=3, async_save=True)
    mgr.save(1, {"x": jnp.ones(4)})
    mgr.wait()
    step, tree, _ = mgr.restore()
    assert step == 1
    np.testing.assert_array_equal(np.asarray(tree["x"]), np.ones(4))


def test_resume_is_bitwise_deterministic(tmp_path):
    """Train 10 steps; vs train 5, checkpoint, restore, train 5 — identical."""
    opt_cfg = OptimizerConfig(peak_lr=1e-3, schedule="cosine",
                              warmup_steps=2, total_steps=10)
    step_fn = jax.jit(make_train_step(CFG, opt_cfg))
    dc = DataConfig(vocab_size=CFG.vocab_size, seq_len=16, global_batch=4)

    def run(n0, n1, params, state):
        for s in range(n0, n1):
            b = {k: jnp.asarray(v) for k, v in make_batch(dc, s).items()}
            params, state, _ = step_fn(params, state, b)
        return params, state

    p0 = init_params(CFG, jax.random.PRNGKey(0))
    pa, sa = run(0, 10, p0, init_opt_state(p0))

    pb, sb = run(0, 5, p0, init_opt_state(p0))
    mgr = CheckpointManager(tmp_path)
    mgr.save(5, {"params": pb, "opt_state": sb})
    _, tree, _ = mgr.restore()
    pc, sc = run(5, 10, tree["params"], tree["opt_state"])

    for a, b in zip(jax.tree.leaves(pa), jax.tree.leaves(pc)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# fault tolerance / elasticity
# ---------------------------------------------------------------------------

def test_straggler_monitor_flags_slow_host():
    mon = StragglerMonitor(threshold=1.5, warmup_steps=2)
    for _ in range(5):
        for h in ["h0", "h1", "h2", "h3"]:
            mon.record(h, 1.0 if h != "h2" else 3.0)
    assert mon.stragglers() == ["h2"]


def test_straggler_median_even_count():
    """Even host counts: the median is the mean of the two middle EMAs —
    the old upper-middle pick biased the fleet median high and let genuine
    stragglers hide under the inflated threshold."""
    mon = StragglerMonitor(threshold=1.5, warmup_steps=1)
    for h, v in [("h0", 1.0), ("h1", 1.0), ("h2", 10.0), ("h3", 10.0)]:
        mon.record(h, v)
    assert mon.median() == pytest.approx(5.5)   # not 10.0 (upper-middle)
    mon2 = StragglerMonitor(threshold=1.5, warmup_steps=1)
    for h, v in [("h0", 1.0), ("h1", 1.0), ("h2", 1.0), ("h3", 2.0)]:
        mon2.record(h, v)
    # with the biased median (1.0 vs correct 1.0) h3 flags either way, but
    # a 6-host fleet where the two middles straddle the gap must use both:
    mon3 = StragglerMonitor(threshold=1.5, warmup_steps=1)
    for i, v in enumerate([1.0, 1.0, 1.0, 3.0, 3.0, 3.0]):
        mon3.record(f"h{i}", v)
    assert mon3.median() == pytest.approx(2.0)
    assert mon3.stragglers() == []              # 3.0 == 1.5 * 2.0, not >
    assert mon2.median() == pytest.approx(1.0)


def test_restart_backoff_jitter():
    base = RestartPolicy(backoff_base_s=0.1, backoff_cap_s=10.0)
    assert base.backoff(3) == pytest.approx(0.8)      # default: exact 2^k
    jit = RestartPolicy(backoff_base_s=0.1, backoff_cap_s=10.0,
                        jitter=0.25, seed=7)
    delays = [jit.backoff(a) for a in range(6)]
    # deterministic: same (seed, attempt) -> same delay
    assert delays == [jit.backoff(a) for a in range(6)]
    # bounded: within +-25% of the un-jittered schedule, never negative
    for a, d in enumerate(delays):
        pure = min(0.1 * 2 ** a, 10.0)
        assert 0.75 * pure - 1e-12 <= d <= 1.25 * pure + 1e-12
    # distinct seeds de-synchronize (thundering-herd avoidance)
    other = RestartPolicy(backoff_base_s=0.1, backoff_cap_s=10.0,
                          jitter=0.25, seed=8)
    assert any(abs(a - b) > 1e-9 for a, b in
               zip(delays, (other.backoff(k) for k in range(6))))


def test_restart_recovers_through_failures(tmp_path):
    mgr = CheckpointManager(tmp_path)
    mgr.save(0, {"x": jnp.zeros(1)})
    calls = {"n": 0}

    def loop(start):
        calls["n"] += 1
        for s in range(start, 10):
            if calls["n"] < 3 and s == 4 + calls["n"]:
                raise SimulatedFailure("boom")
            mgr.save(s + 1, {"x": jnp.asarray([float(s + 1)])})
        return 10

    final = run_with_restarts(
        loop, restore_step=lambda: mgr.latest_step() or 0,
        policy=RestartPolicy(max_restarts=5, backoff_base_s=0.0),
        sleep=lambda _: None)
    assert final == 10 and mgr.latest_step() == 10 and calls["n"] == 3


def test_elastic_plan():
    plan = plan_elastic(384, model_parallel=16, global_batch=256)
    assert plan.model == 16 and plan.data == 16          # 256 <= 384 survivors
    plan = plan_elastic(200, model_parallel=16, global_batch=256)
    assert plan.devices <= 200 and plan.data == 8
    with pytest.raises(AssertionError):
        plan_elastic(8, model_parallel=16, global_batch=256)


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def test_data_deterministic_and_host_sharded():
    dc = DataConfig(vocab_size=64, seq_len=32, global_batch=8)
    a = synth_tokens(dc, step=3)
    b = synth_tokens(dc, step=3)
    np.testing.assert_array_equal(a, b)
    h0 = dataclasses.replace(dc, num_hosts=2, host_id=0)
    h1 = dataclasses.replace(dc, num_hosts=2, host_id=1)
    assert not np.array_equal(synth_tokens(h0, 0), synth_tokens(h1, 0))
    assert synth_tokens(h0, 0).shape == (4, 33)


def test_data_has_learnable_structure():
    """Successor rule ⇒ bigram-predictable > (1 - noise) of the time."""
    dc = DataConfig(vocab_size=64, seq_len=256, global_batch=8, noise=0.15)
    toks = synth_tokens(dc, 0)
    pred = (toks[:, :-1] * 7 + 13) % 64
    acc = np.mean(pred == toks[:, 1:])
    assert acc > 0.75


def test_tokenstream_prefetch_and_state():
    dc = DataConfig(vocab_size=32, seq_len=8, global_batch=2)
    st = TokenStream(dc, start_step=5)
    b1 = next(st)
    assert st.step == 6
    st.close()
    np.testing.assert_array_equal(b1["tokens"], make_batch(dc, 5)["tokens"])


def test_audio_batches():
    dc = DataConfig(vocab_size=32, seq_len=8, global_batch=2, num_codebooks=4)
    b = make_batch(dc, 0)
    assert b["tokens"].shape == (2, 4, 8)
