"""Self-speculative decoding: deterministic tier-1 suite.

Layered like the feature (ISSUE 9):

* mantissa-plane math — packed plane extraction and the draft dequantizer
  against bit-exact oracles built from the PR 3 unpack path;
* draft kernel — ``quantized_matmul_draft`` (packed + flat, prefill +
  decode routing) against the host draft-dequant matmul;
* draft param view — ``make_draft_params`` structural contract (zero-copy
  leaves, lora dropped/kept, per-layer clamping, eager-only);
* engine — ``scan_generate(spec_k>0)`` bit-identical to ``spec_k=0``
  (dense + paged), spec stats accounting, the recurrent-family gate;
* batcher — ``ContinuousBatcher(spec_k>0)`` bit-identical across dense /
  paged / paged+prefix, under a NaN+crash fault storm, and on recurrent
  families where partial accepts exercise the restore+replay path;
* contracts — the draft/verify launches satisfy the static kernel
  contracts the analyzer audits in CI;
* tp — the subprocess worker's ``spec`` mode (8 forced host devices, per
  the XLA-flags isolation rule) re-proves identity at tp=2.

Everything here runs without hypothesis; the property-storm versions of
the batcher laws live in tests/test_speculative_property.py.
"""

import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import PTQConfig, quantize_params
from repro.core.api import pack_for_serving
from repro.models import ModelConfig, Taps, forward, init_params
from repro.quant.mxint import (
    container_bits,
    draft_shift,
    elems_per_byte,
    mxint_draft_dequantize,
    mxint_quantize,
    pack_fields,
    pack_mantissa,
    unpack_fields,
    unpack_fields_plane,
)
from repro.kernels.ops import quantized_matmul_draft
from repro.serve.batching import ContinuousBatcher, Request
from repro.serve.engine import scan_generate
from repro.serve.speculative import make_draft_params

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

DENSE_CFG = ModelConfig(family="dense", num_layers=2, d_model=64,
                        num_heads=4, num_kv_heads=2, d_ff=128,
                        vocab_size=64, head_dim=16, scan_layers=False)
HYBRID_CFG = ModelConfig(family="hybrid_mamba", num_layers=4, d_model=32,
                         num_heads=4, num_kv_heads=2, head_dim=8, d_ff=64,
                         vocab_size=64, ssm_state=8, ssm_head_dim=8,
                         ssm_chunk=4, attn_every=2, scan_layers=False)
_RECURRENT_SKIPS = PTQConfig().skip_patterns + (r"d_skip", r"mu_",
                                                r"bonus", r"ln_")


@pytest.fixture(scope="module")
def packed_dense():
    params = init_params(DENSE_CFG, jax.random.PRNGKey(0))
    taps = Taps()
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                              DENSE_CFG.vocab_size)
    forward(params, {"tokens": toks}, DENSE_CFG, taps=taps)
    from benchmarks.common import remap_stats
    qcfg = PTQConfig(method="qera_approx", rank=8, quantizer="mxint4")
    return pack_for_serving(
        quantize_params(params, qcfg,
                        stats_by_path=remap_stats(taps.layer_stats())), qcfg)


@pytest.fixture(scope="module")
def packed_hybrid():
    params = init_params(HYBRID_CFG, jax.random.PRNGKey(2))
    qcfg = PTQConfig(method="zeroquant_v2", rank=4, quantizer="mxint4",
                     skip_patterns=_RECURRENT_SKIPS)
    return pack_for_serving(quantize_params(params, qcfg), qcfg)


# ---------------------------------------------------------------------------
# mantissa-plane math
# ---------------------------------------------------------------------------

def test_draft_shift_is_container_relative():
    assert draft_shift(4, 2) == 2
    assert draft_shift(4, 4) == 0
    # the 3-bit format stores 4-bit containers: the plane shift counts
    # from the CONTAINER top, keeping packed and flat paths identical
    assert draft_shift(3, 2) == 2
    assert draft_shift(2, 2) == 0
    assert draft_shift(8, 4) == 4
    with pytest.raises(ValueError):
        draft_shift(4, 5)
    with pytest.raises(ValueError):
        draft_shift(4, 0)


@pytest.mark.parametrize("bits", [2, 3, 4, 8])
def test_unpack_fields_plane_matches_shifted_unpack(bits):
    w = container_bits(bits)
    epb = elems_per_byte(bits)
    rng = np.random.default_rng(bits)
    lo, hi = -(2 ** (w - 1)), 2 ** (w - 1)
    mant = jnp.asarray(rng.integers(lo, hi, size=(64, 16)), jnp.int8)
    packed = pack_fields(mant, epb)
    for db in range(1, w + 1):
        plane = unpack_fields_plane(packed, epb, db, k=64)
        oracle = unpack_fields(packed, epb, k=64).astype(jnp.int32) >> (
            w - db)
        np.testing.assert_array_equal(np.asarray(plane),
                                      np.asarray(oracle, np.int8))


def test_draft_dequantize_full_plane_is_full_dequant():
    # draft_bits == container width => shift 0 => the draft IS the full
    # mantissa at the full scale
    w = jax.random.normal(jax.random.PRNGKey(3), (64, 32)) * 0.3
    mant, exp = mxint_quantize(w, 4, 32)
    mant = mant.reshape(64, 32)
    full = mxint_draft_dequantize(mant, exp, 4, 4)
    scale = jnp.exp2(exp.astype(jnp.float32) - 2)
    oracle = mant.astype(jnp.float32) * jnp.repeat(scale, 32, axis=-2)
    np.testing.assert_array_equal(np.asarray(full), np.asarray(oracle))


# ---------------------------------------------------------------------------
# draft kernel vs host oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m", [4, 32])          # decode + prefill routing
@pytest.mark.parametrize("bits,draft_bits", [(4, 2), (4, 4), (3, 2),
                                             (2, 2)])
def test_quantized_matmul_draft_matches_oracle(m, bits, draft_bits):
    k, n, bs = 128, 96, 32
    keys = jax.random.split(jax.random.PRNGKey(7), 2)
    x = jax.random.normal(keys[0], (m, k), jnp.float32)
    w = jax.random.normal(keys[1], (k, n), jnp.float32) * 0.2
    mant, exp = mxint_quantize(w, bits, bs)
    mant = mant.reshape(k, n)
    oracle = x @ mxint_draft_dequantize(mant, exp, bits, draft_bits)
    for buf in (mant, pack_mantissa(mant, bits)):     # flat + packed HBM
        y = quantized_matmul_draft(x, buf, exp, bits=bits, block_size=bs,
                                   draft_bits=draft_bits, interpret=True)
        np.testing.assert_allclose(np.asarray(y), np.asarray(oracle),
                                   rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# draft param view
# ---------------------------------------------------------------------------

def test_make_draft_params_structure(packed_dense):
    draft = make_draft_params(packed_dense, draft_bits=2)
    flat_full = dict(jax.tree_util.tree_flatten_with_path(packed_dense)[0])
    found = []

    def walk(full, d):
        if isinstance(d, dict) and "draft_bits" in d:
            found.append(d)
            assert "lora_a" not in d and "lora_b" not in d
            assert d["mant"] is full["mant"]          # zero-copy view
            assert d["exp"] is full["exp"]
            assert int(d["draft_bits"]) == min(
                2, container_bits(int(full["bits"])))
            assert int(d["draft_shift"]) == draft_shift(
                int(full["bits"]), int(d["draft_bits"]))
            return
        if isinstance(d, dict):
            for kk in d:
                walk(full[kk], d[kk])
            return
        assert d is full                              # plain leaves pass

    walk(packed_dense, draft)
    assert found, "no packed projection became a draft view"
    assert flat_full  # the full tree is untouched (no in-place edits)

    kept = make_draft_params(packed_dense, draft_bits=2, skip_lowrank=False)

    def has_lora(d):
        if isinstance(d, dict):
            return "lora_a" in d or any(has_lora(v) for v in d.values())
        return False

    assert has_lora(kept)
    with pytest.raises(ValueError):
        make_draft_params(packed_dense, draft_bits=0)


# ---------------------------------------------------------------------------
# engine
# ---------------------------------------------------------------------------

def _prompt(b=2, s=8, seed=3):
    return jax.random.randint(jax.random.PRNGKey(seed), (b, s), 0,
                              DENSE_CFG.vocab_size)


def test_engine_spec_identity_and_stats(packed_dense):
    prompt = _prompt()
    ref = np.asarray(scan_generate(packed_dense, DENSE_CFG, prompt, 10))
    for k in (2, 4):
        for db in (2, 4):
            toks, stats = scan_generate(packed_dense, DENSE_CFG, prompt, 10,
                                        spec_k=k, draft_bits=db,
                                        return_spec_stats=True)
            assert np.array_equal(ref, np.asarray(toks)), (k, db)
            assert stats["rounds"] > 0
            # k drafts per live sequence per round
            assert stats["drafted"] == prompt.shape[0] * k * stats["rounds"]
            assert 0 <= stats["accepted"] <= stats["drafted"]


def test_engine_spec_identity_paged(packed_dense):
    prompt = _prompt()
    ref = np.asarray(scan_generate(packed_dense, DENSE_CFG, prompt, 10))
    toks = scan_generate(packed_dense, DENSE_CFG, prompt, 10, spec_k=4,
                         draft_bits=4, page_size=8, prefill_chunk=4)
    assert np.array_equal(ref, np.asarray(toks))


def test_engine_spec_rejects_recurrent(packed_hybrid):
    prompt = jax.random.randint(jax.random.PRNGKey(4), (2, 8), 0,
                                HYBRID_CFG.vocab_size)
    with pytest.raises(ValueError, match="KV-only"):
        scan_generate(packed_hybrid, HYBRID_CFG, prompt, 4, spec_k=2)


# ---------------------------------------------------------------------------
# batcher
# ---------------------------------------------------------------------------

def _reqs(cfg, n=5, seed=0, max_new=6):
    rng = np.random.default_rng(seed)
    pre = rng.integers(0, cfg.vocab_size, size=16).astype(np.int32)
    out = []
    for i in range(n):
        tail = rng.integers(0, cfg.vocab_size,
                            size=int(rng.integers(3, 12))).astype(np.int32)
        p = np.concatenate([pre, tail]) if i % 2 else tail
        out.append(Request(rid=i, prompt=p, max_new_tokens=max_new))
    return out


def _serve(params, cfg, **kw):
    b = ContinuousBatcher(params, cfg, num_slots=3, max_len=48, **kw)
    reqs = _reqs(cfg)
    for r in reqs:
        b.submit(r)
    rep = b.run()
    return {r.rid: list(r.output) for r in reqs}, rep, b


@pytest.mark.parametrize("kw", [{}, {"paged": True, "page_size": 8},
                                {"paged": True, "page_size": 8,
                                 "prefix_cache": True}],
                         ids=["dense", "paged", "prefix"])
def test_batcher_spec_identity(packed_dense, kw):
    ref, rep0, _ = _serve(packed_dense, DENSE_CFG, **kw)
    got, rep, b = _serve(packed_dense, DENSE_CFG, spec_k=4, draft_bits=4,
                         debug_invariants=bool(kw.get("paged")), **kw)
    assert got == ref
    assert rep.spec_rounds > 0 and rep.spec_drafted > 0
    assert 0 <= rep.spec_accepted <= rep.spec_drafted
    assert rep.spec_committed >= rep.spec_rounds      # >= 1 token per round
    assert rep0.spec_rounds == 0                      # spec_k=0 runs clean


def test_batcher_spec_low_precision_draft(packed_dense):
    # draft_bits=2 rejects heavily — identity must hold on the
    # reject-dominated path too (rollback via verify overwrite)
    ref, _, _ = _serve(packed_dense, DENSE_CFG, paged=True, page_size=8)
    got, rep, _ = _serve(packed_dense, DENSE_CFG, paged=True, page_size=8,
                         spec_k=2, draft_bits=2, debug_invariants=True)
    assert got == ref
    assert rep.spec_rounds > 0


def test_batcher_spec_negative_raises(packed_dense):
    with pytest.raises(ValueError):
        ContinuousBatcher(packed_dense, DENSE_CFG, num_slots=2, max_len=32,
                          spec_k=-1)


def test_batcher_spec_fault_storm_identity(packed_dense):
    from repro.runtime.fault_tolerance import RestartPolicy
    from repro.serve.faults import FaultInjector
    from repro.serve.supervisor import ServingSupervisor

    kw = dict(paged=True, page_size=8, num_pages=23, prefix_cache=True,
              nan_retry_limit=10)
    ref, _, _ = _serve(packed_dense, DENSE_CFG, **kw)

    b = ContinuousBatcher(packed_dense, DENSE_CFG, num_slots=3, max_len=48,
                          spec_k=4, draft_bits=4, debug_invariants=True,
                          **kw)
    sup = ServingSupervisor(
        b, injector=FaultInjector.storm(seed=7, ticks=30, p_spike=0.2,
                                        p_nan=0.2, crash_ticks=(5,),
                                        spike_duration=2),
        snapshot_every=2,
        policy=RestartPolicy(max_restarts=4, backoff_base_s=0.0),
        sleep=lambda _: None)
    reqs = _reqs(DENSE_CFG)
    for r in reqs:
        assert sup.submit(r).accepted
    sup.run(max_ticks=500)
    assert {r.rid: list(r.output) for r in reqs} == ref


@pytest.mark.parametrize("kw", [{}, {"paged": True, "page_size": 8}],
                         ids=["dense", "paged"])
def test_batcher_spec_recurrent_replay(packed_hybrid, kw):
    # low-precision drafts on a recurrent family force partial accepts:
    # every rejected span exercises the restore+replay of the SSM rows
    ref, _, _ = _serve(packed_hybrid, HYBRID_CFG, **kw)
    got, rep, _ = _serve(packed_hybrid, HYBRID_CFG, spec_k=2, draft_bits=2,
                         debug_invariants=bool(kw.get("paged")), **kw)
    assert got == ref
    assert rep.spec_rounds > 0


# ---------------------------------------------------------------------------
# static contracts
# ---------------------------------------------------------------------------

def test_draft_launches_satisfy_contracts():
    from repro.analysis.contracts import (audit_arch,
                                          audit_quantized_matmul_draft)
    from repro.configs import get_arch

    for m in (4, 24):                     # decode + verify-chunk shapes
        errs = [v for v in audit_quantized_matmul_draft(
                    m, 4096, 4096, bits=4, block_size=32, where="test")
                if v.severity == "error"]
        assert not errs, errs
    found = audit_arch(get_arch("yi-34b"), bits=4, block_size=32, tp=2,
                       spec_k=4)
    assert found is not None
    assert not [v for v in found if v.severity == "error"], found


# ---------------------------------------------------------------------------
# tensor parallel (subprocess, 8 forced devices)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_tp_spec_identity():
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"),
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               JAX_PLATFORMS="cpu")
    out = subprocess.run(
        [sys.executable, os.path.join(REPO, "tests", "_tp_worker.py"),
         "spec"],
        capture_output=True, text=True, env=env, cwd=REPO, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res == {k: True for k in res}, res
