"""repro.analysis: every documented QERA code demonstrated by a failing
fixture AND a fixed twin, plus the analyzer-clean sweep over the registry,
the latent-finding regressions the auditor surfaced, and the runtime
(debug_invariants) checkers.
"""

import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.analysis import (CODES, audit_arch, audit_decode_attention,
                            audit_matmul_launch, audit_quantize_weights,
                            audit_quantized_matmul, bucketing_violations,
                            callback_violations, donation_violations,
                            lint_paths, lint_source, psum_violations,
                            strict_audit)
from repro.analysis.lint import DEFAULT_LINT_PATHS
from repro.analysis.runtime import (check_page_accounting,
                                    check_protected_writes)
from repro.configs import ASSIGNED_ARCHS, get_arch
from repro.quant.mxint import MXINT_CONFIGS

ROOT = os.path.join(os.path.dirname(__file__), "..")


def codes(violations, severity=None):
    return {v.code for v in violations
            if severity is None or v.severity == severity}


# -- QERA001: VMEM budget ---------------------------------------------------

def test_vmem_overflow_flagged_and_fixed():
    kw = dict(bits=4, block_size=32, decode=False)
    bad = audit_matmul_launch(4096, 8192, 8192, 64, bm=2048, bn=2048,
                              bk=128, **kw)
    assert "QERA001" in codes(bad, "error")
    assert any("pick_blocks" in v.suggestion for v in bad)
    good = audit_matmul_launch(4096, 8192, 8192, 64, bm=128, bn=128,
                               bk=128, **kw)
    assert "QERA001" not in codes(good)


def test_vmem_interpret_backend_has_no_budget():
    out = audit_matmul_launch(4096, 8192, 8192, 64, bits=4, block_size=32,
                              bm=2048, bn=2048, bk=128, decode=False,
                              backend="interpret")
    assert "QERA001" not in codes(out)


# -- QERA002: sublane/lane alignment ---------------------------------------

def test_misaligned_bm_flagged_and_fixed():
    kw = dict(bits=4, block_size=32, bn=128, bk=128, decode=False)
    bad = audit_matmul_launch(288, 256, 256, 8, bm=36, **kw)
    assert "QERA002" in codes(bad, "error")  # Mosaic rejects bm=36
    good = audit_matmul_launch(288, 256, 256, 8, bm=32, **kw)
    assert "QERA002" not in codes(good, "error")


# -- QERA003: packed/exponent divisibility ----------------------------------

def test_untileable_k_flagged_and_fixed():
    bad = audit_quantized_matmul(8, 40, 128, 8, bits=4, block_size=32)
    assert "QERA003" in codes(bad, "error")
    good = audit_quantized_matmul(8, 64, 128, 8, bits=4, block_size=32)
    assert "QERA003" not in codes(good)


def test_gqa_indivisible_heads_flagged():
    bad = audit_decode_attention(4, 12, 5, 64, page_size=32, npages=8)
    assert "QERA003" in codes(bad, "error")
    good = audit_decode_attention(4, 12, 4, 64, page_size=32, npages=8)
    assert "QERA003" not in codes(good)


# -- QERA004: grid sanity ----------------------------------------------------

def test_empty_grid_flagged_and_fixed():
    bad = audit_decode_attention(4, 8, 8, 64, page_size=32, npages=0)
    assert "QERA004" in codes(bad, "error")
    good = audit_decode_attention(4, 8, 8, 64, page_size=32, npages=4)
    assert "QERA004" not in codes(good)


# -- QERA011: psum count/placement ------------------------------------------

def test_psum_contract_pure_checker():
    kw = dict(num_layers=4, where="t")
    # missing both all-reduces at tp=2
    assert "QERA011" in codes(psum_violations(0, 0, tp=2, scan=True, **kw))
    # contract met: 2 in the scan body, none outside
    assert not psum_violations(2, 0, tp=2, scan=True, **kw)
    # right count, wrong placement (outside the scan body)
    assert "QERA011" in codes(psum_violations(0, 2, tp=2, scan=True, **kw))
    # unrolled wants 2 * num_layers
    assert not psum_violations(0, 8, tp=2, scan=False, **kw)
    assert "QERA011" in codes(psum_violations(0, 2, tp=2, scan=False, **kw))
    # tp=1 must not pay any collective
    assert "QERA011" in codes(psum_violations(2, 0, tp=1, scan=True, **kw))
    assert not psum_violations(0, 0, tp=1, scan=True, **kw)


# -- QERA012: donation -------------------------------------------------------

def test_donation_flagged_and_fixed():
    import jax.numpy as jnp
    x = jnp.zeros((8, 128), jnp.float32)

    def not_donatable(a):           # dtype changes: XLA drops the alias
        return a.astype(jnp.bfloat16)

    def donatable(a):
        return a + 1

    with pytest.warns(UserWarning, match="donated"):
        bad = donation_violations(not_donatable, (x,), donate_argnums=(0,),
                                  where="t")
    assert "QERA012" in codes(bad, "error")
    assert not donation_violations(donatable, (x,), donate_argnums=(0,),
                                   where="t")


# -- QERA013: host callbacks in a traced step --------------------------------

def test_callback_flagged_and_fixed():
    import jax
    import jax.numpy as jnp

    def with_cb(x):
        return jax.pure_callback(
            lambda v: np.asarray(v), jax.ShapeDtypeStruct(x.shape, x.dtype),
            x) + 1

    def clean(x):
        return x + 1

    x = jnp.zeros((4,), jnp.float32)
    assert "QERA013" in codes(
        callback_violations(jax.make_jaxpr(with_cb)(x), where="t"))
    assert not callback_violations(jax.make_jaxpr(clean)(x), where="t")


# -- QERA014: recompilation storms -------------------------------------------

def test_bucketing_flagged_and_fixed():
    from repro.serve.paging import page_bucket
    bad = bucketing_violations(lambda n: n, range(1, 257), name="identity",
                               where="t")
    assert "QERA014" in codes(bad, "error")
    good = bucketing_violations(lambda n: page_bucket(n, 256),
                                range(1, 257), name="page_bucket", where="t")
    assert not good


# -- QERA021-025: the AST lint ----------------------------------------------

SERVE = "src/repro/serve/x.py"
KERNELS = "src/repro/kernels/x.py"


def test_lint_host_sync_in_hot_path():
    bad = ("import jax\n"
           "@jax.jit\n"
           "def step(x):\n"
           "    return float(x.sum())\n")
    assert "QERA021" in codes(lint_source(bad, SERVE))
    good = ("import jax\n"
            "@jax.jit\n"
            "def step(x):\n"
            "    return x.sum()\n")
    assert not lint_source(good, SERVE)


def test_lint_item_on_traced_value():
    bad = ("import jax\n"
           "def make_step():\n"
           "    def step(x):\n"
           "        return x.item()\n"
           "    return jax.jit(step)\n")
    assert "QERA021" in codes(lint_source(bad, SERVE))


def test_lint_pool_internals_mutated_outside_pool():
    bad = ("def steal(pool):\n"
           "    pool._refs[3] = 0\n"
           "    pool._free.append(3)\n")
    assert "QERA022" in codes(lint_source(bad, SERVE))
    good = ("class PagePool:\n"
            "    def release(self):\n"
            "        self._refs[3] = 0\n"
            "        self._free.append(3)\n")
    assert not lint_source(good, SERVE)


def test_lint_cow_bypass():
    # pool-leaf writes are allowed ONLY inside serve/paging.py (where the
    # jitted helpers + CoW guard live); anywhere else in serve/ they bypass
    # the fork
    src = ("def write(cache, x):\n"
           "    k_pages = cache\n"
           "    return k_pages.at[0].set(x)\n")
    assert "QERA023" in codes(
        lint_source(src, "src/repro/serve/batching.py"))
    assert not lint_source(src, "src/repro/serve/paging.py")
    fork = ("def admit(self, page):\n"
            "    return self._fork(page)\n")
    assert "QERA023" in codes(
        lint_source(fork, "src/repro/serve/batching.py"))
    guarded = ("def _cow_fork(self, page):\n"
               "    return self._fork(page)\n")
    assert not lint_source(guarded, "src/repro/serve/batching.py")


def test_lint_unseeded_randomness():
    bad = "import numpy as np\nRNG = np.random.default_rng()\n"
    assert "QERA024" in codes(lint_source(bad, SERVE))
    good = "import numpy as np\nRNG = np.random.default_rng(11)\n"
    assert not lint_source(good, SERVE)


def test_lint_unannotated_pallas_call():
    bad = ("import jax.experimental.pallas as pl\n"
           "def launch(k, grid):\n"
           "    return pl.pallas_call(k, grid=grid)\n")
    assert "QERA025" in codes(lint_source(bad, KERNELS))
    good = ("import jax.experimental.pallas as pl\n"
            "def launch(k, grid):\n"
            "    # contract: flash_attention\n"
            "    return pl.pallas_call(k, grid=grid)\n")
    assert not lint_source(good, KERNELS)


def test_repo_hot_path_is_lint_clean():
    assert lint_paths(list(DEFAULT_LINT_PATHS), root=ROOT) == []


# -- the registry sweep ------------------------------------------------------

@pytest.mark.parametrize("fmt", ["mxint4", "mxint3", "mxint2"])
def test_registry_sweep_error_free(fmt):
    """CI acceptance: no error-severity violation anywhere in the
    serviceable registry x format x tp matrix."""
    spec = MXINT_CONFIGS[fmt]
    for arch in ASSIGNED_ARCHS:
        cfg = get_arch(arch)
        for tp in (1, 2, 4):
            found = audit_arch(cfg, bits=spec.bits,
                               block_size=spec.block_size, tp=tp)
            if found is None:
                continue                  # clean refusal (validate_tp)
            errs = [v for v in found if v.severity == "error"]
            assert not errs, (arch, fmt, tp, [str(v) for v in errs])


@pytest.mark.parametrize(
    "arch", ["command-r-plus-104b", "phi3.5-moe-42b-a6.6b",
             "llama4-maverick-400b-a17b"])
def test_never_swept_archs_latent_findings(arch):
    """The archs PR 7 never exercised, whose GQA sublane waste (G not a
    multiple of 8) the auditor originally surfaced as warnings.
    `pick_kv_block` now groups KV heads per grid step so the launched
    q-tile is sublane-aligned — the decode-attention warnings are gone by
    construction (tests/test_gqa_tiles.py pins the kernel side) and the
    archs stay error-free."""
    cfg = get_arch(arch)
    found = audit_arch(cfg, bits=4, block_size=32, tp=1)
    assert found is not None
    assert not [v for v in found if v.severity == "error"]
    warns = [v for v in found
             if v.code == "QERA002" and "decode_attention" in v.where]
    assert not warns, f"{arch}: GQA sublane warnings should be fixed: " \
        f"{[str(v) for v in warns]}"


# -- the latent bugs the auditor caught --------------------------------------

def test_pick_blocks_rounds_prefill_bm_to_sublane_grid():
    from repro.kernels.ops import pick_blocks
    bm, bn, bk, decode = pick_blocks(288, 256, 256, block_size=32,
                                     block_m=36)
    assert not decode and bm % 8 == 0 and bm == 32
    # decode regime is untouched by the cap rounding
    bm, _, _, decode = pick_blocks(8, 256, 256, block_size=32, block_m=36)
    assert decode and bm == 8


def test_quantize_vocab_not_lane_aligned_stays_in_budget():
    from repro.kernels.ops import pick_quant_bn
    n = 202048                      # llama4-maverick vocab: % 128 == 64
    bn = pick_quant_bn(n)
    assert n % bn == 0 and bn <= 2048 and bn % 8 == 0
    out = audit_quantize_weights(4096, n, bits=4, block_size=32)
    assert "QERA001" not in codes(out)


# -- the strict startup gate -------------------------------------------------

def test_strict_audit_refuses_mis_sharded_config():
    rep = strict_audit(get_arch("yi-34b"), tp=3)
    assert rep.errors and {v.code for v in rep.errors} == {"QERA003"}
    rep = strict_audit(get_arch("yi-34b"), tp=2)
    assert not rep.errors


def test_every_code_is_documented():
    doc = open(os.path.join(ROOT, "docs", "analysis.md")).read()
    for code in CODES:
        assert code in doc, f"{code} missing from docs/analysis.md"
    assert len(CODES) >= 8


# -- runtime (debug_invariants) checkers -------------------------------------

def test_page_accounting_detects_tampering():
    from repro.serve.paging import PagePool
    pool = PagePool(8, 4)
    pages = pool.acquire(2)
    slot_pages = [list(pages)]
    table = np.zeros((1, 4), np.int32)
    table[0, :2] = pages
    assert check_page_accounting(pool, slot_pages, table) == []
    # a page reference the pool never granted
    slot_pages[0].append(7)
    errs = check_page_accounting(pool, slot_pages, table)
    assert errs and any("refcount" in e for e in errs)


def test_protected_write_detection_respects_generation():
    prev = {1: (0, "aa"), 2: (0, "bb")}
    # page 1 rewritten under the SAME allocation generation: a CoW bypass
    assert check_protected_writes(prev, {1: (0, "XX"), 2: (0, "bb")})
    # page 1 evicted + reallocated (generation bumped): legitimate rewrite
    assert not check_protected_writes(prev, {1: (1, "XX"), 2: (0, "bb")})


def test_debug_invariants_catches_live_corruption():
    """End-to-end: a batcher with debug_invariants=True must refuse a tick
    after its page accounting is corrupted under it."""
    import jax
    from repro.models import init_params
    from repro.models.config import reduced
    from repro.serve.batching import ContinuousBatcher, Request
    cfg = reduced(get_arch("minicpm-2b"), scan_layers=False)
    params = init_params(cfg, jax.random.PRNGKey(0))
    b = ContinuousBatcher(params, cfg, num_slots=2, max_len=32, paged=True,
                          page_size=8, debug_invariants=True)
    b.submit(Request(rid=0, prompt=np.arange(5, dtype=np.int32),
                     max_new_tokens=4))
    b.step()
    b.step()
    assert b.slot_pages[0], "expected slot 0 to own pages"
    b.slot_pages[0].append(b.pool.num_pages - 1)   # never granted
    with pytest.raises(AssertionError, match="debug_invariants"):
        b.step()


# -- CLI + serve --strict (subprocess) ---------------------------------------

def _env():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env["JAX_PLATFORMS"] = "cpu"
    return env


@pytest.mark.slow
def test_cli_smoke_json_report(tmp_path):
    out = tmp_path / "report.json"
    p = subprocess.run(
        [sys.executable, "-m", "repro.analysis", "--arch", "minicpm-2b",
         "--tp", "1", "2", "--layers", "launch,lint", "--json", str(out)],
        capture_output=True, text=True, env=_env(), cwd=ROOT, timeout=560)
    assert p.returncode == 0, p.stdout + p.stderr
    rep = json.loads(out.read_text())
    assert rep["summary"]["errors"] == 0
    assert rep["cells"]


@pytest.mark.slow
def test_serve_strict_refuses_bad_tp():
    p = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--strict", "--arch",
         "yi-34b", "--tp", "3", "--platform", "cpu"],
        capture_output=True, text=True, env=_env(), cwd=ROOT, timeout=560)
    assert p.returncode == 2
    assert "QERA003" in p.stdout and "refusing to serve" in p.stdout
