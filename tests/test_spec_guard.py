"""Guard against the useless speculative configuration.

``spec_k > 0`` with ``draft_bits = 2`` accepts ~0% of drafts
(docs/speculative.md): every entry point warns loudly, and ``--strict``
serving refuses outright with exit code 2.
"""

import os
import subprocess
import sys
import warnings

import jax
import pytest

from repro.models import ModelConfig, init_params
from repro.serve.batching import ContinuousBatcher
from repro.serve.speculative import MIN_USEFUL_DRAFT_BITS, check_spec_config

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

CFG = ModelConfig(family="dense", num_layers=2, d_model=32, num_heads=4,
                  num_kv_heads=2, d_ff=64, vocab_size=64, head_dim=8)


def test_check_spec_config_verdicts():
    assert MIN_USEFUL_DRAFT_BITS == 3
    with warnings.catch_warnings():
        warnings.simplefilter("error")            # good configs stay silent
        assert check_spec_config(0, 2) is None    # spec off: anything goes
        assert check_spec_config(4, 4) is None
        assert check_spec_config(4, 3) is None
    with pytest.warns(UserWarning, match="draft_bits=2"):
        msg = check_spec_config(4, 2, where="here")
    assert msg is not None and "here" in msg and "~0%" in msg


def test_batcher_warns_on_useless_spec():
    params = init_params(CFG, jax.random.PRNGKey(0))
    with pytest.warns(UserWarning, match="ContinuousBatcher"):
        ContinuousBatcher(params, CFG, num_slots=2, max_len=32, spec_k=2,
                          draft_bits=2)
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        ContinuousBatcher(params, CFG, num_slots=2, max_len=32, spec_k=2,
                          draft_bits=4)


def test_strict_serving_refuses_useless_spec():
    """--strict exits 2 BEFORE any parameter exists, naming the guard."""
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--arch", "minicpm-2b",
         "--reduced", "--strict", "--spec-k", "4", "--draft-bits", "2"],
        capture_output=True, text=True, timeout=240,
        env={**os.environ, "PYTHONPATH": os.path.join(REPO, "src"),
             "JAX_PLATFORMS": "cpu"}, cwd=REPO)
    assert out.returncode == 2, out.stdout + out.stderr
    assert "refusing to serve" in out.stdout
    assert "draft_bits" in out.stdout
