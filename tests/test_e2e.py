"""End-to-end integration tests: the full train driver (loss decreases,
checkpoint/restart through an injected failure), and the bf16-compressed
explicit-DP step (subprocess with 8 forced devices)."""

import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.data.tokenstream import DataConfig
from repro.launch.train import train
from repro.models.config import ModelConfig
from repro.train import OptimizerConfig

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

CFG = ModelConfig(family="dense", num_layers=2, d_model=48, num_heads=4,
                  num_kv_heads=2, d_ff=96, vocab_size=128, head_dim=12)
OPT = OptimizerConfig(peak_lr=3e-3, schedule="wsd", warmup_steps=5,
                      total_steps=60)
DATA = DataConfig(vocab_size=128, seq_len=32, global_batch=8)


def test_train_driver_loss_decreases(tmp_path):
    out = train(CFG, OPT, DATA, steps=60, ckpt_dir=str(tmp_path),
                ckpt_every=20, verbose=False)
    losses = out["losses"]
    assert np.mean(losses[-10:]) < np.mean(losses[:10]) * 0.85
    assert out["final_step"] == 60


def test_train_failure_restart_resumes(tmp_path):
    from repro.runtime.fault_tolerance import SimulatedFailure
    with pytest.raises(SimulatedFailure):
        train(CFG, OPT, DATA, steps=60, ckpt_dir=str(tmp_path),
              ckpt_every=10, fail_at_step=25, verbose=False)
    out = train(CFG, OPT, DATA, steps=60, ckpt_dir=str(tmp_path),
                resume=True, ckpt_every=10, verbose=False)
    assert out["resumed_from"] == 20          # newest ckpt before the crash
    assert out["final_step"] == 60

    # resumed run must equal an uninterrupted run (bitwise)
    ref = train(CFG, OPT, DATA, steps=60, ckpt_dir=None, verbose=False)
    for a, b in zip(np.asarray(out["losses"][-5:]),
                    np.asarray(ref["losses"][-5:])):
        assert a == b


@pytest.mark.slow
def test_compressed_dp_matches_plain_subprocess():
    """bf16-compressed gradient all-reduce ≈ plain step (8 fake devices)."""
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from repro.launch.mesh import make_tiny_mesh
        from repro.models import ModelConfig, init_params
        from repro.train import (OptimizerConfig, init_opt_state,
                                 make_train_step,
                                 make_compressed_dp_train_step)
        cfg = ModelConfig(family="dense", num_layers=2, d_model=32,
                          num_heads=4, num_kv_heads=2, d_ff=64,
                          vocab_size=64, head_dim=8)
        opt = OptimizerConfig(peak_lr=1e-3, schedule="constant",
                              warmup_steps=0, clip_norm=0.0,
                              weight_decay=0.0)
        params = init_params(cfg, jax.random.PRNGKey(0))
        toks = jax.random.randint(jax.random.PRNGKey(1), (8, 17), 0, 64)
        batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
        p1, _, m1 = jax.jit(make_train_step(cfg, opt))(
            params, init_opt_state(params), batch)
        mesh = make_tiny_mesh()   # (2, 2) data x model
        with mesh:
            step = make_compressed_dp_train_step(cfg, opt, mesh)
            p2, _, m2 = jax.jit(step)(params, init_opt_state(params), batch)
        # Compare per-leaf UPDATE norms, not elements: the first Adam step
        # from init is lr * sign(g) elementwise (v = g^2), so any element
        # whose gradient rounds away in bf16 flips its whole +-lr update —
        # elementwise rtol is noise.  The compression claim is about the
        # aggregate direction: deviation small relative to the step taken.
        for p0, a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p1),
                            jax.tree.leaves(p2)):
            p0, a, b = (np.asarray(x, np.float32) for x in (p0, a, b))
            upd = np.linalg.norm(a - p0)
            dev = np.linalg.norm(a - b)
            assert dev <= 0.1 * upd + 1e-7, (dev, upd)
        assert abs(float(m1["ce"]) - float(m2["ce"])) < 0.05
        print("COMPRESSED_DP_OK")
    """)
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    out = subprocess.run([sys.executable, "-c", script], capture_output=True,
                         text=True, env=env, cwd=REPO, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "COMPRESSED_DP_OK" in out.stdout
