"""Sub-byte mantissa packing: round-trips, kernel bit-identity, granularity.

Covers the HBM layout contract end to end WITHOUT requiring hypothesis (the
guarded property modules add randomized sweeps in CI): pack -> unpack is the
identity on mantissas (including odd / non-byte-aligned K), the packed and
flat kernel paths produce BIT-IDENTICAL outputs in both grid variants, the
on-device repack kernel emits the exact layout the matmul kernels consume,
``pick_blocks`` respects the packing granularity, and the MXINT4 mantissa
buffer measures exactly K*N/2 bytes via ``.nbytes``.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ops import pick_blocks, quantize_weights, quantized_matmul
from repro.kernels.ref import mxint_matmul_lowrank_ref, mxint_quantize_ref
from repro.quant.mxint import (
    container_bits,
    elems_per_byte,
    mxint_dequantize,
    mxint_quantize,
    pack_mantissa,
    pack_mxint,
    unpack_mantissa,
    unpack_mxint,
)


# ---------------------------------------------------------------------------
# pack / unpack round-trip
# ---------------------------------------------------------------------------

def test_container_choice():
    # 3-bit rides in a 4-bit container (documented savings: 4 bits/elt);
    # 4-bit packs two per byte, 2-bit four per byte, 8-bit stays flat.
    assert [container_bits(b) for b in (8, 4, 3, 2)] == [8, 4, 4, 2]
    assert [elems_per_byte(b) for b in (8, 4, 3, 2)] == [1, 2, 2, 4]


@pytest.mark.parametrize("bits", [8, 4, 3, 2])
@pytest.mark.parametrize("k", [64, 33, 7, 96, 1])
def test_pack_unpack_roundtrip(bits, k):
    """pack -> unpack is the identity on mantissas, incl. K not divisible by
    elems_per_byte (zero-padded bytes, cropped on unpack)."""
    qmax = 2 ** (bits - 1) - 1
    mant = jax.random.randint(jax.random.PRNGKey(bits * 101 + k), (k, 5),
                              -qmax, qmax + 1, dtype=jnp.int32).astype(jnp.int8)
    packed = pack_mantissa(mant, bits)
    epb = elems_per_byte(bits)
    assert packed.shape == (-(-k // epb), 5)
    out = unpack_mantissa(packed, bits, k)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(mant))


def test_pack_roundtrip_stacked_leading_dims():
    """3-D (stacked-layer) leaves pack along the input axis too."""
    mant = jax.random.randint(jax.random.PRNGKey(0), (3, 64, 8), -7, 8,
                              dtype=jnp.int32).astype(jnp.int8)
    out = unpack_mantissa(pack_mantissa(mant, 4), 4, 64)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(mant))


def test_mxint4_hbm_buffer_is_half_the_bytes():
    """Acceptance: the MXINT4 mantissa HBM buffer is EXACTLY K*N/2 bytes."""
    k, n = 256, 96
    w = jax.random.normal(jax.random.PRNGKey(1), (k, n))
    p = pack_mxint(w, 4, 32)
    assert p.mant.nbytes == k * n // 2
    assert p.mant.dtype == jnp.int8
    # 2-bit: a quarter; 3-bit: half (4-bit container, documented)
    assert pack_mxint(w, 2, 16).mant.nbytes == k * n // 4
    assert pack_mxint(w, 3, 32).mant.nbytes == k * n // 2
    # escape hatch keeps the flat layout
    assert pack_mxint(w, 4, 32, packed=False).mant.nbytes == k * n


@pytest.mark.parametrize("bits,bs", [(4, 32), (3, 32), (2, 16)])
def test_pack_mxint_dequant_unchanged(bits, bs):
    """Packing changes storage only: dequant matches the flat layout bit for
    bit."""
    w = jax.random.normal(jax.random.PRNGKey(2), (128, 48))
    ref = unpack_mxint(pack_mxint(w, bits, bs, packed=False))
    out = unpack_mxint(pack_mxint(w, bits, bs))
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


# ---------------------------------------------------------------------------
# kernel equivalence: packed vs flat storage, both grid variants
# ---------------------------------------------------------------------------

def _quantized_operands(m, k, n, r, bits, bs, seed=3):
    keys = jax.random.split(jax.random.PRNGKey(seed), 4)
    x = jax.random.normal(keys[0], (m, k), jnp.float32)
    w = jax.random.normal(keys[1], (k, n), jnp.float32) * 0.1
    a = jax.random.normal(keys[2], (k, r), jnp.float32) * 0.05
    b = jax.random.normal(keys[3], (r, n), jnp.float32) * 0.05
    mant, exp = mxint_quantize(w, bits, bs)
    return x, mant.reshape(k, n), exp, a, b


@pytest.mark.parametrize("bits,bs", [(4, 32), (3, 32), (2, 16)])
@pytest.mark.parametrize("m", [4, 64])     # decode (skinny-M) and prefill grid
def test_packed_kernel_bit_identical_to_flat(bits, bs, m):
    x, mant, exp, a, b = _quantized_operands(m, 128, 96, 8, bits, bs)
    kw = dict(bits=bits, block_size=bs, block_m=32, interpret=True)
    flat = quantized_matmul(x, mant, exp, a, b, **kw)
    packed = quantized_matmul(x, pack_mantissa(mant, bits), exp, a, b, **kw)
    # same mantissa values, same compute order -> bit-identical outputs
    np.testing.assert_array_equal(np.asarray(flat), np.asarray(packed))
    ref = mxint_matmul_lowrank_ref(x, mant, exp, a, b, bits, bs)
    np.testing.assert_allclose(np.asarray(packed), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


def test_packed_kernel_nonaligned_shapes():
    """Heuristic-block path (no explicit blocks) on a K where the granularity
    rule changes bk: 2-bit bs=16, K=160 -> packed bk=32 (lcm(16, 32)
    multiple) vs flat bk=80, so the K accumulation splits differ — allclose,
    not bit-identity (the bit-identity contract holds at EQUAL block sizes,
    covered above)."""
    x, mant, exp, a, b = _quantized_operands(4, 160, 96, 8, 2, 16)
    flat = quantized_matmul(x, mant, exp, a, b, bits=2, block_size=16,
                            interpret=True)
    packed = quantized_matmul(x, pack_mantissa(mant, 2), exp, a, b, bits=2,
                              block_size=16, interpret=True)
    np.testing.assert_allclose(np.asarray(flat), np.asarray(packed),
                               rtol=1e-5, atol=1e-5)
    # pin the block split and the outputs ARE bit-identical again
    flat = quantized_matmul(x, mant, exp, a, b, bits=2, block_size=16,
                            block_k=32, interpret=True)
    packed = quantized_matmul(x, pack_mantissa(mant, 2), exp, a, b, bits=2,
                              block_size=16, block_k=32, interpret=True)
    np.testing.assert_array_equal(np.asarray(flat), np.asarray(packed))


def test_mismatched_mantissa_rows_rejected():
    x, mant, exp, a, b = _quantized_operands(4, 128, 32, 4, 4, 32)
    with pytest.raises(ValueError, match="mantissa rows"):
        quantized_matmul(x, mant[: 128 // 4], exp, a, b, bits=4,
                         block_size=32, interpret=True)


def test_pick_blocks_respects_packing_granularity():
    # flat layout: largest block_size-multiple divisor of K (160 -> 80)
    assert pick_blocks(4, 160, 128, block_size=16)[2] == 80
    # packed 2-bit (epb=4): bk must keep the packed tile 8-sublane-aligned,
    # i.e. a multiple of lcm(16, 8*4) = 32 -> 32, not 80
    assert pick_blocks(4, 160, 128, block_size=16, epb=4)[2] == 32
    # aligned K keeps the full cap in both modes
    assert pick_blocks(4, 256, 256, block_size=32, epb=2)[2] == 128
    # no granularity-aligned divisor at all -> fall back to block_size rule
    assert pick_blocks(4, 48, 128, block_size=16, epb=4)[2] == 48


# ---------------------------------------------------------------------------
# on-device repack kernel emits the packed layout
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bits,bs", [(4, 32), (3, 32), (2, 16), (8, 32)])
def test_quantize_kernel_packed_emit(bits, bs):
    w = jax.random.normal(jax.random.PRNGKey(5), (96, 32), jnp.float32) * 2.0
    mant_k, exp_k = quantize_weights(w, bits=bits, block_size=bs, packed=True,
                                     interpret=True)
    mant_r, exp_r = mxint_quantize_ref(w, bits, bs, packed=True)
    assert mant_k.shape == (96 // elems_per_byte(bits), 32)
    np.testing.assert_array_equal(np.asarray(mant_k), np.asarray(mant_r))
    np.testing.assert_array_equal(np.asarray(exp_k), np.asarray(exp_r))


def test_quantize_kernel_feeds_matmul_kernel():
    """Device repack -> fused matmul with NO host relayout in between."""
    k, n, m, r = 128, 128, 4, 8
    keys = jax.random.split(jax.random.PRNGKey(6), 4)
    w = jax.random.normal(keys[0], (k, n)) * 0.1
    x = jax.random.normal(keys[1], (m, k))
    a = jax.random.normal(keys[2], (k, r)) * 0.05
    b = jax.random.normal(keys[3], (r, n)) * 0.05
    mant, exp = quantize_weights(w, bits=4, block_size=32, packed=True,
                                 interpret=True)
    out = quantized_matmul(x, mant, exp, a, b, bits=4, block_size=32,
                           interpret=True)
    ref = mxint_matmul_lowrank_ref(x, mant, exp, a, b, 4, 32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# exponent-overflow regression (satellite): bump at e = 127 must saturate
# ---------------------------------------------------------------------------

def test_exponent_overflow_saturates_host():
    """maxabs near float32-max needs the overflow bump at e = 127; the bumped
    exponent used to hit 128 and wrap to -128 on the int8 cast (dequant
    garbage).  It must clamp: e stays 127, mantissa saturates at qmax."""
    w = jnp.full((32, 8), 3.3e38, jnp.float32)     # 3.3e38 / 2^125 rounds to 8
    mant, exp = mxint_quantize(w, 4, 32)
    assert int(np.asarray(exp).max()) == 127
    assert int(np.asarray(exp).min()) == 127       # nothing wrapped negative
    assert np.all(np.asarray(mant) == 7)           # saturated at qmax
    deq = np.asarray(mxint_dequantize(mant, exp, 4))
    # ~7 * 2^125 (loose rtol: XLA-CPU exp2 is ~1e-6 off at huge exponents)
    np.testing.assert_allclose(deq, 7 * 2.0 ** 125, rtol=1e-4)
    assert float(np.abs(deq - 3.3e38).max() / 3.3e38) < 0.15   # saturation


def test_exponent_overflow_mixed_blocks():
    """Only the near-max block saturates; ordinary blocks are untouched."""
    w = jnp.concatenate([jnp.full((32, 8), 3.3e38),
                         jnp.ones((32, 8)) * 0.5])
    mant, exp = mxint_quantize(w, 4, 32)
    deq = np.asarray(mxint_dequantize(mant, exp, 4))
    assert np.all(deq[:32] > 1e38)
    np.testing.assert_allclose(deq[32:], 0.5, rtol=0.2)


def test_exponent_overflow_kernel_matches_host():
    w = jnp.concatenate([jnp.full((32, 32), 3.3e38, jnp.float32),
                         jax.random.normal(jax.random.PRNGKey(7), (64, 32))])
    for packed in (False, True):
        mant_k, exp_k = quantize_weights(w, bits=4, block_size=32,
                                         packed=packed, interpret=True)
        mant_r, exp_r = mxint_quantize_ref(w, 4, 32, packed=packed)
        np.testing.assert_array_equal(np.asarray(mant_k), np.asarray(mant_r))
        np.testing.assert_array_equal(np.asarray(exp_k), np.asarray(exp_r))


# ---------------------------------------------------------------------------
# model layer: the in-graph (non-Pallas) branch unpacks too
# ---------------------------------------------------------------------------

def test_linear_in_graph_dequant_handles_packed():
    from repro.models.layers import linear

    k, n, r = 64, 48, 4
    keys = jax.random.split(jax.random.PRNGKey(8), 4)
    w = jax.random.normal(keys[0], (k, n)) * 0.1
    x = jax.random.normal(keys[1], (3, k))
    mant, exp = mxint_quantize(w, 4, 32)
    p = {
        "exp": exp, "bits": jnp.asarray(4, jnp.int32),
        "block_size": jnp.asarray(32, jnp.int32),
        "lora_a": jax.random.normal(keys[2], (k, r)) * 0.05,
        "lora_b": jax.random.normal(keys[3], (r, n)) * 0.05,
    }
    flat = linear({**p, "mant": mant.reshape(k, n)}, x)
    packed = linear({**p, "mant": pack_mantissa(mant.reshape(k, n), 4)}, x)
    np.testing.assert_array_equal(np.asarray(flat), np.asarray(packed))
    # and the branch stays jittable (epb/bs derived from static shapes)
    jitted = jax.jit(linear)({**p, "mant": pack_mantissa(mant.reshape(k, n), 4)}, x)
    np.testing.assert_allclose(np.asarray(jitted), np.asarray(flat),
                               rtol=1e-6, atol=1e-6)
