"""QPEFT tests: adapter split/merge, frozen base, init-method contrast."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import PTQConfig, quantize_params
from repro.core.qpeft import merge_params, qpeft_finetune, split_trainable
from repro.data.tokenstream import DataConfig, make_batch
from repro.models import ModelConfig, forward, init_params
from repro.models.transformer import lm_loss
from repro.train import OptimizerConfig

CFG = ModelConfig(family="dense", num_layers=2, d_model=32, num_heads=4,
                  num_kv_heads=2, d_ff=64, vocab_size=64, head_dim=8,
                  scan_layers=False)


def _qparams(method="qera_approx", rank=4):
    params = init_params(CFG, jax.random.PRNGKey(0))
    from repro.models import Taps
    from benchmarks.common import remap_stats
    taps = Taps()
    toks = jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0, 64)
    forward(params, {"tokens": toks}, CFG, taps=taps)
    stats = remap_stats(taps.layer_stats())
    qcfg = PTQConfig(method=method, rank=rank, quantizer="mxint3")
    return params, quantize_params(params, qcfg, stats_by_path=stats)


def test_split_merge_roundtrip():
    _, qp = _qparams()
    train, frozen = split_trainable(qp)
    assert train and frozen
    assert all(k.endswith(("lora_a", "lora_b")) or "classifier" in k
               for k in train)
    merged = merge_params(train, frozen)
    for a, b in zip(jax.tree.leaves(merged), jax.tree.leaves(qp)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_finetune_updates_only_adapters():
    _, qp = _qparams()
    from repro.utils.trees import flatten_dict
    # snapshot BEFORE fine-tuning (the step donates the trainable buffers)
    f0 = {k: np.asarray(v).copy() for k, v in flatten_dict(qp).items()}
    dc = DataConfig(vocab_size=64, seq_len=16, global_batch=4)
    batches = ({k: jnp.asarray(v) for k, v in make_batch(dc, s).items()}
               for s in range(12))
    opt = OptimizerConfig(peak_lr=2e-3, schedule="constant", warmup_steps=2,
                          weight_decay=0.0)
    tuned, losses = qpeft_finetune(
        qp, lambda p, b: lm_loss(p, b, CFG), batches, opt)
    f1 = flatten_dict(tuned)
    for k in f0:
        same = np.array_equal(np.asarray(f0[k]), np.asarray(f1[k]))
        if k.endswith(("lora_a", "lora_b")):
            assert not same, f"adapter {k} did not train"
        else:
            assert same, f"frozen param {k} changed"
    assert np.mean(losses[-3:]) < losses[0]


def test_qera_init_lower_initial_output_error():
    """Theorem-guaranteed comparisons on the calibration distribution:
    QERA-exact <= ZeroQuant-V2 (same W-tilde, optimal C_k) and any
    reconstruction <= QLoRA (B=0, no reconstruction).  (QERA vs LoftQ needs
    REAL anisotropic activations — that contrast lives in the benchmark
    suite on the pretrained model, not on this random-init unit model.)"""
    params, _ = _qparams()
    from repro.models import Taps
    from benchmarks.common import remap_stats
    taps = Taps()
    toks = jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0, 64)
    forward(params, {"tokens": toks}, CFG, taps=taps)
    stats = remap_stats(taps.layer_stats())

    logits_fp, _, _ = forward(params, {"tokens": toks}, CFG)
    errs = {}
    for method in ["qlora", "zeroquant_v2", "qera_approx", "qera_exact"]:
        qcfg = PTQConfig(method=method, rank=4, quantizer="mxint2")
        qp = quantize_params(params, qcfg, stats_by_path=stats)
        lq, _, _ = forward(qp, {"tokens": toks}, CFG)
        errs[method] = float(jnp.mean((lq - logits_fp) ** 2))
    assert errs["qera_exact"] <= errs["zeroquant_v2"] * 1.02
    assert errs["qera_exact"] <= errs["qlora"] * 1.02
    assert errs["qera_approx"] <= errs["qlora"] * 1.02
