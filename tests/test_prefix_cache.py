"""Copy-on-write prefix caching tests.

Four layers of coverage: the refcounted ``PagePool`` (acquire/share/release
lifecycle, registered-page LRU parking + reclaim-under-pressure), the
``PrefixIndex`` hash-chain (match/register/unregister), end-to-end warm-vs-
cold token identity through the ContinuousBatcher (dense + hybrid
shared-attn, page-aligned full matches forcing a CoW fork, concurrent
sharing, LRU eviction under pressure, rollback/evict churn), and the
submit-time / scan_generate capacity bugfixes that rode along."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import ModelConfig, init_params
from repro.serve.batching import ContinuousBatcher, Request
from repro.serve.engine import greedy_generate_loop, scan_generate
from repro.serve.paging import PagePool, PrefixIndex
from repro.utils.trees import flatten_dict

CFGS = {
    "dense": ModelConfig(family="dense", num_layers=2, d_model=32, num_heads=4,
                         num_kv_heads=2, d_ff=64, vocab_size=64, head_dim=8),
    "hybrid_mamba": ModelConfig(family="hybrid_mamba", num_layers=4,
                                d_model=32, num_heads=4, num_kv_heads=4,
                                head_dim=8, d_ff=64, vocab_size=64,
                                ssm_state=8, ssm_head_dim=8, ssm_chunk=4,
                                attn_every=2),
}


# ---------------------------------------------------------------------------
# refcounted page pool
# ---------------------------------------------------------------------------

def test_pool_refcount_lifecycle():
    pool = PagePool(num_pages=6, page_size=4)
    a = pool.acquire(2)
    assert all(pool.refcount(p) == 1 for p in a)
    pool.share(a)                                  # a second slot points here
    assert all(pool.refcount(p) == 2 for p in a)
    pool.release(a)
    assert all(pool.refcount(p) == 1 for p in a)   # still owned once
    assert pool.available() == 3
    pool.release(a)
    assert pool.available() == 5                   # back on the free list
    with pytest.raises(AssertionError):            # over-release is an error
        pool.release([a[0]])


def test_pool_registered_pages_park_on_lru_and_revive():
    pool = PagePool(num_pages=6, page_size=4)
    a = pool.acquire(3)
    pool.set_registered(a[0], True)
    pool.release(a)
    # registered page parks (reclaimable, not free); others free outright
    assert pool.available() == 5
    assert pool.refcount(a[0]) == 0 and pool.is_registered(a[0])
    pool.share([a[0]])                             # a prefix hit revives it
    assert pool.refcount(a[0]) == 1
    pool.release([a[0]])
    pool.set_registered(a[0], False)               # index dropped the hash
    assert pool.refcount(a[0]) == 0
    got = pool.acquire(5)                          # whole pool reallocatable
    assert got is not None and len(got) == 5


def test_pool_reclaims_cached_lru_under_pressure():
    pool = PagePool(num_pages=5, page_size=4)
    reclaimed = []
    pool.on_reclaim = reclaimed.append
    pages = pool.acquire(4)                        # pool exhausted
    for p in pages:
        pool.set_registered(p, True)
    pool.release(pages[:2])                        # 2 park on the LRU
    pool.release(pages[2:])                        # then the other 2
    assert pool.available() == 4 and not pool._free
    got = pool.acquire(3)                          # must evict LRU-first
    assert got == pages[:3] == reclaimed           # oldest released first
    assert all(not pool.is_registered(p) for p in got)
    assert pool.acquire(2) is None                 # 1 cached page left


def test_prefix_index_chain_match_and_reclaim():
    pool = PagePool(num_pages=8, page_size=4)
    idx = PrefixIndex(pool)
    toks = np.arange(12, dtype=np.int32)
    hashes = PrefixIndex.chain_hashes(toks, 4)
    assert len(hashes) == 3 and len(set(hashes)) == 3
    # chaining: same page tokens at a different depth hash differently
    assert PrefixIndex.chain_hashes(toks[4:8], 4)[0] != hashes[1]
    pages = pool.acquire(3)
    for h, p in zip(hashes, pages):
        assert idx.register(h, p)
    assert not idx.register(hashes[0], 7)          # first writer wins
    got, _ = idx.match(toks, max_pages=3)
    assert got == pages
    got, _ = idx.match(toks, max_pages=2)          # caller caps the walk
    assert got == pages[:2]
    other = np.concatenate([toks[:4], np.full(8, 63, np.int32)])
    got, _ = idx.match(other, max_pages=3)         # chain breaks at page 1
    assert got == pages[:1]
    # reclaim under pressure drops the hash: the chain is no longer matchable
    pool.release(pages)
    while pool._free:
        pool.acquire(1)
    assert pool.acquire(1) == [pages[0]]           # LRU eviction
    got, _ = idx.match(toks, max_pages=3)
    assert got == []
    assert len(idx) == 2


def test_prefix_index_state_truncates_match():
    pool = PagePool(num_pages=8, page_size=4)
    idx = PrefixIndex(pool)
    toks = np.arange(12, dtype=np.int32)
    hashes = PrefixIndex.chain_hashes(toks, 4)
    pages = pool.acquire(3)
    idx.register(hashes[0], pages[0], state={"s": 0})
    idx.register(hashes[1], pages[1])              # boundary without state
    idx.register(hashes[2], pages[2], state={"s": 2})
    got, st = idx.match(toks, max_pages=3, need_state=True)
    assert got == pages and st == {"s": 2}
    got, st = idx.match(toks, max_pages=2, need_state=True)
    assert got == pages[:1] and st == {"s": 0}     # page 1 has no snapshot


# ---------------------------------------------------------------------------
# end-to-end: warm == cold, shared pages never written
# ---------------------------------------------------------------------------

def _serve(batcher, prompt, steps=6):
    req = Request(rid=0, prompt=prompt, max_new_tokens=steps)
    before = batcher.pool.acquired_total
    batcher.submit(req)
    batcher.run(max_ticks=400)
    assert req.done
    return req.output, batcher.pool.acquired_total - before


def _batcher(params, cfg, **kw):
    kw.setdefault("num_slots", 2)
    kw.setdefault("max_len", 64)
    kw.setdefault("paged", True)
    kw.setdefault("page_size", 4)
    return ContinuousBatcher(params, cfg, **kw)


@pytest.mark.parametrize("family", list(CFGS))
def test_warm_prefix_matches_cold_and_allocates_only_suffix(family):
    """Two requests sharing a 8-token prefix: the warm one must be
    token-identical to a cold-cache run and allocate only
    ``pages_for(suffix)`` new pages."""
    cfg = CFGS[family]
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prefix = rng.integers(0, cfg.vocab_size, 8).astype(np.int32)  # 2 pages
    p1 = np.concatenate([prefix, np.asarray([1, 2, 3], np.int32)])
    p2 = np.concatenate([prefix, np.asarray([9, 8, 7], np.int32)])

    cold = _batcher(params, cfg, chunk_tokens=4)
    out1_cold, pages1_cold = _serve(cold, p1)
    out2_cold, pages2_cold = _serve(cold, p2)

    warm = _batcher(params, cfg, chunk_tokens=4, prefix_cache=True)
    out1, pages1 = _serve(warm, p1)
    out2, pages2 = _serve(warm, p2)
    assert (out1, out2) == (out1_cold, out2_cold)
    assert pages1 == pages1_cold                   # first request is cold
    # 11-token prompt, 8 matched: 1 suffix page + 1 decode-growth page
    # fewer than the cold run's full allocation
    assert pages2 == pages2_cold - 2               # the 2 prefix pages
    assert warm.prefix.hits == 1 and warm.prefix.hit_tokens == 8
    # all slots freed: every page refcount 0, pool fully reallocatable
    assert warm.pool.available() == warm.pool.num_pages - 1


def test_page_aligned_full_match_forks_not_mutates():
    """A page-aligned identical prompt matches every page; the recompute of
    the final token is the one write that lands in a shared page and MUST
    fork it — the cached original's content must be bit-identical after."""
    cfg = CFGS["dense"]
    params = init_params(cfg, jax.random.PRNGKey(0))
    prompt = np.arange(16, dtype=np.int32)         # exactly 4 pages

    b = _batcher(params, cfg, chunk_tokens=8, prefix_cache=True)
    out1, _ = _serve(b, prompt)
    # snapshot the cached prefix pages' content before the warm admission
    cached = [p for p in range(1, b.pool.num_pages)
              if b.pool.is_registered(p)]
    assert len(cached) >= 4
    pool_leaves = {k: np.asarray(v) for k, v in
                   flatten_dict(b.cache).items() if k.endswith("_pages")}
    snap = {k: v[:, cached].copy() for k, v in pool_leaves.items()}

    out2, pages2 = _serve(b, prompt)
    assert out2 == out1                            # deterministic greedy
    assert b.cow_forks >= 1                        # the tail page forked
    after = {k: np.asarray(v)[:, cached] for k, v in
             flatten_dict(b.cache).items() if k.endswith("_pages")}
    for k in snap:
        np.testing.assert_array_equal(snap[k], after[k],
                                      err_msg=f"shared page mutated: {k}")


def test_concurrent_sharing_never_writes_refcounted_pages():
    """Both slots decode simultaneously over the same shared prefix pages
    (refcount 2 while both run): outputs match the cold run and the shared
    pages' content never changes while shared."""
    cfg = CFGS["dense"]
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(3)
    prefix = rng.integers(0, cfg.vocab_size, 12).astype(np.int32)
    prompts = [np.concatenate([prefix, np.asarray(s, np.int32)])
               for s in ([1, 2], [5, 6])]

    def run(prefix_cache):
        b = _batcher(params, cfg, chunk_tokens=4, prefix_cache=prefix_cache)
        reqs = [Request(rid=i, prompt=p, max_new_tokens=6)
                for i, p in enumerate(prompts)]
        for r in reqs:
            b.submit(r)
        snaps = {}
        for _ in range(400):
            if not b.queue and b._adm is None and not b._active():
                break
            b.step()
            # every page shared between slots right now must be bit-stable
            shared = [p for p in range(1, b.pool.num_pages)
                      if b.pool.refcount(p) > 1]
            leaves = {k: np.asarray(v) for k, v in
                      flatten_dict(b.cache).items() if k.endswith("_pages")}
            for p in shared:
                for k, v in leaves.items():
                    if (k, p) in snaps:
                        np.testing.assert_array_equal(
                            snaps[(k, p)], v[:, p],
                            err_msg=f"refcount>1 page {p} written ({k})")
            snaps = {(k, p): leaves[k][:, p].copy()
                     for p in shared for k in leaves}
        assert all(r.done for r in reqs)
        return [r.output for r in reqs], b

    cold, _ = run(False)
    warm, b = run(True)
    assert warm == cold
    assert b.prefix.hits >= 1
    assert b.pool.available() == b.pool.num_pages - 1


def test_lru_eviction_under_pressure_keeps_serving():
    """A pool too small to cache everything must reclaim refcount-0 cached
    pages (LRU) to admit new work — and stay token-identical to a roomy
    prefix-cached run."""
    cfg = CFGS["dense"]
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(4)
    prompts = [rng.integers(0, cfg.vocab_size, 12).astype(np.int32)
               for _ in range(4)]

    def run(num_pages):
        b = _batcher(params, cfg, chunk_tokens=4, prefix_cache=True,
                     num_pages=num_pages, max_len=32)
        return [_serve(b, p, steps=4)[0] for p in prompts], b

    roomy, _ = run(None)
    tight, b = run(9)          # 8 allocatable; each request needs 4 live
    assert tight == roomy
    assert b.pool.reclaimed_cached > 0             # cache actually cycled
    assert b.pool.available() == b.pool.num_pages - 1


def test_churn_storm_with_prefix_cache_stays_lossless():
    """Admit/evict/rollback churn on an oversubscribed pool with the prefix
    cache on: outputs identical to the lossless run, nothing double-freed,
    every page accounted for after the drain."""
    cfg = CFGS["dense"]
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(5)
    prefix = rng.integers(0, cfg.vocab_size, 8).astype(np.int32)
    prompts = [np.concatenate([prefix, rng.integers(
        0, cfg.vocab_size, 1 + i % 3).astype(np.int32)]) for i in range(5)]

    def run(num_pages):
        b = _batcher(params, cfg, chunk_tokens=4, prefix_cache=True,
                     num_pages=num_pages, max_len=24)
        reqs = [Request(rid=i, prompt=p, max_new_tokens=8)
                for i, p in enumerate(prompts)]
        for r in reqs:
            b.submit(r)
        b.run(max_ticks=800)
        assert all(r.done for r in reqs)
        return [r.output for r in reqs], b

    lossless, _ = run(None)
    tight, b = run(8)                              # 7 allocatable pages
    assert tight == lossless
    assert b.pool.available() == b.pool.num_pages - 1
    refs = [b.pool.refcount(p) for p in range(1, b.pool.num_pages)]
    assert all(r == 0 for r in refs)


def test_hybrid_match_requires_state_snapshot():
    """Hybrid matches stop at the deepest page boundary with a recurrent-row
    snapshot; a prefix registered without state (generated pages at slot
    free) must not be skipped over."""
    cfg = CFGS["hybrid_mamba"]
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(6)
    prefix = rng.integers(0, cfg.vocab_size, 8).astype(np.int32)
    p1 = np.concatenate([prefix, np.asarray([1], np.int32)])
    b = _batcher(params, cfg, chunk_tokens=4, prefix_cache=True)
    out1, _ = _serve(b, p1, steps=6)
    # continuation prompt extends p1 + its outputs: those pages registered
    # at slot-free WITHOUT state, so the match must stop at the prompt's
    # boundary snapshots, never beyond — and stay correct
    cont = np.concatenate([p1, np.asarray(out1[:4], np.int32)])
    out_warm, _ = _serve(b, cont, steps=4)
    cold = _batcher(params, cfg, chunk_tokens=4)
    out_cold, _ = _serve(cold, cont, steps=4)
    assert out_warm == out_cold


# ---------------------------------------------------------------------------
# capacity bugfixes (submit-time validation, scan_generate bounds)
# ---------------------------------------------------------------------------

def test_submit_rejects_page_aligned_prompt_filling_whole_pool():
    """A page-aligned prompt that needs every allocatable page can prefill
    but never take its first decode append — must be rejected at submit,
    not die later in step()'s lone-request RuntimeError path."""
    cfg = CFGS["dense"]
    params = init_params(cfg, jax.random.PRNGKey(0))
    b = ContinuousBatcher(params, cfg, num_slots=1, max_len=32, paged=True,
                          page_size=4, num_pages=3)   # 2 allocatable pages
    with pytest.raises(ValueError, match="first decode append"):
        b.submit(Request(rid=0, prompt=np.arange(8, dtype=np.int32)))
    # one page shy of the pool is fine: the append reuses the partial page
    b.submit(Request(rid=1, prompt=np.arange(7, dtype=np.int32),
                     max_new_tokens=1))
    b.run(max_ticks=50)


@pytest.mark.parametrize("paged", [False, True])
def test_submit_rejects_prompt_exceeding_max_len(paged):
    """len(prompt) + 1 > max_len used to IndexError mid-admission (paged)
    or silently clamp the decode append (dense) — reject at submit."""
    cfg = CFGS["dense"]
    params = init_params(cfg, jax.random.PRNGKey(0))
    b = ContinuousBatcher(params, cfg, num_slots=2, max_len=8, paged=paged,
                          page_size=4)
    with pytest.raises(ValueError, match="max_len"):
        b.submit(Request(rid=0, prompt=np.arange(9, dtype=np.int32)))
    with pytest.raises(ValueError, match="max_len"):
        b.submit(Request(rid=1, prompt=np.arange(8, dtype=np.int32)))
    b.submit(Request(rid=2, prompt=np.arange(7, dtype=np.int32),
                     max_new_tokens=1))              # exactly fits
    b.run(max_ticks=50)


@pytest.mark.parametrize("page_size", [0, 4])
def test_scan_generate_rejects_overflowing_rollout(page_size):
    """max_len < prompt + steps used to clamp the decode append index: late
    tokens silently overwrote the last row/page and outputs diverged from
    the loop oracle — must raise instead, in dense and paged modes."""
    cfg = CFGS["dense"]
    params = init_params(cfg, jax.random.PRNGKey(0))
    prompt = jax.random.randint(jax.random.PRNGKey(3), (2, 5), 0,
                                cfg.vocab_size)
    with pytest.raises(ValueError, match="max_len"):
        scan_generate(params, cfg, prompt, steps=8, max_len=8,
                      page_size=page_size)
    # the boundary case still works and matches the oracle
    ref = greedy_generate_loop(params, cfg, prompt, steps=8, max_len=13)
    got = scan_generate(params, cfg, prompt, steps=8, max_len=13,
                        page_size=page_size)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))
