"""Model substrate tests: chunked==naive oracles, scan==loop, per-family
forward/train smoke, calibration taps, PTQ'd forward."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import PTQConfig, quantize_params
from repro.models import ModelConfig, Taps, forward, init_params, lm_loss
from repro.models.config import reduced
from repro.models.mamba2 import mamba2_block, mamba2_block_ref, mamba2_param_shapes
from repro.models.rwkv6 import rwkv6_param_shapes, rwkv6_time_mix, rwkv6_time_mix_ref
from repro.models.layers import init_dense, key_iter


def _batch(cfg, key, batch=2, seq=16):
    if cfg.family == "audio":
        toks = jax.random.randint(key, (batch, cfg.num_codebooks, seq + 1),
                                  0, cfg.vocab_size)
        b = {"tokens": toks[..., :-1], "labels": toks[..., 1:]}
    else:
        toks = jax.random.randint(key, (batch, seq + 1), 0, cfg.vocab_size)
        b = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
    if cfg.family == "vlm":
        b["image_embeds"] = jax.random.normal(
            jax.random.PRNGKey(7), (batch, cfg.vision_seq, cfg.d_model)) * 0.1
    return b


FAMILY_CFGS = {
    "dense": ModelConfig(family="dense", num_layers=2, d_model=32, num_heads=4,
                         num_kv_heads=2, d_ff=64, vocab_size=64, head_dim=8),
    "moe": ModelConfig(family="moe", num_layers=2, d_model=32, num_heads=4,
                       num_kv_heads=4, d_ff=48, vocab_size=64, head_dim=8,
                       num_experts=4, moe_top_k=2),
    "hybrid_mamba": ModelConfig(family="hybrid_mamba", num_layers=4, d_model=32,
                                num_heads=4, num_kv_heads=4, head_dim=8,
                                d_ff=64, vocab_size=64, ssm_state=8,
                                ssm_head_dim=8, ssm_chunk=4, attn_every=2),
    "rwkv": ModelConfig(family="rwkv", num_layers=2, d_model=32, num_heads=4,
                        num_kv_heads=4, d_ff=64, vocab_size=64,
                        rwkv_head_dim=8, rwkv_decay_lora=4, rwkv_chunk=4),
    "vlm": ModelConfig(family="vlm", num_layers=4, d_model=32, num_heads=4,
                       num_kv_heads=2, d_ff=64, vocab_size=64, head_dim=8,
                       cross_attn_every=2, vision_seq=6),
    "audio": ModelConfig(family="audio", num_layers=2, d_model=32, num_heads=4,
                         num_kv_heads=4, d_ff=64, vocab_size=32, head_dim=8,
                         num_codebooks=4),
    "encoder": ModelConfig(family="encoder", num_layers=2, d_model=32,
                           num_heads=4, num_kv_heads=4, d_ff=64, vocab_size=64,
                           head_dim=8, num_classes=3, max_seq_len=64),
}


# ---------------------------------------------------------------------------
# chunked == per-step oracles
# ---------------------------------------------------------------------------

def _mamba_params(cfg, key):
    ks = key_iter(key)
    shapes = mamba2_param_shapes(cfg)
    p = {}
    for name, shp in shapes.items():
        if name == "a_log":
            p[name] = jnp.log(jnp.linspace(1.0, 8.0, cfg.ssm_heads))
        elif name == "dt_bias":
            p[name] = jnp.full(shp, -2.0)
        elif name in ("d_skip", "gate_norm"):
            p[name] = jnp.ones(shp)
        else:
            p[name] = init_dense(next(ks), shp, scale=0.3)
    return p


@pytest.mark.parametrize("seq,chunk", [(16, 4), (16, 16), (12, 5), (8, 1)])
def test_mamba2_chunked_matches_stepwise(seq, chunk):
    cfg = ModelConfig(family="hybrid_mamba", d_model=16, ssm_state=8,
                      ssm_head_dim=8, ssm_chunk=chunk, num_heads=2,
                      num_kv_heads=2, vocab_size=8)
    p = _mamba_params(cfg, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, seq, 16)) * 0.5
    out, _ = mamba2_block(p, x, cfg)
    ref = mamba2_block_ref(p, x, cfg)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def _rwkv_params(cfg, key):
    ks = key_iter(key)
    shapes = rwkv6_param_shapes(cfg)
    p = {}
    for name, shp in shapes.items():
        if name.startswith("mu_"):
            p[name] = jax.random.uniform(next(ks), shp)
        elif name == "decay_w0":
            p[name] = jax.random.uniform(next(ks), shp, minval=-2.0, maxval=1.0)
        elif name == "bonus_u":
            p[name] = 0.2 * jax.random.normal(next(ks), shp)
        elif name == "ln_x":
            p[name] = jnp.ones(shp)
        else:
            p[name] = init_dense(next(ks), shp, scale=0.4)
    return p


@pytest.mark.parametrize("seq,chunk", [(16, 4), (16, 16), (10, 3), (8, 1)])
def test_rwkv6_chunked_matches_stepwise(seq, chunk):
    cfg = ModelConfig(family="rwkv", d_model=16, rwkv_head_dim=8,
                      rwkv_decay_lora=4, rwkv_chunk=chunk, vocab_size=8)
    p = _rwkv_params(cfg, jax.random.PRNGKey(2))
    x = jax.random.normal(jax.random.PRNGKey(3), (2, seq, 16)) * 0.5
    out, _ = rwkv6_time_mix(p, x, cfg)
    ref = rwkv6_time_mix_ref(p, x, cfg)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=5e-4, atol=5e-4)


def test_rwkv6_strong_decay_no_overflow():
    """Extreme decay values must not overflow the chunked path."""
    cfg = ModelConfig(family="rwkv", d_model=16, rwkv_head_dim=8,
                      rwkv_decay_lora=4, rwkv_chunk=16, vocab_size=8)
    p = _rwkv_params(cfg, jax.random.PRNGKey(4))
    p["decay_w0"] = jnp.full((16,), 3.0)   # exp(3)≈20 per-step log decay (clamped)
    x = jax.random.normal(jax.random.PRNGKey(5), (1, 32, 16))
    out, _ = rwkv6_time_mix(p, x, cfg)
    assert np.all(np.isfinite(np.asarray(out)))
    ref = rwkv6_time_mix_ref(p, x, cfg)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-3, atol=1e-3)


# ---------------------------------------------------------------------------
# per-family forward/train smoke
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("family", list(FAMILY_CFGS))
def test_family_forward_shapes_and_finite(family):
    cfg = FAMILY_CFGS[family]
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg, jax.random.PRNGKey(1))
    logits, aux, _ = forward(params, batch, cfg)
    if family == "encoder":
        assert logits.shape == (2, cfg.num_classes)
    elif family == "audio":
        assert logits.shape == (2, cfg.num_codebooks, 16, cfg.vocab_size)
    else:
        assert logits.shape == (2, 16, cfg.vocab_size)
    assert np.all(np.isfinite(np.asarray(logits)))
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("family", ["dense", "moe", "hybrid_mamba", "rwkv",
                                    "vlm", "audio"])
def test_family_scan_matches_loop(family):
    import dataclasses
    cfg = FAMILY_CFGS[family]
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg, jax.random.PRNGKey(1))
    logits_scan, aux_s, _ = forward(params, batch, cfg)
    cfg_loop = dataclasses.replace(cfg, scan_layers=False)
    logits_loop, aux_l, _ = forward(params, batch, cfg_loop)
    np.testing.assert_allclose(np.asarray(logits_scan), np.asarray(logits_loop),
                               rtol=2e-4, atol=2e-4)
    assert float(aux_s) == pytest.approx(float(aux_l), abs=1e-5)


@pytest.mark.parametrize("family", ["dense", "moe", "hybrid_mamba", "rwkv"])
def test_family_train_grad_step(family):
    cfg = FAMILY_CFGS[family]
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg, jax.random.PRNGKey(1))
    (loss, _), grads = jax.value_and_grad(lm_loss, has_aux=True)(
        params, batch, cfg)
    assert np.isfinite(float(loss))
    gnorm = jnp.sqrt(sum(jnp.sum(g * g) for g in jax.tree.leaves(grads)))
    assert np.isfinite(float(gnorm)) and float(gnorm) > 0


def test_remat_matches_no_remat():
    import dataclasses
    cfg = FAMILY_CFGS["dense"]
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg, jax.random.PRNGKey(1))
    (l0, _), g0 = jax.value_and_grad(lm_loss, has_aux=True)(params, batch, cfg)
    cfgr = dataclasses.replace(cfg, remat=True)
    (l1, _), g1 = jax.value_and_grad(lm_loss, has_aux=True)(params, batch, cfgr)
    assert float(l0) == pytest.approx(float(l1), rel=1e-6)
    for a, b in zip(jax.tree.leaves(g0), jax.tree.leaves(g1)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# calibration taps + PTQ integration
# ---------------------------------------------------------------------------

def test_taps_capture_linear_inputs():
    import dataclasses
    cfg = dataclasses.replace(FAMILY_CFGS["dense"], scan_layers=False)
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg, jax.random.PRNGKey(1))
    taps = Taps()
    forward(params, batch, cfg, taps=taps)
    stats = taps.layer_stats()
    assert "blocks/0/attn/wq" in stats and "blocks/1/mlp/wd" in stats
    s = stats["blocks/0/attn/wq"]
    assert s.rxx.shape == (cfg.d_model, cfg.d_model)
    assert s.count == 2 * 16


def test_ptq_roundtrip_forward_close_at_8bit():
    """mxint8 + rank-8 QERA reconstruction ≈ full-precision forward."""
    import dataclasses
    cfg = dataclasses.replace(FAMILY_CFGS["dense"], scan_layers=False)
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg, jax.random.PRNGKey(1))
    taps = Taps()
    logits_fp, _, _ = forward(params, batch, cfg, taps=taps)
    stats = taps.layer_stats()

    qcfg = PTQConfig(method="qera_exact", rank=8, quantizer="mxint8")
    def skey(path):  # params path -> taps key
        return path.replace("/wq", "/attn/wq").replace("/wk", "/attn/wk") \
                   .replace("/wv", "/attn/wv").replace("/wo", "/attn/wo") \
                   .replace("/wg", "/mlp/wg").replace("/wu", "/mlp/wu") \
                   .replace("/wd", "/mlp/wd")
    flat_stats = {}
    for k, v in stats.items():
        parts = k.split("/")          # blocks/i/sub/name -> blocks/name:i
        if parts[0] == "blocks":
            flat_stats[f"blocks/{parts[-1]}:{parts[1]}"] = v
    qparams = quantize_params(params, qcfg, stats_by_path=flat_stats,
                              stats_key_fn=lambda p: p)
    logits_q, _, _ = forward(qparams, batch, cfg)
    err = np.abs(np.asarray(logits_q - logits_fp)).max()
    scale = np.abs(np.asarray(logits_fp)).max()
    assert err < 0.05 * scale + 0.05, (err, scale)


def test_decode_cache_matches_full_forward_dense():
    """Prefill+decode against full-sequence forward (greedy logits match)."""
    cfg = FAMILY_CFGS["dense"]
    params = init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0, cfg.vocab_size)
    logits_full, _, _ = forward(params, {"tokens": toks}, cfg)

    # prefill 8, then decode 4 one at a time
    cache = {"blocks": {
        "k": jnp.zeros((cfg.num_layers, 2, cfg.num_kv_heads, 16, cfg.hd)),
        "v": jnp.zeros((cfg.num_layers, 2, cfg.num_kv_heads, 16, cfg.hd)),
    }}
    lp, _, cache = forward(params, {"tokens": toks[:, :8]}, cfg, cache=cache,
                           cache_len=jnp.asarray(0, jnp.int32))
    np.testing.assert_allclose(np.asarray(lp), np.asarray(logits_full[:, :8]),
                               rtol=2e-3, atol=2e-3)
    for t in range(8, 12):
        lt, _, cache = forward(params, {"tokens": toks[:, t:t + 1]}, cfg,
                               cache=cache, cache_len=jnp.asarray(t, jnp.int32))
        np.testing.assert_allclose(np.asarray(lt[:, 0]),
                                   np.asarray(logits_full[:, t]),
                                   rtol=2e-3, atol=2e-3)


def test_param_count_analytic_close():
    from repro.utils.trees import tree_param_count
    for fam in ["dense", "rwkv"]:
        cfg = FAMILY_CFGS[fam]
        params = init_params(cfg, jax.random.PRNGKey(0))
        actual = tree_param_count(params)
        analytic = cfg.param_count()
        assert abs(actual - analytic) / actual < 0.2, (fam, actual, analytic)
