"""Hypothesis storm over heterogeneous packed trees.

Randomized sweeps of the per-leaf (format, rank) degrees of freedom a
QuantPlan introduces: every leaf of a mixed tree must pack -> unpack
bit-identically at ITS OWN (bits, block_size, epb), the plan JSON codec
must round-trip arbitrary assignments, and the budget formula must be
consistent under composition.  The deterministic end-to-end coverage
(quantize -> pack -> serve) lives in test_quant_plan.py; this module is
the fuzzer on top.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.allocate import LayerChoice, QuantPlan, choice_bytes
from repro.quant.mxint import (
    MXINT_CONFIGS,
    container_bits,
    pack_mxint,
    unpack_mxint,
)

pytest.importorskip("hypothesis")  # property tests skip without hypothesis
from hypothesis import given, settings, strategies as st  # noqa: E402

FORMATS = sorted(MXINT_CONFIGS)

_leaf = st.tuples(st.sampled_from(FORMATS),
                  st.integers(1, 4),       # exponent blocks along K
                  st.integers(1, 6))       # N columns


@settings(max_examples=25, deadline=None)
@given(st.lists(_leaf, min_size=1, max_size=5),
       st.integers(0, 2 ** 31 - 1))
def test_heterogeneous_tree_pack_unpack_bit_identity(leaves, seed):
    """A tree mixing every format: each leaf's packed storage dequantizes
    bit-identically to its own flat layout — no cross-leaf leakage of
    (bits, block_size, epb)."""
    key = jax.random.PRNGKey(seed)
    for i, (fmt, kb, n) in enumerate(leaves):
        spec = MXINT_CONFIGS[fmt]
        k = kb * spec.block_size
        key, sub = jax.random.split(key)
        w = jax.random.normal(sub, (k, n), jnp.float32) * 2.0
        packed = pack_mxint(w, spec.bits, spec.block_size)
        flat = pack_mxint(w, spec.bits, spec.block_size, packed=False)
        np.testing.assert_array_equal(
            np.asarray(unpack_mxint(packed)), np.asarray(unpack_mxint(flat)),
            err_msg=f"leaf {i} fmt={fmt} k={k} n={n}")
        # measured HBM bytes follow the CONTAINER bit-width per leaf
        assert packed.mant.nbytes == k * n * container_bits(spec.bits) // 8


@settings(max_examples=25, deadline=None)
@given(st.dictionaries(
    st.text(st.sampled_from("abqkvwod/"), min_size=1, max_size=12),
    st.tuples(st.sampled_from(FORMATS), st.sampled_from([0, 4, 8, 16, 64])),
    max_size=8),
    st.sampled_from(FORMATS), st.sampled_from([8, 32]))
def test_plan_json_roundtrip_arbitrary(assigns, dfmt, drank):
    plan = QuantPlan(
        assignments={p: LayerChoice(f, r) for p, (f, r) in assigns.items()},
        default=LayerChoice(dfmt, drank), method="qera_exact")
    back = QuantPlan.from_json_dict(plan.to_json_dict())
    assert back.assignments == plan.assignments
    assert back.default == plan.default and back.method == plan.method


@settings(max_examples=50, deadline=None)
@given(st.sampled_from(FORMATS), st.integers(1, 8), st.integers(1, 512),
       st.sampled_from([0, 4, 16, 64]))
def test_choice_bytes_formula(fmt, kb, n, rank):
    spec = MXINT_CONFIGS[fmt]
    k = kb * spec.block_size
    c = LayerChoice(fmt, rank)
    got = choice_bytes(k, n, c)
    assert got == k * n * spec.bits // 8 + (k // spec.block_size) * n \
        + (k + n) * rank * 4
    # monotone in rank and bits
    assert got >= choice_bytes(k, n, LayerChoice(fmt, 0))
