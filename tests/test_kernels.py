"""Per-kernel allclose tests vs the ref.py pure-jnp oracles.

Shapes/dtypes are swept; kernels run in interpret mode on CPU (the kernel
body is executed in Python, which is exactly what we want to validate)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ops import (
    flash_attention,
    pick_blocks,
    quantize_weights,
    quantized_matmul,
)
from repro.kernels.ref import (
    flash_attention_ref,
    mxint_matmul_lowrank_ref,
    mxint_quantize_ref,
)
from repro.quant.mxint import mxint_quantize


def _pack(w, bits, bs):
    mant, exp = mxint_quantize(w, bits, bs)
    k, n = w.shape
    return mant.reshape(k, n), exp


# ---------------------------------------------------------------------------
# mxint_matmul
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,k,n,r", [
    (8, 64, 32, 4),        # tiny
    (16, 128, 128, 8),     # aligned
    (33, 128, 96, 16),     # M needs padding, odd N blocks
])
@pytest.mark.parametrize("bits,bs", [(4, 32), (3, 32), (2, 16), (8, 32)])
def test_mxint_matmul_vs_ref(m, k, n, r, bits, bs):
    keys = jax.random.split(jax.random.PRNGKey(0), 4)
    x = jax.random.normal(keys[0], (m, k), jnp.float32)
    w = jax.random.normal(keys[1], (k, n), jnp.float32) * 0.1
    a = jax.random.normal(keys[2], (k, r), jnp.float32) * 0.05
    b = jax.random.normal(keys[3], (r, n), jnp.float32) * 0.05
    mant, exp = _pack(w, bits, bs)
    ref = mxint_matmul_lowrank_ref(x, mant, exp, a, b, bits, bs)
    out = quantized_matmul(x, mant, exp, a, b, bits=bits, block_size=bs,
                           block_m=16, block_n=32, block_k=64, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_mxint_matmul_dtypes(dtype):
    keys = jax.random.split(jax.random.PRNGKey(1), 4)
    x = jax.random.normal(keys[0], (16, 64), jnp.float32).astype(dtype)
    w = jax.random.normal(keys[1], (64, 64), jnp.float32) * 0.1
    a = jax.random.normal(keys[2], (64, 8), jnp.float32) * 0.05
    b = jax.random.normal(keys[3], (8, 64), jnp.float32) * 0.05
    mant, exp = _pack(w, 4, 32)
    ref = mxint_matmul_lowrank_ref(x.astype(jnp.float32), mant, exp, a, b, 4, 32)
    out = quantized_matmul(x, mant, exp, a, b, bits=4, block_size=32,
                           block_m=16, block_n=64, block_k=64, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-2, atol=2e-2)


def test_mxint_matmul_batched_input():
    keys = jax.random.split(jax.random.PRNGKey(2), 4)
    x = jax.random.normal(keys[0], (2, 5, 64), jnp.float32)
    w = jax.random.normal(keys[1], (64, 32), jnp.float32)
    a = jax.random.normal(keys[2], (64, 4), jnp.float32)
    b = jax.random.normal(keys[3], (4, 32), jnp.float32)
    mant, exp = _pack(w, 4, 32)
    out = quantized_matmul(x, mant, exp, a, b, bits=4, block_size=32,
                           block_m=8, block_n=32, block_k=32, interpret=True)
    ref = mxint_matmul_lowrank_ref(x.reshape(-1, 64), mant, exp, a, b, 4, 32)
    assert out.shape == (2, 5, 32)
    np.testing.assert_allclose(np.asarray(out).reshape(-1, 32), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("m,k,n,r", [
    (4, 160, 96, 8),       # decode path; K forces the divisor fallback (bk=32)
    (8, 192, 128, 8),      # decode path; bk = 96, not the old collapse to 32
    (33, 192, 96, 16),     # prefill path (padded M) with non-128 K and N
    (12, 64, 48, 4),       # decode path; N falls back to a divisor block
])
def test_fused_prologue_nonaligned_shapes(m, k, n, r):
    """Default-block calls hit the (M, K, N) heuristic — decode variant for
    skinny M, largest-divisor bk/bn — and must still match the unfused ref."""
    keys = jax.random.split(jax.random.PRNGKey(7), 4)
    x = jax.random.normal(keys[0], (m, k), jnp.float32)
    w = jax.random.normal(keys[1], (k, n), jnp.float32) * 0.1
    a = jax.random.normal(keys[2], (k, r), jnp.float32) * 0.05
    b = jax.random.normal(keys[3], (r, n), jnp.float32) * 0.05
    mant, exp = _pack(w, 4, 32)
    ref = mxint_matmul_lowrank_ref(x, mant, exp, a, b, 4, 32)
    out = quantized_matmul(x, mant, exp, a, b, bits=4, block_size=32,
                           interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


def test_pick_blocks_heuristic():
    # decode regime: whole (8-padded) M in one block
    bm, bn, bk, decode = pick_blocks(4, 256, 256, block_size=32)
    assert (bm, bn, bk, decode) == (8, 128, 128, True)
    # prefill regime: large M tiles at block_m
    bm, bn, bk, decode = pick_blocks(256, 256, 256, block_size=32)
    assert (bm, bn, bk, decode) == (128, 128, 128, False)
    # prefill bm stays 8-sublane-aligned (never e.g. 33)
    bm, _, _, decode = pick_blocks(33, 128, 128, block_size=32)
    assert (bm, decode) == (40, False)
    # block_k fallback picks the largest divisor that covers MX blocks,
    # not a collapse straight to block_size
    assert pick_blocks(4, 192, 128, block_size=32)[2] == 96
    assert pick_blocks(4, 160, 128, block_size=32)[2] == 32   # only divisor
    # N fallback: largest 8-aligned divisor ≤ block_n
    assert pick_blocks(4, 128, 48, block_size=32)[1] == 48
    assert pick_blocks(4, 128, 96, block_size=32, block_n=32)[1] == 32


# ---------------------------------------------------------------------------
# mxint_quant
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bits,bs", [(4, 32), (3, 32), (2, 16), (8, 32)])
@pytest.mark.parametrize("shape", [(64, 128), (96, 32)])
def test_mxint_quant_kernel_vs_ref(bits, bs, shape):
    if shape[0] % bs:
        pytest.skip("kernel path requires divisible K")
    w = jax.random.normal(jax.random.PRNGKey(3), shape, jnp.float32) * 2.0
    mant_k, exp_k = quantize_weights(w, bits=bits, block_size=bs, interpret=True)
    mant_r, exp_r = mxint_quantize_ref(w, bits, bs)
    np.testing.assert_array_equal(np.asarray(mant_k), np.asarray(mant_r))
    np.testing.assert_array_equal(np.asarray(exp_k), np.asarray(exp_r))


def test_mxint_quant_kernel_extreme_values():
    w = jnp.concatenate([
        jnp.zeros((32, 32)),
        jnp.full((32, 32), 1e-20),
        jnp.full((32, 32), 1e20),
    ])
    mant_k, exp_k = quantize_weights(w, bits=4, block_size=32, interpret=True)
    mant_r, exp_r = mxint_quantize_ref(w, 4, 32)
    np.testing.assert_array_equal(np.asarray(mant_k), np.asarray(mant_r))
    np.testing.assert_array_equal(np.asarray(exp_k), np.asarray(exp_r))


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("b,h,hkv,s,d", [
    (1, 4, 4, 64, 32),     # MHA
    (2, 8, 2, 128, 16),    # GQA group=4
    (1, 2, 1, 96, 64),     # padding (96 % 64 != 0 with block 64)
])
def test_flash_attention_vs_ref(causal, b, h, hkv, s, d):
    keys = jax.random.split(jax.random.PRNGKey(4), 3)
    q = jax.random.normal(keys[0], (b, h, s, d), jnp.float32)
    k = jax.random.normal(keys[1], (b, hkv, s, d), jnp.float32)
    v = jax.random.normal(keys[2], (b, hkv, s, d), jnp.float32)
    out = flash_attention(q, k, v, causal=causal, block_q=64, block_kv=64,
                          interpret=True)
    ref = flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


def test_flash_attention_kv_len_mask():
    """Padded KV positions beyond kv_len must not contribute."""
    keys = jax.random.split(jax.random.PRNGKey(5), 3)
    b, h, s, d = 1, 2, 64, 16
    q = jax.random.normal(keys[0], (b, h, s, d), jnp.float32)
    k = jax.random.normal(keys[1], (b, h, s, d), jnp.float32)
    v = jax.random.normal(keys[2], (b, h, s, d), jnp.float32)
    out = flash_attention(q, k, v, causal=False, kv_len=40, block_q=32,
                          block_kv=32, interpret=True)
    ref = flash_attention_ref(q, k, v, causal=False, kv_len=40)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


def test_flash_attention_decode_shape():
    """Sq=1 decode against a long cache (the serve_step attention pattern)."""
    keys = jax.random.split(jax.random.PRNGKey(6), 3)
    q = jax.random.normal(keys[0], (2, 4, 1, 32), jnp.float32)
    k = jax.random.normal(keys[1], (2, 2, 256, 32), jnp.float32)
    v = jax.random.normal(keys[2], (2, 2, 256, 32), jnp.float32)
    out = flash_attention(q, k, v, causal=False, kv_len=200, interpret=True)
    ref = flash_attention_ref(q, k, v, causal=False, kv_len=200)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)
