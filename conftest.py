"""Repo-root conftest: make `benchmarks` (and repo-root modules) importable
from tests regardless of PYTHONPATH.  Never set XLA flags here — smoke tests
and benches must see 1 device (dry-run tests spawn their own subprocesses)."""
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent))


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "slow: long-running integration tests (subprocess dry-run compiles)")
